"""Roofline aggregation: read experiments/dryrun/*.json and emit the
per-(arch x shape x mesh) table used in EXPERIMENTS.md SRoofline, plus a
kernel micro-benchmark (interpret-mode walltime is NOT a TPU number; it is
recorded only to satisfy the CSV contract and catch regressions)."""
from __future__ import annotations

import glob
import json
import os
import time

import numpy as np

from benchmarks.common import emit_csv_row, save_json

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load_records(mesh: str | None = None):
    recs = []
    for fn in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(fn) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def markdown_table(recs):
    lines = [
        "| arch | shape | mesh | ok | compute_s | memory_s | collective_s | dominant | useful | args GiB/dev | temps GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if not r.get("ok"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAIL | - | - | - | - | - | - | - |"
            )
            continue
        ro = r["roofline"]
        m = r["memory"]
        lines.append(
            "| {a} | {s} | {me} | ok | {c:.3e} | {mm:.3e} | {k:.3e} | {d} | {u:.2f} | {ab:.2f} | {tb:.2f} |".format(
                a=r["arch"], s=r["shape"], me=r["mesh"],
                c=ro["compute_s"], mm=ro["memory_s"], k=ro["collective_s"],
                d=ro["dominant"], u=ro["useful_ratio"],
                ab=m["argument_bytes"] / 2**30, tb=m["temp_bytes"] / 2**30,
            )
        )
    return "\n".join(lines)


def main(bench=None, seed: int = 0):
    recs = load_records()
    n_ok = sum(1 for r in recs if r.get("ok"))
    emit_csv_row("roofline/records", 0.0, f"{n_ok}/{len(recs)} combos ok")
    dominant_counts = {}
    for r in recs:
        if r.get("ok"):
            d = r["roofline"]["dominant"]
            dominant_counts[d] = dominant_counts.get(d, 0) + 1
    emit_csv_row("roofline/dominants", 0.0, str(dominant_counts))
    table = markdown_table(recs)
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/roofline_table.md", "w") as f:
        f.write(table + "\n")
    save_json("roofline_summary", {
        "n_ok": n_ok, "n_total": len(recs), "dominant_counts": dominant_counts,
    })

    # kernel micro-bench (interpret mode; CPU walltime, regression canary only)
    import jax
    import jax.numpy as jnp
    from repro.kernels.ops import flash_attention, ssd_scan

    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (1, 256, 4, 64))
    kk = jax.random.normal(k, (1, 256, 2, 64))
    v = jax.random.normal(k, (1, 256, 2, 64))
    out = flash_attention(q, kk, v, interpret=True)
    out.block_until_ready()
    t0 = time.time()
    for _ in range(3):
        flash_attention(q, kk, v, interpret=True).block_until_ready()
    emit_csv_row("kernels/flash_attention_interp", (time.time() - t0) / 3 * 1e6,
                 "B1 S256 H4/KH2 hd64 (CPU interpret mode)")

    x = jax.random.normal(k, (1, 128, 2, 32))
    dt = jax.nn.softplus(jax.random.normal(k, (1, 128, 2)))
    a = -jnp.exp(jax.random.normal(k, (2,)) * 0.3)
    b = jax.random.normal(k, (1, 128, 16))
    c = jax.random.normal(k, (1, 128, 16))
    y, _ = ssd_scan(x, dt, a, b, c, chunk=32, interpret=True)
    y.block_until_ready()
    t0 = time.time()
    for _ in range(3):
        ssd_scan(x, dt, a, b, c, chunk=32, interpret=True)[0].block_until_ready()
    emit_csv_row("kernels/ssd_scan_interp", (time.time() - t0) / 3 * 1e6,
                 "B1 S128 H2 P32 N16 (CPU interpret mode)")
    return {"n_ok": n_ok, "n_total": len(recs)}


if __name__ == "__main__":
    main()
