"""Split-executor benchmarks: 1F1B vs fill-drain, masked vs padded splits,
overlapped vs synchronous stage handoff, and vectorized plan scoring vs
the per-plan python loop.

Four measurements:

* ``pipeline_schedule`` - train-step wall clock of the fill-drain
  (GPipe + ``jax.grad``) reference vs the 1F1B executor on an S-stage
  mesh at M in {1, 4, 8} microbatches, for an EVEN split (padding-free,
  isolates the schedule/tick win) and an UNEVEN RL-style split (where
  fill-drain additionally pays padded max-length matmuls that 1F1B's
  active-length masking skips). Runs in a subprocess with a forced host
  device count (the parent backend typically has 1 device). Alongside
  the wall clocks it records the STRUCTURAL accounting - tick counts,
  padded vs active block-applies, bubble fractions - so accelerator
  targets can read the schedule win even where a 2-core CPU host is
  dispatch-bound.
* ``pipeline_transport`` - the 1F1B executor's double-buffered
  (``transport="overlap"``) vs synchronous (``transport="sync"``) stage
  handoff at S in {4, 8}, even and uneven splits, on forced CPU host
  devices. Each row carries the measured wall clock AND the structural
  link-model ratio from ``repro.core.transport.simulate_1f1b`` (the
  per-hop bandwidth/latency physics shared with ``plan_cost``); the
  structural ratio is >= 1 by construction (max <= sum per tick), the
  wall clock shows what a dispatch-bound CPU host realizes of it.
* ``plan_scoring`` - ``splitting.score_plans`` (one jitted vmap over the
  stacked enumeration) vs the per-plan ``plan_cost`` python loop at the
  acceptance point L=24, S=4 (1771 plans). Both sides warm.
* CI gate input: bench-smoke reads the per-run JSON and fails if
  1F1B/fill-drain < 1 at the largest measured M, or if the overlapped
  transport falls behind the synchronous one (structural ratio < 1, or
  wall clock below the shared-runner noise floor).

New baseline keys are recorded write-once into ``BENCH_throughput.json``
(never in ``--smoke``).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

from benchmarks.common import (
    BenchConfig, emit_csv_row, record_baseline, save_json, REPO_ROOT,
)


def _structural(schedule: str, m: int, lens) -> dict:
    """Tick counts / block-unit work / bubble fraction of one schedule.

    Block units weigh a backward block-apply at 2x a forward one. The
    fill-drain reference runs every stage padded to ``max(lens)`` blocks
    for ``M + S - 1`` forward ticks plus the same again reversed under
    ``jax.grad``; it also evaluates the LM head + loss on EVERY stage
    every forward tick (counted separately). 1F1B runs ``M + 2(S-1)``
    ticks; each microbatch costs a stage 1 forward + 1 rematerialized
    forward + 2 backward units over its ACTIVE length only (the last
    stage skips the standalone forward slot), and the head runs once per
    microbatch.
    """
    s = len(lens)
    max_len = max(lens)
    total = sum(lens)
    if schedule == "fill_drain":
        ticks = 2 * (m + s - 1)
        block_units = 3 * (m + s - 1) * s * max_len
        head_evals = (m + s - 1) * s
        bubble = (s - 1) / (s - 1 + m)
    else:
        ticks = m + 2 * (s - 1)
        block_units = m * (4 * total - lens[-1])
        head_evals = m
        bubble = 2 * (s - 1) / (m + 2 * (s - 1))
    return {"ticks": ticks, "block_units": block_units,
            "head_evals": head_evals, "bubble_fraction": bubble}


# Runs in a clean subprocess with a forced host device count (the parent
# has already initialized its 1-device CPU backend). Prints one RESULT
# json line with per-(split, M, schedule) step times.
_SCHEDULE_SNIPPET = """
import json, os, time
import jax, jax.numpy as jnp, numpy as np
from dataclasses import replace

from benchmarks.common import enable_persistent_cache

enable_persistent_cache()  # REPRO_JIT_CACHE_DIR rides the environment

from repro.configs import get_config
from repro.models import init_params
from repro.core.pipeline import (
    PipelineConfig, make_stage_mesh, pipeline_step_fn, stage_lengths,
)

SPEC = json.loads(os.environ["PIPE_BENCH_SPEC"])
cfg = replace(get_config(SPEC["arch"]).reduced(), num_layers=SPEC["layers"])
params = init_params(jax.random.PRNGKey(0), cfg)
mesh = make_stage_mesh(SPEC["stages"])
rng = np.random.default_rng(0)
out = []
for split_name, bounds in SPEC["splits"]:
    bounds = tuple(bounds)
    for m in SPEC["microbatches"]:
        tokens = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (m * SPEC["mb_rows"], SPEC["seq"])),
            jnp.int32)
        labels = jnp.asarray(
            rng.integers(0, cfg.vocab_size, tokens.shape), jnp.int32)
        row = {"split": split_name, "boundaries": list(bounds), "m": m,
               "lens": list(stage_lengths(bounds))}
        for sched in ("fill_drain", "1f1b"):
            step = jax.jit(pipeline_step_fn(
                cfg, mesh, bounds, m, pipe=PipelineConfig(schedule=sched)))
            t0 = time.perf_counter()
            l, g = step(params, tokens, labels)
            jax.block_until_ready(jax.tree.leaves(g)[0])
            compile_s = time.perf_counter() - t0
            best = float("inf")  # best-of-2 windows: shared-runner noise
            for _ in range(2):
                t0 = time.perf_counter()
                for _ in range(SPEC["reps"]):
                    l, g = step(params, tokens, labels)
                jax.block_until_ready(jax.tree.leaves(g)[0])
                best = min(best, (time.perf_counter() - t0) / SPEC["reps"])
            row[sched] = {"step_s": best, "compile_s": compile_s,
                          "loss": float(l)}
        row["speedup_1f1b"] = row["fill_drain"]["step_s"] / row["1f1b"]["step_s"]
        out.append(row)
print("RESULT " + json.dumps(out))
"""


def _time_schedules(bench: BenchConfig):
    if bench.smoke:
        spec = {"arch": "qwen2.5-3b", "layers": 4, "stages": 2,
                "splits": [["uneven", [3, 4]]], "microbatches": [1, 4],
                "mb_rows": 2, "seq": 16, "reps": 2}
    else:
        spec = {"arch": "qwen2.5-3b", "layers": 8, "stages": 4,
                "splits": [["even", [2, 4, 6, 8]], ["uneven", [5, 6, 7, 8]]],
                "microbatches": [1, 4, 8], "mb_rows": 2, "seq": 32,
                "reps": 3 if bench.quick else 6}
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={spec['stages']}"
    )
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src")
    env["PIPE_BENCH_SPEC"] = json.dumps(spec)
    out = subprocess.run([sys.executable, "-c", _SCHEDULE_SNIPPET],
                         capture_output=True, text=True, timeout=3000,
                         env=env, cwd=REPO_ROOT)
    if out.returncode != 0:
        raise RuntimeError(
            f"pipeline-schedule subprocess failed:\n{out.stderr[-3000:]}")
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][-1]
    rows = json.loads(line[len("RESULT "):])
    for row in rows:
        for sched in ("fill_drain", "1f1b"):
            row[sched]["structural"] = _structural(sched, row["m"],
                                                   row["lens"])
    return {"spec": spec, "rows": rows}


# Times the SAME 1F1B program under both transports in one subprocess
# (one forced device count per S). Prints one RESULT json line.
_TRANSPORT_SNIPPET = """
import json, os, time
import jax, jax.numpy as jnp, numpy as np
from dataclasses import replace

from benchmarks.common import enable_persistent_cache

enable_persistent_cache()

from repro.configs import get_config
from repro.models import init_params
from repro.core.pipeline import (
    PipelineConfig, make_stage_mesh, pipeline_step_fn, stage_lengths,
)

SPEC = json.loads(os.environ["PIPE_BENCH_SPEC"])
mesh = make_stage_mesh(SPEC["stages"])
rng = np.random.default_rng(0)
out = []
for split_name, bounds in SPEC["splits"]:
    bounds = tuple(bounds)
    # each split carries its own layer count (bounds end at num_layers;
    # S=8 has no even split of 9 layers)
    cfg = replace(get_config(SPEC["arch"]).reduced(), num_layers=bounds[-1])
    params = init_params(jax.random.PRNGKey(0), cfg)
    m = SPEC["microbatches"]
    tokens = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (m * SPEC["mb_rows"], SPEC["seq"])),
        jnp.int32)
    labels = jnp.asarray(
        rng.integers(0, cfg.vocab_size, tokens.shape), jnp.int32)
    row = {"split": split_name, "boundaries": list(bounds), "m": m,
           "lens": list(stage_lengths(bounds))}
    steps, best = {}, {}
    for tr in ("sync", "overlap"):
        steps[tr] = jax.jit(pipeline_step_fn(
            cfg, mesh, bounds, m, pipe=PipelineConfig(transport=tr)))
        t0 = time.perf_counter()
        l, g = steps[tr](params, tokens, labels)
        jax.block_until_ready(jax.tree.leaves(g)[0])
        row[tr] = {"compile_s": time.perf_counter() - t0, "loss": float(l)}
        best[tr] = float("inf")
    # best-of-5 INTERLEAVED windows: alternating the two transports inside
    # each window cancels machine-state drift (turbo, cache warmth) that a
    # sequential sync-then-overlap timing folds into the reported ratio
    for _ in range(5):
        for tr in ("sync", "overlap"):
            t0 = time.perf_counter()
            for _ in range(SPEC["reps"]):
                l, g = steps[tr](params, tokens, labels)
            jax.block_until_ready(jax.tree.leaves(g)[0])
            best[tr] = min(best[tr], (time.perf_counter() - t0) / SPEC["reps"])
    for tr in ("sync", "overlap"):
        row[tr]["step_s"] = best[tr]
    # wall ratio only: forced-CPU devices run synchronous collective-permute
    # (no async start/done), so this is parity +/- timer noise by
    # construction; the structural ratio is attached host-side as
    # row["speedup_overlap"] (see _time_transport)
    row["wall_speedup_overlap"] = row["sync"]["step_s"] / row["overlap"]["step_s"]
    out.append(row)
print("RESULT " + json.dumps(out))
"""


def _transport_model_ratio(stages: int, bounds, m: int, layers: int,
                           seed: int = 0) -> dict:
    """Structural overlap/sync ratio under the per-hop link model.

    Builds the SAME Eq. 8-11 physics the plan oracle prices
    (``plan_transport_model`` wraps ``plan_cost_parts``) on a heterogeneous
    link ladder - hop k at a different TDMA bandwidth plus a fixed link
    latency - and simulates both 1F1B transports. The ratio is >= 1 by
    construction: an overlapped tick pays max(compute, in-flight hop)
    where the synchronous tick pays the sum.
    """
    from repro.configs import get_config
    from repro.core.channel import NetworkConfig
    from repro.core.profiles import transformer_profile
    from repro.core.splitting import SplitPlan
    from repro.core.transport import plan_transport_model, simulate_1f1b
    from dataclasses import replace

    # heterogeneous ladder: every other hop at half bandwidth, 2 ms latency
    hop_bw = tuple(1e6 if k % 2 == 0 else 5e5 for k in range(stages - 1))
    net = NetworkConfig(num_devices=max(8, stages), max_split=stages,
                        hop_bandwidth=hop_bw, hop_latency=2e-3)
    cfg = replace(get_config("qwen2.5-3b").reduced(), num_layers=layers)
    prof = transformer_profile(cfg, batch=1, seq=512)
    u = net.num_devices
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0, net.area_m, (u + 1, 2))
    devices = tuple(int(d) for d in list(range(stages - 1)) + [u])
    p_tx = np.full((stages - 1,), 0.5)
    decoy = np.zeros((stages - 1, u + 1))
    model = plan_transport_model(prof, SplitPlan(tuple(bounds), devices),
                                 pos, p_tx, decoy, net)
    sync = simulate_1f1b(model, m, transport="sync")
    ovl = simulate_1f1b(model, m, transport="overlap")
    return {
        "hop_bandwidth_hz": list(hop_bw), "hop_latency_s": net.hop_latency,
        "sync_total_s": sync["total_s"], "overlap_total_s": ovl["total_s"],
        "model_speedup": sync["total_s"] / ovl["total_s"],
        "bubble_fraction": ovl["bubble_fraction"],
    }


def _time_transport(bench: BenchConfig):
    """Overlapped vs synchronous handoff at S in {4, 8} (subprocess per S).

    Two ratios per split, both recorded:

    * ``speedup_overlap`` (headline, >= 1 by construction): the
      STRUCTURAL overlap/sync ratio under the per-hop link model - each
      overlapped tick pays ``max(compute, in-flight hop)`` where the
      synchronous tick pays the sum, priced by the same Eq. 8-11 physics
      as ``plan_cost`` (``core.transport.simulate_1f1b``). This is what
      the wire delivers on a backend with async collectives.
    * ``wall_speedup_overlap``: the measured wall ratio on the forced-CPU
      stage mesh. XLA's CPU backend emits only SYNCHRONOUS
      collective-permute (no ``-start``/``-done`` pairs - pinned by
      ``test_overlap_issues_no_more_collectives_than_sync``), so wall is
      parity +/- timer noise here; it guards against the overlapped
      schedule REGRESSING (extra copies, bigger carries), not for the
      overlap win itself.
    """
    if bench.smoke:
        cases = [{"stages": 2, "microbatches": 4,
                  "splits": [["even", [2, 4]], ["uneven", [3, 4]]],
                  "mb_rows": 2, "seq": 16, "reps": 2}]
    else:
        cases = [
            {"stages": 4, "microbatches": 8,
             "splits": [["even", [2, 4, 6, 8]], ["uneven", [5, 6, 7, 8]]],
             "mb_rows": 2, "seq": 32, "reps": 3 if bench.quick else 6},
            {"stages": 8, "microbatches": 8,
             "splits": [["even", [1, 2, 3, 4, 5, 6, 7, 8]],
                        ["uneven", [2, 3, 4, 5, 6, 7, 8, 9]]],
             "mb_rows": 2, "seq": 32, "reps": 3 if bench.quick else 6},
        ]
    out = []
    for spec in cases:
        spec = dict(spec, arch="qwen2.5-3b")
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={spec['stages']}"
        )
        env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src")
        env["PIPE_BENCH_SPEC"] = json.dumps(spec)
        res = subprocess.run([sys.executable, "-c", _TRANSPORT_SNIPPET],
                             capture_output=True, text=True, timeout=3000,
                             env=env, cwd=REPO_ROOT)
        if res.returncode != 0:
            raise RuntimeError(
                f"pipeline-transport subprocess failed:\n{res.stderr[-3000:]}")
        line = [l for l in res.stdout.splitlines()
                if l.startswith("RESULT ")][-1]
        rows = json.loads(line[len("RESULT "):])
        for row in rows:
            row["model"] = _transport_model_ratio(
                spec["stages"], row["boundaries"], row["m"],
                layers=row["boundaries"][-1])
            row["speedup_overlap"] = row["model"]["model_speedup"]
        out.append({
            "spec": spec,
            "note": ("speedup_overlap is the structural overlap/sync ratio "
                     "under the per-hop link model (>= 1 by construction; "
                     "what an async backend delivers on the wire); "
                     "wall_speedup_overlap is the measured forced-CPU wall "
                     "ratio, parity +/- noise since the CPU backend runs "
                     "synchronous collective-permute"),
            "rows": rows,
        })
    return out


def _time_plan_scoring(bench: BenchConfig, seed: int):
    from repro.core.channel import NetworkConfig
    from repro.core.profiles import resnet101_profile, transformer_profile
    from repro.configs import get_config
    from repro.core.splitting import (
        SplitPlan, make_plan_scorer, plan_cost, stack_boundaries,
    )
    import jax

    l_layers, s = (10, 3) if bench.smoke else (24, 4)
    net = NetworkConfig()
    u = net.num_devices
    prof = transformer_profile(get_config("qwen2.5-3b"), batch=1, seq=2048)
    prof = prof if prof.num_layers >= l_layers else resnet101_profile(1)
    # score a fixed L-layer prefix enumeration of the profile
    bounds = stack_boundaries(l_layers, s)
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0, net.area_m, (u + 1, 2))
    devices = np.concatenate([np.arange(s - 1), [u]]).astype(np.int32)
    p_tx = np.full((s - 1,), 0.5)
    decoy = np.zeros((s - 1, u + 1))
    decoy[:, s] = 0.2

    # --- python loop (the seed's oracle-baseline pattern) ----------------
    def loop():
        out = []
        for b in bounds:
            plan = SplitPlan(tuple(int(x) for x in b), tuple(devices))
            out.append(plan_cost(prof, plan, pos, p_tx, decoy, net))
        return np.asarray(out)

    ref = loop()  # warm the per-op jit caches
    t0 = time.perf_counter()
    ref = loop()
    loop_s = time.perf_counter() - t0

    # --- vectorized: one dispatch over the whole stack -------------------
    scorer = make_plan_scorer(prof)
    t, e = scorer(bounds, devices, pos, p_tx, decoy, net)  # compile
    jax.block_until_ready(e)
    t0 = time.perf_counter()
    t, e = scorer(bounds, devices, pos, p_tx, decoy, net)
    jax.block_until_ready(e)
    vec_s = time.perf_counter() - t0

    err = float(np.abs(np.stack([np.asarray(t), np.asarray(e)], 1) - ref).max()
                / np.abs(ref).max())
    return {
        "layers": l_layers, "stages": s, "plans": int(bounds.shape[0]),
        "plan_cost_loop_s": loop_s, "score_plans_s": vec_s,
        "speedup": loop_s / vec_s, "traces": scorer.trace_count[0],
        "max_rel_err_vs_loop": err,
    }


def main(bench: BenchConfig = BenchConfig(), seed: int = 0,
         force: bool = False):
    sched = _time_schedules(bench)
    transport = _time_transport(bench)
    scoring = _time_plan_scoring(bench, seed)

    for row in sched["rows"]:
        emit_csv_row(
            f"pipeline/{row['split']}_m{row['m']}",
            1e6 * row["1f1b"]["step_s"],
            f"1f1b_step_s={row['1f1b']['step_s']:.3f} "
            f"speedup_vs_fill_drain={row['speedup_1f1b']:.2f}x "
            f"bubble={row['1f1b']['structural']['bubble_fraction']:.2f}"
            f"(vs {row['fill_drain']['structural']['bubble_fraction']:.2f})")
    for case in transport:
        for row in case["rows"]:
            emit_csv_row(
                f"pipeline/transport_s{case['spec']['stages']}_{row['split']}",
                1e6 * row["overlap"]["step_s"],
                f"overlap_step_s={row['overlap']['step_s']:.3f} "
                f"speedup_vs_sync={row['speedup_overlap']:.2f}x "
                f"wall={row['wall_speedup_overlap']:.2f}x")
    emit_csv_row(
        "pipeline/plan_scoring", 1e6 * scoring["score_plans_s"],
        f"plans={scoring['plans']} speedup={scoring['speedup']:.1f}x "
        f"traces={scoring['traces']}")

    payload = {"pipeline_schedule": sched, "pipeline_transport": transport,
               "plan_scoring": scoring}
    save_json("pipeline", payload)
    if not bench.smoke:
        record_baseline(payload, force=force)
    return payload


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--force", action="store_true",
                    help="re-record existing BENCH_throughput.json keys")
    ap.add_argument("--full", action="store_true",
                    help="non-quick rep counts")
    a = ap.parse_args()
    main(BenchConfig(quick=not a.full), force=a.force)
