"""Dropless vs capacity MoE dispatch throughput (tentpole PR 9).

One measurement, ``moe_dispatch``: tokens/sec through a single MoE layer
(``layers.moe_apply_dropless`` sort-based grouping + grouped block
matmul) against the classic capacity path (``layers.moe_apply``,
ceil(S*k*cf/E) buffer with token dropping) at TOKEN PARITY - the same
(B, S, D) input batch on both sides, jitted, warm. Alongside the wall
clocks it records what the capacity path silently drops at this group
size (the fraction of routed (token, choice) pairs beyond the buffer -
work the dropless path actually computes) and the bitwise parity of the
dropless grouped kernel against the dense per-expert reference
(``layers.moe_apply_dense``).

The dropless path computes T*k + E*(block_size-1) padded rows; the
capacity path computes B*E*C ≈ T*k*capacity_factor rows plus an
O(S*k*E) one-hot position cumsum - so dropless wins on compute even
before correctness (no silent drops, decode-consistent outputs; see the
retired jamba_decode xfail).

CI gate: dropless tokens/sec >= capacity tokens/sec, and the dropless
reference impl must stay bitwise-equal to the dense per-expert loop.
New baseline keys are recorded write-once into ``BENCH_throughput.json``
(never in ``--smoke``).
"""
from __future__ import annotations

import time
from dataclasses import replace

import numpy as np

from benchmarks.common import (
    BenchConfig, emit_csv_row, record_baseline, save_json,
)


def _time_dispatch(bench: BenchConfig, seed: int):
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import layers as L
    from repro.models.layers import moe_capacity
    from repro.models.model import init_block, signature

    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    # smoke keeps the FULL tensor sizes (compile dominates its walltime
    # anyway) and only trims the timing iterations: at toy token counts
    # the sort-dispatch fixed cost dominates and the capacity buffer
    # stops dropping, which inverts the comparison into noise
    b, s = 8, 256
    iters = 5 if bench.smoke else 20
    block_size = 128

    key = jax.random.PRNGKey(seed)
    params = init_block(key, cfg, signature(cfg)[0])["moe"]
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)), jnp.float32)

    impls = {
        "capacity": jax.jit(lambda p, xx: L.moe_apply(
            p, xx, replace(cfg, moe=replace(cfg.moe, dispatch="capacity")))),
        "dropless": jax.jit(lambda p, xx: L.moe_apply_dropless(
            p, xx, cfg, impl="reference", block_size=block_size)),
        "dropless_pallas": jax.jit(lambda p, xx: L.moe_apply_dropless(
            p, xx, cfg, impl="pallas", block_size=block_size)),
    }
    rows = {}
    for name, fn in impls.items():
        y, _ = fn(params, x)  # compile + warm
        jax.block_until_ready(y)
        best = np.inf  # min-of-reps damps shared-box scheduler noise
        for _ in range(3):
            t0 = time.perf_counter()
            for _ in range(iters):
                y, _ = fn(params, x)
            jax.block_until_ready(y)
            best = min(best, (time.perf_counter() - t0) / iters)
        rows[name] = {"apply_s": best, "tokens_per_sec": b * s / best}

    # what the capacity buffer silently drops at this group size (the
    # work dropless computes): routed choices whose position within
    # their expert exceeds the per-group capacity
    e, k = cfg.moe.num_experts, cfg.moe.top_k
    cap = moe_capacity(s, cfg)
    xt = x.reshape(b * s, cfg.d_model)
    _, ids, _ = L._moe_route(params, xt, cfg)
    ids_g = ids.reshape(b, s * k)  # per-group (= batch row) token-major
    onehot = jax.nn.one_hot(ids_g, e, dtype=jnp.int32)
    pos = jnp.take_along_axis(
        jnp.cumsum(onehot, axis=1) - 1, ids_g[..., None], axis=2)[..., 0]
    dropped = float(jnp.mean(pos >= cap))

    # bitwise parity of the grouped paths against the dense per-expert loop
    y_dense, _ = jax.jit(lambda p, xx: L.moe_apply_dense(p, xx, cfg))(params, x)
    y_ref, _ = impls["dropless"](params, x)
    y_pal, _ = impls["dropless_pallas"](params, x)
    bitwise_ref = bool(jnp.array_equal(y_dense, y_ref))
    err_pal = float(jnp.max(jnp.abs(y_pal - y_dense)))

    return {
        "config": cfg.name, "batch": b, "seq": s, "tokens": b * s,
        "num_experts": e, "top_k": k, "capacity": int(cap),
        "capacity_factor": cfg.moe.capacity_factor,
        "block_size": block_size, "iters": iters,
        "rows": rows,
        "speedup_dropless": (rows["dropless"]["tokens_per_sec"]
                             / rows["capacity"]["tokens_per_sec"]),
        "capacity_dropped_fraction": dropped,
        "dropless_bitwise_vs_dense": bitwise_ref,
        "pallas_max_err_vs_dense": err_pal,
    }


def main(bench: BenchConfig = BenchConfig(), seed: int = 0,
         force: bool = False):
    res = _time_dispatch(bench, seed)
    for name, row in res["rows"].items():
        emit_csv_row(
            f"moe_dispatch/{name}", 1e6 * row["apply_s"],
            f"tokens_per_sec={row['tokens_per_sec']:.0f}")
    emit_csv_row(
        "moe_dispatch/summary", 1e6 * res["rows"]["dropless"]["apply_s"],
        f"speedup_dropless={res['speedup_dropless']:.2f}x "
        f"dropped={res['capacity_dropped_fraction']:.3f} "
        f"bitwise={res['dropless_bitwise_vs_dense']}")

    payload = {"moe_dispatch": res}
    save_json("moe_dispatch", payload)
    if not bench.smoke:
        record_baseline(payload, force=force)
    return payload


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--force", action="store_true",
                    help="re-record existing BENCH_throughput.json keys")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    main(BenchConfig(quick=not args.full), seed=args.seed, force=args.force)
