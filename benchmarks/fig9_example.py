"""Fig. 9: qualitative placement example - a trained policy's device
selection and split sizes on a fixed geometry.

Checks the paper's qualitative claims: trainers sit far from eavesdroppers,
decoys sit close to them, and larger sub-models go to safer devices.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchConfig, emit_csv_row, save_json
from repro.core.agents import action_space as A
from repro.core.agents import sac as SAC
from repro.core.agents.loops import train_sac
from repro.core.agents.sac import SACConfig
from repro.core.env import MHSLEnv
from repro.core.profiles import resnet101_profile


def main(bench: BenchConfig = BenchConfig(), seed: int = 0):
    env = MHSLEnv(profile=resnet101_profile(batch=1))
    cfg = SACConfig()
    res = train_sac(env, cfg, episodes=bench.episodes, warmup_episodes=bench.warmup,
                    seed=seed, num_envs=bench.num_envs)
    params = res.params

    key = jax.random.PRNGKey(99)
    st = env.reset(jax.random.PRNGKey(0))
    pair_dim = env.obs_dim + A.flat_dim(env.action_dims)
    hist = jnp.zeros((cfg.hist_len, pair_dim))
    hmask = jnp.zeros((cfg.hist_len,))
    decoy_usage = np.zeros(env.U)
    for t in range(env.episode_len):
        key, ka, ks = jax.random.split(key, 3)
        obs = env.observe(st)
        masks = env.action_masks(st)
        a = SAC.select_action(params, ka, obs, hist, hmask, masks, env.action_dims, cfg)
        decoy_usage += np.asarray(a["decoys"]) * np.asarray(masks["decoys"])
        pair = jnp.concatenate([obs, A.onehot(a, env.action_dims)])
        hist = jnp.roll(hist, -1, axis=0).at[-1].set(pair)
        hmask = jnp.roll(hmask, -1).at[-1].set(1.0)
        st, *_ = env.step(st, a, ks)

    dev_pos = np.asarray(st.dev_pos)
    eav_pos = np.asarray(st.eav_pos)
    stage_dev = [int(d) for d in np.asarray(st.stage_dev)]
    boundaries = [int(b) for b in np.asarray(st.boundaries)]
    trainers = [d for d in stage_dev if d < env.U]
    decoys = [i for i in range(env.U) if decoy_usage[i] > 0 and i not in trainers]

    def min_dist_to_eave(i):
        return float(np.linalg.norm(eav_pos - dev_pos[i], axis=1).min())

    d_train = np.mean([min_dist_to_eave(i) for i in trainers]) if trainers else 0.0
    d_decoy = np.mean([min_dist_to_eave(i) for i in decoys]) if decoys else 0.0
    payload = {
        "dev_pos": dev_pos.tolist(),
        "eav_pos": eav_pos.tolist(),
        "stage_devices": stage_dev,
        "boundaries": boundaries,
        "decoy_usage": decoy_usage.tolist(),
        "mean_trainer_dist_to_eave": d_train,
        "mean_decoy_dist_to_eave": d_decoy,
    }
    save_json("fig9_example", payload)
    emit_csv_row(
        "fig9/summary", 0.0,
        f"trainer_eave_dist={d_train:.0f}m decoy_eave_dist={d_decoy:.0f}m "
        f"plan={boundaries} devices={stage_dev}",
    )
    return payload


if __name__ == "__main__":
    main()
