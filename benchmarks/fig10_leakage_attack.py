"""Fig. 10 (repo extension): analytic vs attacker-measured leakage per cut.

Trains the FSHA-style reconstruction adversary population of
``repro.attack`` - ONE attacker per (cut point x monitoring scenario),
all in ONE jitted dispatch - against the smashed activations of a
reduced transformer, then prices every cut point of an 8-stage split
plan with BOTH :class:`LeakageModel` implementations on the same
:class:`HopGeometry`:

* ``analytic``: the paper's closed-form Eq. 30 with the profile's
  assumed depth-decaying ``leak_norm`` table;
* ``empirical``: identical wireless physics, per-layer values replaced
  by the trained attacker's measured reconstruction accuracy.

Emits one CSV row per cut (analytic, empirical, raw attack accuracy at
both capture levels) and a JSON with the training MSE quarters and the
trace count - the CI smoke gate asserts the attacker actually learns
(MSE decreasing monotonically-on-average) inside a single compiled
trace. Outside smoke mode the vmapped-population training rate is
recorded as the write-once ``attacker_population`` entry of
``BENCH_throughput.json``.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    BenchConfig, Timer, emit_csv_row, record_baseline, save_json,
)
from repro.attack import (
    capture_weight, empirical_model_from, tiny_attack_model_cfg,
    train_attacker_population,
)
from repro.core.channel import NetworkConfig
from repro.core.leakage import AnalyticLeakage, evaluate_leakage, plan_hop_geometry
from repro.core.profiles import transformer_profile
from repro.core.scenario import scenario_from_net

DEPTH = 8
QS = (0.3, 0.8)  # monitoring probabilities -> attacker capture scenarios


def _plan_and_scenario(net: NetworkConfig):
    """One 8-stage plan (one layer per stage -> a hop at EVERY cut) over a
    deterministic line-of-devices geometry with two eavesdroppers."""
    n_dev = DEPTH
    xs = jnp.linspace(60.0, 440.0, n_dev)
    dev_pos = jnp.stack([xs, jnp.full((n_dev,), 250.0)], axis=1)
    eav_pos = jnp.asarray([[150.0, 150.0], [350.0, 360.0]])[: net.num_eaves]
    boundaries = jnp.arange(1, DEPTH + 1)
    devices = jnp.arange(DEPTH)
    decoy_p = jnp.zeros((n_dev,)).at[0].set(0.2).at[n_dev - 1].set(0.2)
    plan = plan_hop_geometry(boundaries, devices, dev_pos, eav_pos,
                             p_tx=0.5, decoy_p=decoy_p)
    sc = scenario_from_net(net)
    sc = sc._replace(eave_mask=jnp.ones((net.num_eaves,)))
    return plan, sc


def main(bench: BenchConfig = BenchConfig(), seed: int = 0):
    steps = 200 if bench.smoke else 600
    cuts = np.arange(1, DEPTH)
    model_cfg = tiny_attack_model_cfg(depth=DEPTH)
    cw = [capture_weight(q) for q in QS]

    res = train_attacker_population(model_cfg, cuts=cuts, capture_weights=cw,
                                    steps=steps, seed=seed)
    hi = int(np.argmax(cw))  # highest-capture scenario prices the hops

    prof = transformer_profile(model_cfg, batch=1, seq=64)
    analytic = AnalyticLeakage.for_profile(prof)
    empirical = empirical_model_from(res, scenario_idx=hi)

    net = NetworkConfig()
    plan, sc = _plan_and_scenario(net)
    rows = {}
    for qi, q in enumerate(QS):
        scq = sc._replace(monitor_prob=jnp.full((net.num_eaves,), q))
        la = np.asarray(evaluate_leakage(analytic, scq, plan))
        le = np.asarray(evaluate_leakage(empirical, scq, plan))
        rows[q] = {"analytic": la.tolist(), "empirical": le.tolist()}
        if qi == len(QS) - 1:
            for k, cut in enumerate(cuts):
                emit_csv_row(
                    f"fig10/cut={cut}", 0.0,
                    f"analytic={la[k]:.4f} empirical={le[k]:.4f} "
                    + " ".join(f"score(q={QS[s]})={res.scores[k, s]:.3f}"
                               for s in range(len(QS))),
                )

    # training-health trace for the CI gate: mean recon MSE of the
    # high-capture attackers in step quarters, + the 1-trace audit
    mse_hi = res.recon_mse[:, hi, :].mean(axis=0)
    quarters = mse_hi.reshape(4, -1).mean(axis=1)
    payload = {
        "cuts": cuts.tolist(),
        "qs": list(QS),
        "capture_weights": res.capture_weights.tolist(),
        "scores": res.scores.tolist(),
        "final_mse": res.final_mse.tolist(),
        "rows": rows,
        "mse_quarters": quarters.tolist(),
        "attacker_traces": res.trace_count[0],
        "population": res.population,
        "steps": steps,
        "train_seconds": res.seconds,
    }
    save_json("fig10_leakage_attack", payload)
    emit_csv_row(
        "fig10/summary", res.seconds * 1e6 / max(res.population * steps, 1),
        f"population={res.population} traces={res.trace_count[0]} "
        f"mse_quarters={'/'.join(f'{m:.3f}' for m in quarters)}",
    )

    if not bench.smoke:
        # write-once throughput entry: vmapped population rate vs a
        # single-attacker run of the same chunk (both include compile)
        with Timer() as t:
            train_attacker_population(model_cfg, cuts=cuts[:1],
                                      capture_weights=cw[:1], steps=steps,
                                      seed=seed)
        single_rate = steps / max(t.seconds, 1e-9)
        pop_rate = res.population * steps / max(res.seconds, 1e-9)
        record_baseline({
            "attacker_population": {
                "population": res.population,
                "steps": steps,
                "pop_steps_per_s": pop_rate,
                "single_steps_per_s": single_rate,
                "vmap_speedup": pop_rate / single_rate,
            }
        })
    return payload


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    main(BenchConfig(smoke=a.smoke), seed=a.seed)
