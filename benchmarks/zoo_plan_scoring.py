"""Architecture-aware split-plan scoring across the model zoo (PR 9).

One measurement, ``zoo_plan_scoring``: for four heterogeneous zoo
configs - pure attention (qwen2.5-3b), attention+MoE (qwen3-moe-30b),
pure SSM (mamba2-370m), hybrid SSM/MoE (jamba-v0.1-52b) - score the FULL
(L-1 choose S-1) cut-point enumeration through ``make_plan_scorer``
under a nonzero ``NetworkConfig.state_cycles_per_bit`` (the
architecture-aware pricing knob: attention KV, SSM scan state, and MoE
resident expert banks all enter the Eq. 8-9 compute terms through
``ProfileTable.state_cum``). Per config it records plans/sec and the
scorer's compiled-trace count, which must be EXACTLY 1 - the whole
enumeration runs as one jitted vmap per profile.

To show the pricing actually differentiates block types (not just adds a
constant), each config also records its best-plan boundaries with state
pricing OFF (the homogeneous seed behaviour) and ON: configs whose
blocks carry unequal resident state (MoE banks vs dense, KV vs SSM
state) shift their optimal cuts, and the per-block-kind state histogram
explains why.

CI gate: >= 4 configs, each scored in exactly 1 compiled trace with the
full enumeration. New baseline keys are recorded write-once into
``BENCH_throughput.json`` (never in ``--smoke``).
"""
from __future__ import annotations

import time
from dataclasses import replace

import numpy as np

from benchmarks.common import (
    BenchConfig, emit_csv_row, record_baseline, save_json,
)

ZOO = [
    "qwen2.5-3b",       # pure attention
    "qwen3-moe-30b-a3b",  # attention + MoE expert banks
    "mamba2-370m",      # pure SSM
    "jamba-v0.1-52b",   # hybrid SSM/attention + MoE
]

# resident-state maintenance cycles per bit: visible against the Eq. 8
# FLOP term at paper scale without drowning it
STATE_CYCLES_PER_BIT = 0.01


def _score_zoo(bench: BenchConfig, seed: int):
    import jax
    from repro.configs import get_config
    from repro.core.channel import NetworkConfig
    from repro.core.profiles import (
        KIND_NAMES, profile_table, transformer_profile,
    )
    from repro.core.splitting import make_plan_scorer, stack_boundaries

    s = 3 if bench.smoke else 4
    rng = np.random.default_rng(seed)
    net0 = NetworkConfig(max_split=s)
    net1 = replace(net0, state_cycles_per_bit=STATE_CYCLES_PER_BIT)
    u = net0.num_devices
    pos = rng.uniform(0, net0.area_m, (u + 1, 2))
    devices = np.concatenate([np.arange(s - 1), [u]]).astype(np.int32)
    p_tx = np.full((s - 1,), 0.5)
    decoy = np.zeros((s - 1, u + 1))
    decoy[:, s] = 0.2

    configs = []
    for name in ZOO:
        cfg = get_config(name)
        prof = transformer_profile(cfg, batch=1, seq=2048)
        tab = profile_table(prof)
        bounds = stack_boundaries(cfg.num_layers, s)  # FULL enumeration

        scorer = make_plan_scorer(prof)
        t, e = scorer(bounds, devices, pos, p_tx, decoy, net1)  # compile
        jax.block_until_ready(e)
        t0 = time.perf_counter()
        t, e = scorer(bounds, devices, pos, p_tx, decoy, net1)
        jax.block_until_ready(e)
        dt = time.perf_counter() - t0

        # best plan with pricing OFF (homogeneous seed behaviour) vs ON
        t0_, _ = scorer(bounds, devices, pos, p_tx, decoy, net0)
        best_off = bounds[int(np.argmin(np.asarray(t0_)))]
        best_on = bounds[int(np.argmin(np.asarray(t)))]

        kinds = np.asarray(tab.kind)
        state_by_kind = {
            KIND_NAMES[kv]: float(np.asarray(tab.state_bits)[kinds == kv].sum())
            for kv in sorted(set(int(k) for k in kinds))
        }
        configs.append({
            "config": name, "layers": cfg.num_layers, "stages": s,
            "plans": int(bounds.shape[0]), "score_s": dt,
            "plans_per_sec": bounds.shape[0] / dt,
            "traces": scorer.trace_count[0],
            "best_boundaries_homogeneous": [int(b) for b in best_off],
            "best_boundaries_state_priced": [int(b) for b in best_on],
            "cut_moved": bool(np.any(best_off != best_on)),
            "state_bits_by_kind": state_by_kind,
        })
    return {"state_cycles_per_bit": STATE_CYCLES_PER_BIT, "stages": s,
            "configs": configs}


def main(bench: BenchConfig = BenchConfig(), seed: int = 0,
         force: bool = False):
    res = _score_zoo(bench, seed)
    for row in res["configs"]:
        emit_csv_row(
            f"zoo_plan_scoring/{row['config']}", 1e6 * row["score_s"],
            f"plans={row['plans']} plans_per_sec={row['plans_per_sec']:.0f} "
            f"traces={row['traces']} cut_moved={row['cut_moved']}")

    payload = {"zoo_plan_scoring": res}
    save_json("zoo_plan_scoring", payload)
    if not bench.smoke:
        record_baseline(payload, force=force)
    return payload


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--force", action="store_true",
                    help="re-record existing BENCH_throughput.json keys")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    main(BenchConfig(quick=not args.full), seed=args.seed, force=args.force)
