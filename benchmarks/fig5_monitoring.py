"""Fig. 5: information leaked vs eavesdropper monitoring probability.

Agents (ICM-CA, plain SAC, PPO) are trained at q=0.8 (Table I) and
evaluated across q in {0.3 .. 0.9}. Paper claims ICM-CA leaks ~13% less
than SAC and ~22% less than PPO.

The q sweep rides the scenario API: all five points are a stacked
``ScenarioParams`` batch evaluated in ONE jitted call per agent
(``evaluate_population``) - no env re-instantiation, no per-point
recompile, and PPO evaluates on the same vectorized rollout engine as
the SAC agents (the seed's per-step host eval loop is gone).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    BenchConfig, emit_csv_row, save_json, train_standard_agents,
)
from repro.core.agents import rollout as R
from repro.core.agents.ppo import ppo_policy
from repro.core.env import MHSLEnv
from repro.core.profiles import resnet101_profile
from repro.core.scenario import evaluate_population, scenario_grid, stack_scenarios

QS = [0.3, 0.45, 0.6, 0.75, 0.9]


def main(bench: BenchConfig = BenchConfig(), seed: int = 0):
    prof = resnet101_profile(batch=1)
    # --leakage empirical swaps the paper's closed-form per-layer values
    # for the trained attacker population's measurements; everything
    # downstream (training, the q sweep, the derived reductions) is
    # identical because both ride the same LeakageModel API
    env = MHSLEnv(profile=prof, leakage_model=bench.leakage_model(seed))
    adims = env.action_dims

    agents = train_standard_agents(env, bench, seed,
                                   algos=("icm_ca", "sac", "ppo"),
                                   ckpt_ns="fig5")
    scenarios = stack_scenarios(scenario_grid(env.scenario(), monitor_prob=QS))

    leak = {}
    for name in ("icm_ca", "sac"):
        a = agents[name]
        leak[name] = evaluate_population(
            env, R.sac_policy(adims, a["cfg"]), a["params"], scenarios,
            episodes=bench.eval_episodes, hist_len=a["cfg"].hist_len,
        )["leak"]
    leak["ppo"] = evaluate_population(
        env, ppo_policy(adims), agents["ppo"]["params"], scenarios,
        episodes=bench.eval_episodes, seed=500,
    )["leak"]

    rows = {}
    for i, q in enumerate(QS):
        rows[q] = {name: float(leak[name][i]) for name in leak}
        emit_csv_row(
            f"fig5/q={q}", 0.0,
            " ".join(f"{k}={v:.3f}" for k, v in rows[q].items()),
        )

    mean = {k: float(np.mean([rows[q][k] for q in QS])) for k in rows[QS[0]]}
    derived = {
        "mean_leak": mean,
        "reduction_vs_sac_pct": 100 * (mean["sac"] - mean["icm_ca"]) / max(mean["sac"], 1e-9),
        "reduction_vs_ppo_pct": 100 * (mean["ppo"] - mean["icm_ca"]) / max(mean["ppo"], 1e-9),
    }
    save_json("fig5_monitoring",
              {"rows": rows, "derived": derived, "leakage": bench.leakage})
    emit_csv_row("fig5/summary", 0.0,
                 f"leak_reduction_vs_sac={derived['reduction_vs_sac_pct']:.1f}% "
                 f"vs_ppo={derived['reduction_vs_ppo_pct']:.1f}%")
    return derived


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--leakage", default="analytic",
                    choices=("analytic", "empirical"))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    main(BenchConfig(smoke=a.smoke, leakage=a.leakage), seed=a.seed)
