"""Fig. 5: information leaked vs eavesdropper monitoring probability.

Agents (ICM-CA, plain SAC, PPO) are trained at q=0.8 (Table I) and
evaluated across q in {0.3 .. 0.9}. Paper claims ICM-CA leaks ~13% less
than SAC and ~22% less than PPO.
"""
from __future__ import annotations

from dataclasses import replace

import numpy as np

from benchmarks.common import BenchConfig, emit_csv_row, save_json
from repro.core.agents import action_space as A
from repro.core.agents.loops import evaluate_sac, train_sac
from repro.core.agents.ppo import PPOConfig, make_ppo_update, ppo_logits, train_ppo
from repro.core.agents.sac import SACConfig
from repro.core.channel import NetworkConfig
from repro.core.env import MHSLEnv
from repro.core.profiles import resnet101_profile

QS = [0.3, 0.45, 0.6, 0.75, 0.9]


def _eval_ppo(env, params, episodes, seed=500):
    import jax
    import jax.numpy as jnp

    key = jax.random.PRNGKey(seed)
    adims = env.action_dims
    env_step = jax.jit(env.step)
    tot_leak = 0.0
    for ep in range(episodes):
        key, kr = jax.random.split(key)
        st = env.reset(kr)
        for t in range(env.episode_len):
            key, ka, ks = jax.random.split(key, 3)
            obs = env.observe(st)
            masks = env.action_masks(st)
            logits = ppo_logits(params, obs, masks, adims)
            a = A.sample(ka, logits)
            st, r, done, info = env_step(st, a, ks)
            tot_leak += float(info["leak"])
    return tot_leak / episodes


def main(bench: BenchConfig = BenchConfig(), seed: int = 0):
    prof = resnet101_profile(batch=1)
    env = MHSLEnv(profile=prof)

    agents = {}
    cfg_full = SACConfig()
    agents["icm_ca"] = (train_sac(env, cfg_full, episodes=bench.episodes,
                                  warmup_episodes=bench.warmup, seed=seed,
                                  num_envs=bench.num_envs).params, cfg_full)
    cfg_plain = SACConfig(use_icm=False, use_ca=False)
    agents["sac"] = (train_sac(env, cfg_plain, episodes=bench.episodes,
                               warmup_episodes=bench.warmup, seed=seed,
                               num_envs=bench.num_envs).params, cfg_plain)
    ppo_params = train_ppo(env, PPOConfig(), episodes=bench.episodes, seed=seed,
                           num_envs=bench.num_envs).params

    rows = {}
    for q in QS:
        env_q = MHSLEnv(profile=prof, net=replace(NetworkConfig(), monitor_prob=q))
        row = {}
        for name, (params, cfg) in agents.items():
            row[name] = evaluate_sac(env_q, params, cfg, episodes=bench.eval_episodes)["leak"]
        row["ppo"] = _eval_ppo(env_q, ppo_params, bench.eval_episodes)
        rows[q] = row
        emit_csv_row(
            f"fig5/q={q}", 0.0,
            " ".join(f"{k}={v:.3f}" for k, v in row.items()),
        )

    mean = {k: float(np.mean([rows[q][k] for q in QS])) for k in rows[QS[0]]}
    derived = {
        "mean_leak": mean,
        "reduction_vs_sac_pct": 100 * (mean["sac"] - mean["icm_ca"]) / max(mean["sac"], 1e-9),
        "reduction_vs_ppo_pct": 100 * (mean["ppo"] - mean["icm_ca"]) / max(mean["ppo"], 1e-9),
    }
    save_json("fig5_monitoring", {"rows": rows, "derived": derived})
    emit_csv_row("fig5/summary", 0.0,
                 f"leak_reduction_vs_sac={derived['reduction_vs_sac_pct']:.1f}% "
                 f"vs_ppo={derived['reduction_vs_ppo_pct']:.1f}%")
    return derived


if __name__ == "__main__":
    main()
