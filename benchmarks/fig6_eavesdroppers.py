"""Fig. 6: information leaked vs number of eavesdroppers (1..4).

The observation dimension depends on E, so each point trains fresh agents.
Paper claims gaps grow with E: up to 18% less leakage than SAC and 30%
less than PPO at E=4.
"""
from __future__ import annotations

from dataclasses import replace

import numpy as np

from benchmarks.common import BenchConfig, emit_csv_row, save_json
from repro.core.agents.loops import evaluate_sac, train_sac
from repro.core.agents.ppo import PPOConfig, train_ppo
from repro.core.agents.sac import SACConfig
from repro.core.channel import NetworkConfig
from repro.core.env import MHSLEnv
from repro.core.profiles import resnet101_profile

ES = [1, 2, 3, 4]


def main(bench: BenchConfig = BenchConfig(), seed: int = 0):
    prof = resnet101_profile(batch=1)
    episodes = max(bench.episodes // 2, 40)
    rows = {}
    for e in ES:
        env = MHSLEnv(profile=prof, net=replace(NetworkConfig(), num_eaves=e))
        row = {}
        cfg = SACConfig()
        res = train_sac(env, cfg, episodes=episodes, warmup_episodes=bench.warmup,
                        seed=seed, num_envs=bench.num_envs)
        row["icm_ca"] = float(np.mean(res.episode_leak[-10:]))
        cfg_p = SACConfig(use_icm=False, use_ca=False)
        res = train_sac(env, cfg_p, episodes=episodes, warmup_episodes=bench.warmup,
                        seed=seed, num_envs=bench.num_envs)
        row["sac"] = float(np.mean(res.episode_leak[-10:]))
        res = train_ppo(env, PPOConfig(), episodes=episodes, seed=seed,
                        num_envs=bench.num_envs)
        row["ppo"] = float(np.mean(res.episode_leak[-10:]))
        rows[e] = row
        emit_csv_row(f"fig6/E={e}", 0.0, " ".join(f"{k}={v:.3f}" for k, v in row.items()))

    last = rows[ES[-1]]
    derived = {
        "rows": rows,
        "reduction_vs_sac_at_E4_pct": 100 * (last["sac"] - last["icm_ca"]) / max(last["sac"], 1e-9),
        "reduction_vs_ppo_at_E4_pct": 100 * (last["ppo"] - last["icm_ca"]) / max(last["ppo"], 1e-9),
    }
    save_json("fig6_eavesdroppers", derived)
    emit_csv_row("fig6/summary", 0.0,
                 f"E4_reduction_vs_sac={derived['reduction_vs_sac_at_E4_pct']:.1f}% "
                 f"vs_ppo={derived['reduction_vs_ppo_at_E4_pct']:.1f}%")
    return derived


if __name__ == "__main__":
    main()
