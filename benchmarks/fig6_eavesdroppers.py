"""Fig. 6: information leaked vs number of eavesdroppers (1..4).

Paper claims gaps grow with E: up to 18% less leakage than SAC and 30%
less than PPO at E=4.

The sweep runs in ONE padded environment (E_max = 4) whose
``ScenarioParams.eave_mask`` activates 1..4 eavesdroppers - padded
entries are bit-equivalent to a smaller env (per-eavesdropper PRNG
folding in ``sample_leakage``), so no env is re-instantiated and the
observation space stays fixed across the sweep. The SAC agents train as
a 4-scenario population in lockstep on device (``train_population``,
one compile for all points); PPO has no population trainer yet, so it
trains per-point via the ``scenario`` runtime argument - each
``train_ppo`` call still builds its own jits, but the padded env keeps
the agents comparable across E.
"""
from __future__ import annotations

from dataclasses import replace

import numpy as np

from benchmarks.common import (
    BenchConfig, emit_csv_row, save_json, train_standard_agents,
)
from repro.core.agents.sac import SACConfig
from repro.core.channel import NetworkConfig
from repro.core.env import MHSLEnv
from repro.core.profiles import resnet101_profile
from repro.core.scenario import (
    scenario_grid, stack_scenarios, train_population,
)

ES = [1, 2, 3, 4]
E_MAX = 4


def main(bench: BenchConfig = BenchConfig(), seed: int = 0):
    prof = resnet101_profile(batch=1)
    # bench.leakage selects the hop-pricing model (analytic | empirical
    # attacker measurements) through the same LeakageModel API as fig5
    env = MHSLEnv(profile=prof, net=replace(NetworkConfig(), num_eaves=E_MAX),
                  leakage_model=bench.leakage_model(seed))
    # smoke mode keeps the tiny count - flooring it back to 40 would defeat
    # the CI rot-detector's minutes-on-CPU contract
    episodes = bench.episodes if bench.smoke else max(bench.episodes // 2, 40)
    scens = scenario_grid(env.scenario(), active_eaves=ES)
    stacked = stack_scenarios(scens)

    def last10(res):
        return float(np.mean(res.episode_leak[-10:]))

    pops = {
        "icm_ca": train_population(
            env, SACConfig(), stacked, episodes=episodes,
            warmup_episodes=bench.warmup, seed=seed, num_envs=bench.num_envs,
            mesh=bench.mesh(), checkpoint_dir=bench.ckpt("fig6/icm_ca"),
            checkpoint_every=bench.checkpoint_every),
        "sac": train_population(
            env, SACConfig(use_icm=False, use_ca=False), stacked,
            episodes=episodes, warmup_episodes=bench.warmup, seed=seed,
            num_envs=bench.num_envs, mesh=bench.mesh(),
            checkpoint_dir=bench.ckpt("fig6/sac"),
            checkpoint_every=bench.checkpoint_every),
    }
    rows = {e: {name: last10(pop.results[i]) for name, pop in pops.items()}
            for i, e in enumerate(ES)}
    for i, e in enumerate(ES):
        ppo = train_standard_agents(env, bench, seed, episodes=episodes,
                                    algos=("ppo",), scenario=scens[i])
        rows[e]["ppo"] = last10(ppo["ppo"]["result"])
        emit_csv_row(f"fig6/E={e}", 0.0,
                     " ".join(f"{k}={v:.3f}" for k, v in rows[e].items()))

    last = rows[ES[-1]]
    derived = {
        "rows": rows,
        "leakage": bench.leakage,
        "reduction_vs_sac_at_E4_pct": 100 * (last["sac"] - last["icm_ca"]) / max(last["sac"], 1e-9),
        "reduction_vs_ppo_at_E4_pct": 100 * (last["ppo"] - last["icm_ca"]) / max(last["ppo"], 1e-9),
    }
    save_json("fig6_eavesdroppers", derived)
    emit_csv_row("fig6/summary", 0.0,
                 f"E4_reduction_vs_sac={derived['reduction_vs_sac_at_E4_pct']:.1f}% "
                 f"vs_ppo={derived['reduction_vs_ppo_at_E4_pct']:.1f}%")
    return derived


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--leakage", default="analytic",
                    choices=("analytic", "empirical"))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    main(BenchConfig(smoke=a.smoke, leakage=a.leakage), seed=a.seed)
