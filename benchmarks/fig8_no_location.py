"""Fig. 8: training without eavesdropper location information.

Paper claims similar convergence rate with ~12% lower accumulated reward
around epoch 25.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import BenchConfig, emit_csv_row, save_json
from repro.core.agents.loops import train_sac
from repro.core.agents.sac import SACConfig
from repro.core.env import MHSLEnv
from repro.core.profiles import resnet101_profile


def main(bench: BenchConfig = BenchConfig(), seed: int = 0):
    prof = resnet101_profile(batch=1)
    res_known = train_sac(MHSLEnv(profile=prof, know_eave_locations=True),
                          SACConfig(), episodes=bench.episodes,
                          warmup_episodes=bench.warmup, seed=seed,
                          num_envs=bench.num_envs)
    res_blind = train_sac(MHSLEnv(profile=prof, know_eave_locations=False),
                          SACConfig(), episodes=bench.episodes,
                          warmup_episodes=bench.warmup, seed=seed,
                          num_envs=bench.num_envs)
    known = float(np.mean(res_known.episode_reward[-10:]))
    blind = float(np.mean(res_blind.episode_reward[-10:]))
    derived = {
        "known_curve": res_known.episode_reward,
        "blind_curve": res_blind.episode_reward,
        "final_known": known,
        "final_blind": blind,
        "reward_drop_pct": 100 * (known - blind) / max(abs(known), 1e-9),
    }
    save_json("fig8_no_location", derived)
    emit_csv_row("fig8/summary", 0.0,
                 f"known={known:.2f} blind={blind:.2f} drop={derived['reward_drop_pct']:.1f}%")
    return derived


if __name__ == "__main__":
    main()
