"""Fig. 8: training without eavesdropper location information.

Paper claims similar convergence rate with ~12% lower accumulated reward
around epoch 25.

Location knowledge is a scenario axis (``know_eave_locations`` in
``ScenarioParams``), so both variants train as ONE 2-scenario population
in lockstep on device - same env object, same compiled chunk step, same
reset/action keys; the runs differ only by the observation blinding.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import BenchConfig, emit_csv_row, save_json
from repro.core.agents.sac import SACConfig
from repro.core.env import MHSLEnv
from repro.core.profiles import resnet101_profile
from repro.core.scenario import scenario_grid, stack_scenarios, train_population


def main(bench: BenchConfig = BenchConfig(), seed: int = 0):
    prof = resnet101_profile(batch=1)
    env = MHSLEnv(profile=prof)
    scens = stack_scenarios(
        scenario_grid(env.scenario(), know_eave_locations=[1.0, 0.0])
    )
    pop = train_population(env, SACConfig(), scens, episodes=bench.episodes,
                           warmup_episodes=bench.warmup, seed=seed,
                           num_envs=bench.num_envs, mesh=bench.mesh(),
                           checkpoint_dir=bench.ckpt("fig8/pop"),
                           checkpoint_every=bench.checkpoint_every)
    res_known, res_blind = pop.results
    known = float(np.mean(res_known.episode_reward[-10:]))
    blind = float(np.mean(res_blind.episode_reward[-10:]))
    derived = {
        "known_curve": res_known.episode_reward,
        "blind_curve": res_blind.episode_reward,
        "final_known": known,
        "final_blind": blind,
        "reward_drop_pct": 100 * (known - blind) / max(abs(known), 1e-9),
    }
    save_json("fig8_no_location", derived)
    emit_csv_row("fig8/summary", 0.0,
                 f"known={known:.2f} blind={blind:.2f} drop={derived['reward_drop_pct']:.1f}%")
    return derived


if __name__ == "__main__":
    main()
