"""Shared benchmark utilities: run configs, timing, CSV emission."""
from __future__ import annotations

import json
import os
import sys
import time
from dataclasses import dataclass
from typing import Optional

import numpy as np

OUT_DIR = os.environ.get("BENCH_OUT", "experiments/bench")
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE_PATH = os.path.join(REPO_ROOT, "BENCH_throughput.json")


def enable_persistent_cache():
    """Opt-in JAX persistent compilation cache (``REPRO_JIT_CACHE_DIR``).

    The pipeline/throughput benchmarks are compile-heavy (a dozen
    shard_map scan programs); with the env knob set, bench-smoke and
    repeat local runs stop re-paying those compiles. Returns the cache
    dir when enabled, None otherwise. Safe on jax versions without the
    config knobs (silently disabled).
    """
    cache_dir = os.environ.get("REPRO_JIT_CACHE_DIR")
    if not cache_dir:
        return None
    os.makedirs(cache_dir, exist_ok=True)
    try:
        import jax

        jax.config.update("jax_compilation_cache_dir", cache_dir)
        # CPU-backend compiles are small and fast individually - cache
        # everything rather than only >1s entries
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)
    except Exception:  # noqa: BLE001 - older jax: knob names differ; skip
        return None
    return cache_dir


def record_baseline(entries: dict, *, force: bool = False,
                    path: str | None = None) -> list:
    """Merge NEW metric keys into a write-once baseline JSON.

    ``path`` defaults to ``BENCH_throughput.json`` (resolved at call
    time so tests can monkeypatch ``BASELINE_PATH``); the serving
    benchmark records into ``BENCH_serving.json`` with the same
    write-once/--force semantics.

    Existing keys are REFUSED, not clobbered: re-recording a key that is
    already in the baseline requires ``force=True`` (the benchmark CLIs'
    ``--force``) or ``BENCH_THROUGHPUT_REFRESH=1``, and only the CALLER'S
    keys are ever rewritten - other benchmarks' entries are always
    preserved. A newly added metric is backfilled the first time it is
    measured. Callers skip this entirely in smoke mode. Returns the list
    of keys actually written.
    """
    if path is None:
        path = BASELINE_PATH
    refresh = force or os.environ.get("BENCH_THROUGHPUT_REFRESH") == "1"
    if os.path.exists(path):
        with open(path) as f:
            baseline = json.load(f)
    else:
        baseline = {}
    missing = [k for k in entries if refresh or k not in baseline]
    refused = [k for k in entries if k not in missing]
    if refused:
        print(
            f"record_baseline: write-once, refusing to overwrite {refused} "
            f"in {os.path.basename(path)} (pass --force / force=True or set "
            "BENCH_THROUGHPUT_REFRESH=1 to re-record)",
            file=sys.stderr, flush=True,
        )
    if not missing:
        return []
    for k in missing:
        baseline[k] = entries[k]
    with open(path, "w") as f:
        json.dump(baseline, f, indent=1, default=float)
    return missing


@dataclass(frozen=True)
class BenchConfig:
    quick: bool = True
    # CI smoke mode (benchmarks/run.py --smoke): tiny episode/step counts so
    # the figure scripts execute end-to-end in minutes on CPU, and NO
    # baseline JSON writes (the numbers are meaningless for tracking).
    smoke: bool = False
    # vmapped env population per training chunk (rollout engine). 1 keeps
    # the seed's episode ordering and updates-per-env-step ratio (updates
    # are batched at chunk end either way - see train_sac's docstring);
    # raise it, e.g. BenchConfig(num_envs=8), to trade per-episode update
    # freshness for wall-clock. Metrics stay per-episode regardless.
    num_envs: int = 1
    # shard the population axis of SAC training over this many host devices
    # (None = no mesh, plain vmap). Threaded into train_sac/train_population
    # by train_standard_agents and the fig benchmarks.
    shard_devices: Optional[int] = None
    # stop/resume knobs threaded into the SAC trainers: each trained agent
    # checkpoints under {checkpoint_dir}/{algo} every checkpoint_every
    # episodes and resumes from an existing checkpoint by default.
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0
    # which LeakageModel the leakage figures price hops with: "analytic"
    # (the paper's closed-form Eq. 30 values, the default) or "empirical"
    # (per-layer values measured by training the FSHA-style attacker
    # population of repro.attack - see leakage_model()).
    leakage: str = "analytic"

    def leakage_model(self, seed: int = 0):
        """None for the analytic default (MHSLEnv's built-in
        AnalyticLeakage), or a trained EmpiricalLeakage - making the
        learned attacker a one-flag swap for every fig benchmark."""
        if self.leakage == "analytic":
            return None
        if self.leakage != "empirical":
            raise ValueError(f"unknown leakage model {self.leakage!r}")
        from repro.attack import train_empirical_model

        return train_empirical_model(seed=seed,
                                     steps=120 if self.smoke else 400)

    @property
    def episodes(self) -> int:
        if self.smoke:
            return 8
        return 160 if self.quick else 400

    @property
    def warmup(self) -> int:
        if self.smoke:
            return 2
        return 15 if self.quick else 30

    @property
    def eval_episodes(self) -> int:
        if self.smoke:
            return 4
        return 15 if self.quick else 50

    def mesh(self):
        """Population mesh for the configured device count (None = no mesh)."""
        if self.shard_devices is None:
            return None
        from repro.launch.mesh import make_population_mesh

        return make_population_mesh(self.shard_devices)

    def ckpt(self, name: str):
        """Per-agent checkpoint subdirectory (None when checkpointing off)."""
        if self.checkpoint_dir is None:
            return None
        return os.path.join(self.checkpoint_dir, name)


def derived_seed(seed: int, idx: int) -> int:
    """Per-variant seed for multi-variant benchmarks: distinct streams so
    ablation deltas aren't correlated-noise artifacts, deterministic in the
    base seed so default runs stay reproducible. idx 0 keeps ``seed``."""
    return seed + 7919 * idx  # 7919: prime stride, no overlap for idx < stride


def train_standard_agents(env, bench: BenchConfig, seed: int = 0, *,
                          episodes: int | None = None,
                          warmup: int | None = None,
                          algos=("icm_ca", "sac", "ppo"),
                          scenario=None, num_envs: int | None = None,
                          ckpt_ns: str | None = None):
    """The agent-training preamble shared by fig4/fig5/fig6.

    Trains the requested algorithms on ``env`` (optionally under a
    ``ScenarioParams`` override) and returns
    ``{name: {"params", "cfg", "result", "seconds"}}``. Algorithms:
    ``icm_ca`` (full SAC), ``sac`` (no ICM/CA ablation), ``ppo``, ``dqn``.

    ``ckpt_ns`` namespaces this call's checkpoints under
    ``bench.checkpoint_dir`` (e.g. ``"fig4"``): different figures train
    agents with the same names, so checkpointing is OFF unless the caller
    provides a namespace - resuming another figure's agent would silently
    return its curves.
    """
    from repro.core.agents.dqn import DQNConfig, train_dqn
    from repro.core.agents.loops import train_sac
    from repro.core.agents.ppo import PPOConfig, train_ppo
    from repro.core.agents.sac import SACConfig

    episodes = bench.episodes if episodes is None else episodes
    warmup = bench.warmup if warmup is None else warmup
    num_envs = bench.num_envs if num_envs is None else num_envs
    # mesh + resume knobs ride on the SAC trainer (the engine's mesh-aware
    # path); PPO/DQN have no population/mesh trainer yet
    mesh = bench.mesh()

    def ck(name):
        return bench.ckpt(f"{ckpt_ns}/{name}") if ckpt_ns else None

    out = {}
    for name in algos:
        with Timer() as t:
            if name == "icm_ca":
                cfg = SACConfig()
                res = train_sac(env, cfg, episodes=episodes,
                                warmup_episodes=warmup, seed=seed,
                                num_envs=num_envs, scenario=scenario,
                                mesh=mesh, checkpoint_dir=ck(name),
                                checkpoint_every=bench.checkpoint_every)
            elif name == "sac":
                cfg = SACConfig(use_icm=False, use_ca=False)
                res = train_sac(env, cfg, episodes=episodes,
                                warmup_episodes=warmup, seed=seed,
                                num_envs=num_envs, scenario=scenario,
                                mesh=mesh, checkpoint_dir=ck(name),
                                checkpoint_every=bench.checkpoint_every)
            elif name == "ppo":
                cfg = PPOConfig()
                res = train_ppo(env, cfg, episodes=episodes, seed=seed,
                                num_envs=num_envs, scenario=scenario)
            elif name == "dqn":
                cfg = DQNConfig(eps_decay_episodes=max(episodes // 2, 1))
                res = train_dqn(env, cfg, episodes=episodes, seed=seed,
                                num_envs=num_envs, scenario=scenario)
            else:
                raise ValueError(f"unknown algo {name!r}")
        out[name] = {"params": res.params, "cfg": cfg, "result": res,
                     "seconds": t.seconds}
    return out


def save_json(name: str, payload) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def emit_csv_row(name: str, us_per_call: float, derived: str) -> None:
    """Scaffold contract: ``name,us_per_call,derived``."""
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def smooth(xs, k: int = 10):
    xs = np.asarray(xs, dtype=np.float64)
    if len(xs) < k:
        return xs
    kern = np.ones(k) / k
    return np.convolve(xs, kern, mode="valid")


def episodes_to_reach(rewards, threshold: float) -> int:
    """First episode whose smoothed reward crosses `threshold` (paper's
    convergence-rate metric); len(rewards) if never."""
    sm = smooth(rewards)
    idx = np.argmax(sm >= threshold)
    if sm[idx] < threshold:
        return len(rewards)
    return int(idx)


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0
