"""Shared benchmark utilities: run configs, timing, CSV emission."""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass

import numpy as np

OUT_DIR = os.environ.get("BENCH_OUT", "experiments/bench")


@dataclass(frozen=True)
class BenchConfig:
    quick: bool = True
    # vmapped env population per training chunk (rollout engine). 1 keeps
    # the seed's episode ordering and updates-per-env-step ratio (updates
    # are batched at chunk end either way - see train_sac's docstring);
    # raise it, e.g. BenchConfig(num_envs=8), to trade per-episode update
    # freshness for wall-clock. Metrics stay per-episode regardless.
    num_envs: int = 1

    @property
    def episodes(self) -> int:
        return 160 if self.quick else 400

    @property
    def warmup(self) -> int:
        return 15 if self.quick else 30

    @property
    def eval_episodes(self) -> int:
        return 15 if self.quick else 50


def train_standard_agents(env, bench: BenchConfig, seed: int = 0, *,
                          episodes: int | None = None,
                          warmup: int | None = None,
                          algos=("icm_ca", "sac", "ppo"),
                          scenario=None, num_envs: int | None = None):
    """The agent-training preamble shared by fig4/fig5/fig6.

    Trains the requested algorithms on ``env`` (optionally under a
    ``ScenarioParams`` override) and returns
    ``{name: {"params", "cfg", "result", "seconds"}}``. Algorithms:
    ``icm_ca`` (full SAC), ``sac`` (no ICM/CA ablation), ``ppo``, ``dqn``.
    """
    from repro.core.agents.dqn import DQNConfig, train_dqn
    from repro.core.agents.loops import train_sac
    from repro.core.agents.ppo import PPOConfig, train_ppo
    from repro.core.agents.sac import SACConfig

    episodes = bench.episodes if episodes is None else episodes
    warmup = bench.warmup if warmup is None else warmup
    num_envs = bench.num_envs if num_envs is None else num_envs
    out = {}
    for name in algos:
        with Timer() as t:
            if name == "icm_ca":
                cfg = SACConfig()
                res = train_sac(env, cfg, episodes=episodes,
                                warmup_episodes=warmup, seed=seed,
                                num_envs=num_envs, scenario=scenario)
            elif name == "sac":
                cfg = SACConfig(use_icm=False, use_ca=False)
                res = train_sac(env, cfg, episodes=episodes,
                                warmup_episodes=warmup, seed=seed,
                                num_envs=num_envs, scenario=scenario)
            elif name == "ppo":
                cfg = PPOConfig()
                res = train_ppo(env, cfg, episodes=episodes, seed=seed,
                                num_envs=num_envs, scenario=scenario)
            elif name == "dqn":
                cfg = DQNConfig(eps_decay_episodes=max(episodes // 2, 1))
                res = train_dqn(env, cfg, episodes=episodes, seed=seed,
                                num_envs=num_envs, scenario=scenario)
            else:
                raise ValueError(f"unknown algo {name!r}")
        out[name] = {"params": res.params, "cfg": cfg, "result": res,
                     "seconds": t.seconds}
    return out


def save_json(name: str, payload) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def emit_csv_row(name: str, us_per_call: float, derived: str) -> None:
    """Scaffold contract: ``name,us_per_call,derived``."""
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def smooth(xs, k: int = 10):
    xs = np.asarray(xs, dtype=np.float64)
    if len(xs) < k:
        return xs
    kern = np.ones(k) / k
    return np.convolve(xs, kern, mode="valid")


def episodes_to_reach(rewards, threshold: float) -> int:
    """First episode whose smoothed reward crosses `threshold` (paper's
    convergence-rate metric); len(rewards) if never."""
    sm = smooth(rewards)
    idx = np.argmax(sm >= threshold)
    if sm[idx] < threshold:
        return len(rewards)
    return int(idx)


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0
