"""Shared benchmark utilities: run configs, timing, CSV emission."""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass

import numpy as np

OUT_DIR = os.environ.get("BENCH_OUT", "experiments/bench")


@dataclass(frozen=True)
class BenchConfig:
    quick: bool = True
    # vmapped env population per training chunk (rollout engine). 1 keeps
    # the seed's episode ordering and updates-per-env-step ratio (updates
    # are batched at chunk end either way - see train_sac's docstring);
    # raise it, e.g. BenchConfig(num_envs=8), to trade per-episode update
    # freshness for wall-clock. Metrics stay per-episode regardless.
    num_envs: int = 1

    @property
    def episodes(self) -> int:
        return 160 if self.quick else 400

    @property
    def warmup(self) -> int:
        return 15 if self.quick else 30

    @property
    def eval_episodes(self) -> int:
        return 15 if self.quick else 50


def save_json(name: str, payload) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def emit_csv_row(name: str, us_per_call: float, derived: str) -> None:
    """Scaffold contract: ``name,us_per_call,derived``."""
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def smooth(xs, k: int = 10):
    xs = np.asarray(xs, dtype=np.float64)
    if len(xs) < k:
        return xs
    kern = np.ones(k) / k
    return np.convolve(xs, kern, mode="valid")


def episodes_to_reach(rewards, threshold: float) -> int:
    """First episode whose smoothed reward crosses `threshold` (paper's
    convergence-rate metric); len(rewards) if never."""
    sm = smooth(rewards)
    idx = np.argmax(sm >= threshold)
    if sm[idx] < threshold:
        return len(rewards)
    return int(idx)


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0
