"""Fig. 3: convergence of ICM-CA vs SAC-without-ICM vs SAC-without-CA.

Paper claims: ICM improves convergence rate up to 3x and final reward up to
30%; CA adds up to 9% reward.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    BenchConfig, Timer, derived_seed, emit_csv_row, episodes_to_reach,
    save_json,
)
from repro.core.agents.loops import train_sac
from repro.core.agents.sac import SACConfig
from repro.core.env import MHSLEnv
from repro.core.profiles import resnet101_profile

VARIANTS = {
    "icm_ca": dict(use_icm=True, use_ca=True),
    "no_icm": dict(use_icm=False, use_ca=True),
    "no_ca": dict(use_icm=True, use_ca=False),
}


def main(bench: BenchConfig = BenchConfig(), seed: int = 0):
    env = MHSLEnv(profile=resnet101_profile(batch=1))
    curves = {}
    # each variant trains on its own derived seed (identical seeds would
    # correlate the init/exploration noise across the ablation arms, making
    # the deltas partly artifacts of one shared draw)
    for i, (name, flags) in enumerate(VARIANTS.items()):
        cfg = SACConfig(**flags)
        with Timer() as t:
            res = train_sac(env, cfg, episodes=bench.episodes,
                            warmup_episodes=bench.warmup,
                            seed=derived_seed(seed, i),
                            num_envs=bench.num_envs, mesh=bench.mesh(),
                            checkpoint_dir=bench.ckpt(f"fig3/{name}"),
                            checkpoint_every=bench.checkpoint_every)
        curves[name] = {
            "reward": res.episode_reward,
            "leak": res.episode_leak,
            "states": res.states_explored,
            "seconds": t.seconds,
        }
        emit_csv_row(
            f"fig3/{name}",
            t.seconds * 1e6 / bench.episodes,
            f"final_reward={np.mean(res.episode_reward[-10:]):.3f}",
        )

    # paper metrics
    full = np.mean(curves["icm_ca"]["reward"][-10:])
    no_icm = np.mean(curves["no_icm"]["reward"][-10:])
    no_ca = np.mean(curves["no_ca"]["reward"][-10:])
    thresh = 0.9 * full  # reward is negative: within 10% of final
    conv_full = episodes_to_reach(curves["icm_ca"]["reward"], thresh)
    conv_noicm = episodes_to_reach(curves["no_icm"]["reward"], thresh)
    derived = {
        "final_reward": {"icm_ca": full, "no_icm": no_icm, "no_ca": no_ca},
        "reward_gain_vs_no_icm_pct": 100 * (full - no_icm) / max(abs(no_icm), 1e-9),
        "reward_gain_vs_no_ca_pct": 100 * (full - no_ca) / max(abs(no_ca), 1e-9),
        "convergence_speedup_vs_no_icm": conv_noicm / max(conv_full, 1),
        "episodes_to_threshold": {"icm_ca": conv_full, "no_icm": conv_noicm},
    }
    save_json("fig3_convergence", {"curves": curves, "derived": derived})
    emit_csv_row(
        "fig3/summary", 0.0,
        f"speedup_vs_no_icm={derived['convergence_speedup_vs_no_icm']:.2f}x "
        f"gain_vs_no_icm={derived['reward_gain_vs_no_icm_pct']:.1f}%",
    )
    return derived


if __name__ == "__main__":
    main()
