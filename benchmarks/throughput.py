"""RL engine throughput: legacy per-step loop vs device-resident engine.

Measures, with the SAME ``SACConfig`` on the current backend:

* ``env_steps_per_sec`` - the seed's per-step host loop (one jit dispatch
  per env call, host history window) vs the vmapped ``lax.scan`` rollout.
* ``updates_per_sec`` - per-call jitted SAC updates fed by the host-numpy
  replay buffer vs the fused update scan sampling the device buffer (both
  sides run the seed's sequential three-backward update, so this metric
  keeps tracking pure dispatch overhead).
* ``update_path`` - the gradient-update ladder on the device buffer:
  seed host loop -> fused scan (sequential update) -> fused scan with the
  single-backward JOINT update (``cfg.joint_update``, shared
  critic/ICM forwards). CI gates joint-fused >= 1x the seed loop; on the
  2-core CPU box it lands ~1.35x (small-op dispatch bound - the 128-wide
  layers leave little backward-count FLOP savings to reclaim), with the
  structural headroom aimed at accelerator backends.
* ``fused_chunk`` - end-to-end training-chunk rate: the PR-3 loop
  (three dispatches per chunk, ``int(buf.size)`` host sync, full-obs
  transfer + per-row Python state hashing) vs ONE buffer-donated
  ``make_train_chunk`` call with device-reduced metrics, plus the
  resulting ``train_sac`` episodes/sec.
* ``scenario_sweep`` - a 5-point ``monitor_prob`` evaluation sweep: the
  seed's per-point loop (fresh env + fresh jits per point, one recompile
  each) vs one stacked-``ScenarioParams`` call through the population
  evaluator (compiles exactly once). Acceptance: >=3x wall-clock.
* ``sharded_population`` - the mesh-sharded population path: a
  scenarios x envs rollout on a multi-device population mesh
  (``XLA_FLAGS=--xla_force_host_platform_device_count=4`` in a clean
  subprocess, so the measurement is independent of the parent's device
  count) vs the same population on one device. Records env-steps/sec for
  both; on forced CPU host devices the "speedup" only tracks XLA's
  thread partitioning, so it is reported, not gated.

Emits the scaffold CSV rows, saves each run's numbers to the bench OUT_DIR,
and records the baseline in ``BENCH_throughput.json`` at the repo root so
later PRs can track the performance trajectory. The baseline is
write-once - an existing file is never clobbered by routine benchmark runs
(set ``BENCH_THROUGHPUT_REFRESH=1`` to re-baseline deliberately), but a
newly added metric is backfilled the first time it is measured. Smoke runs
(``--smoke``) never touch the baseline.
Acceptance for the engine PR: >=5x env-steps/sec.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from dataclasses import replace

from benchmarks.common import BenchConfig, emit_csv_row, save_json
from repro.core.agents import rollout as R
from repro.core.agents import sac as SAC
from repro.core.agents.buffer import ReplayBuffer
from repro.core.agents.loops import _SAC_FIELDS, _sac_example
from repro.core.env import MHSLEnv
from repro.core.profiles import resnet101_profile
from repro.core.scenario import (
    make_population_evaluator, scenario_grid, stack_scenarios,
)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE_PATH = os.path.join(REPO_ROOT, "BENCH_throughput.json")

NUM_ENVS = 32  # engine population for the rollout measurement


def _time_legacy_rollout(env, params, cfg, episodes: int, key) -> float:
    """Seed dispatch pattern: per-step jitted calls. Returns steps/sec."""
    legacy = R.make_legacy_episode(env, R.sac_policy(env.action_dims, cfg),
                                   cfg.hist_len)
    st0 = env.reset(jax.random.PRNGKey(0))
    legacy(params, st0, key)  # warm the per-op jit caches
    t0 = time.perf_counter()
    for ep in range(episodes):
        key, k = jax.random.split(key)
        states, rewards = legacy(params, st0, k)
    jax.block_until_ready(rewards[-1])
    dt = time.perf_counter() - t0
    return episodes * env.episode_len / dt


def _time_engine_rollout(env, params, cfg, chunks: int, key) -> float:
    """Vmapped scan rollout over NUM_ENVS envs. Returns steps/sec."""
    rollout = R.make_batched_rollout(env, R.sac_policy(env.action_dims, cfg),
                                     cfg.hist_len)
    st0 = R.make_batched_reset(env)(
        jnp.broadcast_to(jax.random.PRNGKey(0), (NUM_ENVS, 2))
    )
    akeys = jax.random.split(key, NUM_ENVS)
    jax.block_until_ready(rollout(params, st0, akeys))  # compile
    t0 = time.perf_counter()
    for _ in range(chunks):
        _, traj = rollout(params, st0, akeys)
    jax.block_until_ready(traj["reward"])
    dt = time.perf_counter() - t0
    return chunks * NUM_ENVS * env.episode_len / dt


def _fill_buffers(env, params, cfg):
    """One uniform-policy chunk fills parallel host/device buffers."""
    adims = env.action_dims
    rollout = R.make_batched_rollout(env, R.uniform_policy(adims), cfg.hist_len)
    n = 64
    st0 = R.make_batched_reset(env)(
        jnp.broadcast_to(jax.random.PRNGKey(0), (n, 2))
    )
    _, traj = rollout(params, st0, jax.random.split(jax.random.PRNGKey(1), n))
    flat = R.flatten_transitions(traj, _SAC_FIELDS)

    dev_buf = R.buffer_init(cfg.buffer_size, _sac_example(env, cfg))
    dev_buf = R.buffer_add(dev_buf, flat)

    host = jax.device_get(flat)
    np_buf = ReplayBuffer(cfg.buffer_size,
                          jax.tree.map(lambda x: x[0], host))
    rows = n * env.episode_len
    for i in range(rows):
        np_buf.add(jax.tree.map(lambda x: x[i], host))
    return np_buf, dev_buf


def _time_legacy_updates(update, params, opt_state, np_buf, cfg,
                         n_updates: int) -> float:
    rng = np.random.default_rng(0)
    batch = np_buf.sample(rng, cfg.batch)
    params, opt_state, m = update(params, opt_state, batch)  # compile
    jax.block_until_ready(m)
    t0 = time.perf_counter()
    for _ in range(n_updates):
        batch = np_buf.sample(rng, cfg.batch)
        params, opt_state, m = update(params, opt_state, batch)
    jax.block_until_ready(m)
    return n_updates / (time.perf_counter() - t0)


def _time_engine_updates(update, params, opt_state, dev_buf, cfg,
                         n_updates: int, repeats: int = 4) -> float:
    fused = R.make_fused_update(update, cfg.batch, n_updates)
    key = jax.random.PRNGKey(0)
    out = fused(params, opt_state, dev_buf, key)  # compile
    jax.block_until_ready(out[2])
    t0 = time.perf_counter()
    for i in range(repeats):
        p, o, m = fused(params, opt_state, dev_buf,
                        jax.random.fold_in(key, i))
    jax.block_until_ready(m)
    return repeats * n_updates / (time.perf_counter() - t0)


def _time_update_paths(env, params, np_buf, dev_buf, cfg, n_updates: int):
    """The update ladder: seed host loop -> fused sequential -> fused joint.

    All three run the same ``SACConfig`` losses on identical buffers; the
    only variables are dispatch granularity and backward count. The two
    rungs feeding the CI gate (legacy, fused joint) take the best of two
    timing windows so a scheduling blip on a shared runner cannot flip
    the gated ratio on its own."""
    dims = env.action_dims
    seq_cfg = replace(cfg, joint_update=False)
    upd_seq, init_seq = SAC.make_update(dims, seq_cfg)
    upd_joint, init_joint = SAC.make_update(dims, replace(cfg,
                                                          joint_update=True))
    legacy = max(
        _time_legacy_updates(upd_seq, params, init_seq(params), np_buf, cfg,
                             n_updates)
        for _ in range(2)
    )
    fused_seq = _time_engine_updates(upd_seq, params, init_seq(params),
                                     dev_buf, cfg, n_updates)
    fused_joint = max(
        _time_engine_updates(upd_joint, params, init_joint(params), dev_buf,
                             cfg, n_updates)
        for _ in range(2)
    )
    return {
        "n_updates": n_updates,
        "updates_per_sec": {"legacy": legacy, "fused_sequential": fused_seq,
                            "fused_joint": fused_joint},
        "joint_speedup_vs_legacy": fused_joint / legacy,
        "joint_speedup_vs_fused_sequential": fused_joint / fused_seq,
    }


def _legacy_obs_hash(obs, bins: float = 4.0) -> int:
    """The PR-3 per-row Python state hash (kept here as the baseline's
    metric cost; the engine now packs keys on device)."""
    o = np.asarray(obs)
    discrete = o[3:]
    head = np.round(o[:3] * bins)
    return hash(tuple(np.round(discrete * bins).astype(np.int64).tolist())
                + tuple(head.astype(np.int64).tolist()))


def _time_chunk_loops(env, cfg, chunks: int, num_envs: int, key):
    """PR-3 chunk loop vs the fused train chunk, same chunk schedule.

    Both sides are warmed (compiles excluded), then timed over ``chunks``
    training chunks of ``num_envs`` episodes including all their per-chunk
    host work. Also reports end-to-end ``train_sac`` episodes/sec for the
    same workload (one-time compiles INCLUDED, as a user pays them)."""
    from repro.core.agents.loops import (
        TrainResult, _reduced_chunk_metrics, _sac_example, _SAC_FIELDS,
        train_sac,
    )

    adims = env.action_dims
    key, k0, kr, ka, ku = jax.random.split(key, 5)
    params0 = SAC.init_agent(k0, env.obs_dim, adims, cfg)
    n_updates = cfg.updates_per_step * env.episode_len * num_envs
    rkeys = jax.random.split(kr, num_envs)
    akeys = jax.random.split(ka, num_envs)
    episodes = chunks * num_envs

    # --- PR-3 replica: separate dispatches + host syncs per chunk --------
    upd_seq, init_seq = SAC.make_update(adims, replace(cfg,
                                                       joint_update=False))
    reset_batch = R.make_batched_reset(env)
    rollout_actor = R.make_batched_rollout(env, R.sac_policy(adims, cfg),
                                           cfg.hist_len)
    fused = R.make_fused_update(upd_seq, cfg.batch, n_updates)

    def pr3_chunk(params, opt_state, buf, result, seen):
        st0 = reset_batch(rkeys)
        _, traj = rollout_actor(params, st0, akeys)
        buf = R.buffer_add(buf, R.flatten_transitions(traj, _SAC_FIELDS))
        host = jax.device_get({k: traj[k] for k in ("obs", "reward", "leak",
                                                    "viol")})
        for i in range(num_envs):
            for row in host["obs"][i]:
                seen.add(_legacy_obs_hash(row))
            result.episode_reward.append(float(host["reward"][i].sum()))
            result.episode_leak.append(float(host["leak"][i].sum()))
            result.episode_violation.append(float(host["viol"][i].sum()))
            result.states_explored.append(len(seen))
        if int(buf.size) >= cfg.batch:  # the per-chunk host size sync
            params, opt_state, _ = fused(params, opt_state, buf, ku)
        return params, opt_state, buf

    buf = R.buffer_init(cfg.buffer_size, _sac_example(env, cfg))
    params, opt_state = params0, init_seq(params0)
    for _ in range(2):  # warm the jits AND fill past batch size so every
        # timed chunk runs its update scan (needs num_envs*T*2 >= batch)
        params, opt_state, buf = pr3_chunk(params, opt_state, buf,
                                           TrainResult(), set())
    result, seen = TrainResult(), set()
    t0 = time.perf_counter()
    for _ in range(chunks):
        params, opt_state, buf = pr3_chunk(params, opt_state, buf, result,
                                           seen)
    pr3_eps = episodes / (time.perf_counter() - t0)

    # --- fused chunk: one buffer-donated dispatch per chunk --------------
    upd_joint, init_joint = SAC.make_update(adims, cfg)
    chunk = R.make_train_chunk(
        env, R.uniform_policy(adims), R.sac_policy(adims, cfg), upd_joint,
        hist_len=cfg.hist_len, fields=_SAC_FIELDS, batch_size=cfg.batch,
        n_updates=n_updates,
    )
    buf = R.buffer_init(cfg.buffer_size, _sac_example(env, cfg))
    params, opt_state = params0, init_joint(params0)
    train = jnp.asarray(True)
    for _ in range(2):  # warm + fill, mirroring the PR-3 side
        params, opt_state, buf, m = chunk(params, opt_state, buf, rkeys,
                                          akeys, ku, train)
        _reduced_chunk_metrics(TrainResult(), set(), jax.device_get(m), 0,
                               episodes, num_envs)
    result, seen = TrainResult(), set()
    t0 = time.perf_counter()
    for c in range(chunks):
        params, opt_state, buf, m = chunk(params, opt_state, buf, rkeys,
                                          akeys, ku, train)
        _reduced_chunk_metrics(result, seen, jax.device_get(m),
                               c * num_envs, episodes, num_envs)
    fused_eps = episodes / (time.perf_counter() - t0)

    # --- end-to-end train_sac on the fused engine (compiles included) ----
    t0 = time.perf_counter()
    train_sac(env, cfg, episodes=episodes, warmup_episodes=num_envs,
              num_envs=num_envs, seed=1)
    e2e_eps = episodes / (time.perf_counter() - t0)

    return {
        "num_envs": num_envs,
        "chunks": chunks,
        "episodes": episodes,
        "episodes_per_sec": {"pr3_chunk_loop": pr3_eps,
                             "fused_chunk": fused_eps,
                             "train_sac_end_to_end": e2e_eps},
        "fused_chunk_speedup": fused_eps / pr3_eps,
    }


SWEEP_QS = (0.3, 0.45, 0.6, 0.75, 0.9)


def _time_scenario_sweep(env, params, cfg, episodes: int, key):
    """5-point ``monitor_prob`` sweep: per-point re-jit loop (the seed's
    pattern - fresh env + fresh jits per point) vs ONE stacked-scenario
    evaluation through the population evaluator. Returns wall-clocks,
    retrace counts, and the speedup. Both sides time end-to-end including
    compiles - that is precisely the cost the scenario API removes."""
    k_reset, k_act = jax.random.split(key)
    rkeys = jax.random.split(k_reset, episodes)
    akeys = jax.random.split(k_act, episodes)

    # --- baseline: re-instantiate env + rebuild jits per sweep point -----
    t0 = time.perf_counter()
    loop_leak, loop_traces = [], 0
    for q in SWEEP_QS:
        env_q = MHSLEnv(profile=env.profile,
                        net=replace(env.net, monitor_prob=q))
        rollout = R.make_batched_rollout(
            env_q, R.sac_policy(env_q.action_dims, cfg), cfg.hist_len)
        st0 = R.make_batched_reset(env_q)(rkeys)
        _, traj = rollout(params, st0, akeys)
        loop_leak.append(float(traj["leak"].sum()) / episodes)
        loop_traces += rollout.trace_count[0]
    dt_loop = time.perf_counter() - t0

    # --- scenario API: one compiled eval step for the whole grid ---------
    evaluator = make_population_evaluator(
        env, R.sac_policy(env.action_dims, cfg), cfg.hist_len)
    scens = stack_scenarios(
        scenario_grid(env.scenario(), monitor_prob=list(SWEEP_QS)))
    t0 = time.perf_counter()
    out = evaluator(params, rkeys, akeys, scens)
    sweep_leak = [float(x) for x in jax.device_get(out["leak"])]
    dt_sweep = time.perf_counter() - t0

    return {
        "points": len(SWEEP_QS),
        "episodes_per_point": episodes,
        "per_point_rejit_s": dt_loop,
        "scenario_sweep_s": dt_sweep,
        "sweep_speedup": dt_loop / dt_sweep,
        "compiles": {"per_point_loop": loop_traces,
                     "scenario_sweep": evaluator.trace_count[0]},
        "leak": {"per_point_loop": loop_leak, "scenario_sweep": sweep_leak},
    }


SHARDED_DEVICES = 4

# Runs in a clean subprocess with a forced host device count (the parent
# process has already initialized its backend, typically with 1 device).
# Measures the SAME population rollout twice: default single-device
# placement vs sharded over a population mesh spanning every device.
_SHARDED_SNIPPET = """
import json, time
import jax
from repro.core.agents import rollout as R
from repro.core.agents import sac as SAC
from repro.core.env import MHSLEnv
from repro.core.profiles import resnet101_profile
from repro.core.scenario import (
    make_population_rollout, scenario_grid, stack_scenarios,
)
from repro.distribution import population as PD
from repro.launch.mesh import make_population_mesh

N, NUM_ENVS, CHUNKS = {n}, {num_envs}, {chunks}
env = MHSLEnv(profile=resnet101_profile(batch=1))
cfg = SAC.SACConfig()
key = jax.random.PRNGKey(0)
key, k0, kr, ka = jax.random.split(key, 4)
params = SAC.init_agent(k0, env.obs_dim, env.action_dims, cfg)
rollout = make_population_rollout(env, R.sac_policy(env.action_dims, cfg),
                                  cfg.hist_len)
scens = stack_scenarios(scenario_grid(
    env.scenario(), monitor_prob=[0.3 + 0.6 * i / (N - 1) for i in range(N)]))
rkeys = jax.random.split(kr, NUM_ENVS)
akeys = jax.random.split(ka, NUM_ENVS)


def measure(params, rkeys, akeys, scens):
    jax.block_until_ready(rollout(params, rkeys, akeys, scens))  # compile
    t0 = time.perf_counter()
    for _ in range(CHUNKS):
        _, traj = rollout(params, rkeys, akeys, scens)
    jax.block_until_ready(traj["reward"])
    return CHUNKS * N * NUM_ENVS * env.episode_len / (time.perf_counter() - t0)


single_sps = measure(params, rkeys, akeys, scens)
mesh = make_population_mesh()
sharded_sps = measure(
    PD.replicate(params, mesh), PD.replicate(rkeys, mesh),
    PD.replicate(akeys, mesh), PD.shard_population(scens, mesh, N))
print("RESULT " + json.dumps({{
    "devices": len(jax.devices()), "scenarios": N, "num_envs": NUM_ENVS,
    "episode_len": env.episode_len,
    "env_steps_per_sec": {{"single_device": single_sps,
                           "sharded": sharded_sps}},
    "sharded_speedup": sharded_sps / single_sps,
}}))
"""


def _time_sharded_population(bench: BenchConfig):
    """Sharded-population rollout throughput on a forced multi-device host."""
    # scenarios must divide SHARDED_DEVICES even in smoke mode, else the
    # placement falls back to replication and the sharded path goes untested
    n, num_envs = (4, 4) if bench.smoke else (4, 8)
    chunks = 2 if bench.smoke else (6 if bench.quick else 20)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={SHARDED_DEVICES}"
    )
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src")
    code = _SHARDED_SNIPPET.format(n=n, num_envs=num_envs, chunks=chunks)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=1200, env=env, cwd=REPO_ROOT)
    if out.returncode != 0:
        raise RuntimeError(
            f"sharded-population subprocess failed:\n{out.stderr[-3000:]}"
        )
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def main(bench: BenchConfig = BenchConfig(), seed: int = 0):
    env = MHSLEnv(profile=resnet101_profile(batch=1))
    cfg = SAC.SACConfig()
    # the seed's update path for the legacy-tracking metrics, so
    # `updates_per_sec` keeps its historical meaning (dispatch overhead
    # with an identical update fn on both sides)
    seq_update, seq_init = SAC.make_update(env.action_dims,
                                           replace(cfg, joint_update=False))
    key = jax.random.PRNGKey(seed)
    key, k0 = jax.random.split(key)
    params = SAC.init_agent(k0, env.obs_dim, env.action_dims, cfg)
    opt_state = seq_init(params)

    legacy_eps = 3 if bench.smoke else (20 if bench.quick else 60)
    engine_chunks = 3 if bench.smoke else (20 if bench.quick else 60)
    n_updates = 8 if bench.smoke else (50 if bench.quick else 200)
    chunk_chunks = 2 if bench.smoke else (6 if bench.quick else 16)
    chunk_envs = 8 if bench.smoke else NUM_ENVS

    key, k1, k2 = jax.random.split(key, 3)
    legacy_sps = _time_legacy_rollout(env, params, cfg, legacy_eps, k1)
    engine_sps = _time_engine_rollout(env, params, cfg, engine_chunks, k2)
    rollout_speedup = engine_sps / legacy_sps

    np_buf, dev_buf = _fill_buffers(env, params, cfg)
    legacy_ups = _time_legacy_updates(seq_update, params, opt_state, np_buf,
                                      cfg, n_updates)
    engine_ups = _time_engine_updates(seq_update, params, opt_state, dev_buf,
                                      cfg, n_updates)
    update_speedup = engine_ups / legacy_ups

    # the update ladder feeds a CI gate, so even smoke mode measures
    # enough updates to amortize dispatch noise
    update_path = _time_update_paths(env, params, np_buf, dev_buf, cfg,
                                     max(n_updates, 32))
    key, kc = jax.random.split(key)
    fused_chunk = _time_chunk_loops(env, cfg, chunk_chunks, chunk_envs, kc)

    key, k3 = jax.random.split(key)
    sweep = _time_scenario_sweep(env, params, cfg,
                                 2 if bench.smoke else
                                 (8 if bench.quick else 32), k3)

    sharded = _time_sharded_population(bench)

    emit_csv_row("throughput/legacy_env_steps_per_sec", 1e6 / legacy_sps,
                 f"env_steps_per_sec={legacy_sps:.0f}")
    emit_csv_row("throughput/engine_env_steps_per_sec", 1e6 / engine_sps,
                 f"env_steps_per_sec={engine_sps:.0f} num_envs={NUM_ENVS}")
    emit_csv_row("throughput/legacy_updates_per_sec", 1e6 / legacy_ups,
                 f"updates_per_sec={legacy_ups:.0f}")
    emit_csv_row("throughput/engine_updates_per_sec", 1e6 / engine_ups,
                 f"updates_per_sec={engine_ups:.0f}")
    joint_ups = update_path["updates_per_sec"]["fused_joint"]
    emit_csv_row("throughput/update_path", 1e6 / joint_ups,
                 f"updates_per_sec={joint_ups:.0f} "
                 f"joint_speedup_vs_legacy="
                 f"{update_path['joint_speedup_vs_legacy']:.2f}x")
    fc = fused_chunk["episodes_per_sec"]
    emit_csv_row("throughput/fused_chunk", 1e6 / max(fc["fused_chunk"], 1e-9),
                 f"episodes_per_sec={fc['fused_chunk']:.2f} "
                 f"vs_pr3={fused_chunk['fused_chunk_speedup']:.2f}x "
                 f"train_sac={fc['train_sac_end_to_end']:.2f}")
    emit_csv_row("throughput/scenario_sweep", 1e6 * sweep["scenario_sweep_s"],
                 f"sweep_speedup={sweep['sweep_speedup']:.1f}x "
                 f"compiles={sweep['compiles']['scenario_sweep']}"
                 f"(vs {sweep['compiles']['per_point_loop']})")
    emit_csv_row(
        "throughput/sharded_population",
        1e6 / max(sharded["env_steps_per_sec"]["sharded"], 1e-9),
        f"env_steps_per_sec={sharded['env_steps_per_sec']['sharded']:.0f} "
        f"devices={sharded['devices']} scenarios={sharded['scenarios']} "
        f"num_envs={sharded['num_envs']} "
        f"speedup_vs_1dev={sharded['sharded_speedup']:.2f}x")
    emit_csv_row("throughput/summary", 0.0,
                 f"rollout_speedup={rollout_speedup:.1f}x "
                 f"update_speedup={update_speedup:.1f}x "
                 f"scenario_sweep_speedup={sweep['sweep_speedup']:.1f}x")

    payload = {
        "backend": jax.default_backend(),
        "num_envs": NUM_ENVS,
        "env_steps_per_sec": {"legacy": legacy_sps, "engine": engine_sps},
        "updates_per_sec": {"legacy": legacy_ups, "engine": engine_ups},
        "rollout_speedup": rollout_speedup,
        "update_speedup": update_speedup,
        "update_path": update_path,
        "fused_chunk": fused_chunk,
        "scenario_sweep": sweep,
        "sharded_population": sharded,
    }
    save_json("throughput", payload)
    if bench.smoke:  # smoke numbers are for rot detection, not tracking
        return payload
    refresh = os.environ.get("BENCH_THROUGHPUT_REFRESH") == "1"
    if refresh or not os.path.exists(BASELINE_PATH):
        with open(BASELINE_PATH, "w") as f:
            json.dump(payload, f, indent=1, default=float)
    else:
        # the baseline is write-once for existing metrics, but a newly
        # added metric gets recorded into it the first time it is measured
        with open(BASELINE_PATH) as f:
            baseline = json.load(f)
        missing = [k for k in payload if k not in baseline]
        if missing:
            for k in missing:
                baseline[k] = payload[k]
            with open(BASELINE_PATH, "w") as f:
                json.dump(baseline, f, indent=1, default=float)
    return payload


if __name__ == "__main__":
    main()
