"""RL engine throughput: legacy per-step loop vs device-resident engine.

Measures, with the SAME ``SACConfig`` on the current backend:

* ``env_steps_per_sec`` - the seed's per-step host loop (one jit dispatch
  per env call, host history window) vs the vmapped ``lax.scan`` rollout.
* ``updates_per_sec`` - per-call jitted SAC updates fed by the host-numpy
  replay buffer vs the fused update scan sampling the device buffer.
* ``scenario_sweep`` - a 5-point ``monitor_prob`` evaluation sweep: the
  seed's per-point loop (fresh env + fresh jits per point, one recompile
  each) vs one stacked-``ScenarioParams`` call through the population
  evaluator (compiles exactly once). Acceptance: >=3x wall-clock.
* ``sharded_population`` - the mesh-sharded population path: a
  scenarios x envs rollout on a multi-device population mesh
  (``XLA_FLAGS=--xla_force_host_platform_device_count=4`` in a clean
  subprocess, so the measurement is independent of the parent's device
  count) vs the same population on one device. Records env-steps/sec for
  both; on forced CPU host devices the "speedup" only tracks XLA's
  thread partitioning, so it is reported, not gated.

Emits the scaffold CSV rows, saves each run's numbers to the bench OUT_DIR,
and records the baseline in ``BENCH_throughput.json`` at the repo root so
later PRs can track the performance trajectory. The baseline is
write-once - an existing file is never clobbered by routine benchmark runs
(set ``BENCH_THROUGHPUT_REFRESH=1`` to re-baseline deliberately), but a
newly added metric is backfilled the first time it is measured. Smoke runs
(``--smoke``) never touch the baseline.
Acceptance for the engine PR: >=5x env-steps/sec.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from dataclasses import replace

from benchmarks.common import BenchConfig, emit_csv_row, save_json
from repro.core.agents import rollout as R
from repro.core.agents import sac as SAC
from repro.core.agents.buffer import ReplayBuffer
from repro.core.agents.loops import _SAC_FIELDS, _sac_example
from repro.core.env import MHSLEnv
from repro.core.profiles import resnet101_profile
from repro.core.scenario import (
    make_population_evaluator, scenario_grid, stack_scenarios,
)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE_PATH = os.path.join(REPO_ROOT, "BENCH_throughput.json")

NUM_ENVS = 32  # engine population for the rollout measurement


def _time_legacy_rollout(env, params, cfg, episodes: int, key) -> float:
    """Seed dispatch pattern: per-step jitted calls. Returns steps/sec."""
    legacy = R.make_legacy_episode(env, R.sac_policy(env.action_dims, cfg),
                                   cfg.hist_len)
    st0 = env.reset(jax.random.PRNGKey(0))
    legacy(params, st0, key)  # warm the per-op jit caches
    t0 = time.perf_counter()
    for ep in range(episodes):
        key, k = jax.random.split(key)
        states, rewards = legacy(params, st0, k)
    jax.block_until_ready(rewards[-1])
    dt = time.perf_counter() - t0
    return episodes * env.episode_len / dt


def _time_engine_rollout(env, params, cfg, chunks: int, key) -> float:
    """Vmapped scan rollout over NUM_ENVS envs. Returns steps/sec."""
    rollout = R.make_batched_rollout(env, R.sac_policy(env.action_dims, cfg),
                                     cfg.hist_len)
    st0 = R.make_batched_reset(env)(
        jnp.broadcast_to(jax.random.PRNGKey(0), (NUM_ENVS, 2))
    )
    akeys = jax.random.split(key, NUM_ENVS)
    jax.block_until_ready(rollout(params, st0, akeys))  # compile
    t0 = time.perf_counter()
    for _ in range(chunks):
        _, traj = rollout(params, st0, akeys)
    jax.block_until_ready(traj["reward"])
    dt = time.perf_counter() - t0
    return chunks * NUM_ENVS * env.episode_len / dt


def _fill_buffers(env, params, cfg):
    """One uniform-policy chunk fills parallel host/device buffers."""
    adims = env.action_dims
    rollout = R.make_batched_rollout(env, R.uniform_policy(adims), cfg.hist_len)
    n = 64
    st0 = R.make_batched_reset(env)(
        jnp.broadcast_to(jax.random.PRNGKey(0), (n, 2))
    )
    _, traj = rollout(params, st0, jax.random.split(jax.random.PRNGKey(1), n))
    flat = R.flatten_transitions(traj, _SAC_FIELDS)

    dev_buf = R.buffer_init(cfg.buffer_size, _sac_example(env, cfg))
    dev_buf = R.buffer_add(dev_buf, flat)

    host = jax.device_get(flat)
    np_buf = ReplayBuffer(cfg.buffer_size,
                          jax.tree.map(lambda x: x[0], host))
    rows = n * env.episode_len
    for i in range(rows):
        np_buf.add(jax.tree.map(lambda x: x[i], host))
    return np_buf, dev_buf


def _time_legacy_updates(update, params, opt_state, np_buf, cfg,
                         n_updates: int) -> float:
    rng = np.random.default_rng(0)
    batch = np_buf.sample(rng, cfg.batch)
    params, opt_state, m = update(params, opt_state, batch)  # compile
    jax.block_until_ready(m)
    t0 = time.perf_counter()
    for _ in range(n_updates):
        batch = np_buf.sample(rng, cfg.batch)
        params, opt_state, m = update(params, opt_state, batch)
    jax.block_until_ready(m)
    return n_updates / (time.perf_counter() - t0)


def _time_engine_updates(update, params, opt_state, dev_buf, cfg,
                         n_updates: int, repeats: int = 4) -> float:
    fused = R.make_fused_update(update, cfg.batch, n_updates)
    key = jax.random.PRNGKey(0)
    out = fused(params, opt_state, dev_buf, key)  # compile
    jax.block_until_ready(out[2])
    t0 = time.perf_counter()
    for i in range(repeats):
        p, o, m = fused(params, opt_state, dev_buf,
                        jax.random.fold_in(key, i))
    jax.block_until_ready(m)
    return repeats * n_updates / (time.perf_counter() - t0)


SWEEP_QS = (0.3, 0.45, 0.6, 0.75, 0.9)


def _time_scenario_sweep(env, params, cfg, episodes: int, key):
    """5-point ``monitor_prob`` sweep: per-point re-jit loop (the seed's
    pattern - fresh env + fresh jits per point) vs ONE stacked-scenario
    evaluation through the population evaluator. Returns wall-clocks,
    retrace counts, and the speedup. Both sides time end-to-end including
    compiles - that is precisely the cost the scenario API removes."""
    k_reset, k_act = jax.random.split(key)
    rkeys = jax.random.split(k_reset, episodes)
    akeys = jax.random.split(k_act, episodes)

    # --- baseline: re-instantiate env + rebuild jits per sweep point -----
    t0 = time.perf_counter()
    loop_leak, loop_traces = [], 0
    for q in SWEEP_QS:
        env_q = MHSLEnv(profile=env.profile,
                        net=replace(env.net, monitor_prob=q))
        rollout = R.make_batched_rollout(
            env_q, R.sac_policy(env_q.action_dims, cfg), cfg.hist_len)
        st0 = R.make_batched_reset(env_q)(rkeys)
        _, traj = rollout(params, st0, akeys)
        loop_leak.append(float(traj["leak"].sum()) / episodes)
        loop_traces += rollout.trace_count[0]
    dt_loop = time.perf_counter() - t0

    # --- scenario API: one compiled eval step for the whole grid ---------
    evaluator = make_population_evaluator(
        env, R.sac_policy(env.action_dims, cfg), cfg.hist_len)
    scens = stack_scenarios(
        scenario_grid(env.scenario(), monitor_prob=list(SWEEP_QS)))
    t0 = time.perf_counter()
    out = evaluator(params, rkeys, akeys, scens)
    sweep_leak = [float(x) for x in jax.device_get(out["leak"])]
    dt_sweep = time.perf_counter() - t0

    return {
        "points": len(SWEEP_QS),
        "episodes_per_point": episodes,
        "per_point_rejit_s": dt_loop,
        "scenario_sweep_s": dt_sweep,
        "sweep_speedup": dt_loop / dt_sweep,
        "compiles": {"per_point_loop": loop_traces,
                     "scenario_sweep": evaluator.trace_count[0]},
        "leak": {"per_point_loop": loop_leak, "scenario_sweep": sweep_leak},
    }


SHARDED_DEVICES = 4

# Runs in a clean subprocess with a forced host device count (the parent
# process has already initialized its backend, typically with 1 device).
# Measures the SAME population rollout twice: default single-device
# placement vs sharded over a population mesh spanning every device.
_SHARDED_SNIPPET = """
import json, time
import jax
from repro.core.agents import rollout as R
from repro.core.agents import sac as SAC
from repro.core.env import MHSLEnv
from repro.core.profiles import resnet101_profile
from repro.core.scenario import (
    make_population_rollout, scenario_grid, stack_scenarios,
)
from repro.distribution import population as PD
from repro.launch.mesh import make_population_mesh

N, NUM_ENVS, CHUNKS = {n}, {num_envs}, {chunks}
env = MHSLEnv(profile=resnet101_profile(batch=1))
cfg = SAC.SACConfig()
key = jax.random.PRNGKey(0)
key, k0, kr, ka = jax.random.split(key, 4)
params = SAC.init_agent(k0, env.obs_dim, env.action_dims, cfg)
rollout = make_population_rollout(env, R.sac_policy(env.action_dims, cfg),
                                  cfg.hist_len)
scens = stack_scenarios(scenario_grid(
    env.scenario(), monitor_prob=[0.3 + 0.6 * i / (N - 1) for i in range(N)]))
rkeys = jax.random.split(kr, NUM_ENVS)
akeys = jax.random.split(ka, NUM_ENVS)


def measure(params, rkeys, akeys, scens):
    jax.block_until_ready(rollout(params, rkeys, akeys, scens))  # compile
    t0 = time.perf_counter()
    for _ in range(CHUNKS):
        _, traj = rollout(params, rkeys, akeys, scens)
    jax.block_until_ready(traj["reward"])
    return CHUNKS * N * NUM_ENVS * env.episode_len / (time.perf_counter() - t0)


single_sps = measure(params, rkeys, akeys, scens)
mesh = make_population_mesh()
sharded_sps = measure(
    PD.replicate(params, mesh), PD.replicate(rkeys, mesh),
    PD.replicate(akeys, mesh), PD.shard_population(scens, mesh, N))
print("RESULT " + json.dumps({{
    "devices": len(jax.devices()), "scenarios": N, "num_envs": NUM_ENVS,
    "episode_len": env.episode_len,
    "env_steps_per_sec": {{"single_device": single_sps,
                           "sharded": sharded_sps}},
    "sharded_speedup": sharded_sps / single_sps,
}}))
"""


def _time_sharded_population(bench: BenchConfig):
    """Sharded-population rollout throughput on a forced multi-device host."""
    # scenarios must divide SHARDED_DEVICES even in smoke mode, else the
    # placement falls back to replication and the sharded path goes untested
    n, num_envs = (4, 4) if bench.smoke else (4, 8)
    chunks = 2 if bench.smoke else (6 if bench.quick else 20)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={SHARDED_DEVICES}"
    )
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src")
    code = _SHARDED_SNIPPET.format(n=n, num_envs=num_envs, chunks=chunks)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=1200, env=env, cwd=REPO_ROOT)
    if out.returncode != 0:
        raise RuntimeError(
            f"sharded-population subprocess failed:\n{out.stderr[-3000:]}"
        )
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def main(bench: BenchConfig = BenchConfig(), seed: int = 0):
    env = MHSLEnv(profile=resnet101_profile(batch=1))
    cfg = SAC.SACConfig()
    key = jax.random.PRNGKey(seed)
    key, k0 = jax.random.split(key)
    params = SAC.init_agent(k0, env.obs_dim, env.action_dims, cfg)
    update, init_opt = SAC.make_update(env.action_dims, cfg)
    opt_state = init_opt(params)

    legacy_eps = 3 if bench.smoke else (20 if bench.quick else 60)
    engine_chunks = 3 if bench.smoke else (20 if bench.quick else 60)
    n_updates = 8 if bench.smoke else (50 if bench.quick else 200)

    key, k1, k2 = jax.random.split(key, 3)
    legacy_sps = _time_legacy_rollout(env, params, cfg, legacy_eps, k1)
    engine_sps = _time_engine_rollout(env, params, cfg, engine_chunks, k2)
    rollout_speedup = engine_sps / legacy_sps

    np_buf, dev_buf = _fill_buffers(env, params, cfg)
    legacy_ups = _time_legacy_updates(update, params, opt_state, np_buf, cfg,
                                      n_updates)
    engine_ups = _time_engine_updates(update, params, opt_state, dev_buf, cfg,
                                      n_updates)
    update_speedup = engine_ups / legacy_ups

    key, k3 = jax.random.split(key)
    sweep = _time_scenario_sweep(env, params, cfg,
                                 2 if bench.smoke else
                                 (8 if bench.quick else 32), k3)

    sharded = _time_sharded_population(bench)

    emit_csv_row("throughput/legacy_env_steps_per_sec", 1e6 / legacy_sps,
                 f"env_steps_per_sec={legacy_sps:.0f}")
    emit_csv_row("throughput/engine_env_steps_per_sec", 1e6 / engine_sps,
                 f"env_steps_per_sec={engine_sps:.0f} num_envs={NUM_ENVS}")
    emit_csv_row("throughput/legacy_updates_per_sec", 1e6 / legacy_ups,
                 f"updates_per_sec={legacy_ups:.0f}")
    emit_csv_row("throughput/engine_updates_per_sec", 1e6 / engine_ups,
                 f"updates_per_sec={engine_ups:.0f}")
    emit_csv_row("throughput/scenario_sweep", 1e6 * sweep["scenario_sweep_s"],
                 f"sweep_speedup={sweep['sweep_speedup']:.1f}x "
                 f"compiles={sweep['compiles']['scenario_sweep']}"
                 f"(vs {sweep['compiles']['per_point_loop']})")
    emit_csv_row(
        "throughput/sharded_population",
        1e6 / max(sharded["env_steps_per_sec"]["sharded"], 1e-9),
        f"env_steps_per_sec={sharded['env_steps_per_sec']['sharded']:.0f} "
        f"devices={sharded['devices']} scenarios={sharded['scenarios']} "
        f"num_envs={sharded['num_envs']} "
        f"speedup_vs_1dev={sharded['sharded_speedup']:.2f}x")
    emit_csv_row("throughput/summary", 0.0,
                 f"rollout_speedup={rollout_speedup:.1f}x "
                 f"update_speedup={update_speedup:.1f}x "
                 f"scenario_sweep_speedup={sweep['sweep_speedup']:.1f}x")

    payload = {
        "backend": jax.default_backend(),
        "num_envs": NUM_ENVS,
        "env_steps_per_sec": {"legacy": legacy_sps, "engine": engine_sps},
        "updates_per_sec": {"legacy": legacy_ups, "engine": engine_ups},
        "rollout_speedup": rollout_speedup,
        "update_speedup": update_speedup,
        "scenario_sweep": sweep,
        "sharded_population": sharded,
    }
    save_json("throughput", payload)
    if bench.smoke:  # smoke numbers are for rot detection, not tracking
        return payload
    refresh = os.environ.get("BENCH_THROUGHPUT_REFRESH") == "1"
    if refresh or not os.path.exists(BASELINE_PATH):
        with open(BASELINE_PATH, "w") as f:
            json.dump(payload, f, indent=1, default=float)
    else:
        # the baseline is write-once for existing metrics, but a newly
        # added metric gets recorded into it the first time it is measured
        with open(BASELINE_PATH) as f:
            baseline = json.load(f)
        missing = [k for k in payload if k not in baseline]
        if missing:
            for k in missing:
                baseline[k] = payload[k]
            with open(BASELINE_PATH, "w") as f:
                json.dump(baseline, f, indent=1, default=float)
    return payload


if __name__ == "__main__":
    main()
