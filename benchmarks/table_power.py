"""Corollaries 1-2: closed-form optimal powers vs exhaustive grid search.

For sampled geometries, verify the closed form attains (up to grid
resolution) the minimum expected leakage among all feasible power choices.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchConfig, Timer, emit_csv_row, save_json
from repro.core.channel import NetworkConfig, data_rate, tx_time
from repro.core.leakage import (
    expected_leakage,
    optimal_powers_single_decoy,
    optimal_powers_single_eave,
)


def grid_best(bits, d_tx_rx, d_tx_d, dist_e, dd_e, b_t, b_e, net, n=60):
    grid = np.linspace(1e-3, float(b_e / b_t), n)
    best = (np.inf, None)
    for ps in grid:
        for pd in grid:
            if (ps + pd) * float(b_t) > float(b_e) + 1e-12:
                continue
            rate = data_rate(jnp.asarray(ps), d_tx_rx, jnp.asarray([pd]),
                             jnp.asarray([d_tx_d]), net)
            if float(tx_time(bits, rate)) > float(b_t):
                continue
            leak = float(expected_leakage(jnp.asarray(ps), dist_e, jnp.asarray([pd]),
                                          dd_e, jnp.asarray([net.monitor_prob]),
                                          jnp.asarray(1.0)))
            if leak < best[0]:
                best = (leak, (ps, pd))
    return best


def main(bench: BenchConfig = BenchConfig(), seed: int = 0):
    net = NetworkConfig()
    rng = np.random.default_rng(seed)
    rows = []
    with Timer() as t:
        for trial in range(5 if bench.quick else 20):
            d_tx_rx = jnp.asarray(float(rng.uniform(80, 300)))
            d_tx_d = jnp.asarray(float(rng.uniform(80, 300)))
            dist_e = jnp.asarray([float(rng.uniform(100, 400))])
            dd_e = jnp.asarray([[float(rng.uniform(50, 200))]])
            bits = jnp.asarray(2e6)
            b_t, b_e = jnp.asarray(1.5), jnp.asarray(3.0)
            p_s, p_d = optimal_powers_single_decoy(bits, d_tx_rx, d_tx_d, b_t, b_e, net)
            closed = float(expected_leakage(p_s, dist_e, jnp.asarray([p_d]), dd_e,
                                            jnp.asarray([net.monitor_prob]),
                                            jnp.asarray(1.0)))
            g_leak, g_p = grid_best(bits, d_tx_rx, d_tx_d, dist_e, dd_e, b_t, b_e, net)
            rows.append(dict(trial=trial, closed_leak=closed, grid_leak=g_leak,
                             p_s=float(p_s), p_d=float(p_d),
                             gap_pct=100 * (closed - g_leak) / max(g_leak, 1e-12)))
    worst_gap = max(r["gap_pct"] for r in rows)
    save_json("table_power", {"rows": rows, "worst_gap_pct": worst_gap})
    emit_csv_row("table_power/cor1", t.seconds * 1e6 / max(len(rows), 1),
                 f"worst_gap_vs_grid={worst_gap:.2f}%")

    # Corollary 2 structural check
    dd_e2 = jnp.asarray([100.0, 250.0, 400.0])
    p_s2, p_d2 = optimal_powers_single_eave(jnp.asarray(2e6), jnp.asarray(150.0),
                                            dd_e2, jnp.asarray(1.5), jnp.asarray(3.0), net)
    recv = np.asarray(p_d2) / np.asarray(dd_e2) ** 2
    emit_csv_row("table_power/cor2", 0.0,
                 f"recv_power_spread={float(recv.max() - recv.min()):.2e} (water-levelled)")
    return {"worst_gap_pct": worst_gap}


if __name__ == "__main__":
    main()
