"""Fig. 4: ICM-CA (SAC) vs PPO vs DQN convergence.

Paper claims ~2x convergence-rate gain vs PPO/DQN and ~40% higher reward
than PPO.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    BenchConfig, emit_csv_row, episodes_to_reach, save_json,
    train_standard_agents,
)
from repro.core.env import MHSLEnv
from repro.core.profiles import resnet101_profile


def main(bench: BenchConfig = BenchConfig(), seed: int = 0):
    env = MHSLEnv(profile=resnet101_profile(batch=1))
    agents = train_standard_agents(env, bench, seed,
                                   algos=("icm_ca", "ppo", "dqn"),
                                   ckpt_ns="fig4")
    curves = {
        name: {"reward": a["result"].episode_reward,
               "leak": a["result"].episode_leak,
               "states": a["result"].states_explored,
               "seconds": a["seconds"]}
        for name, a in agents.items()
    }

    finals = {k: float(np.mean(v["reward"][-10:])) for k, v in curves.items()}
    thresh = 0.9 * finals["icm_ca"]
    conv = {k: episodes_to_reach(v["reward"], thresh) for k, v in curves.items()}
    derived = {
        "final_reward": finals,
        "convergence_speedup_vs_ppo": conv["ppo"] / max(conv["icm_ca"], 1),
        "convergence_speedup_vs_dqn": conv["dqn"] / max(conv["icm_ca"], 1),
        "reward_gain_vs_ppo_pct": 100 * (finals["icm_ca"] - finals["ppo"]) / max(abs(finals["ppo"]), 1e-9),
    }
    for k, v in curves.items():
        emit_csv_row(f"fig4/{k}", v["seconds"] * 1e6 / bench.episodes,
                     f"final_reward={finals[k]:.3f}")
    save_json("fig4_algorithms", {"curves": curves, "derived": derived})
    emit_csv_row("fig4/summary", 0.0,
                 f"speedup_vs_ppo={derived['convergence_speedup_vs_ppo']:.2f}x "
                 f"gain_vs_ppo={derived['reward_gain_vs_ppo_pct']:.1f}%")
    return derived


if __name__ == "__main__":
    main()
