"""Serving benchmarks: continuous batching vs static batches, fused
decode scan vs the v0 per-token host loop.

Two measurements:

* ``serving`` - the SAME mixed-length Poisson trace served by (a) the
  static-batch baseline (``launch.serve.run_static``: admit N at a time
  in arrival order, every row pays the batch max gen length) and (b) the
  continuous-batching engine (``ServingService``: arrivals admitted into
  draining slots each tick, one compiled step). Cases cover a 1-stage
  single-device runner and a multi-stage split plan with per-stage KV
  rings on forced host devices. Each case runs in a clean subprocess
  (the forced device count and the tcmalloc LD_PRELOAD both must be set
  before the backend initializes) and records wall-clock requests/sec,
  tokens/sec, p50/p99 latency, AND the structural slot-occupancy
  accounting (useful decode-slot-steps over executed ones) - the
  occupancy ratio shows the slot-reuse win even where a 2-core CPU host
  is dispatch-bound. Both sides warm their compiles before the clock
  starts, and the engine's compiled-trace count is audited (1 trace
  across arrivals, completions, and drain).
* ``faulted_serving`` - the 1-stage engine on one trace fault-free vs
  under ``core.faults.reference_schedule`` (device 0 out for fault-clock
  ticks [4, 9), hops at 80% bandwidth): rps/p50 both sides, recovery
  tick count, eviction count, and a bitwise completion check (rid-keyed
  sampling makes even re-served requests identical). The chaos-smoke CI
  gate reads this entry and fails if degraded rps falls below the static
  baseline.
* ``decode_fusion`` - tok/s of the fused single-dispatch decode
  (``make_generate_fn``: one ``lax.scan`` over the whole generation) vs
  the v0 per-token loop (one jitted dispatch + host sync per token),
  both with warm jits. This is the before/after for folding the host
  loop into the engine step.
* CI gate input: bench-smoke reads the per-run JSON and fails if the
  continuous engine's requests/sec falls below the static baseline's in
  any stage case.

New baseline keys are recorded write-once into ``BENCH_serving.json``
(never in ``--smoke``); the shared CSV contract rows still print.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from benchmarks.common import (
    BenchConfig, emit_csv_row, record_baseline, save_json, REPO_ROOT,
)

SERVING_BASELINE = os.path.join(REPO_ROOT, "BENCH_serving.json")
_TCMALLOC = "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4"


# Runs ONE case (static + engine on the same trace) in a clean
# subprocess with a forced host device count. Prints a RESULT json line.
_SERVE_SNIPPET = """
import json, os
import numpy as np

from benchmarks.common import enable_persistent_cache

enable_persistent_cache()  # REPRO_JIT_CACHE_DIR rides the environment

from repro.serving import ServeConfig, ServingService, poisson_trace
from repro.serving.engine import init_engine_state
from repro.launch.serve import run_static

SPEC = json.loads(os.environ["SERVE_BENCH_SPEC"])
cfg = ServeConfig.load(None, SPEC["serve"])
mc = cfg.model_config()
trace = poisson_trace(
    n_requests=SPEC["requests"], rate_per_sec=SPEC["rate"],
    vocab_size=mc.vocab_size, plen_range=(4, cfg.prompt_pad),
    gen_range=(4, cfg.max_new), seed=SPEC["seed"])
warm = poisson_trace(
    n_requests=2, rate_per_sec=1e9, vocab_size=mc.vocab_size,
    plen_range=(4, cfg.prompt_pad), gen_range=(2, 4), seed=SPEC["seed"] + 1)

stat = run_static(cfg, trace, warmup=True)

svc = ServingService(cfg)
svc.run(warm)  # compile the engine step off the clock
svc.state = init_engine_state(svc.runner, cfg.num_slots, cfg.prompt_pad,
                              cfg.max_new)
eng = svc.run(trace)

# both paths run the same (num_slots, prompt_pad) decode shapes at
# temperature 0, so per-request tokens must agree bitwise
match = (set(stat["completions"]) == set(eng["completions"]) and all(
    np.array_equal(stat["completions"][r], eng["completions"][r])
    for r in stat["completions"]))

drop = ("completions", "latencies", "replans")
print("RESULT " + json.dumps({
    "static": {k: v for k, v in stat.items() if k not in drop},
    "engine": {k: v for k, v in eng.items() if k not in drop},
    "engine_traces": len(svc.step.trace_count),
    "tokens_match": bool(match),
}, default=float))
"""


# Degraded-mode serving under the REFERENCE fault schedule (device 0
# out for fault-clock ticks [4, 9), all hops at 80% bandwidth) vs the
# same engine fault-free and the static baseline. Clean subprocess,
# RESULT json line, same contract as _SERVE_SNIPPET.
_FAULT_SNIPPET = """
import json, os
import numpy as np

from benchmarks.common import enable_persistent_cache

enable_persistent_cache()

from repro.core import faults as F
from repro.serving import ServeConfig, ServingService, poisson_trace
from repro.serving.engine import init_engine_state
from repro.launch.serve import run_static

SPEC = json.loads(os.environ["SERVE_BENCH_SPEC"])
cfg = ServeConfig.load(None, SPEC["serve"])
mc = cfg.model_config()
trace = poisson_trace(
    n_requests=SPEC["requests"], rate_per_sec=SPEC["rate"],
    vocab_size=mc.vocab_size, plen_range=(4, cfg.prompt_pad),
    gen_range=(4, cfg.max_new), seed=SPEC["seed"])
warm = poisson_trace(
    n_requests=2, rate_per_sec=1e9, vocab_size=mc.vocab_size,
    plen_range=(4, cfg.prompt_pad), gen_range=(2, 4), seed=SPEC["seed"] + 1)

svc = ServingService(cfg)
svc.run(warm)  # compile off the clock
# warm the FAULT path off the clock too (evict_slots + replanner oracle
# compile once): a tiny trace under an outage that fires on tick 1, so
# the eviction/replan/recovery machinery runs before timing starts -
# symmetric with the static baseline's warmup=True and the engine warm
wsched = F.make_schedule(
    1, 1, outages=[(0, 1 * cfg.fault_tick_s, 3 * cfg.fault_tick_s)],
    hop_bandwidth_scale=[0.8])
svc.state = init_engine_state(svc.runner, cfg.num_slots, cfg.prompt_pad,
                              cfg.max_new)
svc.run(list(warm), faults=wsched)

def fresh_run(faults=None):
    svc.state = init_engine_state(svc.runner, cfg.num_slots, cfg.prompt_pad,
                                  cfg.max_new)
    return svc.run(list(trace), faults=faults)

# Best-of-REPS per phase (min wall): scheduling noise on a shared box is
# one-sided slowdown, so the min is the right point estimate for the
# rps >= static CI gate. Token bitwise-match is asserted on EVERY rep.
REPS = 2
sched = F.reference_schedule(1, 1, tick_seconds=cfg.fault_tick_s)
stat = free = faulted = None
match = True
for _ in range(REPS):
    s = run_static(cfg, trace, warmup=True)
    fr = fresh_run()
    fa = fresh_run(faults=sched)
    match = match and (
        set(fr["completions"]) == set(fa["completions"]) and all(
            np.array_equal(fr["completions"][r], fa["completions"][r])
            for r in fr["completions"]))
    best = lambda a, b: b if a is None or b["wall_seconds"] < a["wall_seconds"] else a
    stat, free, faulted = best(stat, s), best(free, fr), best(faulted, fa)

keep = ("num_requests", "wall_seconds", "ticks", "requests_per_sec",
        "tokens_per_sec", "p50_latency_s", "p99_latency_s",
        "fault_events", "retries", "evictions", "recovery_ticks")
print("RESULT " + json.dumps({
    "static": {k: v for k, v in stat.items()
               if k in ("requests_per_sec", "p50_latency_s",
                        "wall_seconds", "num_requests")},
    "fault_free": {k: v for k, v in free.items() if k in keep},
    "faulted": {k: v for k, v in faulted.items() if k in keep},
    "engine_traces": len(svc.step.trace_count),
    "tokens_match": bool(match),
}, default=float))
"""


def _case_env(stages: int) -> dict:
    """Subprocess env per SNIPPETS 2-3: forced host device count for the
    stage mesh, tcmalloc preloaded when the box has it, TF log noise
    off."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={stages}"
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src")
    env["TF_CPP_MIN_LOG_LEVEL"] = "4"
    if os.path.exists(_TCMALLOC):
        env["LD_PRELOAD"] = _TCMALLOC
        env["TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD"] = "60000000000"
    return env


def _serving_cases(bench: BenchConfig, seed: int):
    # Decode-dominated SATURATED load is where continuous batching pays:
    # short prompts (prefill is a per-arrival cost BOTH sides pay once per
    # batch), a wide mixed gen-length spread (the static baseline's decode
    # scan always runs max_new steps, so every early-finishing row drags
    # dead slot-steps to the batch end), arrival_slots == num_slots (one
    # batched prefill refills ALL freed slots), and an offered load well
    # above service capacity so the queue stays non-empty and rps measures
    # SERVICE throughput - at sub-capacity rates both sides finish right
    # after the last arrival and rps just reads back the arrival rate.
    if bench.smoke:
        cases = [
            {"name": "1-stage", "stages": 1, "requests": 16, "rate": 512.0,
             "serve": {"num_slots": 4, "arrival_slots": 4, "prompt_pad": 8,
                       "max_new": 24, "decode_chunk": 8}},
            {"name": "2-stage", "stages": 2, "requests": 8, "rate": 512.0,
             "serve": {"num_slots": 4, "arrival_slots": 4, "prompt_pad": 8,
                       "max_new": 16, "decode_chunk": 8,
                       "boundaries": [1, 2]}},
        ]
    else:
        cases = [
            {"name": "1-stage", "stages": 1, "requests": 48, "rate": 512.0,
             "serve": {"num_slots": 8, "arrival_slots": 8, "prompt_pad": 8,
                       "max_new": 48, "decode_chunk": 12}},
            {"name": "2-stage", "stages": 2, "requests": 16, "rate": 512.0,
             "serve": {"num_slots": 4, "arrival_slots": 4, "prompt_pad": 8,
                       "max_new": 32, "decode_chunk": 8,
                       "boundaries": [1, 2]}},
        ]
    rows = []
    for case in cases:
        spec = {"requests": case["requests"], "rate": case["rate"],
                "seed": seed, "serve": dict(case["serve"], seed=seed)}
        env = _case_env(case["stages"])
        env["SERVE_BENCH_SPEC"] = json.dumps(spec)
        res = subprocess.run([sys.executable, "-c", _SERVE_SNIPPET],
                             capture_output=True, text=True, timeout=3000,
                             env=env, cwd=REPO_ROOT)
        if res.returncode != 0:
            raise RuntimeError(
                f"serving subprocess ({case['name']}) failed:\n"
                f"{res.stderr[-3000:]}")
        line = [l for l in res.stdout.splitlines()
                if l.startswith("RESULT ")][-1]
        row = json.loads(line[len("RESULT "):])
        row["name"] = case["name"]
        row["stages"] = case["stages"]
        row["spec"] = spec
        row["rps_speedup"] = (
            row["engine"]["requests_per_sec"]
            / max(row["static"]["requests_per_sec"], 1e-12))
        row["occupancy_ratio"] = (
            row["engine"]["slot_occupancy"]
            / max(row["static"]["slot_occupancy"], 1e-12))
        rows.append(row)
    return rows


def _faulted_serving(bench: BenchConfig, seed: int):
    """Degraded-mode serving: the 1-stage engine under the reference
    fault schedule vs fault-free, plus the static baseline on the same
    trace. The fault clock runs at 5ms/tick so the injected outage costs
    a fixed ~25ms stall + one eviction/recovery cycle - the CI gate
    checks degraded rps still clears the static baseline."""
    case = ({"requests": 128, "rate": 512.0,
             "serve": {"num_slots": 4, "arrival_slots": 4, "prompt_pad": 8,
                       "max_new": 24, "decode_chunk": 8}}
            if bench.smoke else
            {"requests": 128, "rate": 512.0,
             "serve": {"num_slots": 8, "arrival_slots": 8, "prompt_pad": 8,
                       "max_new": 48, "decode_chunk": 12}})
    spec = {"requests": case["requests"], "rate": case["rate"], "seed": seed,
            "serve": dict(case["serve"], seed=seed, fault_tick_s=0.005,
                          max_retries=3, retry_backoff_s=0.002)}
    env = _case_env(1)
    env["SERVE_BENCH_SPEC"] = json.dumps(spec)
    res = subprocess.run([sys.executable, "-c", _FAULT_SNIPPET],
                         capture_output=True, text=True, timeout=3000,
                         env=env, cwd=REPO_ROOT)
    if res.returncode != 0:
        raise RuntimeError(
            f"faulted-serving subprocess failed:\n{res.stderr[-3000:]}")
    line = [l for l in res.stdout.splitlines()
            if l.startswith("RESULT ")][-1]
    row = json.loads(line[len("RESULT "):])
    row["spec"] = spec
    row["rps_degradation"] = (
        row["faulted"]["requests_per_sec"]
        / max(row["fault_free"]["requests_per_sec"], 1e-12))
    row["rps_vs_static"] = (
        row["faulted"]["requests_per_sec"]
        / max(row["static"]["requests_per_sec"], 1e-12))
    return row


def _decode_fusion(bench: BenchConfig, seed: int):
    """Fused-scan generate vs the v0 per-token loop, warm jits both
    sides. The loop body here mirrors ``batching.decode_python_loop``
    (whose token-level equivalence to ``generate_static`` is pinned by
    tests) but holds its jitted prefill/decode warm across the timed
    call, so the measured gap is dispatch structure, not compile."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.serving import ServeConfig
    from repro.serving.batching import _row_sample, make_generate_fn
    from repro.serving.runners import SingleDeviceRunner
    from repro.models import init_params

    b, p, g = (4, 16, 8) if bench.smoke else (8, 32, 32)
    cfg = ServeConfig()
    mc = cfg.model_config()
    params = init_params(jax.random.PRNGKey(seed), mc)
    runner = SingleDeviceRunner(mc)
    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(rng.integers(0, mc.vocab_size, (b, p)), jnp.int32)
    plens = jnp.full((b,), p, jnp.int32)
    gens = jnp.full((b,), g, jnp.int32)
    req_ids = jnp.arange(b, dtype=jnp.int32)
    base_key = jax.random.PRNGKey(seed)

    gen = jax.jit(make_generate_fn(runner, max_new=g, temperature=0.0))
    buf, _ = gen(params, runner.init_caches(b, p + g), prompts, plens, gens,
                 req_ids, base_key)
    jax.block_until_ready(buf)
    t0 = time.perf_counter()
    fused_buf, _ = gen(params, runner.init_caches(b, p + g), prompts, plens,
                       gens, req_ids, base_key)
    jax.block_until_ready(fused_buf)
    fused_s = time.perf_counter() - t0

    prefill = jax.jit(runner.prefill)
    decode = jax.jit(runner.decode)
    sample = jax.jit(lambda lg, n: _row_sample(
        lg.astype(jnp.float32), base_key, req_ids, n, 0.0))

    def loop():
        caches = runner.init_caches(b, p + g)
        logits_all, caches = prefill(params, caches, prompts)
        last = jnp.take_along_axis(
            logits_all, (plens - 1)[:, None, None], axis=1)[:, 0]
        tok = sample(last, jnp.zeros((b,), jnp.int32))
        buf = [tok]
        pos = plens
        for i in range(1, g):
            logits, caches = decode(params, tok[:, None], caches, pos)
            tok = sample(logits, jnp.full((b,), i, jnp.int32))
            buf.append(tok)
            pos = pos + 1
            jax.block_until_ready(tok)  # the v0 per-token host sync
        return jnp.stack(buf, axis=1)

    loop_buf = loop()  # warm prefill/decode/sample
    t0 = time.perf_counter()
    loop_buf = loop()
    loop_s = time.perf_counter() - t0

    total = b * g
    return {
        "batch": b, "prompt_len": p, "gen": g,
        "loop_s": loop_s, "fused_s": fused_s,
        "loop_tok_s": total / loop_s, "fused_tok_s": total / fused_s,
        "speedup": loop_s / fused_s,
        "tokens_match": bool(jnp.array_equal(loop_buf, fused_buf)),
    }


def main(bench: BenchConfig = BenchConfig(), seed: int = 0,
         force: bool = False):
    cases = _serving_cases(bench, seed)
    faulted = _faulted_serving(bench, seed)
    fusion = _decode_fusion(bench, seed)

    for row in cases:
        emit_csv_row(
            f"serving/{row['name']}",
            1e6 * row["engine"]["wall_seconds"],
            f"engine_rps={row['engine']['requests_per_sec']:.2f} "
            f"static_rps={row['static']['requests_per_sec']:.2f} "
            f"speedup={row['rps_speedup']:.2f}x "
            f"occupancy={row['engine']['slot_occupancy']:.2f}"
            f"(vs {row['static']['slot_occupancy']:.2f}) "
            f"ticks={row['engine']['ticks']} "
            f"traces={row['engine_traces']} match={row['tokens_match']}")
    emit_csv_row(
        "serving/faulted", 1e6 * faulted["faulted"]["wall_seconds"],
        f"faulted_rps={faulted['faulted']['requests_per_sec']:.2f} "
        f"free_rps={faulted['fault_free']['requests_per_sec']:.2f} "
        f"static_rps={faulted['static']['requests_per_sec']:.2f} "
        f"degradation={faulted['rps_degradation']:.2f}x "
        f"recovery_ticks={faulted['faulted']['recovery_ticks']} "
        f"evictions={faulted['faulted']['evictions']} "
        f"traces={faulted['engine_traces']} match={faulted['tokens_match']}")
    emit_csv_row(
        "serving/decode_fusion", 1e6 * fusion["fused_s"],
        f"fused_tok_s={fusion['fused_tok_s']:.0f} "
        f"loop_tok_s={fusion['loop_tok_s']:.0f} "
        f"speedup={fusion['speedup']:.1f}x match={fusion['tokens_match']}")

    payload = {"serving": {"cases": cases}, "faulted_serving": faulted,
               "decode_fusion": fusion}
    save_json("serving", payload)
    if not bench.smoke:
        record_baseline(payload, force=force, path=SERVING_BASELINE)
    return payload


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--force", action="store_true",
                    help="re-record existing BENCH_serving.json keys")
    ap.add_argument("--smoke", action="store_true", help="tiny CI sizes")
    a = ap.parse_args()
    main(BenchConfig(smoke=a.smoke), force=a.force)
