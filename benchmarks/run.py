"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (scaffold contract) and saves
full curves/tables under experiments/bench/.

    PYTHONPATH=src python -m benchmarks.run [--only fig3,fig5] [--full]

``--smoke`` is the CI rot-detector mode: tiny episode/step counts so the
figure scripts execute end-to-end on CPU in minutes, with NO baseline
JSON writes (the CSV + per-run OUT_DIR artifacts are still emitted and
uploaded by the workflow).
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import BenchConfig, emit_csv_row, enable_persistent_cache

ALL = [
    "fig3_convergence",
    "fig4_algorithms",
    "fig5_monitoring",
    "fig6_eavesdroppers",
    "fig7_exploration",
    "fig8_no_location",
    "fig9_example",
    "fig10_leakage_attack",
    "table_power",
    "roofline",
    "throughput",
    "pipeline",
    "serving",
    "moe_dispatch",
    "zoo_plan_scoring",
]


def select(names, only: str):
    """Resolve a ``--only`` spec against the benchmark list.

    A spec entry matches a benchmark on its EXACT name or as an explicit
    underscore-delimited prefix (``fig10`` -> ``fig10_leakage_attack``).
    Bare ``startswith`` matching would make ``--only fig1`` silently run
    ``fig10_leakage_attack``; an entry that matches nothing is an error
    rather than a silent no-op.
    """
    picked = []
    for o in only.split(","):
        o = o.strip()
        if not o:
            continue
        hits = [n for n in names if n == o or n.startswith(o + "_")]
        if not hits:
            raise SystemExit(f"--only: {o!r} matches no benchmark in {names}")
        picked.extend(h for h in hits if h not in picked)
    return [n for n in names if n in picked]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated benchmark names")
    ap.add_argument("--full", action="store_true", help="paper-scale episode counts")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny counts, no baseline JSON writes")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--leakage", default="analytic",
                    choices=("analytic", "empirical"),
                    help="leakage model the fig benchmarks price hops "
                         "with: the paper's closed-form values or the "
                         "trained attacker population's measurements")
    args = ap.parse_args(argv)

    if args.full and args.smoke:
        ap.error("--full and --smoke are mutually exclusive")
    cache_dir = enable_persistent_cache()  # REPRO_JIT_CACHE_DIR opt-in
    if cache_dir:
        print(f"# jit cache: {cache_dir}", flush=True)
    bench = BenchConfig(quick=not args.full, smoke=args.smoke,
                        leakage=args.leakage)
    names = ALL if not args.only else select(ALL, args.only)
    print("name,us_per_call,derived")
    t_all = time.time()
    failures = []
    for name in names:
        mod = __import__(f"benchmarks.{name}", fromlist=["main"])
        t0 = time.time()
        try:
            mod.main(bench, seed=args.seed)
            emit_csv_row(f"{name}/walltime", (time.time() - t0) * 1e6, "ok")
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            emit_csv_row(f"{name}/walltime", (time.time() - t0) * 1e6, f"FAIL: {e}")
    emit_csv_row("total/walltime", (time.time() - t_all) * 1e6,
                 f"{len(names) - len(failures)}/{len(names)} benchmarks ok")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
