"""Fig. 7: state-exploration ability - distinct states visited vs episodes.

Paper claims ICM-CA explores ~2.5x more states than SAC within 20 epochs.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import BenchConfig, derived_seed, emit_csv_row, save_json
from repro.core.agents.loops import train_sac
from repro.core.agents.sac import SACConfig
from repro.core.env import MHSLEnv
from repro.core.profiles import resnet101_profile


def main(bench: BenchConfig = BenchConfig(), seed: int = 0):
    env = MHSLEnv(profile=resnet101_profile(batch=1))
    # distinct derived seeds per variant: a shared seed would correlate the
    # exploration noise between the two arms of the comparison
    res_full = train_sac(env, SACConfig(), episodes=bench.episodes,
                         warmup_episodes=bench.warmup,
                         seed=derived_seed(seed, 0),
                         num_envs=bench.num_envs, mesh=bench.mesh())
    res_sac = train_sac(env, SACConfig(use_icm=False, use_ca=False),
                        episodes=bench.episodes, warmup_episodes=bench.warmup,
                        seed=derived_seed(seed, 1), num_envs=bench.num_envs,
                        mesh=bench.mesh())
    at = min(bench.warmup + 20, len(res_full.states_explored) - 1)
    ratio = res_full.states_explored[at] / max(res_sac.states_explored[at], 1)
    derived = {
        "icm_ca_states": res_full.states_explored,
        "sac_states": res_sac.states_explored,
        "exploration_ratio_at_20": ratio,
    }
    save_json("fig7_exploration", derived)
    emit_csv_row("fig7/summary", 0.0, f"exploration_ratio_at_20ep={ratio:.2f}x")
    return derived


if __name__ == "__main__":
    main()
