from repro.data.pipeline import input_specs, synthetic_batch, synthetic_stream

__all__ = ["input_specs", "synthetic_batch", "synthetic_stream"]
