"""Synthetic data pipeline + ShapeDtypeStruct input specs for dry-runs.

The data pipeline is deterministic and seeded (no dataset downloads on this
box); it produces next-token LM batches plus stub modality features for
VLM/audio architectures. ``input_specs`` mirrors the exact structures as
``jax.ShapeDtypeStruct`` stand-ins for ``.lower()`` without allocation.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.frontends import FRONTEND_DIMS


def _frontend_len(cfg: ModelConfig) -> int:
    return cfg.frontend_tokens if cfg.frontend != "none" else 0


def synthetic_batch(
    cfg: ModelConfig, batch: int, seq: int, seed: int = 0
) -> Dict[str, jax.Array]:
    """A train batch: tokens (B, S_text), labels shifted, optional frontend."""
    f = _frontend_len(cfg)
    s_text = seq - f
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, size=(batch, s_text + 1), dtype=np.int32)
    out = {
        "tokens": jnp.asarray(toks[:, :-1]),
        "labels": jnp.asarray(toks[:, 1:]),
    }
    if f:
        out["frontend"] = jnp.asarray(
            rng.standard_normal((batch, f, FRONTEND_DIMS[cfg.frontend]), dtype=np.float32)
        )
    return out


def synthetic_stream(
    cfg: ModelConfig, batch: int, seq: int, seed: int = 0
) -> Iterator[Dict[str, jax.Array]]:
    step = 0
    while True:
        yield synthetic_batch(cfg, batch, seq, seed=seed + step)
        step += 1


# ---------------------------------------------------------------------------
# dry-run input specs (no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this shape kind."""
    b, s = shape.global_batch, shape.seq_len
    f = _frontend_len(cfg)
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s - f), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s - f), jnp.int32),
        }
        if f:
            specs["frontend"] = jax.ShapeDtypeStruct(
                (b, f, FRONTEND_DIMS[cfg.frontend]), jnp.float32
            )
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s - f), jnp.int32)}
        if f:
            specs["frontend"] = jax.ShapeDtypeStruct(
                (b, f, FRONTEND_DIMS[cfg.frontend]), jnp.float32
            )
        return specs
    if shape.kind == "decode":
        return {
            "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
            "cache_index": jax.ShapeDtypeStruct((), jnp.int32),
        }
    raise KeyError(shape.kind)
