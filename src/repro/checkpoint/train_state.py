"""Stop/resume checkpoints for the RL population trainers.

A training checkpoint is a pair of files per step under one directory:

* ``step_{N:08d}.npz``  - the DEVICE state (agent params, optimizer state,
  replay buffer storage + ring pointers, PRNG keys), written through
  ``checkpoint.store.save_pytree``;
* ``step_{N:08d}.json`` - the HOST state (episode counter, per-episode
  metric curves, the distinct-states-explored hash set) - everything the
  training loop keeps in Python between chunks;

plus a ``LATEST`` file naming the newest step. Both trainers checkpoint at
chunk boundaries, where the loop state above is the COMPLETE state of the
run: restoring it and re-entering the loop replays the exact key
derivations and buffer contents, so a resumed run's episode-reward
trajectory is bit-identical to an uninterrupted one (pinned by
``tests/test_population_mesh.py``).

Restore is sharding-aware: pass ``shardings`` (or a ``like`` tree of
already-placed arrays) and every leaf is ``device_put`` onto its mesh
placement, so long sharded-population runs resume straight onto the mesh.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional, Tuple

import jax

import hashlib

import numpy as np

from repro.checkpoint.store import load_pytree, save_pytree

_STEP_RE = re.compile(r"^step_(\d{8})\.npz$")


def pytree_fingerprint(tree: Any) -> Optional[str]:
    """Content hash of a pytree of arrays (order = tree order), used to
    fingerprint the scenario physics a run was trained under. None in,
    None out (no scenario override)."""
    if tree is None:
        return None
    h = hashlib.sha256()
    for leaf in jax.tree.leaves(tree):
        h.update(np.asarray(leaf).tobytes())
    return h.hexdigest()


def validate_resume(host_state: Dict[str, Any], meta: Dict[str, Any],
                    episodes: int, directory: str) -> int:
    """Shared resume gate for the trainers: the checkpoint's run
    fingerprint must match the caller's knobs exactly, and the saved
    episode counter must not be past the requested run length - resuming
    under different knobs would silently produce a trajectory belonging to
    neither run. Returns the restored episode counter."""
    if host_state.get("meta") != meta:
        raise ValueError(
            f"checkpoint {directory} was written by a run with "
            f"{host_state.get('meta')}, cannot resume with {meta}")
    ep = int(host_state["ep"])
    if ep > episodes:
        raise ValueError(
            f"checkpoint {directory} is at episode {ep}, past the "
            f"requested episodes={episodes}")
    return ep


def _npz_path(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:08d}.npz")


def _json_path(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:08d}.json")


def save_train_checkpoint(directory: str, step: int, device_state: Any,
                          host_state: Dict[str, Any]) -> str:
    """Write one checkpoint; returns the .npz path. ``LATEST`` is updated
    last (atomic rename) so a crash mid-write never corrupts the newest
    resumable step."""
    os.makedirs(directory, exist_ok=True)
    save_pytree(device_state, _npz_path(directory, step))
    tmp = _json_path(directory, step) + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"step": step, **host_state}, f)
    os.replace(tmp, _json_path(directory, step))
    tmp = os.path.join(directory, "LATEST.tmp")
    with open(tmp, "w") as f:
        f.write(str(step))
    os.replace(tmp, os.path.join(directory, "LATEST"))
    return _npz_path(directory, step)


def _complete(directory: str, step: int) -> bool:
    """Both halves of the checkpoint must exist - a crash between the npz
    and json writes leaves an orphan that must not be offered for resume."""
    return (os.path.exists(_npz_path(directory, step))
            and os.path.exists(_json_path(directory, step)))


def latest_checkpoint_step(directory: str) -> Optional[int]:
    """Newest complete step in ``directory`` (None when empty/missing).
    Trusts ``LATEST`` when present and valid, else scans the step files."""
    if not os.path.isdir(directory):
        return None
    latest = os.path.join(directory, "LATEST")
    if os.path.exists(latest):
        try:
            with open(latest) as f:
                step = int(f.read().strip())
        except (ValueError, OSError):
            step = None  # unreadable/garbage LATEST: fall back to the scan
        if step is not None and _complete(directory, step):
            return step
    steps = [int(m.group(1)) for name in os.listdir(directory)
             if (m := _STEP_RE.match(name)) and _complete(directory,
                                                          int(m.group(1)))]
    return max(steps) if steps else None


def load_train_checkpoint(
    directory: str, like: Any, *, step: Optional[int] = None,
    shardings: Optional[Any] = None,
) -> Tuple[int, Any, Dict[str, Any]]:
    """Restore ``(step, device_state, host_state)``.

    ``like`` is the freshly-initialized device-state pytree (structure,
    shapes, dtypes - and, when already placed on a mesh, the shardings to
    restore onto unless ``shardings`` overrides them).
    """
    if step is None:
        step = latest_checkpoint_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    if shardings is None:
        leaves = jax.tree.leaves(like)
        if leaves and all(hasattr(x, "sharding") for x in leaves):
            shardings = jax.tree.map(lambda x: x.sharding, like)
    device_state = load_pytree(_npz_path(directory, step), like,
                               shardings=shardings)
    with open(_json_path(directory, step)) as f:
        host_state = json.load(f)
    return step, device_state, host_state
