"""Checkpointing: pytree <-> .npz with structure manifest.

No orbax on this box; this implements a self-contained, deterministic
format: leaves are flattened with ``jax.tree_util`` key paths as archive
names, restored into the original treedef. Restore is sharding-aware: pass
``like`` (a pytree of arrays or ShapeDtypeStructs with shardings) and each
leaf is device_put with the matching sharding.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import numpy as np


def _key_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_pytree(tree: Any, path: str) -> None:
    """Atomic write: the archive lands under ``path`` only via
    ``os.replace`` of a fully-written temp file, so a crash (or SIGKILL -
    the chaos harness does exactly this) mid-save can never leave a torn
    half-archive where a resumable checkpoint is expected. The temp file
    is written through an open handle because ``np.savez`` appends
    ``.npz`` to bare path names."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {}
    manifest = []
    for p, leaf in flat:
        k = _key_str(p)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":  # bfloat16 etc: no numpy equivalent
            arr = np.asarray(jax.numpy.asarray(leaf).astype("float32"))
            manifest.append(k + "::bf16")
        else:
            manifest.append(k)
        arrays[k] = arr
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    try:
        with open(tmp, "wb") as f:
            np.savez(f, __manifest__=np.asarray(json.dumps(manifest)),
                     **arrays)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_pytree(path: str, like: Any, *, shardings: Optional[Any] = None) -> Any:
    with np.load(path, allow_pickle=False) as z:
        flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for p, ref in flat_like:
            k = _key_str(p)
            if k not in z:
                raise KeyError(f"checkpoint {path} missing leaf {k}")
            arr = z[k]
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(f"{k}: shape {arr.shape} != expected {ref.shape}")
            ref_dtype = getattr(ref, "dtype", None)
            if ref_dtype is not None and arr.dtype != ref_dtype:
                arr = jax.numpy.asarray(arr).astype(ref_dtype)
            leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree
