from repro.checkpoint.store import load_pytree, save_pytree
from repro.checkpoint.train_state import (
    latest_checkpoint_step,
    load_train_checkpoint,
    save_train_checkpoint,
)

__all__ = [
    "save_pytree",
    "load_pytree",
    "save_train_checkpoint",
    "load_train_checkpoint",
    "latest_checkpoint_step",
]
