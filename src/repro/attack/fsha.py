"""FSHA-style reconstruction adversary against smashed activations.

The attacker observes the activations crossing a split boundary of the
1F1B executor (Eq. 1's wireless hop) and tries to reconstruct the
private stage-0 input. Following the feature-space-hijacking trio
(*Unleashing the Tiger*, Pasquini et al.; *Evaluating Privacy Leakage in
Split Learning*, Qiu et al.), the attacker trains three networks with an
alternating step:

* **encoder** ``enc``: captured smashed activation -> attacker feature
  space;
* **decoder** ``dec``: feature -> reconstructed private input;
* **discriminator** ``disc``: separates features of the attacker's OWN
  shadow pipeline (a re-initialized copy of the split model over public
  auxiliary data) from features of captured client activations.

Unlike full FSHA the client model is FIXED - we are *evaluating* the
leakage of a given split, not hijacking the training protocol - so the
adversarial game aligns the ATTACKER's encoder to the captured feature
distribution: step A trains enc+dec on the shadow inversion loss plus a
non-saturating generator loss on captured features; step B trains the
discriminator to separate the two. Captured-activation terms are gated
per step by a Bernoulli capture draw with the scenario's
``capture_probability * monitor_prob`` weight, so the wireless physics
(decoy powers, eavesdropper geometry, monitoring) shapes how much data
the attacker effectively trains on.

The whole training run is ONE jitted dispatch (``make_attack_chunk``,
the ``rollout.make_train_chunk`` idiom: ``.fn``/``.jitted``/
``.trace_count``), which is what ``repro.attack.population`` vmaps over
a (split boundary x scenario) population.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.nn.layers import init_mlp, mlp_apply
from repro.optim.optimizers import adamw, apply_updates

Array = jax.Array


@dataclass(frozen=True)
class AttackConfig:
    d_data: int  # private-input dim (stage-0 embedding width)
    d_smash: int  # smashed-activation dim crossing the boundary
    feat_dim: int = 32
    hidden: int = 64
    lr: float = 3e-3
    disc_lr: float = 1e-3
    adv_weight: float = 0.1  # weight of the captured-feature alignment loss
    # weight of the supervised inversion loss on captured hops whose
    # plaintext the attacker knows (the auxiliary-known-records assumption
    # of Qiu et al.: the adversary holds some records of the training
    # distribution, so a captured activation of a known record yields a
    # supervised (x, z) pair). Gated by the same per-step capture draw.
    known_weight: float = 1.0
    batch: int = 64


def attack_optimizers(cfg: AttackConfig):
    return adamw(cfg.lr), adamw(cfg.disc_lr)


def init_attacker(key, cfg: AttackConfig):
    k_e, k_d, k_c = jax.random.split(key, 3)
    return {
        "atk": {
            "enc": init_mlp(k_e, (cfg.d_smash, cfg.hidden, cfg.feat_dim)),
            "dec": init_mlp(k_d, (cfg.feat_dim, cfg.hidden, cfg.d_data)),
        },
        "disc": init_mlp(k_c, (cfg.feat_dim, cfg.hidden, 1)),
    }


def init_attack_state(params, cfg: AttackConfig):
    opt_a, opt_d = attack_optimizers(cfg)
    return opt_a.init(params["atk"]), opt_d.init(params["disc"])


def reconstruct(params, z: Array) -> Array:
    """dec(enc(z)): the attacker's input reconstruction."""
    return mlp_apply(params["atk"]["dec"], mlp_apply(params["atk"]["enc"], z))


def attack_scores(params, z: Array, x: Array):
    """(attack accuracy, reconstruction MSE) on held-out client data.

    Accuracy is the variance-explained of the reconstruction
    (1 - MSE/Var(x)) clipped to [0, 1] - 0 means the attacker does no
    better than predicting the mean, 1 means perfect reconstruction.
    This is the empirical per-boundary information value that
    :class:`repro.core.leakage.EmpiricalLeakage` prices hops with.
    """
    rec = reconstruct(params, z)
    mse = jnp.mean((rec - x) ** 2)
    var = jnp.mean((x - x.mean(axis=0, keepdims=True)) ** 2)
    return jnp.clip(1.0 - mse / jnp.maximum(var, 1e-12), 0.0, 1.0), mse


def _attacker_loss(atk, disc, cfg: AttackConfig, z_aux, x_aux, z_cli, x_cli,
                   cap):
    # shadow inversion: invert the attacker's own (re-initialized) pipeline
    f_aux = mlp_apply(atk["enc"], z_aux)
    rec = mlp_apply(atk["dec"], f_aux)
    l_rec = jnp.mean((rec - x_aux) ** 2)
    f_cli = mlp_apply(atk["enc"], z_cli)
    # known-record inversion: captured activations of records the attacker
    # holds the plaintext for give supervised pairs (Qiu et al.)
    rec_cli = mlp_apply(atk["dec"], f_cli)
    l_known = jnp.mean((rec_cli - x_cli) ** 2)
    # captured-feature alignment (non-saturating generator loss); both
    # client terms are active only on steps where the eavesdropper
    # actually captured the hop
    logit = mlp_apply(disc, f_cli)[..., 0]
    l_adv = jnp.mean(jax.nn.softplus(-logit))
    loss = l_rec + cap * (cfg.known_weight * l_known + cfg.adv_weight * l_adv)
    return loss, (l_known, l_adv)


def _disc_loss(disc, f_aux, f_cli, cap):
    l_real = jnp.mean(jax.nn.softplus(-mlp_apply(disc, f_aux)[..., 0]))
    l_fake = jnp.mean(jax.nn.softplus(mlp_apply(disc, f_cli)[..., 0]))
    return l_real + cap * l_fake


def make_attack_chunk(cfg: AttackConfig, n_steps: int):
    """ONE jitted call running ``n_steps`` alternating attacker updates.

    Returns ``chunk(params, opt_state, pools, p_eff, key) ->
    (params, opt_state, metrics)`` where ``pools`` is the device-resident
    data ``{"z_cli": (P, d_smash), "x_cli": (P, d_data),
    "z_aux": (P, d_smash), "x_aux": (P, d_data)}``, ``p_eff`` the scalar
    per-step capture probability (capture_probability x monitor_prob of
    the scenario), and ``metrics`` per-step traces
    ``{"recon_mse", "adv", "disc", "cap"}`` each ``(n_steps,)``
    (``recon_mse`` is the known-record reconstruction loss the CI smoke
    gate tracks). Exposes ``.fn`` (untraced, for the population
    vmap), ``.jitted`` and ``.trace_count`` like
    ``rollout.make_train_chunk``.
    """
    opt_a, opt_d = attack_optimizers(cfg)
    trace_count = [0]

    def fn(params, opt_state, pools, p_eff, key):
        trace_count[0] += 1
        pool = pools["z_cli"].shape[0]

        def step(carry, k):
            params, (sa, sd) = carry
            ki, kc = jax.random.split(k)
            idx = jax.random.randint(ki, (cfg.batch,), 0, pool)
            z_aux = pools["z_aux"][idx]
            x_aux = pools["x_aux"][idx]
            z_cli = pools["z_cli"][idx]
            x_cli = pools["x_cli"][idx]
            cap = (jax.random.uniform(kc) < p_eff).astype(jnp.float32)

            # step A: attacker (encoder + decoder)
            (_, (l_known, l_adv)), g = jax.value_and_grad(
                _attacker_loss, has_aux=True)(
                params["atk"], params["disc"], cfg, z_aux, x_aux, z_cli,
                x_cli, cap)
            ups, sa = opt_a.update(g, sa, params["atk"])
            atk = apply_updates(params["atk"], ups)

            # step B: discriminator, on the UPDATED encoder's features
            f_aux = jax.lax.stop_gradient(mlp_apply(atk["enc"], z_aux))
            f_cli = jax.lax.stop_gradient(mlp_apply(atk["enc"], z_cli))
            l_d, gd = jax.value_and_grad(_disc_loss)(
                params["disc"], f_aux, f_cli, cap)
            upd, sd = opt_d.update(gd, sd, params["disc"])
            disc = apply_updates(params["disc"], upd)

            metrics = {"recon_mse": l_known, "adv": l_adv, "disc": l_d,
                       "cap": cap}
            return ({"atk": atk, "disc": disc}, (sa, sd)), metrics

        keys = jax.random.split(key, n_steps)
        (params, opt_state), ms = jax.lax.scan(step, (params, opt_state), keys)
        return params, opt_state, ms

    jitted = jax.jit(fn)

    def chunk(params, opt_state, pools, p_eff, key):
        return jitted(params, opt_state, pools, p_eff, key)

    chunk.fn = fn
    chunk.jitted = jitted
    chunk.trace_count = trace_count
    return chunk


# ---------------------------------------------------------------------------
# smashed activations: what actually crosses each 1F1B stage boundary
# ---------------------------------------------------------------------------


def smashed_activations(params, model_cfg, tokens, cuts):
    """Stage-boundary activations of the split model for ``tokens``.

    Returns ``(x0, z)`` with ``x0`` (B, T, d) the private stage-0 input
    (the embedding - what the attacker reconstructs) and ``z``
    (K, B, T, d) the activation AFTER layer ``cuts[k]`` - exactly the
    tensor ``pipeline_step_fn``'s forward slot ships over hop k when the
    plan's cumulative boundary is ``cuts[k]`` (the stage-input stash of
    the next stage).
    """
    from repro.models import model as M

    sig = M.signature(model_cfg)
    period = M.find_period(sig)
    if period != 1:
        raise ValueError(
            f"attack assumes layer-group period 1 (got period {period}); "
            "same restriction as the pipeline executor")
    blocks = params["slots"][0]
    x0 = params["embed"][tokens]  # (B, T, d)
    positions = jnp.arange(tokens.shape[-1])

    def body(x, blk):
        out, _, _ = M.block_apply(blk, x, model_cfg, sig[0],
                                  positions=positions)
        return out, out

    _, ys = jax.lax.scan(body, x0, blocks)  # (L, B, T, d)
    cuts = jnp.asarray(cuts, jnp.int32)
    return x0, ys[cuts - 1]


def flatten_rows(x: Array) -> Array:
    """(..., B, T, d) -> (..., B*T, d): token-position rows for the MLPs."""
    return x.reshape(x.shape[:-3] + (x.shape[-3] * x.shape[-2], x.shape[-1]))
