"""Learned attacker-in-the-loop leakage evaluation.

A trainable FSHA-style reconstruction adversary (encoder / decoder /
discriminator, alternating jitted train step) measures how much a real
eavesdropper learns from the smashed activations crossing each split
boundary - the empirical counterpart of the paper's analytic Eq. 30
model, surfaced through :class:`repro.core.leakage.EmpiricalLeakage`.
"""
from repro.attack.fsha import (
    AttackConfig,
    attack_scores,
    flatten_rows,
    init_attack_state,
    init_attacker,
    make_attack_chunk,
    reconstruct,
    smashed_activations,
)
from repro.attack.population import (
    AttackResult,
    capture_weight,
    empirical_model_from,
    init_attacker_population,
    make_activation_scorer,
    make_population_attack_chunk,
    tiny_attack_model_cfg,
    train_attacker_population,
    train_empirical_model,
)

__all__ = [
    "AttackConfig",
    "AttackResult",
    "attack_scores",
    "capture_weight",
    "empirical_model_from",
    "flatten_rows",
    "init_attack_state",
    "init_attacker",
    "init_attacker_population",
    "make_activation_scorer",
    "make_attack_chunk",
    "make_population_attack_chunk",
    "reconstruct",
    "smashed_activations",
    "tiny_attack_model_cfg",
    "train_attacker_population",
    "train_empirical_model",
]
