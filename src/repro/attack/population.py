"""Attacker populations: one adversary per (split boundary x scenario).

The fused single-attacker chunk (:func:`repro.attack.fsha.
make_attack_chunk`) vmaps over a flattened (boundary x scenario) axis
exactly like ``scenario.train_population`` vmaps the SAC chunk: every
attacker trains in lockstep inside ONE jitted dispatch (1-trace audit
via ``.trace_count``), each against its own smashed-activation pool and
its scenario's capture probability.

``train_attacker_population`` is the end-to-end driver: it builds the
client model and the attacker's shadow copy, extracts the stage-boundary
activations for every requested cut point, trains the population, and
measures per-boundary attack accuracy on held-out client data.
``train_empirical_model`` wraps it into a ready
:class:`repro.core.leakage.EmpiricalLeakage`.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, replace
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.attack.fsha import (
    AttackConfig,
    attack_scores,
    flatten_rows,
    init_attack_state,
    init_attacker,
    make_attack_chunk,
    smashed_activations,
)
from repro.core.leakage import EmpiricalLeakage, capture_probability

Array = jax.Array


def capture_weight(monitor_prob: float, *, p_tx: float = 0.5,
                   dist_tx_e: float = 300.0,
                   decoy_p: Sequence[float] = (0.2,),
                   decoy_dist_e: Sequence[float] = (300.0,),
                   o: float = 1.0) -> float:
    """Effective per-hop capture probability of one eavesdropper under a
    canonical geometry: Theorem 1's capture probability times the
    monitoring probability. This is the Bernoulli weight gating how often
    the attacker's training step actually receives a captured batch."""
    dp = jnp.asarray(decoy_p, jnp.float32)
    dde = jnp.asarray(decoy_dist_e, jnp.float32)[:, None]
    cap = capture_probability(jnp.float32(p_tx),
                              jnp.asarray([dist_tx_e], jnp.float32), dp, dde, o)
    return float(cap[0]) * float(monitor_prob)


def init_attacker_population(key, cfg: AttackConfig, n: int):
    """Stacked params + optimizer states for ``n`` attackers (axis 0)."""
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: init_attacker(k, cfg))(keys)
    opt_state = jax.vmap(lambda p: init_attack_state(p, cfg))(params)
    return params, opt_state


def make_population_attack_chunk(cfg: AttackConfig, n_steps: int):
    """vmapped attacker-population train chunk, ONE jitted dispatch.

    ``pop(params, opt_state, pools, p_eff, keys)`` with every argument
    stacked on a leading population axis (pools is a dict of (N, P, d)
    arrays, ``p_eff`` (N,), ``keys`` (N, 2)). Exposes ``.fn``/
    ``.jitted``/``.trace_count`` - the audit asserts ``trace_count == 1``
    across every (boundary x scenario) batch of the same shapes.
    """
    chunk = make_attack_chunk(cfg, n_steps)
    fn = jax.vmap(chunk.fn, in_axes=(0, 0, 0, 0, 0))
    jitted = jax.jit(fn)

    def pop(params, opt_state, pools, p_eff, keys):
        return jitted(params, opt_state, pools, p_eff, keys)

    pop.fn = fn
    pop.jitted = jitted
    pop.trace_count = chunk.trace_count
    return pop


@dataclass
class AttackResult:
    """Trained population + measurements.

    ``scores``/``final_mse`` are (n_cuts, n_scenarios): held-out attack
    accuracy (variance-explained, in [0, 1]) and reconstruction MSE.
    ``recon_mse`` is the per-step training trace
    (n_cuts, n_scenarios, steps) - the CI smoke gate checks it decreases
    monotonically-on-average. ``params`` keeps the stacked population
    (leading axis cut-major: attacker ``k * n_scenarios + s``).
    """

    params: Any
    opt_state: Any
    scores: np.ndarray
    final_mse: np.ndarray
    recon_mse: np.ndarray
    cuts: np.ndarray
    capture_weights: np.ndarray
    num_layers: int
    trace_count: list
    seconds: float
    steps: int

    @property
    def population(self) -> int:
        return self.scores.size


def _tile_cuts_scenarios(per_cut: Array, n_scen: int) -> Array:
    """(K, ...) -> (K * n_scen, ...), cut-major attacker order."""
    return jnp.repeat(per_cut, n_scen, axis=0)


def _standardize(a: Array, eps: float = 1e-6):
    """Zero-mean/unit-std per dim over the pool axis (-2); returns stats."""
    m = a.mean(axis=-2, keepdims=True)
    s = a.std(axis=-2, keepdims=True) + eps
    return (a - m) / s, m, s


def train_attacker_population(
    model_cfg,
    *,
    cuts: Sequence[int],
    capture_weights: Sequence[float],
    acfg: Optional[AttackConfig] = None,
    steps: int = 300,
    seed: int = 0,
    train_tokens=(32, 64),
    eval_tokens=(8, 64),
    embed_scale: float = 25.0,
) -> AttackResult:
    """Train one attacker per (cut point x scenario) in one dispatch.

    ``cuts`` are cumulative layer indices (1..L-1) of ``model_cfg``;
    ``capture_weights`` the per-scenario effective capture probabilities
    (:func:`capture_weight`). The client model and the attacker's shadow
    model are two independent initializations of ``model_cfg`` - the
    shadow supplies the attacker's (x, z) inversion pairs, captured
    client activations only enter through the capture-gated adversarial
    alignment, so low-capture scenarios genuinely learn less.

    ``embed_scale`` lifts the probe models' embedding table to O(1)
    magnitude: a randomly initialized embedding is ~50x smaller than the
    block outputs it rides the residual stream with, which makes the
    token signal in a smashed activation vanishingly small - unlike a
    trained model, whose embeddings carry O(1) token information. The
    rescale restores a realistic signal-to-block ratio for the probe.
    """
    cuts = np.asarray(cuts, np.int64)
    capture_weights = np.asarray(capture_weights, np.float64)
    n_scen = len(capture_weights)
    n = len(cuts) * n_scen
    if acfg is None:
        acfg = AttackConfig(d_data=model_cfg.d_model, d_smash=model_cfg.d_model)

    key = jax.random.PRNGKey(seed)
    k_cli, k_shadow, k_tok, k_init, k_train = jax.random.split(key, 5)
    from repro.models import init_params

    cli_params = init_params(k_cli, model_cfg)
    shadow_params = init_params(k_shadow, model_cfg)
    cli_params["embed"] = cli_params["embed"] * embed_scale
    shadow_params["embed"] = shadow_params["embed"] * embed_scale

    kt_cli, kt_aux, kt_ev = jax.random.split(k_tok, 3)
    toks = lambda k, shape: jax.random.randint(k, shape, 0, model_cfg.vocab_size)
    t_cli, t_aux, t_ev = (toks(kt_cli, train_tokens), toks(kt_aux, train_tokens),
                          toks(kt_ev, eval_tokens))

    # (K, P, d) pools: client activations (captured), shadow pairs (owned).
    # Everything is standardized per cut over the pool axis - activation
    # scale grows with residual depth, and the variance-explained score is
    # computed in the same standardized space (held-out data uses the
    # TRAIN pool's client statistics).
    x_cli, z_cli = smashed_activations(cli_params, model_cfg, t_cli, cuts)
    x_aux, z_aux = smashed_activations(shadow_params, model_cfg, t_aux, cuts)
    x_ev, z_ev = smashed_activations(cli_params, model_cfg, t_ev, cuts)
    z_cli, zc_m, zc_s = _standardize(flatten_rows(z_cli))
    z_aux, _, _ = _standardize(flatten_rows(z_aux))
    z_ev = (flatten_rows(z_ev) - zc_m) / zc_s
    x_cli, xc_m, xc_s = _standardize(flatten_rows(x_cli))
    x_aux, _, _ = _standardize(flatten_rows(x_aux))
    x_ev = (flatten_rows(x_ev) - xc_m) / xc_s
    x_cli = jnp.broadcast_to(x_cli[None], z_cli.shape)
    x_aux = jnp.broadcast_to(x_aux[None], z_aux.shape)
    x_ev = jnp.broadcast_to(x_ev[None], z_ev.shape)

    pools = {
        "z_cli": _tile_cuts_scenarios(z_cli, n_scen),
        "x_cli": _tile_cuts_scenarios(x_cli, n_scen),
        "z_aux": _tile_cuts_scenarios(z_aux, n_scen),
        "x_aux": _tile_cuts_scenarios(x_aux, n_scen),
    }
    p_eff = jnp.tile(jnp.asarray(capture_weights, jnp.float32), len(cuts))

    params, opt_state = init_attacker_population(k_init, acfg, n)
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        k_train, jnp.arange(n))

    pop = make_population_attack_chunk(acfg, steps)
    t0 = time.time()
    params, opt_state, metrics = pop(params, opt_state, pools, p_eff, keys)
    jax.block_until_ready(params)
    seconds = time.time() - t0

    sc, mse = jax.vmap(attack_scores)(
        params, _tile_cuts_scenarios(z_ev, n_scen),
        _tile_cuts_scenarios(x_ev, n_scen))
    shape = (len(cuts), n_scen)
    return AttackResult(
        params=params,
        opt_state=opt_state,
        scores=np.asarray(sc).reshape(shape),
        final_mse=np.asarray(mse).reshape(shape),
        recon_mse=np.asarray(metrics["recon_mse"]).reshape(shape + (steps,)),
        cuts=cuts,
        capture_weights=capture_weights,
        num_layers=model_cfg.num_layers,
        trace_count=pop.trace_count,
        seconds=seconds,
        steps=steps,
    )


def make_activation_scorer(stacked_params):
    """Live-activation scorer for :class:`EmpiricalLeakage.score_fn`.

    ``stacked_params`` is a trained attacker population whose leading
    axis matches the hop axis of the activations dict
    ``{"z": (H, n, d_smash), "x": (H, n, d_data)}``; returns per-hop
    attack accuracies (H,).
    """

    def score(activations):
        def one(p, z, x):
            s, _ = attack_scores(p, z, x)
            return s

        return jax.vmap(one)(stacked_params, activations["z"],
                             activations["x"])

    return score


def empirical_model_from(result: AttackResult, *, scenario_idx: int = 0,
                         num_layers: Optional[int] = None,
                         with_scorer: bool = False) -> EmpiricalLeakage:
    """Wrap one scenario column of an :class:`AttackResult` into an
    :class:`EmpiricalLeakage` (interpolated onto ``num_layers``)."""
    score_fn = None
    if with_scorer:
        n_scen = len(result.capture_weights)
        col = jax.tree.map(lambda a: a[scenario_idx::n_scen], result.params)
        score_fn = make_activation_scorer(col)
    return EmpiricalLeakage.from_scores(
        result.cuts, result.scores[:, scenario_idx], result.num_layers,
        num_layers=num_layers, score_fn=score_fn)


def tiny_attack_model_cfg(depth: int = 8, d_model: int = 32):
    """Reduced transformer the quick empirical model measures leakage on."""
    from repro.configs import get_config

    cfg = get_config("stablelm-1.6b").reduced()
    return replace(cfg, num_layers=depth, d_model=d_model, num_heads=2,
                   num_kv_heads=2, head_dim=16, d_ff=64, vocab_size=256,
                   name=f"attack-probe-{depth}x{d_model}")


def train_empirical_model(*, seed: int = 0, steps: int = 400,
                          depth: int = 8, d_model: int = 32,
                          monitor_prob: float = 0.8,
                          num_layers: Optional[int] = None) -> EmpiricalLeakage:
    """One-call empirical leakage model: train a small attacker population
    over every cut of a reduced transformer and return the measured
    per-layer values as an :class:`EmpiricalLeakage` (interpolated onto
    ``num_layers`` when pricing a different profile's depth). This is
    what the fig benchmarks' ``--leakage empirical`` flag builds."""
    model_cfg = tiny_attack_model_cfg(depth, d_model)
    res = train_attacker_population(
        model_cfg,
        cuts=np.arange(1, depth),
        capture_weights=[capture_weight(monitor_prob)],
        steps=steps,
        seed=seed,
    )
    return empirical_model_from(res, num_layers=num_layers)
