from repro.optim.optimizers import (
    OptState,
    adamw,
    clip_by_global_norm,
    cosine_schedule,
    linear_warmup_cosine,
    sgd_momentum,
)

__all__ = [
    "OptState",
    "adamw",
    "sgd_momentum",
    "clip_by_global_norm",
    "cosine_schedule",
    "linear_warmup_cosine",
]
