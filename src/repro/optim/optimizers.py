"""Minimal optimizer library (optax is not available offline).

Optimizers are (init, update) pairs over arbitrary pytrees, matching the
usual gradient-transformation contract:

    opt = adamw(lr=1e-3)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    mu: Any = None
    nu: Any = None


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


def _tree_zeros_like(tree, dtype=None):
    return jax.tree.map(lambda x: jnp.zeros_like(x, dtype=dtype or x.dtype), tree)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), norm


def cosine_schedule(base_lr: float, total_steps: int, final_frac: float = 0.1):
    def lr(step):
        frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return base_lr * (final_frac + (1 - final_frac) * cos)

    return lr


def linear_warmup_cosine(base_lr: float, warmup: int, total_steps: int, final_frac: float = 0.1):
    cos = cosine_schedule(base_lr, max(total_steps - warmup, 1), final_frac)

    def lr(step):
        w = jnp.clip(step / max(warmup, 1), 0.0, 1.0)
        return jnp.where(step < warmup, base_lr * w, cos(step - warmup))

    return lr


def adamw(
    lr: float | Callable = 1e-3,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    max_grad_norm: Optional[float] = None,
    state_dtype=jnp.float32,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=_tree_zeros_like(params, state_dtype),
            nu=_tree_zeros_like(params, state_dtype),
        )

    def update(grads, state: OptState, params=None):
        if max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state.step + 1
        stepf = step.astype(jnp.float32)
        bc1 = 1 - b1**stepf
        bc2 = 1 - b2**stepf
        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(m.dtype), state.mu, grads
        )
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(v.dtype)),
            state.nu,
            grads,
        )
        lr_t = lr_fn(step)

        def upd(m, v, p):
            u = -(lr_t * (m / bc1) / (jnp.sqrt(v / bc2) + eps))
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(u.dtype)
            return u.astype(p.dtype)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, OptState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def sgd_momentum(lr: float | Callable = 1e-2, momentum: float = 0.9) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return OptState(step=jnp.zeros((), jnp.int32), mu=_tree_zeros_like(params))

    def update(grads, state: OptState, params=None):
        step = state.step + 1
        mu = jax.tree.map(lambda m, g: momentum * m + g.astype(m.dtype), state.mu, grads)
        updates = jax.tree.map(lambda m, p: (-lr_fn(step) * m).astype(p.dtype), mu, params)
        return updates, OptState(step=step, mu=mu)

    return Optimizer(init=init, update=update)
