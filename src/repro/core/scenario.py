"""Scenario parameters as a runtime pytree + scenario-batched train/eval.

The seed froze every physics constant (``NetworkConfig`` fields,
``monitor_prob``, ``power_levels``, budgets, ``leak_scale``) into jit
closures, so each point of the paper's sweeps (Figs. 5/6/8) paid a full
recompile and the scenario axis could not ride the vectorized rollout
engine. This module splits the env configuration into

* **static structure** - shapes only: U devices, E_max eavesdroppers,
  S stages, NBINS size bins, number of power levels. These stay on
  ``MHSLEnv`` and fix every array shape.
* **dynamic physics** - ``ScenarioParams``, a pytree of jnp scalars /
  small vectors passed as a *runtime argument* through
  ``channel -> leakage -> env -> rollout -> trainers``. One compiled
  train/eval step serves every sweep point; sweeping is just calling the
  same compiled function with different leaf values, or vmapping over a
  stacked scenario batch.

Sweep axes that change a SHAPE (more devices, more stages) still require
a new env; eavesdropper count specifically does NOT - pad to ``E_max``
and vary ``eave_mask`` (Fig. 6's sweep runs in one padded env).

Composition with ``num_envs``: the scenario axis vmaps OUTSIDE the env
population, giving ``(num_scenarios, num_envs, T, ...)`` trajectories
from a single jitted call (``make_population_rollout`` /
``make_population_evaluator``), and ``train_population`` trains one agent
per scenario in lockstep on device.
"""
from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import NetworkConfig

Array = jax.Array


class ScenarioParams(NamedTuple):
    """Dynamic physics of one MHSL scenario (all leaves are jnp arrays).

    Every field is a runtime value: changing any of them re-uses the
    existing jit cache. Vector fields are sized by the env's static
    shapes (``E_max`` eavesdroppers, ``P`` power levels).
    """

    monitor_prob: Array  # (E,) per-eavesdropper q_e
    eave_mask: Array  # (E,) 1.0 = active, 0.0 = padded-out eavesdropper
    know_eave_locations: Array  # () 1.0 = l_M observed, 0.0 = blinded
    gamma_t: Array  # () per-iteration delay budget (s)
    gamma_e: Array  # () per-iteration energy budget (J)
    bandwidth_hz: Array  # () B
    noise_w: Array  # () N0 * B in watts
    rayleigh_o: Array  # () o
    power_levels: Array  # (P,) discrete transmit powers (W)
    leak_scale: Array  # () leakage reward scale
    area_m: Array  # () deployment area side length
    f_cpu_hz: Array  # () f^B device CPU clock
    theta_chip: Array  # () vartheta chip energy coefficient
    lambda_f: Array  # () Eq. 8 complexity multiplier (seed applied 1.0)
    lambda_b: Array  # () Eq. 9 complexity multiplier (seed applied 1.0)
    # per-hop link model (heterogeneous wireless links between consecutive
    # stages): hop k of a plan transmits at hop_bandwidth_hz[k] (thermal
    # noise scales with it) and pays a fixed hop_latency_s[k] on every
    # activation/cotangent transmission. Defaults (full(bandwidth_hz),
    # zeros) reproduce the uniform-link seed physics bit-exactly.
    hop_bandwidth_hz: Array  # (max_split - 1,)
    hop_latency_s: Array  # (max_split - 1,)
    # architecture-aware state pricing (NetworkConfig.state_cycles_per_bit):
    # maintenance cycles per resident state bit folded into the Eq. 8-9
    # compute terms. 0.0 reproduces homogeneous residual-MLP pricing.
    state_cycles_per_bit: Array  # ()

    @property
    def num_eaves(self) -> int:
        return self.monitor_prob.shape[-1]

    @property
    def num_power_levels(self) -> int:
        return self.power_levels.shape[-1]

    @property
    def num_hops(self) -> int:
        return self.hop_bandwidth_hz.shape[-1]


def scenario_from_net(
    net: NetworkConfig,
    *,
    know_eave_locations: bool = True,
    leak_scale: float = 1.0,
) -> ScenarioParams:
    """Build the dynamic-physics pytree matching a Table-I config.

    ``lambda_f``/``lambda_b`` default to 1.0: the seed env never threaded
    ``NetworkConfig.lambda_f`` into Eqs. 8-9 (faithfulness ledger), and
    this constructor preserves that behaviour exactly. Sweeps can set
    them explicitly via ``scenario_grid``.
    """
    e = net.num_eaves
    # every leaf carries an explicit (strong) dtype: weak-typed python
    # scalars would make `scenario=None` default-path traces incompatible
    # with explicit sweep scenarios and silently retrace the engine
    return ScenarioParams(
        monitor_prob=jnp.full((e,), net.monitor_prob, jnp.float32),
        eave_mask=jnp.ones((e,), jnp.float32),
        know_eave_locations=jnp.asarray(
            1.0 if know_eave_locations else 0.0, jnp.float32),
        gamma_t=jnp.asarray(net.gamma_t, jnp.float32),
        gamma_e=jnp.asarray(net.gamma_e, jnp.float32),
        bandwidth_hz=jnp.asarray(net.bandwidth_hz, jnp.float32),
        noise_w=jnp.asarray(net.noise_w, jnp.float32),
        rayleigh_o=jnp.asarray(net.rayleigh_o, jnp.float32),
        power_levels=jnp.asarray(net.power_levels, jnp.float32),
        leak_scale=jnp.asarray(leak_scale, jnp.float32),
        area_m=jnp.asarray(net.area_m, jnp.float32),
        f_cpu_hz=jnp.asarray(net.f_cpu_hz, jnp.float32),
        theta_chip=jnp.asarray(net.theta_chip, jnp.float32),
        lambda_f=jnp.asarray(1.0, jnp.float32),
        lambda_b=jnp.asarray(1.0, jnp.float32),
        hop_bandwidth_hz=jnp.asarray(net.hop_bandwidth_hz, jnp.float32),
        hop_latency_s=jnp.asarray(net.hop_latency_s, jnp.float32),
        state_cycles_per_bit=jnp.asarray(net.state_cycles_per_bit,
                                         jnp.float32),
    )


# ---------------------------------------------------------------------------
# grid construction + stacking
# ---------------------------------------------------------------------------


def replace_param(base: ScenarioParams, name: str, value) -> ScenarioParams:
    """``_replace`` one field, broadcasting scalars to the field's shape
    (e.g. ``monitor_prob=0.3`` -> ``full((E,), 0.3)``)."""
    ref = getattr(base, name)
    val = jnp.broadcast_to(jnp.asarray(value, ref.dtype), ref.shape)
    return base._replace(**{name: val})


def scale_param(base: ScenarioParams, name: str, scale) -> ScenarioParams:
    """Multiplicative sibling of :func:`replace_param`: scale one field
    elementwise (broadcast against the field's shape), keeping its dtype.
    Degradation sweeps (``core.faults.degrade_scenario``) ride this so a
    faulted scenario stays the same pytree structure as the base one."""
    ref = getattr(base, name)
    val = ref * jnp.asarray(scale, ref.dtype)
    return base._replace(**{name: val.astype(ref.dtype)})


def shift_param(base: ScenarioParams, name: str, delta) -> ScenarioParams:
    """Additive sibling of :func:`replace_param` (see :func:`scale_param`)."""
    ref = getattr(base, name)
    val = ref + jnp.asarray(delta, ref.dtype)
    return base._replace(**{name: val.astype(ref.dtype)})


def with_active_eaves(base: ScenarioParams, count: int) -> ScenarioParams:
    """Scenario with only the first ``count`` eavesdroppers active: their
    mask is 1, the rest are padding (zero monitoring, zero observation)."""
    e = base.num_eaves
    if not 0 <= count <= e:
        raise ValueError(f"count must be in [0, {e}], got {count}")
    mask = (jnp.arange(e) < count).astype(base.eave_mask.dtype)
    return base._replace(eave_mask=mask)


def scenario_grid(base: ScenarioParams, **axes: Sequence) -> List[ScenarioParams]:
    """Cartesian product over named parameter axes.

    ``scenario_grid(base, monitor_prob=[0.3, 0.6], gamma_e=[50.0, 75.0])``
    yields 4 scenarios in row-major order of the keyword arguments. The
    special axis ``active_eaves`` takes integer counts and varies
    ``eave_mask`` (padded-E sweep).
    """
    names = list(axes)
    out = []
    for combo in itertools.product(*(axes[n] for n in names)):
        sp = base
        for name, value in zip(names, combo):
            if name == "active_eaves":
                sp = with_active_eaves(sp, int(value))
            else:
                sp = replace_param(sp, name, value)
        out.append(sp)
    return out


def stack_scenarios(scenarios: Sequence[ScenarioParams]) -> ScenarioParams:
    """Stack N scenarios into one batched pytree (leading axis N) ready
    for the population vmap."""
    if not scenarios:
        raise ValueError("need at least one scenario")
    return jax.tree.map(lambda *xs: jnp.stack(xs), *scenarios)


def num_scenarios(stacked: ScenarioParams) -> int:
    return int(stacked.monitor_prob.shape[0])


def jit_cache_size(fn) -> int:
    """Compiled-variant count of an engine callable (recompile auditing).

    Accepts either a jitted function or one of this module's /
    ``rollout``'s wrappers (which expose their inner jit as ``.jitted``).
    Falls back to the wrapper's retrace counter on jax versions without
    the (private) ``_cache_size`` introspection.
    """
    inner = getattr(fn, "jitted", fn)
    if hasattr(inner, "_cache_size"):
        return inner._cache_size()
    trace_count = getattr(fn, "trace_count", None)
    if trace_count is not None:
        return trace_count[0]
    raise AttributeError(
        "no jit cache introspection available on this jax version")


# ---------------------------------------------------------------------------
# population rollout / evaluation: scenario axis composed with num_envs
# ---------------------------------------------------------------------------


def make_population_rollout(env, policy, hist_len: int, *,
                            share_params: bool = True,
                            extra_record=None):
    """Rollout vmapped over scenarios x envs in one jitted call.

    Returns ``run(params, rkeys, akeys, scenarios)`` where ``rkeys`` /
    ``akeys`` are ``(num_envs,)`` key batches shared across scenarios
    (controlled comparison: every sweep point replays the same episode
    draws), ``scenarios`` is a stacked ``ScenarioParams`` with leading
    axis N, and trajectory leaves come back ``(N, num_envs, T, ...)``.
    ``share_params=False`` maps ``params`` over the scenario axis too
    (one agent per scenario, as produced by ``train_population``).

    The wrapper exposes ``run.jitted`` (for ``jit_cache_size``) and
    ``run.trace_count`` (a 1-element list bumped on every retrace).
    """
    from repro.core.agents import rollout as R

    one = R.make_episode_rollout(env, policy, hist_len,
                                 extra_record=extra_record)
    trace_count = [0]

    def _per_scenario(params, rkeys, akeys, sp):
        trace_count[0] += 1  # executes only while tracing
        st0 = jax.vmap(env.reset, in_axes=(0, None))(rkeys, sp)
        return jax.vmap(one, in_axes=(None, 0, 0, None))(
            params, st0, akeys, sp
        )

    jitted = jax.jit(jax.vmap(
        _per_scenario,
        in_axes=(None if share_params else 0, None, None, 0),
    ))

    def run(params, rkeys, akeys, scenarios):
        return jitted(params, rkeys, akeys, scenarios)

    run.jitted = jitted
    run.trace_count = trace_count
    return run


def make_population_evaluator(env, policy, hist_len: int = 1, *,
                              share_params: bool = True,
                              leakage_model=None):
    """One compiled eval step for a whole scenario sweep.

    Returns ``evaluate(params, rkeys, akeys, scenarios)`` ->
    ``{"reward", "leak", "viol"}``, each ``(N,)``: per-scenario means
    over the episode batch of per-episode sums. A 5-point
    ``monitor_prob`` grid (or any other parameter grid of the same
    shapes) compiles this exactly once.

    ``leakage_model`` overrides the env's :class:`~repro.core.leakage.
    LeakageModel` for this evaluation (e.g. score an analytically
    trained agent under attacker-measured EmpiricalLeakage values).
    """
    from repro.core.agents import rollout as R

    if leakage_model is not None:
        env = dataclasses.replace(env, leakage_model=leakage_model)

    one = R.make_episode_rollout(env, policy, hist_len)
    trace_count = [0]

    def _per_scenario(params, rkeys, akeys, sp):
        trace_count[0] += 1
        st0 = jax.vmap(env.reset, in_axes=(0, None))(rkeys, sp)
        _, traj = jax.vmap(one, in_axes=(None, 0, 0, None))(
            params, st0, akeys, sp
        )
        return {
            "reward": traj["reward"].sum(axis=-1).mean(),
            "leak": traj["leak"].sum(axis=-1).mean(),
            "viol": traj["viol"].sum(axis=-1).mean(),
        }

    jitted = jax.jit(jax.vmap(
        _per_scenario,
        in_axes=(None if share_params else 0, None, None, 0),
    ))

    def evaluate(params, rkeys, akeys, scenarios):
        return jitted(params, rkeys, akeys, scenarios)

    evaluate.jitted = jitted
    evaluate.trace_count = trace_count
    return evaluate


def evaluate_population(env, policy, params, scenarios, *,
                        episodes: int = 20, seed: int = 1000,
                        hist_len: int = 1, share_params: bool = True,
                        leakage_model=None) -> Dict[str, np.ndarray]:
    """Evaluate ``params`` across a stacked scenario batch in ONE jitted
    call (fresh geometry per episode, same episode keys per scenario).

    Key derivation mirrors ``loops.evaluate_sac`` so a batch-of-1 sweep
    reproduces the single-scenario evaluation numbers. ``leakage_model``
    swaps the leakage pricing for this evaluation (analytic default).
    """
    ev = make_population_evaluator(env, policy, hist_len,
                                   share_params=share_params,
                                   leakage_model=leakage_model)
    key = jax.random.PRNGKey(seed)
    k_reset, k_act = jax.random.split(key)
    out = ev(params, jax.random.split(k_reset, episodes),
             jax.random.split(k_act, episodes), scenarios)
    return {k: np.asarray(v) for k, v in out.items()}


# ---------------------------------------------------------------------------
# population training: one SAC agent per scenario, trained in lockstep
# ---------------------------------------------------------------------------


@dataclass
class PopulationResult:
    """Per-scenario training curves + the stacked parameter pytree
    (leading axis = scenario)."""

    results: List[Any] = field(default_factory=list)  # List[TrainResult]
    params: Any = None


def _stack_like(tree, n: int):
    """Zero-initialized copy of ``tree`` with a new leading axis n."""
    return jax.tree.map(lambda x: jnp.zeros((n,) + x.shape, x.dtype), tree)


def train_population(env, cfg, scenarios: ScenarioParams, *,
                     episodes: int = 200, seed: int = 0,
                     warmup_episodes: int = 10, num_envs: int = 1,
                     resample_positions: bool = False, mesh=None,
                     checkpoint_dir: Optional[str] = None,
                     checkpoint_every: int = 0,
                     resume: bool = True) -> PopulationResult:
    """Train one ICM-CA SAC agent per scenario, all scenarios in lockstep.

    The whole chunk cycle - vmapped rollout over ``(N, num_envs)``,
    batched replay writes into N stacked device buffers, N fused update
    scans - runs under single jitted calls with the scenario axis mapped
    by ``jax.vmap``; nothing recompiles across scenarios. Chunking,
    warmup rounding, and metric bookkeeping match ``loops.train_sac``
    (every scenario shares the chunk schedule, reset keys, and action
    keys, so sweep points differ only by their physics).

    ``mesh`` (``launch.mesh.make_population_mesh``) shards the SCENARIO
    axis across devices: per-scenario agent params, optimizer state,
    replay buffers, and the stacked ``ScenarioParams`` all carry their
    leading ``N`` axis on the mesh, while the shared reset/action keys are
    replicated - pure data parallelism over sweep points, with metrics
    all-gathered by the per-chunk ``device_get``. The compiled chunk
    functions are unchanged, so a 1-device mesh is bit-identical to the
    plain vmap path (pinned by ``tests/test_population_mesh.py``).

    ``checkpoint_dir`` / ``checkpoint_every`` / ``resume`` behave as in
    ``loops.train_sac``: complete loop state saved at chunk boundaries,
    bit-exact continuation on restore.
    """
    from repro.checkpoint import train_state as TS
    from repro.core.agents import rollout as R
    from repro.core.agents import sac as SAC
    from repro.core.agents.loops import (
        TrainResult, _reduced_chunk_metrics, _sac_example, _SAC_FIELDS,
    )
    from repro.distribution import population as PD

    if num_envs < 1:
        raise ValueError(f"num_envs must be >= 1, got {num_envs}")
    n = num_scenarios(scenarios)
    adims = env.action_dims
    key = jax.random.PRNGKey(seed)
    key, k0 = jax.random.split(key)
    params = jax.vmap(
        lambda k: SAC.init_agent(k, env.obs_dim, adims, cfg)
    )(jax.random.split(k0, n))
    update, init_opt = SAC.make_update(adims, cfg)
    opt_state = jax.vmap(init_opt)(params)

    buf = _stack_like(R.buffer_init(cfg.buffer_size, _sac_example(env, cfg)), n)
    n_updates = cfg.updates_per_step * env.episode_len * num_envs
    # the fused train chunk vmapped over the scenario axis: params /
    # optimizer state / buffers / update keys / scenarios are mapped, the
    # shared chunk keys and warmup flag are broadcast. The stacked buffer
    # storage is donated where XLA supports it (in-place ring writes on
    # accelerators; CPU does not implement donation).
    chunk = R.make_train_chunk(
        env, R.uniform_policy(adims), R.sac_policy(adims, cfg), update,
        hist_len=cfg.hist_len, fields=_SAC_FIELDS, batch_size=cfg.batch,
        n_updates=n_updates,
    )
    donate = (2,) if jax.default_backend() != "cpu" else ()
    vm_chunk = jax.jit(
        jax.vmap(chunk.fn, in_axes=(0, 0, 0, None, None, 0, None, 0)),
        donate_argnums=donate,
    )

    pop = PopulationResult(results=[TrainResult() for _ in range(n)])
    seen: List[set] = [set() for _ in range(n)]
    key, reset_key = jax.random.split(key)

    # mesh placement: scenario axis sharded, shared chunk keys replicated
    params = PD.shard_population(params, mesh, n)
    opt_state = PD.shard_population(opt_state, mesh, n)
    buf = PD.shard_population(buf, mesh, n)
    scenarios = PD.shard_population(scenarios, mesh, n)

    # run fingerprint: loop knobs + agent config + the stacked scenario
    # physics; TS.validate_resume hard-errors on any mismatch (editing a
    # sweep grid must not silently resume the old grid's checkpoint)
    meta = dict(seed=seed, num_envs=num_envs, num_scenarios=n,
                warmup_episodes=warmup_episodes,
                resample_positions=resample_positions,
                cfg=repr(cfg), scenario=TS.pytree_fingerprint(scenarios))

    ep = 0
    last_saved = None
    if checkpoint_dir and resume and (
        TS.latest_checkpoint_step(checkpoint_dir) is not None
    ):
        like = dict(params=params, opt_state=opt_state, buf=buf,
                    key=key, reset_key=reset_key)
        _, dev, host = TS.load_train_checkpoint(checkpoint_dir, like)
        TS.validate_resume(host, meta, episodes, checkpoint_dir)
        params, opt_state, buf = dev["params"], dev["opt_state"], dev["buf"]
        key, reset_key = dev["key"], dev["reset_key"]
        ep = last_saved = int(host["ep"])
        for res, saved in zip(pop.results, host["results"]):
            res.episode_reward = list(saved["episode_reward"])
            res.episode_leak = list(saved["episode_leak"])
            res.episode_violation = list(saved["episode_violation"])
            res.states_explored = list(saved["states_explored"])
        seen = [set(s) for s in host["seen"]]

    def _save(ep_now: int) -> None:
        TS.save_train_checkpoint(
            checkpoint_dir, ep_now,
            dict(params=params, opt_state=opt_state, buf=buf,
                 key=key, reset_key=reset_key),
            dict(ep=ep_now, meta=meta,
                 results=[dict(episode_reward=r.episode_reward,
                               episode_leak=r.episode_leak,
                               episode_violation=r.episode_violation,
                               states_explored=r.states_explored)
                          for r in pop.results],
                 seen=[sorted(s) for s in seen]),
        )

    while ep < episodes:
        if (checkpoint_dir and checkpoint_every
                and (last_saved is None or ep - last_saved >= checkpoint_every)):
            _save(ep)
            last_saved = ep
        if resample_positions:
            key, reset_key = jax.random.split(key)
        rkeys = R.episode_reset_keys(reset_key, num_envs, resample_positions)
        key, ksub, ku = jax.random.split(key, 3)
        akeys = jax.random.split(ksub, num_envs)
        rkeys = PD.replicate(rkeys, mesh)
        akeys = PD.replicate(akeys, mesh)
        ukeys = PD.shard_population(jax.random.split(ku, n), mesh, n)

        # every scenario's full chunk cycle in ONE buffer-donated dispatch;
        # the traced warmup flag and per-lane buffer-fill gate replace the
        # host-side `int(buf.size[0])` sync
        train = jnp.asarray(ep >= warmup_episodes)
        params, opt_state, buf, metrics = vm_chunk(
            params, opt_state, buf, rkeys, akeys, ukeys, train, scenarios
        )
        # one device->host transfer of the reduced metrics for all
        # scenarios (all-gathering the scenario shards), then per-episode
        # bookkeeping on each scenario's slice
        host = jax.device_get(metrics)
        for s in range(n):
            _reduced_chunk_metrics(
                pop.results[s], seen[s],
                jax.tree.map(lambda x: x[s], host), ep, episodes, num_envs,
            )
        ep += num_envs

    if checkpoint_dir and last_saved != ep:
        _save(ep)

    pop.params = params
    return pop
