"""MHSL RL environment (paper §III): jittable, lax.scan-able.

Episode structure (2S-1 steps, Eq. 15-23):
  step 1           : pick s_1 and its sub-model size (no transmission)
  steps 2..S       : pick next trainer (server at n=S), sub-model size,
                     decoy set, powers; forward hop s_{n-1} -> s_n happens
  steps S+1..2S-1  : gradient hops back (server -> ... -> s_1); agent picks
                     decoys + powers only

Action (factored discrete, masked):
  u       in [0, U)        next trainer device
  size    in [0, NBINS)    sub-model size bin (maps to #layers)
  decoys  in {0,1}^U       deceptive-signal devices for this hop
  p_tx    in [0, P)        trainer power level
  p_d     in [0, P)        decoy power level (shared across decoys)

State obs (Eq. 15): remaining energy/time, unassigned model fraction,
per-device assignment vector r, transmitter one-hot v, distances to
eavesdroppers l_M (zeroed when locations unknown) and devices l_D, phase.

Static vs dynamic split: ``MHSLEnv`` itself pins only the SHAPES
(U, E_max, S, NBINS, number of power levels, layer profile). Every
physics constant - budgets, monitoring probabilities, power-level
values, bandwidth/noise, leakage scale, CPU/energy coefficients, the
eavesdropper active-mask - lives in a ``ScenarioParams`` pytree
(``repro.core.scenario``) passed as a runtime argument to
``reset``/``observe``/``step``. One compiled step therefore serves every
sweep point; ``env.scenario()`` builds the defaults matching the
constructor flags, and omitting the argument falls back to it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import (
    NetworkConfig,
    compute_energy,
    compute_time_bwd,
    compute_time_fwd,
    data_rate,
    sample_positions,
    state_energy,
    state_time,
    tx_time,
)
from repro.core.leakage import AnalyticLeakage, LeakageModel
from repro.core.profiles import LayerProfile, profile_table
from repro.core.scenario import ScenarioParams, scenario_from_net

Array = jax.Array

NBINS = 4  # split-size bins
OMEGA_1 = 5.0  # energy-violation penalty weight (Eq. 20)
OMEGA_2 = 5.0  # time-violation penalty weight


class EnvState(NamedTuple):
    dev_pos: Array  # (U+1, 2), last row = server
    eav_pos: Array  # (E, 2)
    e_r: Array  # remaining energy (J)
    t_r: Array  # remaining time (s)
    assigned: Array  # (U+1,) 0 = free, k = holds stage k (1-indexed)
    stage_dev: Array  # (S,) device per stage, -1 = unset
    boundaries: Array  # (S,) cumulative layer counts, 0 = unset
    layers_used: Array  # scalar
    n: Array  # step counter (1-indexed)
    done: Array
    leaked: Array  # cumulative information leaked (for metrics)


_DEFAULT_LEAKAGE = AnalyticLeakage()


@dataclass(frozen=True)
class MHSLEnv:
    profile: LayerProfile
    net: NetworkConfig = NetworkConfig()
    know_eave_locations: bool = True
    leak_scale: float = 1.0
    # LeakageModel pricing the per-hop information values + the Monte-Carlo
    # draw in step(); None = the paper's AnalyticLeakage (bit-identical to
    # the pre-protocol free functions). Pass an EmpiricalLeakage to score
    # hops with attacker-measured values instead of the assumed leak_norm
    # decay (repro.attack.train_empirical_model builds one).
    leakage_model: Optional[LeakageModel] = None

    # ---- static structure --------------------------------------------------
    @property
    def U(self) -> int:
        return self.net.num_devices

    @property
    def E(self) -> int:
        return self.net.num_eaves

    @property
    def S(self) -> int:
        return self.net.max_split

    @property
    def L(self) -> int:
        return self.profile.num_layers

    @property
    def episode_len(self) -> int:
        return 2 * self.S - 1

    @property
    def num_power_levels(self) -> int:
        return len(self.net.power_levels)

    @property
    def action_dims(self) -> Dict[str, int]:
        return {
            "u": self.U,
            "size": NBINS,
            "decoys": self.U,  # U binary heads
            "p_tx": self.num_power_levels,
            "p_d": self.num_power_levels,
        }

    @property
    def obs_dim(self) -> int:
        # e_r, t_r, remaining_frac, r (U+1), v one-hot (U+1), l_M (E),
        # l_D (U+1), phase, n/2S
        return 3 + (self.U + 1) + (self.U + 1) + self.E + (self.U + 1) + 2

    # ---- dynamic physics ---------------------------------------------------
    def scenario(self) -> ScenarioParams:
        """Default dynamic-physics pytree matching the constructor flags."""
        return scenario_from_net(
            self.net,
            know_eave_locations=self.know_eave_locations,
            leak_scale=self.leak_scale,
        )

    def _params(self, params: Optional[ScenarioParams]) -> ScenarioParams:
        return self.scenario() if params is None else params

    # ---- split-plan oracle -------------------------------------------------
    def make_split_oracle(self):
        """Device-side oracle over EVERY split of this env's profile.

        Returns ``oracle(dev_pos, devices, p_tx, decoy_power, scenario=None,
        device_mask=None)`` scoring all ``(L-1 choose S-1)`` boundary plans
        (Eq. 10/11 static cost) in one jitted dispatch for a candidate device
        assignment ``devices`` (S,), per-hop trainer powers ``p_tx`` (S-1,)
        and decoy powers ``decoy_power`` (S-1, U+1). ``dev_pos`` is the
        (U+1, 2) position array from an :class:`EnvState`. The result dict
        carries the stacked ``boundaries`` plus per-plan ``delay``/``energy``
        and a ``feasible`` mask against the scenario budgets - the fast
        oracle for masking split-size actions that cannot meet Eq. 10/11,
        and the batched replacement for per-plan
        :func:`repro.core.splitting.plan_cost` loops. ``device_mask`` is an
        optional ``(U+1,)`` up/down mask (``core.faults.device_up``): plans
        whose assignment touches a down device are marked infeasible, which
        is how failure-aware re-planning routes around an outage. Scenario
        and mask values are runtime args - sweeps and fault injection reuse
        one trace (``oracle.trace_count``).
        """
        from repro.core.splitting import (make_plan_scorer, plan_devices_up,
                                          stack_boundaries)

        bounds = stack_boundaries(self.L, self.S)
        scorer = make_plan_scorer(self.profile)

        def oracle(dev_pos, devices, p_tx, decoy_power,
                   scenario: Optional[ScenarioParams] = None,
                   device_mask=None):
            sp = self._params(scenario)
            t, e = scorer(bounds, devices, dev_pos, p_tx, decoy_power, sp)
            feasible = (t <= sp.gamma_t) & (e <= sp.gamma_e)
            if device_mask is not None:
                feasible = feasible & plan_devices_up(devices, device_mask)
            return {
                "boundaries": bounds,
                "delay": t,
                "energy": e,
                "feasible": feasible,
            }

        oracle.trace_count = scorer.trace_count
        return oracle

    def _leakage(self) -> LeakageModel:
        return _DEFAULT_LEAKAGE if self.leakage_model is None else self.leakage_model

    # ---- constants as jnp --------------------------------------------------
    def _consts(self):
        # hoisted per-profile host tables (cached across envs sharing the
        # profile); the jnp.asarray casts reproduce the seed's f32 values
        # bit-exactly inside each trace. The per-layer information values
        # route through the LeakageModel: identity for AnalyticLeakage,
        # attacker-measured scores for EmpiricalLeakage.
        t = profile_table(self.profile)
        return (
            jnp.asarray(t.act_bits),
            jnp.asarray(t.grad_bits),
            jnp.asarray(self._leakage().layer_values(t.leak_norm)),
            jnp.asarray(t.fwd_cum),
            jnp.asarray(t.bwd_cum),
            jnp.asarray(t.state_cum),
        )

    # ---- reset ---------------------------------------------------------------
    def reset(self, key, params: Optional[ScenarioParams] = None) -> EnvState:
        sp = self._params(params)
        kp, _ = jax.random.split(key)
        dev, eav = sample_positions(kp, self.U, self.E, sp.area_m)
        server = jnp.full((1, 2), 0.5) * sp.area_m
        dev_pos = jnp.concatenate([dev, server], axis=0)
        return EnvState(
            dev_pos=dev_pos,
            eav_pos=eav,
            e_r=jnp.asarray(sp.gamma_e),
            t_r=jnp.asarray(sp.gamma_t),
            assigned=jnp.zeros(self.U + 1, jnp.int32),
            stage_dev=jnp.full((self.S,), -1, jnp.int32),
            boundaries=jnp.zeros((self.S,), jnp.int32),
            layers_used=jnp.zeros((), jnp.int32),
            n=jnp.ones((), jnp.int32),
            done=jnp.zeros((), bool),
            leaked=jnp.zeros(()),
        )

    # ---- observation -----------------------------------------------------------
    def observe(self, state: EnvState,
                params: Optional[ScenarioParams] = None) -> Array:
        sp = self._params(params)
        v_idx = self._current_tx(state)
        v_onehot = jax.nn.one_hot(v_idx, self.U + 1)
        v_pos = state.dev_pos[v_idx]
        l_m = jnp.linalg.norm(state.eav_pos - v_pos[None, :], axis=1) / sp.area_m
        # blinded (know_eave_locations=0) and padded (eave_mask=0)
        # eavesdroppers vanish from the observation
        l_m = l_m * sp.know_eave_locations * sp.eave_mask
        l_d = jnp.linalg.norm(state.dev_pos - v_pos[None, :], axis=1) / sp.area_m
        phase = (state.n > self.S).astype(jnp.float32)
        return jnp.concatenate(
            [
                jnp.stack(
                    [
                        state.e_r / sp.gamma_e,
                        state.t_r / sp.gamma_t,
                        1.0 - state.layers_used / self.L,
                    ]
                ),
                state.assigned.astype(jnp.float32) / self.S,
                v_onehot,
                l_m,
                l_d,
                jnp.stack([phase, state.n.astype(jnp.float32) / self.episode_len]),
            ]
        )

    def _current_tx(self, state: EnvState) -> Array:
        """Device transmitting at this step (for obs/leak geometry)."""
        n = state.n
        fwd_tx = state.stage_dev[jnp.clip(n - 2, 0, self.S - 1)]
        # backward step n transmits from stage s_{2S-n+1} (1-indexed, Eq. 20)
        bwd_tx = state.stage_dev[jnp.clip(2 * self.S - n, 0, self.S - 1)]
        idx = jnp.where(n <= self.S, fwd_tx, bwd_tx)
        return jnp.where(idx < 0, 0, idx).astype(jnp.int32)

    # ---- action masks ------------------------------------------------------
    def action_masks(self, state: EnvState) -> Dict[str, Array]:
        n = state.n
        assign_phase = n < self.S  # steps 1..S-1 pick devices
        u_mask = jnp.where(
            assign_phase, (state.assigned[: self.U] == 0), jnp.zeros(self.U, bool)
        )
        # always keep at least one valid entry for the categorical
        u_mask = jnp.where(u_mask.any(), u_mask, jnp.ones(self.U, bool).at[1:].set(False))
        size_mask = jnp.where(
            assign_phase, jnp.ones(NBINS, bool), jnp.zeros(NBINS, bool).at[0].set(True)
        )
        # decoys: any device not transmitting/receiving this hop
        tx = self._current_tx(state)
        rx = self._rx(state)
        dec_mask = jnp.ones(self.U, bool)
        dec_mask = dec_mask.at[jnp.clip(tx, 0, self.U - 1)].set(
            jnp.where(tx < self.U, False, dec_mask[jnp.clip(tx, 0, self.U - 1)])
        )
        dec_mask = dec_mask.at[jnp.clip(rx, 0, self.U - 1)].set(
            jnp.where(rx < self.U, False, dec_mask[jnp.clip(rx, 0, self.U - 1)])
        )
        dec_mask = jnp.where(n >= 2, dec_mask, jnp.zeros(self.U, bool))
        p_mask = jnp.ones(self.num_power_levels, bool)
        return {"u": u_mask, "size": size_mask, "decoys": dec_mask,
                "p_tx": p_mask, "p_d": p_mask}

    def _rx(self, state: EnvState) -> Array:
        n = state.n
        fwd_rx = state.stage_dev[jnp.clip(n - 1, 0, self.S - 1)]
        # backward step n delivers to stage s_{2S-n} (1-indexed, Eq. 20)
        bwd_rx = state.stage_dev[jnp.clip(2 * self.S - n - 1, 0, self.S - 1)]
        idx = jnp.where(n <= self.S, fwd_rx, bwd_rx)
        return jnp.where(idx < 0, self.U, idx).astype(jnp.int32)

    # ---- step ----------------------------------------------------------------
    def step(self, state: EnvState, action: Dict[str, Array], key,
             params: Optional[ScenarioParams] = None,
             ) -> Tuple[EnvState, Array, Array, Dict]:
        sp = self._params(params)
        act_bits, grad_bits, leak_v, fwd_cum, bwd_cum, state_cum = self._consts()
        powers = sp.power_levels
        n = state.n
        S, U, L = self.S, self.U, self.L

        # ---- 1) assignment phase bookkeeping (steps 1..S) --------------------
        is_assign = n < S  # agent picks a device for stages 1..S-1
        is_server_stage = n == S  # stage S goes to the server automatically
        stage_idx = jnp.clip(n - 1, 0, S - 1)

        # size mapping: keep >=1 layer for each later stage
        remaining = L - state.layers_used
        stages_after = S - n
        max_take = jnp.maximum(remaining - stages_after, 1)
        frac = (action["size"].astype(jnp.float32) + 1.0) / NBINS
        take = jnp.clip(jnp.ceil(frac * max_take).astype(jnp.int32), 1, max_take)
        take = jnp.where(is_server_stage, remaining, take)

        new_dev = jnp.where(
            is_assign, action["u"].astype(jnp.int32), jnp.where(is_server_stage, U, -1)
        )
        do_assign = is_assign | is_server_stage
        stage_dev = jnp.where(
            do_assign, state.stage_dev.at[stage_idx].set(new_dev), state.stage_dev
        )
        boundaries = jnp.where(
            do_assign,
            state.boundaries.at[stage_idx].set(state.layers_used + take),
            state.boundaries,
        )
        layers_used = jnp.where(do_assign, state.layers_used + take, state.layers_used)
        assigned = jnp.where(
            is_assign & (new_dev < U),
            state.assigned.at[jnp.clip(new_dev, 0, U)].set(n.astype(jnp.int32)),
            state.assigned,
        )

        # ---- 2) transmission (steps 2..2S-1) --------------------------------
        has_hop = n >= 2
        fwd_hop = has_hop & (n <= S)
        hop_fwd_idx = jnp.clip(n - 2, 0, S - 2)  # forward hop index (0-based)
        hop_bwd_idx = jnp.clip(2 * S - n - 1, 0, S - 2)  # backward hop index
        hop = jnp.where(fwd_hop, hop_fwd_idx, hop_bwd_idx)

        tx = jnp.where(fwd_hop, stage_dev[hop], stage_dev[hop + 1])
        rx = jnp.where(fwd_hop, stage_dev[hop + 1], stage_dev[hop])
        tx = jnp.where(tx < 0, 0, tx)
        rx = jnp.where(rx < 0, U, rx)
        boundary_layer = jnp.clip(boundaries[hop] - 1, 0, L - 1)
        bits = jnp.where(fwd_hop, act_bits[boundary_layer], grad_bits[boundary_layer])

        p_tx = powers[action["p_tx"]]
        p_d_level = powers[action["p_d"]]
        decoys = action["decoys"].astype(jnp.float32)
        # exclude tx/rx from decoys regardless of agent output
        decoys = decoys.at[jnp.clip(tx, 0, U - 1)].set(
            jnp.where(tx < U, 0.0, decoys[jnp.clip(tx, 0, U - 1)])
        )
        decoys = decoys.at[jnp.clip(rx, 0, U - 1)].set(
            jnp.where(rx < U, 0.0, decoys[jnp.clip(rx, 0, U - 1)])
        )
        decoy_p = jnp.concatenate([decoys * p_d_level, jnp.zeros((1,))])  # (U+1,)

        tx_pos = state.dev_pos[tx]
        rx_pos = state.dev_pos[rx]
        d_tx_rx = jnp.linalg.norm(tx_pos - rx_pos) + 1e-6
        d_dec_rx = jnp.linalg.norm(state.dev_pos - rx_pos[None, :], axis=1)
        rate = data_rate(p_tx, d_tx_rx, decoy_p, d_dec_rx, sp)
        t_hop = jnp.where(has_hop, tx_time(bits, rate), 0.0)

        # stage compute times (Eq. 20): on a forward hop the RECEIVING stage
        # (hop+1) runs its forward pass; on a backward hop the TRANSMITTING
        # stage is stage hop+1 (tx = stage_dev[hop+1] above) and it runs its
        # backward pass before sending the gradient - both directions charge
        # stage hop+1, only the fwd/bwd FLOP table differs.
        st = hop + 1
        lo = jnp.where(st == 0, 0, boundaries[jnp.clip(st - 1, 0, S - 1)])
        hi = boundaries[st]
        stage_fwd_flops = fwd_cum[hi] - fwd_cum[lo]
        stage_bwd_flops = bwd_cum[hi] - bwd_cum[lo]
        stage_flops = jnp.where(fwd_hop, stage_fwd_flops, stage_bwd_flops)
        # resident-state maintenance (KV / SSM state / MoE expert bank) is
        # charged once per direction, matching plan_cost's per-iteration 2x
        stage_state = state_cum[hi] - state_cum[lo]
        t_comp = jnp.where(
            fwd_hop,
            compute_time_fwd(stage_fwd_flops, sp, lam=sp.lambda_f),
            compute_time_bwd(stage_bwd_flops, sp, lam=sp.lambda_b),
        ) + state_time(stage_state, sp)
        t_comp = jnp.where(has_hop, t_comp, 0.0)
        # energy (Eq. 11) charges the same direction-dependent FLOPs the
        # delay model does: fwd table on forward hops, bwd table on backward
        e_comp = jnp.where(
            has_hop,
            compute_energy(stage_flops, sp) + state_energy(stage_state, sp),
            0.0)
        e_hop = (p_tx + decoy_p.sum()) * t_hop + e_comp

        # ---- 3) leakage (Eqs. 12-13, 20-21) ----------------------------------
        d_tx_e = jnp.linalg.norm(state.eav_pos - tx_pos[None, :], axis=1)
        decoy_dist_e = jnp.linalg.norm(
            state.dev_pos[:, None, :] - state.eav_pos[None, :, :], axis=-1
        )  # (U+1, E)
        # padded eavesdroppers (eave_mask=0) never monitor, so they leak
        # nothing and (with the per-eavesdropper key folding in
        # sample_leakage) leave the active ones' draws untouched
        q_e = sp.monitor_prob * sp.eave_mask
        delta = leak_v[boundary_layer] * sp.leak_scale
        leak = jnp.where(
            has_hop,
            self._leakage().sample_leakage(
                key, p_tx, d_tx_e, decoy_p, decoy_dist_e, q_e, delta, sp.rayleigh_o
            ),
            0.0,
        )

        # ---- 4) budgets + reward (Eq. 20) -------------------------------------
        e_r = state.e_r - e_hop
        t_r = state.t_r - t_hop - t_comp
        reward = (
            -leak
            - OMEGA_1 * (e_r <= 0).astype(jnp.float32)
            - OMEGA_2 * (t_r <= 0).astype(jnp.float32)
        )
        reward = jnp.where(has_hop, reward, 0.0)

        done = n >= self.episode_len
        new_state = EnvState(
            dev_pos=state.dev_pos,
            eav_pos=state.eav_pos,
            e_r=e_r,
            t_r=t_r,
            assigned=assigned,
            stage_dev=stage_dev,
            boundaries=boundaries,
            layers_used=layers_used,
            n=n + 1,
            done=done,
            leaked=state.leaked + leak,
        )
        info = {
            "leak": leak,
            "t_hop": t_hop,
            "e_hop": e_hop,
            "rate": rate,
            "tx": tx,
            "rx": rx,
            "decoy_p": decoy_p,
        }
        return new_state, reward, done, info
