"""TPU-native MHSL executor: a split plan runs as pipeline parallelism.

The paper's multi-hop split learning IS pipeline parallelism: sub-model k
on device s_k, activations hop s_k -> s_{k+1} (Eq. 1), gradients hop back
(Eq. 4). Here a ``SplitPlan`` executes on a TPU mesh 'stage' axis via
``shard_map`` with ``jax.lax.ppermute`` hops - ICI links play the role of
the wireless links.

Two schedules, selected by :class:`PipelineConfig`:

* ``fill_drain`` (the reference): a GPipe-style forward scan of
  ``M + S - 1`` ticks whose backward comes from ``jax.grad`` reversing
  the scan (all forwards, then all backwards). Every stage is padded to
  the longest stage with zero-initialized blocks - exact identities, so
  the function is preserved, but the padded blocks and the per-tick
  final-norm + LM-head + loss computed on EVERY stage all burn real
  compute.
* ``1f1b`` (the fast path, :func:`pipeline_step_fn`): an interleaved
  one-forward-one-backward schedule over ``M + 2(S-1)`` ticks. Each tick
  a stage runs the forward of one in-flight microbatch AND the manual
  VJP of another (warmup/drain slots are ``lax.cond``-ed out, so idle
  ticks skip their compute); activations/cotangents hop between ticks as
  donated scan carries via paired ``ppermute``s. Stage compute is masked
  to the stage's ACTIVE length (a per-stage ``lax.cond`` over the padded
  block scan), so uneven RL splits no longer pay the padded max-length
  matmuls - the Eq. 10 imbalance cost stays visible as bubble ticks, not
  as fake FLOPs. The LM head/loss runs only on the last stage's backward
  slot, and its param gradients accumulate in fp32 on-device, sharded by
  stage. Backward slots rematerialize their stage forward from a stashed
  stage input (depth ``2(S-1)+1`` ring), which is what bounds the stash
  at O(S) activations instead of GPipe's O(M).

Stage handoffs are DOUBLE-BUFFERED by default
(``PipelineConfig.transport="overlap"``): the scan carry holds the
wire-dtype SEND buffers produced by the previous tick, and both
``ppermute`` hops are issued at the top of the tick - before any of the
tick's block compute - so XLA's async collectives
(``collective-permute-start``/``-done``) can overlap each hop with the
slot that does not consume it (the forward hop hides behind the backward
VJP and vice versa). ``transport="sync"`` keeps the PR-5 barrier shape
(hops issued after the tick's compute, on its fresh outputs) as the
measured baseline; both transports consume every buffer on the same tick,
so they are numerically identical. Activations/cotangents are cast to
``PipelineConfig.wire_dtype`` before the hop (default: the compute
dtype), so the wire pays bf16 bytes even when stages accumulate in fp32 -
the paper's Eq. 1/4 transmissions priced per
``repro.core.transport``'s link model.

A 2-D (stage x env) mesh (``launch.mesh.make_stage_env_mesh``) composes
this pipeline with data parallelism: pass ``env_axis`` and the
microbatch-row dim of ``tokens``/``labels`` shards over ``env`` while
stage params replicate across it; loss and grads are ``pmean``-ed over
the env axis after the stage ``psum``.

Uneven splits (the RL agent's choice!) are supported by padding every
stage to the longest stage with zero-initialized blocks: residual blocks
with zeroed projections are exact identities, so the pipeline computes the
same function while exposing the real cost of imbalance - exactly the
trade-off the paper's Eq. 10 penalizes.

Mixed block types (the model-zoo case: Jamba's A/M hybrid period, MoE
every-k layers) run through the 1F1B schedule via a UNION param layout:
every layer row carries every field any signature in the layer-group
period uses (attn, mamba, mlp, moe), zero-filled where foreign, and a
STATIC per-slot block-kind schedule (one int8 code per layer, restacked
per stage like the params) drives a ``lax.switch`` inside the stage scan
- one branch per distinct signature, each reading only its own fields,
so the foreign zero rows get exact-zero gradients. Homogeneous
(period-1) architectures keep the original single-signature fast path
with no switch and no union padding; the fill-drain reference remains
period-1 only (mixed parity is pinned against the plain ``M.forward``
loss instead, see tests/test_pipeline_schedule.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import ModelConfig
from repro.launch.mesh import make_stage_mesh  # noqa: F401  (re-export)
from repro.models import model as M
from repro.models import layers as L


@dataclass(frozen=True)
class PipelineConfig:
    """Split-executor knobs.

    ``schedule``: ``"1f1b"`` (interleaved steady-state, masked uneven
    splits, manual per-stage VJP) or ``"fill_drain"`` (the GPipe-style
    reference whose backward is ``jax.grad`` of the forward scan).
    ``stage_impl``: ``"reference"`` applies blocks through
    ``models.layers``; ``"pallas"`` routes the residual MLP half-block
    through the fused Pallas stage kernel
    (``repro.kernels.stage_block``, interpret-mode on CPU).
    ``transport``: ``"overlap"`` (double-buffered handoff, hops issued at
    the top of the tick on the previous tick's send buffers) or ``"sync"``
    (hops issued after the tick's compute - the PR-5 barrier baseline).
    ``wire_dtype``: dtype activations/cotangents are cast to before each
    ``ppermute`` hop; ``None`` keeps them in ``compute_dtype`` (no cast,
    bit-identical to the seed executor).
    """

    schedule: str = "1f1b"
    stage_impl: str = "reference"
    # activation dtype in stage compute. bf16 is the production default;
    # the grad-parity tests pin both schedules at f32, where reassociation
    # noise drops below the 2e-5 gate.
    compute_dtype: str = "bfloat16"
    # activation/cotangent dtype ON THE WIRE (the ppermute payload).
    # None -> compute_dtype. Setting e.g. "bfloat16" under fp32 compute
    # halves Eq. 1/4 hop bytes at a quantization cost the parity tests
    # bound.
    wire_dtype: Optional[str] = None
    transport: str = "overlap"

    @property
    def dtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def wire(self):
        return jnp.dtype(self.wire_dtype or self.compute_dtype)

    @property
    def block_impl(self) -> str:
        assert self.stage_impl in ("reference", "pallas"), self.stage_impl
        return "pallas_stage" if self.stage_impl == "pallas" else "auto"

    def __post_init__(self):
        if self.transport not in ("overlap", "sync"):
            raise ValueError(
                f"transport must be 'overlap' or 'sync', got {self.transport!r}")


def _check_boundaries(boundaries: Sequence[int],
                      num_layers: Optional[int] = None) -> None:
    """Validate split-plan cut points before they reach the executor.

    ``boundaries`` are CUMULATIVE layer counts: strictly increasing,
    positive, and (when the layer count is known) ending exactly at
    ``num_layers``. A malformed plan would otherwise produce silently
    empty or overlapping stages deep inside ``shard_map``.
    """
    bl = list(boundaries)
    if not bl:
        raise ValueError("boundaries must be non-empty")
    lo = 0
    for k, b in enumerate(bl):
        if int(b) <= lo:
            raise ValueError(
                "boundaries must be strictly increasing positive cut points; "
                f"got {tuple(bl)} (entry {k} = {b} after {lo})")
        lo = int(b)
    if num_layers is not None and lo != num_layers:
        raise ValueError(
            f"last boundary must equal the layer count {num_layers}; "
            f"got {tuple(bl)}")


def stage_lengths(boundaries: Sequence[int]) -> Tuple[int, ...]:
    _check_boundaries(boundaries)
    out, lo = [], 0
    for b in boundaries:
        out.append(b - lo)
        lo = b
    return tuple(out)


def restack_for_stages(slot_params, boundaries: Sequence[int]):
    """(L, ...) stacked layer params -> (S, max_len, ...) with zero padding.

    Zero-padded blocks are exact identity functions of the residual stream
    (all projections zero => zero update).

    Implemented as ONE constant-index gather + mask rather than per-stage
    slice/concat/stack: under jit, GSPMD must repartition this op's output
    onto the pipeline mesh's stage axis, and XLA's SPMD partitioner
    miscompiles the concat-of-slices form on multi-axis (stage x env)
    meshes (wrong layer rows land on stages). A single gather with a
    host-constant index partitions correctly everywhere.
    """
    num_layers = int(jax.tree.leaves(slot_params)[0].shape[0])
    _check_boundaries(boundaries, num_layers=num_layers)
    s = len(boundaries)
    lens = stage_lengths(boundaries)
    max_len = max(lens)
    idx = np.zeros((s, max_len), np.int32)
    mask = np.zeros((s, max_len), bool)
    lo = 0
    for k, b in enumerate(boundaries):
        idx[k, : b - lo] = np.arange(lo, b)
        mask[k, : b - lo] = True
        lo = b
    idx_f = jnp.asarray(idx.reshape(-1))
    mask_f = jnp.asarray(mask.reshape(-1))

    def one(a):
        out = jnp.take(a, idx_f, axis=0)
        m = mask_f.reshape((s * max_len,) + (1,) * (a.ndim - 1))
        return jnp.where(m, out, 0).reshape((s, max_len) + a.shape[1:])

    return jax.tree.map(one, slot_params)


def unstack_stage_grads(stage_grads, boundaries: Sequence[int]):
    """(S, max_len, ...) per-stage grads -> (L, ...) layer layout.

    Inverse of :func:`restack_for_stages`; the zero-padding rows are
    dropped (their gradients are exact zeros - the padded blocks touch
    the residual stream through zeroed projections on both sides).
    Gather-based for the same SPMD-partitioner reason as
    :func:`restack_for_stages`.
    """
    lens = stage_lengths(boundaries)
    s, max_len = len(lens), max(lens)
    idx = jnp.asarray(
        np.concatenate([k * max_len + np.arange(n) for k, n in enumerate(lens)]),
        jnp.int32,
    )

    def one(a):
        flat = a.reshape((s * max_len,) + a.shape[2:])
        return jnp.take(flat, idx, axis=0)

    return jax.tree.map(one, stage_grads)


def unique_signatures(cfg: ModelConfig):
    """Distinct per-layer signatures + per-layer branch codes.

    Returns ``(sig, uniq, codes)``: the full per-layer signature tuple,
    the distinct signatures in first-appearance order (the ``lax.switch``
    branch order of the mixed-block executor), and an ``(L,)`` int32
    array mapping each layer to its branch index. All host constants -
    the block-type schedule is STATIC per split plan.
    """
    sig = M.signature(cfg)
    uniq = []
    for s in sig:
        if s not in uniq:
            uniq.append(s)
    codes = np.asarray([uniq.index(s) for s in sig], np.int32)
    return sig, tuple(uniq), codes


def _sig_field_keys(cfg: ModelConfig, slot_sig) -> Tuple[str, ...]:
    """Top-level param fields a signature's block reads (host constant)."""
    shapes = jax.eval_shape(
        lambda k: M.init_block(k, cfg, slot_sig, jnp.float32),
        jax.random.PRNGKey(0))
    return tuple(shapes.keys())


def union_layer_params(slots, num_layers: int):
    """Per-period slot stacks -> ONE (L, ...) stack in a UNION field layout.

    ``slots`` is ``params["slots"]``: a ``period``-tuple of trees whose
    leading dim is ``L / period`` (layer ``i`` lives in slot ``i % period``
    at row ``i // period``). The union row for a layer carries every
    top-level field any slot in the period uses; fields foreign to the
    layer's own signature are zero-filled and never read by its
    ``lax.switch`` branch (their gradients come back as exact zeros, see
    :func:`split_union_grads`). Field shapes agree across slots because
    every block of a config shares one ``ModelConfig``.
    """
    period = len(slots)
    fields = {}
    for slot in slots:
        for k, v in slot.items():
            fields.setdefault(k, jax.tree.map(
                lambda a: jnp.zeros((num_layers,) + a.shape[1:], a.dtype), v))
    out = {}
    for k, base in fields.items():
        for j, slot in enumerate(slots):
            if k in slot:
                # static-stride scatter: slot j owns layers j, j+p, j+2p, ...
                base = jax.tree.map(
                    lambda b, sv: b.at[j::period].set(sv), base, slot[k])
        out[k] = base
    return out


def split_union_grads(union_grads, slots):
    """(L, ...) union-layout grads -> the ``params["slots"]`` structure.

    Inverse of :func:`union_layer_params`: slot ``j`` takes the static
    strided rows ``[j::period]`` of exactly its own fields; the union's
    foreign-field rows (exact zeros - no switch branch reads them) are
    dropped.
    """
    period = len(slots)
    out = []
    for j, slot in enumerate(slots):
        out.append({
            k: jax.tree.map(lambda a: a[j::period], union_grads[k])
            for k in slot
        })
    return tuple(out)


def _stage_codes(layer_codes: np.ndarray, boundaries: Sequence[int]):
    """(L,) per-layer branch codes -> (S, max_len) per-stage schedule.

    Same layout as :func:`restack_for_stages`; padding slots get code 0
    but are masked by the stage's active length before dispatch.
    """
    lens = stage_lengths(boundaries)
    s, max_len = len(lens), max(lens)
    out = np.zeros((s, max_len), np.int32)
    lo = 0
    for k, b in enumerate(boundaries):
        out[k, : b - lo] = layer_codes[lo:b]
        lo = b
    return jnp.asarray(out)


def pipeline_loss_fn(cfg: ModelConfig, mesh: Mesh, boundaries: Sequence[int],
                     n_microbatches: int, stage_axis: str = "stage",
                     pipe: Optional[PipelineConfig] = None,
                     env_axis: Optional[str] = None):
    """Build the fill-drain (GPipe) pipelined LM loss - the REFERENCE path.

    (params, tokens, labels) -> scalar loss; backward comes from
    ``jax.grad`` reversing the scan. tokens: (M * mb, T). The schedule
    runs M + S - 1 ticks; each tick every stage applies its (padded)
    blocks and ppermutes the activation to the next stage. The 1F1B
    executor (:func:`pipeline_step_fn`) is gradient-compatible with this
    function at rtol <= 2e-5 and is what the benchmarks race against it.

    ``env_axis``: on a 2-D (stage x env) mesh, shard the microbatch ROW
    dim over this axis (data parallelism composed with the pipeline);
    the loss is ``pmean``-ed over it.
    """
    sig = M.signature(cfg)
    period = M.find_period(sig)
    assert period == 1, (
        f"fill-drain reference needs period-1 archs, got {period}; "
        "mixed block types run through the 1f1b schedule")
    slot_sig = sig[0]
    s_stages = len(boundaries)
    max_len = max(stage_lengths(boundaries))
    blk_impl = pipe.block_impl if pipe is not None else "auto"
    act_dtype = pipe.dtype if pipe is not None else jnp.bfloat16
    env_size = int(mesh.shape[env_axis]) if env_axis is not None else 1

    def fn(params, tokens, labels):
        stage_blocks = restack_for_stages(params["slots"][0], boundaries)
        m_total, t_len = tokens.shape
        mb = m_total // n_microbatches
        if mb % env_size:
            raise ValueError(
                f"microbatch size {mb} must divide over env axis ({env_size})")
        tok_mb = tokens.reshape(n_microbatches, mb, t_len)
        lab_mb = labels.reshape(n_microbatches, mb, t_len)

        def per_stage(stage_blocks, tok_mb, lab_mb, embed, final_norm, head):
            stage_blocks = jax.tree.map(lambda a: a[0], stage_blocks)  # drop S dim
            mb = tok_mb.shape[1]  # LOCAL rows (sharded over env_axis)
            sidx = jax.lax.axis_index(stage_axis)
            positions = jnp.arange(t_len)

            def apply_stage(x):
                for i in range(max_len):
                    blk = jax.tree.map(lambda a: a[i], stage_blocks)
                    x, _, _ = M.block_apply(
                        blk, x, cfg, slot_sig, positions=positions, cache=None,
                        cache_index=None, impl=blk_impl,
                    )
                return x

            # loss accumulators are (1,)-shaped, not scalars: they differ
            # across stages (only the last stage emits loss), and shard_map's
            # partial-eval cannot concatenate rank-0 residuals that vary over
            # the mesh - jax.grad through the pipeline needs the singleton
            # axis (see test_pipeline_matches_reference).
            def tick(carry, t):
                x, loss_acc, nloss = carry
                # stage 0 ingests microbatch t (if valid)
                mb_in_idx = jnp.clip(t, 0, n_microbatches - 1)
                fresh = embed[tok_mb[mb_in_idx]].astype(x.dtype)
                x = jnp.where((sidx == 0) & (t < n_microbatches), fresh, x)
                x = apply_stage(x)
                # last stage emits loss for microbatch t - (S-1)
                mb_out = t - (s_stages - 1)
                is_out = (sidx == s_stages - 1) & (mb_out >= 0)
                xh = L.rms_norm(x, final_norm, cfg.norm_eps)
                logits = jnp.einsum("bsd,dv->bsv", xh, head.astype(x.dtype))
                lab = lab_mb[jnp.clip(mb_out, 0, n_microbatches - 1)]
                li = M.softmax_xent(logits, lab)
                loss_acc = loss_acc + jnp.where(is_out, li, 0.0)[None]
                nloss = nloss + jnp.where(is_out, 1.0, 0.0)[None]
                # hop to the next stage (the multi-hop transmission, Eq. 1)
                perm = [(i, (i + 1) % s_stages) for i in range(s_stages)]
                x = jax.lax.ppermute(x, stage_axis, perm)
                return (x, loss_acc, nloss), None

            x0 = jnp.zeros((mb, t_len, cfg.d_model), act_dtype)
            ticks = n_microbatches + s_stages - 1
            (x, loss_acc, nloss), _ = jax.lax.scan(
                tick, (x0, jnp.zeros((1,)), jnp.zeros((1,))), jnp.arange(ticks)
            )
            # broadcast the last stage's mean loss to everyone
            total = jax.lax.psum(loss_acc, stage_axis)
            cnt = jax.lax.psum(nloss, stage_axis)
            loss = (total / jnp.maximum(cnt, 1.0))[0]
            if env_axis is not None:
                loss = jax.lax.pmean(loss, env_axis)
            return loss

        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        data_spec = P(None, env_axis) if env_axis is not None else P()
        loss = shard_map(
            per_stage,
            mesh=mesh,
            in_specs=(
                jax.tree.map(lambda _: P(stage_axis), stage_blocks),
                data_spec, data_spec, P(), P(), P(),
            ),
            out_specs=P(),
            check_rep=False,
        )(stage_blocks, tok_mb, lab_mb, params["embed"], params["final_norm"], head)
        return loss

    return fn


def pipeline_step_fn(cfg: ModelConfig, mesh: Mesh, boundaries: Sequence[int],
                     n_microbatches: int, stage_axis: str = "stage",
                     pipe: PipelineConfig = PipelineConfig(),
                     env_axis: Optional[str] = None):
    """Build the pipelined train step: (params, tokens, labels) -> (loss, grads).

    ``pipe.schedule == "1f1b"`` runs the interleaved schedule described in
    the module docstring; ``"fill_drain"`` wraps the reference loss in
    ``jax.value_and_grad`` (useful as the benchmark baseline and parity
    oracle). Gradients come back in the exact ``params`` pytree structure
    (zero for untouched leaves such as frontends).

    1F1B mechanics (S stages, M microbatches, T = M + 2(S-1) ticks,
    stash depth D = 2(S-1) + 1):

    * tick ``t``, stage ``i`` FORWARDS microbatch ``t - i`` (when in
      ``[0, M)``) and BACKWARDS microbatch ``t - 2(S-1) + i`` - the last
      stage runs its forward and backward of the same microbatch
      back-to-back in one tick, which is what shortens the schedule to
      ``M + 2(S-1)`` ticks against fill-drain's ``2(M + S - 1)``.
    * a stage's forward stashes only its INPUT activation; the backward
      slot re-runs the stage forward under ``jax.vjp`` (rematerialized
      backward), keeping the stash O(S) deep.
    * the forward slot is skipped on the last stage (its loss VJP
      recomputes it), so the final-norm + LM-head + loss run ONCE per
      microbatch instead of on every stage every tick.
    * per-stage block grads accumulate sharded (out_spec along the stage
      axis) and are re-laid-out to the (L, ...) slot layout host-side;
      embed/final-norm/head grads are psum'd across stages.
    * ``pipe.transport`` picks the handoff: ``"overlap"`` carries the
      wire-dtype send buffers through the scan and issues both
      ``ppermute``s at the TOP of the next tick (before its compute, so
      XLA can run them as async collectives under the opposite slot);
      ``"sync"`` hops at the end of the tick on its fresh outputs. Both
      consume each buffer exactly one tick after it is produced, so they
      compute the same function.
    * ``env_axis``: on a 2-D (stage x env) mesh, shard the microbatch ROW
      dim over this axis; loss and grads are ``pmean``-ed over it after
      the stage-axis reductions.
    """
    if pipe.schedule == "fill_drain":
        loss_fn = pipeline_loss_fn(cfg, mesh, boundaries, n_microbatches,
                                   stage_axis, pipe=pipe, env_axis=env_axis)

        def fd_step(params, tokens, labels):
            return jax.value_and_grad(loss_fn)(params, tokens, labels)

        return fd_step
    assert pipe.schedule == "1f1b", pipe.schedule

    sig, uniq_sigs, layer_codes = unique_signatures(cfg)
    period = M.find_period(sig)
    mixed = period > 1
    slot_sig = sig[0]
    uniq_keys = [_sig_field_keys(cfg, u) for u in uniq_sigs]
    s_stages = len(boundaries)
    lens = stage_lengths(boundaries)
    max_len = max(lens)
    m_micro = n_microbatches
    n_ticks = m_micro + 2 * (s_stages - 1)
    depth = 2 * (s_stages - 1) + 1  # activation-stash ring depth
    blk_impl = pipe.block_impl
    wdtype = pipe.wire
    overlap = pipe.transport == "overlap"
    env_size = int(mesh.shape[env_axis]) if env_axis is not None else 1

    def fn(params, tokens, labels):
        if mixed:
            layer_stack = union_layer_params(params["slots"], cfg.num_layers)
        else:
            layer_stack = params["slots"][0]
        stage_blocks = restack_for_stages(layer_stack, boundaries)
        codes_st = _stage_codes(layer_codes, boundaries)  # (S, max_len)
        lens_arr = jnp.asarray(lens, jnp.int32)
        m_total, t_len = tokens.shape
        mb = m_total // m_micro
        if mb % env_size:
            raise ValueError(
                f"microbatch size {mb} must divide over env axis ({env_size})")
        tok_mb = tokens.reshape(m_micro, mb, t_len)
        lab_mb = labels.reshape(m_micro, mb, t_len)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]

        def per_stage(stage_blocks, codes_st, lens_arr, tok_mb, lab_mb, embed,
                      final_norm, head):
            stage_blocks = jax.tree.map(lambda a: a[0], stage_blocks)
            codes = codes_st[0]  # (max_len,) this stage's block-kind schedule
            mb = tok_mb.shape[1]  # LOCAL rows (sharded over env_axis)
            active_len = lens_arr[0]
            sidx = jax.lax.axis_index(stage_axis)
            is_first = sidx == 0
            is_last = sidx == s_stages - 1
            positions = jnp.arange(t_len)

            if mixed:
                # one switch branch per distinct signature; each reads ONLY
                # its own fields of the union row, so the foreign zero-filled
                # fields transpose to exact-zero gradients (MoE router aux is
                # dropped, matching the homogeneous path)
                branches = []
                for u, keys in zip(uniq_sigs, uniq_keys):
                    def br(blk, xx, _u=u, _keys=keys):
                        sub = {k: blk[k] for k in _keys}
                        out, _, _ = M.block_apply(
                            sub, xx, cfg, _u, positions=positions,
                            cache=None, cache_index=None, impl=blk_impl,
                        )
                        return out
                    branches.append(br)

                def apply_block(blk, code, xx):
                    return jax.lax.switch(code, branches, blk, xx)
            else:
                def apply_block(blk, code, xx):
                    out, _, _ = M.block_apply(
                        blk, xx, cfg, slot_sig, positions=positions,
                        cache=None, cache_index=None, impl=blk_impl,
                    )
                    return out

            def stage_fwd(blocks, x):
                # scan over the padded block stack; the cond masks compute
                # down to the stage's ACTIVE length (padding blocks are
                # exact identities, so skipping them is value-preserving)
                def body(xc, blk_code_i):
                    blk, code, i = blk_code_i
                    xc = jax.lax.cond(
                        i < active_len,
                        lambda xx: apply_block(blk, code, xx),
                        lambda xx: xx, xc)
                    return xc, None

                out, _ = jax.lax.scan(
                    body, x, (blocks, codes, jnp.arange(max_len)))
                return out

            def stage_loss(blocks, fnorm, hd, x, lab):
                y = stage_fwd(blocks, x)
                xh = L.rms_norm(y, fnorm, cfg.norm_eps)
                logits = jnp.einsum("bsd,dv->bsv", xh, hd.astype(y.dtype))
                return M.softmax_xent(logits, lab)

            zero_blocks = jax.tree.map(jnp.zeros_like, stage_blocks)
            perm_f = [(i, (i + 1) % s_stages) for i in range(s_stages)]
            perm_b = [(i, (i - 1) % s_stages) for i in range(s_stages)]

            def tick(carry, t):
                buf_x, buf_g, stash, gblocks, gembed, gnorm, ghead, loss_acc = carry

                # ---- the hops (Eq. 1 forward, Eq. 4 gradient) -------------
                # overlap: the carry holds LAST tick's wire-dtype send
                # buffers; issuing both ppermutes here, before any of this
                # tick's block compute, lets XLA schedule them as async
                # collective-permute-start/done pairs that run under the
                # slot that does not consume them.
                if overlap:
                    x_in = jax.lax.ppermute(
                        buf_x, stage_axis, perm_f).astype(pipe.dtype)
                    g_in = jax.lax.ppermute(
                        buf_g, stage_axis, perm_b).astype(pipe.dtype)
                else:
                    x_in, g_in = buf_x, buf_g

                # ---- forward slot: microbatch t - i -----------------------
                mf = t - sidx
                f_valid = (mf >= 0) & (mf < m_micro)
                # the embedding gather is stage 0's alone - cond it out on
                # the other S-1 stages instead of masking it to zeros
                x0 = jax.lax.cond(
                    is_first,
                    lambda xx: embed[
                        tok_mb[jnp.clip(mf, 0, m_micro - 1)]
                    ].astype(xx.dtype),
                    lambda xx: xx,
                    x_in,
                )
                stash = jax.lax.cond(
                    f_valid,
                    lambda st: jax.lax.dynamic_update_index_in_dim(
                        st, x0, jnp.mod(mf, depth), 0
                    ),
                    lambda st: st,
                    stash,
                )
                # the last stage's forward happens inside its loss VJP, so
                # its forward slot only stashes
                y = jax.lax.cond(
                    f_valid & (~is_last),
                    lambda xx: stage_fwd(stage_blocks, xx),
                    lambda xx: xx,
                    x0,
                )

                # ---- backward slot: microbatch t - 2(S-1) + i -------------
                mbk = t - 2 * (s_stages - 1) + sidx
                b_valid = (mbk >= 0) & (mbk < m_micro)
                mb_c = jnp.clip(mbk, 0, m_micro - 1)
                x_saved = jax.lax.dynamic_index_in_dim(
                    stash, jnp.mod(mbk, depth), 0, keepdims=False
                )
                lab = lab_mb[mb_c]
                toksb = tok_mb[mb_c]

                def run_bwd(operand):
                    x_sv, g, lb = operand

                    def last_branch(_):
                        li, vjp = jax.vjp(
                            lambda bl, fn_, hd_, xx: stage_loss(bl, fn_, hd_, xx, lb),
                            stage_blocks, final_norm, head, x_sv,
                        )
                        dbl, dfn, dhd, dx = vjp(jnp.asarray(1.0 / m_micro, jnp.float32))
                        return li, dbl, dfn, dhd, dx

                    def mid_branch(_):
                        _, vjp = jax.vjp(
                            lambda bl, xx: stage_fwd(bl, xx), stage_blocks, x_sv
                        )
                        dbl, dx = vjp(g)
                        return (jnp.zeros((), jnp.float32), dbl,
                                jnp.zeros_like(final_norm), jnp.zeros_like(head),
                                dx)

                    return jax.lax.cond(is_last, last_branch, mid_branch, None)

                def skip_bwd(operand):
                    x_sv, g, _lb = operand
                    return (jnp.zeros((), jnp.float32), zero_blocks,
                            jnp.zeros_like(final_norm), jnp.zeros_like(head),
                            jnp.zeros_like(g))

                li, dbl, dfn, dhd, dx = jax.lax.cond(
                    b_valid, run_bwd, skip_bwd, (x_saved, g_in, lab)
                )
                gblocks = jax.tree.map(jnp.add, gblocks, dbl)
                gnorm = gnorm + dfn
                ghead = ghead + dhd
                loss_acc = loss_acc + li
                # stage 0's dx is the cotangent of the embedding lookup;
                # the full-vocab scatter-add is cond-gated like the other
                # idle slots (it would otherwise run masked-to-zero on
                # every stage every tick)
                gembed = jax.lax.cond(
                    b_valid & is_first,
                    lambda ge: ge.at[toksb].add(dx.astype(ge.dtype)),
                    lambda ge: ge,
                    gembed,
                )

                if overlap:
                    # stage outputs become NEXT tick's in-flight buffers
                    x_next = y.astype(wdtype)
                    g_next = dx.astype(wdtype)
                else:
                    # synchronous handoff: hop now, on this tick's outputs
                    x_next = jax.lax.ppermute(
                        y.astype(wdtype), stage_axis, perm_f).astype(pipe.dtype)
                    g_next = jax.lax.ppermute(
                        dx.astype(wdtype), stage_axis, perm_b).astype(pipe.dtype)
                return (x_next, g_next, stash, gblocks, gembed, gnorm, ghead,
                        loss_acc), None

            buf_dtype = wdtype if overlap else pipe.dtype
            x0 = jnp.zeros((mb, t_len, cfg.d_model), buf_dtype)
            g0 = jnp.zeros_like(x0)
            stash0 = jnp.zeros((depth, mb, t_len, cfg.d_model), pipe.dtype)
            carry0 = (
                x0, g0, stash0,
                jax.tree.map(jnp.zeros_like, stage_blocks),
                jnp.zeros_like(embed),
                jnp.zeros_like(final_norm),
                jnp.zeros_like(head),
                jnp.zeros((), jnp.float32),
            )
            (_, _, _, gblocks, gembed, gnorm, ghead, loss_acc), _ = jax.lax.scan(
                tick, carry0, jnp.arange(n_ticks)
            )
            loss = jax.lax.psum(loss_acc, stage_axis) / m_micro
            gembed = jax.lax.psum(gembed, stage_axis)
            gnorm = jax.lax.psum(gnorm, stage_axis)
            ghead = jax.lax.psum(ghead, stage_axis)
            if env_axis is not None:
                # data-parallel reduction: every env shard saw mb/env_size
                # rows of each microbatch, so the mean-of-means is the mean
                loss = jax.lax.pmean(loss, env_axis)
                gblocks = jax.lax.pmean(gblocks, env_axis)
                gembed = jax.lax.pmean(gembed, env_axis)
                gnorm = jax.lax.pmean(gnorm, env_axis)
                ghead = jax.lax.pmean(ghead, env_axis)
            return (loss, jax.tree.map(lambda a: a[None], gblocks), gembed,
                    gnorm, ghead)

        data_spec = P(None, env_axis) if env_axis is not None else P()
        loss, gstages, gembed, gnorm, ghead = shard_map(
            per_stage,
            mesh=mesh,
            in_specs=(
                jax.tree.map(lambda _: P(stage_axis), stage_blocks),
                P(stage_axis), P(stage_axis), data_spec, data_spec,
                P(), P(), P(),
            ),
            out_specs=(
                P(),
                jax.tree.map(lambda _: P(stage_axis), stage_blocks),
                P(), P(), P(),
            ),
            check_rep=False,
        )(stage_blocks, codes_st, lens_arr, tok_mb, lab_mb, params["embed"],
          params["final_norm"], head)

        grads = jax.tree.map(jnp.zeros_like, params)
        union_grads = unstack_stage_grads(gstages, boundaries)
        if mixed:
            grads["slots"] = split_union_grads(union_grads, params["slots"])
        else:
            grads["slots"] = (union_grads,)
        grads["final_norm"] = gnorm
        if cfg.tie_embeddings:
            grads["embed"] = gembed + ghead.T
        else:
            grads["embed"] = gembed
            grads["lm_head"] = ghead
        return loss, grads

    return fn


# ---------------------------------------------------------------------------
# serving: decode-mode stage pass (per-stage KV rings)
# ---------------------------------------------------------------------------


def stage_kv_caches(cfg: ModelConfig, boundaries: Sequence[int],
                    num_slots: int, cache_len: int, dtype=jnp.float32):
    """Per-stage KV rings for pipelined serving.

    Returns ``{"k", "v"}`` of shape ``(S, max_len, B, kv_len, KH, hd)`` -
    stage ``k``'s ring holds ONLY its own layers' KV entries (row ``i`` of
    stage ``k`` is global layer ``boundaries[k-1] + i``; padding rows
    belong to the zero-identity padding blocks and stay zero). Shard with
    ``P(stage_axis)`` on the leading dim - the cache never leaves its
    stage, exactly like the paper's sub-model state never leaves its
    device.
    """
    sig = M.signature(cfg)
    if any(kind != "A" for kind, _, _ in sig):
        raise ValueError("stage_kv_caches: attention-only archs")
    lens = stage_lengths(boundaries)
    s, max_len = len(lens), max(lens)
    kv_len = (min(cache_len, cfg.attention_window)
              if cfg.attention_window is not None else cache_len)
    shape = (s, max_len, num_slots, kv_len, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def pipeline_serve_fns(cfg: ModelConfig, mesh: Mesh, boundaries: Sequence[int],
                       stage_axis: str = "stage",
                       pipe: PipelineConfig = PipelineConfig(
                           compute_dtype="float32")):
    """Build the decode-mode stage passes for the serving engine.

    Returns ``(prefill, decode)`` with the engine's runner signatures:

    * ``prefill(params, caches, prompts)``: ``prompts`` (B, P) ->
      ``(logits (B, P, V), caches)`` - a fresh-sequence pass (scalar
      cache index 0) through all stages; the caller gathers the row it
      wants (per-slot prompt length) and WHERE-merges caches for the
      slots it actually admitted.
    * ``decode(params, tok, caches, pos)``: ``tok`` (B, 1), ``pos`` (B,)
      per-slot entry counts -> ``(logits (B, V), caches)`` - one token
      through the token ring.

    Both run the serial token ring: S ticks, tick ``t`` computes on stage
    ``t`` (``lax.cond`` on the stage index - padding blocks and foreign
    ticks skip their FLOPs) while the activation hop (``ppermute``, the
    Eq. 1 transmission) fires unconditionally every tick, cast to
    ``pipe.wire_dtype`` on the wire. Decode is SERIAL by construction:
    the sampled token feeds back into stage 0, so consecutive tokens
    cannot pipeline - the multi-hop latency the paper's Eq. 5-7 charges
    per inference. Logits replicate off the last stage via a masked
    ``psum`` (exact: the other stages contribute exact zeros).

    The hops stay OUTSIDE every ``cond`` so each stage executes the same
    collective sequence regardless of which slot is live - that is what
    keeps the engine step one compiled trace across arrivals/completions.
    """
    sig, uniq_sigs, layer_codes = unique_signatures(cfg)
    period = M.find_period(sig)
    mixed = period > 1
    slot_sig = sig[0]
    if any(kind != "A" for kind, _, _ in sig):
        raise ValueError(
            "pipeline serving: SSM/hybrid archs are unservable - padded "
            "batched prefill relies on causal masking, which protects KV "
            "attention but not recurrent scan state")
    if any(is_moe for _, is_moe, _ in sig) and cfg.moe.dispatch != "dropless":
        raise ValueError(
            "pipeline serving: capacity-dropping MoE is unservable (padded "
            "prefill rows steal expert capacity from real rows); set "
            "moe.dispatch='dropless'")
    uniq_keys = [_sig_field_keys(cfg, u) for u in uniq_sigs]
    s_stages = len(boundaries)
    lens = stage_lengths(boundaries)
    max_len = max(lens)
    blk_impl = pipe.block_impl
    wdtype = pipe.wire
    perm_f = [(i, (i + 1) % s_stages) for i in range(s_stages)]

    def _ring_pass(params, caches, x, positions, cache_index):
        """Token-ring forward: x (B, s, d) embedded input (live on stage 0).

        Returns (logits (B, s, V), caches). Runs under shard_map."""
        if mixed:
            layer_stack = union_layer_params(params["slots"], cfg.num_layers)
        else:
            layer_stack = params["slots"][0]
        stage_blocks = restack_for_stages(layer_stack, boundaries)
        codes_st = _stage_codes(layer_codes, boundaries)
        lens_arr = jnp.asarray(lens, jnp.int32)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]

        def per_stage(stage_blocks, codes_st, lens_arr, ck, cv, x, embed,
                      final_norm, head):
            stage_blocks = jax.tree.map(lambda a: a[0], stage_blocks)
            codes = codes_st[0]
            ck, cv = ck[0], cv[0]  # (max_len, B, kv, KH, hd)
            active_len = lens_arr[0]
            sidx = jax.lax.axis_index(stage_axis)

            if mixed:
                # all signatures are kind "A" here (gated above), so every
                # switch branch threads the same-shaped KV ring; dense vs
                # MoE MLP halves differ per branch
                branches = []
                for u, keys in zip(uniq_sigs, uniq_keys):
                    def br(blk, xi, ki, vi, _u=u, _keys=keys):
                        sub = {k: blk[k] for k in _keys}
                        out, nc, _ = M.block_apply(
                            sub, xi, cfg, _u, positions=positions,
                            cache={"k": ki, "v": vi},
                            cache_index=cache_index, impl=blk_impl,
                        )
                        return out, nc["k"], nc["v"]
                    branches.append(br)

                def apply_block(blk, code, xi, ki, vi):
                    return jax.lax.switch(code, branches, blk, xi, ki, vi)
            else:
                def apply_block(blk, code, xi, ki, vi):
                    out, nc, _ = M.block_apply(
                        blk, xi, cfg, slot_sig, positions=positions,
                        cache={"k": ki, "v": vi},
                        cache_index=cache_index, impl=blk_impl,
                    )
                    return out, nc["k"], nc["v"]

            def stage_apply(operand):
                xx, ck, cv = operand

                def body(carry, blk_cache_i):
                    xc, = carry
                    blk, k_i, v_i, code, i = blk_cache_i

                    def apply(op):
                        xi, ki, vi = op
                        return apply_block(blk, code, xi, ki, vi)

                    xc, k_i, v_i = jax.lax.cond(
                        i < active_len, apply, lambda op: op, (xc, k_i, v_i))
                    return (xc,), (k_i, v_i)

                (xx,), (nk, nv) = jax.lax.scan(
                    body, (xx,), (stage_blocks, ck, cv, codes,
                                  jnp.arange(max_len)))
                return xx, nk, nv

            for t in range(s_stages):
                if t > 0:
                    # the hop: Eq. 1 transmission, wire-dtype bytes
                    x = jax.lax.ppermute(
                        x.astype(wdtype), stage_axis, perm_f
                    ).astype(pipe.dtype)
                x, ck, cv = jax.lax.cond(
                    sidx == t, stage_apply, lambda op: op, (x, ck, cv))

            xh = L.rms_norm(x, final_norm, cfg.norm_eps)
            logits = jnp.einsum("bsd,dv->bsv", xh, head.astype(x.dtype))
            is_last = (sidx == s_stages - 1)
            logits = jax.lax.psum(
                jnp.where(is_last, logits.astype(jnp.float32), 0.0),
                stage_axis)
            return logits, ck[None], cv[None]

        logits, ck, cv = shard_map(
            per_stage,
            mesh=mesh,
            in_specs=(
                jax.tree.map(lambda _: P(stage_axis), stage_blocks),
                P(stage_axis), P(stage_axis), P(stage_axis), P(stage_axis),
                P(), P(), P(), P(),
            ),
            out_specs=(P(), P(stage_axis), P(stage_axis)),
            check_rep=False,
        )(stage_blocks, codes_st, lens_arr, caches["k"], caches["v"], x,
          params["embed"], params["final_norm"], head)
        return logits, {"k": ck, "v": cv}

    def prefill(params, caches, prompts):
        x = params["embed"].astype(pipe.dtype)[prompts]
        positions = jnp.arange(prompts.shape[1])
        return _ring_pass(params, caches, x, positions,
                          jnp.zeros((), jnp.int32))

    def decode(params, tok, caches, pos):
        x = params["embed"].astype(pipe.dtype)[tok]
        positions = pos[:, None]  # (B, 1) per-row
        logits, caches = _ring_pass(params, caches, x, positions, pos)
        return logits[:, -1], caches

    return prefill, decode
