"""TPU-native MHSL executor: a split plan runs as pipeline parallelism.

The paper's multi-hop split learning IS pipeline parallelism: sub-model k
on device s_k, activations hop s_k -> s_{k+1} (Eq. 1), gradients hop back
(Eq. 4). Here a ``SplitPlan`` executes on a TPU mesh 'stage' axis via
``shard_map`` with ``jax.lax.ppermute`` hops - ICI links play the role of
the wireless links, and JAX's ppermute transpose gives the backward hops
automatically under ``jax.grad``.

Uneven splits (the RL agent's choice!) are supported by padding every
stage to the longest stage with zero-initialized blocks: residual blocks
with zeroed projections are exact identities, so the pipeline computes the
same function while exposing the real cost of imbalance (bubble time) -
exactly the trade-off the paper's Eq. 10 penalizes.

Restriction: architectures with layer-group period 1 (all but Jamba, whose
period is 8; noted in DESIGN.md SArch-applicability).
"""
from __future__ import annotations

from functools import partial
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models import layers as L


def stage_lengths(boundaries: Sequence[int]) -> Tuple[int, ...]:
    out, lo = [], 0
    for b in boundaries:
        out.append(b - lo)
        lo = b
    return tuple(out)


def restack_for_stages(slot_params, boundaries: Sequence[int]):
    """(L, ...) stacked layer params -> (S, max_len, ...) with zero padding.

    Zero-padded blocks are exact identity functions of the residual stream
    (all projections zero => zero update).
    """
    s = len(boundaries)
    lens = stage_lengths(boundaries)
    max_len = max(lens)

    def one(a):
        parts = []
        lo = 0
        for k, b in enumerate(boundaries):
            seg = a[lo:b]
            pad = max_len - (b - lo)
            if pad:
                seg = jnp.concatenate(
                    [seg, jnp.zeros((pad,) + a.shape[1:], a.dtype)], axis=0
                )
            parts.append(seg)
            lo = b
        return jnp.stack(parts)  # (S, max_len, ...)

    return jax.tree.map(one, slot_params)


def pipeline_loss_fn(cfg: ModelConfig, mesh: Mesh, boundaries: Sequence[int],
                     n_microbatches: int, stage_axis: str = "stage"):
    """Build a pipelined LM loss: (params, tokens, labels) -> scalar loss.

    tokens: (M * mb, T). The GPipe-style schedule runs M + S - 1 ticks;
    each tick every stage applies its blocks and ppermutes the activation
    to the next stage.
    """
    sig = M.signature(cfg)
    period = M.find_period(sig)
    assert period == 1, f"pipeline executor needs period-1 archs, got {period}"
    slot_sig = sig[0]
    s_stages = len(boundaries)
    max_len = max(stage_lengths(boundaries))

    def fn(params, tokens, labels):
        stage_blocks = restack_for_stages(params["slots"][0], boundaries)
        m_total, t_len = tokens.shape
        mb = m_total // n_microbatches
        tok_mb = tokens.reshape(n_microbatches, mb, t_len)
        lab_mb = labels.reshape(n_microbatches, mb, t_len)

        def per_stage(stage_blocks, tok_mb, lab_mb, embed, final_norm, head):
            stage_blocks = jax.tree.map(lambda a: a[0], stage_blocks)  # drop S dim
            sidx = jax.lax.axis_index(stage_axis)
            positions = jnp.arange(t_len)

            def apply_stage(x):
                for i in range(max_len):
                    blk = jax.tree.map(lambda a: a[i], stage_blocks)
                    x, _, _ = M.block_apply(
                        blk, x, cfg, slot_sig, positions=positions, cache=None,
                        cache_index=None, impl="auto",
                    )
                return x

            # loss accumulators are (1,)-shaped, not scalars: they differ
            # across stages (only the last stage emits loss), and shard_map's
            # partial-eval cannot concatenate rank-0 residuals that vary over
            # the mesh - jax.grad through the pipeline needs the singleton
            # axis (see test_pipeline_matches_reference).
            def tick(carry, t):
                x, loss_acc, nloss = carry
                # stage 0 ingests microbatch t (if valid)
                mb_in_idx = jnp.clip(t, 0, n_microbatches - 1)
                fresh = embed[tok_mb[mb_in_idx]].astype(x.dtype)
                x = jnp.where((sidx == 0) & (t < n_microbatches), fresh, x)
                x = apply_stage(x)
                # last stage emits loss for microbatch t - (S-1)
                mb_out = t - (s_stages - 1)
                is_out = (sidx == s_stages - 1) & (mb_out >= 0)
                xh = L.rms_norm(x, final_norm, cfg.norm_eps)
                logits = jnp.einsum("bsd,dv->bsv", xh, head.astype(x.dtype))
                lab = lab_mb[jnp.clip(mb_out, 0, n_microbatches - 1)]
                li = M.softmax_xent(logits, lab)
                loss_acc = loss_acc + jnp.where(is_out, li, 0.0)[None]
                nloss = nloss + jnp.where(is_out, 1.0, 0.0)[None]
                # hop to the next stage (the multi-hop transmission, Eq. 1)
                perm = [(i, (i + 1) % s_stages) for i in range(s_stages)]
                x = jax.lax.ppermute(x, stage_axis, perm)
                return (x, loss_acc, nloss), None

            x0 = jnp.zeros((mb, t_len, cfg.d_model), jnp.bfloat16)
            ticks = n_microbatches + s_stages - 1
            (x, loss_acc, nloss), _ = jax.lax.scan(
                tick, (x0, jnp.zeros((1,)), jnp.zeros((1,))), jnp.arange(ticks)
            )
            # broadcast the last stage's mean loss to everyone
            total = jax.lax.psum(loss_acc, stage_axis)
            cnt = jax.lax.psum(nloss, stage_axis)
            return (total / jnp.maximum(cnt, 1.0))[0]

        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        loss = shard_map(
            per_stage,
            mesh=mesh,
            in_specs=(
                jax.tree.map(lambda _: P(stage_axis), stage_blocks),
                P(), P(), P(), P(), P(),
            ),
            out_specs=P(),
            check_rep=False,
        )(stage_blocks, tok_mb, lab_mb, params["embed"], params["final_norm"], head)
        return loss

    return fn


def make_stage_mesh(n_stages: int, stage_axis: str = "stage") -> Mesh:
    devs = jax.devices()[:n_stages]
    assert len(devs) >= n_stages, f"need {n_stages} devices, have {len(jax.devices())}"
    return Mesh(np.array(devs), (stage_axis,))
