"""Deterministic fault injection for the unreliable wireless edge.

The paper's whole setting is devices that come and go: eavesdroppers
monitor, links degrade, and the agent must keep choosing feasible
device/split assignments anyway.  This module makes that an explicit,
REPLAYABLE input instead of an accident of the host environment:

* :class:`FaultSchedule` - a pytree of jnp leaves describing per-device
  outage windows, per-hop link drop/slowdown multipliers, and per-device
  straggler factors.  Like :class:`repro.core.scenario.ScenarioParams`
  it is a *runtime argument*: injecting, moving, or clearing faults
  never retraces a compiled function (pinned by
  ``tests/test_faults.py``).
* :class:`FaultClock` - the single mapping from executor ticks / the
  serving service's virtual time onto the schedule's time axis, so the
  1F1B transport simulator and the serving loop read the SAME outage
  windows.
* :func:`degrade_scenario` - folds the schedule's link degradation into
  a ``ScenarioParams``, which is how the Eq. 10/11 plan oracle, the
  transport tick model, and the online re-planner all price partial
  outage from one source of truth.

Schedules are either hand-built (:func:`fault_free`,
:func:`reference_schedule`) or sampled from a PRNG key
(:func:`sample_fault_schedule`) - seeded, so a chaos run is replayable
bit-for-bit.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

_INF = float("inf")


class FaultSchedule(NamedTuple):
    """Dynamic fault state of one deployment (all leaves jnp arrays).

    ``D`` devices (the env's ``U`` trainers plus the server as row
    ``U``), ``W`` outage windows per device, ``H`` inter-stage hops
    (``max_split - 1``, matching ``ScenarioParams.hop_bandwidth_hz``).
    Unused outage windows are ``[inf, inf)`` and match no time.
    """

    outage_start: Array  # (D, W) seconds; inf = unused window
    outage_end: Array    # (D, W) seconds (half-open [start, end))
    hop_bandwidth_scale: Array  # (H,) multiplier in (0, 1] on link bandwidth
    hop_latency_add_s: Array    # (H,) added fixed per-hop latency (s)
    compute_slowdown: Array     # (D,) straggler multiplier >= 1 on compute

    @property
    def num_devices(self) -> int:
        return self.outage_start.shape[-2]

    @property
    def num_windows(self) -> int:
        return self.outage_start.shape[-1]

    @property
    def num_hops(self) -> int:
        return self.hop_bandwidth_scale.shape[-1]


def fault_free(num_devices: int, num_hops: int,
               num_windows: int = 1) -> FaultSchedule:
    """The no-op schedule: no outages, unit link scale, no stragglers.

    Every query under it reproduces the fault-free numbers bit-exactly
    (``degrade_scenario`` with unit scale / zero add multiplies by 1.0
    and adds 0.0 in f32 - an exact no-op on finite values).
    """
    return FaultSchedule(
        outage_start=jnp.full((num_devices, num_windows), _INF, jnp.float32),
        outage_end=jnp.full((num_devices, num_windows), _INF, jnp.float32),
        hop_bandwidth_scale=jnp.ones((num_hops,), jnp.float32),
        hop_latency_add_s=jnp.zeros((num_hops,), jnp.float32),
        compute_slowdown=jnp.ones((num_devices,), jnp.float32),
    )


def make_schedule(
    num_devices: int,
    num_hops: int,
    *,
    outages: Sequence[Tuple[int, float, float]] = (),
    hop_bandwidth_scale: Optional[Sequence[float]] = None,
    hop_latency_add_s: Optional[Sequence[float]] = None,
    compute_slowdown: Optional[Sequence[float]] = None,
    num_windows: Optional[int] = None,
) -> FaultSchedule:
    """Hand-built schedule: ``outages`` is a list of
    ``(device, start_s, end_s)`` windows; the degradation vectors default
    to the fault-free values."""
    per_dev: dict = {}
    for dev, t0, t1 in outages:
        if not 0 <= dev < num_devices:
            raise ValueError(f"outage device {dev} not in [0, {num_devices})")
        if not t1 > t0:
            raise ValueError(f"outage window [{t0}, {t1}) is empty")
        per_dev.setdefault(int(dev), []).append((float(t0), float(t1)))
    w = max([len(v) for v in per_dev.values()] + [1])
    if num_windows is not None:
        if num_windows < w:
            raise ValueError(
                f"num_windows={num_windows} < {w} windows on one device")
        w = num_windows
    start = np.full((num_devices, w), _INF, np.float32)
    end = np.full((num_devices, w), _INF, np.float32)
    for dev, wins in per_dev.items():
        for i, (t0, t1) in enumerate(sorted(wins)):
            start[dev, i] = t0
            end[dev, i] = t1
    base = fault_free(num_devices, num_hops, w)
    return base._replace(
        outage_start=jnp.asarray(start),
        outage_end=jnp.asarray(end),
        hop_bandwidth_scale=(
            base.hop_bandwidth_scale if hop_bandwidth_scale is None
            else jnp.asarray(hop_bandwidth_scale, jnp.float32)),
        hop_latency_add_s=(
            base.hop_latency_add_s if hop_latency_add_s is None
            else jnp.asarray(hop_latency_add_s, jnp.float32)),
        compute_slowdown=(
            base.compute_slowdown if compute_slowdown is None
            else jnp.asarray(compute_slowdown, jnp.float32)),
    )


def sample_fault_schedule(
    key,
    num_devices: int,
    num_hops: int,
    *,
    horizon_s: float,
    num_windows: int = 1,
    outage_prob: float = 0.3,
    outage_len_s: Tuple[float, float] = (0.05, 0.5),
    bandwidth_scale: Tuple[float, float] = (0.5, 1.0),
    latency_add_s: Tuple[float, float] = (0.0, 0.0),
    slowdown: Tuple[float, float] = (1.0, 1.0),
) -> FaultSchedule:
    """Seeded random schedule: each (device, window) slot is an outage
    with probability ``outage_prob``, starting uniformly in the horizon
    with a uniform length; hop/straggler degradations draw uniformly
    from their ranges.  Same key -> bit-identical schedule (the replay
    contract chaos runs lean on)."""
    k_on, k_t0, k_len, k_bw, k_lat, k_slow = jax.random.split(key, 6)
    shape = (num_devices, num_windows)
    on = jax.random.bernoulli(k_on, outage_prob, shape)
    t0 = jax.random.uniform(k_t0, shape, minval=0.0, maxval=horizon_s)
    ln = jax.random.uniform(k_len, shape, minval=outage_len_s[0],
                            maxval=outage_len_s[1])
    start = jnp.where(on, t0, _INF).astype(jnp.float32)
    end = jnp.where(on, t0 + ln, _INF).astype(jnp.float32)
    return FaultSchedule(
        outage_start=start,
        outage_end=end,
        hop_bandwidth_scale=jax.random.uniform(
            k_bw, (num_hops,), minval=bandwidth_scale[0],
            maxval=bandwidth_scale[1]).astype(jnp.float32),
        hop_latency_add_s=jax.random.uniform(
            k_lat, (num_hops,), minval=latency_add_s[0],
            maxval=latency_add_s[1]).astype(jnp.float32),
        compute_slowdown=jax.random.uniform(
            k_slow, (num_devices,), minval=slowdown[0],
            maxval=slowdown[1]).astype(jnp.float32),
    )


def reference_schedule(num_devices: int, num_hops: int, *,
                       tick_seconds: float = 0.02) -> FaultSchedule:
    """The fixed reference schedule used by the chaos benchmarks / CI
    gate: device 0 drops out for ticks [4, 9) of the serving fault
    clock, every hop runs at 80% bandwidth.  Deterministic by
    construction (no PRNG)."""
    return make_schedule(
        num_devices, num_hops,
        outages=[(0, 4 * tick_seconds, 9 * tick_seconds)],
        hop_bandwidth_scale=[0.8] * num_hops,
    )


# ---------------------------------------------------------------------------
# queries (jnp-pure: safe inside jit, cheap outside)
# ---------------------------------------------------------------------------


def device_up(schedule: FaultSchedule, t) -> Array:
    """(D,) bool mask: device is OUTSIDE every outage window at time t."""
    t = jnp.asarray(t, jnp.float32)
    down = ((t >= schedule.outage_start)
            & (t < schedule.outage_end)).any(axis=-1)
    return ~down


def next_recovery(schedule: FaultSchedule, t, devices=None) -> Array:
    """Earliest time >= t at which every (selected) device is up.

    ``devices`` selects rows (default: all).  Returns ``t`` itself when
    nothing is down.  Host-side recovery-wait logic uses this to jump
    the virtual clock deterministically to the end of an outage instead
    of spinning."""
    start, end = schedule.outage_start, schedule.outage_end
    if devices is not None:
        idx = jnp.asarray(devices, jnp.int32)
        start, end = start[idx], end[idx]
    t = jnp.asarray(t, jnp.float32)
    covering = (t >= start) & (t < end)
    return jnp.maximum(t, jnp.where(covering, end, -_INF).max())


def outage_stall(schedule: FaultSchedule, t, devices) -> Array:
    """Seconds a step starting at ``t`` on ``devices`` stalls before all
    of them are back up (0.0 when none is down)."""
    return next_recovery(schedule, t, devices) - jnp.asarray(t, jnp.float32)


def degrade_scenario(sp, schedule: FaultSchedule):
    """Fold the schedule's LINK degradation into a ``ScenarioParams``.

    Hop ``k`` runs at ``hop_bandwidth_hz[k] * hop_bandwidth_scale[k]``
    and pays ``hop_latency_s[k] + hop_latency_add_s[k]`` - the same
    per-hop link model Eq. 10/11 already price, so ``plan_cost``,
    ``score_plans``, the split oracle, and the transport tick model all
    see one consistent degraded physics.  A ``fault_free`` schedule is a
    bit-exact no-op.  Pure pytree arithmetic: scoring under degraded
    scenarios reuses the fault-free compiled traces.
    """
    from repro.core.scenario import scale_param, shift_param

    h = sp.hop_bandwidth_hz.shape[-1]
    if schedule.num_hops != h:
        raise ValueError(
            f"schedule has {schedule.num_hops} hops, scenario has {h}")
    sp = scale_param(sp, "hop_bandwidth_hz", schedule.hop_bandwidth_scale)
    return shift_param(sp, "hop_latency_s", schedule.hop_latency_add_s)


# ---------------------------------------------------------------------------
# the tick <-> schedule-time mapping
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FaultClock:
    """Maps executor ticks / serving virtual time onto the schedule.

    ``tick_seconds > 0``: schedule time is ``tick * tick_seconds`` -
    fully deterministic, independent of host wall-clock (what the chaos
    tests and the reference benchmark schedule use).  ``tick_seconds ==
    0``: schedule time is the caller-supplied virtual ``now`` (the
    serving loop's arrival clock), for wall-coupled injection.
    """

    tick_seconds: float = 0.0

    def time_of(self, tick: int, now: float = 0.0) -> float:
        if self.tick_seconds > 0:
            return tick * self.tick_seconds
        return now

    def ticks_until(self, t_now: float, t_target: float) -> int:
        """Whole ticks from ``t_now`` until ``t_target`` has passed
        (minimum 1; only meaningful for tick-driven clocks)."""
        if self.tick_seconds <= 0:
            return 1
        import math

        return max(int(math.ceil((t_target - t_now) / self.tick_seconds)), 1)


__all__ = [
    "FaultClock",
    "FaultSchedule",
    "degrade_scenario",
    "device_up",
    "fault_free",
    "make_schedule",
    "next_recovery",
    "outage_stall",
    "reference_schedule",
    "sample_fault_schedule",
]
