"""Wireless channel model (paper §II-C, Eq. 5) + Table-I constants.

TDMA links with Rayleigh fading; deceptive-signal devices appear as
interference in the SINR of eavesdropped/legitimate links. All functions
are jnp-pure and jittable so the RL environment can lax.scan over them.

The ``net`` argument of every physics function is duck-typed: it accepts
either the static ``NetworkConfig`` (host floats, baked into the jit as
constants - the legacy path) or a ``repro.core.scenario.ScenarioParams``
pytree (traced jnp scalars - the sweep path, where one compiled function
serves every parameter point). Both expose the same attribute names
(``bandwidth_hz``, ``noise_w``, ``rayleigh_o``, ``f_cpu_hz``,
``theta_chip``).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclass(frozen=True)
class NetworkConfig:
    """Paper Table I defaults.

    Structure-defining fields (``num_devices``, ``num_eaves``,
    ``max_split``, ``len(power_levels)``) fix array SHAPES and stay
    static on ``MHSLEnv``; every other field is a physics VALUE whose
    runtime representation is ``repro.core.scenario.ScenarioParams``
    (built from this config via ``scenario_from_net``). Sweeping a value
    field through ``ScenarioParams`` never recompiles.
    """

    num_devices: int = 6  # U
    num_eaves: int = 2  # E
    area_m: float = 800.0  # 800 x 800 m^2
    bandwidth_hz: float = 1e6  # B = 1 MHz
    # per-hop link overrides (heterogeneous wireless links). Empty tuple =
    # every one of the ``max_split - 1`` inter-stage hops runs at
    # ``bandwidth_hz``; a tuple of length ``max_split - 1`` gives each hop
    # its own TDMA bandwidth. ``hop_latency_s`` is a fixed per-hop link
    # latency (propagation + MAC handshake) added to every transmission in
    # both directions; a scalar applies to all hops.
    hop_bandwidth: tuple = ()
    hop_latency: float = 0.0
    noise_dbm_hz: float = -90.0  # N0
    rayleigh_o: float = 1.0  # o
    monitor_prob: float = 0.8  # q_e
    gamma_t: float = 8.0  # per-iteration delay budget (s)
    gamma_e: float = 75.0  # per-iteration energy budget (J)
    f_cpu_hz: float = 5.5e9  # f^B, 4-7 GHz
    omega_cycles_per_bit: float = 1e5  # omega^B, 1e4-1e6
    lambda_f: float = 1.5e9  # lambda_f FLOPs-scale coefficient (Table I)
    lambda_b: float = 1.5e9  # lambda_b
    theta_chip: float = 1e-28  # vartheta_k energy coefficient
    # architecture-aware state pricing: maintenance cycles per RESIDENT
    # state bit per iteration (attention KV, SSM scan state, MoE expert
    # weights - ProfileTable.state_bits). Folded into the Eq. 8-9 compute
    # terms of plan_cost/score_plans, so cut points price differently
    # across block types. 0.0 (default) reproduces the homogeneous
    # residual-MLP pricing exactly.
    state_cycles_per_bit: float = 0.0
    power_levels: tuple = (0.1, 0.2, 0.5, 1.0)  # discrete transmit powers (W)
    max_split: int = 4  # S (number of sub-models incl. server)

    @property
    def noise_w(self) -> float:
        # N0 * B in watts
        return 10 ** (self.noise_dbm_hz / 10) * 1e-3 * self.bandwidth_hz

    @property
    def hop_bandwidth_hz(self) -> np.ndarray:
        """Per-hop bandwidths, shape ``(max_split - 1,)`` (duck-typed with
        ``ScenarioParams.hop_bandwidth_hz``)."""
        h = self.max_split - 1
        if self.hop_bandwidth:
            if len(self.hop_bandwidth) != h:
                raise ValueError(
                    f"hop_bandwidth needs {h} entries (max_split - 1), "
                    f"got {len(self.hop_bandwidth)}")
            return np.asarray(self.hop_bandwidth, np.float64)
        return np.full(h, self.bandwidth_hz, np.float64)

    @property
    def hop_latency_s(self) -> np.ndarray:
        """Per-hop fixed link latencies, shape ``(max_split - 1,)``."""
        return np.full(self.max_split - 1, self.hop_latency, np.float64)


def channel_gain(dist: Array, o: float = 1.0) -> Array:
    """h = o * m^-2 (paper's distance-squared path loss)."""
    return o / jnp.maximum(dist, 1.0) ** 2


def data_rate(
    p_tx: Array,
    dist_tx_rx: Array,
    interferer_p: Array,
    interferer_dist_rx: Array,
    net: NetworkConfig,
    bandwidth_hz: Array | None = None,
) -> Array:
    """Eq. 5: TDMA SINR rate with deceptive-signal interference.

    interferer_p: (D,) powers of deceptive devices (0 for inactive).
    interferer_dist_rx: (D,) distances from deceptive devices to receiver.
    bandwidth_hz: optional per-link bandwidth override (heterogeneous hops);
    the thermal noise floor N0*B scales with it. ``None`` keeps the
    config-wide ``net.bandwidth_hz``/``net.noise_w`` with no extra float
    ops, so legacy callers stay bit-identical.
    """
    sig = p_tx * channel_gain(dist_tx_rx, net.rayleigh_o)
    interf = jnp.sum(interferer_p * channel_gain(interferer_dist_rx, net.rayleigh_o))
    if bandwidth_hz is None:
        bw, noise = net.bandwidth_hz, net.noise_w
    else:
        bw = bandwidth_hz
        noise = net.noise_w * (bw / net.bandwidth_hz)
    sinr = sig / (interf + noise)
    return bw * jnp.log2(1.0 + sinr)


def tx_time(bits: Array, rate: Array) -> Array:
    """Eqs. 6-7: transmission delay of `bits` at `rate`."""
    return bits / jnp.maximum(rate, 1.0)


IPC = 8.0  # FLOPs retired per cycle on the edge-device CPU model


def compute_time_fwd(fwd_flops: Array, net: NetworkConfig, lam: float = 1.0) -> Array:
    """Eq. 8 re-expressed: T^F = lambda_f * FLOPs(theta_k, z) / (f * IPC).

    NOTE (faithfulness ledger): the paper's literal Eq. 8 multiplies
    activation bits by parameter bits under a cycles/bit coefficient, which
    is dimensionally ambiguous (units: s * bits). We keep the paper's
    structure - compute time scales with stage complexity over CPU clock -
    but measure complexity in FLOPs from the layer profile. lambda stays a
    per-model complexity multiplier as in Table I.
    """
    return lam * fwd_flops / (net.f_cpu_hz * IPC)


def compute_time_bwd(bwd_flops: Array, net: NetworkConfig, lam: float = 1.0) -> Array:
    """Eq. 9, same structure with lambda_b."""
    return lam * bwd_flops / (net.f_cpu_hz * IPC)


def compute_energy(flops: Array, net: NetworkConfig) -> Array:
    """First term of Eq. 11: vartheta * f^2 * cycles (cycles = FLOPs/IPC)."""
    return net.theta_chip * net.f_cpu_hz**2 * (flops / IPC)


def state_time(state_bits: Array, net: NetworkConfig) -> Array:
    """Per-DIRECTION cost of a stage's resident state (KV cache, SSM scan
    state, MoE expert weights): ``state_cycles_per_bit`` maintenance
    cycles per bit over the CPU clock. Plan costs add it to BOTH the
    Eq. 8 forward and Eq. 9 backward stage times (state is touched each
    direction)."""
    return net.state_cycles_per_bit * state_bits / net.f_cpu_hz


def state_energy(state_bits: Array, net: NetworkConfig) -> Array:
    """Eq. 11 energy of one direction's state-maintenance cycles
    (matching :func:`state_time`; plan costs charge it twice per
    iteration)."""
    return net.theta_chip * net.f_cpu_hz**2 * (
        net.state_cycles_per_bit * state_bits)


def sample_positions(key, num_devices: int, num_eaves: int, area_m):
    """Device + eavesdropper positions uniform in the area. ``area_m`` may
    be a traced scalar (``ScenarioParams.area_m``); the counts are static
    shapes."""
    k1, k2 = jax.random.split(key)
    dev = jax.random.uniform(k1, (num_devices, 2)) * area_m
    eav = jax.random.uniform(k2, (num_eaves, 2)) * area_m
    return dev, eav


def pairwise_dist(a: Array, b: Array) -> Array:
    """a: (N,2), b: (M,2) -> (N,M)."""
    return jnp.sqrt(jnp.sum((a[:, None, :] - b[None, :, :]) ** 2, axis=-1) + 1e-9)
