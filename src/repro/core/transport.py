"""Structural transport accounting for the split executor.

The 1F1B executor (``repro.core.pipeline``) moves activations between
stages with per-tick ``ppermute`` hops; this module prices those hops
from the SAME physics as the Eq. 10/11 plan oracle
(``splitting.plan_cost_parts``): per-stage compute times from Eqs. 8-9
and per-hop transmission times from Eqs. 5-7 at each hop's link
bandwidth + fixed link latency (``ScenarioParams.hop_bandwidth_hz`` /
``hop_latency_s``).

Two transports of the 1F1B schedule are modelled (matching
``PipelineConfig.transport``):

* ``"sync"`` - every tick pays its compute, THEN its hops: the stage
  stalls on the neighbour's send before the next block runs
  (tick = compute + transport).
* ``"overlap"`` - double-buffered handoff: the hop carrying microbatch
  m+1's activation is issued before microbatch m's block compute, so a
  tick pays ``max(compute, transport)`` (transport is the in-flight
  buffer from the PREVIOUS tick).

Agreement contract (pinned by ``tests/test_transport.py``): at M=1 the
synchronous 1F1B wall-time model equals ``plan_cost``'s Eq. 10 delay -
same per-stage / per-hop terms, and with one microbatch there is nothing
to overlap, so the executor's structural tick accounting and the plan
oracle are the same number.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.profiles import LayerProfile
from repro.core.splitting import SplitPlan, plan_cost_parts


@dataclass(frozen=True)
class TransportModel:
    """Per-stage / per-hop cost terms of one plan under one link model.

    Compute and transmission terms are per ITERATION (the whole batch, as
    in ``plan_cost``); the schedule simulators divide by M for
    per-microbatch slot costs. ``hop_latency`` is paid once per
    microbatch per hop (a fixed link cost, it does not shrink with
    microbatching).
    """

    t_comp_fwd: np.ndarray  # (S,)   Eq. 8 stage forward time
    t_comp_bwd: np.ndarray  # (S,)   Eq. 9 stage backward time
    t_tx_fwd: np.ndarray  # (S-1,) activation transmission time (no latency)
    t_tx_bwd: np.ndarray  # (S-1,) cotangent transmission time (no latency)
    hop_latency: np.ndarray  # (S-1,) fixed per-transmission link latency

    @property
    def num_stages(self) -> int:
        return len(self.t_comp_fwd)


def plan_transport_model(
    profile: LayerProfile,
    plan: SplitPlan,
    positions: np.ndarray,
    p_tx: np.ndarray,
    decoy_power: np.ndarray,
    net,
) -> TransportModel:
    """Build the executor's transport model from the plan-cost breakdown.

    ``net`` is a ``NetworkConfig`` or ``ScenarioParams`` (duck-typed, as
    everywhere in ``core.channel``). Because the terms come from
    :func:`repro.core.splitting.plan_cost_parts`, the model and the plan
    oracle can never disagree on hop physics.
    """
    parts = plan_cost_parts(profile, plan, positions, p_tx, decoy_power, net)
    s = plan.num_stages
    lat = np.asarray(net.hop_latency_s, np.float64)[: s - 1]
    return TransportModel(
        t_comp_fwd=parts["t_comp_fwd"],
        t_comp_bwd=parts["t_comp_bwd"],
        t_tx_fwd=parts["t_hop_fwd"] - lat,
        t_tx_bwd=parts["t_hop_bwd"] - lat,
        hop_latency=lat,
    )


def faulted_transport_model(
    profile: LayerProfile,
    plan: SplitPlan,
    positions: np.ndarray,
    p_tx: np.ndarray,
    decoy_power: np.ndarray,
    sp,
    schedule,
) -> TransportModel:
    """Transport model under a :class:`repro.core.faults.FaultSchedule`.

    Link degradation folds through ``faults.degrade_scenario`` BEFORE
    the plan-cost breakdown - the same degraded ``ScenarioParams`` that
    ``plan_cost``/``score_plans`` price, so the executor's delay
    accounting under partial outage can never disagree with Eq. 10
    (pinned at M=1 sync by ``tests/test_faults.py``).  Per-device
    straggler factors then scale each stage's compute terms via the
    plan's device assignment (Eqs. 8-9 run on the assigned device's
    effective clock).  A ``fault_free`` schedule is a bit-exact no-op.
    """
    from repro.core.faults import degrade_scenario

    model = plan_transport_model(profile, plan, positions, p_tx,
                                 decoy_power, degrade_scenario(sp, schedule))
    slow = np.asarray(schedule.compute_slowdown, np.float64)
    devs = np.asarray(plan.devices, np.int64)
    return TransportModel(
        t_comp_fwd=model.t_comp_fwd * slow[devs],
        t_comp_bwd=model.t_comp_bwd * slow[devs],
        t_tx_fwd=model.t_tx_fwd,
        t_tx_bwd=model.t_tx_bwd,
        hop_latency=model.hop_latency,
    )


def tick_costs(model: TransportModel, m: int):
    """Per-tick (compute, transport) seconds of the 1F1B schedule.

    Mirrors the executor's slot arithmetic exactly: at tick ``t`` stage
    ``i`` forwards microbatch ``t - i`` and backwards microbatch
    ``t - 2(S-1) + i``; stages run in parallel (a tick's compute is the
    max over stages, a stage's two slots are serial), and the paired
    ``ppermute`` fires every hop's transmission concurrently (a tick's
    transport is the max over active hops). Returns two ``(n_ticks,)``
    arrays with ``n_ticks = M + 2(S-1)``.
    """
    s = model.num_stages
    n_ticks = m + 2 * (s - 1)
    fwd_c = model.t_comp_fwd / m
    bwd_c = model.t_comp_bwd / m
    hop_f = model.t_tx_fwd / m + model.hop_latency
    hop_b = model.t_tx_bwd / m + model.hop_latency
    compute = np.zeros(n_ticks)
    transport = np.zeros(n_ticks)
    for t in range(n_ticks):
        per_stage = np.zeros(s)
        for i in range(s):
            if 0 <= t - i < m:  # forward slot (last stage: inside its VJP)
                per_stage[i] += fwd_c[i]
            if 0 <= t - 2 * (s - 1) + i < m:  # backward slot
                per_stage[i] += bwd_c[i]
        compute[t] = per_stage.max()
        tr = 0.0
        for k in range(s - 1):
            if 0 <= t - k < m:  # forward hop k: stage k -> k+1
                tr = max(tr, hop_f[k])
            if 0 <= t - 2 * (s - 1) + (k + 1) < m:  # cotangent hop k+1 -> k
                tr = max(tr, hop_b[k])
        transport[t] = tr
    return compute, transport


def simulate_1f1b(model: TransportModel, m: int, *,
                  transport: str = "overlap") -> dict:
    """Simulated 1F1B wall-time under the link model.

    ``transport="sync"``: tick = compute + transport (the stage waits on
    its sends). ``transport="overlap"``: tick = max(compute, in-flight
    transport), where the in-flight buffer is the one produced the
    previous tick (double-buffered handoff; idealized full overlap).
    Returns total/compute/transport seconds, per-tick arrays, and the
    bubble fraction (idle stage-slots over total stage-slots).
    """
    if transport not in ("sync", "overlap"):
        raise ValueError(f"unknown transport {transport!r}")
    s = model.num_stages
    compute, tr = tick_costs(model, m)
    n_ticks = len(compute)
    if transport == "sync":
        per_tick = compute + tr
    else:
        in_flight = np.concatenate([[0.0], tr[:-1]])
        per_tick = np.maximum(compute, in_flight)
    # idle stage-slots: each of the n_ticks*S stage-ticks has a forward
    # and a backward slot; exactly 2*M*S of them do real work
    active_slots = sum(
        (0 <= t - i < m) + (0 <= t - 2 * (s - 1) + i < m)
        for t in range(n_ticks) for i in range(s)
    )
    return {
        "transport": transport,
        "ticks": n_ticks,
        "total_s": float(per_tick.sum()),
        "compute_s": float(compute.sum()),
        "transport_s": float(tr.sum()),
        "per_tick_s": per_tick,
        "bubble_fraction": 1.0 - active_slots / (2.0 * s * n_ticks),
    }


def simulate_1f1b_faulted(model: TransportModel, m: int, schedule, devices,
                          *, transport: str = "overlap",
                          t_start: float = 0.0) -> dict:
    """:func:`simulate_1f1b` under outage windows.

    ``devices`` is the plan's stage -> device assignment; a tick whose
    start time falls inside any assigned device's outage window STALLS
    until the last such device recovers (the executor retries the hop /
    block until its peer is back), then pays its normal cost.  Per-tick
    costs should come from a :func:`faulted_transport_model` so link
    degradation and stragglers are already priced in.  Returns the
    :func:`simulate_1f1b` dict plus ``stall_s`` / ``per_tick_stall_s``;
    a ``fault_free`` schedule reproduces :func:`simulate_1f1b` exactly.
    """
    from repro.core import faults as F

    base = simulate_1f1b(model, m, transport=transport)
    per_tick = np.asarray(base["per_tick_s"], np.float64)
    devs = np.asarray(devices, np.int64)
    stalls = np.zeros_like(per_tick)
    t = float(t_start)
    for i, cost in enumerate(per_tick):
        stalls[i] = float(F.outage_stall(schedule, t, devs))
        t += stalls[i] + float(cost)
    out = dict(base)
    out["per_tick_stall_s"] = stalls
    out["stall_s"] = float(stalls.sum())
    out["total_s"] = float(per_tick.sum() + stalls.sum())
    return out
