"""Layer profiles: what the MHSL splitter needs to know about a model.

A ``LayerProfile`` gives, for each of L split-able layers:
  * param_bytes[i]   - G(theta_i), bytes of parameters resident in layer i
  * act_bytes[i]     - Gamma(z_i), bytes of the activation EMITTED by layer i
                       (what hops to the next device, incl. SSM state for
                       'M' blocks at the boundary)
  * grad_bytes[i]    - Gamma(dL/dz_i), bytes of the cotangent hopping back
  * fwd_flops[i] / bwd_flops[i]

Two sources:
  * ``transformer_profile(cfg, batch, seq)`` - derived exactly from any of
    the 10 assigned architecture configs;
  * ``resnet101_profile(batch)`` - the paper's own workload (ResNet-101 on
    ImageNet, Table I setting), from the published per-stage layer table.

The paper's delay model (Eqs. 8-9) uses an abstract complexity coefficient
lambda_f/lambda_b; we keep those as explicit knobs so Table-I values
(1-2 GFLOP equivalents) reproduce, while real profiles feed the TPU
pipeline executor.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

from repro.configs.base import ModelConfig


# per-block kind codes (LayerProfile.kind / ProfileTable.kind): the mixer
# in the low bit-space, +2 when the block's FFN half is an expert bank
KIND_ATTN = 0       # attention mixer + dense MLP
KIND_SSM = 1        # Mamba-2 (SSD) mixer
KIND_ATTN_MOE = 2   # attention mixer + MoE expert bank
KIND_SSM_MOE = 3    # SSM mixer + MoE expert bank
KIND_NAMES = {KIND_ATTN: "attn", KIND_SSM: "ssm",
              KIND_ATTN_MOE: "attn+moe", KIND_SSM_MOE: "ssm+moe"}


def block_kind(cfg: ModelConfig, i: int) -> int:
    """Kind code of block ``i`` of ``cfg`` (KIND_* constants)."""
    base = KIND_SSM if cfg.pattern[i] == "M" else KIND_ATTN
    return base + (2 if cfg.is_moe_block(i) else 0)


@dataclass(frozen=True)
class LayerProfile:
    name: str
    param_bytes: np.ndarray  # (L,)
    act_bytes: np.ndarray  # (L,) activation emitted after layer i
    grad_bytes: np.ndarray  # (L,) cotangent entering layer i from above
    fwd_flops: np.ndarray  # (L,)
    bwd_flops: np.ndarray  # (L,)
    # leakage sensitivity delta_i: information value (bytes-equivalent) of
    # observing the traffic emitted by layer i. Earlier layers leak more
    # about raw data [20]; default: act_bytes * depth-decaying risk factor.
    leak_value: np.ndarray  # (L,)
    # architecture-aware columns (None = homogeneous legacy profile, treated
    # as all-zero state / all-KIND_ATTN):
    #   state_bytes[i] - bytes of RESIDENT per-block state the hosting device
    #     must keep live across the run: attention KV cache, SSM scan + conv
    #     state, MoE expert + router weights. Priced per stage via
    #     NetworkConfig.state_cycles_per_bit (maintenance cycles folded into
    #     the Eq. 8-9 compute terms), so cut points land differently across
    #     block types.
    #   kind[i] - KIND_* code of block i (int8).
    state_bytes: np.ndarray = None  # (L,)
    kind: np.ndarray = None  # (L,) int8 KIND_* codes

    @property
    def num_layers(self) -> int:
        return len(self.param_bytes)

    def total_param_bytes(self) -> float:
        return float(self.param_bytes.sum())


@dataclass(frozen=True)
class ProfileTable:
    """Hoisted per-profile arrays shared by every plan-cost consumer.

    ``plan_cost``/``score_plans`` and ``MHSLEnv._consts`` all need the same
    derived quantities: per-layer boundary bits and cumulative-FLOP tables
    (stage sums become two gathers + a subtraction instead of a per-stage
    slice-and-sum). Building them per call made the host plan scorer
    re-derive each field S times per plan; this table is computed once per
    ``LayerProfile`` and cached (see :func:`profile_table`). All arrays are
    host numpy (float64) - device consumers ``jnp.asarray`` them inside
    their traces, which reproduces the seed's exact f32 casts.
    """

    act_bits: np.ndarray  # (L,)   activation bits emitted by layer i
    grad_bits: np.ndarray  # (L,)   cotangent bits entering layer i
    leak_norm: np.ndarray  # (L,)   leak_value / max(leak_value)
    fwd_cum: np.ndarray  # (L+1,) cumulative fwd FLOPs, fwd_cum[0] = 0
    bwd_cum: np.ndarray  # (L+1,) cumulative bwd FLOPs
    # architecture-aware columns (all-zero / all-KIND_ATTN for legacy
    # profiles built without them, e.g. resnet101):
    kind: np.ndarray  # (L,)   int8 KIND_* block codes
    state_bits: np.ndarray  # (L,)   resident state bits of layer i
    state_cum: np.ndarray  # (L+1,) cumulative state bits, state_cum[0] = 0


def _state_kind(profile: LayerProfile):
    """Normalized (state_bytes, kind) with the legacy-None defaults."""
    L = profile.num_layers
    state = profile.state_bytes
    kind = profile.kind
    if state is None:
        state = np.zeros(L, dtype=np.float64)
    if kind is None:
        kind = np.zeros(L, dtype=np.int8)
    return np.asarray(state, np.float64), np.asarray(kind, np.int8)


def profile_digest(profile: LayerProfile) -> str:
    """Content digest of a profile's arrays (plus name).

    Cache key for the derived-table and plan-scorer caches: two
    equal-content profiles (e.g. ``transformer_profile`` rebuilt per sweep
    point) share one entry - and one compiled scorer - instead of keying
    on object identity and silently recompiling per object. Hashing a few
    hundred float64s is nanoseconds next to a jit trace. The new
    state/kind columns hash in normalized form, so a legacy profile built
    with ``state_bytes=None`` shares its entry with an explicit all-zero
    one.
    """
    import hashlib

    h = hashlib.blake2b(profile.name.encode(), digest_size=16)
    state, kind = _state_kind(profile)
    for field, arr in (
        ("param_bytes", profile.param_bytes),
        ("act_bytes", profile.act_bytes),
        ("grad_bytes", profile.grad_bytes),
        ("fwd_flops", profile.fwd_flops),
        ("bwd_flops", profile.bwd_flops),
        ("leak_value", profile.leak_value),
        ("state_bytes", state),
        ("kind", kind),
    ):
        arr = np.ascontiguousarray(arr)
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


# content-keyed (see profile_digest); bounded by the number of DISTINCT
# profiles a process touches
_TABLE_CACHE: dict = {}


def profile_table(profile: LayerProfile) -> ProfileTable:
    """Cached :class:`ProfileTable` for ``profile`` (built once per content)."""
    key = profile_digest(profile)
    hit = _TABLE_CACHE.get(key)
    if hit is not None:
        return hit
    state, kind = _state_kind(profile)
    state_bits = state * 8.0
    table = ProfileTable(
        act_bits=profile.act_bytes * 8.0,
        grad_bits=profile.grad_bytes * 8.0,
        leak_norm=profile.leak_value / profile.leak_value.max(),
        fwd_cum=np.concatenate([[0.0], np.cumsum(profile.fwd_flops)]),
        bwd_cum=np.concatenate([[0.0], np.cumsum(profile.bwd_flops)]),
        kind=kind,
        state_bits=state_bits,
        state_cum=np.concatenate([[0.0], np.cumsum(state_bits)]),
    )
    _TABLE_CACHE[key] = table
    return table


def _leak_weights(L: int, floor: float = 0.3) -> np.ndarray:
    """Depth-decaying data-leakage risk: layer 0 risks raw-data leakage,
    deep layers leak increasingly task-specific features [20]."""
    d = np.linspace(1.0, floor, L)
    return d


def transformer_profile(
    cfg: ModelConfig, batch: int, seq: int, *, bytes_per_param: int = 4,
    act_bytes_per_el: int = 2,
) -> LayerProfile:
    L = cfg.num_layers
    d = cfg.d_model
    pb = np.array([cfg.block_params(i) for i in range(L)], dtype=np.float64)
    pb *= bytes_per_param
    act = np.full(L, batch * seq * d * act_bytes_per_el, dtype=np.float64)
    # SSM boundary also carries the recurrent state
    for i, kind in enumerate(cfg.pattern):
        if kind == "M":
            sc = cfg.ssm
            nh = sc.num_heads(d)
            act[i] += batch * nh * sc.head_dim * sc.d_state * 4
    grad = np.full(L, batch * seq * d * act_bytes_per_el, dtype=np.float64)
    active = np.array([cfg.active_block_params(i) for i in range(L)], dtype=np.float64)
    fwd = 2.0 * active * batch * seq
    # attention quadratic term (full attention; window caps it)
    for i, kind in enumerate(cfg.pattern):
        if kind == "A":
            ctx = min(seq, cfg.attention_window or seq)
            fwd[i] += 2.0 * 2.0 * batch * seq * ctx * cfg.num_heads * cfg.head_dim * 0.5
    bwd = 2.0 * fwd
    leak = act * _leak_weights(L)
    # per-block resident state: what the hosting device keeps live beyond
    # the streaming activation - attention KV cache, SSM scan + conv state,
    # and the full expert bank of MoE blocks (every expert's weights are
    # resident even though only top_k are active per token)
    state = np.zeros(L, dtype=np.float64)
    kinds = np.zeros(L, dtype=np.int8)
    for i, kind in enumerate(cfg.pattern):
        kinds[i] = block_kind(cfg, i)
        if kind == "A":
            ctx = min(seq, cfg.attention_window or seq)
            state[i] += (batch * ctx * 2 * cfg.num_kv_heads * cfg.head_dim
                         * act_bytes_per_el)
        else:
            sc = cfg.ssm
            nh = sc.num_heads(d)
            state[i] += batch * nh * sc.head_dim * sc.d_state * 4
            state[i] += batch * (sc.d_inner(d) + 2 * sc.d_state) * (sc.d_conv - 1) * 4
        if cfg.is_moe_block(i):
            state[i] += cfg.mlp_params(True) * bytes_per_param
    return LayerProfile(
        name=cfg.name,
        param_bytes=pb,
        act_bytes=act,
        grad_bytes=grad,
        fwd_flops=fwd,
        bwd_flops=bwd,
        leak_value=leak,
        state_bytes=state,
        kind=kinds,
    )


# ---------------------------------------------------------------------------
# paper-faithful ResNet-101 profile
# ---------------------------------------------------------------------------

# (blocks, in_ch, mid_ch, out_ch, spatial) per ResNet-101 stage @224x224
_RESNET101_STAGES: List[Tuple[int, int, int, int, int]] = [
    (3, 64, 64, 256, 56),
    (4, 256, 128, 512, 28),
    (23, 512, 256, 1024, 14),
    (3, 1024, 512, 2048, 7),
]


def resnet101_profile(batch: int = 1, *, image: int = 224,
                      act_bytes_per_el: int = 2) -> LayerProfile:
    """Bottleneck-block granularity (33 blocks + stem + fc = 35 layers).

    Activations hop the wireless links in fp16 (2 B/el): the paper's 8 s /
    75 J Table-I budgets are only satisfiable at ~Mbps TDMA rates with
    half-precision feature transmission (noted in the faithfulness ledger).
    """
    params, acts, flops = [], [], []
    # stem: 7x7/2 conv 3->64 + pool -> 56x56
    params.append(7 * 7 * 3 * 64 * 4)
    acts.append(batch * 64 * 56 * 56 * act_bytes_per_el)
    flops.append(2 * 7 * 7 * 3 * 64 * batch * 112 * 112)
    for blocks, cin, mid, cout, sp in _RESNET101_STAGES:
        for bidx in range(blocks):
            ci = cin if bidx == 0 else cout
            p = (ci * mid + 9 * mid * mid + mid * cout) * 4
            if bidx == 0 and ci != cout:
                p += ci * cout * 4  # downsample projection
            params.append(p)
            acts.append(batch * cout * sp * sp * act_bytes_per_el)
            flops.append(2 * (ci * mid + 9 * mid * mid + mid * cout) * batch * sp * sp)
    # classifier
    params.append(2048 * 1000 * 4)
    acts.append(batch * 1000 * act_bytes_per_el)
    flops.append(2 * 2048 * 1000 * batch)
    pb = np.asarray(params, dtype=np.float64)
    ab = np.asarray(acts, dtype=np.float64)
    fw = np.asarray(flops, dtype=np.float64)
    return LayerProfile(
        name="resnet101",
        param_bytes=pb,
        act_bytes=ab,
        grad_bytes=ab.copy(),
        fwd_flops=fw,
        bwd_flops=2 * fw,
        leak_value=ab * _leak_weights(len(pb)),
    )


def get_profile(name: str, batch: int, seq: int = 0) -> LayerProfile:
    if name == "resnet101":
        return resnet101_profile(batch)
    from repro.configs import get_config

    return transformer_profile(get_config(name), batch, seq or 2048)
