"""Split plans: partition a LayerProfile into S sequential stages.

A plan is ``boundaries`` = cumulative layer counts [c_1 < ... < c_S = L]:
stage k holds layers [c_{k-1}, c_k). ``devices`` maps stage -> device id
(device U == the server, which always holds the last stage).

Provides the Eq. 6-11 aggregate delay/energy of executing a plan, and an
exhaustive plan enumerator used by the oracle baselines and tests.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Iterator, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.channel import (
    NetworkConfig,
    compute_energy,
    compute_time_bwd,
    compute_time_fwd,
    data_rate,
    tx_time,
)
from repro.core.profiles import LayerProfile


@dataclass(frozen=True)
class SplitPlan:
    boundaries: Tuple[int, ...]  # cumulative, last == L
    devices: Tuple[int, ...]  # stage -> device id (len S; last is server id)

    @property
    def num_stages(self) -> int:
        return len(self.boundaries)

    def stage_range(self, k: int) -> Tuple[int, int]:
        lo = 0 if k == 0 else self.boundaries[k - 1]
        return lo, self.boundaries[k]


def stage_sums(profile: LayerProfile, boundaries: Sequence[int], field: str) -> np.ndarray:
    arr = getattr(profile, field)
    out = []
    lo = 0
    for hi in boundaries:
        out.append(arr[lo:hi].sum())
        lo = hi
    return np.asarray(out)


def boundary_bits(profile: LayerProfile, boundaries: Sequence[int], field: str) -> np.ndarray:
    """Bits transmitted at each inter-stage hop (S-1 hops).

    Hop k carries the activation emitted by the last layer of stage k.
    """
    arr = getattr(profile, field)
    return np.asarray([arr[b - 1] * 8.0 for b in boundaries[:-1]])


def plan_cost(
    profile: LayerProfile,
    plan: SplitPlan,
    positions: np.ndarray,  # (U+1, 2) device positions (last row = server)
    p_tx: np.ndarray,  # (S-1,) trainer power per forward hop
    decoy_power: np.ndarray,  # (S-1, U+1) decoy powers per hop (0 = inactive)
    net: NetworkConfig,
):
    """Total delay (Eq. 10) and energy (Eq. 11) of one training iteration.

    Gradient hops reuse the same powers in reverse (the env lets the agent
    choose per-hop powers; this helper is the static-cost oracle).
    """
    s = plan.num_stages
    fwd = stage_sums(profile, plan.boundaries, "fwd_flops")
    bwd = stage_sums(profile, plan.boundaries, "bwd_flops")
    act_bits = boundary_bits(profile, plan.boundaries, "act_bytes")
    grad_bits = boundary_bits(profile, plan.boundaries, "grad_bytes")

    t_total = 0.0
    e_total = 0.0
    for k in range(s):
        t_total += float(compute_time_fwd(fwd[k], net))
        t_total += float(compute_time_bwd(bwd[k], net))
        e_total += float(compute_energy(fwd[k] + bwd[k], net))
    for k in range(s - 1):
        tx, rx = plan.devices[k], plan.devices[k + 1]
        d_tx_rx = float(np.linalg.norm(positions[tx] - positions[rx]))
        d_dec_rx = np.linalg.norm(positions - positions[rx], axis=1)
        # forward hop
        r = float(
            data_rate(p_tx[k], d_tx_rx, jnp.asarray(decoy_power[k]), jnp.asarray(d_dec_rx), net)
        )
        t_f = float(tx_time(act_bits[k], r))
        # gradient hop (reverse direction, same powers)
        d_dec_tx = np.linalg.norm(positions - positions[tx], axis=1)
        r_b = float(
            data_rate(p_tx[k], d_tx_rx, jnp.asarray(decoy_power[k]), jnp.asarray(d_dec_tx), net)
        )
        t_b = float(tx_time(grad_bits[k], r_b))
        t_total += t_f + t_b
        e_total += (float(p_tx[k]) + float(decoy_power[k].sum())) * (t_f + t_b)
    return t_total, e_total


def enumerate_boundaries(num_layers: int, s: int) -> Iterator[Tuple[int, ...]]:
    """All ways to cut L layers into S non-empty contiguous stages."""
    for cuts in itertools.combinations(range(1, num_layers), s - 1):
        yield tuple(cuts) + (num_layers,)


def even_boundaries(num_layers: int, s: int) -> Tuple[int, ...]:
    base = num_layers // s
    rem = num_layers % s
    out, acc = [], 0
    for k in range(s):
        acc += base + (1 if k < rem else 0)
        out.append(acc)
    return tuple(out)
