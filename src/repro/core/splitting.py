"""Split plans: partition a LayerProfile into S sequential stages.

A plan is ``boundaries`` = cumulative layer counts [c_1 < ... < c_S = L]:
stage k holds layers [c_{k-1}, c_k). ``devices`` maps stage -> device id
(device U == the server, which always holds the last stage).

Two scoring paths share one :class:`repro.core.profiles.ProfileTable`:

* :func:`plan_cost` - the host reference: one plan at a time, python-float
  accumulation, per-hop jnp physics. Stage sums come from the hoisted
  cumulative-FLOP tables (two gathers + a subtraction), so a call is
  O(S), not O(S * L) re-slicing per field.
* :func:`score_plans` / :func:`make_plan_scorer` - the device path: the
  WHOLE plan batch (e.g. every ``(L-1 choose S-1)`` enumeration from
  :func:`stack_boundaries`) is scored by a single jitted vmap. The
  network argument is duck-typed like ``repro.core.channel``: a static
  ``NetworkConfig`` is converted to a ``ScenarioParams`` pytree, so
  monitor-prob/bandwidth/budget sweeps and boundary re-scores reuse ONE
  trace (``scorer.trace_count`` audits this). This is the fast oracle
  the RL env uses for split-action masking and what the cut-point sweep
  benchmarks call instead of the per-plan python loop.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from functools import partial
from typing import Iterator, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import (
    NetworkConfig,
    compute_energy,
    compute_time_bwd,
    compute_time_fwd,
    data_rate,
    state_energy,
    state_time,
    tx_time,
)
from repro.core.profiles import LayerProfile, profile_digest, profile_table


@dataclass(frozen=True)
class SplitPlan:
    boundaries: Tuple[int, ...]  # cumulative, last == L
    devices: Tuple[int, ...]  # stage -> device id (len S; last is server id)

    @property
    def num_stages(self) -> int:
        return len(self.boundaries)

    def stage_range(self, k: int) -> Tuple[int, int]:
        lo = 0 if k == 0 else self.boundaries[k - 1]
        return lo, self.boundaries[k]


def stage_sums(profile: LayerProfile, boundaries: Sequence[int], field: str) -> np.ndarray:
    arr = getattr(profile, field)
    out = []
    lo = 0
    for hi in boundaries:
        out.append(arr[lo:hi].sum())
        lo = hi
    return np.asarray(out)


def boundary_bits(profile: LayerProfile, boundaries: Sequence[int], field: str) -> np.ndarray:
    """Bits transmitted at each inter-stage hop (S-1 hops).

    Hop k carries the activation emitted by the last layer of stage k.
    """
    arr = getattr(profile, field)
    return np.asarray([arr[b - 1] * 8.0 for b in boundaries[:-1]])


def _hop_link(net, num_hops: int):
    """Per-hop (bandwidth_hz, latency_s) arrays for the first ``num_hops``
    inter-stage links.

    Duck-typed over ``NetworkConfig`` (host numpy properties) and
    ``ScenarioParams`` (jnp leaves); both expose ``hop_bandwidth_hz`` /
    ``hop_latency_s`` sized ``max_split - 1``, which bounds the hop count
    of any feasible plan.
    """
    bw, lat = net.hop_bandwidth_hz, net.hop_latency_s
    if bw.shape[-1] < num_hops:
        raise ValueError(
            f"link model has {bw.shape[-1]} hops, plan needs {num_hops}")
    return bw[:num_hops], lat[:num_hops]


def plan_cost_parts(
    profile: LayerProfile,
    plan: SplitPlan,
    positions: np.ndarray,  # (U+1, 2) device positions (last row = server)
    p_tx: np.ndarray,  # (S-1,) trainer power per forward hop
    decoy_power: np.ndarray,  # (S-1, U+1) decoy powers per hop (0 = inactive)
    net: NetworkConfig,
) -> dict:
    """Per-stage / per-hop breakdown of :func:`plan_cost` (host floats).

    Returns ``t_comp_fwd``/``t_comp_bwd`` ``(S,)`` stage compute times,
    ``t_hop_fwd``/``t_hop_bwd`` ``(S-1,)`` per-hop transmission times
    (Eq. 6-7 at the hop's link bandwidth, plus its fixed link latency),
    and ``e_comp``/``e_tx`` energies. The split executor's transport tick
    model (``repro.core.transport``) consumes these directly, which is
    what pins the executor's simulated time to the Eq. 10/11 oracle.
    """
    s = plan.num_stages
    tab = profile_table(profile)
    b = np.asarray(plan.boundaries, np.int64)
    lo = np.concatenate([[0], b[:-1]])
    fwd = tab.fwd_cum[b] - tab.fwd_cum[lo]
    bwd = tab.bwd_cum[b] - tab.bwd_cum[lo]
    state = tab.state_cum[b] - tab.state_cum[lo]
    act_bits = tab.act_bits[b[:-1] - 1]
    grad_bits = tab.grad_bits[b[:-1] - 1]
    hop_bw, hop_lat = _hop_link(net, s - 1)

    t_comp_fwd = np.zeros(s)
    t_comp_bwd = np.zeros(s)
    e_comp = 0.0
    for k in range(s):
        # resident-state maintenance (KV / SSM state / MoE expert bank)
        # folds INTO the stage compute terms, so the transport tick model
        # and the Eq. 10 total stay in automatic agreement
        t_state = float(state_time(state[k], net))
        t_comp_fwd[k] = float(compute_time_fwd(fwd[k], net)) + t_state
        t_comp_bwd[k] = float(compute_time_bwd(bwd[k], net)) + t_state
        e_comp += float(compute_energy(fwd[k] + bwd[k], net))
        e_comp += 2.0 * float(state_energy(state[k], net))  # fwd + bwd touch
    t_hop_fwd = np.zeros(max(s - 1, 0))
    t_hop_bwd = np.zeros(max(s - 1, 0))
    e_tx = 0.0
    for k in range(s - 1):
        tx, rx = plan.devices[k], plan.devices[k + 1]
        d_tx_rx = float(np.linalg.norm(positions[tx] - positions[rx]))
        d_dec_rx = np.linalg.norm(positions - positions[rx], axis=1)
        # forward hop
        r = float(
            data_rate(p_tx[k], d_tx_rx, jnp.asarray(decoy_power[k]),
                      jnp.asarray(d_dec_rx), net,
                      bandwidth_hz=float(hop_bw[k]))
        )
        t_f = float(tx_time(act_bits[k], r)) + float(hop_lat[k])
        # gradient hop (reverse direction, same powers)
        d_dec_tx = np.linalg.norm(positions - positions[tx], axis=1)
        r_b = float(
            data_rate(p_tx[k], d_tx_rx, jnp.asarray(decoy_power[k]),
                      jnp.asarray(d_dec_tx), net,
                      bandwidth_hz=float(hop_bw[k]))
        )
        t_b = float(tx_time(grad_bits[k], r_b)) + float(hop_lat[k])
        t_hop_fwd[k] = t_f
        t_hop_bwd[k] = t_b
        # the radio is on for the whole hop (latency included)
        e_tx += (float(p_tx[k]) + float(decoy_power[k].sum())) * (t_f + t_b)
    return {
        "t_comp_fwd": t_comp_fwd, "t_comp_bwd": t_comp_bwd,
        "t_hop_fwd": t_hop_fwd, "t_hop_bwd": t_hop_bwd,
        "e_comp": e_comp, "e_tx": e_tx,
    }


def plan_cost(
    profile: LayerProfile,
    plan: SplitPlan,
    positions: np.ndarray,  # (U+1, 2) device positions (last row = server)
    p_tx: np.ndarray,  # (S-1,) trainer power per forward hop
    decoy_power: np.ndarray,  # (S-1, U+1) decoy powers per hop (0 = inactive)
    net: NetworkConfig,
):
    """Total delay (Eq. 10) and energy (Eq. 11) of one training iteration.

    Gradient hops reuse the same powers in reverse (the env lets the agent
    choose per-hop powers; this helper is the static-cost oracle). The
    per-stage FLOP sums come from the cached :func:`profile_table`
    cumulative tables, so repeated calls do not re-derive each profile
    field per stage. Hop transmissions run at the per-hop link bandwidth /
    latency of ``net``'s link model (uniform ``bandwidth_hz`` / zero
    latency by default). See :func:`plan_cost_parts` for the breakdown.
    """
    parts = plan_cost_parts(profile, plan, positions, p_tx, decoy_power, net)
    t_total = (parts["t_comp_fwd"].sum() + parts["t_comp_bwd"].sum()
               + parts["t_hop_fwd"].sum() + parts["t_hop_bwd"].sum())
    e_total = parts["e_comp"] + parts["e_tx"]
    return float(t_total), float(e_total)


def enumerate_boundaries(num_layers: int, s: int) -> Iterator[Tuple[int, ...]]:
    """All ways to cut L layers into S non-empty contiguous stages."""
    for cuts in itertools.combinations(range(1, num_layers), s - 1):
        yield tuple(cuts) + (num_layers,)


def stack_boundaries(num_layers: int, s: int) -> np.ndarray:
    """The full enumeration as one ``((L-1 choose S-1), S)`` int32 array.

    Host-side, built once; :func:`score_plans` scores the whole stack in
    a single device dispatch.
    """
    return np.asarray(list(enumerate_boundaries(num_layers, s)), np.int32)


def even_boundaries(num_layers: int, s: int) -> Tuple[int, ...]:
    base = num_layers // s
    rem = num_layers % s
    out, acc = [], 0
    for k in range(s):
        acc += base + (1 if k < rem else 0)
        out.append(acc)
    return tuple(out)


def plan_devices_up(devices, device_mask):
    """Per-plan survivability under a device up/down mask.

    ``devices`` is an ``(..., S)`` device-assignment batch (or a single
    ``(S,)`` assignment), ``device_mask`` a ``(U+1,)`` bool/float mask
    (1 = up).  Returns an ``(...,)`` bool: every stage of the plan sits
    on an up device.  Runtime values throughout - masking out a failed
    device never retraces the oracle - and the fast path the failure-
    aware serving re-planner uses to route around dead devices.
    """
    devs = jnp.asarray(devices, jnp.int32)
    up = jnp.asarray(device_mask).astype(bool)[devs]
    return up.all(axis=-1)


# ---------------------------------------------------------------------------
# vectorized plan scoring (the device-side oracle)
# ---------------------------------------------------------------------------


def _score_one(consts, boundaries, devices, positions, p_tx, decoy, sp):
    """Eq. 10/11 cost of ONE plan, all-jnp (vmapped over the plan batch).

    ``consts`` = (fwd_cum, bwd_cum, act_bits, grad_bits, state_cum) device
    tables; ``sp`` is a ScenarioParams pytree (lambda_f/lambda_b and
    state_cycles_per_bit ride along, so complexity-coefficient and
    state-pricing sweeps are also retrace-free; the lambdas default to the
    1.0 that :func:`plan_cost` applies).
    """
    fwd_cum, bwd_cum, act_bits_t, grad_bits_t, state_cum = consts
    lo = jnp.concatenate([jnp.zeros((1,), boundaries.dtype), boundaries[:-1]])
    fwd = fwd_cum[boundaries] - fwd_cum[lo]
    bwd = bwd_cum[boundaries] - bwd_cum[lo]
    state = state_cum[boundaries] - state_cum[lo]
    act_bits = act_bits_t[boundaries[:-1] - 1]
    grad_bits = grad_bits_t[boundaries[:-1] - 1]

    t_comp = (
        compute_time_fwd(fwd, sp, lam=sp.lambda_f)
        + compute_time_bwd(bwd, sp, lam=sp.lambda_b)
        + 2.0 * state_time(state, sp)  # fwd + bwd touch, as in plan_cost
    ).sum()
    e_comp = (compute_energy(fwd + bwd, sp)
              + 2.0 * state_energy(state, sp)).sum()

    s = boundaries.shape[0]
    hop_bw = sp.hop_bandwidth_hz[: s - 1]
    hop_lat = sp.hop_latency_s[: s - 1]
    tx_pos = positions[devices[:-1]]  # (S-1, 2)
    rx_pos = positions[devices[1:]]
    d_tx_rx = jnp.linalg.norm(tx_pos - rx_pos, axis=-1)
    d_dec_rx = jnp.linalg.norm(positions[None, :, :] - rx_pos[:, None, :], axis=-1)
    d_dec_tx = jnp.linalg.norm(positions[None, :, :] - tx_pos[:, None, :], axis=-1)
    rate = jax.vmap(
        lambda p, d, ip, idist, bw: data_rate(p, d, ip, idist, sp,
                                              bandwidth_hz=bw)
    )
    r_f = rate(p_tx, d_tx_rx, decoy, d_dec_rx, hop_bw)
    r_b = rate(p_tx, d_tx_rx, decoy, d_dec_tx, hop_bw)
    t_f = tx_time(act_bits, r_f) + hop_lat
    t_b = tx_time(grad_bits, r_b) + hop_lat
    t_total = t_comp + (t_f + t_b).sum()
    e_total = e_comp + ((p_tx + decoy.sum(-1)) * (t_f + t_b)).sum()
    return t_total, e_total


def make_plan_scorer(profile: LayerProfile):
    """Build the jitted batch scorer for ``profile``.

    Returns ``scorer(boundaries, devices, positions, p_tx, decoy_power,
    net) -> (delay (N,), energy (N,))`` where ``boundaries``/``devices``
    are ``(N, S)`` plan batches (``devices`` may also be a single ``(S,)``
    assignment shared by every plan, likewise ``p_tx`` ``(S-1,)`` and
    ``decoy_power`` ``(S-1, U+1)``), and ``net`` is either a static
    ``NetworkConfig`` or a ``ScenarioParams`` pytree. Boundary, position,
    power, and scenario sweeps all hit one compiled trace per batch shape
    (``scorer.trace_count`` is the audit hook; ``scorer.jitted`` exposes
    the underlying jit for cache introspection).
    """
    from repro.core.scenario import ScenarioParams, scenario_from_net

    tab = profile_table(profile)
    consts = (
        jnp.asarray(tab.fwd_cum),
        jnp.asarray(tab.bwd_cum),
        jnp.asarray(tab.act_bits),
        jnp.asarray(tab.grad_bits),
        jnp.asarray(tab.state_cum),
    )
    trace_count = [0]

    def _batch(boundaries, devices, positions, p_tx, decoy, sp):
        trace_count[0] += 1  # executes only while tracing
        one = partial(_score_one, consts)
        return jax.vmap(one, in_axes=(0, 0, None, 0, 0, None))(
            boundaries, devices, positions, p_tx, decoy, sp
        )

    jitted = jax.jit(_batch)

    def scorer(boundaries, devices, positions, p_tx, decoy_power, net):
        sp = net if isinstance(net, ScenarioParams) else scenario_from_net(net)
        boundaries = jnp.asarray(boundaries, jnp.int32)
        n, s = boundaries.shape
        if s - 1 > sp.hop_bandwidth_hz.shape[-1]:
            raise ValueError(
                f"link model has {sp.hop_bandwidth_hz.shape[-1]} hops, "
                f"plans need {s - 1}")
        devices = jnp.broadcast_to(jnp.asarray(devices, jnp.int32), (n, s))
        p_tx = jnp.broadcast_to(
            jnp.asarray(p_tx, jnp.float32), (n, s - 1)
        )
        decoy_power = jnp.asarray(decoy_power, jnp.float32)
        decoy_power = jnp.broadcast_to(
            decoy_power, (n, s - 1, decoy_power.shape[-1])
        )
        return jitted(boundaries, devices, jnp.asarray(positions, jnp.float32),
                      p_tx, decoy_power, sp)

    scorer.trace_count = trace_count
    scorer.jitted = jitted
    return scorer


# scorer cache: content-keyed (profiles.profile_digest), so equal-content
# profiles rebuilt per sweep point share ONE compiled scorer
_SCORER_CACHE: dict = {}


def score_plans(
    profile: LayerProfile,
    boundaries,
    devices,
    positions,
    p_tx,
    decoy_power,
    net,
):
    """Score a whole plan batch in one dispatch (see :func:`make_plan_scorer`).

    Convenience wrapper that caches one scorer per profile CONTENT, so
    repeated calls (cut-point sweeps, env oracles, benchmarks) share a
    single compiled trace per batch shape even when the profile object is
    rebuilt between calls.
    """
    key = profile_digest(profile)
    scorer = _SCORER_CACHE.get(key)
    if scorer is None:
        scorer = make_plan_scorer(profile)
        _SCORER_CACHE[key] = scorer
    return scorer(boundaries, devices, positions, p_tx, decoy_power, net)
