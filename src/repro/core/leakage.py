"""Eavesdropper & leakage model: Eq. 12-13, Theorem 1, Corollaries 1-2.

All expressions follow the paper exactly:
  * an eavesdropper locks onto the max-SNR signal among {trainer} U decoys
    (Eq. 12) under Rayleigh fading, giving capture probability
      P(e captures trainer) = prod_d  p_s m_s,e^-2 / (p_d m_d,e^-2 + p_s m_s,e^-2)
    (Theorem 1 / Eq. 37);
  * expected leakage of one hop = sum_e P_capture(e) * q_e * delta (Eq. 30);
  * closed-form optimal powers for |D|=1 (Corollary 1) and |E|=1
    (Corollary 2).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.channel import NetworkConfig, channel_gain

Array = jax.Array


def capture_probability(
    p_tx: Array,  # scalar trainer power
    dist_tx_e: Array,  # (E,) trainer -> eavesdropper distances
    decoy_p: Array,  # (U,) decoy powers (0 for non-decoys)
    decoy_dist_e: Array,  # (U, E) decoy -> eavesdropper distances
    o: float = 1.0,
) -> Array:
    """Theorem 1 product term, per eavesdropper. Returns (E,)."""
    s_tx = p_tx * channel_gain(dist_tx_e, o)  # (E,)
    s_d = decoy_p[:, None] * channel_gain(decoy_dist_e, o)  # (U, E)
    # P(S_d < S_tx) per decoy; inactive decoys (p=0) contribute factor 1
    frac = s_tx[None, :] / jnp.maximum(s_d + s_tx[None, :], 1e-30)  # (U, E)
    frac = jnp.where(decoy_p[:, None] > 0, frac, 1.0)
    return jnp.prod(frac, axis=0)  # (E,)


def expected_leakage(
    p_tx: Array,
    dist_tx_e: Array,
    decoy_p: Array,
    decoy_dist_e: Array,
    q_e: Array,  # (E,) monitoring probabilities
    delta: Array,  # scalar information value of this hop
    o: float = 1.0,
) -> Array:
    """Eq. 30: E[I] for one hop."""
    cap = capture_probability(p_tx, dist_tx_e, decoy_p, decoy_dist_e, o)
    return jnp.sum(cap * q_e) * delta


def sample_leakage(
    key,
    p_tx: Array,
    dist_tx_e: Array,
    decoy_p: Array,
    decoy_dist_e: Array,
    q_e: Array,
    delta: Array,
    o=1.0,
) -> Array:
    """Monte-Carlo single-draw leakage (Eqs. 12-13, 20-21): sample Rayleigh
    SNRs, pick the argmax per eavesdropper, sample the monitoring Bernoulli.

    The PRNG key is folded per eavesdropper INDEX, so each eavesdropper's
    draw depends only on its own slot: extending the eavesdropper axis with
    padded entries (``q_e`` masked to 0) leaves the active eavesdroppers'
    samples bit-identical to a smaller-E environment. This is what makes
    the padded-E scenario sweep (``ScenarioParams.eave_mask``) exactly
    equivalent to re-instantiating a smaller env.
    """
    e = dist_tx_e.shape[0]
    mean_tx = p_tx * channel_gain(dist_tx_e, o)  # (E,)
    mean_d = decoy_p[:, None] * channel_gain(decoy_dist_e, o)  # (U, E)
    means = jnp.concatenate([mean_tx[None, :], mean_d], axis=0)  # (U+1, E)

    def one_eave(ke, mean_col, q):
        ks, km = jax.random.split(ke)
        # Rayleigh power ~ Exponential(mean = p h): sample via -mean*log(U)
        un = jax.random.uniform(ks, mean_col.shape, minval=1e-12, maxval=1.0)
        snr = -mean_col * jnp.log(un)
        captured = jnp.argmax(snr) == 0  # trainer had max SNR
        monitored = jax.random.uniform(km) < q
        return captured & monitored

    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(key, jnp.arange(e))
    hits = jax.vmap(one_eave)(keys, means.T, q_e)
    return jnp.sum(hits) * delta


# ---------------------------------------------------------------------------
# Corollaries: closed-form optimal powers
# ---------------------------------------------------------------------------


def optimal_powers_single_decoy(
    bits: Array,  # Gamma(z_k) in bits
    dist_tx_rx: Array,  # m_{s_k, s_{k+1}}
    dist_tx_decoy: Array,  # m_{s_k, d}: decoy interference distance AT THE RECEIVER
    b_t: Array,  # time budget B_T
    b_e: Array,  # energy budget B_E
    net: NetworkConfig,
) -> Tuple[Array, Array]:
    """Corollary 1 (|D|=1): returns (p_s*, p_d*).

    xi_0 p_s - xi_d p_d = chi_1 (rate constraint tight)
    p_s + p_d = chi_2 = B_E / B_T (energy tight)

    When the energy budget is tight (xi_0 chi_2 < chi_1) the unclamped
    interior solution would assign the decoy NEGATIVE power; physical
    powers are non-negative, so the decoy is clamped to 0 and the whole
    budget goes to the trainer (the rate constraint is then best-effort
    infeasible either way). The energy identity p_s + p_d = chi_2 holds
    in both regimes.
    """
    o = net.rayleigh_o
    snr_req = 2.0 ** (bits / (b_t * net.bandwidth_hz)) - 1.0
    xi0 = o / dist_tx_rx**2
    xid = (o / dist_tx_decoy**2) * snr_req
    chi1 = net.noise_w * snr_req
    chi2 = b_e / b_t
    p_d = jnp.maximum((xi0 * chi2 - chi1) / (xi0 + xid), 0.0)
    # equals (chi1 + xid*chi2)/(xi0 + xid) in the interior regime
    p_s = chi2 - p_d
    return p_s, p_d


def optimal_powers_single_eave(
    bits: Array,
    dist_tx_rx: Array,
    decoy_dist_e: Array,  # (D,) decoy -> eavesdropper distances
    b_t: Array,
    b_e: Array,
    net: NetworkConfig,
) -> Tuple[Array, Array]:
    """Corollary 2 (|E|=1, decoy interference at the receiver ignored):
    returns (p_s*, p_d* (D,)).

    Clamped to physical powers: if the rate constraint alone demands more
    than the whole energy budget (chi_1/xi_0 > chi_2) the trainer gets the
    full budget and the decoys 0, instead of the unclamped solution's
    negative decoy powers.
    """
    o = net.rayleigh_o
    snr_req = 2.0 ** (bits / (b_t * net.bandwidth_hz)) - 1.0
    xi0 = o / dist_tx_rx**2
    chi1 = net.noise_w * snr_req
    chi2 = b_e / b_t
    p_s = jnp.minimum(chi1 / xi0, chi2)
    # water-levelling: equalize p_d m_{d,e}^-2 across decoys (Eq. 47-50)
    budget = jnp.maximum(chi2 - p_s, 0.0)
    denom = jnp.sum(decoy_dist_e**2)
    p_d = budget * decoy_dist_e**2 / jnp.maximum(denom, 1e-30)
    return p_s, p_d
