"""Eavesdropper & leakage model behind the unified :class:`LeakageModel` API.

All analytic expressions follow the paper exactly:
  * an eavesdropper locks onto the max-SNR signal among {trainer} U decoys
    (Eq. 12) under Rayleigh fading, giving capture probability
      P(e captures trainer) = prod_d  p_s m_s,e^-2 / (p_d m_d,e^-2 + p_s m_s,e^-2)
    (Theorem 1 / Eq. 37);
  * expected leakage of one hop = sum_e P_capture(e) * q_e * delta (Eq. 30);
  * closed-form optimal powers for |D|=1 (Corollary 1) and |E|=1
    (Corollary 2).

Two implementations share the protocol:

* :class:`AnalyticLeakage` - the paper's model. The per-layer information
  value ``delta`` comes from the profile's depth-decaying ``leak_norm``
  table (an ASSUMPTION about how much an activation reveals).
* :class:`EmpiricalLeakage` - the same wireless physics (capture +
  monitoring), but the per-layer value is MEASURED by a trained
  reconstruction adversary (``repro.attack``): the attacker's attack
  accuracy (variance-explained of its input reconstruction) at each cut
  point replaces the assumed ``leak_norm`` decay.

Both expose ``evaluate(scenario, plan, activations=None, key=None)``
over a per-hop :class:`HopGeometry` batch, and every consumer
(``env.step``, ``scenario.evaluate_population``, the fig benchmarks)
threads the model rather than calling the free functions, so swapping
analytic for empirical is a one-argument change.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import NetworkConfig, channel_gain

Array = jax.Array

__all__ = [
    "LeakageModel",
    "AnalyticLeakage",
    "EmpiricalLeakage",
    "HopGeometry",
    "plan_hop_geometry",
    "evaluate_leakage",
    # legacy free functions (thin wrappers over AnalyticLeakage)
    "capture_probability",
    "expected_leakage",
    "sample_leakage",
    "optimal_powers_single_decoy",
    "optimal_powers_single_eave",
]


class HopGeometry(NamedTuple):
    """Transmit geometry of the forward hops of one split plan.

    Leading axis = hops (H = S-1 for an S-stage plan). This is the
    ``plan`` argument of :meth:`LeakageModel.evaluate`; build it from a
    concrete plan + positions with :func:`plan_hop_geometry`.
    """

    p_tx: Array  # (H,) trainer transmit power per hop
    dist_tx_e: Array  # (H, E) trainer -> eavesdropper distances
    decoy_p: Array  # (H, D) decoy powers (0 for inactive decoys)
    decoy_dist_e: Array  # (H, D, E) decoy -> eavesdropper distances
    boundary_layer: Array  # (H,) int32 cut-layer index (0-based) per hop

    @property
    def num_hops(self) -> int:
        return self.p_tx.shape[0]


@runtime_checkable
class LeakageModel(Protocol):
    """Unified per-hop leakage estimator.

    ``evaluate(scenario, plan, activations=None, key=None)`` returns the
    per-hop leakage ``(H,)`` of ``plan`` under ``scenario``'s physics:
    expected leakage when ``key`` is None, a Monte-Carlo draw otherwise.
    ``activations`` optionally carries the smashed activations crossing
    each hop (``{"z": (H, n, d), "x": (H, n, d)}``) for models that can
    score live activations instead of a per-layer table.

    ``layer_values(leak_norm)`` maps the profile's per-layer information
    table to the table this model prices hops with (identity for the
    analytic model) - the hook ``MHSLEnv`` threads through its reward.
    """

    def evaluate(self, scenario, plan: HopGeometry, activations=None,
                 key=None) -> Array: ...

    def layer_values(self, leak_norm: np.ndarray) -> np.ndarray: ...


@dataclass(frozen=True, eq=False)
class AnalyticLeakage:
    """The paper's closed-form leakage model (Theorem 1 + Eq. 30).

    ``value_table`` (per-layer information values, shape (L,)) is only
    needed for :meth:`evaluate`; build it from a profile with
    :meth:`for_profile`. The method bodies are the bit-exact homes of the
    former module-level free functions.
    """

    value_table: Optional[np.ndarray] = None

    @classmethod
    def for_profile(cls, profile) -> "AnalyticLeakage":
        from repro.core.profiles import profile_table

        return cls(value_table=profile_table(profile).leak_norm)

    # ---- per-layer information values (env hook) --------------------------
    def layer_values(self, leak_norm: np.ndarray) -> np.ndarray:
        """Analytic model prices hops with the profile table unchanged."""
        return leak_norm

    # ---- Theorem 1 --------------------------------------------------------
    def capture_probability(
        self,
        p_tx: Array,  # scalar trainer power
        dist_tx_e: Array,  # (E,) trainer -> eavesdropper distances
        decoy_p: Array,  # (U,) decoy powers (0 for non-decoys)
        decoy_dist_e: Array,  # (U, E) decoy -> eavesdropper distances
        o: float = 1.0,
    ) -> Array:
        """Theorem 1 product term, per eavesdropper. Returns (E,)."""
        s_tx = p_tx * channel_gain(dist_tx_e, o)  # (E,)
        s_d = decoy_p[:, None] * channel_gain(decoy_dist_e, o)  # (U, E)
        # P(S_d < S_tx) per decoy; inactive decoys (p=0) contribute factor 1
        frac = s_tx[None, :] / jnp.maximum(s_d + s_tx[None, :], 1e-30)  # (U, E)
        frac = jnp.where(decoy_p[:, None] > 0, frac, 1.0)
        return jnp.prod(frac, axis=0)  # (E,)

    # ---- Eq. 30 -----------------------------------------------------------
    def expected_leakage(
        self,
        p_tx: Array,
        dist_tx_e: Array,
        decoy_p: Array,
        decoy_dist_e: Array,
        q_e: Array,  # (E,) monitoring probabilities
        delta: Array,  # scalar information value of this hop
        o: float = 1.0,
    ) -> Array:
        """Eq. 30: E[I] for one hop."""
        cap = self.capture_probability(p_tx, dist_tx_e, decoy_p, decoy_dist_e, o)
        return jnp.sum(cap * q_e) * delta

    # ---- Monte-Carlo draw (Eqs. 12-13, 20-21) -----------------------------
    def sample_leakage(
        self,
        key,
        p_tx: Array,
        dist_tx_e: Array,
        decoy_p: Array,
        decoy_dist_e: Array,
        q_e: Array,
        delta: Array,
        o=1.0,
    ) -> Array:
        """Monte-Carlo single-draw leakage: sample Rayleigh SNRs, pick the
        argmax per eavesdropper, sample the monitoring Bernoulli.

        The PRNG key is folded per eavesdropper INDEX, so each
        eavesdropper's draw depends only on its own slot: extending the
        eavesdropper axis with padded entries (``q_e`` masked to 0) leaves
        the active eavesdroppers' samples bit-identical to a smaller-E
        environment. This is what makes the padded-E scenario sweep
        (``ScenarioParams.eave_mask``) exactly equivalent to
        re-instantiating a smaller env.
        """
        e = dist_tx_e.shape[0]
        mean_tx = p_tx * channel_gain(dist_tx_e, o)  # (E,)
        mean_d = decoy_p[:, None] * channel_gain(decoy_dist_e, o)  # (U, E)
        means = jnp.concatenate([mean_tx[None, :], mean_d], axis=0)  # (U+1, E)

        def one_eave(ke, mean_col, q):
            ks, km = jax.random.split(ke)
            # Rayleigh power ~ Exponential(mean = p h): sample via -mean*log(U)
            un = jax.random.uniform(ks, mean_col.shape, minval=1e-12, maxval=1.0)
            snr = -mean_col * jnp.log(un)
            captured = jnp.argmax(snr) == 0  # trainer had max SNR
            monitored = jax.random.uniform(km) < q
            return captured & monitored

        keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(key, jnp.arange(e))
        hits = jax.vmap(one_eave)(keys, means.T, q_e)
        return jnp.sum(hits) * delta

    # ---- unified entry point ----------------------------------------------
    def _hop_values(self, plan: HopGeometry, activations) -> Array:
        """Per-hop information values (H,) before the leak_scale factor."""
        if self.value_table is None:
            raise ValueError(
                "evaluate() needs a per-layer value table - construct the "
                "model via AnalyticLeakage.for_profile(profile) (or "
                "EmpiricalLeakage.from_scores)")
        return jnp.asarray(self.value_table)[plan.boundary_layer]

    def evaluate(self, scenario, plan: HopGeometry, activations=None,
                 key=None) -> Array:
        """Per-hop leakage (H,) of ``plan`` under ``scenario``.

        ``key=None`` -> Eq. 30 expectation; otherwise one Monte-Carlo
        draw per hop (key folded per hop index). ``activations`` is
        ignored by the analytic model.
        """
        q_e = scenario.monitor_prob * scenario.eave_mask
        delta = self._hop_values(plan, activations) * scenario.leak_scale  # (H,)
        o = scenario.rayleigh_o
        if key is None:
            def one(g_p, g_de, g_dp, g_dde, d):
                return self.expected_leakage(g_p, g_de, g_dp, g_dde, q_e, d, o)
        else:
            hop_keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
                key, jnp.arange(plan.num_hops))

            def one(g_p, g_de, g_dp, g_dde, d, k):
                return self.sample_leakage(k, g_p, g_de, g_dp, g_dde, q_e, d, o)

            return jax.vmap(one)(plan.p_tx, plan.dist_tx_e, plan.decoy_p,
                                 plan.decoy_dist_e, delta, hop_keys)
        return jax.vmap(one)(plan.p_tx, plan.dist_tx_e, plan.decoy_p,
                             plan.decoy_dist_e, delta)


@dataclass(frozen=True, eq=False)
class EmpiricalLeakage(AnalyticLeakage):
    """Attacker-measured leakage: paper physics, learned information values.

    ``depths``/``scores`` hold the trained reconstruction adversary's
    attack accuracy (variance-explained in [0, 1]) at normalized cut
    depths; :meth:`layer_values` interpolates them onto any profile's
    layer axis, so a model measured on a depth-8 transformer prices a
    35-layer ResNet profile's cut points by relative depth. When
    ``score_fn`` is set (see ``repro.attack.make_activation_scorer``) and
    ``evaluate`` receives live smashed activations, the hop values come
    from scoring THOSE activations with the trained decoder instead of
    the interpolated table.
    """

    depths: Optional[np.ndarray] = None  # (K,) normalized cut depths in (0, 1)
    scores: Optional[np.ndarray] = None  # (K,) measured attack accuracy
    score_fn: Optional[Callable] = None  # activations dict -> (H,) scores

    @classmethod
    def from_scores(cls, cuts, scores, num_layers_measured: int,
                    num_layers: Optional[int] = None,
                    score_fn: Optional[Callable] = None) -> "EmpiricalLeakage":
        """Build from per-cut attack accuracies measured on an
        ``num_layers_measured``-layer model; ``num_layers`` sizes the
        ``value_table`` used by :meth:`evaluate` (defaults to the
        measured depth)."""
        depths = np.asarray(cuts, np.float64) / float(num_layers_measured)
        scores = np.asarray(scores, np.float64)
        order = np.argsort(depths)
        depths, scores = depths[order], scores[order]
        ell = num_layers_measured if num_layers is None else num_layers
        table = np.interp((np.arange(ell) + 1.0) / ell, depths, scores)
        return cls(value_table=table.astype(np.float32), depths=depths,
                   scores=scores, score_fn=score_fn)

    def layer_values(self, leak_norm: np.ndarray) -> np.ndarray:
        if self.depths is None or self.scores is None:
            raise ValueError("EmpiricalLeakage needs measured depths/scores "
                             "- build it via from_scores()")
        ell = len(leak_norm)
        vals = np.interp((np.arange(ell) + 1.0) / ell, self.depths, self.scores)
        return vals.astype(np.float32)

    def _hop_values(self, plan: HopGeometry, activations) -> Array:
        if activations is not None and self.score_fn is not None:
            return self.score_fn(activations)  # (H,) live attacker scores
        return super()._hop_values(plan, activations)


# module-level default used by the thin wrappers and env's fallback
_ANALYTIC = AnalyticLeakage()


def plan_hop_geometry(boundaries, devices, dev_pos, eav_pos, p_tx,
                      decoy_p) -> HopGeometry:
    """HopGeometry for the forward hops of one concrete split plan.

    ``boundaries``/``devices`` are the (S,) plan arrays (cumulative layer
    counts / device per stage), ``dev_pos`` (U+1, 2) and ``eav_pos``
    (E, 2) the positions, ``p_tx`` scalar or (S-1,) trainer powers and
    ``decoy_p`` (D,) or (S-1, D) decoy powers (decoy interference priced
    at the eavesdropper, matching ``env.step``).
    """
    b = jnp.asarray(boundaries, jnp.int32)
    dv = jnp.asarray(devices, jnp.int32)
    h = b.shape[0] - 1
    tx_pos = jnp.asarray(dev_pos)[dv[:-1]]  # (H, 2) transmitting stage
    dist_tx_e = jnp.linalg.norm(
        jnp.asarray(eav_pos)[None, :, :] - tx_pos[:, None, :], axis=-1)
    dde = jnp.linalg.norm(
        jnp.asarray(dev_pos)[:, None, :] - jnp.asarray(eav_pos)[None, :, :],
        axis=-1)  # (D, E)
    decoy_dist_e = jnp.broadcast_to(dde[None], (h,) + dde.shape)
    p_tx = jnp.broadcast_to(jnp.asarray(p_tx, jnp.float32), (h,))
    decoy_p = jnp.asarray(decoy_p, jnp.float32)
    if decoy_p.ndim == 1:
        decoy_p = jnp.broadcast_to(decoy_p[None], (h, decoy_p.shape[0]))
    boundary_layer = jnp.maximum(b[:-1] - 1, 0)
    return HopGeometry(p_tx=p_tx, dist_tx_e=dist_tx_e, decoy_p=decoy_p,
                       decoy_dist_e=decoy_dist_e, boundary_layer=boundary_layer)


def evaluate_leakage(model: LeakageModel, scenario, plan: HopGeometry,
                     activations=None, key=None) -> Array:
    """Functional entry point of the protocol: per-hop leakage (H,)."""
    return model.evaluate(scenario, plan, activations=activations, key=key)


# ---------------------------------------------------------------------------
# legacy free functions - thin wrappers over AnalyticLeakage
# ---------------------------------------------------------------------------


def capture_probability(p_tx, dist_tx_e, decoy_p, decoy_dist_e,
                        o: float = 1.0) -> Array:
    """Theorem 1 product term, per eavesdropper. Returns (E,).

    Deprecation note: retained as a bit-identical thin wrapper over
    :meth:`AnalyticLeakage.capture_probability`; new code should hold a
    :class:`LeakageModel` and call the method (or ``evaluate``).
    """
    return _ANALYTIC.capture_probability(p_tx, dist_tx_e, decoy_p,
                                         decoy_dist_e, o)


def expected_leakage(p_tx, dist_tx_e, decoy_p, decoy_dist_e, q_e, delta,
                     o: float = 1.0) -> Array:
    """Eq. 30: E[I] for one hop.

    Deprecation note: retained as a bit-identical thin wrapper over
    :meth:`AnalyticLeakage.expected_leakage`; prefer the
    :class:`LeakageModel` protocol.
    """
    return _ANALYTIC.expected_leakage(p_tx, dist_tx_e, decoy_p, decoy_dist_e,
                                      q_e, delta, o)


def sample_leakage(key, p_tx, dist_tx_e, decoy_p, decoy_dist_e, q_e, delta,
                   o=1.0) -> Array:
    """Monte-Carlo single-draw leakage (Eqs. 12-13, 20-21).

    Deprecation note: retained as a bit-identical thin wrapper over
    :meth:`AnalyticLeakage.sample_leakage` (including the
    per-eavesdropper-index key folding that makes padded-E sweeps exact);
    prefer the :class:`LeakageModel` protocol.
    """
    return _ANALYTIC.sample_leakage(key, p_tx, dist_tx_e, decoy_p,
                                    decoy_dist_e, q_e, delta, o)


# ---------------------------------------------------------------------------
# Corollaries: closed-form optimal powers
# ---------------------------------------------------------------------------


def optimal_powers_single_decoy(
    bits: Array,  # Gamma(z_k) in bits
    dist_tx_rx: Array,  # m_{s_k, s_{k+1}}
    dist_tx_decoy: Array,  # m_{s_k, d}: decoy interference distance AT THE RECEIVER
    b_t: Array,  # time budget B_T
    b_e: Array,  # energy budget B_E
    net: NetworkConfig,
) -> Tuple[Array, Array]:
    """Corollary 1 (|D|=1): returns (p_s*, p_d*).

    xi_0 p_s - xi_d p_d = chi_1 (rate constraint tight)
    p_s + p_d = chi_2 = B_E / B_T (energy tight)

    When the energy budget is tight (xi_0 chi_2 < chi_1) the unclamped
    interior solution would assign the decoy NEGATIVE power; physical
    powers are non-negative, so the decoy is clamped to 0 and the whole
    budget goes to the trainer (the rate constraint is then best-effort
    infeasible either way). The energy identity p_s + p_d = chi_2 holds
    in both regimes.
    """
    o = net.rayleigh_o
    snr_req = 2.0 ** (bits / (b_t * net.bandwidth_hz)) - 1.0
    xi0 = o / dist_tx_rx**2
    xid = (o / dist_tx_decoy**2) * snr_req
    chi1 = net.noise_w * snr_req
    chi2 = b_e / b_t
    p_d = jnp.maximum((xi0 * chi2 - chi1) / (xi0 + xid), 0.0)
    # equals (chi1 + xid*chi2)/(xi0 + xid) in the interior regime
    p_s = chi2 - p_d
    return p_s, p_d


def optimal_powers_single_eave(
    bits: Array,
    dist_tx_rx: Array,
    decoy_dist_e: Array,  # (D,) decoy -> eavesdropper distances
    b_t: Array,
    b_e: Array,
    net: NetworkConfig,
) -> Tuple[Array, Array]:
    """Corollary 2 (|E|=1, decoy interference at the receiver ignored):
    returns (p_s*, p_d* (D,)).

    Clamped to physical powers: if the rate constraint alone demands more
    than the whole energy budget (chi_1/xi_0 > chi_2) the trainer gets the
    full budget and the decoys 0, instead of the unclamped solution's
    negative decoy powers.
    """
    o = net.rayleigh_o
    snr_req = 2.0 ** (bits / (b_t * net.bandwidth_hz)) - 1.0
    xi0 = o / dist_tx_rx**2
    chi1 = net.noise_w * snr_req
    chi2 = b_e / b_t
    p_s = jnp.minimum(chi1 / xi0, chi2)
    # water-levelling: equalize p_d m_{d,e}^-2 across decoys (Eq. 47-50)
    budget = jnp.maximum(chi2 - p_s, 0.0)
    denom = jnp.sum(decoy_dist_e**2)
    p_d = budget * decoy_dist_e**2 / jnp.maximum(denom, 1e-30)
    return p_s, p_d
