"""Eavesdropper & leakage model: Eq. 12-13, Theorem 1, Corollaries 1-2.

All expressions follow the paper exactly:
  * an eavesdropper locks onto the max-SNR signal among {trainer} U decoys
    (Eq. 12) under Rayleigh fading, giving capture probability
      P(e captures trainer) = prod_d  p_s m_s,e^-2 / (p_d m_d,e^-2 + p_s m_s,e^-2)
    (Theorem 1 / Eq. 37);
  * expected leakage of one hop = sum_e P_capture(e) * q_e * delta (Eq. 30);
  * closed-form optimal powers for |D|=1 (Corollary 1) and |E|=1
    (Corollary 2).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.channel import NetworkConfig, channel_gain

Array = jax.Array


def capture_probability(
    p_tx: Array,  # scalar trainer power
    dist_tx_e: Array,  # (E,) trainer -> eavesdropper distances
    decoy_p: Array,  # (U,) decoy powers (0 for non-decoys)
    decoy_dist_e: Array,  # (U, E) decoy -> eavesdropper distances
    o: float = 1.0,
) -> Array:
    """Theorem 1 product term, per eavesdropper. Returns (E,)."""
    s_tx = p_tx * channel_gain(dist_tx_e, o)  # (E,)
    s_d = decoy_p[:, None] * channel_gain(decoy_dist_e, o)  # (U, E)
    # P(S_d < S_tx) per decoy; inactive decoys (p=0) contribute factor 1
    frac = s_tx[None, :] / jnp.maximum(s_d + s_tx[None, :], 1e-30)  # (U, E)
    frac = jnp.where(decoy_p[:, None] > 0, frac, 1.0)
    return jnp.prod(frac, axis=0)  # (E,)


def expected_leakage(
    p_tx: Array,
    dist_tx_e: Array,
    decoy_p: Array,
    decoy_dist_e: Array,
    q_e: Array,  # (E,) monitoring probabilities
    delta: Array,  # scalar information value of this hop
    o: float = 1.0,
) -> Array:
    """Eq. 30: E[I] for one hop."""
    cap = capture_probability(p_tx, dist_tx_e, decoy_p, decoy_dist_e, o)
    return jnp.sum(cap * q_e) * delta


def sample_leakage(
    key,
    p_tx: Array,
    dist_tx_e: Array,
    decoy_p: Array,
    decoy_dist_e: Array,
    q_e: Array,
    delta: Array,
    o: float = 1.0,
) -> Array:
    """Monte-Carlo single-draw leakage (Eqs. 12-13, 20-21): sample Rayleigh
    SNRs, pick the argmax per eavesdropper, sample the monitoring Bernoulli."""
    ke, kq = jax.random.split(key)
    e = dist_tx_e.shape[0]
    u = decoy_p.shape[0]
    # Rayleigh power ~ Exponential(mean = p h): sample via -mean*log(U)
    un = jax.random.uniform(ke, (u + 1, e), minval=1e-12, maxval=1.0)
    mean_tx = p_tx * channel_gain(dist_tx_e, o)  # (E,)
    mean_d = decoy_p[:, None] * channel_gain(decoy_dist_e, o)  # (U, E)
    means = jnp.concatenate([mean_tx[None, :], mean_d], axis=0)  # (U+1, E)
    snr = -means * jnp.log(un)
    captured = jnp.argmax(snr, axis=0) == 0  # (E,) trainer had max SNR
    monitored = jax.random.uniform(kq, (e,)) < q_e
    return jnp.sum(captured & monitored) * delta


# ---------------------------------------------------------------------------
# Corollaries: closed-form optimal powers
# ---------------------------------------------------------------------------


def optimal_powers_single_decoy(
    bits: Array,  # Gamma(z_k) in bits
    dist_tx_rx: Array,  # m_{s_k, s_{k+1}}
    dist_tx_decoy: Array,  # m_{s_k, d}: decoy interference distance AT THE RECEIVER
    b_t: Array,  # time budget B_T
    b_e: Array,  # energy budget B_E
    net: NetworkConfig,
) -> Tuple[Array, Array]:
    """Corollary 1 (|D|=1): returns (p_s*, p_d*).

    xi_0 p_s - xi_d p_d = chi_1 (rate constraint tight)
    p_s + p_d = chi_2 = B_E / B_T (energy tight)
    """
    o = net.rayleigh_o
    snr_req = 2.0 ** (bits / (b_t * net.bandwidth_hz)) - 1.0
    xi0 = o / dist_tx_rx**2
    xid = (o / dist_tx_decoy**2) * snr_req
    chi1 = net.noise_w * snr_req
    chi2 = b_e / b_t
    p_s = (chi1 + xid * chi2) / (xi0 + xid)
    p_d = (xi0 * chi2 - chi1) / (xi0 + xid)
    return p_s, p_d


def optimal_powers_single_eave(
    bits: Array,
    dist_tx_rx: Array,
    decoy_dist_e: Array,  # (D,) decoy -> eavesdropper distances
    b_t: Array,
    b_e: Array,
    net: NetworkConfig,
) -> Tuple[Array, Array]:
    """Corollary 2 (|E|=1, decoy interference at the receiver ignored):
    returns (p_s*, p_d* (D,))."""
    o = net.rayleigh_o
    snr_req = 2.0 ** (bits / (b_t * net.bandwidth_hz)) - 1.0
    xi0 = o / dist_tx_rx**2
    chi1 = net.noise_w * snr_req
    chi2 = b_e / b_t
    p_s = chi1 / xi0
    # water-levelling: equalize p_d m_{d,e}^-2 across decoys (Eq. 47-50)
    budget = chi2 - p_s
    denom = jnp.sum(decoy_dist_e**2)
    p_d = budget * decoy_dist_e**2 / jnp.maximum(denom, 1e-30)
    return p_s, p_d
