"""Factored discrete action space with masking (paper's action-mask algorithm [30]).

Heads: u (categorical U), size (categorical NBINS), decoys (U binary),
p_tx / p_d (categorical over power levels). Joint log-prob / entropy are
sums over heads; invalid entries are masked to -inf before sampling.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

NEG = -1e9


def masked_logits(logits: Dict[str, jax.Array], masks: Dict[str, jax.Array]):
    out = {}
    out["u"] = jnp.where(masks["u"], logits["u"], NEG)
    out["size"] = jnp.where(masks["size"], logits["size"], NEG)
    # decoys: (..., U, 2); masking the 'on' column forces 'off'
    dec = logits["decoys"]
    off_on = jnp.stack([jnp.zeros_like(masks["decoys"], jnp.float32),
                        jnp.where(masks["decoys"], 0.0, NEG)], axis=-1)
    out["decoys"] = dec + off_on
    out["p_tx"] = jnp.where(masks["p_tx"], logits["p_tx"], NEG)
    out["p_d"] = jnp.where(masks["p_d"], logits["p_d"], NEG)
    return out


def sample(key, logits: Dict[str, jax.Array]):
    ks = jax.random.split(key, 5)
    return {
        "u": jax.random.categorical(ks[0], logits["u"]),
        "size": jax.random.categorical(ks[1], logits["size"]),
        "decoys": jax.random.categorical(ks[2], logits["decoys"], axis=-1),
        "p_tx": jax.random.categorical(ks[3], logits["p_tx"]),
        "p_d": jax.random.categorical(ks[4], logits["p_d"]),
    }


def _cat_logp(logits, idx):
    lp = jax.nn.log_softmax(logits, axis=-1)
    return jnp.take_along_axis(lp, idx[..., None].astype(jnp.int32), axis=-1)[..., 0]


def log_prob(logits: Dict[str, jax.Array], action: Dict[str, jax.Array]):
    lp = _cat_logp(logits["u"], action["u"])
    lp += _cat_logp(logits["size"], action["size"])
    lp += _cat_logp(logits["decoys"], action["decoys"]).sum(-1)
    lp += _cat_logp(logits["p_tx"], action["p_tx"])
    lp += _cat_logp(logits["p_d"], action["p_d"])
    return lp


def _cat_entropy(logits):
    lp = jax.nn.log_softmax(logits, axis=-1)
    p = jnp.exp(lp)
    return -(p * jnp.where(p > 0, lp, 0.0)).sum(-1)


def entropy(logits: Dict[str, jax.Array]):
    h = _cat_entropy(logits["u"])
    h += _cat_entropy(logits["size"])
    h += _cat_entropy(logits["decoys"]).sum(-1)
    h += _cat_entropy(logits["p_tx"])
    h += _cat_entropy(logits["p_d"])
    return h


def log_prob_entropy(logits: Dict[str, jax.Array], action: Dict[str, jax.Array]):
    """Joint (log_prob, entropy) sharing one log_softmax per head.

    ``log_prob`` and ``entropy`` each normalize every head; actor losses
    need both, so the separate calls ran log_softmax twice per head. The
    shared normalization is bit-identical to the separate calls (same ops
    on the same inputs) at half the softmax work.
    """
    lp_total = None
    ent_total = None
    for name in ("u", "size", "decoys", "p_tx", "p_d"):
        lp = jax.nn.log_softmax(logits[name], axis=-1)
        idx = action[name][..., None].astype(jnp.int32)
        head_lp = jnp.take_along_axis(lp, idx, axis=-1)[..., 0]
        p = jnp.exp(lp)
        head_ent = -(p * jnp.where(p > 0, lp, 0.0)).sum(-1)
        if name == "decoys":
            head_lp = head_lp.sum(-1)
            head_ent = head_ent.sum(-1)
        lp_total = head_lp if lp_total is None else lp_total + head_lp
        ent_total = head_ent if ent_total is None else ent_total + head_ent
    return lp_total, ent_total


def onehot(action: Dict[str, jax.Array], dims: Dict[str, int]):
    """Flatten an action into a single one-hot feature vector b(n)."""
    parts = [
        jax.nn.one_hot(action["u"], dims["u"]),
        jax.nn.one_hot(action["size"], dims["size"]),
        action["decoys"].astype(jnp.float32),
        jax.nn.one_hot(action["p_tx"], dims["p_tx"]),
        jax.nn.one_hot(action["p_d"], dims["p_d"]),
    ]
    return jnp.concatenate(parts, axis=-1)


def flat_dim(dims: Dict[str, int]) -> int:
    return dims["u"] + dims["size"] + dims["decoys"] + dims["p_tx"] + dims["p_d"]
