"""Replay buffer (host numpy, circular).

The trainers now use the device-resident buffer in
``repro.core.agents.rollout`` (``buffer_init``/``buffer_add``/
``buffer_sample``); this host implementation is kept as the reference for
the buffer-parity test and the throughput baseline."""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import numpy as np


class ReplayBuffer:
    def __init__(self, capacity: int, example: Dict[str, np.ndarray]):
        self.capacity = capacity
        self.size = 0
        self.ptr = 0
        self.store = {}
        for k, v in example.items():
            if isinstance(v, dict):
                self.store[k] = {
                    kk: np.zeros((capacity,) + np.shape(vv), np.asarray(vv).dtype)
                    for kk, vv in v.items()
                }
            else:
                self.store[k] = np.zeros((capacity,) + np.shape(v), np.asarray(v).dtype)

    def add(self, item: Dict):
        i = self.ptr
        for k, v in item.items():
            if isinstance(v, dict):
                for kk, vv in v.items():
                    self.store[k][kk][i] = np.asarray(vv)
            else:
                self.store[k][i] = np.asarray(v)
        self.ptr = (self.ptr + 1) % self.capacity
        self.size = min(self.size + 1, self.capacity)

    def sample(self, rng: np.random.Generator, batch: int) -> Dict:
        idx = rng.integers(0, self.size, size=batch)

        def take(v):
            if isinstance(v, dict):
                return {kk: jnp.asarray(vv[idx]) for kk, vv in v.items()}
            return jnp.asarray(v[idx])

        return {k: take(v) for k, v in self.store.items()}
