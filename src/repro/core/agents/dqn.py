"""DQN baseline (paper baseline d, [35]).

Q-learning needs a FLAT discrete action space; the factored MHSL action
space is flattened over (u, size, p_tx, p_d) and the decoy subset is fixed
to the heuristic "all eligible devices" (the paper itself notes Q-learning
struggles as the space grows - this mirrors that constraint honestly).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.agents.buffer import ReplayBuffer
from repro.core.env import MHSLEnv, NBINS
from repro.nn import init_mlp, mlp_apply
from repro.optim import adamw
from repro.optim.optimizers import apply_updates


@dataclass(frozen=True)
class DQNConfig:
    hidden: int = 128
    gamma: float = 0.95
    lr: float = 3e-4
    eps_start: float = 1.0
    eps_end: float = 0.05
    eps_decay_episodes: int = 100
    batch: int = 128
    buffer_size: int = 50_000
    target_update: int = 200  # gradient steps between target syncs


def flat_dims(env: MHSLEnv):
    return (env.U, NBINS, env.num_power_levels, env.num_power_levels)


def unflatten_action(idx, env: MHSLEnv, masks):
    u_n, s_n, p_n, _ = flat_dims(env)
    u = idx // (s_n * p_n * p_n)
    rem = idx % (s_n * p_n * p_n)
    size = rem // (p_n * p_n)
    rem = rem % (p_n * p_n)
    p_tx = rem // p_n
    p_d = rem % p_n
    return {
        "u": u.astype(jnp.int32),
        "size": size.astype(jnp.int32),
        "decoys": masks["decoys"].astype(jnp.int32),  # heuristic: all eligible
        "p_tx": p_tx.astype(jnp.int32),
        "p_d": p_d.astype(jnp.int32),
    }


def flat_mask(env: MHSLEnv, masks):
    u_n, s_n, p_n, _ = flat_dims(env)
    m = (
        masks["u"][:, None, None, None]
        & masks["size"][None, :, None, None]
        & masks["p_tx"][None, None, :, None]
        & masks["p_d"][None, None, None, :]
    )
    return m.reshape(-1)


def train_dqn(env: MHSLEnv, cfg: DQNConfig, episodes: int = 200, seed: int = 0):
    from repro.core.agents.loops import TrainResult, _obs_hash

    key = jax.random.PRNGKey(seed)
    rng = np.random.default_rng(seed)
    n_actions = int(np.prod(flat_dims(env)))
    key, k0 = jax.random.split(key)
    params = init_mlp(k0, [env.obs_dim, cfg.hidden, cfg.hidden, n_actions])
    target = jax.tree.map(jnp.copy, params)
    opt = adamw(cfg.lr)
    opt_state = opt.init(params)

    env_step = jax.jit(env.step)
    env_observe = jax.jit(env.observe)
    env_masks = jax.jit(env.action_masks)

    @jax.jit
    def q_values(params, obs):
        return mlp_apply(params, obs)

    @jax.jit
    def update(params, target, opt_state, batch):
        def loss_fn(params):
            q = mlp_apply(params, batch["obs"])
            qa = jnp.take_along_axis(q, batch["a"][:, None], axis=1)[:, 0]
            qn = mlp_apply(target, batch["obs_next"])
            qn = jnp.where(batch["mask_next"] > 0, qn, -1e9).max(-1)
            tgt = batch["reward"] + cfg.gamma * (1 - batch["done"]) * qn
            return jnp.mean((qa - jax.lax.stop_gradient(tgt)) ** 2)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        ups, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, ups), opt_state, loss

    result = TrainResult()
    seen = set()
    key, reset_key = jax.random.split(key)
    grad_steps = 0
    buf = None
    for ep in range(episodes):
        st = env.reset(reset_key)
        eps = max(
            cfg.eps_end,
            cfg.eps_start
            - (cfg.eps_start - cfg.eps_end) * ep / max(cfg.eps_decay_episodes, 1),
        )
        ep_r = ep_leak = ep_viol = 0.0
        for t in range(env.episode_len):
            obs = env_observe(st)
            masks = env_masks(st)
            seen.add(_obs_hash(obs))
            fm = flat_mask(env, masks)
            key, ka, ks = jax.random.split(key, 3)
            if rng.random() < eps:
                valid = np.flatnonzero(np.asarray(fm))
                a_idx = int(rng.choice(valid))
            else:
                q = q_values(params, obs)
                a_idx = int(jnp.argmax(jnp.where(fm, q, -1e9)))
            action = unflatten_action(jnp.asarray(a_idx), env, masks)
            st2, r, done, info = env_step(st, action, ks)
            obs2 = env_observe(st2)
            fm2 = flat_mask(env, env_masks(st2))
            item = dict(
                obs=np.asarray(obs, np.float32),
                obs_next=np.asarray(obs2, np.float32),
                a=np.int32(a_idx),
                mask_next=np.asarray(fm2, np.float32),
                reward=np.float32(r),
                done=np.float32(done),
            )
            if buf is None:
                buf = ReplayBuffer(cfg.buffer_size, item)
            buf.add(item)
            ep_r += float(r)
            ep_leak += float(info["leak"])
            ep_viol += float((st2.e_r <= 0) | (st2.t_r <= 0))
            st = st2
            if buf.size >= cfg.batch:
                batch = buf.sample(rng, cfg.batch)
                params, opt_state, loss = update(params, target, opt_state, batch)
                grad_steps += 1
                if grad_steps % cfg.target_update == 0:
                    target = jax.tree.map(jnp.copy, params)
        result.episode_reward.append(ep_r)
        result.episode_leak.append(ep_leak)
        result.episode_violation.append(ep_viol)
        result.states_explored.append(len(seen))
    result.params = params  # type: ignore[attr-defined]
    return result
