"""DQN baseline (paper baseline d, [35]).

Q-learning needs a FLAT discrete action space; the factored MHSL action
space is flattened over (u, size, p_tx, p_d) and the decoy subset is fixed
to the heuristic "all eligible devices" (the paper itself notes Q-learning
struggles as the space grows - this mirrors that constraint honestly).

Training runs on the shared device-resident rollout engine
(``repro.core.agents.rollout``): epsilon-greedy action selection happens on
device inside the scanned rollout, transitions land in the device replay
buffer, and each chunk's gradient steps (with periodic target-network
syncs) run in one fused ``lax.scan``.

The update is already single-backward with no duplicated forwards (one
Q forward on ``obs`` with grad, one target forward on ``obs_next``
without), so the SAC joint-update restructure has nothing to fuse here;
the flat action mask is likewise computed once per step in the policy
and reused as ``mask_next`` by shifting the trajectory.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.agents import action_space as A
from repro.core.agents import rollout as R
from repro.core.env import MHSLEnv, NBINS
from repro.nn import init_mlp, mlp_apply
from repro.optim import adamw
from repro.optim.optimizers import apply_updates


@dataclass(frozen=True)
class DQNConfig:
    hidden: int = 128
    gamma: float = 0.95
    lr: float = 3e-4
    eps_start: float = 1.0
    eps_end: float = 0.05
    eps_decay_episodes: int = 100
    batch: int = 128
    buffer_size: int = 50_000
    target_update: int = 200  # gradient steps between target syncs


def flat_dims(env: MHSLEnv):
    return (env.U, NBINS, env.num_power_levels, env.num_power_levels)


def unflatten_action(idx, env: MHSLEnv, masks):
    u_n, s_n, p_n, _ = flat_dims(env)
    u = idx // (s_n * p_n * p_n)
    rem = idx % (s_n * p_n * p_n)
    size = rem // (p_n * p_n)
    rem = rem % (p_n * p_n)
    p_tx = rem // p_n
    p_d = rem % p_n
    return {
        "u": u.astype(jnp.int32),
        "size": size.astype(jnp.int32),
        "decoys": masks["decoys"].astype(jnp.int32),  # heuristic: all eligible
        "p_tx": p_tx.astype(jnp.int32),
        "p_d": p_d.astype(jnp.int32),
    }


def flat_mask(env: MHSLEnv, masks):
    u_n, s_n, p_n, _ = flat_dims(env)
    m = (
        masks["u"][:, None, None, None]
        & masks["size"][None, :, None, None]
        & masks["p_tx"][None, None, :, None]
        & masks["p_d"][None, None, None, :]
    )
    return m.reshape(-1)


def _dqn_policy(env: MHSLEnv) -> R.Policy:
    """Device-side epsilon-greedy over the flat masked action space.

    ``params`` is a bundle ``{"q": q_net_params, "eps": scalar}`` so the
    decayed epsilon flows through the jitted rollout as a traced value
    (no recompile per episode)."""

    def policy(bundle, key, obs, hist, hist_mask, masks):
        fm = flat_mask(env, masks)
        q = mlp_apply(bundle["q"], obs)
        k_explore, k_rand = jax.random.split(key)
        rand_a = jax.random.categorical(k_rand, jnp.where(fm, 0.0, A.NEG))
        greedy_a = jnp.argmax(jnp.where(fm, q, A.NEG))
        explore = jax.random.uniform(k_explore) < bundle["eps"]
        a_idx = jnp.where(explore, rand_a, greedy_a).astype(jnp.int32)
        # fm is recorded so mask_next can be derived by shifting the
        # trajectory instead of recomputing every mask a second time
        return unflatten_action(a_idx, env, masks), {
            "a": a_idx, "fm": fm.astype(jnp.float32)
        }

    return policy


_DQN_FIELDS = ("obs", "obs_next", "a", "mask_next", "reward", "done")


def _dqn_example(env: MHSLEnv, n_actions: int):
    return dict(
        obs=jnp.zeros((env.obs_dim,), jnp.float32),
        obs_next=jnp.zeros((env.obs_dim,), jnp.float32),
        a=jnp.zeros((), jnp.int32),
        mask_next=jnp.zeros((n_actions,), jnp.float32),
        reward=jnp.zeros((), jnp.float32),
        done=jnp.zeros((), jnp.float32),
    )


def _make_dqn_update(cfg: DQNConfig, opt):
    """One Q-learning step in the engine's ``update_fn`` signature.

    The "params" slot carries ``{"q", "target", "gs"}`` so the periodic
    target sync and gradient-step counter thread through
    ``rollout.make_fused_update``'s scan unchanged."""

    def update_fn(bundle, opt_state, batch):
        params, target = bundle["q"], bundle["target"]

        def loss_fn(params):
            q = mlp_apply(params, batch["obs"])
            qa = jnp.take_along_axis(q, batch["a"][:, None], axis=1)[:, 0]
            qn = mlp_apply(target, batch["obs_next"])
            qn = jnp.where(batch["mask_next"] > 0, qn, A.NEG).max(-1)
            tgt = batch["reward"] + cfg.gamma * (1 - batch["done"]) * qn
            return jnp.mean((qa - jax.lax.stop_gradient(tgt)) ** 2)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        ups, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, ups)
        gs = bundle["gs"] + 1
        sync = (gs % cfg.target_update) == 0
        target = jax.tree.map(lambda t, p: jnp.where(sync, p, t), target, params)
        return {"q": params, "target": target, "gs": gs}, opt_state, loss

    return update_fn


def train_dqn(env: MHSLEnv, cfg: DQNConfig, episodes: int = 200, seed: int = 0,
              num_envs: int = 1, scenario=None):
    """``scenario`` (``ScenarioParams``) overrides the env physics as a
    runtime value - sweep points share the jit caches of this call."""
    from repro.core.agents.loops import TrainResult, _chunk_metrics

    if num_envs < 1:
        raise ValueError(f"num_envs must be >= 1, got {num_envs}")
    key = jax.random.PRNGKey(seed)
    n_actions = int(np.prod(flat_dims(env)))
    key, k0 = jax.random.split(key)
    params = init_mlp(k0, [env.obs_dim, cfg.hidden, cfg.hidden, n_actions])
    target = jax.tree.map(jnp.copy, params)
    opt = adamw(cfg.lr)
    opt_state = opt.init(params)

    rollout = R.make_batched_rollout(env, _dqn_policy(env), hist_len=1)
    # mask_next[t] = fm[t+1]; only the post-episode state needs a fresh mask
    final_mask = jax.jit(jax.vmap(
        lambda st: flat_mask(env, env.action_masks(st)).astype(jnp.float32)
    ))
    reset_batch = R.make_batched_reset(env)
    buf = R.buffer_init(cfg.buffer_size, _dqn_example(env, n_actions))
    # one gradient step per env step, as in the seed loop
    n_updates = env.episode_len * num_envs
    fused_update = R.make_fused_update(_make_dqn_update(cfg, opt), cfg.batch,
                                       n_updates)
    learner = {"q": params, "target": target, "gs": jnp.zeros((), jnp.int32)}

    result = TrainResult()
    seen: set = set()
    key, reset_key = jax.random.split(key)

    ep = 0
    while ep < episodes:
        eps = max(
            cfg.eps_end,
            cfg.eps_start
            - (cfg.eps_start - cfg.eps_end) * ep / max(cfg.eps_decay_episodes, 1),
        )
        rkeys = R.episode_reset_keys(reset_key, num_envs, resample=False)
        key, ksub = jax.random.split(key)
        akeys = jax.random.split(ksub, num_envs)

        st0 = reset_batch(rkeys, scenario)
        bundle = {"q": learner["q"], "eps": jnp.asarray(eps, jnp.float32)}
        st_final, traj = rollout(bundle, st0, akeys, scenario)
        traj["mask_next"] = jnp.concatenate(
            [traj["fm"][:, 1:], final_mask(st_final)[:, None]], axis=1
        )

        buf = R.buffer_add(buf, R.flatten_transitions(traj, _DQN_FIELDS))
        _chunk_metrics(result, seen, traj, ep, episodes, num_envs)

        if int(buf.size) >= cfg.batch:
            key, ku = jax.random.split(key)
            learner, opt_state, _ = fused_update(learner, opt_state, buf, ku)
        ep += num_envs

    result.params = learner["q"]  # type: ignore[attr-defined]
    return result
