"""PPO baseline (paper baseline c, [34]).

Standard clipped-objective PPO over the same factored masked action space,
actor on the raw state (no CA, no ICM), V critic with GAE.

Rollouts run on the shared device-resident engine
(``repro.core.agents.rollout``): each chunk of ``num_envs`` episodes is one
vmapped ``lax.scan`` that also records per-step log-probs and values, GAE
runs as a vmapped reverse scan on device, and the ``epochs`` policy updates
over each collected batch run inside a single jitted scan.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.agents import action_space as A
from repro.core.agents import rollout as R
from repro.core.agents.icm import sum_head_dims
from repro.core.agents.sac import _split_heads
from repro.core.env import MHSLEnv
from repro.nn import init_mlp, mlp_apply
from repro.optim import adamw
from repro.optim.optimizers import apply_updates


@dataclass(frozen=True)
class PPOConfig:
    hidden: int = 128
    gamma: float = 0.95
    lam: float = 0.95
    clip: float = 0.2
    lr: float = 3e-4
    entropy_coef: float = 0.01
    value_coef: float = 0.5
    episodes_per_batch: int = 8
    epochs: int = 4


def init_ppo(key, obs_dim: int, action_dims: Dict[str, int], cfg: PPOConfig):
    k1, k2 = jax.random.split(key)
    return {
        "actor": init_mlp(k1, [obs_dim, cfg.hidden, cfg.hidden, sum_head_dims(action_dims)]),
        "critic": init_mlp(k2, [obs_dim, cfg.hidden, cfg.hidden, 1]),
    }


def ppo_logits(params, obs, masks, action_dims):
    raw = mlp_apply(params["actor"], obs)
    return A.masked_logits(_split_heads(raw, action_dims), masks)


def ppo_policy(action_dims: Dict[str, int]) -> R.Policy:
    """Sampling policy that also records log-prob and value per step."""

    def policy(params, key, obs, hist, hist_mask, masks):
        logits = ppo_logits(params, obs, masks, action_dims)
        action = A.sample(key, logits)
        lp = A.log_prob(logits, action)
        v = mlp_apply(params["critic"], obs)[..., 0]
        return action, {"logp": lp, "v": v}

    return policy


def make_ppo_update(action_dims, cfg: PPOConfig):
    opt = adamw(cfg.lr)

    def loss_fn(params, batch):
        logits = ppo_logits(params, batch["obs"], batch["masks"], action_dims)
        # shared per-head log_softmax for log-prob AND entropy (the
        # separate A.log_prob/A.entropy calls normalized every head twice)
        lp, ent = A.log_prob_entropy(logits, batch["action"])
        ratio = jnp.exp(lp - batch["logp_old"])
        adv = batch["adv"]
        unclipped = ratio * adv
        clipped = jnp.clip(ratio, 1 - cfg.clip, 1 + cfg.clip) * adv
        pg = -jnp.mean(jnp.minimum(unclipped, clipped))
        v = mlp_apply(params["critic"], batch["obs"])[..., 0]
        vloss = jnp.mean((batch["ret"] - v) ** 2)
        ent = jnp.mean(ent)
        return pg + cfg.value_coef * vloss - cfg.entropy_coef * ent, (pg, vloss, ent)

    @jax.jit
    def update(params, opt_state, batch):
        (loss, auxs), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        ups, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, ups)
        return params, opt_state, {"loss": loss, "pg": auxs[0], "v": auxs[1], "ent": auxs[2]}

    return update, opt.init


_PPO_FIELDS = ("obs", "masks", "action", "logp", "adv", "ret")


def train_ppo(env: MHSLEnv, cfg: PPOConfig, episodes: int = 200, seed: int = 0,
              num_envs: int = 1, scenario=None):
    """``scenario`` (``ScenarioParams``) overrides the env physics as a
    runtime value - sweep points share the jit caches of this call."""
    from repro.core.agents.loops import TrainResult, _chunk_metrics

    if num_envs < 1:
        raise ValueError(f"num_envs must be >= 1, got {num_envs}")
    key = jax.random.PRNGKey(seed)
    adims = env.action_dims
    key, k0 = jax.random.split(key)
    params = init_ppo(k0, env.obs_dim, adims, cfg)
    update, opt_init = make_ppo_update(adims, cfg)
    opt_state = opt_init(params)

    rollout = R.make_batched_rollout(env, ppo_policy(adims), hist_len=1)
    reset_batch = R.make_batched_reset(env)
    gae_batch = jax.jit(jax.vmap(
        lambda r, v: R.gae(r, v, cfg.gamma, cfg.lam)
    ))
    run_epochs = R.make_scan_updates(update, cfg.epochs)
    # normalize advantages over the whole collected batch (seed behaviour)
    norm_adv = jax.jit(
        lambda a: (a - a.mean()) / (a.std() + 1e-6)
    )

    result = TrainResult()
    seen: set = set()
    key, reset_key = jax.random.split(key)
    pending = []  # flattened chunk batches awaiting a policy update
    pending_eps = 0

    ep = 0
    while ep < episodes:
        rkeys = R.episode_reset_keys(reset_key, num_envs, resample=False)
        key, ksub = jax.random.split(key)
        akeys = jax.random.split(ksub, num_envs)

        st0 = reset_batch(rkeys, scenario)
        _, traj = rollout(params, st0, akeys, scenario)
        adv, ret = gae_batch(traj["reward"], traj["v"])
        traj = dict(traj, adv=adv, ret=ret)

        pending.append(R.flatten_transitions(traj, _PPO_FIELDS))
        pending_eps += num_envs
        _chunk_metrics(result, seen, traj, ep, episodes, num_envs)

        if pending_eps >= cfg.episodes_per_batch:
            batch = jax.tree.map(lambda *xs: jnp.concatenate(xs), *pending)
            batch["logp_old"] = batch.pop("logp")
            batch["adv"] = norm_adv(batch["adv"])
            params, opt_state, _ = run_epochs(params, opt_state, batch)
            pending, pending_eps = [], 0
        ep += num_envs

    result.params = params  # type: ignore[attr-defined]
    return result
