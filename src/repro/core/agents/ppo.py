"""PPO baseline (paper baseline c, [34]).

Standard clipped-objective PPO over the same factored masked action space,
actor on the raw state (no CA, no ICM), V critic with GAE.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.agents import action_space as A
from repro.core.agents.icm import sum_head_dims
from repro.core.agents.sac import _split_heads
from repro.core.env import MHSLEnv
from repro.nn import init_mlp, mlp_apply
from repro.optim import adamw
from repro.optim.optimizers import apply_updates


@dataclass(frozen=True)
class PPOConfig:
    hidden: int = 128
    gamma: float = 0.95
    lam: float = 0.95
    clip: float = 0.2
    lr: float = 3e-4
    entropy_coef: float = 0.01
    value_coef: float = 0.5
    episodes_per_batch: int = 8
    epochs: int = 4


def init_ppo(key, obs_dim: int, action_dims: Dict[str, int], cfg: PPOConfig):
    k1, k2 = jax.random.split(key)
    return {
        "actor": init_mlp(k1, [obs_dim, cfg.hidden, cfg.hidden, sum_head_dims(action_dims)]),
        "critic": init_mlp(k2, [obs_dim, cfg.hidden, cfg.hidden, 1]),
    }


def ppo_logits(params, obs, masks, action_dims):
    raw = mlp_apply(params["actor"], obs)
    return A.masked_logits(_split_heads(raw, action_dims), masks)


def make_ppo_update(action_dims, cfg: PPOConfig):
    opt = adamw(cfg.lr)

    def loss_fn(params, batch):
        logits = ppo_logits(params, batch["obs"], batch["masks"], action_dims)
        lp = A.log_prob(logits, batch["action"])
        ratio = jnp.exp(lp - batch["logp_old"])
        adv = batch["adv"]
        unclipped = ratio * adv
        clipped = jnp.clip(ratio, 1 - cfg.clip, 1 + cfg.clip) * adv
        pg = -jnp.mean(jnp.minimum(unclipped, clipped))
        v = mlp_apply(params["critic"], batch["obs"])[..., 0]
        vloss = jnp.mean((batch["ret"] - v) ** 2)
        ent = jnp.mean(A.entropy(logits))
        return pg + cfg.value_coef * vloss - cfg.entropy_coef * ent, (pg, vloss, ent)

    @jax.jit
    def update(params, opt_state, batch):
        (loss, auxs), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        ups, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, ups)
        return params, opt_state, {"loss": loss, "pg": auxs[0], "v": auxs[1], "ent": auxs[2]}

    return update, opt.init


def train_ppo(env: MHSLEnv, cfg: PPOConfig, episodes: int = 200, seed: int = 0):
    from repro.core.agents.loops import TrainResult, _obs_hash

    key = jax.random.PRNGKey(seed)
    adims = env.action_dims
    key, k0 = jax.random.split(key)
    params = init_ppo(k0, env.obs_dim, adims, cfg)
    update, opt_init = make_ppo_update(adims, cfg)
    opt_state = opt_init(params)

    env_step = jax.jit(env.step)
    env_observe = jax.jit(env.observe)
    env_masks = jax.jit(env.action_masks)

    @jax.jit
    def act(params, key, obs, masks):
        logits = ppo_logits(params, obs, masks, adims)
        action = A.sample(key, logits)
        lp = A.log_prob(logits, action)
        v = mlp_apply(params["critic"], obs)[..., 0]
        return action, lp, v

    result = TrainResult()
    seen = set()
    key, reset_key = jax.random.split(key)
    traj = []
    for ep in range(episodes):
        st = env.reset(reset_key)
        ep_r = ep_leak = ep_viol = 0.0
        rows = []
        for t in range(env.episode_len):
            obs = env_observe(st)
            masks = env_masks(st)
            seen.add(_obs_hash(obs))
            key, ka, ks = jax.random.split(key, 3)
            action, lp, v = act(params, ka, obs, masks)
            st2, r, done, info = env_step(st, action, ks)
            rows.append(
                dict(obs=np.asarray(obs), masks={k: np.asarray(m) for k, m in masks.items()},
                     action={k: np.asarray(v_) for k, v_ in action.items()},
                     logp_old=float(lp), v=float(v), r=float(r), done=float(done))
            )
            ep_r += float(r)
            ep_leak += float(info["leak"])
            ep_viol += float((st2.e_r <= 0) | (st2.t_r <= 0))
            st = st2
        # GAE for this episode
        vs = np.array([row["v"] for row in rows] + [0.0])
        rs = np.array([row["r"] for row in rows])
        adv = np.zeros(len(rows))
        g = 0.0
        for t in reversed(range(len(rows))):
            delta = rs[t] + cfg.gamma * vs[t + 1] - vs[t]
            g = delta + cfg.gamma * cfg.lam * g
            adv[t] = g
        ret = adv + vs[:-1]
        for row, a_, rt in zip(rows, adv, ret):
            row["adv"] = a_
            row["ret"] = rt
        traj.extend(rows)

        result.episode_reward.append(ep_r)
        result.episode_leak.append(ep_leak)
        result.episode_violation.append(ep_viol)
        result.states_explored.append(len(seen))

        if (ep + 1) % cfg.episodes_per_batch == 0:
            batch = {
                "obs": jnp.asarray(np.stack([r_["obs"] for r_ in traj])),
                "masks": {
                    k: jnp.asarray(np.stack([r_["masks"][k] for r_ in traj]))
                    for k in traj[0]["masks"]
                },
                "action": {
                    k: jnp.asarray(np.stack([r_["action"][k] for r_ in traj]))
                    for k in traj[0]["action"]
                },
                "logp_old": jnp.asarray([r_["logp_old"] for r_ in traj]),
                "adv": jnp.asarray(
                    (np.array([r_["adv"] for r_ in traj]) - np.mean([r_["adv"] for r_ in traj]))
                    / (np.std([r_["adv"] for r_ in traj]) + 1e-6)
                ),
                "ret": jnp.asarray([r_["ret"] for r_ in traj]),
            }
            for _ in range(cfg.epochs):
                params, opt_state, m = update(params, opt_state, batch)
            traj = []

    result.params = params  # type: ignore[attr-defined]
    return result
