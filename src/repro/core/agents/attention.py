"""Cross-attention over historical state-action pairs (paper Eq. 24).

H = the last I observed (s, a) pairs; Q = W_Q [s(n); H], K = W_K H,
V = W_V H; s'(n) = softmax(QK^T / sqrt(C)) V. We return the attended
summary for the current-state query row concatenated with s(n), which is
what the actor consumes.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.nn import init_dense


def init_cross_attention(key, obs_dim: int, pair_dim: int, attn_dim: int = 64):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(pair_dim)
    return {
        "wq_s": jax.random.normal(k1, (obs_dim, attn_dim)) * (1.0 / math.sqrt(obs_dim)),
        "wq_h": jax.random.normal(k2, (pair_dim, attn_dim)) * s,
        "wk": jax.random.normal(k3, (pair_dim, attn_dim)) * s,
        "wv": jax.random.normal(k4, (pair_dim, attn_dim)) * s,
    }


def cross_attention(params, obs, history, hist_mask=None):
    """obs: (..., obs_dim); history: (..., I, pair_dim) newest-last.

    hist_mask: (..., I) 1 = valid pair. Returns (..., attn_dim + obs_dim).
    """
    q_s = obs @ params["wq_s"]  # (..., C) current-state query
    q_h = history @ params["wq_h"]  # (..., I, C) history queries (Eq. 24 Q)
    k = history @ params["wk"]
    v = history @ params["wv"]
    c = k.shape[-1]
    q = jnp.concatenate([q_s[..., None, :], q_h], axis=-2)  # (..., I+1, C)
    scores = jnp.einsum("...qc,...ic->...qi", q, k) / math.sqrt(c)
    if hist_mask is not None:
        # dtype-aware mask value: a -1e9 literal overflows fp16 to -inf
        # (NaN softmax rows once every entry is masked) and wastes bf16
        # range; finfo.min is the most-negative finite score in any dtype
        scores = jnp.where(hist_mask[..., None, :] > 0, scores,
                           jnp.finfo(scores.dtype).min)
    # guard: if no history at all, attention output is zeros
    any_valid = (
        (hist_mask.sum(-1, keepdims=True) > 0)
        if hist_mask is not None
        else jnp.ones(scores.shape[:-2] + (1,), bool)
    )
    w = jax.nn.softmax(scores, axis=-1)
    attended = jnp.einsum("...qi,...ic->...qc", w, v)
    s_prime = attended[..., 0, :]  # the current-state row
    s_prime = jnp.where(any_valid, s_prime, jnp.zeros_like(s_prime))
    return jnp.concatenate([obs, s_prime], axis=-1)


def cross_attention_slim(params, obs, history, hist_mask=None):
    """``cross_attention`` minus the dead work: only the current-state row.

    The actor consumes only ``attended[..., 0, :]``, so the ``W_Q H``
    projection and the I history-query score rows never reach the output -
    their gradients are exactly zero. This variant scores the single
    ``q_s`` row against K (one ``(..., I)`` score vector instead of the
    ``(..., I+1, I)`` matrix), same values and gradients as the reference
    for everything that survives (``wq_h``'s zero gradient included, since
    autodiff emits zeros for unused leaves). Used on the update hot path
    (``sac.joint_loss``); the full reference stays the pinned semantics
    for rollout policies and the Pallas kernel parity tests.
    """
    q_s = obs @ params["wq_s"]  # (..., C)
    k = history @ params["wk"]
    v = history @ params["wv"]
    c = k.shape[-1]
    scores = jnp.einsum("...c,...ic->...i", q_s, k) / math.sqrt(c)
    if hist_mask is not None:
        scores = jnp.where(hist_mask > 0, scores,
                           jnp.finfo(scores.dtype).min)
    any_valid = (
        (hist_mask.sum(-1, keepdims=True) > 0)
        if hist_mask is not None
        else jnp.ones(scores.shape[:-1] + (1,), bool)
    )
    w = jax.nn.softmax(scores, axis=-1)
    s_prime = jnp.einsum("...i,...ic->...c", w, v)
    s_prime = jnp.where(any_valid, s_prime, jnp.zeros_like(s_prime))
    return jnp.concatenate([obs, s_prime], axis=-1)
