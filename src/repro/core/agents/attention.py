"""Cross-attention over historical state-action pairs (paper Eq. 24).

H = the last I observed (s, a) pairs; Q = W_Q [s(n); H], K = W_K H,
V = W_V H; s'(n) = softmax(QK^T / sqrt(C)) V. We return the attended
summary for the current-state query row concatenated with s(n), which is
what the actor consumes.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.nn import init_dense


def init_cross_attention(key, obs_dim: int, pair_dim: int, attn_dim: int = 64):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(pair_dim)
    return {
        "wq_s": jax.random.normal(k1, (obs_dim, attn_dim)) * (1.0 / math.sqrt(obs_dim)),
        "wq_h": jax.random.normal(k2, (pair_dim, attn_dim)) * s,
        "wk": jax.random.normal(k3, (pair_dim, attn_dim)) * s,
        "wv": jax.random.normal(k4, (pair_dim, attn_dim)) * s,
    }


def cross_attention(params, obs, history, hist_mask=None):
    """obs: (..., obs_dim); history: (..., I, pair_dim) newest-last.

    hist_mask: (..., I) 1 = valid pair. Returns (..., attn_dim + obs_dim).
    """
    q_s = obs @ params["wq_s"]  # (..., C) current-state query
    q_h = history @ params["wq_h"]  # (..., I, C) history queries (Eq. 24 Q)
    k = history @ params["wk"]
    v = history @ params["wv"]
    c = k.shape[-1]
    q = jnp.concatenate([q_s[..., None, :], q_h], axis=-2)  # (..., I+1, C)
    scores = jnp.einsum("...qc,...ic->...qi", q, k) / math.sqrt(c)
    if hist_mask is not None:
        scores = jnp.where(hist_mask[..., None, :] > 0, scores, -1e9)
    # guard: if no history at all, attention output is zeros
    any_valid = (
        (hist_mask.sum(-1, keepdims=True) > 0)
        if hist_mask is not None
        else jnp.ones(scores.shape[:-2] + (1,), bool)
    )
    w = jax.nn.softmax(scores, axis=-1)
    attended = jnp.einsum("...qi,...ic->...qc", w, v)
    s_prime = attended[..., 0, :]  # the current-state row
    s_prime = jnp.where(any_valid, s_prime, jnp.zeros_like(s_prime))
    return jnp.concatenate([obs, s_prime], axis=-1)
