"""Device-resident vectorized rollout engine for the MHSL RL agents.

The seed trainers (``train_sac``/``train_dqn``/``train_ppo``) were host-
Python per-step loops: every env step paid separate jit dispatches for
``env.step``/``observe``/``action_masks``, round-tripped ``np.asarray``
host<->device copies, pushed transitions into a host-numpy replay buffer,
and ran ``updates_per_step`` gradient updates in an inner Python loop.
Reproducing the paper's convergence figures was bottlenecked on dispatch
overhead, not compute.

This module fuses the whole rollout-store-update cycle on device:

* ``make_batched_rollout`` - a ``jax.vmap``-batched population of
  ``MHSLEnv`` instances stepped under ``lax.scan`` over the full ``2S-1``
  step episode. The scan carry holds the env state, the cross-attention
  history window, and its validity mask; the stacked scan outputs are the
  full transition batch (one device array per field, ``(num_envs, T, ...)``).
* ``BufferState`` + ``buffer_init``/``buffer_add``/``buffer_sample`` - a
  replay buffer held as a pytree of device arrays with jitted
  ``.at[idx].set`` ring writes. ``buffer_add`` donates the buffer storage
  (``jax.jit(..., donate_argnums=(0,))``) so accelerator backends update it
  in place; donation is skipped on CPU where XLA does not implement it.
* ``make_fused_update`` - ``n_updates`` gradient steps fused into a single
  jitted ``lax.scan`` over pre-sampled batch indices, gathering minibatches
  straight out of the device buffer.
* ``make_scan_updates`` - the on-policy analogue: ``n`` epochs over one
  fixed batch under ``lax.scan`` (PPO).
* ``make_legacy_episode`` - the seed's per-step host loop, kept as the
  reference implementation for the throughput baseline and the
  rollout-equivalence test. Trainers do not use it.

All policies share one signature so SAC / DQN / PPO plug into the same
engine::

    policy(params, key, obs, hist, hist_mask, masks) -> (action, extras)

``extras`` is a dict of additional per-step fields recorded into the
trajectory (e.g. PPO's ``logp``/``v``, DQN's flat action index).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.agents import action_space as A
from repro.core.env import EnvState, MHSLEnv

Array = jax.Array
Policy = Callable[..., Tuple[Dict[str, Array], Dict[str, Array]]]


# ---------------------------------------------------------------------------
# device replay buffer: pytree of (capacity, ...) arrays + ring pointer
# ---------------------------------------------------------------------------


class BufferState(NamedTuple):
    """Replay storage as a pytree of device arrays (circular, fixed cap)."""

    data: Any  # pytree; each leaf (capacity, ...)
    ptr: Array  # int32 scalar, next write slot
    size: Array  # int32 scalar, filled slots


def buffer_init(capacity: int, example: Any) -> BufferState:
    """Allocate device storage from a single-transition example pytree."""
    data = jax.tree.map(
        lambda x: jnp.zeros((capacity,) + jnp.shape(x), jnp.asarray(x).dtype),
        example,
    )
    return BufferState(
        data=data, ptr=jnp.zeros((), jnp.int32), size=jnp.zeros((), jnp.int32)
    )


def _buffer_add(state: BufferState, batch: Any) -> BufferState:
    """Ring-write a batch of transitions (leaves shaped (B, ...)).

    Matches the host ReplayBuffer's semantics exactly, including batches
    larger than the capacity: only the last ``capacity`` rows survive. The
    pre-drop keeps the scatter indices unique - with duplicates the winning
    ``.at[idx].set`` write would be backend-defined."""
    capacity = jax.tree.leaves(state.data)[0].shape[0]
    n_total = jax.tree.leaves(batch)[0].shape[0]
    drop = max(n_total - capacity, 0)
    if drop:
        batch = jax.tree.map(lambda b: b[drop:], batch)
    n = n_total - drop
    idx = (state.ptr + drop + jnp.arange(n, dtype=jnp.int32)) % capacity
    data = jax.tree.map(
        lambda d, b: d.at[idx].set(b.astype(d.dtype)), state.data, batch
    )
    return BufferState(
        data=data,
        ptr=(state.ptr + n_total) % capacity,
        size=jnp.minimum(state.size + n_total, capacity),
    )


# XLA:CPU has no buffer donation; donate only where it is implemented so the
# add is a true in-place device update on accelerators and warning-free on
# CPU. The backend query is deferred to the first call - probing it at import
# time would initialize the JAX backend as an import side effect.
_buffer_add_jitted = None


def buffer_add(state: BufferState, batch: Any) -> BufferState:
    """Jitted ring write; donates the buffer storage where XLA supports it."""
    global _buffer_add_jitted
    if _buffer_add_jitted is None:
        donate: Tuple[int, ...] = (0,) if jax.default_backend() != "cpu" else ()
        _buffer_add_jitted = jax.jit(_buffer_add, donate_argnums=donate)
    return _buffer_add_jitted(state, batch)


def buffer_gather(state: BufferState, idx: Array) -> Any:
    """Gather transitions at ``idx`` (any leading shape) from the buffer."""
    return jax.tree.map(lambda d: d[idx], state.data)


def _buffer_sample(state: BufferState, key, batch_size: int) -> Any:
    idx = jax.random.randint(
        key, (batch_size,), 0, jnp.maximum(state.size, 1)
    )
    return buffer_gather(state, idx)


# batch_size shapes the sample, so it is a static (compile-time) argument
buffer_sample = jax.jit(_buffer_sample, static_argnums=(2,))
buffer_sample.__doc__ = "Uniform device-side sample of batch_size transitions."


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------


def uniform_policy(action_dims: Dict[str, int]) -> Policy:
    """Masked-uniform exploration policy (the seed's warmup behaviour)."""

    def policy(params, key, obs, hist, hist_mask, masks):
        logits = {
            "u": jnp.where(masks["u"], 0.0, A.NEG),
            "size": jnp.where(masks["size"], 0.0, A.NEG),
            "decoys": jnp.stack(
                [jnp.zeros(action_dims["decoys"]),
                 jnp.where(masks["decoys"], 0.0, A.NEG)], -1
            ),
            "p_tx": jnp.zeros(action_dims["p_tx"]),
            "p_d": jnp.zeros(action_dims["p_d"]),
        }
        return A.sample(key, logits), {}

    return policy


def sac_policy(action_dims: Dict[str, int], cfg) -> Policy:
    """Stochastic ICM-CA SAC actor (same math as ``SAC.select_action``)."""
    from repro.core.agents import sac as SAC  # local import: avoid cycle

    def policy(params, key, obs, hist, hist_mask, masks):
        logits = SAC.actor_logits(
            params, obs, hist, hist_mask, masks, action_dims, cfg
        )
        return A.sample(key, logits), {}

    return policy


# ---------------------------------------------------------------------------
# scanned episode rollout
# ---------------------------------------------------------------------------


def make_episode_rollout(
    env: MHSLEnv,
    policy: Policy,
    hist_len: int,
    extra_record: Optional[Callable] = None,
    record_state: bool = False,
):
    """One full ``2S-1``-step episode under ``lax.scan`` (single env).

    Returns ``one_episode(params, st0, key, scenario=None) ->
    (final_state, traj)``. ``scenario`` is a ``ScenarioParams`` pytree of
    runtime physics values; ``None`` falls back to ``env.scenario()``
    (the constructor defaults). Because the scenario is an ARGUMENT, one
    compiled rollout serves every sweep point - and an outer ``jax.vmap``
    over a stacked scenario batch composes with the ``num_envs`` vmap
    (see ``repro.core.scenario.make_population_rollout``).

    ``traj`` leaves are stacked over the episode axis ``T = 2S-1``:
    obs / obs_next / hist / hist_mask / action / masks / reward / done plus
    ``leak``/``viol`` diagnostics and any policy ``extras``.
    ``record_state=True`` additionally stacks the full post-step
    ``EnvState`` per step as ``traj["env_state"]`` (diagnostics/tests only;
    trainers leave it off to keep the scan outputs lean).

    ``extra_record(st, action, st2, info) -> dict`` can append fields that
    need the post-step state (e.g. DQN's flat next-step action mask).

    Key threading matches the seed per-step loop exactly
    (``key, ka, ks = jax.random.split(key, 3)`` each step), so a rollout
    from the same initial key is bit-identical to the legacy path.
    """
    adims = env.action_dims
    pair_dim = env.obs_dim + A.flat_dim(adims)

    def one_episode(params, st0: EnvState, key, scenario=None):
        sp = env.scenario() if scenario is None else scenario
        hist0 = jnp.zeros((hist_len, pair_dim), jnp.float32)
        hmask0 = jnp.zeros((hist_len,), jnp.float32)

        def step_fn(carry, _):
            st, hist, hmask, key = carry
            obs = env.observe(st, sp)
            masks = env.action_masks(st)
            key, ka, ks = jax.random.split(key, 3)
            action, extras = policy(params, ka, obs, hist, hmask, masks)
            st2, reward, done, info = env.step(st, action, ks, sp)
            obs2 = env.observe(st2, sp)
            pair = jnp.concatenate(
                [obs, A.onehot(action, adims)]
            ).astype(jnp.float32)
            hist2 = jnp.roll(hist, -1, axis=0).at[-1].set(pair)
            hmask2 = jnp.roll(hmask, -1).at[-1].set(1.0)
            trans = dict(
                obs=obs.astype(jnp.float32),
                obs_next=obs2.astype(jnp.float32),
                hist=hist,
                hist_mask=hmask,
                action=action,
                masks=masks,
                reward=jnp.asarray(reward, jnp.float32),
                done=jnp.asarray(done, jnp.float32),
                leak=jnp.asarray(info["leak"], jnp.float32),
                viol=((st2.e_r <= 0) | (st2.t_r <= 0)).astype(jnp.float32),
            )
            if record_state:
                trans["env_state"] = st2
            if extra_record is not None:
                trans.update(extra_record(st, action, st2, info))
            trans.update(extras)
            return (st2, hist2, hmask2, key), trans

        (st_final, _, _, _), traj = jax.lax.scan(
            step_fn, (st0, hist0, hmask0, key), None, length=env.episode_len
        )
        return st_final, traj

    return one_episode


def make_batched_rollout(
    env: MHSLEnv,
    policy: Policy,
    hist_len: int,
    extra_record: Optional[Callable] = None,
    record_state: bool = False,
):
    """``jax.vmap`` the scanned episode over an env population and jit it.

    Returns ``rollout(params, st0_batch, keys, scenario=None) ->
    (final_states, traj)`` with traj leaves shaped ``(num_envs, T, ...)``.
    The population size is fixed by the shapes of ``st0_batch``/``keys``
    (one compile per size). ``scenario`` is shared by the whole
    population and is a runtime argument: sweeping its values re-uses the
    jit cache (``rollout.jitted`` / ``rollout.trace_count`` expose the
    inner jit for recompile auditing).
    """
    one = make_episode_rollout(env, policy, hist_len, extra_record,
                               record_state)
    trace_count = [0]

    def _one(params, st0, key, sp):
        trace_count[0] += 1  # executes only while (re)tracing
        return one(params, st0, key, sp)

    jitted = jax.jit(jax.vmap(_one, in_axes=(None, 0, 0, None)))
    default_sp = env.scenario()  # built once; the default path re-uses it

    def rollout(params, st0, keys, scenario=None):
        return jitted(params, st0, keys,
                      default_sp if scenario is None else scenario)

    rollout.jitted = jitted
    rollout.trace_count = trace_count
    return rollout


def make_batched_reset(env: MHSLEnv):
    """Vectorized ``env.reset`` over a batch of PRNG keys. The returned
    ``reset(keys, scenario=None)`` takes the scenario as a runtime value
    (budgets / area feed the initial state)."""
    jitted = jax.jit(jax.vmap(env.reset, in_axes=(0, None)))
    default_sp = env.scenario()

    def reset(keys, scenario=None):
        return jitted(keys, default_sp if scenario is None else scenario)

    reset.jitted = jitted
    return reset


# ---------------------------------------------------------------------------
# fused gradient updates
# ---------------------------------------------------------------------------


def _scan_metric_means(metrics):
    """Per-metric mean over the scan axis. Reporting only the FINAL step's
    metrics made the fig-3/4 loss curves single-sample noise; the mean over
    the chunk's gradient steps is the statistic the curves want."""
    return jax.tree.map(lambda x: x.mean(axis=0), metrics)


def make_fused_update(update_fn, batch_size: int, n_updates: int):
    """Fuse ``n_updates`` off-policy gradient steps into one jitted scan.

    Batch indices for every step are pre-sampled in one shot, then each
    scan iteration gathers its minibatch directly from the device buffer -
    zero host round-trips between gradient steps.

    ``update_fn(params, opt_state, batch) -> (params, opt_state, metrics)``.
    Returns ``fused(params, opt_state, buf, key)`` -> same triple, with
    each metric averaged over the ``n_updates`` scan steps.
    """

    @jax.jit
    def fused(params, opt_state, buf: BufferState, key):
        idx = jax.random.randint(
            key, (n_updates, batch_size), 0, jnp.maximum(buf.size, 1)
        )

        def body(carry, idx_row):
            params, opt_state = carry
            batch = buffer_gather(buf, idx_row)
            params, opt_state, metrics = update_fn(params, opt_state, batch)
            return (params, opt_state), metrics

        (params, opt_state), metrics = jax.lax.scan(
            body, (params, opt_state), idx
        )
        return params, opt_state, _scan_metric_means(metrics)

    return fused


def make_scan_updates(update_fn, n: int):
    """Run ``n`` update epochs over one fixed batch inside a jitted scan
    (the on-policy / PPO analogue of ``make_fused_update``); metrics come
    back averaged over the ``n`` epochs."""

    @jax.jit
    def run(params, opt_state, batch):
        def body(carry, _):
            params, opt_state = carry
            params, opt_state, metrics = update_fn(params, opt_state, batch)
            return (params, opt_state), metrics

        (params, opt_state), metrics = jax.lax.scan(
            body, (params, opt_state), None, length=n
        )
        return params, opt_state, _scan_metric_means(metrics)

    return run


# ---------------------------------------------------------------------------
# fused train chunk: reset -> rollout -> buffer add -> updates -> metrics
# ---------------------------------------------------------------------------

# Discretization bin width for the Fig. 7 distinct-state counter.
OBS_BINS = 4.0

# Two FNV-1a style 32-bit mixes with different offset bases; their
# concatenation is an effectively-64-bit state key. uint32 arithmetic only
# (jax keeps uint64 disabled by default), deterministic across processes -
# unlike Python's salted str/bytes hashes - so checkpointed explored-state
# sets resume exactly in a fresh interpreter.
_KEY_PRIME = 16777619
_KEY_BASIS_HI = 0x811C9DC5
_KEY_BASIS_LO = 0x9E3779B9


def pack_obs_keys(obs: Array, bins: float = OBS_BINS) -> Array:
    """Pack discretized observations into per-row state keys on device.

    ``obs`` (..., D) float -> (..., 2) uint32: each observation row is
    binned with ``round(obs * bins)`` (the Fig. 7 discretization) and
    mixed column-by-column into two independent 32-bit lanes. The host
    counterpart ``loops._pack_obs_keys_np`` produces bit-identical lanes,
    so device-reduced and host-hashed explored-state sets interoperate.
    """
    q = jnp.round(obs * bins).astype(jnp.int32).astype(jnp.uint32)
    prime = jnp.uint32(_KEY_PRIME)

    def mix(basis: int) -> Array:
        h = jnp.full(q.shape[:-1], basis, jnp.uint32)
        for d in range(q.shape[-1]):
            h = (h ^ q[..., d]) * prime
        return h

    return jnp.stack([mix(_KEY_BASIS_HI), mix(_KEY_BASIS_LO)], axis=-1)


def make_train_chunk(
    env: MHSLEnv,
    explore_policy: Policy,
    train_policy: Policy,
    update_fn,
    *,
    hist_len: int,
    fields: Tuple[str, ...],
    batch_size: int,
    n_updates: int,
):
    """ONE jitted, buffer-donated call for a whole training chunk.

    Fuses what ``loops.train_sac`` previously issued as three separate
    dispatches plus two host round-trips per chunk::

        reset -> episode rollout (explore or train policy, lax.cond on the
        traced ``train`` flag) -> ring-buffer write -> n_updates fused
        update scan (lax.cond-gated on warmup AND buffer fill, so there is
        no per-chunk ``int(buf.size)`` host sync) -> on-device metric
        reduction (per-episode reward/leak/violation sums + packed
        discretized-obs keys for the Fig. 7 counter).

    Returns ``chunk(params, opt_state, buf, rkeys, akeys, ukey, train,
    scenario=None) -> (params, opt_state, buf, metrics)`` where ``train``
    is a TRACED bool (warmup chunks pass False) and ``metrics`` is::

        {"reward"|"leak"|"viol": (num_envs,) episode sums,
         "obs_keys": (num_envs, T, 2) uint32 packed state keys,
         "update": per-metric means over the update scan (zeros when the
                   chunk did not update), "did_update": bool}

    The buffer storage is donated on backends that implement donation
    (in-place ring writes, no copy per chunk); all other state flows
    through untouched. The wrapper exposes ``.fn`` (the untraced body -
    ``scenario.train_population`` vmaps it over the scenario axis),
    ``.jitted``, and ``.trace_count`` for recompile audits. Because the
    warmup flag, PRNG keys, buffer contents, and ``ScenarioParams`` are
    all runtime values, a full run - warmup through training, across any
    scenario sweep - compiles the chunk exactly once.
    """
    one_explore = make_episode_rollout(env, explore_policy, hist_len)
    one_train = make_episode_rollout(env, train_policy, hist_len)
    trace_count = [0]

    def fn(params, opt_state, buf: BufferState, rkeys, akeys, ukey, train,
           sp):
        trace_count[0] += 1  # executes only while (re)tracing
        st0 = jax.vmap(env.reset, in_axes=(0, None))(rkeys, sp)

        def roll(one):
            def run(_):
                return jax.vmap(one, in_axes=(None, 0, 0, None))(
                    params, st0, akeys, sp
                )

            return run

        # both policies record identical trajectory structures, so the
        # traced warmup flag selects the branch without retracing
        _, traj = jax.lax.cond(train, roll(one_train), roll(one_explore),
                               None)
        buf = _buffer_add(buf, flatten_transitions(traj, fields))

        def run_updates(carry):
            params, opt_state = carry
            idx = jax.random.randint(
                ukey, (n_updates, batch_size), 0, jnp.maximum(buf.size, 1)
            )

            def body(c, idx_row):
                p, o = c
                p, o, m = update_fn(p, o, buffer_gather(buf, idx_row))
                return (p, o), m

            (params, opt_state), ms = jax.lax.scan(
                body, (params, opt_state), idx
            )
            return params, opt_state, _scan_metric_means(ms)

        # metric structure for the skip branches (abstract - no FLOPs)
        m_shape = jax.eval_shape(run_updates, (params, opt_state))[2]
        zeros = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), m_shape)

        def skip(carry):
            return carry[0], carry[1], zeros

        def maybe_update(carry):
            # inner gate on buffer fill; under a scenario vmap this pred is
            # mapped (per-lane buffers) and lowers to a select, while the
            # outer scalar warmup cond still skips update work entirely
            return jax.lax.cond(buf.size >= batch_size, run_updates, skip,
                                carry)

        params, opt_state, upd = jax.lax.cond(
            train, maybe_update, skip, (params, opt_state)
        )
        metrics = {
            "reward": traj["reward"].sum(axis=1),
            "leak": traj["leak"].sum(axis=1),
            "viol": traj["viol"].sum(axis=1),
            "obs_keys": pack_obs_keys(traj["obs"]),
            "update": upd,
            "did_update": train & (buf.size >= batch_size),
        }
        return params, opt_state, buf, metrics

    donate: Tuple[int, ...] = (2,) if jax.default_backend() != "cpu" else ()
    jitted = jax.jit(fn, donate_argnums=donate)
    default_sp = env.scenario()

    def chunk(params, opt_state, buf, rkeys, akeys, ukey, train,
              scenario=None):
        return jitted(params, opt_state, buf, rkeys, akeys, ukey, train,
                      default_sp if scenario is None else scenario)

    chunk.fn = fn
    chunk.jitted = jitted
    chunk.trace_count = trace_count
    return chunk


def gae(rewards: Array, values: Array, gamma: float, lam: float):
    """Generalized advantage estimation over one episode (reverse scan).

    ``rewards``/``values``: (T,). The terminal bootstrap value is 0 (MHSL
    episodes always end at ``2S-1``). Returns (advantages, returns).
    """
    v_next = jnp.concatenate([values[1:], jnp.zeros((1,), values.dtype)])

    def body(g, xs):
        r, v, vn = xs
        delta = r + gamma * vn - v
        g = delta + gamma * lam * g
        return g, g

    _, adv = jax.lax.scan(
        body, jnp.zeros((), values.dtype), (rewards, values, v_next),
        reverse=True,
    )
    return adv, adv + values


# ---------------------------------------------------------------------------
# helpers shared by the trainers
# ---------------------------------------------------------------------------


def flatten_transitions(traj: Any, keys: Tuple[str, ...]) -> Any:
    """Select ``keys`` from a (num_envs, T, ...) trajectory and flatten the
    leading two axes into one transition batch of num_envs * T rows."""
    sub = {k: traj[k] for k in keys}
    return jax.tree.map(
        lambda x: x.reshape((x.shape[0] * x.shape[1],) + x.shape[2:]), sub
    )


def episode_reset_keys(reset_key, num_envs: int, resample: bool):
    """Per-env reset keys for one chunk. With ``resample`` each env draws a
    fresh geometry; otherwise every env replays the same geometry (the
    seed's fixed-geometry training setup)."""
    if num_envs == 1:
        return reset_key[None]
    if resample:
        return jax.random.split(reset_key, num_envs)
    return jnp.broadcast_to(reset_key, (num_envs,) + reset_key.shape)


# ---------------------------------------------------------------------------
# legacy reference: the seed's per-step host loop
# ---------------------------------------------------------------------------


def make_legacy_episode(env: MHSLEnv, policy: Policy, hist_len: int):
    """The seed implementation's dispatch pattern: one jitted call per env
    operation, host numpy history window, one Python iteration per step.

    Kept only as (a) the baseline for ``benchmarks/throughput.py`` and
    (b) the ground truth for the rollout-equivalence test. Trainers use the
    scanned engine above.

    Returns ``run(params, st0, key) -> (states, rewards)`` where ``states``
    is the list of post-step ``EnvState``s and ``rewards`` the per-step
    reward arrays.
    """
    adims = env.action_dims
    pair_dim = env.obs_dim + A.flat_dim(adims)
    env_step = jax.jit(env.step)
    env_observe = jax.jit(env.observe)
    env_masks = jax.jit(env.action_masks)
    pol = jax.jit(policy)

    def run(params, st: EnvState, key):
        hist = np.zeros((hist_len, pair_dim), np.float32)
        hist_mask = np.zeros((hist_len,), np.float32)
        states, rewards = [], []
        for _ in range(env.episode_len):
            obs = env_observe(st)
            masks = env_masks(st)
            key, ka, ks = jax.random.split(key, 3)
            action, _ = pol(
                params, ka, obs, jnp.asarray(hist), jnp.asarray(hist_mask),
                masks,
            )
            st, reward, done, info = env_step(st, action, ks)
            pair = np.concatenate(
                [np.asarray(obs, np.float32),
                 np.asarray(A.onehot(action, adims), np.float32)]
            )
            hist = np.roll(hist, -1, axis=0)
            hist[-1] = pair
            hist_mask = np.roll(hist_mask, -1)
            hist_mask[-1] = 1.0
            states.append(st)
            rewards.append(reward)
        return states, rewards

    return run
