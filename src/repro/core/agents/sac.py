"""ICM-CA soft actor-critic (paper §III, Algorithm 1).

Follows the paper's (simplified, discrete) SAC: a V-network critic trained
on TD targets (Eq. 28) and an entropy-regularized actor trained on the TD
advantage (Eq. 29), with
  * cross-attention state enhancement s'(n) (Eq. 24)   [use_ca]
  * ICM intrinsic reward R_C with weight zeta (Eq. 23) [use_icm]
  * action masking over the factored discrete action space.

Ablations (paper baselines a/b) come from toggling use_icm / use_ca.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.agents import action_space as A
from repro.core.agents import icm as ICM
from repro.core.agents.attention import (
    cross_attention,
    cross_attention_slim,
    init_cross_attention,
)
from repro.nn import init_mlp, mlp_apply
from repro.optim import adamw
from repro.optim.optimizers import apply_updates


@dataclass(frozen=True)
class SACConfig:
    hidden: int = 128
    feat_dim: int = 32
    attn_dim: int = 64
    hist_len: int = 4  # I in Eq. 24
    gamma: float = 0.95
    alpha: float = 0.03  # entropy weight (Eq. 29)
    zeta: float = 0.3  # intrinsic-reward weight (Table I)
    v_inv: float = 6.0  # v in Eq. 27 (Table I: 5-8)
    eta_a: float = 1e-4  # actor lr (Table I)
    eta_c: float = 3e-4  # critic lr (Table I)
    eta_icm: float = 3e-4
    batch: int = 128
    buffer_size: int = 50_000
    updates_per_step: int = 2
    use_icm: bool = True
    use_ca: bool = True
    # single-backward joint update: shared critic/ICM forwards, one
    # value_and_grad over the whole (actor, critic, icm) pytree with
    # stop_gradient routing. False restores the seed's sequential
    # three-backward path (critic, then actor against the *updated*
    # critic's advantage values, then ICM).
    joint_update: bool = True
    # cross-attention implementation for batched actor forwards:
    # "ref" = agents.attention.cross_attention, "pallas" = the fused
    # kernels.ca_attention kernel (unbatched/vmapped call sites always
    # use the reference path).
    ca_impl: str = "ref"


def init_agent(key, obs_dim: int, action_dims: Dict[str, int], cfg: SACConfig):
    ks = jax.random.split(key, 8)
    pair_dim = obs_dim + A.flat_dim(action_dims)
    actor_in = obs_dim + (cfg.attn_dim if cfg.use_ca else 0)
    head_out = ICM.sum_head_dims(action_dims)
    params = {
        "actor": {
            "trunk": init_mlp(ks[0], [actor_in, cfg.hidden, cfg.hidden]),
            "heads": init_mlp(ks[1], [cfg.hidden, head_out]),
        },
        "critic": init_mlp(ks[2], [obs_dim, cfg.hidden, cfg.hidden, 1]),
    }
    if cfg.use_ca:
        params["actor"]["ca"] = init_cross_attention(
            ks[3], obs_dim, pair_dim, cfg.attn_dim
        )
    if cfg.use_icm:
        params["icm"] = ICM.init_icm(ks[4], obs_dim, action_dims, cfg.feat_dim, cfg.hidden)
    return params


def _split_heads(raw, action_dims):
    u, rest = jnp.split(raw, [action_dims["u"]], -1)
    size, rest = jnp.split(rest, [action_dims["size"]], -1)
    dec, rest = jnp.split(rest, [2 * action_dims["decoys"]], -1)
    p_tx, p_d = jnp.split(rest, [action_dims["p_tx"]], -1)
    return {
        "u": u,
        "size": size,
        "decoys": dec.reshape(dec.shape[:-1] + (action_dims["decoys"], 2)),
        "p_tx": p_tx,
        "p_d": p_d,
    }


def _head_logits(params, x, masks, action_dims):
    """Trunk -> heads -> masked factored logits (shared by every actor
    forward so the head architecture lives in one place)."""
    h = mlp_apply(params["actor"]["trunk"], x, final_act=jax.nn.relu)
    raw = mlp_apply(params["actor"]["heads"], h)
    return A.masked_logits(_split_heads(raw, action_dims), masks)


def actor_logits(params, obs, hist, hist_mask, masks, action_dims, cfg: SACConfig):
    if cfg.use_ca:
        if cfg.ca_impl == "pallas" and obs.ndim == 2:
            from repro.kernels.ca_attention import ca_attention

            x = ca_attention(params["actor"]["ca"], obs, hist, hist_mask)
        else:
            x = cross_attention(params["actor"]["ca"], obs, hist, hist_mask)
    else:
        x = obs
    return _head_logits(params, x, masks, action_dims)


def critic_v(params, obs):
    return mlp_apply(params["critic"], obs)[..., 0]


# ---------------------------------------------------------------------------
# update step
# ---------------------------------------------------------------------------
#
# Loss semantics shared by both paths (Eqs. 22-23, 25-29):
#   critic  - TD regression onto r_total + gamma (1-d) sg[V(s')]
#   actor   - policy gradient on the fully stop-gradiented TD advantage
#             plus entropy, so actor grads never leak into the critic
#   icm     - L_F + v L_I; r_c is stop-gradiented inside icm_losses, so
#             r_total is a constant w.r.t. every parameter
#
# Because each loss term touches exactly one parameter subtree once the
# stop_gradients are in place, one backward over the SUMMED loss yields
# the same per-head gradients as three separate backwards at the same
# parameter point. The only semantic difference of the joint path is
# advantage freshness: the sequential path evaluates the actor's
# advantage VALUES against the critic it just updated, the joint path
# against the chunk-start critic (one eta_c Adam step apart).


def bounded_reward(reward, r_c, cfg: SACConfig):
    """r_total = reward + zeta tanh(R_C) (Eq. 23 with the bonus bounded:
    raw 0.5*||phi-phi_hat||^2 can reach feat_dim/2 >> |env reward| and
    swamp the leakage signal)."""
    return reward + cfg.zeta * jnp.tanh(r_c)


def intrinsic_reward(icm_params, batch, action_dims, cfg: SACConfig):
    """(r_total, r_c, l_i, l_f) with ONE ICM forward (Eqs. 22-23, 25-26).

    ``r_c`` (and therefore ``r_total``) carries no gradient: ``icm_losses``
    stop-gradients both feature embeddings inside R_C."""
    avec = A.onehot(batch["action"], action_dims)
    l_i, l_f, r_c = ICM.icm_losses(
        icm_params, batch["obs"], batch["obs_next"], batch["action"], avec,
        action_dims,
    )
    return bounded_reward(batch["reward"], r_c, cfg), r_c, l_i, l_f


def joint_loss(params, batch, action_dims, cfg: SACConfig):
    """Single scalar whose one backward reproduces all three heads' grads.

    Shared forwards, restructured for minimal dispatch on the hot path:

    * ``obs`` and ``obs_next`` ride ONE stacked ``(2B, ...)`` forward
      through the critic and the ICM feature extractor (the sequential
      path runs each network twice per loss, and the critic nets appear
      in both the critic and actor losses - four critic forwards total);
    * the ICM runs once for r_c AND its own loss (the sequential path
      runs it once outside the grad and once inside);
    * the CA actor uses ``cross_attention_slim`` - only the current-state
      query row, whose gradients are identical to the reference (the
      history-query rows never reach the actor output, so ``wq_h``'s
      gradient is exactly zero either way);
    * log-prob and entropy share one log_softmax per action head.
    """
    b = batch["obs"].shape[0]
    both = jnp.concatenate([batch["obs"], batch["obs_next"]], axis=0)
    v_both = critic_v(params, both)
    v, v_next = v_both[:b], v_both[b:]

    if cfg.use_icm:
        avec = A.onehot(batch["action"], action_dims)
        phi_both = ICM.features(params["icm"], both)
        phi, phi_next = phi_both[:b], phi_both[b:]
        phi_hat = ICM.forward_model(params["icm"], phi, avec)
        l_f = 0.5 * jnp.sum(
            (phi_hat - jax.lax.stop_gradient(phi_next)) ** 2, -1
        ).mean()
        inv = ICM.inverse_logits(params["icm"], phi, phi_next, action_dims)
        l_i = (-A.log_prob(inv, batch["action"])).mean()
        r_c = 0.5 * jnp.sum(
            (jax.lax.stop_gradient(phi_hat)
             - jax.lax.stop_gradient(phi_next)) ** 2, -1
        )
        r_total = bounded_reward(batch["reward"], r_c, cfg)
    else:
        r_c = jnp.zeros_like(batch["reward"])
        r_total = batch["reward"]

    td = r_total + cfg.gamma * (1.0 - batch["done"]) * v_next
    lc = jnp.mean((r_total + cfg.gamma * (1.0 - batch["done"])
                   * jax.lax.stop_gradient(v_next) - v) ** 2)

    if cfg.use_ca:
        if cfg.ca_impl == "pallas":
            from repro.kernels.ca_attention import ca_attention

            x = ca_attention(params["actor"]["ca"], batch["obs"],
                             batch["hist"], batch["hist_mask"])
        else:
            x = cross_attention_slim(params["actor"]["ca"], batch["obs"],
                                     batch["hist"], batch["hist_mask"])
    else:
        x = batch["obs"]
    logits = _head_logits(params, x, batch["masks"], action_dims)
    lp, ent = A.log_prob_entropy(logits, batch["action"])
    y = jax.lax.stop_gradient(td - v)
    la = -jnp.mean(lp * y + cfg.alpha * ent)

    total = lc + la
    metrics = {"critic_loss": lc, "actor_loss": la, "r_c": r_c.mean()}
    if cfg.use_icm:
        total = total + l_f + cfg.v_inv * l_i
        metrics.update(icm_inv_loss=l_i, icm_fwd_loss=l_f)
    return total, metrics


def make_update(action_dims, cfg: SACConfig):
    """``update(params, opt_state, batch) -> (params, opt_state, metrics)``.

    ``cfg.joint_update`` selects the single-backward joint update (shared
    forwards, one ``value_and_grad`` over the full parameter pytree);
    ``False`` keeps the seed's sequential three-backward path bit-for-bit.
    Optimizer-state layout ({actor, critic, icm} AdamW triples) is
    identical for both, so checkpoints are interchangeable."""
    opt_a = adamw(cfg.eta_a)
    opt_c = adamw(cfg.eta_c)
    opt_i = adamw(cfg.eta_icm)

    def init_opt(params):
        return {
            "actor": opt_a.init(params["actor"]),
            "critic": opt_c.init(params["critic"]),
            "icm": opt_i.init(params["icm"]) if cfg.use_icm else (),
        }

    if cfg.joint_update:

        @jax.jit
        def update(params, opt_state, batch):
            (_, metrics), grads = jax.value_and_grad(
                joint_loss, has_aux=True
            )(params, batch, action_dims, cfg)
            ua, oa = opt_a.update(grads["actor"], opt_state["actor"],
                                  params["actor"])
            uc, oc = opt_c.update(grads["critic"], opt_state["critic"],
                                  params["critic"])
            new_params = dict(params)
            new_params["actor"] = apply_updates(params["actor"], ua)
            new_params["critic"] = apply_updates(params["critic"], uc)
            new_opt = {"actor": oa, "critic": oc}
            if cfg.use_icm:
                ui, oi = opt_i.update(grads["icm"], opt_state["icm"],
                                      params["icm"])
                new_params["icm"] = apply_updates(params["icm"], ui)
                new_opt["icm"] = oi
            else:
                new_opt["icm"] = opt_state["icm"]
            return new_params, new_opt, metrics

        return update, init_opt

    def loss_critic(critic_params, params, batch, r_total):
        p = dict(params)
        p["critic"] = critic_params
        v = critic_v(p, batch["obs"])
        v_next = jax.lax.stop_gradient(critic_v(p, batch["obs_next"]))
        target = r_total + cfg.gamma * (1.0 - batch["done"]) * v_next
        return jnp.mean((target - v) ** 2)

    def loss_actor(actor_params, params, batch, r_total):
        p = dict(params)
        p["actor"] = actor_params
        logits = actor_logits(
            p, batch["obs"], batch["hist"], batch["hist_mask"], batch["masks"],
            action_dims, cfg,
        )
        lp = A.log_prob(logits, batch["action"])
        ent = A.entropy(logits)
        v = critic_v(p, batch["obs"])
        v_next = critic_v(p, batch["obs_next"])
        y = jax.lax.stop_gradient(
            r_total + cfg.gamma * (1.0 - batch["done"]) * v_next - v
        )
        return -jnp.mean(lp * y + cfg.alpha * ent)

    def loss_icm(icm_params, batch):
        avec = A.onehot(batch["action"], action_dims)
        l_i, l_f, _ = ICM.icm_losses(
            icm_params, batch["obs"], batch["obs_next"], batch["action"], avec,
            action_dims,
        )
        return l_f + cfg.v_inv * l_i, (l_i, l_f)

    @jax.jit
    def update(params, opt_state, batch):
        # intrinsic reward (Eq. 22-23)
        if cfg.use_icm:
            avec = A.onehot(batch["action"], action_dims)
            _, _, r_c = ICM.icm_losses(
                params["icm"], batch["obs"], batch["obs_next"], batch["action"],
                avec, action_dims,
            )
            r_total = bounded_reward(batch["reward"], r_c, cfg)
        else:
            r_c = jnp.zeros_like(batch["reward"])
            r_total = batch["reward"]

        lc, gc = jax.value_and_grad(loss_critic)(
            params["critic"], params, batch, r_total
        )
        uc, oc = opt_c.update(gc, opt_state["critic"], params["critic"])
        params = dict(params)
        params["critic"] = apply_updates(params["critic"], uc)

        la, ga = jax.value_and_grad(loss_actor)(params["actor"], params, batch, r_total)
        ua, oa = opt_a.update(ga, opt_state["actor"], params["actor"])
        params["actor"] = apply_updates(params["actor"], ua)

        metrics = {"critic_loss": lc, "actor_loss": la, "r_c": r_c.mean()}
        new_opt = {"critic": oc, "actor": oa}
        if cfg.use_icm:
            (li_total, (l_i, l_f)), gi = jax.value_and_grad(loss_icm, has_aux=True)(
                params["icm"], batch
            )
            ui, oi = opt_i.update(gi, opt_state["icm"], params["icm"])
            params["icm"] = apply_updates(params["icm"], ui)
            new_opt["icm"] = oi
            metrics.update(icm_inv_loss=l_i, icm_fwd_loss=l_f)
        else:
            new_opt["icm"] = opt_state["icm"]
        return params, new_opt, metrics

    return update, init_opt


@partial(jax.jit, static_argnames=("action_dims_t", "cfg"))
def _select(params, key, obs, hist, hist_mask, masks, action_dims_t, cfg):
    action_dims = dict(action_dims_t)
    logits = actor_logits(params, obs, hist, hist_mask, masks, action_dims, cfg)
    return A.sample(key, logits)


def select_action(params, key, obs, hist, hist_mask, masks, action_dims, cfg):
    return _select(
        params, key, obs, hist, hist_mask, masks,
        tuple(sorted(action_dims.items())), cfg,
    )
