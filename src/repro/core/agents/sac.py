"""ICM-CA soft actor-critic (paper §III, Algorithm 1).

Follows the paper's (simplified, discrete) SAC: a V-network critic trained
on TD targets (Eq. 28) and an entropy-regularized actor trained on the TD
advantage (Eq. 29), with
  * cross-attention state enhancement s'(n) (Eq. 24)   [use_ca]
  * ICM intrinsic reward R_C with weight zeta (Eq. 23) [use_icm]
  * action masking over the factored discrete action space.

Ablations (paper baselines a/b) come from toggling use_icm / use_ca.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.agents import action_space as A
from repro.core.agents import icm as ICM
from repro.core.agents.attention import cross_attention, init_cross_attention
from repro.nn import init_mlp, mlp_apply
from repro.optim import adamw
from repro.optim.optimizers import apply_updates


@dataclass(frozen=True)
class SACConfig:
    hidden: int = 128
    feat_dim: int = 32
    attn_dim: int = 64
    hist_len: int = 4  # I in Eq. 24
    gamma: float = 0.95
    alpha: float = 0.03  # entropy weight (Eq. 29)
    zeta: float = 0.3  # intrinsic-reward weight (Table I)
    v_inv: float = 6.0  # v in Eq. 27 (Table I: 5-8)
    eta_a: float = 1e-4  # actor lr (Table I)
    eta_c: float = 3e-4  # critic lr (Table I)
    eta_icm: float = 3e-4
    batch: int = 128
    buffer_size: int = 50_000
    updates_per_step: int = 2
    use_icm: bool = True
    use_ca: bool = True


def init_agent(key, obs_dim: int, action_dims: Dict[str, int], cfg: SACConfig):
    ks = jax.random.split(key, 8)
    pair_dim = obs_dim + A.flat_dim(action_dims)
    actor_in = obs_dim + (cfg.attn_dim if cfg.use_ca else 0)
    head_out = ICM.sum_head_dims(action_dims)
    params = {
        "actor": {
            "trunk": init_mlp(ks[0], [actor_in, cfg.hidden, cfg.hidden]),
            "heads": init_mlp(ks[1], [cfg.hidden, head_out]),
        },
        "critic": init_mlp(ks[2], [obs_dim, cfg.hidden, cfg.hidden, 1]),
    }
    if cfg.use_ca:
        params["actor"]["ca"] = init_cross_attention(
            ks[3], obs_dim, pair_dim, cfg.attn_dim
        )
    if cfg.use_icm:
        params["icm"] = ICM.init_icm(ks[4], obs_dim, action_dims, cfg.feat_dim, cfg.hidden)
    return params


def _split_heads(raw, action_dims):
    u, rest = jnp.split(raw, [action_dims["u"]], -1)
    size, rest = jnp.split(rest, [action_dims["size"]], -1)
    dec, rest = jnp.split(rest, [2 * action_dims["decoys"]], -1)
    p_tx, p_d = jnp.split(rest, [action_dims["p_tx"]], -1)
    return {
        "u": u,
        "size": size,
        "decoys": dec.reshape(dec.shape[:-1] + (action_dims["decoys"], 2)),
        "p_tx": p_tx,
        "p_d": p_d,
    }


def actor_logits(params, obs, hist, hist_mask, masks, action_dims, cfg: SACConfig):
    if cfg.use_ca:
        x = cross_attention(params["actor"]["ca"], obs, hist, hist_mask)
    else:
        x = obs
    h = mlp_apply(params["actor"]["trunk"], x, final_act=jax.nn.relu)
    raw = mlp_apply(params["actor"]["heads"], h)
    return A.masked_logits(_split_heads(raw, action_dims), masks)


def critic_v(params, obs):
    return mlp_apply(params["critic"], obs)[..., 0]


# ---------------------------------------------------------------------------
# update step
# ---------------------------------------------------------------------------


def make_update(action_dims, cfg: SACConfig):
    opt_a = adamw(cfg.eta_a)
    opt_c = adamw(cfg.eta_c)
    opt_i = adamw(cfg.eta_icm)

    def loss_critic(critic_params, params, batch, r_total):
        p = dict(params)
        p["critic"] = critic_params
        v = critic_v(p, batch["obs"])
        v_next = jax.lax.stop_gradient(critic_v(p, batch["obs_next"]))
        target = r_total + cfg.gamma * (1.0 - batch["done"]) * v_next
        return jnp.mean((target - v) ** 2)

    def loss_actor(actor_params, params, batch, r_total):
        p = dict(params)
        p["actor"] = actor_params
        logits = actor_logits(
            p, batch["obs"], batch["hist"], batch["hist_mask"], batch["masks"],
            action_dims, cfg,
        )
        lp = A.log_prob(logits, batch["action"])
        ent = A.entropy(logits)
        v = critic_v(p, batch["obs"])
        v_next = critic_v(p, batch["obs_next"])
        y = jax.lax.stop_gradient(
            r_total + cfg.gamma * (1.0 - batch["done"]) * v_next - v
        )
        return -jnp.mean(lp * y + cfg.alpha * ent)

    def loss_icm(icm_params, batch):
        avec = A.onehot(batch["action"], action_dims)
        l_i, l_f, _ = ICM.icm_losses(
            icm_params, batch["obs"], batch["obs_next"], batch["action"], avec,
            action_dims,
        )
        return l_f + cfg.v_inv * l_i, (l_i, l_f)

    @jax.jit
    def update(params, opt_state, batch):
        # intrinsic reward (Eq. 22-23)
        if cfg.use_icm:
            avec = A.onehot(batch["action"], action_dims)
            _, _, r_c = ICM.icm_losses(
                params["icm"], batch["obs"], batch["obs_next"], batch["action"],
                avec, action_dims,
            )
            # bound the curiosity bonus (raw 0.5*||phi-phi_hat||^2 can reach
            # feat_dim/2 >> |env reward| and swamp the leakage signal)
            r_total = batch["reward"] + cfg.zeta * jnp.tanh(r_c)
        else:
            r_c = jnp.zeros_like(batch["reward"])
            r_total = batch["reward"]

        lc, gc = jax.value_and_grad(loss_critic)(
            params["critic"], params, batch, r_total
        )
        uc, oc = opt_c.update(gc, opt_state["critic"], params["critic"])
        params = dict(params)
        params["critic"] = apply_updates(params["critic"], uc)

        la, ga = jax.value_and_grad(loss_actor)(params["actor"], params, batch, r_total)
        ua, oa = opt_a.update(ga, opt_state["actor"], params["actor"])
        params["actor"] = apply_updates(params["actor"], ua)

        metrics = {"critic_loss": lc, "actor_loss": la, "r_c": r_c.mean()}
        new_opt = {"critic": oc, "actor": oa}
        if cfg.use_icm:
            (li_total, (l_i, l_f)), gi = jax.value_and_grad(loss_icm, has_aux=True)(
                params["icm"], batch
            )
            ui, oi = opt_i.update(gi, opt_state["icm"], params["icm"])
            params["icm"] = apply_updates(params["icm"], ui)
            new_opt["icm"] = oi
            metrics.update(icm_inv_loss=l_i, icm_fwd_loss=l_f)
        else:
            new_opt["icm"] = opt_state["icm"]
        return params, new_opt, metrics

    def init_opt(params):
        return {
            "actor": opt_a.init(params["actor"]),
            "critic": opt_c.init(params["critic"]),
            "icm": opt_i.init(params["icm"]) if cfg.use_icm else (),
        }

    return update, init_opt


@partial(jax.jit, static_argnames=("action_dims_t", "cfg"))
def _select(params, key, obs, hist, hist_mask, masks, action_dims_t, cfg):
    action_dims = dict(action_dims_t)
    logits = actor_logits(params, obs, hist, hist_mask, masks, action_dims, cfg)
    return A.sample(key, logits)


def select_action(params, key, obs, hist, hist_mask, masks, action_dims, cfg):
    return _select(
        params, key, obs, hist, hist_mask, masks,
        tuple(sorted(action_dims.items())), cfg,
    )
