"""Intrinsic Curiosity Module (paper §III-A.4, Eqs. 17-19, 22, 25-27).

Components (all MLP+residual; the forward model also carries a GRU as in
the paper's Fig. 2):
  * feature extractor  phi(s)           (Eq. 17), sigmoid output so each
    element lies in [0,1] (used by the Lemma-1 boundedness argument)
  * forward dynamics   phi_hat(s') = f(phi(s), a)    (Eq. 18)
  * inverse dynamics   p_hat(a | phi(s), phi(s'))    (Eq. 19), factored
    over the action heads

Losses: L_I (Eq. 25) cross-entropy, L_F (Eq. 26) 0.5 L2, L_E (Eq. 27)
combined; intrinsic reward R_C (Eq. 22).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.agents import action_space as A
from repro.nn import (
    gru_apply,
    init_gru,
    init_mlp,
    init_residual_mlp,
    mlp_apply,
    residual_mlp_apply,
)


def init_icm(key, obs_dim: int, action_dims: Dict[str, int], feat_dim: int = 32,
             hidden: int = 128):
    adim = A.flat_dim(action_dims)
    ks = jax.random.split(key, 5)
    return {
        "feat": init_residual_mlp(ks[0], obs_dim, hidden, 2, feat_dim),
        "fwd_in": init_residual_mlp(ks[1], feat_dim + adim, hidden, 1, hidden),
        "fwd_gru": init_gru(ks[2], hidden, feat_dim),
        "inv": init_mlp(ks[3], [2 * feat_dim, hidden,
                                sum_head_dims(action_dims)]),
    }


def sum_head_dims(action_dims: Dict[str, int]) -> int:
    return (
        action_dims["u"]
        + action_dims["size"]
        + 2 * action_dims["decoys"]
        + action_dims["p_tx"]
        + action_dims["p_d"]
    )


def features(params, obs):
    """phi(s) in [0,1]^feat (Eq. 17)."""
    return residual_mlp_apply(params["feat"], obs, final_act=jax.nn.sigmoid)


def forward_model(params, phi, action_vec):
    """phi_hat(s') (Eq. 18): MLP+residual encoder then GRU cell with phi as
    the hidden state (output squashed to [0,1] like phi)."""
    h = residual_mlp_apply(params["fwd_in"], jnp.concatenate([phi, action_vec], -1))
    out = gru_apply(params["fwd_gru"], phi, h)
    return jax.nn.sigmoid(out)


def inverse_logits(params, phi, phi_next, action_dims):
    raw = mlp_apply(params["inv"], jnp.concatenate([phi, phi_next], -1))
    u, rest = jnp.split(raw, [action_dims["u"]], -1)
    size, rest = jnp.split(rest, [action_dims["size"]], -1)
    dec, rest = jnp.split(rest, [2 * action_dims["decoys"]], -1)
    p_tx, p_d = jnp.split(rest, [action_dims["p_tx"]], -1)
    return {
        "u": u,
        "size": size,
        "decoys": dec.reshape(dec.shape[:-1] + (action_dims["decoys"], 2)),
        "p_tx": p_tx,
        "p_d": p_d,
    }


def icm_losses(params, obs, obs_next, action, action_vec, action_dims):
    """Returns (L_I, L_F, R_C) for a batch (Eqs. 22, 25, 26)."""
    phi = features(params, obs)
    phi_next = features(params, obs_next)
    phi_hat = forward_model(params, phi, action_vec)
    l_f = 0.5 * jnp.sum((phi_hat - jax.lax.stop_gradient(phi_next)) ** 2, -1)
    inv = inverse_logits(params, phi, phi_next, action_dims)
    l_i = -A.log_prob(inv, action)  # cross-entropy with one-hot b(n)
    r_c = 0.5 * jnp.sum(
        (jax.lax.stop_gradient(phi_hat) - jax.lax.stop_gradient(phi_next)) ** 2, -1
    )
    return l_i.mean(), l_f.mean(), r_c
