"""Training loops: episode rollout + off-policy updates (Algorithm 1).

Tracks the paper's figure metrics: accumulated reward per episode (Figs.
3-4), information leaked (Figs. 5-6), and distinct states explored (Fig. 7,
hash of the discretized observation).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.agents import action_space as A
from repro.core.agents import sac as SAC
from repro.core.agents.buffer import ReplayBuffer
from repro.core.env import MHSLEnv


def _obs_hash(obs: np.ndarray, bins: float = 4.0) -> int:
    """Distinct-state counter (paper Fig. 7): the discrete plan structure
    (assignment vector r, transmitter one-hot, phase) plus coarsely binned
    budgets - continuous noise dims are excluded so the count reflects
    genuinely new (assignment x budget-regime) states."""
    o = np.asarray(obs)
    discrete = o[3:]  # r, v one-hot, l_M, l_D, phase, n  (skip raw budgets)
    head = np.round(o[:3] * bins)  # budget/progress coarse bins
    return hash(tuple(np.round(discrete * bins).astype(np.int64).tolist())
                + tuple(head.astype(np.int64).tolist()))


@dataclass
class TrainResult:
    episode_reward: list = field(default_factory=list)
    episode_leak: list = field(default_factory=list)
    episode_violation: list = field(default_factory=list)
    states_explored: list = field(default_factory=list)  # cumulative distinct
    metrics: list = field(default_factory=list)


def train_sac(
    env: MHSLEnv,
    cfg: SAC.SACConfig,
    episodes: int = 200,
    seed: int = 0,
    warmup_episodes: int = 10,
    resample_positions: bool = False,
) -> TrainResult:
    key = jax.random.PRNGKey(seed)
    rng = np.random.default_rng(seed)
    adims = env.action_dims
    key, k0 = jax.random.split(key)
    params = SAC.init_agent(k0, env.obs_dim, adims, cfg)
    update, init_opt = SAC.make_update(adims, cfg)
    opt_state = init_opt(params)

    pair_dim = env.obs_dim + A.flat_dim(adims)
    hist0 = np.zeros((cfg.hist_len, pair_dim), np.float32)

    # example transition for buffer allocation
    key, kr = jax.random.split(key)
    st = env.reset(kr)
    obs0 = np.asarray(env.observe(st), np.float32)
    masks0 = {k: np.asarray(v) for k, v in env.action_masks(st).items()}
    example = dict(
        obs=obs0,
        obs_next=obs0,
        hist=hist0,
        hist_mask=np.zeros((cfg.hist_len,), np.float32),
        action={
            "u": np.int32(0),
            "size": np.int32(0),
            "decoys": np.zeros((adims["decoys"],), np.int32),
            "p_tx": np.int32(0),
            "p_d": np.int32(0),
        },
        masks=masks0,
        reward=np.float32(0),
        done=np.float32(0),
    )
    buf = ReplayBuffer(cfg.buffer_size, example)

    env_step = jax.jit(env.step)
    env_observe = jax.jit(env.observe)
    env_masks = jax.jit(env.action_masks)

    result = TrainResult()
    seen = set()
    key, kpos = jax.random.split(key)
    reset_key = kpos

    for ep in range(episodes):
        if resample_positions:
            key, reset_key = jax.random.split(key)
        st = env.reset(reset_key)
        hist = hist0.copy()
        hist_mask = np.zeros((cfg.hist_len,), np.float32)
        ep_r, ep_leak, ep_viol = 0.0, 0.0, 0.0
        for t in range(env.episode_len):
            obs = env_observe(st)
            masks = env_masks(st)
            seen.add(_obs_hash(obs))
            key, ka, ks = jax.random.split(key, 3)
            if ep < warmup_episodes:
                logits = {
                    "u": jnp.where(masks["u"], 0.0, -1e9),
                    "size": jnp.where(masks["size"], 0.0, -1e9),
                    "decoys": jnp.stack(
                        [jnp.zeros(adims["decoys"]),
                         jnp.where(masks["decoys"], 0.0, -1e9)], -1
                    ),
                    "p_tx": jnp.zeros(adims["p_tx"]),
                    "p_d": jnp.zeros(adims["p_d"]),
                }
                action = A.sample(ka, logits)
            else:
                action = SAC.select_action(
                    params, ka, obs, jnp.asarray(hist), jnp.asarray(hist_mask),
                    masks, adims, cfg,
                )
            st2, r, done, info = env_step(st, action, ks)
            obs2 = env_observe(st2)
            buf.add(
                dict(
                    obs=np.asarray(obs, np.float32),
                    obs_next=np.asarray(obs2, np.float32),
                    hist=hist.copy(),
                    hist_mask=hist_mask.copy(),
                    action={k: np.asarray(v) for k, v in action.items()},
                    masks={k: np.asarray(v) for k, v in masks.items()},
                    reward=np.float32(r),
                    done=np.float32(done),
                )
            )
            # roll history (newest last)
            pair = np.concatenate(
                [np.asarray(obs, np.float32),
                 np.asarray(A.onehot(action, adims), np.float32)]
            )
            hist = np.roll(hist, -1, axis=0)
            hist[-1] = pair
            hist_mask = np.roll(hist_mask, -1)
            hist_mask[-1] = 1.0
            ep_r += float(r)
            ep_leak += float(info["leak"])
            ep_viol += float((st2.e_r <= 0) | (st2.t_r <= 0))
            st = st2

            if ep >= warmup_episodes and buf.size >= cfg.batch:
                for _ in range(cfg.updates_per_step):
                    batch = buf.sample(rng, cfg.batch)
                    params, opt_state, m = update(params, opt_state, batch)

        result.episode_reward.append(ep_r)
        result.episode_leak.append(ep_leak)
        result.episode_violation.append(ep_viol)
        result.states_explored.append(len(seen))

    result.params = params  # type: ignore[attr-defined]
    return result


def evaluate_sac(env: MHSLEnv, params, cfg: SAC.SACConfig, episodes: int = 20,
                 seed: int = 1000) -> Dict[str, float]:
    key = jax.random.PRNGKey(seed)
    adims = env.action_dims
    pair_dim = env.obs_dim + A.flat_dim(adims)
    env_step = jax.jit(env.step)
    env_observe = jax.jit(env.observe)
    env_masks = jax.jit(env.action_masks)
    tot_r, tot_leak = 0.0, 0.0
    for ep in range(episodes):
        key, kr = jax.random.split(key)
        st = env.reset(kr)
        hist = np.zeros((cfg.hist_len, pair_dim), np.float32)
        hist_mask = np.zeros((cfg.hist_len,), np.float32)
        for t in range(env.episode_len):
            obs = env_observe(st)
            masks = env_masks(st)
            key, ka, ks = jax.random.split(key, 3)
            action = SAC.select_action(
                params, ka, obs, jnp.asarray(hist), jnp.asarray(hist_mask),
                masks, adims, cfg,
            )
            st, r, done, info = env_step(st, action, ks)
            pair = np.concatenate(
                [np.asarray(obs, np.float32),
                 np.asarray(A.onehot(action, adims), np.float32)]
            )
            hist = np.roll(hist, -1, axis=0)
            hist[-1] = pair
            hist_mask = np.roll(hist_mask, -1)
            hist_mask[-1] = 1.0
            tot_r += float(r)
            tot_leak += float(info["leak"])
    return {"reward": tot_r / episodes, "leak": tot_leak / episodes}
