"""Training loops: episode rollout + off-policy updates (Algorithm 1).

Built on the device-resident rollout engine (``repro.core.agents.rollout``):
``train_sac`` runs each chunk - env reset, the vmapped ``lax.scan``
episode rollout over ``num_envs`` environments, the batched replay-buffer
write, the fused gradient-update scan, and the per-episode metric
reduction - as ONE jitted, buffer-donated call
(``rollout.make_train_chunk``). The only per-chunk host traffic is a
single ``device_get`` of the reduced metrics (episode sums plus packed
discretized-obs state keys); there is no ``int(buf.size)`` sync and no
full-trajectory transfer.

Tracks the paper's figure metrics: accumulated reward per episode (Figs.
3-4), information leaked (Figs. 5-6), and distinct states explored (Fig. 7,
packed key of the discretized observation).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import train_state as TS
from repro.core.agents import action_space as A
from repro.core.agents import rollout as R
from repro.core.agents import sac as SAC
from repro.core.env import MHSLEnv
from repro.distribution import population as PD


def _pack_obs_keys_np(obs: np.ndarray, bins: float = R.OBS_BINS) -> np.ndarray:
    """Vectorized distinct-state keys (paper Fig. 7): discretize every
    observation row with ``round(obs * bins)`` and mix the columns into a
    uint64 key, all in batched numpy - the previous ``_obs_hash`` built a
    Python tuple per row (``num_envs * T`` rows per chunk).

    Bit-compatible with the device-side ``rollout.pack_obs_keys`` lanes
    (``key == (hi << 32) | lo``), and - unlike Python's salted ``hash`` -
    deterministic across interpreter runs, so checkpointed explored-state
    sets resume exactly.
    """
    q = np.round(np.asarray(obs) * bins).astype(np.int32).astype(np.uint32)
    prime = np.uint32(R._KEY_PRIME)
    hi = np.full(q.shape[:-1], R._KEY_BASIS_HI, np.uint32)
    lo = np.full(q.shape[:-1], R._KEY_BASIS_LO, np.uint32)
    for d in range(q.shape[-1]):
        col = q[..., d]
        hi = (hi ^ col) * prime
        lo = (lo ^ col) * prime
    return (hi.astype(np.uint64) << np.uint64(32)) | lo.astype(np.uint64)


def _combine_key_lanes(packed: np.ndarray) -> np.ndarray:
    """(..., 2) uint32 device key lanes -> (...,) uint64 host keys."""
    p = np.asarray(packed)
    return ((p[..., 0].astype(np.uint64) << np.uint64(32))
            | p[..., 1].astype(np.uint64))


@dataclass
class TrainResult:
    episode_reward: list = field(default_factory=list)
    episode_leak: list = field(default_factory=list)
    episode_violation: list = field(default_factory=list)
    states_explored: list = field(default_factory=list)  # cumulative distinct
    metrics: list = field(default_factory=list)


# transition fields persisted to the SAC replay buffer
_SAC_FIELDS = ("obs", "obs_next", "hist", "hist_mask", "action", "masks",
               "reward", "done")


def _sac_example(env: MHSLEnv, cfg: SAC.SACConfig) -> Dict:
    """Single-transition pytree defining the replay buffer layout."""
    adims = env.action_dims
    pair_dim = env.obs_dim + A.flat_dim(adims)
    return dict(
        obs=jnp.zeros((env.obs_dim,), jnp.float32),
        obs_next=jnp.zeros((env.obs_dim,), jnp.float32),
        hist=jnp.zeros((cfg.hist_len, pair_dim), jnp.float32),
        hist_mask=jnp.zeros((cfg.hist_len,), jnp.float32),
        action={
            "u": jnp.zeros((), jnp.int32),
            "size": jnp.zeros((), jnp.int32),
            "decoys": jnp.zeros((adims["decoys"],), jnp.int32),
            "p_tx": jnp.zeros((), jnp.int32),
            "p_d": jnp.zeros((), jnp.int32),
        },
        masks={
            "u": jnp.zeros((adims["u"],), bool),
            "size": jnp.zeros((adims["size"],), bool),
            "decoys": jnp.zeros((adims["decoys"],), bool),
            "p_tx": jnp.zeros((adims["p_tx"],), bool),
            "p_d": jnp.zeros((adims["p_d"],), bool),
        },
        reward=jnp.zeros((), jnp.float32),
        done=jnp.zeros((), jnp.float32),
    )


def _chunk_metrics(result: TrainResult, seen: set, traj, ep: int,
                   episodes: int, num_envs: int) -> None:
    """Single device->host transfer per chunk; then per-episode bookkeeping
    (reward/leak/violation sums + the distinct-state counter, computed via
    the vectorized numpy packing + ``np.unique`` rather than a Python hash
    loop over every observation row)."""
    host = jax.device_get({
        "obs": traj["obs"],
        "reward": traj["reward"],
        "leak": traj["leak"],
        "viol": traj["viol"],
    })
    keys = _pack_obs_keys_np(host["obs"])  # (num_envs, T)
    for i in range(num_envs):
        if ep + i >= episodes:
            break
        seen.update(int(k) for k in np.unique(keys[i]))
        result.episode_reward.append(float(host["reward"][i].sum()))
        result.episode_leak.append(float(host["leak"][i].sum()))
        result.episode_violation.append(float(host["viol"][i].sum()))
        result.states_explored.append(len(seen))


def _reduced_chunk_metrics(result: TrainResult, seen: set, m, ep: int,
                           episodes: int, num_envs: int) -> None:
    """Bookkeeping from a fused train chunk's device-reduced metrics
    (already on host): per-episode sums are precomputed, observations
    arrive as packed state keys instead of raw rows."""
    keys = _combine_key_lanes(m["obs_keys"])  # (num_envs, T)
    for i in range(num_envs):
        if ep + i >= episodes:
            break
        seen.update(int(k) for k in np.unique(keys[i]))
        result.episode_reward.append(float(m["reward"][i]))
        result.episode_leak.append(float(m["leak"][i]))
        result.episode_violation.append(float(m["viol"][i]))
        result.states_explored.append(len(seen))
    if bool(m["did_update"]):
        result.metrics.append(
            {k: float(v) for k, v in m["update"].items()}
        )


def train_sac(
    env: MHSLEnv,
    cfg: SAC.SACConfig,
    episodes: int = 200,
    seed: int = 0,
    warmup_episodes: int = 10,
    resample_positions: bool = False,
    num_envs: int = 1,
    scenario=None,
    mesh=None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 0,
    resume: bool = True,
) -> TrainResult:
    """ICM-CA SAC training on the device-resident engine.

    ``scenario`` (a ``repro.core.scenario.ScenarioParams``) overrides the
    env's default physics as a runtime value - training the same env
    object across sweep points re-uses every jit cache. ``None`` keeps
    the constructor defaults. To train a whole scenario batch in one
    vectorized run, use ``repro.core.scenario.train_population``.

    ``num_envs`` environments run as one vmapped population; each chunk -
    the rollout of ``num_envs`` full episodes, the replay write, the
    ``num_envs * episode_len * updates_per_step`` gradient steps (the same
    updates-per-env-step ratio as the seed per-step loop, run with the
    ``cfg.joint_update`` single-backward step by default), and the metric
    reduction - is ONE buffer-donated jitted call
    (``rollout.make_train_chunk``). Note the cadence difference vs the
    seed: updates are
    batched at chunk end with the rollout policy frozen for the episode,
    where the seed interleaved ``updates_per_step`` steps after every env
    step - counts match, training dynamics are the standard batched-RL
    approximation. With ``num_envs > 1`` the warmup boundary rounds UP to
    chunk granularity: a chunk that straddles ``warmup_episodes`` still
    rolls out uniformly and gradient updates start with the first chunk
    that begins at or past the boundary. If ``episodes`` is not a multiple
    of ``num_envs`` the final chunk still trains on the full population
    but only the first ``episodes`` entries are reported.

    ``mesh`` (``launch.mesh.make_population_mesh``) shards the ``num_envs``
    axis of env states / key batches and the replay buffer's capacity axis
    across devices; agent params and optimizer state are replicated. The
    compiled chunk functions are unchanged - jit propagates the committed
    input shardings - so a 1-device mesh is bit-identical to ``mesh=None``.

    ``checkpoint_dir`` + ``checkpoint_every`` save the complete loop state
    (params, opt state, replay buffer, PRNG keys, episode counter, metric
    curves, explored-state hashes) at chunk boundaries every
    ``checkpoint_every`` episodes, plus once at the end. With ``resume``
    (default) an existing checkpoint in the directory is restored and
    training continues from its episode counter; the resumed trajectory is
    bit-identical to an uninterrupted run.
    """
    if num_envs < 1:
        raise ValueError(f"num_envs must be >= 1, got {num_envs}")
    key = jax.random.PRNGKey(seed)
    adims = env.action_dims
    key, k0 = jax.random.split(key)
    params = SAC.init_agent(k0, env.obs_dim, adims, cfg)
    update, init_opt = SAC.make_update(adims, cfg)
    opt_state = init_opt(params)

    buf = R.buffer_init(cfg.buffer_size, _sac_example(env, cfg))
    n_updates = cfg.updates_per_step * env.episode_len * num_envs
    chunk = R.make_train_chunk(
        env, R.uniform_policy(adims), R.sac_policy(adims, cfg), update,
        hist_len=cfg.hist_len, fields=_SAC_FIELDS, batch_size=cfg.batch,
        n_updates=n_updates,
    )

    result = TrainResult()
    seen: set = set()
    key, kpos = jax.random.split(key)
    reset_key = kpos

    # mesh placement: replicated agent, population-sharded replay storage
    params = PD.replicate(params, mesh)
    opt_state = PD.replicate(opt_state, mesh)
    buf = PD.shard_population(buf, mesh, cfg.buffer_size)

    # run fingerprint saved with every checkpoint: loop knobs plus the
    # agent config and scenario physics the run was trained under -
    # TS.validate_resume hard-errors on any mismatch
    meta = dict(seed=seed, num_envs=num_envs,
                warmup_episodes=warmup_episodes,
                resample_positions=resample_positions,
                cfg=repr(cfg), scenario=TS.pytree_fingerprint(scenario))

    ep = 0
    last_saved = None
    if checkpoint_dir and resume and (
        TS.latest_checkpoint_step(checkpoint_dir) is not None
    ):
        like = dict(params=params, opt_state=opt_state, buf=buf,
                    key=key, reset_key=reset_key)
        step, dev, host = TS.load_train_checkpoint(checkpoint_dir, like)
        TS.validate_resume(host, meta, episodes, checkpoint_dir)
        params, opt_state, buf = dev["params"], dev["opt_state"], dev["buf"]
        key, reset_key = dev["key"], dev["reset_key"]
        ep = last_saved = int(host["ep"])
        result.episode_reward = list(host["episode_reward"])
        result.episode_leak = list(host["episode_leak"])
        result.episode_violation = list(host["episode_violation"])
        result.states_explored = list(host["states_explored"])
        seen = set(host["seen"])

    def _save(ep_now: int) -> None:
        TS.save_train_checkpoint(
            checkpoint_dir, ep_now,
            dict(params=params, opt_state=opt_state, buf=buf,
                 key=key, reset_key=reset_key),
            dict(ep=ep_now, meta=meta,
                 episode_reward=result.episode_reward,
                 episode_leak=result.episode_leak,
                 episode_violation=result.episode_violation,
                 states_explored=result.states_explored,
                 seen=sorted(seen)),
        )

    while ep < episodes:
        # chunk-boundary checkpoint: the state right here fully determines
        # the remainder of the run (keys are split inside the chunk)
        if (checkpoint_dir and checkpoint_every
                and (last_saved is None or ep - last_saved >= checkpoint_every)):
            _save(ep)
            last_saved = ep
        if resample_positions:
            key, reset_key = jax.random.split(key)
        rkeys = R.episode_reset_keys(reset_key, num_envs, resample_positions)
        key, ksub, ku = jax.random.split(key, 3)
        akeys = jax.random.split(ksub, num_envs)
        rkeys = PD.shard_population(rkeys, mesh, num_envs)
        akeys = PD.shard_population(akeys, mesh, num_envs)

        # whole chunk (reset/rollout/buffer/updates/metric reduction) in one
        # buffer-donated dispatch. Warmup rounds UP to chunk granularity: the
        # traced `train` flag stays False until the chunk that starts
        # at/past the boundary (exact at num_envs=1), and the update scan is
        # additionally cond-gated on buffer fill - on device, no size sync.
        train = jnp.asarray(ep >= warmup_episodes)
        params, opt_state, buf, metrics = chunk(
            params, opt_state, buf, rkeys, akeys, ku, train, scenario
        )
        _reduced_chunk_metrics(result, seen, jax.device_get(metrics), ep,
                               episodes, num_envs)
        ep += num_envs

    if checkpoint_dir and last_saved != ep:
        _save(ep)

    result.params = params  # type: ignore[attr-defined]
    return result


def evaluate_sac(env: MHSLEnv, params, cfg: SAC.SACConfig, episodes: int = 20,
                 seed: int = 1000, scenario=None) -> Dict[str, float]:
    """Policy evaluation: all ``episodes`` run as one vmapped population
    (fresh geometry per episode, matching the seed's evaluation draw).
    ``scenario`` sweeps evaluation physics without recompiling; for a
    whole grid in one call use ``repro.core.scenario.evaluate_population``.
    """
    key = jax.random.PRNGKey(seed)
    k_reset, k_act = jax.random.split(key)
    rollout = R.make_batched_rollout(
        env, R.sac_policy(env.action_dims, cfg), cfg.hist_len
    )
    st0 = R.make_batched_reset(env)(jax.random.split(k_reset, episodes),
                                    scenario)
    _, traj = rollout(params, st0, jax.random.split(k_act, episodes), scenario)
    return {
        "reward": float(jnp.sum(traj["reward"])) / episodes,
        "leak": float(jnp.sum(traj["leak"])) / episodes,
    }
