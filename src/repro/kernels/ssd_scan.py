"""Pallas TPU kernel for the Mamba-2 SSD chunk recurrence.

TPU-native design:
  * grid (batch, heads, n_chunks) with the chunk dimension innermost and
    sequential; the running state h (head_dim x d_state) lives in VMEM
    scratch across chunk steps - the inter-chunk recurrence never touches
    HBM;
  * per step the kernel computes the intra-chunk (quadratic) term with two
    (chunk x chunk) MXU matmuls + the state in/out contributions, exactly
    mirroring ``repro.models.ssm.ssd_chunked``;
  * chunk length defaults to 64 and head_dim/d_state are zero-padded to
    lane multiples by the wrapper when needed.

Validated in interpret mode against ``ref.ssd_scan_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    x_ref,  # (1, chunk, 1, P)
    dt_ref,  # (1, chunk, 1)
    a_ref,  # (1,)  decay rate for this head
    b_ref,  # (1, chunk, N)
    c_ref,  # (1, chunk, N)
    y_ref,  # (1, chunk, 1, P)
    hout_ref,  # (1, 1, P, N) final state
    h_ref,  # VMEM scratch (P, N)
    *,
    chunk: int,
    n_chunks: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (L,)
    a = a_ref[0].astype(jnp.float32)  # scalar
    bm = b_ref[0].astype(jnp.float32)  # (L, N)
    cm = c_ref[0].astype(jnp.float32)  # (L, N)

    da = dt * a  # (L,) log-decay per step
    da_cum = jnp.cumsum(da)  # (L,)

    # intra-chunk: decay[i,j] = exp(da_cum[i] - da_cum[j]) for j <= i
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tril = lj <= li
    decay = jnp.where(tril, jnp.exp(da_cum[:, None] - da_cum[None, :]), 0.0)
    scores = (
        jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
        * decay
    )  # (L, L)
    y_diag = jax.lax.dot_general(
        scores * dt[None, :], x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (L, P)

    # contribution of the incoming state
    state_decay = jnp.exp(da_cum)  # (L,)
    y_off = (
        jax.lax.dot_general(cm, h_ref[...], (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
        * state_decay[:, None]
    )  # (L, P)
    y_ref[0, :, 0, :] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: h' = exp(sum da) h + sum_l exp(da_cum[-1]-da_cum[l]) dt_l x_l b_l^T
    decay_states = jnp.exp(da_cum[-1] - da_cum) * dt  # (L,)
    upd = jax.lax.dot_general(
        x * decay_states[:, None], bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (P, N)
    h_ref[...] = h_ref[...] * jnp.exp(da_cum[-1]) + upd

    @pl.when(ic == n_chunks - 1)
    def _emit():
        hout_ref[0, 0, :, :] = h_ref[...].astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,  # (B, S, H, P) float32
    dt: jax.Array,  # (B, S, H)
    a: jax.Array,  # (H,)
    b: jax.Array,  # (B, S, N)
    c: jax.Array,  # (B, S, N)
    *,
    chunk: int = 64,
    interpret: bool = True,
):
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    n_chunks = x.shape[1] // chunk

    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    y, h_last = pl.pallas_call(
        kernel,
        grid=(bsz, h, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk, 1), lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec((1,), lambda ib, ih, ic: (ih,)),
            pl.BlockSpec((1, chunk, n), lambda ib, ih, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, n), lambda ib, ih, ic: (ib, ic, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, 1, p, n), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, n_chunks * chunk, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b, c)
    return y[:, :s], h_last
