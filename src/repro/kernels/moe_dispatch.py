"""Pallas grouped expert-FFN kernel for dropless MoE dispatch.

``layers.moe_apply_dropless`` sorts the (T*k) routed token copies by
expert id and packs them into per-expert regions padded to ``blk``-row
blocks, so every grid step processes ONE ``(blk, D)`` row tile that
belongs to exactly one expert. This kernel runs the expert FFN over that
padded buffer:

  * grid ``(n_blocks,)``; each step reads its ``(blk, D)`` tile plus a
    one-element ``block_eid`` tile naming the owning expert, and the
    expert weight stacks ride along whole (``(E, D, F)``/``(E, F, D)``
    fit VMEM at split-executor sizes - a TPU production variant would
    swap the whole-stack loads for scalar-prefetch weight BlockSpecs);
  * per tile: up/gate matmuls, activation, down-projection, all with
    ``preferred_element_type=jnp.float32`` - no HBM round-trip between
    them. Padding rows are zero; FFN(0) rows are never gathered back.

The backward pass is the jax AD of ``grouped_ffn_reference`` (the
mathematically-identical gathered-weight batched einsum), the same
custom-VJP pattern as ``stage_block`` - pallas_call has no transpose
rule, so gradients are reference-exact by construction.

``interpret=None`` resolves from the backend (compiled on TPU, Pallas
interpreter elsewhere). Forward AND grad are validated bitwise against
the dense per-expert reference in ``tests/test_moe_dropless.py``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _act(name: str, g, u):
    if name == "swiglu":
        return jax.nn.silu(g) * u
    if name == "gelu":
        return jax.nn.gelu(u)
    if name == "relu2":
        return jnp.square(jax.nn.relu(u))
    if name == "silu":
        return jax.nn.silu(u)
    raise KeyError(name)


def grouped_ffn_reference(buf, block_eid, w_gate, w_up, w_down,
                          activation: str):
    """Expert FFN over a block-padded expert-sorted buffer, pure jnp.

    ``buf``: (P, D) rows grouped so rows ``[i*blk, (i+1)*blk)`` all belong
    to expert ``block_eid[i]``; ``block_eid``: (n_blocks,) int32. Weights
    are the ``init_moe`` stacks (``w_gate`` may be None). Returns (P, D).
    """
    nb = block_eid.shape[0]
    p, d = buf.shape
    blk = p // nb
    xb = buf.reshape(nb, blk, d)
    dt = buf.dtype
    wu = w_up.astype(dt)[block_eid]      # (nb, D, F)
    wd = w_down.astype(dt)[block_eid]    # (nb, F, D)
    if activation == "swiglu":
        wg = w_gate.astype(dt)[block_eid]
        g = jnp.einsum("nbd,ndf->nbf", xb, wg,
                       preferred_element_type=jnp.float32).astype(dt)
        u = jnp.einsum("nbd,ndf->nbf", xb, wu,
                       preferred_element_type=jnp.float32).astype(dt)
    else:
        g = None
        u = jnp.einsum("nbd,ndf->nbf", xb, wu,
                       preferred_element_type=jnp.float32).astype(dt)
    h = _act(activation, g, u).astype(dt)
    out = jnp.einsum("nbf,nfd->nbd", h, wd,
                     preferred_element_type=jnp.float32)
    return out.reshape(p, d).astype(dt)


def _kernel_gated(x_ref, eid_ref, wg_ref, wu_ref, wd_ref, out_ref, *,
                  activation: str):
    x = x_ref[...]  # (blk, D)
    dt = x.dtype
    e = eid_ref[0]
    wg = jax.lax.dynamic_index_in_dim(wg_ref[...].astype(dt), e, 0, False)
    wu = jax.lax.dynamic_index_in_dim(wu_ref[...].astype(dt), e, 0, False)
    wd = jax.lax.dynamic_index_in_dim(wd_ref[...].astype(dt), e, 0, False)
    g = jnp.dot(x, wg, preferred_element_type=jnp.float32).astype(dt)
    u = jnp.dot(x, wu, preferred_element_type=jnp.float32).astype(dt)
    h = _act(activation, g, u).astype(dt)
    out_ref[...] = jnp.dot(
        h, wd, preferred_element_type=jnp.float32).astype(out_ref.dtype)


def _kernel_plain(x_ref, eid_ref, wu_ref, wd_ref, out_ref, *,
                  activation: str):
    x = x_ref[...]
    dt = x.dtype
    e = eid_ref[0]
    wu = jax.lax.dynamic_index_in_dim(wu_ref[...].astype(dt), e, 0, False)
    wd = jax.lax.dynamic_index_in_dim(wd_ref[...].astype(dt), e, 0, False)
    u = jnp.dot(x, wu, preferred_element_type=jnp.float32).astype(dt)
    h = _act(activation, None, u).astype(dt)
    out_ref[...] = jnp.dot(
        h, wd, preferred_element_type=jnp.float32).astype(out_ref.dtype)


def _forward(buf, block_eid, w_gate, w_up, w_down, activation: str,
             interpret: bool):
    p, d = buf.shape
    nb = block_eid.shape[0]
    blk = p // nb
    e, _, f = w_up.shape
    row_spec = pl.BlockSpec((blk, d), lambda i: (i, 0))
    eid_spec = pl.BlockSpec((1,), lambda i: (i,))
    whole = lambda shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))
    if activation == "swiglu":
        kernel = functools.partial(_kernel_gated, activation=activation)
        in_specs = [row_spec, eid_spec, whole((e, d, f)), whole((e, d, f)),
                    whole((e, f, d))]
        args = (buf, block_eid, w_gate, w_up, w_down)
    else:
        kernel = functools.partial(_kernel_plain, activation=activation)
        in_specs = [row_spec, eid_spec, whole((e, d, f)), whole((e, f, d))]
        args = (buf, block_eid, w_up, w_down)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=in_specs,
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((p, d), buf.dtype),
        interpret=interpret,
    )(*args)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _grouped(buf, block_eid, w_gate, w_up, w_down, activation, interpret):
    return _forward(buf, block_eid, w_gate, w_up, w_down, activation,
                    interpret)


def _grouped_fwd(buf, block_eid, w_gate, w_up, w_down, activation,
                 interpret):
    out = _forward(buf, block_eid, w_gate, w_up, w_down, activation,
                   interpret)
    return out, (buf, block_eid, w_gate, w_up, w_down)


def _grouped_bwd(activation, interpret, residuals, g):
    buf, block_eid, w_gate, w_up, w_down = residuals
    _, vjp = jax.vjp(
        lambda b, wg, wu, wd: grouped_ffn_reference(
            b, block_eid, wg, wu, wd, activation),
        buf, w_gate, w_up, w_down,
    )
    db, dwg, dwu, dwd = vjp(g)
    return db, None, dwg, dwu, dwd


_grouped.defvjp(_grouped_fwd, _grouped_bwd)

_grouped_jitted = jax.jit(_grouped, static_argnums=(5, 6))


def grouped_moe_ffn(buf, block_eid, params, *, activation: str,
                    interpret: Optional[bool] = None):
    """Fused grouped expert FFN over a block-padded sorted buffer.

    ``params`` is the ``models.layers.init_moe`` dict. ``interpret=None``
    resolves from the backend: the compiled kernel on TPU, the Pallas
    interpreter everywhere else.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    w_gate = params.get("w_gate", params["w_up"])
    return _grouped_jitted(buf, block_eid, w_gate, params["w_up"],
                           params["w_down"], activation, interpret)
