"""Pallas TPU flash attention: causal, GQA, optional sliding window.

TPU-native design:
  * grid (batch, q_head, n_q, n_kv) with the kv dimension innermost and
    sequential: online-softmax state (m, l, acc) lives in VMEM scratch
    across kv steps - the HBM->VMEM working set per step is one
    (q_blk, hd) query tile plus one (kv_blk, hd) K/V tile each;
  * block shapes default to 128 - multiples of the 128-wide MXU;
  * GQA indexes the kv head as h // (H // KH) in the BlockSpec index map,
    so no repeated-KV copy ever exists in HBM;
  * fully-masked causal blocks are skipped with @pl.when (the grid still
    visits them, but no MXU work is issued).

Validated in interpret mode against ``ref.flash_attention_ref``.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref,  # (1, q_blk, 1, hd), (1, kv_blk, 1, hd)
    o_ref,  # (1, q_blk, 1, hd)
    acc_ref, m_ref, l_ref,  # VMEM scratch: (q_blk, hd), (q_blk,), (q_blk,)
    *,
    scale: float,
    q_blk: int,
    kv_blk: int,
    n_kv: int,
    causal: bool,
    window: Optional[int],
    q_offset: int,
    seq_kv: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # block-level causal/window reachability (python-level only when static)
    qpos = q_offset + iq * q_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, kv_blk), 0)
    kpos = ik * kv_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, kv_blk), 1)
    needed = jnp.asarray(True)
    if causal:
        needed &= ik * kv_blk <= q_offset + iq * q_blk + q_blk - 1
    if window is not None:
        needed &= (ik + 1) * kv_blk - 1 > q_offset + iq * q_blk - window

    @pl.when(needed)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # (q_blk, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (kv_blk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = (
            jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
            )
            * scale
        )  # (q_blk, kv_blk)
        ok = kpos < seq_kv
        if causal:
            ok &= kpos <= qpos
        if window is not None:
            ok &= kpos > qpos - window
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _emit():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_blk", "kv_blk", "q_offset", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Skv, KH, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_blk: int = 128,
    kv_blk: int = 128,
    q_offset: int = 0,
    interpret: bool = True,
) -> jax.Array:
    b, sq, h, hd = q.shape
    skv, kh = k.shape[1], k.shape[2]
    g = h // kh
    q_blk = min(q_blk, sq)
    kv_blk = min(kv_blk, skv)
    n_q = -(-sq // q_blk)
    n_kv = -(-skv // kv_blk)
    pad_q = n_q * q_blk - sq
    pad_kv = n_kv * kv_blk - skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))

    kernel = functools.partial(
        _kernel,
        scale=1.0 / math.sqrt(hd),
        q_blk=q_blk,
        kv_blk=kv_blk,
        n_kv=n_kv,
        causal=causal,
        window=window,
        q_offset=q_offset,
        seq_kv=skv,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, q_blk, 1, hd), lambda ib, ih, iq, ik: (ib, iq, ih, 0)),
            pl.BlockSpec((1, kv_blk, 1, hd), lambda ib, ih, iq, ik: (ib, ik, ih // g, 0)),
            pl.BlockSpec((1, kv_blk, 1, hd), lambda ib, ih, iq, ik: (ib, ik, ih // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_blk, 1, hd), lambda ib, ih, iq, ik: (ib, iq, ih, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_q * q_blk, h, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_blk, hd), jnp.float32),
            pltpu.VMEM((q_blk,), jnp.float32),
            pltpu.VMEM((q_blk,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]
