"""Jit'd public wrappers for the Pallas kernels.

On this CPU container ``interpret=True`` executes the kernel bodies in
Python for correctness validation; on TPU pass ``interpret=False``.
"""
from repro.kernels.ca_attention import ca_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_dispatch import grouped_moe_ffn
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.stage_block import stage_mlp_block

__all__ = ["ca_attention", "flash_attention", "grouped_moe_ffn", "ssd_scan",
           "stage_mlp_block"]
