"""Pallas fused history cross-attention for the CA actor (paper Eq. 24).

``agents.attention.cross_attention`` scores a full ``(batch, I+1, C)``
query block - the current-state query s(n) stacked on the I history
queries - but the actor consumes ONLY the current-state row of the
attended output. The reference therefore pays ``(I+1) x I`` score work
(plus the W_Q H projection of every history row) for one useful row.

This kernel fuses the useful part into a single VMEM-resident pass per
batch tile:

  * grid ``(n_b,)`` over batch tiles of ``blk`` rows; the projection
    weights ride along whole (they are tiny: pair_dim x C);
  * per tile: one ``(blk, obs_dim) @ (obs_dim, C)`` query projection,
    the K/V projections of the ``(blk*I, pair_dim)`` history block, the
    masked ``(blk, I)`` score row for the current-state query only, a
    numerically-stable softmax, and the weighted V reduction - no
    ``(I+1, I)`` score matrix, no W_Q H projection, no HBM round-trip
    between the five ops;
  * masking uses ``jnp.finfo(dtype).min`` (not a ``-1e9`` literal), so
    the kernel stays correct when scores are bf16/fp16;
  * rows with no valid history attend to nothing and emit zeros, exactly
    like the reference's ``any_valid`` guard.

``interpret=True`` (the default) executes the kernel body on CPU for
parity testing against ``agents.attention.cross_attention``
(``tests/test_kernels.py``); pass ``interpret=False`` on TPU.
"""
from __future__ import annotations

import functools
import math

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(obs_ref, hist_ref, mask_ref, wqs_ref, wk_ref, wv_ref, out_ref,
            *, scale: float):
    obs = obs_ref[...]  # (blk, obs_dim)
    hist = hist_ref[...]  # (blk, I, pair_dim)
    blk, i, pair_dim = hist.shape

    q = jnp.dot(obs, wqs_ref[...], preferred_element_type=jnp.float32)
    h2 = hist.reshape(blk * i, pair_dim)
    k = jnp.dot(h2, wk_ref[...], preferred_element_type=jnp.float32)
    v = jnp.dot(h2, wv_ref[...], preferred_element_type=jnp.float32)
    k = k.reshape(blk, i, -1)
    v = v.reshape(blk, i, -1)

    # current-state query row only: (blk, I) scores on the VPU (I is tiny,
    # so an MXU batched matmul would waste the systolic array)
    s = (q[:, None, :] * k).sum(axis=-1) * scale
    valid = mask_ref[...] > 0  # (blk, I)
    s = jnp.where(valid, s, jnp.finfo(s.dtype).min)
    s = s - s.max(axis=-1, keepdims=True)
    e = jnp.exp(s)
    w = e / e.sum(axis=-1, keepdims=True)
    att = (w[:, :, None] * v).sum(axis=1)  # (blk, C)
    att = jnp.where(valid.any(axis=-1)[:, None], att, 0.0)
    out_ref[...] = att.astype(out_ref.dtype)


def _ca_forward(params, obs: jax.Array, history: jax.Array,
                hist_mask: jax.Array, blk: int, interpret: bool) -> jax.Array:
    b, obs_dim = obs.shape
    i, pair_dim = history.shape[1], history.shape[2]
    c = params["wk"].shape[-1]
    blk = min(blk, b)
    n_b = -(-b // blk)
    pad = n_b * blk - b
    if pad:
        obs = jnp.pad(obs, ((0, pad), (0, 0)))
        history = jnp.pad(history, ((0, pad), (0, 0), (0, 0)))
        # padded rows carry an all-invalid mask and emit zeros
        hist_mask = jnp.pad(hist_mask, ((0, pad), (0, 0)))

    kernel = functools.partial(_kernel, scale=1.0 / math.sqrt(c))
    s_prime = pl.pallas_call(
        kernel,
        grid=(n_b,),
        in_specs=[
            pl.BlockSpec((blk, obs_dim), lambda ib: (ib, 0)),
            pl.BlockSpec((blk, i, pair_dim), lambda ib: (ib, 0, 0)),
            pl.BlockSpec((blk, i), lambda ib: (ib, 0)),
            pl.BlockSpec((obs_dim, c), lambda ib: (0, 0)),
            pl.BlockSpec((pair_dim, c), lambda ib: (0, 0)),
            pl.BlockSpec((pair_dim, c), lambda ib: (0, 0)),
        ],
        out_specs=pl.BlockSpec((blk, c), lambda ib: (ib, 0)),
        out_shape=jax.ShapeDtypeStruct((n_b * blk, c), obs.dtype),
        interpret=interpret,
    )(obs, history, hist_mask, params["wq_s"], params["wk"], params["wv"])
    return jnp.concatenate([obs[:b], s_prime[:b]], axis=-1)


# Training reaches this kernel through the actor loss, and pallas_call has
# no built-in transpose rule - so the backward pass is the jax AD of the
# mathematically-identical slim reference (custom-VJP kernel pattern).
# ``wq_h`` receives its exact zero cotangent like every other unused leaf.
@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _ca(params, obs, history, hist_mask, blk, interpret):
    return _ca_forward(params, obs, history, hist_mask, blk, interpret)


def _ca_fwd(params, obs, history, hist_mask, blk, interpret):
    out = _ca_forward(params, obs, history, hist_mask, blk, interpret)
    return out, (params, obs, history, hist_mask)


def _ca_bwd(blk, interpret, residuals, g):
    from repro.core.agents.attention import cross_attention_slim

    params, obs, history, hist_mask = residuals
    _, vjp = jax.vjp(
        lambda p, o, h: cross_attention_slim(p, o, h, hist_mask),
        params, obs, history,
    )
    dp, do, dh = vjp(g)
    return dp, do, dh, jnp.zeros_like(hist_mask)


_ca.defvjp(_ca_fwd, _ca_bwd)


_ca_jitted = jax.jit(_ca, static_argnums=(4, 5))


def ca_attention(params, obs: jax.Array, history: jax.Array,
                 hist_mask: jax.Array, *, blk: int = 128,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Fused masked history cross-attention (batched call sites only).

    ``params``: the ``agents.attention.init_cross_attention`` dict
    (``wq_h`` is unused - only the current-state query row survives to
    the output). ``obs`` (B, obs_dim), ``history`` (B, I, pair_dim)
    newest-last, ``hist_mask`` (B, I) with 1 = valid pair. Returns
    ``(B, obs_dim + C)``: the observation concatenated with the attended
    summary, matching ``cross_attention``'s output contract.
    Differentiable: the backward pass runs the slim reference's VJP.

    ``interpret=None`` (the default, and what ``SACConfig.ca_impl``'s
    call sites use) resolves from the backend: the compiled kernel on
    TPU, the Pallas interpreter everywhere else.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _ca_jitted(params, obs, history, hist_mask, blk, interpret)
