"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Skv, KH, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
) -> jax.Array:
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    kr = jnp.repeat(k, g, axis=2)
    vr = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32))
    s *= 1.0 / math.sqrt(hd)
    qpos = q_offset + jnp.arange(sq)
    kpos = jnp.arange(k.shape[1])
    ok = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        ok &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        ok &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(ok[None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, vr.astype(jnp.float32))
    return out.astype(q.dtype)


def ssd_scan_ref(x, dt, a, b, c, *, chunk: int = 64, h0=None):
    """Delegates to the model's chunked SSD implementation."""
    from repro.models.ssm import ssd_chunked

    return ssd_chunked(x, dt, a, b, c, chunk=chunk, h0=h0)
