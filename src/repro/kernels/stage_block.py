"""Pallas fused residual-stage kernel for the split executor.

One pipeline-stage block spends its MLP half in five HBM round-trips when
composed from ``models/layers.py`` primitives: RMSNorm read/write, the
up/gate matmuls, the activation, the down matmul, and the residual add.
This kernel fuses the whole residual half-block

    y = x + w_down @ act(rms_norm(x) @ w_up [, rms_norm(x) @ w_gate])

into a single VMEM-resident pass per row tile:

  * grid ``(n_r,)`` over row tiles of ``blk`` tokens (the ``(B, S, D)``
    activation is flattened to ``(B*S, D)`` rows); the weights ride along
    whole - at split-executor sizes ``(D, F)`` fits VMEM comfortably;
  * per tile: the f32 RMSNorm, the up/gate matmuls and the down-projection
    all with ``preferred_element_type=jnp.float32`` (fp32 accumulate even
    for bf16 activations), the activation nonlinearity on the VPU, and the
    residual add - no HBM round-trip between the five ops;
  * supported activations: ``swiglu`` (gated), ``gelu``, ``relu2``,
    ``silu`` - everything ``models/layers.py`` offers; MoE half-blocks
    stay on the reference path (the scatter/gather dispatch does not fit
    a single fused tile).

``interpret=None`` resolves from the backend (compiled on TPU, Pallas
interpreter elsewhere), exactly like ``ca_attention``. The backward pass
is the jax AD of the mathematically-identical ``models.layers.mlp_block``
reference (custom-VJP kernel pattern - pallas_call has no transpose
rule), so gradients are reference-exact by construction.

Selected by ``PipelineConfig.stage_impl == "pallas"`` through
``models.model.block_apply``'s ``impl="pallas_stage"`` routing; validated
forward AND grad against the reference in ``tests/test_kernels.py``.
"""
from __future__ import annotations

import functools

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _act(name: str, g, u):
    """Gated/plain activation in f32. ``g`` is None for ungated MLPs."""
    if name == "swiglu":
        return jax.nn.silu(g) * u
    if name == "gelu":
        return jax.nn.gelu(u)
    if name == "relu2":
        return jnp.square(jax.nn.relu(u))
    if name == "silu":
        return jax.nn.silu(u)
    raise KeyError(name)


def _kernel_gated(x_ref, nw_ref, wg_ref, wu_ref, wd_ref, out_ref, *,
                  activation: str, eps: float):
    x = x_ref[...]  # (blk, D)
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    h = (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * nw_ref[...].astype(dt)
    g = jnp.dot(h, wg_ref[...].astype(dt), preferred_element_type=jnp.float32)
    u = jnp.dot(h, wu_ref[...].astype(dt), preferred_element_type=jnp.float32)
    hcurr = _act(activation, g, u).astype(dt)
    y = jnp.dot(hcurr, wd_ref[...].astype(dt), preferred_element_type=jnp.float32)
    out_ref[...] = (x32 + y).astype(out_ref.dtype)


def _kernel_plain(x_ref, nw_ref, wu_ref, wd_ref, out_ref, *,
                  activation: str, eps: float):
    x = x_ref[...]
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    h = (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * nw_ref[...].astype(dt)
    u = jnp.dot(h, wu_ref[...].astype(dt), preferred_element_type=jnp.float32)
    hcurr = _act(activation, None, u).astype(dt)
    y = jnp.dot(hcurr, wd_ref[...].astype(dt), preferred_element_type=jnp.float32)
    out_ref[...] = (x32 + y).astype(out_ref.dtype)


def _forward(norm_w, params, x, activation: str, eps: float, blk: int,
             interpret: bool):
    b, s, d = x.shape
    rows = b * s
    xr = x.reshape(rows, d)
    blk = min(blk, rows)
    n_r = -(-rows // blk)
    pad = n_r * blk - rows
    if pad:
        # padded rows are all-zero: rsqrt(0 + eps) is finite, so they just
        # compute garbage that is sliced away below
        xr = jnp.pad(xr, ((0, pad), (0, 0)))

    gated = activation == "swiglu"
    f = params["w_up"].shape[-1]
    row_spec = pl.BlockSpec((blk, d), lambda i: (i, 0))
    whole = lambda shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))
    if gated:
        kernel = functools.partial(_kernel_gated, activation=activation, eps=eps)
        in_specs = [row_spec, whole((d,)), whole((d, f)), whole((d, f)),
                    whole((f, d))]
        args = (xr, norm_w, params["w_gate"], params["w_up"], params["w_down"])
    else:
        kernel = functools.partial(_kernel_plain, activation=activation, eps=eps)
        in_specs = [row_spec, whole((d,)), whole((d, f)), whole((f, d))]
        args = (xr, norm_w, params["w_up"], params["w_down"])
    out = pl.pallas_call(
        kernel,
        grid=(n_r,),
        in_specs=in_specs,
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((n_r * blk, d), x.dtype),
        interpret=interpret,
    )(*args)
    return out[:rows].reshape(b, s, d)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _fused(norm_w, params, x, activation, eps, blk, interpret):
    return _forward(norm_w, params, x, activation, eps, blk, interpret)


def _fused_fwd(norm_w, params, x, activation, eps, blk, interpret):
    out = _forward(norm_w, params, x, activation, eps, blk, interpret)
    return out, (norm_w, params, x)


def _fused_bwd(activation, eps, blk, interpret, residuals, g):
    from repro.models.layers import mlp_block

    norm_w, params, x = residuals
    _, vjp = jax.vjp(
        lambda nw, p, xx: mlp_block(nw, p, xx, activation, eps),
        norm_w, params, x,
    )
    return vjp(g)


_fused.defvjp(_fused_fwd, _fused_bwd)

_fused_jitted = jax.jit(_fused, static_argnums=(3, 4, 5, 6))


def stage_mlp_block(norm_w, params, x, *, activation: str, eps: float = 1e-6,
                    blk: int = 128, interpret: Optional[bool] = None):
    """Fused residual MLP half-block: ``x + mlp(rms_norm(x, norm_w))``.

    ``params`` is the ``models.layers.init_mlp`` dict; ``x`` is
    ``(B, S, D)``. Forward runs the fused Pallas kernel (fp32 accumulate);
    backward runs the ``models.layers.mlp_block`` reference VJP.
    ``interpret=None`` resolves from the backend: the compiled kernel on
    TPU, the Pallas interpreter everywhere else.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _fused_jitted(norm_w, params, x, activation, eps, blk, interpret)
