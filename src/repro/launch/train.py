"""Training launcher: real execution at reduced scale, or full-scale lower.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --steps 50 [--reduced] [--data-par 2 --model-par 2]

On this CPU container use --reduced (default). On a real TPU slice, drop
--reduced and the same code path shards the full architecture over the
production mesh.
"""
import argparse
import os
import time

# host device count must be set before jax import when multi-device CPU
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import save_pytree
from repro.configs import get_config
from repro.data import synthetic_stream
from repro.distribution.context import activation_sharding
from repro.distribution.sharding import batch_axes, param_shardings
from repro.launch.mesh import make_host_mesh
from repro.models import init_params, make_train_step
from repro.optim import adamw, linear_warmup_cosine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--data-par", type=int, default=2)
    ap.add_argument("--model-par", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--bf16-compute", action="store_true")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh(args.data_par, args.model_par)
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}  model: {cfg.name}")

    params = init_params(jax.random.PRNGKey(0), cfg)
    psh = param_shardings(jax.eval_shape(lambda: params), cfg, mesh)
    params = jax.tree.map(lambda a, s: jax.device_put(a, s), params, psh)
    opt = adamw(linear_warmup_cosine(args.lr, 10, args.steps), max_grad_norm=1.0)
    opt_state = opt.init(params)
    osh = param_shardings(jax.eval_shape(lambda: opt_state), cfg, mesh)

    step_fn = make_train_step(
        cfg, opt, compute_copy_dtype=jnp.bfloat16 if args.bf16_compute else None
    )
    baxes = batch_axes(mesh, args.batch)
    stream = synthetic_stream(cfg, args.batch, args.seq)
    ex = next(stream)
    bsh = {k: NamedSharding(mesh, P(baxes, *([None] * (v.ndim - 1)))) for k, v in ex.items()}
    jitted = jax.jit(step_fn, in_shardings=(psh, osh, bsh), out_shardings=(psh, osh, None))

    t0 = time.time()
    with activation_sharding(mesh, baxes):
        for step in range(args.steps):
            batch = jax.tree.map(lambda a, s: jax.device_put(a, s), next(stream), bsh)
            params, opt_state, m = jitted(params, opt_state, batch)
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step:4d}  loss {float(m['loss']):.4f}  "
                      f"({time.time() - t0:.1f}s)", flush=True)
    if args.ckpt:
        save_pytree(params, args.ckpt)
        print(f"saved -> {args.ckpt}")


if __name__ == "__main__":
    main()
