"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state. The dry-run launcher forces 512
host devices via XLA_FLAGS *before* any jax import.

Target hardware: TPU v5e pods, 256 chips/pod (16x16 ICI torus); multi-pod =
2 pods over DCN. Axes: 'data' (FSDP+DP), 'model' (tensor parallel), 'pod'
(pure DP over DCN).
"""
from __future__ import annotations

import inspect

import jax


def _make_mesh(shape, axes):
    """jax.make_mesh across jax versions: ``axis_types`` (and
    ``jax.sharding.AxisType``) only exist on newer releases; every axis here
    is Auto anyway, which is also the default where the kwarg exists."""
    if "axis_types" in inspect.signature(jax.make_mesh).parameters and hasattr(
        jax.sharding, "AxisType"
    ):
        types = (jax.sharding.AxisType.Auto,) * len(axes)
        return jax.make_mesh(shape, axes, axis_types=types)
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many devices the host actually has (tests)."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return _make_mesh((data, model), ("data", "model"))


def make_stage_mesh(n_stages: int, stage_axis: str = "stage"):
    """1-D mesh over host devices for the split executor's pipeline stages.

    Stage k of a ``SplitPlan`` runs on device k; ``ppermute`` hops along
    this axis play the paper's wireless activation/gradient hops. Builds
    ``Mesh`` directly from an explicit device slice (``jax.make_mesh``
    picks devices itself, and the stage order must be pinned), so it does
    NOT go through ``_make_mesh`` - it lives here with the other mesh
    constructors for discoverability.
    """
    import numpy as np
    from jax.sharding import Mesh

    devs = jax.devices()
    assert len(devs) >= n_stages, f"need {n_stages} devices, have {len(devs)}"
    return Mesh(np.array(devs[:n_stages]), (stage_axis,))


def make_stage_env_mesh(n_stages: int, n_envs: int | None = None,
                        stage_axis: str = "stage", env_axis: str = "env"):
    """2-D (stage x env) mesh: pipelined stage compute per scenario shard.

    Row s, column e holds stage ``s`` of the split model for env shard
    ``e``: the split executor ppermutes activations along ``stage_axis``
    (hops pinned to device order, like :func:`make_stage_mesh`) while the
    population/data axis shards microbatch rows or scenario sweeps along
    ``env_axis`` - ``distribution.sharding.population_axes`` picks the
    ``'env'`` axis by NAME, so ``train_population`` drives this mesh
    unchanged. ``n_envs=None`` takes every remaining device
    (``len(devices) // n_stages``).
    """
    import numpy as np
    from jax.sharding import Mesh

    devs = jax.devices()
    if n_envs is None:
        n_envs = len(devs) // n_stages
    need = n_stages * n_envs
    assert n_envs >= 1 and len(devs) >= need, \
        f"need {n_stages}x{n_envs} devices, have {len(devs)}"
    grid = np.array(devs[:need]).reshape(n_stages, n_envs)
    return Mesh(grid, (stage_axis, env_axis))


def make_population_mesh(num_devices: int | None = None, axis: str = "env"):
    """1-D mesh over host devices for the RL engine's population axis.

    The vectorized trainers shard the ``num_envs`` / scenario axis of their
    env states and replay buffers over this mesh (agent params stay
    replicated); a 1-device mesh is the bit-identical fallback to the plain
    vmap path. ``num_devices=None`` takes every device the host has.
    """
    devs = jax.devices()
    n = len(devs) if num_devices is None else num_devices
    assert n <= len(devs), (n, len(devs))
    return _make_mesh((n,), (axis,))
