"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state. The dry-run launcher forces 512
host devices via XLA_FLAGS *before* any jax import.

Target hardware: TPU v5e pods, 256 chips/pod (16x16 ICI torus); multi-pod =
2 pods over DCN. Axes: 'data' (FSDP+DP), 'model' (tensor parallel), 'pod'
(pure DP over DCN).
"""
from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many devices the host actually has (tests)."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"), axis_types=_auto(2))
