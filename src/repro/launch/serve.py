"""Serving launcher: sharded prefill + decode loop with resident weights.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
        --batch 4 --prompt-len 64 --gen 16 [--data-par 2 --model-par 2]

Uses serve-mode sharding (weights resident per chip, no FSDP axis) - the
SPerf-validated configuration for decode.
"""
import argparse
import os
import time

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.distribution.context import activation_sharding
from repro.distribution.sharding import batch_axes, cache_shardings, param_shardings
from repro.launch.mesh import make_host_mesh
from repro.models import init_caches, init_params, make_decode_step, make_prefill_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--data-par", type=int, default=2)
    ap.add_argument("--model-par", type=int, default=2)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    mesh = make_host_mesh(args.data_par, args.model_par)
    params = init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.bfloat16)
    psh = param_shardings(jax.eval_shape(lambda: params), cfg, mesh, mode="serve")
    params = jax.tree.map(lambda a, s: jax.device_put(a, s), params, psh)

    cache_len = args.prompt_len + args.gen
    caches = init_caches(cfg, args.batch, cache_len)
    csh = cache_shardings(jax.eval_shape(lambda: caches), cfg, mesh, args.batch)
    caches = jax.tree.map(lambda a, s: jax.device_put(a, s), caches, csh)

    baxes = batch_axes(mesh, args.batch)
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))

    rng = np.random.default_rng(0)
    prompts = jax.device_put(
        jnp.asarray(rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32),
        NamedSharding(mesh, P(baxes, None)),
    )
    with activation_sharding(mesh, baxes):
        t0 = time.time()
        logits, caches = prefill(params, prompts, caches)
        logits.block_until_ready()
        print(f"prefill {args.batch}x{args.prompt_len}: {(time.time()-t0)*1e3:.1f} ms")
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        t0 = time.time()
        for i in range(args.gen - 1):
            logits, caches = decode(params, tok, caches,
                                    jnp.asarray(args.prompt_len + i, jnp.int32))
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        jax.block_until_ready(tok)
        dt = time.time() - t0
        print(f"decode {args.gen-1} steps: {dt*1e3:.1f} ms "
              f"({(args.gen-1)*args.batch/max(dt,1e-9):.0f} tok/s)")


if __name__ == "__main__":
    main()
