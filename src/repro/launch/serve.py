"""Config-driven serving launcher: continuous-batching engine or static.

    # continuous service on a Poisson trace, single device
    PYTHONPATH=src python -m repro.launch.serve --mode engine \
        --requests 32 --rate 8.0

    # split serving: 2-stage plan with per-stage KV rings
    XLA_FLAGS=--xla_force_host_platform_device_count=2 \
    PYTHONPATH=src python -m repro.launch.serve --mode engine \
        --set boundaries 1,2

    # everything from a reviewable JSON config, CLI keys override
    PYTHONPATH=src python -m repro.launch.serve --config serve.json \
        --set num_slots 16 --set decode_chunk 4

Every engine/scheduler knob is a :class:`repro.serving.ServeConfig`
field: the launcher loads ``--config`` (JSON), applies ``--set key
value`` overrides, and runs. ``--mode static`` runs the same trace
through the static-batch baseline (``generate_static``: batch, wait for
ALL rows, next batch) for an apples-to-apples comparison.

The v0 ``--data-par/--model-par`` mesh flags are gone: serving
parallelism is now the SPLIT PLAN (``boundaries`` -> pipeline stages
with per-stage KV rings), which is the deployment shape the paper
actually optimizes.
"""
from __future__ import annotations

import argparse
import json

import numpy as np


def run_static(cfg, trace, *, warmup: bool = False):
    """Static-batch baseline: admit in arrival order, N at a time, wait
    for the whole batch (every row pays the batch max gen length).

    ``warmup=True`` runs one throwaway batch before the clock starts so
    the reported wall time excludes the generate compile (the benchmark
    comparison point; the engine side warms the same way).
    """
    import time

    import jax
    import jax.numpy as jnp

    from repro.models import init_params
    from repro.serving.batching import make_generate_fn
    from repro.serving.runners import PipelineRunner, SingleDeviceRunner

    model_cfg = cfg.model_config()
    params = init_params(jax.random.PRNGKey(cfg.seed), model_cfg)
    dtype = jnp.dtype(cfg.compute_dtype)
    if cfg.boundaries is None:
        runner = SingleDeviceRunner(model_cfg, compute_dtype=dtype)
    else:
        from repro.core.pipeline import PipelineConfig
        from repro.launch.mesh import make_stage_mesh

        runner = PipelineRunner(
            model_cfg, make_stage_mesh(len(cfg.boundaries)), cfg.boundaries,
            pipe=PipelineConfig(compute_dtype=cfg.compute_dtype,
                                wire_dtype=cfg.wire_dtype))
    n = cfg.num_slots
    gen = jax.jit(make_generate_fn(runner, max_new=cfg.max_new,
                                   temperature=cfg.temperature))
    base_key = jax.random.PRNGKey(cfg.seed)
    order = sorted(trace, key=lambda r: r.arrival_time)
    if warmup and order:
        caches = runner.init_caches(n, cfg.prompt_pad + cfg.max_new)
        buf, _ = gen(params, caches,
                     jnp.zeros((n, cfg.prompt_pad), jnp.int32),
                     jnp.ones((n,), jnp.int32), jnp.ones((n,), jnp.int32),
                     jnp.full((n,), -1, jnp.int32), base_key)
        jax.block_until_ready(buf)
    t0 = time.perf_counter()
    done = {}
    lats = {}
    num_batches = 0
    for lo in range(0, len(order), n):
        batch = order[lo:lo + n]
        # arrival-aware, same virtual-clock discipline as the engine's
        # service loop: a batch cannot start before its members arrive,
        # and waiting while idle jumps the clock instead of burning wall
        ready_at = max(r.arrival_time for r in batch)
        now = time.perf_counter() - t0
        if now < ready_at:
            t0 -= ready_at - now
        ap = np.zeros((n, cfg.prompt_pad), np.int32)
        al = np.ones((n,), np.int32)
        ag = np.ones((n,), np.int32)
        ar = np.full((n,), -1, np.int32)
        for i, r in enumerate(batch):
            ap[i, :r.plen] = r.prompt
            al[i] = r.plen
            ag[i] = r.gen_target
            ar[i] = r.rid
        caches = runner.init_caches(n, cfg.prompt_pad + cfg.max_new)
        buf, n_gen = gen(params, caches, jnp.asarray(ap), jnp.asarray(al),
                         jnp.asarray(ag), jnp.asarray(ar), base_key)
        jax.block_until_ready(buf)
        num_batches += 1
        now = time.perf_counter() - t0
        buf = np.asarray(buf)
        for i, r in enumerate(batch):
            done[r.rid] = buf[i, :int(n_gen[i])]
            lats[r.rid] = now - r.arrival_time
    wall = time.perf_counter() - t0
    ls = sorted(lats.values())
    pct = lambda q: ls[min(int(q * len(ls)), len(ls) - 1)] if ls else 0.0
    return {
        "completions": done,
        "num_requests": len(done),
        "wall_seconds": wall,
        "requests_per_sec": len(done) / wall if wall else 0.0,
        "tokens_per_sec": sum(len(t) for t in done.values()) / wall
        if wall else 0.0,
        "p50_latency_s": pct(0.50),
        "p99_latency_s": pct(0.99),
        # structural accounting, comparable to the engine's: useful
        # decode-slot-steps over executed ones. Every batch runs the
        # full max_new-length decode scan on all n rows (drained and
        # padded rows included) - that padding is exactly what the
        # continuous engine's slot reuse reclaims.
        "slot_occupancy": sum(len(t) for t in done.values())
        / (num_batches * n * cfg.max_new) if num_batches else 0.0,
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--config", default=None, help="ServeConfig JSON file")
    ap.add_argument("--set", nargs=2, action="append", default=[],
                    metavar=("KEY", "VALUE"),
                    help="override a ServeConfig field")
    ap.add_argument("--mode", choices=("engine", "static"), default="engine")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=8.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--trace-seed", type=int, default=0)
    ap.add_argument("--json", action="store_true",
                    help="emit metrics as one JSON line")
    args = ap.parse_args(argv)

    from repro.serving import ServeConfig, poisson_trace

    overrides = {k: ServeConfig.parse_override(k, v) for k, v in args.set}
    cfg = ServeConfig.load(args.config, overrides)
    model_cfg = cfg.model_config()
    trace = poisson_trace(
        n_requests=args.requests, rate_per_sec=args.rate,
        vocab_size=model_cfg.vocab_size,
        plen_range=(4, cfg.prompt_pad), gen_range=(4, cfg.max_new),
        seed=args.trace_seed)

    if args.mode == "static":
        res = run_static(cfg, trace)
    else:
        from repro.serving import ServingService

        svc = ServingService(cfg)
        res = svc.run(trace)

    metrics = {k: v for k, v in res.items()
               if k not in ("completions", "latencies", "replans")}
    if args.json:
        print(json.dumps(metrics, default=float))
    else:
        print(f"{args.mode}: {res['num_requests']} requests in "
              f"{res['wall_seconds']:.2f}s")
        print(f"  requests/sec {res['requests_per_sec']:.2f}  "
              f"tokens/sec {res['tokens_per_sec']:.1f}")
        print(f"  p50 {res['p50_latency_s']*1e3:.0f} ms  "
              f"p99 {res['p99_latency_s']*1e3:.0f} ms  "
              f"slot occupancy {res['slot_occupancy']:.2f}")
    return res


if __name__ == "__main__":
    main()
