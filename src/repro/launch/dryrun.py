import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production mesh, record memory/cost/collective analysis for the roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]

The XLA_FLAGS line above MUST run before any jax import: jax locks the
device count on first init. Only the dry-run uses 512 placeholder devices.
"""

import argparse
import json
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config, get_shape
from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import input_specs
from repro.distribution.context import activation_sharding
from repro.distribution.sharding import (
    batch_axes,
    cache_shardings,
    param_shardings,
)
from repro.launch.hlo_analysis import parse_collectives, roofline_from
from repro.launch.mesh import make_production_mesh
from repro.models import (
    init_caches,
    init_params,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
from repro.optim import adamw

# sliding window used when a full-attention arch must run long_500k
LONG_CONTEXT_WINDOW = 8192

# SPerf-measured: seq-sharding fresh KV (cache-layout alignment) wins for
# these archs (kh=8, hd=128) and regresses for kh=4 / hd=192 archs.
KV_SEQ_SHARD_GOOD = {"pixtral-12b", "minitron-4b"}


def arch_for_shape(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """long_500k needs sub-quadratic attention: switch full-attention archs
    to their sliding-window variant (noted in EXPERIMENTS.md)."""
    if (
        shape.name == "long_500k"
        and "A" in cfg.pattern
        and cfg.attention_window is None
    ):
        return cfg.with_window(LONG_CONTEXT_WINDOW)
    return cfg


def _tokens_per_device(shape: ShapeConfig, n_dev: int) -> float:
    toks = shape.global_batch * (shape.seq_len if shape.kind == "train" else (
        shape.seq_len if shape.kind == "prefill" else 1))
    return toks / n_dev


def model_flops_per_device(cfg: ModelConfig, shape: ShapeConfig, n_dev: int) -> float:
    n_active = cfg.active_param_count()
    toks = _tokens_per_device(shape, n_dev)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * toks


def analytic_hlo_flops_per_device(
    cfg: ModelConfig, shape: ShapeConfig, n_dev: int, *, remat: bool = True
) -> float:
    """Closed-form estimate of the compiled per-device matmul FLOPs.

    XLA's cost analysis counts a lax.scan body once, so the scan-mode
    compile under-reports; this analytic model (validated within ~2% of
    fully-unrolled compiles, see EXPERIMENTS.md SDry-run) is used for the
    roofline compute term.  Terms: parameter matmuls (2*active per token),
    attention score/value matmuls (causal-halved), LM head, backward (2x)
    and remat recompute (1x) for training.
    """
    toks = _tokens_per_device(shape, n_dev)
    # parameter matmuls, input embedding excluded (gather, not matmul)
    active = cfg.active_param_count() - cfg.vocab_size * cfg.d_model
    fwd = 2.0 * active * toks
    # attention quadratic
    ctx = min(shape.seq_len, cfg.attention_window or shape.seq_len)
    n_attn = cfg.num_attn_layers
    if n_attn and cfg.num_heads:
        per_tok = 4.0 * ctx * cfg.num_heads * cfg.head_dim
        if shape.kind != "decode":
            per_tok *= 0.5  # causal half
        fwd += per_tok * n_attn * toks
    if shape.kind == "train":
        mult = 3.0 + (1.0 if remat else 0.0)
        return fwd * mult
    return fwd


def build_lowered(cfg: ModelConfig, shape: ShapeConfig, mesh, *, remat=True, unroll=True,
                  variant: str = "baseline"):
    """Lower the right step function for this shape kind. Returns jax.Lowered.

    variants (SPerf hillclimb):
      baseline           - f32 params, FSDP+TP train sharding everywhere
      bf16cast           - train: per-step bf16 compute copy of matrix params
      serve_resident     - decode/prefill: weights resident (no FSDP axis)
      serve_resident_bf16- serve_resident + weights stored in bf16
    """
    n_dev = mesh.devices.size
    baxes = batch_axes(mesh, shape.global_batch)
    param_dtype = (
        jnp.bfloat16 if variant == "serve_resident_bf16" and shape.kind != "train"
        else jnp.float32
    )
    params_shape = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg, dtype=param_dtype)
    )
    p_mode = "serve" if variant.startswith("serve_resident") and shape.kind != "train" else "train"
    psh = param_shardings(params_shape, cfg, mesh, mode=p_mode)
    compute_copy = jnp.bfloat16 if (variant == "bf16cast" and shape.kind == "train") else None
    kv_seq = cfg.name.split("-sw")[0] in KV_SEQ_SHARD_GOOD or any(
        cfg.name.startswith(a) for a in KV_SEQ_SHARD_GOOD
    )
    moe_a2a = variant == "moe_a2a"
    specs = input_specs(cfg, shape)

    def bsh(spec):
        return NamedSharding(mesh, P(baxes, *([None] * (len(spec.shape) - 1))))

    if shape.kind == "train":
        opt = adamw(1e-4, max_grad_norm=1.0)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        osh = param_shardings(opt_shape, cfg, mesh)
        step = make_train_step(cfg, opt, remat=remat, unroll=unroll,
                               compute_copy_dtype=compute_copy,
                               param_shardings_tree=psh if compute_copy else None)
        batch_sh = {k: bsh(v) for k, v in specs.items()}
        metrics_sh = {
            "loss": NamedSharding(mesh, P()),
            "aux": NamedSharding(mesh, P()),
            "total": NamedSharding(mesh, P()),
        }
        jitted = jax.jit(
            step,
            in_shardings=(psh, osh, batch_sh),
            out_shardings=(psh, osh, metrics_sh),
        )
        with activation_sharding(mesh, baxes, kv_seq_shard=kv_seq, moe_a2a=moe_a2a):
            return jitted.lower(params_shape, opt_shape, specs)

    if shape.kind == "prefill":
        f = shape.seq_len - (cfg.frontend_tokens if cfg.frontend != "none" else 0)
        cache_len = shape.seq_len
        caches_shape = jax.eval_shape(
            lambda: init_caches(cfg, shape.global_batch, cache_len)
        )
        csh = cache_shardings(caches_shape, cfg, mesh, shape.global_batch)
        prefill = make_prefill_step(cfg, unroll=unroll)

        def prefill_full(params, tokens, frontend=None):
            caches = jax.tree.map(
                lambda s, sh: jax.lax.with_sharding_constraint(jnp.zeros(s.shape, s.dtype), sh),
                caches_shape, csh,
            )
            return prefill(params, tokens, caches, frontend_feats=frontend)

        logits_sh = NamedSharding(
            mesh, P(baxes, "model" if cfg.vocab_size % _axis(mesh, "model") == 0 else None)
        )
        args = [params_shape, specs["tokens"]]
        in_sh = [psh, bsh(specs["tokens"])]
        if "frontend" in specs:
            args.append(specs["frontend"])
            in_sh.append(bsh(specs["frontend"]))
        jitted = jax.jit(
            prefill_full,
            in_shardings=tuple(in_sh),
            out_shardings=(logits_sh, csh),
        )
        with activation_sharding(mesh, baxes, kv_seq_shard=kv_seq, moe_a2a=moe_a2a):
            return jitted.lower(*args)

    # decode
    cache_len = shape.seq_len
    caches_shape = jax.eval_shape(
        lambda: init_caches(cfg, shape.global_batch, cache_len)
    )
    csh = cache_shardings(caches_shape, cfg, mesh, shape.global_batch)
    decode = make_decode_step(cfg, unroll=unroll)
    logits_sh = NamedSharding(
        mesh, P(baxes, "model" if cfg.vocab_size % _axis(mesh, "model") == 0 else None)
    )
    jitted = jax.jit(
        decode,
        in_shardings=(psh, bsh(specs["tokens"]), csh, NamedSharding(mesh, P())),
        out_shardings=(logits_sh, csh),
    )
    with activation_sharding(mesh, baxes, kv_seq_shard=kv_seq, moe_a2a=moe_a2a):
        return jitted.lower(
            params_shape, specs["tokens"], caches_shape, specs["cache_index"]
        )


def _axis(mesh, name):
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _period(cfg: ModelConfig) -> int:
    from repro.models import find_period, signature

    return find_period(signature(cfg))


def _scaled_bytes(cost_raw: dict, repeats: int) -> float:
    """Approximate unrolled bytes-accessed from a scan-mode compile: the
    dominant traffic is the layer loop body, counted once by XLA; scaling
    by the trip count recovers the per-step total (validated vs unrolled
    compiles, see EXPERIMENTS.md)."""
    return float(cost_raw.get("bytes accessed", 0.0)) * repeats


def run_one(arch: str, shape_name: str, *, multi_pod=False, out_dir="experiments/dryrun",
            verbose=True, variant: str = "baseline"):
    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = get_shape(shape_name)
    cfg0 = get_config(arch)
    cfg = arch_for_shape(cfg0, shape)
    t0 = time.time()
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "variant": cfg.name,
        "perf_variant": variant,
        "n_devices": int(mesh.devices.size),
    }
    try:
        # single scan-mode compile (deployment form). Memory analysis is
        # meaningful (the loop reuses buffers by construction); collectives
        # inside the layer loop are scaled by the parsed trip count; the
        # compute term uses the analytic matmul-FLOPs model (validated vs
        # unrolled compiles within ~2%, see EXPERIMENTS.md).
        lowered = build_lowered(cfg, shape, mesh, unroll=False, variant=variant)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        mem = compiled.memory_analysis()
        cost_raw = compiled.cost_analysis() or {}
        txt = compiled.as_text()
        coll = parse_collectives(txt, loop_aware=True)
        mf = model_flops_per_device(cfg, shape, mesh.devices.size)
        af = analytic_hlo_flops_per_device(cfg, shape, mesh.devices.size)
        # memory term: scale scan-mode bytes-accessed by the layer loop too
        coll_flat = parse_collectives(txt, loop_aware=False)
        cost = dict(cost_raw)
        cost["flops"] = af
        rep = max(1, cfg.num_layers // _period(cfg))
        cost["bytes accessed"] = _scaled_bytes(cost_raw, rep)
        roof = roofline_from(cost, coll, mf)
        rec.update(
            ok=True,
            lower_s=round(t1 - t0, 2),
            compile_s=round(t2 - t1, 2),
            flops_xla_scan=float(cost_raw.get("flops", 0.0)),
            bytes_xla_scan=float(cost_raw.get("bytes accessed", 0.0)),
            collectives_scan_body=dict(
                wire_bytes=coll_flat.wire_bytes, counts=coll_flat.counts
            ),
            memory=dict(
                argument_bytes=mem.argument_size_in_bytes,
                output_bytes=mem.output_size_in_bytes,
                temp_bytes=mem.temp_size_in_bytes,
                alias_bytes=mem.alias_size_in_bytes,
            ),
            collectives=dict(
                result_bytes=coll.result_bytes,
                wire_bytes=coll.wire_bytes,
                counts=coll.counts,
            ),
            roofline=roof.as_dict(),
            hlo_bytes=len(txt),
        )
        if verbose:
            live = (
                mem.argument_size_in_bytes
                - mem.alias_size_in_bytes
                + mem.temp_size_in_bytes
            )
            print(
                f"[ok] {arch} x {shape_name} x {rec['mesh']}: "
                f"compile {rec['compile_s']}s, "
                f"args {mem.argument_size_in_bytes/2**30:.2f} GiB/dev, "
                f"temps {mem.temp_size_in_bytes/2**30:.2f} GiB/dev, "
                f"dominant={roof.dominant} "
                f"(c={roof.compute_s:.3e}s m={roof.memory_s:.3e}s k={roof.collective_s:.3e}s) "
                f"useful={roof.useful_ratio:.2f}",
                flush=True,
            )
    except Exception as e:  # noqa: BLE001 - record failures, don't die
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[FAIL] {arch} x {shape_name} x {rec['mesh']}: {e}", flush=True)
    os.makedirs(out_dir, exist_ok=True)
    suffix = "" if variant == "baseline" else f"__{variant}"
    fn = f"{arch}__{shape_name}__{rec['mesh'].replace('x','_')}{suffix}.json"
    with open(os.path.join(out_dir, fn), "w") as f:
        json.dump(rec, f, indent=1, default=float)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args(argv)

    combos = []
    if args.all:
        for a in ARCH_IDS:
            for s in INPUT_SHAPES:
                combos.append((a, s.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        combos = [(args.arch, args.shape)]

    n_ok = 0
    for a, s in combos:
        mesh_tag = "2_16_16" if args.multi_pod else "16_16"
        fn = os.path.join(args.out, f"{a}__{s}__{mesh_tag}.json")
        if args.skip_existing and os.path.exists(fn):
            with open(fn) as f:
                if json.load(f).get("ok"):
                    n_ok += 1
                    print(f"[skip] {a} x {s} x {mesh_tag} (cached ok)", flush=True)
                    continue
        rec = run_one(a, s, multi_pod=args.multi_pod, out_dir=args.out,
                      variant=args.variant)
        n_ok += bool(rec.get("ok"))
    print(f"dry-run: {n_ok}/{len(combos)} ok", flush=True)
    return 0 if n_ok == len(combos) else 1


if __name__ == "__main__":
    sys.exit(main())
