"""Kill-and-resume chaos harness for the training checkpoint path.

The claim under test is the strongest form of crash safety the trainers
promise: a run that is SIGKILLed mid-chunk (no cleanup, no atexit, torn
nothing thanks to the atomic checkpoint writes) and then re-launched
into the same checkpoint directory finishes with metric trajectories
BIT-IDENTICAL to a run that was never interrupted. The harness:

1. launches ``python -m repro.launch.chaos --child ...`` - a subprocess
   running ``train_sac`` with checkpointing, printing ``METRICS {json}``
   on completion;
2. polls the checkpoint directory until a resumable step lands
   (``latest_checkpoint_step``), then delivers ``SIGKILL`` - by
   construction the child dies between chunk boundaries, exactly where
   a real preemption would land;
3. re-launches the SAME command; the child restores the checkpoint
   (``resume=True``) and trains the remaining episodes;
4. compares the resumed metrics against an uninterrupted in-process
   reference run, element-for-element (floats compared by equality, not
   tolerance).

``--seeds`` runs the whole dance once per seed (the CI chaos-smoke
matrix). Exit code 0 = every seed bit-identical.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import time
from typing import List, Optional


def _child_main(args) -> None:
    """Subprocess body: one checkpointed train_sac run, metrics to stdout."""
    from repro.core.agents.loops import train_sac
    from repro.core.agents.sac import SACConfig
    from repro.core.env import MHSLEnv
    from repro.core.profiles import resnet101_profile

    env = MHSLEnv(profile=resnet101_profile(batch=1))
    res = train_sac(
        env, SACConfig(), episodes=args.episodes, seed=args.seed,
        warmup_episodes=args.warmup, num_envs=args.num_envs,
        checkpoint_dir=args.dir, checkpoint_every=args.checkpoint_every)
    print("METRICS " + json.dumps({
        "episode_reward": res.episode_reward,
        "episode_leak": res.episode_leak,
        "episode_violation": res.episode_violation,
        "states_explored": res.states_explored,
    }), flush=True)


def _child_cmd(args, ckpt_dir: str) -> List[str]:
    return [
        sys.executable, "-m", "repro.launch.chaos", "--child",
        "--dir", ckpt_dir, "--seed", str(args.seed),
        "--episodes", str(args.episodes), "--warmup", str(args.warmup),
        "--num-envs", str(args.num_envs),
        "--checkpoint-every", str(args.checkpoint_every),
    ]


def _parse_metrics(stdout: str) -> dict:
    for line in stdout.splitlines():
        if line.startswith("METRICS "):
            return json.loads(line[len("METRICS "):])
    raise RuntimeError(f"no METRICS line in child output:\n{stdout}")


def _launch(cmd: List[str]) -> subprocess.Popen:
    return subprocess.Popen(cmd, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True)


def kill_and_resume(args, ckpt_dir: str) -> dict:
    """One chaos round: launch, SIGKILL after the first resumable
    checkpoint, relaunch to completion. Returns the resumed metrics."""
    from repro.checkpoint.train_state import latest_checkpoint_step

    cmd = _child_cmd(args, ckpt_dir)
    victim = _launch(cmd)
    deadline = time.monotonic() + args.timeout
    killed = False
    try:
        while time.monotonic() < deadline:
            step = latest_checkpoint_step(ckpt_dir)
            if step is not None and step >= args.kill_after:
                victim.send_signal(signal.SIGKILL)
                killed = True
                break
            if victim.poll() is not None:
                break  # finished before we could kill it - still valid
            time.sleep(0.05)
        else:
            victim.kill()
            out = victim.communicate()[0]
            raise TimeoutError(
                f"no checkpoint >= {args.kill_after} within "
                f"{args.timeout}s; child output:\n{out}")
        out = victim.communicate()[0]
    finally:
        if victim.poll() is None:
            victim.kill()
            victim.communicate()
    if not killed:
        print(f"  [warn] child finished before the kill landed "
              f"(checkpoint cadence too coarse?); resume still exercised",
              flush=True)
    survivor = _launch(cmd)
    out, _ = survivor.communicate(timeout=args.timeout)
    if survivor.returncode != 0:
        raise RuntimeError(
            f"resume run exited {survivor.returncode}:\n{out}")
    return _parse_metrics(out)


def reference_metrics(args) -> dict:
    """The uninterrupted run, in-process (same code path, no faults)."""
    from repro.core.agents.loops import train_sac
    from repro.core.agents.sac import SACConfig
    from repro.core.env import MHSLEnv
    from repro.core.profiles import resnet101_profile

    env = MHSLEnv(profile=resnet101_profile(batch=1))
    res = train_sac(env, SACConfig(), episodes=args.episodes,
                    seed=args.seed, warmup_episodes=args.warmup,
                    num_envs=args.num_envs)
    return {
        "episode_reward": res.episode_reward,
        "episode_leak": res.episode_leak,
        "episode_violation": res.episode_violation,
        "states_explored": res.states_explored,
    }


def compare(resumed: dict, reference: dict) -> List[str]:
    """Bit-exact comparison; returns human-readable mismatches."""
    problems = []
    for k in sorted(set(resumed) | set(reference)):
        a, b = resumed.get(k), reference.get(k)
        if a != b:
            problems.append(f"{k}: resumed {a} != reference {b}")
    return problems


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--child", action="store_true",
                    help="internal: run the training child process")
    ap.add_argument("--dir", default=None,
                    help="checkpoint directory (child) / scratch root")
    ap.add_argument("--seed", type=int, default=5)
    ap.add_argument("--seeds", default=None,
                    help="comma-separated seed matrix (overrides --seed)")
    ap.add_argument("--episodes", type=int, default=10)
    ap.add_argument("--warmup", type=int, default=4)
    ap.add_argument("--num-envs", type=int, default=2)
    ap.add_argument("--checkpoint-every", type=int, default=2)
    ap.add_argument("--kill-after", type=int, default=2,
                    help="SIGKILL once a checkpoint at >= this episode "
                         "exists")
    ap.add_argument("--timeout", type=float, default=600.0)
    args = ap.parse_args(argv)

    if args.child:
        if args.dir is None:
            ap.error("--child requires --dir")
        _child_main(args)
        return 0

    import tempfile

    seeds = ([int(s) for s in args.seeds.split(",")] if args.seeds
             else [args.seed])
    failures = 0
    for seed in seeds:
        args.seed = seed
        with tempfile.TemporaryDirectory(dir=args.dir) as root:
            ckpt_dir = os.path.join(root, f"chaos_seed{seed}")
            print(f"[chaos] seed {seed}: kill-and-resume ...", flush=True)
            resumed = kill_and_resume(args, ckpt_dir)
            print(f"[chaos] seed {seed}: uninterrupted reference ...",
                  flush=True)
            ref = reference_metrics(args)
            problems = compare(resumed, ref)
            if problems:
                failures += 1
                print(f"[chaos] seed {seed}: MISMATCH", flush=True)
                for p in problems:
                    print("  " + p, flush=True)
            else:
                n = len(ref["episode_reward"])
                print(f"[chaos] seed {seed}: OK - {n} episode metrics "
                      f"bit-identical after SIGKILL + resume", flush=True)
    if failures:
        print(f"[chaos] {failures}/{len(seeds)} seeds FAILED", flush=True)
        return 1
    print(f"[chaos] all {len(seeds)} seed(s) bit-identical", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
