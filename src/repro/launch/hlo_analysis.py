"""Post-SPMD HLO analysis: collective bytes + roofline terms.

The compiled module text is PER-DEVICE (SPMD partitioned), so shapes parsed
here are per-device shard shapes and ``cost_analysis()`` numbers are
per-device too. Roofline terms are therefore per-chip directly.

Wire-byte factors per collective (ring algorithms, n = replica group size):
  all-reduce        2 (n-1)/n * result_bytes
  all-gather          (n-1)/n * result_bytes   (result = gathered)
  reduce-scatter      (n-1)   * result_bytes   (result = one shard)
  all-to-all          (n-1)/n * result_bytes
  collective-permute          result_bytes
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2  # conservative default


# ---------------------------------------------------------------------------
# while-loop aware computation parsing
# ---------------------------------------------------------------------------

_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_WHILE_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_WHILE_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_TRIP_RE = re.compile(r"known_trip_count\D+(\d+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def split_computations(hlo_text: str):
    """Split module text into {computation_name: body_text}.

    Computation headers start at column 0 (optionally 'ENTRY') and end
    with '{'; bodies are indented; the closing '}' is at column 0.
    """
    comps = {}
    cur_name, cur_lines = None, []
    for line in hlo_text.splitlines():
        stripped = line.rstrip()
        if cur_name is None:
            if stripped.endswith("{") and stripped and not line[0].isspace():
                m = _COMP_HEAD_RE.match(stripped)
                if m:
                    cur_name = m.group(1)
                    cur_lines = []
        else:
            if stripped == "}":
                comps[cur_name] = "\n".join(cur_lines)
                cur_name = None
            else:
                cur_lines.append(line)
    return comps


def loop_multipliers(hlo_text: str):
    """Effective execution-count multiplier per computation.

    Each `while` op's body executes trip-count times (parsed as the largest
    integer constant in its condition computation - the canonical
    lax.scan lowering compares the induction variable against the length).
    Multipliers compose through nesting via the computation call graph.
    """
    comps = split_computations(hlo_text)
    # find while ops: (enclosing_comp, body_name, trip_count). The CPU/TPU
    # pipelines record known_trip_count in backend_config; fall back to the
    # largest constant in the condition computation.
    whiles = []
    for name, body in comps.items():
        for line in body.splitlines():
            if " while(" not in line:
                continue
            mb = _WHILE_BODY_RE.search(line)
            if not mb:
                continue
            mt = _TRIP_RE.search(line)
            if mt:
                tc = int(mt.group(1))
            else:
                mc = _WHILE_COND_RE.search(line)
                consts = [int(c) for c in _CONST_RE.findall(comps.get(mc.group(1), ""))] if mc else []
                tc = max(consts) if consts else 1
            whiles.append((name, mb.group(1), tc))

    # called-computations edges (calls, fusions, while bodies, conditionals)
    single_re = re.compile(r"(?:to_apply|body|condition)=%?([\w.\-]+)")
    braced_re = re.compile(r"(?:calls|branch_computations)=\{([^}]*)\}")
    children = {name: set() for name in comps}
    for name, body in comps.items():
        for m in single_re.finditer(body):
            children[name].add(m.group(1))
        for m in braced_re.finditer(body):
            for c in re.split(r",\s*", m.group(1)):
                children[name].add(c.strip().lstrip("%"))

    while_body_trip = {}
    for _, body_name, tc in whiles:
        while_body_trip[body_name] = max(while_body_trip.get(body_name, 1), tc)

    # propagate multipliers from the entry computation
    mult = {}

    def visit(name, m, depth=0):
        if depth > 50 or name not in comps:
            return
        if mult.get(name, 0) >= m:
            return
        mult[name] = m
        for c in children.get(name, ()):  # body computations multiply by trip
            cm = m * while_body_trip.get(c, 1)
            visit(c, cm, depth + 1)

    # entry = computation not called by anyone
    called = set()
    for cs in children.values():
        called |= cs
    entries = [n for n in comps if n not in called]
    for e in entries:
        visit(e, 1)
    return comps, mult


@dataclass
class CollectiveStats:
    # per-device result bytes and wire-byte estimates, per collective kind
    result_bytes: Dict[str, int] = field(default_factory=dict)
    wire_bytes: Dict[str, float] = field(default_factory=dict)
    counts: Dict[str, int] = field(default_factory=dict)

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    @property
    def total_result_bytes(self) -> int:
        return sum(self.result_bytes.values())


def parse_collectives(hlo_text: str, *, loop_aware: bool = False) -> CollectiveStats:
    """Sum collective bytes in the module.

    loop_aware=True multiplies collectives inside while-loop bodies by the
    loop trip count (lax.scan over layers) so a scan-mode compile yields
    the same totals as a fully unrolled one.
    """
    if loop_aware:
        comps, mult = loop_multipliers(hlo_text)
        stats = CollectiveStats()
        for name, body in comps.items():
            m = mult.get(name, 1)
            sub = _parse_flat(body)
            for kind in sub.result_bytes:
                stats.result_bytes[kind] = (
                    stats.result_bytes.get(kind, 0) + sub.result_bytes[kind] * m
                )
                stats.wire_bytes[kind] = (
                    stats.wire_bytes.get(kind, 0.0) + sub.wire_bytes[kind] * m
                )
                stats.counts[kind] = stats.counts.get(kind, 0) + sub.counts[kind] * m
        return stats
    return _parse_flat(hlo_text)


def _parse_flat(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        for kind in _COLLECTIVES:
            # match " <result-type> kind(" to avoid matching metadata/calls
            marker = f" {kind}("
            marker_start = f" {kind}-start("
            if marker not in line and marker_start not in line:
                continue
            lhs = line.split(f"{kind}-start(" if marker_start in line else f"{kind}(")[0]
            # result type(s) appear between '=' and the op name
            try:
                result_part = lhs.split("=", 1)[1]
            except IndexError:
                continue
            rb = _shape_bytes(result_part)
            if rb == 0:
                continue
            n = _group_size(line)
            if kind == "all-reduce":
                wb = 2 * (n - 1) / n * rb
            elif kind == "all-gather":
                wb = (n - 1) / n * rb
            elif kind == "reduce-scatter":
                wb = (n - 1) * rb
            elif kind == "all-to-all":
                wb = (n - 1) / n * rb
            else:  # collective-permute
                wb = float(rb)
            stats.result_bytes[kind] = stats.result_bytes.get(kind, 0) + rb
            stats.wire_bytes[kind] = stats.wire_bytes.get(kind, 0.0) + wb
            stats.counts[kind] = stats.counts.get(kind, 0) + 1
            break
    return stats


def pipeline_collective_counts(
    hlo_text: str, n_ticks: int = 1, *, loop_aware: bool = True
) -> Dict[str, float]:
    """Issued-collective counts per pipeline tick, by collective kind.

    The 1F1B executor issues its stage hops (``collective-permute``,
    possibly split into async ``-start``/``-done`` pairs - only the start
    is counted) and its loss/grad reductions (``all-reduce``) inside the
    tick scan; loop-aware parsing multiplies body ops by the scan trip
    count, and dividing by ``n_ticks`` normalizes to per-tick issue
    counts. This is the regression surface for the double-buffered
    transport: overlap moves the hops to the top of the tick but must not
    issue MORE of them than the synchronous handoff.
    """
    stats = parse_collectives(hlo_text, loop_aware=loop_aware)
    return {k: c / n_ticks for k, c in stats.counts.items()}


# ---------------------------------------------------------------------------
# roofline
# ---------------------------------------------------------------------------

# TPU v5e per-chip constants (assignment-specified)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link (wire-byte estimate treated as per-chip stream)


@dataclass
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float
    wire_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float = 0.0  # 6 N D (train) / 2 N D (decode), per device
    useful_ratio: float = 0.0

    def as_dict(self):
        return dict(
            flops_per_device=self.flops_per_device,
            hbm_bytes_per_device=self.hbm_bytes_per_device,
            wire_bytes_per_device=self.wire_bytes_per_device,
            compute_s=self.compute_s,
            memory_s=self.memory_s,
            collective_s=self.collective_s,
            dominant=self.dominant,
            model_flops=self.model_flops,
            useful_ratio=self.useful_ratio,
        )


def roofline_from(cost: Optional[dict], coll: CollectiveStats, model_flops_per_device: float = 0.0) -> Roofline:
    flops = float(cost.get("flops", 0.0)) if cost else 0.0
    hbm = float(cost.get("bytes accessed", 0.0)) if cost else 0.0
    wire = coll.total_wire_bytes
    c_s = flops / PEAK_FLOPS_BF16
    m_s = hbm / HBM_BW
    k_s = wire / ICI_BW
    dom = max((("compute", c_s), ("memory", m_s), ("collective", k_s)), key=lambda t: t[1])[0]
    return Roofline(
        flops_per_device=flops,
        hbm_bytes_per_device=hbm,
        wire_bytes_per_device=wire,
        compute_s=c_s,
        memory_s=m_s,
        collective_s=k_s,
        dominant=dom,
        model_flops=model_flops_per_device,
        useful_ratio=(model_flops_per_device / flops) if flops else 0.0,
    )
