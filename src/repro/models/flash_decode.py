"""Distributed flash-decoding over a length-sharded KV cache.

When GQA kv-heads cannot shard across the TP axis, the KV cache shards by
LENGTH; naive GSPMD attention then all-gathers the whole cache every
decoded token (~1 GB/layer at 32k, measured: 52 GB/step on
qwen3-moe-30b-a3b decode_32k). This shard_map computes attention locally
per cache shard and combines with logsumexp statistics - per layer the
cross-shard traffic is a psum of (B, H, hd) partials + (B, H) stats.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.distribution import context as ctx

NEG = -1e30


def flash_decode(
    q: jax.Array,  # (B, 1, H, hd) - replicated over the model axis
    ck: jax.Array,  # (B, L, KH, hd) - L sharded over the model axis
    cv: jax.Array,
    cache_index: jax.Array,  # scalar current position, or (B,) per-row
    *,
    window: Optional[int] = None,
) -> jax.Array:
    mesh = ctx._STATE["mesh"]
    batch_ax = ctx._STATE["batch"]
    model_ax = ctx._STATE["model"]
    b, _, h, hd = q.shape
    kh = ck.shape[2]
    g = h // kh
    scale = 1.0 / math.sqrt(hd)
    vec_idx = jnp.ndim(cache_index) == 1

    def local(qc, kc, vc, idx):
        # qc (b_loc, 1, H, hd); kc/vc (b_loc, L_loc, KH, hd)
        l_loc = kc.shape[1]
        shard = jax.lax.axis_index(model_ax)
        kpos = shard * l_loc + jnp.arange(l_loc)
        if vec_idx:
            # per-row cache index: (b_loc, L_loc) validity mask
            ok = kpos[None, :] <= idx[:, None]
            if window is not None:
                ok &= kpos[None, :] > idx[:, None] - window
            okb = ok[:, None, :]
        else:
            ok = kpos <= idx
            if window is not None:
                ok &= kpos > idx - window
            okb = ok[None, None, :]
        kr = jnp.repeat(kc, g, axis=2).astype(jnp.float32)
        vr = jnp.repeat(vc, g, axis=2).astype(jnp.float32)
        s = jnp.einsum("bhd,bkhd->bhk", qc[:, 0].astype(jnp.float32), kr) * scale
        # (b, H, L_loc)
        s = jnp.where(okb, s, NEG)
        m_loc = s.max(axis=-1)  # (b, H)
        m = jax.lax.pmax(m_loc, model_ax)
        p = jnp.exp(s - m[..., None])
        p = jnp.where(okb, p, 0.0)
        l_sum = jax.lax.psum(p.sum(axis=-1), model_ax)  # (b, H)
        out = jax.lax.psum(jnp.einsum("bhk,bkhd->bhd", p, vr), model_ax)
        out = out / jnp.maximum(l_sum[..., None], 1e-30)
        return out[:, None].astype(qc.dtype)  # (b, 1, H, hd)

    qspec = P(batch_ax, None, None, None)
    cspec = P(batch_ax, model_ax, None, None)
    ispec = P(batch_ax) if vec_idx else P()
    return shard_map(
        local,
        mesh=mesh,
        in_specs=(qspec, cspec, cspec, ispec),
        out_specs=qspec,
        check_rep=False,
    )(q, ck, cv, cache_index)
