"""Core transformer layers: RMSNorm, RoPE, GQA attention, MLPs, MoE.

Everything is pure-functional: ``init_*`` returns a param pytree,
``*_apply``-style functions consume it. Compute runs in ``cfg`` activation
dtype (bf16 by default) with f32 softmax/norm accumulation.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distribution.context import constrain

Array = jax.Array

# ---------------------------------------------------------------------------
# basics
# ---------------------------------------------------------------------------


def rms_norm(x: Array, weight: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * weight.astype(dt)


def init_dense(key, d_in: int, d_out: int, dtype=jnp.float32, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def activation_fn(name: str):
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "silu":
        return jax.nn.silu
    raise KeyError(name)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_angles(positions: Array, head_dim: int, theta: float) -> tuple[Array, Array]:
    """positions: (..., S) int -> cos/sin of shape (..., S, head_dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """x: (B, S, H, hd); cos/sin: (S, hd//2) or (B, S, hd//2)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    if cos.ndim == 2:  # (S, hd/2) -> broadcast over batch and heads
        cos_, sin_ = cos[None, :, None, :], sin[None, :, None, :]
    else:  # (B, S, hd/2)
        cos_, sin_ = cos[:, :, None, :], sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos_ - x2 * sin_, x1 * sin_ + x2 * cos_], axis=-1)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, dtype=jnp.float32):
    d, h, kh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_dense(ks[0], d, h * hd, dtype),
        "wk": init_dense(ks[1], d, kh * hd, dtype),
        "wv": init_dense(ks[2], d, kh * hd, dtype),
        "wo": init_dense(ks[3], h * hd, d, dtype, scale=1.0 / math.sqrt(h * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kh * hd,), dtype)
        p["bv"] = jnp.zeros((kh * hd,), dtype)
    return p


def _mask_bias(qpos: Array, kpos: Array, window: Optional[int]) -> Array:
    """(Sq, Skv) additive f32 bias: 0 allowed, -inf disallowed."""
    ok = kpos[None, :] <= qpos[:, None]
    if window is not None:
        ok &= kpos[None, :] > (qpos[:, None] - window)
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def _repeat_kv(k: Array, groups: int) -> Array:
    if groups == 1:
        return k
    b, s, kh, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kh, groups, hd)).reshape(
        b, s, kh * groups, hd
    )


def dense_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    q_offset,
    window: Optional[int] = None,
    causal: bool = True,
) -> Array:
    """Reference attention; materializes (Sq, Skv) scores. q:(B,Sq,H,hd)."""
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    k = _repeat_kv(k, h // kh)
    v = _repeat_kv(v, h // kh)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    scores *= 1.0 / math.sqrt(hd)
    qpos = q_offset + jnp.arange(sq)
    kpos = jnp.arange(k.shape[1])
    if causal:
        scores = scores + _mask_bias(qpos, kpos, window)[None, None]
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)


def chunked_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    q_offset: int = 0,
    window: Optional[int] = None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> Array:
    """Flash-style online-softmax attention in pure JAX (no SqxSkv temp).

    Outer scan over q chunks, inner scan over kv chunks; peak temporary is
    (B, H, q_chunk, kv_chunk). Causal + optional sliding window.
    """
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    kh = k.shape[2]
    g = h // kh
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    n_q = -(-sq // q_chunk)
    n_kv = -(-skv // kv_chunk)
    pad_q = n_q * q_chunk - sq
    pad_kv = n_kv * kv_chunk - skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))

    # (n, B, chunk, heads, hd) layouts for scan
    qs = q.reshape(b, n_q, q_chunk, h, hd).transpose(1, 0, 2, 3, 4)
    ks = k.reshape(b, n_kv, kv_chunk, kh, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, n_kv, kv_chunk, kh, hd).transpose(1, 0, 2, 3, 4)
    qs = constrain(qs, {1: "batch", 3: "model"})
    ks = constrain(ks, {1: "batch"})
    vs = constrain(vs, {1: "batch"})
    scale = 1.0 / math.sqrt(hd)

    def q_body(_, qc_i):
        qc, qi = qc_i
        qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_body(carry, kc_vc_i):
            acc, m, l = carry
            kc, vc, ki = kc_vc_i
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            kc_r = _repeat_kv(kc, g)
            vc_r = _repeat_kv(vc, g)
            s = (
                jnp.einsum("bqhd,bkhd->bhqk", qc, kc_r, preferred_element_type=jnp.float32)
                * scale
            )
            s = constrain(s, {0: "batch", 1: "model"})
            bias = _mask_bias(qpos, kpos, window)
            # mask out kv padding
            bias = jnp.where((kpos < skv)[None, :], bias, -jnp.inf)
            s = s + bias[None, None]
            m_new = jnp.maximum(m, s.max(axis=-1))
            # fully-masked blocks: keep p/corr at exactly 0, never exp(-inf+inf)
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_safe[..., None]), 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(vc_r.dtype), vc_r
            ).astype(jnp.float32)
            acc = constrain(acc, {0: "batch", 1: "model"})
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((b, h, q_chunk, hd), jnp.float32)
        m0 = jnp.full((b, h, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            jax.checkpoint(kv_body), (acc0, m0, l0), (ks, vs, jnp.arange(n_kv))
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.transpose(0, 2, 1, 3)  # (B, q_chunk, H, hd)

    _, outs = jax.lax.scan(jax.checkpoint(q_body), None, (qs, jnp.arange(n_q)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, n_q * q_chunk, h, hd)
    return out[:, :sq].astype(q.dtype)


def _row_cache_update(cache: Array, fresh: Array, index: Array) -> Array:
    """Slot-indexed KV write: row ``b`` of ``cache`` takes ``fresh[b]`` at
    its OWN position ``index[b]`` (vmapped ``dynamic_update_slice``).

    This is what lets one compiled decode step serve a continuous batch of
    slots sitting at different sequence positions (the serving engine's
    per-slot KV rings); the scalar-``cache_index`` path is untouched.
    """
    return jax.vmap(
        lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0, 0))
    )(cache, fresh, index)


def attention_apply(
    params,
    x: Array,
    cfg: ModelConfig,
    *,
    positions: Array,
    kv_cache=None,
    cache_index=None,
    impl: str = "auto",
):
    """Self-attention with GQA + RoPE.

    positions: (S,) absolute positions of the inputs, or (B, S) per-row
    positions when ``cache_index`` is a vector.
    kv_cache: optional dict {k:(B,C,KH,hd), v:(B,C,KH,hd)} - decode mode.
    cache_index: scalar number of valid entries already in the cache, or a
    (B,) vector of PER-ROW entry counts (slot-indexed decode: every batch
    row writes its fresh K/V at its own position and masks its own
    history; see :func:`_row_cache_update`).
    Returns (out, new_cache).
    """
    b, s, d = x.shape
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", x, params["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    from repro.distribution.context import kv_seq_shard_enabled, model_axis_divides

    q = constrain(q.reshape(b, s, h, hd), {0: "batch", 2: "model"})
    # Fresh K/V sharding in cache paths is a measured knob (SPerf log):
    # head-sharding when the TP axis divides kh; otherwise SEQUENCE-sharding
    # aligns fresh KV with the length-sharded cache and removes a per-layer
    # replicate-reshard ("involuntary full rematerialization", ~4 GB/layer
    # all-gather on pixtral prefill_32k) - but it REGRESSES collectives on
    # kh=4 GQA and hd=192 archs, so it is opt-in per architecture.
    kv_dim = 2
    if kv_cache is not None and not model_axis_divides(kh) and kv_seq_shard_enabled():
        kv_dim = 1
    k = constrain(k.reshape(b, s, kh, hd), {0: "batch", kv_dim: "model"})
    v = constrain(v.reshape(b, s, kh, hd), {0: "batch", kv_dim: "model"})
    cos, sin = rope_angles(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    new_cache = None
    vec_idx = cache_index is not None and jnp.ndim(cache_index) == 1
    if kv_cache is not None:
        cache_len = kv_cache["k"].shape[1]
        cd = kv_cache["k"].dtype
        if cfg.attention_window is not None and cache_len == cfg.attention_window and s == 1:
            # ring-buffer cache for sliding-window decode (1 token)
            t = cache_index  # absolute position(s) of the new token
            slot = t % cache_len
            # entry i now holds absolute position t - ((t - i) mod L), which is
            # always within the window; it is valid iff it is >= 0.
            idx = jnp.arange(cache_len)
            if vec_idx:
                ck = _row_cache_update(kv_cache["k"], k.astype(cd), slot)
                cv = _row_cache_update(kv_cache["v"], v.astype(cd), slot)
                abs_pos = t[:, None] - jnp.mod(t[:, None] - idx[None, :], cache_len)
                kpos_bias = jnp.where(abs_pos >= 0, 0.0, -jnp.inf)[:, None, None, :]
            else:
                ck = jax.lax.dynamic_update_slice(
                    kv_cache["k"], k.astype(cd), (0, slot, 0, 0))
                cv = jax.lax.dynamic_update_slice(
                    kv_cache["v"], v.astype(cd), (0, slot, 0, 0))
                abs_pos = t - jnp.mod(t - idx, cache_len)
                kpos_bias = jnp.where(abs_pos >= 0, 0.0, -jnp.inf)[None, None, None, :]
            scores = jnp.einsum(
                "bqhd,bkhd->bhqk",
                q,
                _repeat_kv(ck, h // kh),
                preferred_element_type=jnp.float32,
            ) / math.sqrt(hd)
            scores = scores + kpos_bias
            w = jax.nn.softmax(scores, axis=-1)
            out = jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), _repeat_kv(cv, h // kh))
            new_cache = {"k": ck, "v": cv}
        else:
            if vec_idx:
                ck = _row_cache_update(kv_cache["k"], k.astype(cd), cache_index)
                cv = _row_cache_update(kv_cache["v"], v.astype(cd), cache_index)
            else:
                ck = jax.lax.dynamic_update_slice(
                    kv_cache["k"], k.astype(cd), (0, cache_index, 0, 0)
                )
                cv = jax.lax.dynamic_update_slice(
                    kv_cache["v"], v.astype(cd), (0, cache_index, 0, 0)
                )
            from repro.distribution.context import active as ctx_active

            if (
                s == 1
                and ctx_active()
                and not model_axis_divides(kh)
                and model_axis_divides(cache_len)
            ):
                # distributed flash-decoding over the length-sharded cache
                from repro.models.flash_decode import flash_decode

                out = flash_decode(q, ck, cv, cache_index,
                                   window=cfg.attention_window)
            else:
                kpos = jnp.arange(cache_len)
                qpos = positions  # (s,) absolute, or (B, s) per-row
                ok = kpos <= qpos[..., None]
                if vec_idx:
                    ok &= kpos < (cache_index[:, None, None] + s)
                else:
                    ok &= kpos[None, :] < (cache_index + s)
                if cfg.attention_window is not None:
                    ok &= kpos > (qpos[..., None] - cfg.attention_window)
                bias = jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)
                scores = jnp.einsum(
                    "bqhd,bkhd->bhqk",
                    q,
                    _repeat_kv(ck, h // kh),
                    preferred_element_type=jnp.float32,
                ) / math.sqrt(hd)
                scores = scores + (bias[:, None] if vec_idx else bias[None, None])
                w = jax.nn.softmax(scores, axis=-1)
                out = jnp.einsum(
                    "bhqk,bkhd->bqhd", w.astype(v.dtype), _repeat_kv(cv, h // kh)
                )
            new_cache = {"k": ck, "v": cv}
    else:
        use_chunked = impl == "chunked" or (impl == "auto" and s > 2048)
        if impl == "pallas":
            from repro.kernels import ops as kops

            out = kops.flash_attention(
                q, k, v, causal=True, window=cfg.attention_window, interpret=True
            )
        elif use_chunked:
            out = chunked_attention(q, k, v, q_offset=0, window=cfg.attention_window)
        else:
            out = dense_attention(q, k, v, q_offset=0, window=cfg.attention_window)

    out = out.reshape(b, s, h * hd).astype(x.dtype)  # cache dtype may differ
    out = jnp.einsum("bse,ed->bsd", out, params["wo"].astype(x.dtype))
    return out, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, activation: str, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    if activation == "swiglu":
        return {
            "w_gate": init_dense(ks[0], d_model, d_ff, dtype),
            "w_up": init_dense(ks[1], d_model, d_ff, dtype),
            "w_down": init_dense(ks[2], d_ff, d_model, dtype, scale=1.0 / math.sqrt(d_ff)),
        }
    return {
        "w_up": init_dense(ks[0], d_model, d_ff, dtype),
        "w_down": init_dense(ks[1], d_ff, d_model, dtype, scale=1.0 / math.sqrt(d_ff)),
    }


def mlp_apply(params, x: Array, activation: str) -> Array:
    if activation == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(x.dtype))
        u = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(x.dtype))
        hcurr = jax.nn.silu(g) * u
    else:
        u = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(x.dtype))
        hcurr = activation_fn(activation)(u)
    return jnp.einsum("bsf,fd->bsd", hcurr, params["w_down"].astype(x.dtype))


def mlp_block(norm_w: Array, params, x: Array, activation: str,
              eps: float = 1e-6) -> Array:
    """Reference residual MLP half-block: ``x + mlp(rms_norm(x))``.

    This is the exact computation the fused Pallas stage kernel
    (``repro.kernels.stage_block``) performs in one VMEM-resident pass;
    the kernel's custom VJP differentiates THIS function, so the two are
    gradient-identical by construction.
    """
    return x + mlp_apply(params, rms_norm(x, norm_w, eps), activation)


# ---------------------------------------------------------------------------
# MoE (top-k, capacity-bounded, scatter/gather dispatch)
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32):
    m = cfg.moe
    ks = jax.random.split(key, 4)
    d, f, e = cfg.d_model, m.expert_d_ff, m.num_experts
    p = {
        "router": init_dense(ks[0], d, e, jnp.float32),
        "w_up": (jax.random.normal(ks[2], (e, d, f)) / math.sqrt(d)).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d)) / math.sqrt(f)).astype(dtype),
    }
    if cfg.activation == "swiglu":
        p["w_gate"] = (jax.random.normal(ks[1], (e, d, f)) / math.sqrt(d)).astype(dtype)
    return p


def moe_capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(math.ceil(tokens_per_group * m.top_k * m.capacity_factor / m.num_experts))
    return max(c, 1)


def moe_apply(params, x: Array, cfg: ModelConfig):
    """x: (B, S, D). Returns (y, aux_loss).

    Dispatch via scatter-add into an (E, C, D) per-group buffer (group =
    batch row), which avoids the O(tokens x E x C) one-hot and maps to
    all-to-all under expert sharding.
    """
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.num_experts, m.top_k
    c = moe_capacity(s, cfg)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)  # (B, S, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert, per group (=batch row)
    def group_positions(eids):  # (S, k) -> (S, k) position_in_expert
        flat = eids.reshape(-1)  # (S*k,) in token-major order
        onehot = jax.nn.one_hot(flat, e, dtype=jnp.int32)  # (S*k, E)
        pos = jnp.cumsum(onehot, axis=0) - 1  # occurrences before + self
        return jnp.take_along_axis(pos, flat[:, None], axis=1)[:, 0].reshape(eids.shape)

    pos_in_expert = jax.vmap(group_positions)(expert_ids)  # (B, S, k)
    keep = pos_in_expert < c
    slot = expert_ids * c + jnp.minimum(pos_in_expert, c - 1)  # (B, S, k)

    def dispatch_group(xg, slotg, keepg):  # (S,D),(S,k),(S,k)
        # k separate scatters: avoids materializing the (S*k, D) repeated
        # token tensor (whose cotangent all-reduced ~1.5 TB/step on
        # qwen3-235b train_4k; SPerf iteration 2)
        buf = jnp.zeros((e * c, d), x.dtype)
        for j in range(k):
            buf = buf.at[slotg[:, j]].add(xg * keepg[:, j : j + 1].astype(x.dtype))
        return buf

    buf = jax.vmap(dispatch_group)(x, slot, keep)  # (B, E*C, D)
    buf = constrain(buf.reshape(b, e, c, d), {0: "batch", 1: "model"})

    # expert FFN: (B, E, C, D) -> (B, E, C, D), contracting per expert
    if cfg.activation == "swiglu":
        g = jnp.einsum("becd,edf->becf", buf, params["w_gate"].astype(x.dtype))
        u = jnp.einsum("becd,edf->becf", buf, params["w_up"].astype(x.dtype))
        hcurr = jax.nn.silu(g) * u
    else:
        u = jnp.einsum("becd,edf->becf", buf, params["w_up"].astype(x.dtype))
        hcurr = activation_fn(cfg.activation)(u)
    out = jnp.einsum("becf,efd->becd", hcurr, params["w_down"].astype(x.dtype))
    out = constrain(out, {0: "batch", 1: "model"})
    out = out.reshape(b, e * c, d)

    def combine_group(outg, slotg, keepg, gateg):  # (E*C,D),(S,k),(S,k),(S,k)
        got = outg[slotg.reshape(-1)].reshape(s, k, d)
        w = (gateg * keepg).astype(x.dtype)
        return jnp.einsum("skd,sk->sd", got, w)

    y = jax.vmap(combine_group)(out, slot, keep, gate_vals)

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    f_e = jnp.mean(
        jax.nn.one_hot(expert_ids[..., 0], e, dtype=jnp.float32), axis=(0, 1)
    )
    p_e = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(f_e * p_e) * m.router_aux_weight
    return y, aux


def _moe_route(params, xt: Array, cfg: ModelConfig):
    """Shared token routing for the dropless + dense-reference paths.

    xt: (T, D) flattened tokens. Returns (gates (T, k) f32 renormalized,
    expert_ids (T, k) int32, aux scalar). Identical code on both sides is
    what makes the dropless-vs-dense parity BITWISE rather than approximate.
    """
    m = cfg.moe
    e, k = m.num_experts, m.top_k
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    f_e = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], e, dtype=jnp.float32), axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f_e * p_e) * m.router_aux_weight
    return gate_vals, expert_ids, aux


def _moe_combine(out_choices: Array, gates: Array, dtype) -> Array:
    """(T, k, D) per-choice expert outputs + (T, k) gates -> (T, D).

    The k-summation runs through ONE einsum on both the dropless and the
    dense-reference side, so the combine order is identical (a scatter-add
    combine would not be)."""
    return jnp.einsum("tkd,tk->td", out_choices, gates.astype(dtype))


def moe_apply_dense(params, x: Array, cfg: ModelConfig):
    """Dense per-expert reference: EVERY expert FFN over EVERY token.

    x: (B, S, D) -> (y, aux). O(T * E) FFN rows - the bitwise ground truth
    the dropless dispatch is parity-pinned against, never a production
    path. Written as a python loop over experts so each expert's rows go
    through a plain (T, D) @ (D, F) gemm.
    """
    b, s, d = x.shape
    e = cfg.moe.num_experts
    xt = x.reshape(b * s, d)
    gates, ids, aux = _moe_route(params, xt, cfg)
    swiglu = cfg.activation == "swiglu"
    per_expert = []
    for j in range(e):
        wu = params["w_up"][j].astype(x.dtype)
        wd = params["w_down"][j].astype(x.dtype)
        if swiglu:
            wg = params["w_gate"][j].astype(x.dtype)
            g = jnp.einsum("td,df->tf", xt, wg,
                           preferred_element_type=jnp.float32).astype(x.dtype)
            u = jnp.einsum("td,df->tf", xt, wu,
                           preferred_element_type=jnp.float32).astype(x.dtype)
            h = jax.nn.silu(g) * u
        else:
            u = jnp.einsum("td,df->tf", xt, wu,
                           preferred_element_type=jnp.float32).astype(x.dtype)
            h = activation_fn(cfg.activation)(u)
        per_expert.append(
            jnp.einsum("tf,fd->td", h, wd,
                       preferred_element_type=jnp.float32).astype(x.dtype))
    stacked = jnp.stack(per_expert)  # (E, T, D)
    t = b * s
    got = stacked[ids, jnp.arange(t)[:, None]]  # (T, k, D)
    y = _moe_combine(got, gates, x.dtype)
    return y.reshape(b, s, d), aux


def moe_apply_dropless(params, x: Array, cfg: ModelConfig, *,
                       impl: str = "reference", block_size: int = 128,
                       interpret=None):
    """Dropless MoE dispatch: sort-based token grouping + grouped matmul.

    Every routed (token, choice) is computed - no capacity buffer, no
    token dropping, so the output of a token is independent of which
    other tokens share its dispatch group (the structural defect behind
    the old ``jamba_decode`` xfail: the capacity path drops differently
    at prefill group size vs decode group size 1).

    x: (B, S, D) -> (y, aux). Stable-argsort the (T*k) flat expert ids,
    gather tokens into expert-contiguous rows, run the expert FFN
    grouped, then gather back through the inverse permutation and combine
    with one einsum (order-preserving, see ``_moe_combine``).

    Both impls share one padded layout: per-expert regions padded up to
    ``block_size`` rows (a STATIC ``T*k + E*(block_size-1)`` row bound,
    so the whole dispatch jits with fixed shapes; padding rows are zero
    and never gathered back). impl="reference" runs the jittable
    ``kernels.moe_dispatch.grouped_ffn_reference`` batched einsum (the
    production CPU path); impl="pallas" runs the fused
    ``grouped_moe_ffn`` Pallas kernel over the same blocks. Both are
    bitwise-identical to ``moe_apply_dense`` on CPU (pinned by
    ``tests/test_moe_dropless.py``; ``lax.ragged_dot`` was rejected here
    - its gemm blocking drifts ~2e-6 from the plain per-expert gemm).
    """
    from repro.kernels.moe_dispatch import (
        grouped_ffn_reference, grouped_moe_ffn,
    )

    m = cfg.moe
    b, s, d = x.shape
    e, k = m.num_experts, m.top_k
    t = b * s
    xt = x.reshape(t, d)
    gates, ids, aux = _moe_route(params, xt, cfg)

    flat = ids.reshape(-1)                      # (T*k,) token-major
    order = jnp.argsort(flat)                   # stable: ties keep token order
    sorted_eids = flat[order]
    counts = jnp.zeros((e,), jnp.int32).at[flat].add(1)

    blk = block_size
    padded = ((counts + blk - 1) // blk) * blk              # (E,)
    starts = jnp.cumsum(padded) - padded
    excl = jnp.cumsum(counts) - counts
    pos_in_expert = jnp.arange(t * k) - excl[sorted_eids]
    dest = starts[sorted_eids] + pos_in_expert              # unique rows
    p_rows = -(-(t * k + e * (blk - 1)) // blk) * blk       # static bound
    pbuf = jnp.zeros((p_rows, d), x.dtype).at[dest].set(xt[order // k])
    block_eid = jnp.minimum(
        jnp.searchsorted(jnp.cumsum(padded),
                         jnp.arange(p_rows // blk) * blk, side="right"),
        e - 1).astype(jnp.int32)

    if impl == "reference":
        out_p = grouped_ffn_reference(
            pbuf, block_eid, params.get("w_gate"), params["w_up"],
            params["w_down"], cfg.activation)
    elif impl == "pallas":
        out_p = grouped_moe_ffn(pbuf, block_eid, params,
                                activation=cfg.activation,
                                interpret=interpret)
    else:
        raise ValueError(f"unknown dropless impl {impl!r}")
    out_sorted = out_p[dest]

    inv = jnp.argsort(order)                    # flat choice -> sorted row
    got = out_sorted[inv].reshape(t, k, d)
    y = _moe_combine(got, gates, x.dtype)
    return y.reshape(b, s, d), aux
