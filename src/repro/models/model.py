"""Decoder LM assembly: embedding -> scan over layer groups -> logits.

Architecture-generic: the per-layer ``signature`` (block kind A/M, MoE flag,
MLP presence) is derived from the config; layers are grouped into the
smallest repeating period so ``jax.lax.scan`` keeps compile time O(period),
not O(depth) - essential for 94-96 layer models on the dry-run host.

KV/SSM caches are threaded through the same scan as stacked xs/ys.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distribution.context import constrain
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.frontends import FRONTEND_DIMS, init_frontend, frontend_apply

Array = jax.Array


# ---------------------------------------------------------------------------
# layer signatures and period grouping
# ---------------------------------------------------------------------------


def signature(cfg: ModelConfig):
    """Per-layer (kind, is_moe, has_mlp)."""
    sig = []
    for i in range(cfg.num_layers):
        kind = cfg.pattern[i]
        is_moe = cfg.is_moe_block(i) and (kind == "A" or cfg.arch_type == "hybrid")
        has_mlp = kind == "A" or cfg.arch_type == "hybrid"
        sig.append((kind, is_moe, has_mlp))
    return tuple(sig)


def find_period(sig) -> int:
    n = len(sig)
    for p in range(1, n + 1):
        if n % p == 0 and all(sig[i] == sig[i % p] for i in range(n)):
            return p
    return n


# ---------------------------------------------------------------------------
# per-slot block init / apply
# ---------------------------------------------------------------------------


def init_block(key, cfg: ModelConfig, slot_sig, dtype=jnp.float32):
    kind, is_moe, has_mlp = slot_sig
    ks = jax.random.split(key, 3)
    p: Dict[str, Any] = {"norm1": jnp.ones((cfg.d_model,), dtype)}
    if kind == "A":
        p["attn"] = L.init_attention(ks[0], cfg, dtype)
    else:
        p["mamba"] = S.init_mamba(ks[0], cfg, dtype)
    if has_mlp:
        p["norm2"] = jnp.ones((cfg.d_model,), dtype)
        if is_moe:
            p["moe"] = L.init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.activation, dtype)
    return p


def block_apply(
    p,
    x: Array,
    cfg: ModelConfig,
    slot_sig,
    *,
    positions,
    cache=None,
    cache_index=None,
    impl: str = "auto",
):
    """One residual block. Returns (x, new_cache, aux)."""
    kind, is_moe, has_mlp = slot_sig
    aux = jnp.zeros((), jnp.float32)
    # "pallas_stage" (the split executor's PipelineConfig.stage_impl knob)
    # fuses the residual MLP half-block; the attention/mamba half keeps the
    # default routing.
    half_impl = "auto" if impl == "pallas_stage" else impl
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind == "A":
        out, new_kv = L.attention_apply(
            p["attn"], h, cfg, positions=positions,
            kv_cache=None if cache is None else {"k": cache["k"], "v": cache["v"]},
            cache_index=cache_index, impl=half_impl,
        )
        new_cache = {} if new_kv is None else new_kv
    else:
        out, (new_ssm, new_conv) = S.mamba_apply(
            p["mamba"], h, cfg,
            ssm_state=None if cache is None else cache["ssm"],
            conv_state=None if cache is None else cache["conv"],
            use_pallas=(half_impl == "pallas"),
        )
        new_cache = {"ssm": new_ssm, "conv": new_conv}
        if cache is None:
            new_cache = {}
    x = x + out
    if has_mlp:
        if is_moe:
            from repro.distribution.context import moe_a2a_enabled
            from repro.models.moe_a2a import a2a_applicable, moe_apply_a2a

            h2 = L.rms_norm(x, p["norm2"], cfg.norm_eps)
            if moe_a2a_enabled() and a2a_applicable(cfg):
                y, aux = moe_apply_a2a(p["moe"], h2, cfg)
            elif cfg.moe.dispatch == "dropless":
                y, aux = L.moe_apply_dropless(p["moe"], h2, cfg)
            else:
                y, aux = L.moe_apply(p["moe"], h2, cfg)
            x = x + y
        elif impl == "pallas_stage":
            from repro.kernels.stage_block import stage_mlp_block

            x = stage_mlp_block(p["norm2"], p["mlp"], x,
                                activation=cfg.activation, eps=cfg.norm_eps)
        else:
            x = L.mlp_block(p["norm2"], p["mlp"], x, cfg.activation,
                            cfg.norm_eps)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig, dtype=jnp.float32):
    sig = signature(cfg)
    period = find_period(sig)
    repeats = cfg.num_layers // period
    keys = jax.random.split(key, period + 3)
    slots = []
    for si in range(period):
        slot_keys = jax.random.split(keys[si], repeats)
        slots.append(jax.vmap(lambda k: init_block(k, cfg, sig[si], dtype))(slot_keys))
    params = {
        "embed": (jax.random.normal(keys[-1], (cfg.vocab_size, cfg.d_model)) * 0.02).astype(
            dtype
        ),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "slots": tuple(slots),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(keys[-2], (cfg.d_model, cfg.vocab_size))
            / math.sqrt(cfg.d_model)
        ).astype(dtype)
    if cfg.frontend != "none":
        params["frontend"] = init_frontend(keys[-3], cfg, dtype)
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def init_caches(cfg: ModelConfig, batch: int, cache_len: int, dtype=jnp.bfloat16):
    """Per-slot stacked caches (leading dim = repeats)."""
    sig = signature(cfg)
    period = find_period(sig)
    repeats = cfg.num_layers // period
    kv_len = (
        min(cache_len, cfg.attention_window)
        if cfg.attention_window is not None
        else cache_len
    )
    caches = []
    for si in range(period):
        kind, _, _ = sig[si]
        if kind == "A":
            shape = (repeats, batch, kv_len, cfg.num_kv_heads, cfg.head_dim)
            caches.append({"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)})
        else:
            sc = cfg.ssm
            di = sc.d_inner(cfg.d_model)
            nh = sc.num_heads(cfg.d_model)
            caches.append(
                {
                    "ssm": jnp.zeros(
                        (repeats, batch, nh, sc.head_dim, sc.d_state), jnp.float32
                    ),
                    "conv": jnp.zeros(
                        (repeats, batch, sc.d_conv - 1, di + 2 * sc.d_state), dtype
                    ),
                }
            )
    return tuple(caches)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def forward(
    params,
    tokens: Array,
    cfg: ModelConfig,
    *,
    caches=None,
    cache_index=None,
    frontend_feats: Optional[Array] = None,
    impl: str = "auto",
    remat: bool = False,
    unroll: bool = False,
    compute_dtype=jnp.bfloat16,
):
    """tokens: (B, S) int32. Returns (logits, new_caches, aux_loss).

    frontend_feats: (B, F, d_frontend) stub modality embeddings, prepended.
    """
    sig = signature(cfg)
    period = find_period(sig)
    b, s_tok = tokens.shape

    x = params["embed"].astype(compute_dtype)[tokens]
    x = constrain(x, {0: "batch"})
    if frontend_feats is not None:
        fe = frontend_apply(params["frontend"], frontend_feats.astype(compute_dtype))
        x = jnp.concatenate([fe, x], axis=1)
    s = x.shape[1]
    if cache_index is None:
        positions = jnp.arange(s)
    elif jnp.ndim(cache_index) == 1:
        # slot-indexed serving: per-row entry counts -> per-row positions (B, s)
        positions = cache_index[:, None] + jnp.arange(s)
    else:
        positions = cache_index + jnp.arange(s)

    def body(carry, xs):
        xact, aux = carry
        slot_params, slot_caches = xs

        def inner(xact, aux, slot_params, slot_caches):
            new_caches = []
            for si in range(period):
                cache = None
                if caches is not None:
                    cache = slot_caches[si]
                xact, nc, a = block_apply(
                    slot_params[si], xact, cfg, sig[si],
                    positions=positions, cache=cache, cache_index=cache_index,
                    impl=impl,
                )
                xact = constrain(xact, {0: "batch"})
                new_caches.append(nc)
                aux = aux + a
            return xact, aux, tuple(new_caches)

        if remat:
            # NOTE: save_only_these_names("moe_a2a") was measured (SPerf
            # pair 1, iter 5b): it cuts the exchange 3786->... but pins
            # ~2.3 TB/dev of buffers - recompute is the right side of the
            # trade at 16 GiB/chip, so nothing is saved.
            f = jax.checkpoint(inner, policy=jax.checkpoint_policies.nothing_saveable)
        else:
            f = inner
        xact, aux, new_caches = f(xact, aux, slot_params, slot_caches)
        return (xact, aux), new_caches

    caches_xs = tuple({} for _ in range(period))
    aux0 = jnp.zeros((), jnp.float32)
    if unroll:
        # python-loop unroll: true per-layer HLO (exact flop/collective
        # accounting in the dry-run; scan counts the body only once)
        repeats = cfg.num_layers // period
        carry = (x, aux0)
        ys = []
        for r in range(repeats):
            sp = jax.tree.map(lambda a: a[r], params["slots"])
            cc = jax.tree.map(lambda a: a[r], caches) if caches is not None else caches_xs
            carry, nc = body(carry, (sp, cc))
            ys.append(nc)
        (x, aux) = carry
        if caches is None:
            new_caches = None
        else:
            new_caches = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    elif caches is None:
        (x, aux), _ = jax.lax.scan(
            lambda c, sp: body(c, (sp, caches_xs)),
            (x, aux0),
            params["slots"],
        )
        new_caches = None
    else:
        (x, aux), new_caches = jax.lax.scan(
            body,
            (x, aux0),
            (params["slots"], caches),
        )

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ).astype(compute_dtype)
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    logits = constrain(logits, {0: "batch", 2: "model"})
    return logits, new_caches, aux


# ---------------------------------------------------------------------------
# losses and steps
# ---------------------------------------------------------------------------


def softmax_xent(logits: Array, labels: Array, mask: Optional[Array] = None):
    """logits: (B,S,V) ; labels: (B,S) int32; mask: (B,S) 1=count."""
    logits = constrain(logits.astype(jnp.float32), {0: "batch", 2: "model"})
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def loss_fn(params, batch, cfg: ModelConfig, *, impl="auto", remat=True, unroll=False):
    tokens = batch["tokens"]
    labels = batch["labels"]
    frontend = batch.get("frontend")
    logits, _, aux = forward(
        params, tokens, cfg, frontend_feats=frontend, impl=impl, remat=remat,
        unroll=unroll,
    )
    if frontend is not None:
        # loss only over the text region (frontend positions are prefix)
        f = logits.shape[1] - labels.shape[1]
        logits = logits[:, f:]
    loss = softmax_xent(logits, labels, batch.get("mask"))
    return loss + aux, (loss, aux)


def make_train_step(cfg: ModelConfig, optimizer, *, impl="auto", remat=True, unroll=False,
                    compute_copy_dtype=None, param_shardings_tree=None):
    """compute_copy_dtype: when set (e.g. jnp.bfloat16), matrix params are
    cast to it ONCE per step before the forward pass, so FSDP all-gathers
    and all weight reads move half the bytes; the f32 master copy and the
    optimizer update stay full precision (classic mixed precision).

    param_shardings_tree: when given, the casted copy is PINNED to the same
    sharding as the master param - without this, GSPMD hoists the FSDP
    all-gather ABOVE the convert and gathers f32 anyway (measured, SPerf
    iteration 3)."""

    def cast_tree(p):
        if compute_copy_dtype is None:
            return p

        def one(a, sh=None):
            if a.dtype == jnp.float32 and a.ndim >= 2:
                a = a.astype(compute_copy_dtype)
                if sh is not None:
                    a = jax.lax.with_sharding_constraint(a, sh)
            return a

        if param_shardings_tree is None:
            return jax.tree.map(one, p)
        return jax.tree.map(one, p, param_shardings_tree)

    def train_step(params, opt_state, batch):
        if compute_copy_dtype is None:
            (total, (loss, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch, cfg, impl=impl, remat=remat, unroll=unroll
            )
        else:
            # differentiate wrt the LOW-PRECISION copy: the gradient
            # reduction (the dominant train collective) then moves
            # compute_copy_dtype bytes, and the f32 master update follows.
            params_c = cast_tree(params)
            (total, (loss, aux)), grads_c = jax.value_and_grad(
                lambda p: loss_fn(p, batch, cfg, impl=impl, remat=remat,
                                  unroll=unroll),
                has_aux=True,
            )(params_c)
            grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads_c, params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        from repro.optim.optimizers import apply_updates

        params = apply_updates(params, updates)
        metrics = {"loss": loss, "aux": aux, "total": total}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, *, impl="auto", unroll=False,
                      compute_dtype=jnp.bfloat16):
    def prefill(params, tokens, caches, frontend_feats=None):
        logits, new_caches, _ = forward(
            params, tokens, cfg, caches=caches, cache_index=jnp.zeros((), jnp.int32),
            frontend_feats=frontend_feats, impl=impl, remat=False, unroll=unroll,
            compute_dtype=compute_dtype,
        )
        return logits[:, -1], new_caches

    return prefill


def make_decode_step(cfg: ModelConfig, *, impl="auto", unroll=False,
                     compute_dtype=jnp.bfloat16):
    def decode(params, tokens, caches, cache_index):
        """tokens: (B, 1); cache_index: int32 tokens already seen - a scalar
        (lockstep batch) or a (B,) vector (per-slot counts, serving engine)."""
        logits, new_caches, _ = forward(
            params, tokens, cfg, caches=caches, cache_index=cache_index,
            impl=impl, remat=False, unroll=unroll, compute_dtype=compute_dtype,
        )
        return logits[:, -1], new_caches

    return decode
