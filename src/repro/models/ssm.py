"""Mamba-2 (SSD, state-space duality) block [arXiv:2405.21060].

Chunked SSD algorithm: within-chunk quadratic attention-like term +
across-chunk recurrent state passing. Pure-jnp reference here; the Pallas
kernel in ``repro.kernels.ssd_scan`` implements the same chunk recurrence
with VMEM state carry and is validated against ``ssd_chunked``.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Array = jax.Array


def segsum(x: Array) -> Array:
    """Stable 'segment sum': out[..., i, j] = sum_{j<k<=i} x[..., k], -inf for j>i.

    x: (..., T) -> (..., T, T) lower-triangular cumulative sums.
    """
    t = x.shape[-1]
    xx = jnp.broadcast_to(x[..., None, :], x.shape + (t,)).swapaxes(-1, -2)
    mask = jnp.tril(jnp.ones((t, t), bool), k=-1)
    xx = jnp.where(mask, xx, 0)
    out = jnp.cumsum(xx, axis=-2)
    mask2 = jnp.tril(jnp.ones((t, t), bool), k=0)
    return jnp.where(mask2, out, -jnp.inf)


def ssd_chunked(
    x: Array,  # (B, S, H, P) inputs
    dt: Array,  # (B, S, H) positive step sizes
    a: Array,  # (H,) negative decay rates (A = -exp(a_log))
    b: Array,  # (B, S, N) input matrix (single group)
    c: Array,  # (B, S, N) output matrix
    chunk: int = 64,
    h0: Optional[Array] = None,  # (B, H, P, N) initial state
):
    """Chunked SSD. Returns (y: (B,S,H,P), h_final: (B,H,P,N))."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk

    # chunked views: (B, nc, L, ...)
    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bc = b.reshape(bsz, nc, chunk, n)
    cc = c.reshape(bsz, nc, chunk, n)

    da = dtc * a[None, None, None, :]  # (B, nc, L, H) log-decay per step
    da_cum = jnp.cumsum(da, axis=2)  # within-chunk cumulative

    # 1) intra-chunk (diagonal block) output
    ss = segsum(da.transpose(0, 1, 3, 2))  # (B, nc, H, L, L)
    decay = jnp.exp(ss)
    scores = jnp.einsum("bzln,bzmn,bzhlm->bzhlm", cc, bc, decay)
    y_diag = jnp.einsum("bzhlm,bzmh,bzmhp->bzlhp", scores, dtc, xc)

    # 2) per-chunk final states
    decay_states = jnp.exp(da_cum[:, :, -1:, :] - da_cum)  # (B, nc, L, H)
    states = jnp.einsum("bzln,bzlh,bzlhp->bzhpn", bc, decay_states * dtc, xc)

    # 3) inter-chunk recurrence over chunk index
    chunk_decay = jnp.exp(da_cum[:, :, -1, :])  # (B, nc, H)

    def scan_fn(hprev, inp):
        st, dec = inp  # (B,H,P,N), (B,H)
        hnew = hprev * dec[:, :, None, None] + st
        return hnew, hprev

    h_init = (
        h0 if h0 is not None else jnp.zeros((bsz, h, p, n), x.dtype)
    )
    h_last, h_before = jax.lax.scan(
        scan_fn,
        h_init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    h_before = h_before.transpose(1, 0, 2, 3, 4)  # (B, nc, H, P, N) state entering chunk

    # 4) state -> output contribution
    state_decay = jnp.exp(da_cum)  # (B, nc, L, H)
    y_off = jnp.einsum("bzln,bzhpn,bzlh->bzlhp", cc, h_before, state_decay)

    y = (y_diag + y_off).reshape(bsz, nc * chunk, h, p)
    return y[:, :s], h_last


def ssd_decode_step(
    x: Array,  # (B, 1, H, P)
    dt: Array,  # (B, 1, H)
    a: Array,  # (H,)
    b: Array,  # (B, 1, N)
    c: Array,  # (B, 1, N)
    h: Array,  # (B, H, P, N)
):
    """Single recurrent step: h' = exp(dt*a) h + dt * x b^T ; y = h' c."""
    dec = jnp.exp(dt[:, 0, :] * a[None, :])  # (B, H)
    upd = jnp.einsum("bhp,bn->bhpn", x[:, 0] * dt[:, 0, :, None], b[:, 0])
    h_new = h * dec[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", h_new, c[:, 0])[:, None]
    return y, h_new


# ---------------------------------------------------------------------------
# full Mamba-2 block
# ---------------------------------------------------------------------------


def init_mamba(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    sc = cfg.ssm
    di = sc.d_inner(d)
    nh = sc.num_heads(d)
    n = sc.d_state
    ks = jax.random.split(key, 5)
    d_in_proj = 2 * di + 2 * n + nh  # z, x, B, C, dt
    conv_dim = di + 2 * n
    return {
        "in_proj": (jax.random.normal(ks[0], (d, d_in_proj)) / math.sqrt(d)).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (sc.d_conv, conv_dim)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm_w": jnp.ones((di,), dtype),
        "out_proj": (jax.random.normal(ks[4], (di, d)) / math.sqrt(di)).astype(dtype),
    }


def causal_conv1d(x: Array, w: Array, bias: Array, state: Optional[Array] = None):
    """x: (B, S, C); w: (K, C) depthwise. Returns (y, new_state (B, K-1, C))."""
    k = w.shape[0]
    if state is not None:
        x_ext = jnp.concatenate([state, x], axis=1)
    else:
        x_ext = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # depthwise conv as sum of shifted slices (K is tiny, 4)
    s = x.shape[1]
    y = sum(x_ext[:, i : i + s, :] * w[i][None, None, :] for i in range(k))
    y = y + bias[None, None, :]
    new_state = x_ext[:, -(k - 1) :, :] if k > 1 else None
    return jax.nn.silu(y), new_state


def mamba_apply(params, x: Array, cfg: ModelConfig, *, ssm_state=None, conv_state=None,
                use_pallas: bool = False):
    """Mamba-2 block. x: (B,S,D).

    Train/prefill: ssm_state/conv_state None -> chunked SSD, returns states.
    Decode: S==1 with states -> recurrent step.
    Returns (y, (new_ssm_state, new_conv_state)).
    """
    bsz, s, d = x.shape
    sc = cfg.ssm
    di = sc.d_inner(d)
    nh = sc.num_heads(d)
    n = sc.d_state

    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(x.dtype))
    z, xin, bmat, cmat, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1
    )
    conv_in = jnp.concatenate([xin, bmat, cmat], axis=-1)
    decode = ssm_state is not None and s == 1
    conv_out, new_conv = causal_conv1d(
        conv_in, params["conv_w"].astype(x.dtype), params["conv_b"].astype(x.dtype),
        state=conv_state.astype(x.dtype) if conv_state is not None else None,
    )
    if conv_state is not None and new_conv is not None:
        new_conv = new_conv.astype(conv_state.dtype)
    xin, bmat, cmat = jnp.split(conv_out, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None, None, :])
    a = -jnp.exp(params["a_log"])  # (H,) negative

    xh = xin.reshape(bsz, s, nh, sc.head_dim)
    if decode:
        y, new_ssm = ssd_decode_step(
            xh.astype(jnp.float32), dt, a, bmat.astype(jnp.float32),
            cmat.astype(jnp.float32), ssm_state.astype(jnp.float32),
        )
    elif use_pallas:
        from repro.kernels import ops as kops

        y, new_ssm = kops.ssd_scan(
            xh.astype(jnp.float32), dt, a, bmat.astype(jnp.float32),
            cmat.astype(jnp.float32), chunk=sc.chunk, interpret=True,
        )
    else:
        y, new_ssm = ssd_chunked(
            xh.astype(jnp.float32), dt, a, bmat.astype(jnp.float32),
            cmat.astype(jnp.float32), chunk=sc.chunk,
            h0=ssm_state.astype(jnp.float32) if ssm_state is not None else None,
        )
    y = y + xh.astype(jnp.float32) * params["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, di).astype(x.dtype)
    # gated RMSNorm (Mamba-2)
    y = y * jax.nn.silu(z)
    dtv = y.dtype
    y32 = y.astype(jnp.float32)
    var = jnp.mean(y32 * y32, axis=-1, keepdims=True)
    y = (y32 * jax.lax.rsqrt(var + cfg.norm_eps)).astype(dtv) * params["norm_w"].astype(dtv)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(x.dtype))
    new_ssm = new_ssm.astype(jnp.float32)
    return out, (new_ssm, new_conv)
