"""Expert-parallel MoE via explicit shard_map all-to-all.

GSPMD lowers the scatter-based MoE dispatch to f32 ALL-REDUCES of the full
(B, S*topk, D) buffers (measured: 5.6 TB/step on qwen3-235b train_4k,
97% of all collective bytes). The communication-optimal schedule is the
GShard/DeepSpeed one: route token copies to their experts' home shards
with `jax.lax.all_to_all`, run the expert FFN locally, route back, and
combine locally. This module implements exactly that under `shard_map`:

  per device:  local tokens -(scatter, local)-> (tp, E_loc*C, D)
               -- all_to_all over the TP axis -->
               (tp, E_loc*C, D) grouped by my experts -> FFN ->
               -- all_to_all back --> local combine with gates.

Cross-shard traffic per layer: 2 x (E, C_local, D) in activation dtype,
instead of ~2 x (B, S*topk, D) f32 all-reduce. Both all_to_alls are
linear, so JAX autodiff transposes them back to all_to_alls - the backward
pass gets the same schedule for free.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.ad_checkpoint
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distribution import context as ctx
from repro.models.layers import activation_fn


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    return max(int(math.ceil(tokens * m.top_k * m.capacity_factor / m.num_experts)), 1)


def moe_apply_a2a(params, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Drop-in for layers.moe_apply when the activation-sharding context is
    installed and the TP axis divides num_experts. x: (B, S, D)."""
    mesh = ctx._STATE["mesh"]
    batch_ax = ctx._STATE["batch"]
    model_ax = ctx._STATE["model"]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = sizes.get(model_ax, 1)
    m = cfg.moe
    e, k = m.num_experts, m.top_k
    e_loc = e // tp
    swiglu = cfg.activation == "swiglu"

    def local(xl, router, wg, wu, wd):
        # xl: (B_loc, S, D) - same tokens on every model shard within a
        # data shard. wg/wu/wd: (E_loc, D, F) local experts.
        b, s, d = xl.shape
        toks = b * s
        c = _capacity(toks, cfg)
        xt = xl.reshape(toks, d)
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, -1)
        gates, ids = jax.lax.top_k(probs, k)  # (T, k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

        # position of each (token, choice) within its expert
        flat = ids.reshape(-1)
        onehot = jax.nn.one_hot(flat, e, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - 1
        pos = jnp.take_along_axis(pos, flat[:, None], axis=1)[:, 0].reshape(toks, k)
        keep = pos < c
        # destination layout: shard = id // e_loc, row = (id % e_loc) * c + pos
        slot = ids * c + jnp.minimum(pos, c - 1)  # global (E*C) slot

        buf = jnp.zeros((e * c, d), xl.dtype)
        for j in range(k):
            buf = buf.at[slot[:, j]].add(xt * keep[:, j, None].astype(xl.dtype))
        buf = buf.reshape(tp, e_loc * c, d)
        # exchange: device p receives every shard's block for ITS experts
        recv = jax.lax.all_to_all(buf, model_ax, split_axis=0, concat_axis=0)
        # name the a2a results so the layer remat policy can SAVE them:
        # recomputing the forward under remat would re-run both exchanges
        recv = jax.ad_checkpoint.checkpoint_name(recv, "moe_a2a")
        # (tp, e_loc*c, d): entry [src] = tokens from shard src for my experts
        recv = recv.reshape(tp, e_loc, c, d).transpose(1, 0, 2, 3)
        recv = recv.reshape(e_loc, tp * c, d)

        if swiglu:
            g = jnp.einsum("ekd,edf->ekf", recv, wg.astype(xl.dtype))
            u = jnp.einsum("ekd,edf->ekf", recv, wu.astype(xl.dtype))
            h = jax.nn.silu(g) * u
        else:
            u = jnp.einsum("ekd,edf->ekf", recv, wu.astype(xl.dtype))
            h = activation_fn(cfg.activation)(u)
        out = jnp.einsum("ekf,efd->ekd", h, wd.astype(xl.dtype))

        out = out.reshape(e_loc, tp, c, d).transpose(1, 0, 2, 3)  # (tp, e_loc, c, d)
        back = jax.lax.all_to_all(
            out.reshape(tp, e_loc * c, d), model_ax, split_axis=0, concat_axis=0
        )
        back = jax.ad_checkpoint.checkpoint_name(back, "moe_a2a")
        back = back.reshape(e * c, d)  # my tokens' results, global slot layout

        got = back[slot.reshape(-1)].reshape(toks, k, d)
        w = (gates * keep).astype(xl.dtype)
        y = jnp.einsum("tkd,tk->td", got, w).reshape(b, s, d)

        # load-balance aux (Switch), averaged over the data axes
        f_e = jnp.mean(jax.nn.one_hot(ids[:, 0], e, dtype=jnp.float32), axis=0)
        p_e = jnp.mean(probs, axis=0)
        aux = e * jnp.sum(f_e * p_e) * m.router_aux_weight
        if batch_ax:
            aux = jax.lax.pmean(aux, batch_ax)
        return y, aux

    xspec = P(batch_ax, None, None)
    wspec = P(model_ax, None, None)
    y, aux = shard_map(
        local,
        mesh=mesh,
        in_specs=(xspec, P(), wspec, wspec, wspec),
        out_specs=(xspec, P()),
        check_rep=False,
    )(
        x,
        params["router"],
        params.get("w_gate", params["w_up"]),
        params["w_up"],
        params["w_down"],
    )
    return y, aux


def a2a_applicable(cfg: ModelConfig) -> bool:
    if not ctx.active() or not cfg.moe.enabled:
        return False
    mesh = ctx._STATE["mesh"]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = sizes.get(ctx._STATE["model"], 1)
    return tp > 1 and cfg.moe.num_experts % tp == 0
