"""Modality frontend STUBS (per assignment carve-out).

The ViT / codec encoders are NOT implemented; ``input_specs`` supplies
precomputed patch/frame embeddings of the right shape. The only learned
piece here is the projector that maps frontend features into d_model.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# feature dims the (stub) encoders would emit
FRONTEND_DIMS = {"vision": 1024, "audio": 128}


def init_frontend(key, cfg: ModelConfig, dtype=jnp.float32):
    d_in = FRONTEND_DIMS[cfg.frontend]
    k1, k2 = jax.random.split(key)
    return {
        "proj": (jax.random.normal(k1, (d_in, cfg.d_model)) / math.sqrt(d_in)).astype(dtype),
        "bias": jnp.zeros((cfg.d_model,), dtype),
    }


def frontend_apply(params, feats: jax.Array) -> jax.Array:
    """feats: (B, F, d_in) -> (B, F, d_model)."""
    return jnp.einsum("bfd,de->bfe", feats, params["proj"].astype(feats.dtype)) + params[
        "bias"
    ].astype(feats.dtype)
