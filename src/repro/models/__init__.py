from repro.models.model import (
    find_period,
    forward,
    init_caches,
    init_params,
    loss_fn,
    make_decode_step,
    make_prefill_step,
    make_train_step,
    signature,
    softmax_xent,
)

__all__ = [
    "find_period",
    "forward",
    "init_caches",
    "init_params",
    "loss_fn",
    "make_decode_step",
    "make_prefill_step",
    "make_train_step",
    "signature",
    "softmax_xent",
]
