"""Model backends for the serving engine.

A runner exposes exactly two pure functions the engine composes into its
jitted step:

* ``prefill(params, caches, prompts)``: ``prompts`` (B, P) int32 ->
  ``(logits (B, P, V), new_caches)`` - a fresh-sequence pass (scalar
  cache index 0). The engine gathers each row's logits at its own
  prompt length and WHERE-merges the cache rows of the slots it admitted.
* ``decode(params, tok, caches, pos)``: ``tok`` (B, 1), ``pos`` (B,)
  per-slot entry counts -> ``(logits (B, V), new_caches)`` - one token
  per slot at each slot's OWN position (slot-indexed KV writes, see
  ``models.layers._row_cache_update``).

Both backends restrict to attention-only architectures (MoE allowed
under DROPLESS dispatch): padded batched prefill relies on causal
masking to keep pad garbage out of valid rows, which holds for KV caches
but NOT for SSM recurrent state (pad tokens would pollute it) or
capacity-bounded MoE routing (pad tokens would steal expert capacity
from real rows - dropless dispatch computes every routed token, so each
row's output is independent of its dispatch-group neighbours).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M

Array = jax.Array


def check_servable(cfg: ModelConfig) -> None:
    """Raise for architectures the serving engine cannot run correctly.

    Attention configs serve (any layer-group period - the single-device
    runner scans slots natively and the pipeline runner dispatches a
    static block-kind schedule); MoE layers serve under DROPLESS dispatch
    only. SSM/hybrid stay rejected: padded batched prefill relies on
    causal masking, which protects KV attention but not recurrent state.
    """
    sig = M.signature(cfg)
    if any(kind != "A" for kind, _, _ in sig):
        raise ValueError(
            "serving engine: SSM/hybrid archs are unservable - padded "
            "batched prefill is masked out of KV attention but would "
            "pollute the recurrent scan state")
    if any(is_moe for _, is_moe, _ in sig) and cfg.moe.dispatch != "dropless":
        raise ValueError(
            "serving engine: capacity-dropping MoE is unservable (padded "
            "prefill rows steal expert capacity from real rows); set "
            "moe.dispatch='dropless'")


class SingleDeviceRunner:
    """Whole model on one device; caches are the stacked per-layer rings."""

    def __init__(self, cfg: ModelConfig, *, compute_dtype=jnp.float32):
        check_servable(cfg)
        self.cfg = cfg
        self.compute_dtype = compute_dtype

    def init_caches(self, num_slots: int, cache_len: int):
        return M.init_caches(self.cfg, num_slots, cache_len,
                             dtype=self.compute_dtype)

    def prefill(self, params, caches, prompts):
        logits, new_caches, _ = M.forward(
            params, prompts, self.cfg, caches=caches,
            cache_index=jnp.zeros((), jnp.int32), remat=False,
            compute_dtype=self.compute_dtype)
        return logits, new_caches

    def decode(self, params, tok, caches, pos):
        logits, new_caches, _ = M.forward(
            params, tok, self.cfg, caches=caches, cache_index=pos,
            remat=False, compute_dtype=self.compute_dtype)
        return logits[:, -1], new_caches


class PipelineRunner:
    """Split plan on a stage mesh: per-stage KV rings, ppermute hops.

    ``boundaries`` is the split plan's cumulative cut points (the Eq. 10
    decision variable); each stage holds only its own layers' KV ring and
    activations cross stage boundaries on the wire
    (``PipelineConfig.wire_dtype``) - serving the model exactly as the
    paper deploys it across hops.
    """

    def __init__(self, cfg: ModelConfig, mesh, boundaries: Sequence[int],
                 *, stage_axis: str = "stage", pipe=None):
        from repro.core.pipeline import PipelineConfig, pipeline_serve_fns

        check_servable(cfg)
        if pipe is None:
            pipe = PipelineConfig(compute_dtype="float32")
        self.cfg = cfg
        self.mesh = mesh
        self.boundaries = tuple(int(b) for b in boundaries)
        self.stage_axis = stage_axis
        self.pipe = pipe
        self.compute_dtype = pipe.dtype
        self._prefill, self._decode = pipeline_serve_fns(
            cfg, mesh, self.boundaries, stage_axis=stage_axis, pipe=pipe)

    def init_caches(self, num_slots: int, cache_len: int):
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.core.pipeline import stage_kv_caches

        caches = stage_kv_caches(self.cfg, self.boundaries, num_slots,
                                 cache_len, dtype=self.compute_dtype)
        # place fresh rings with their steady-state sharding up front:
        # the serve passes emit P(stage)-sharded caches, and feeding the
        # engine step host-layout zeros on call 1 then stage-sharded
        # caches on call 2 would compile the step TWICE (one executable
        # per input sharding - a multi-second hiccup mid-service)
        sharding = NamedSharding(self.mesh, PartitionSpec(self.stage_axis))
        return jax.tree.map(lambda c: jax.device_put(c, sharding), caches)

    def prefill(self, params, caches, prompts):
        return self._prefill(params, caches, prompts)

    def decode(self, params, tok, caches, pos):
        return self._decode(params, tok, caches, pos)


def cache_where(mask: Array, new_caches, old_caches):
    """Per-slot cache select: ``mask`` (B,) picks NEW rows, else old.

    Works for both runner cache layouts - the slot axis is the unique
    axis of size ``B = len(mask)``... which is ambiguous in general, so
    the axis is located by matching ``B`` from the RIGHT (the slot axis
    sits left of (kv_len, KH, hd) in both layouts: axis -4).
    """

    def one(n, o):
        m = mask.reshape((-1,) + (1,) * 3)
        return jnp.where(
            jnp.expand_dims(m, tuple(range(n.ndim - 4))), n, o)

    return jax.tree.map(one, new_caches, old_caches)
