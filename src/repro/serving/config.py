"""Serving knobs + the config-driven launcher contract.

``ServeConfig`` is the single source of truth for both entrypoints
(``examples/serve.py`` and ``launch/serve.py``): the launcher loads a
JSON file (``--config serve.json``), applies CLI ``--key value``
overrides on top, and hands the result to
:class:`repro.serving.service.ServingService` - the same
config-file-plus-overrides shape as the exemplar split-deployment
launchers, so a deployment is a reviewable artifact instead of a shell
history.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple


@dataclass(frozen=True)
class ServeConfig:
    """Engine + scheduler + trace knobs.

    Scheduler knobs: ``num_slots`` is the continuous batch width (N
    draining microbatch slots), ``arrival_slots`` bounds admissions per
    tick (A), ``decode_chunk`` is tokens decoded per engine tick (the
    admission latency/dispatch-overhead trade: a slot freed mid-chunk
    re-admits only at the next tick).
    """

    arch: str = "qwen2_5_3b"      # repro.configs module name
    reduced: bool = True          # .reduced() dry-run arch (CPU-sized)
    num_layers: Optional[int] = None  # override depth (benchmarks)
    num_slots: int = 8
    arrival_slots: int = 4
    prompt_pad: int = 32          # admitted prompts pad to this length
    max_new: int = 32             # gen_buf depth / decode scan bound
    decode_chunk: int = 8
    temperature: float = 0.0
    seed: int = 0
    # split serving: None = single device; else the split plan's
    # cumulative cut points, run on a stage mesh of len(boundaries)
    # devices with per-stage KV rings.
    boundaries: Optional[Tuple[int, ...]] = None
    compute_dtype: str = "float32"
    wire_dtype: Optional[str] = None
    # online re-planner cadence: re-score the split plan every K engine
    # ticks (0 = off). Re-plans are recorded, not applied mid-flight
    # (cache migration between stages is out of scope).
    replan_every: int = 0
    # failure handling (active when a FaultSchedule is passed to run()):
    # deadline_s is the default per-request completion budget after
    # arrival (0 = no deadline; Request.deadline overrides); a failed
    # tick retries up to max_retries times with exponential backoff
    # starting at retry_backoff_s before evicting in-flight slots;
    # fault_tick_s > 0 drives the FaultClock deterministically
    # (schedule time = tick * fault_tick_s, independent of wall clock).
    deadline_s: float = 0.0
    max_retries: int = 3
    retry_backoff_s: float = 0.01
    fault_tick_s: float = 0.0

    def model_config(self):
        import importlib

        mod = importlib.import_module(f"repro.configs.{self.arch}")
        cfg = mod.CONFIG.reduced() if self.reduced else mod.CONFIG
        if self.num_layers is not None:
            cfg = dataclasses.replace(cfg, num_layers=self.num_layers)
        return cfg

    @classmethod
    def load(cls, path: Optional[str] = None,
             overrides: Optional[dict] = None) -> "ServeConfig":
        """JSON file -> ServeConfig, with ``overrides`` applied on top.

        Unknown keys are an error (a typoed knob must not silently run
        the defaults)."""
        raw = {}
        if path is not None:
            with open(path) as f:
                raw.update(json.load(f))
        raw.update(overrides or {})
        fields = {f.name: f for f in dataclasses.fields(cls)}
        unknown = sorted(set(raw) - set(fields))
        if unknown:
            raise KeyError(f"unknown ServeConfig keys: {unknown}")
        if "boundaries" in raw and raw["boundaries"] is not None:
            raw["boundaries"] = tuple(int(b) for b in raw["boundaries"])
        return cls(**raw)

    @staticmethod
    def parse_override(key: str, value: str):
        """CLI override coercion: ``--num_slots 16``, ``--boundaries
        2,4``, ``--reduced false``."""
        fields = {f.name: f for f in dataclasses.fields(ServeConfig)}
        if key not in fields:
            raise KeyError(f"unknown ServeConfig key: {key}")
        if key == "boundaries":
            return tuple(int(x) for x in value.split(","))
        typ = fields[key].type
        if value.lower() in ("none", "null"):
            return None
        if "bool" in str(typ):
            return value.lower() in ("1", "true", "yes")
        if "int" in str(typ):
            return int(value)
        if "float" in str(typ):
            return float(value)
        return value
