"""Static batched generation: padded prefill + ONE fused decode dispatch.

This is the shared core the v0 ``examples/serve.py`` and
``launch/serve.py`` both hand-rolled as a per-token Python loop (one
device dispatch + host sync per generated token). Here the whole decode
runs as a single jitted ``lax.scan`` - one dispatch per ``max_new``
tokens - and the same routine serves as (a) the demo/launcher generate,
(b) the benchmarks' static-batch baseline, and (c) the engine's
bit-identity reference (``generate_reference``).

Bit-identity mechanics (measured on the CPU backend, pinned by
``tests/test_serving.py``): per-ROW float results are invariant to the
other rows' contents at a FIXED batch shape, but a (1,d)x(d,e) decode
matmul is NOT bitwise a row of the (N,d)x(d,e) one (gemv vs gemm
accumulation order, ~6e-7 drift). ``generate_reference`` therefore runs
the single request alone in row 0 of a batch PADDED to the engine's slot
count - same shapes as the engine step, so equality is structural.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def request_key(base_key: Array, req_id) -> Array:
    """Per-request sampling stream, independent of slot/tick placement."""
    return jax.random.fold_in(base_key, req_id)


def sample_token(logits: Array, key: Array, temperature: float) -> Array:
    """(.., V) f32 logits -> int32 token. ``temperature`` is a static
    Python float: 0.0 means greedy argmax (no key consumed)."""
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / temperature
    if logits.ndim == 1:
        return jax.random.categorical(key, scaled).astype(jnp.int32)
    keys = jax.random.split(key, logits.shape[0])
    return jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)


def _token_key(base_key: Array, req_id: Array, token_idx) -> Array:
    return jax.random.fold_in(request_key(base_key, req_id), token_idx)


def _row_sample(logits: Array, base_key: Array, req_id: Array, token_idx,
                temperature: float) -> Array:
    """Per-row sampling with per-(request, token) keys: row ``b`` draws
    from ``fold_in(fold_in(base, req_id[b]), token_idx[b])`` - the key
    depends only on WHICH request and WHICH token, never on the slot or
    tick it happens to occupy, so the engine and the reference consume
    identical streams."""
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    token_idx = jnp.broadcast_to(token_idx, req_id.shape)
    keys = jax.vmap(_token_key, in_axes=(None, 0, 0))(base_key, req_id,
                                                      token_idx)
    return jax.vmap(jax.random.categorical)(
        keys, logits.astype(jnp.float32) / temperature).astype(jnp.int32)


def make_generate_fn(runner, *, max_new: int, temperature: float = 0.0):
    """Build the fused static generate: ONE prefill + ONE decode scan.

    Returned ``generate(params, caches, prompts, plens, gen_targets,
    req_ids, base_key)``:

    * ``prompts`` (B, P) zero-padded, ``plens`` (B,) true lengths;
    * ``gen_targets`` (B,) tokens wanted per row (<= max_new); rows stop
      advancing once done (their KV writes freeze in place, masked);
    * returns ``(tokens (B, max_new) int32, n_gen (B,))``.

    Jit this (it is one trace for all call sites); the decode scan is the
    satellite "fold the per-token Python loop into one dispatch".
    """

    def generate(params, caches, prompts, plens, gen_targets, req_ids,
                 base_key):
        b = prompts.shape[0]
        logits_all, caches = runner.prefill(params, caches, prompts)
        last = jnp.take_along_axis(
            logits_all, (plens - 1)[:, None, None], axis=1)[:, 0]
        tok = _row_sample(last.astype(jnp.float32), base_key, req_ids,
                          jnp.zeros((b,), jnp.int32), temperature)
        buf0 = jnp.zeros((b, max_new), jnp.int32).at[:, 0].set(tok)
        active0 = gen_targets > 1

        def step(carry, _):
            caches, tok, pos, n_gen, active, buf = carry
            logits, caches = runner.decode(params, tok[:, None], caches, pos)
            nxt = _row_sample(logits.astype(jnp.float32), base_key, req_ids,
                              n_gen, temperature)
            tok = jnp.where(active, nxt, tok)
            buf = jax.vmap(
                lambda row, t, i: jax.lax.dynamic_update_slice(row, t[None], (i,))
            )(buf, tok, jnp.clip(n_gen, 0, max_new - 1))
            # frozen rows keep their old buf rows: re-select
            buf = jnp.where(active[:, None], buf, carry[5])
            pos = jnp.where(active, pos + 1, pos)
            n_gen = jnp.where(active, n_gen + 1, n_gen)
            active = active & (n_gen < gen_targets)
            return (caches, tok, pos, n_gen, active, buf), None

        n0 = jnp.ones((b,), jnp.int32)
        carry = (caches, tok, plens, n0, active0, buf0)
        (caches, tok, pos, n_gen, active, buf), _ = jax.lax.scan(
            step, carry, None, length=max_new - 1)
        return buf, n_gen

    return generate


def generate_static(runner, params, prompts, plens, gen_targets, *,
                    max_new: int, temperature: float = 0.0,
                    base_key=None, req_ids=None, cache_len=None,
                    pad_rows: Optional[int] = None):
    """Convenience one-shot static generate (builds caches, jits, runs).

    ``pad_rows``: pad the batch with inert rows up to this total so the
    decode matmuls have the same shape as an engine with that many slots
    (see module docstring); returns only the real rows.
    """
    prompts = jnp.asarray(prompts, jnp.int32)
    plens = jnp.asarray(plens, jnp.int32)
    gen_targets = jnp.asarray(gen_targets, jnp.int32)
    b, p = prompts.shape
    if req_ids is None:
        req_ids = jnp.arange(b, dtype=jnp.int32)
    req_ids = jnp.asarray(req_ids, jnp.int32)
    if base_key is None:
        base_key = jax.random.PRNGKey(0)
    n_real = b
    if pad_rows is not None and pad_rows > b:
        pad = pad_rows - b
        prompts = jnp.concatenate(
            [prompts, jnp.zeros((pad, p), jnp.int32)])
        plens = jnp.concatenate([plens, jnp.ones((pad,), jnp.int32)])
        gen_targets = jnp.concatenate(
            [gen_targets, jnp.ones((pad,), jnp.int32)])
        req_ids = jnp.concatenate(
            [req_ids, jnp.full((pad,), -1, jnp.int32)])
        b = pad_rows
    if cache_len is None:
        cache_len = p + max_new
    caches = runner.init_caches(b, cache_len)
    gen = jax.jit(make_generate_fn(runner, max_new=max_new,
                                   temperature=temperature))
    buf, n_gen = gen(params, caches, prompts, plens, gen_targets, req_ids,
                     base_key)
    return buf[:n_real], n_gen[:n_real]


def generate_reference(runner, params, prompt, *, gen_target: int,
                       max_new: int, prompt_pad: int, slots: int,
                       temperature: float = 0.0, base_key=None,
                       req_id: int = 0, cache_len=None):
    """THE single-request reference path: one request, alone, in row 0 of
    a ``slots``-row batch (the other rows are inert padding). Engine
    outputs must match this bitwise per request."""
    prompt = jnp.asarray(prompt, jnp.int32)
    pl = prompt.shape[0]
    padded = jnp.zeros((1, prompt_pad), jnp.int32).at[0, :pl].set(prompt)
    toks, n_gen = generate_static(
        runner, params, padded, jnp.array([pl], jnp.int32),
        jnp.array([gen_target], jnp.int32), max_new=max_new,
        temperature=temperature, base_key=base_key,
        req_ids=jnp.array([req_id], jnp.int32), cache_len=cache_len,
        pad_rows=slots)
    return toks[0, :int(n_gen[0])]


def decode_python_loop(runner, params, prompts, plens, gen_targets, *,
                       max_new: int, temperature: float = 0.0,
                       base_key=None, req_ids=None, cache_len=None):
    """The v0 per-token host loop (one jitted dispatch + host sync per
    token). Kept ONLY as the benchmark "before" for the fused-scan
    satellite; produces the same tokens as :func:`generate_static`."""
    prompts = jnp.asarray(prompts, jnp.int32)
    plens = jnp.asarray(plens, jnp.int32)
    gen_targets = jnp.asarray(gen_targets, jnp.int32)
    b, p = prompts.shape
    if req_ids is None:
        req_ids = jnp.arange(b, dtype=jnp.int32)
    if base_key is None:
        base_key = jax.random.PRNGKey(0)
    if cache_len is None:
        cache_len = p + max_new
    caches = runner.init_caches(b, cache_len)

    prefill = jax.jit(runner.prefill)
    decode = jax.jit(runner.decode)
    sample = jax.jit(lambda lg, n: _row_sample(
        lg.astype(jnp.float32), base_key, req_ids, n, temperature))

    logits_all, caches = prefill(params, caches, prompts)
    last = jnp.take_along_axis(
        logits_all, (plens - 1)[:, None, None], axis=1)[:, 0]
    tok = sample(last, jnp.zeros((b,), jnp.int32))
    buf = [tok]
    pos = plens
    for i in range(1, max_new):
        logits, caches = decode(params, tok[:, None], caches, pos)
        active = jnp.asarray(i, jnp.int32) < gen_targets
        nxt = sample(logits, jnp.full((b,), i, jnp.int32))
        tok = jnp.where(active, nxt, tok)
        buf.append(jnp.where(active, tok, 0))
        pos = jnp.where(active, pos + 1, pos)
        jax.block_until_ready(tok)  # the v0 loop's per-token host sync
    toks = jnp.stack(buf, axis=1)
    n_gen = jnp.minimum(gen_targets, max_new)
    mask = jnp.arange(max_new)[None, :] < n_gen[:, None]
    return jnp.where(mask, toks, 0), n_gen
