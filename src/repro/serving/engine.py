"""The continuous-batching engine: ONE jitted step, slot-indexed state.

Each call to the engine step (a) admits up to ``A`` newly arrived
requests into free slots - in-trace, via a cumsum pack over the free-slot
mask, (b) prefills the admitted rows (a batched padded prefill whose
cache rows are WHERE-merged only for taken slots, ``lax.cond``-ed out
entirely on ticks with no arrivals), and (c) decodes ``decode_chunk``
tokens for every active slot in one ``lax.scan`` (slot-indexed KV
writes, per-slot positions, active masking, on-device sampling).

All shapes are static - (N) slots, (A, P) arrival buffers, fixed chunk -
so arrivals, completions, and re-plans never retrace: the step stays one
compiled trace for the whole service lifetime (``step.trace_count``
audits this, same idiom as ``core.splitting.make_plan_scorer``).

Invariant the bit-identity proof leans on: KV caches only ever hold
FINITE values. Freed slots are not zeroed - their stale rows are masked
out of attention by the per-row causal mask, and a masked FINITE value
is a bitwise no-op on the softmax (exact-zero weight), whereas a NaN/Inf
would poison the row max. Stale rows in the new request's decode region
are overwritten the tick before they could first be attended.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.serving.batching import _row_sample
from repro.serving.runners import cache_where

Array = jax.Array


class EngineState(NamedTuple):
    caches: object          # runner cache pytree, slot axis = num_slots
    prompt: Array           # (N, P) int32 zero-padded admitted prompts
    plen: Array             # (N,) int32 true prompt lengths
    gen_target: Array       # (N,) int32 tokens wanted per slot
    pos: Array              # (N,) int32 per-slot KV entry count
    last_tok: Array         # (N,) int32 token feeding the next decode
    n_gen: Array            # (N,) int32 tokens generated so far
    active: Array           # (N,) bool slot is mid-request
    req_id: Array           # (N,) int32 request id (-1 = never used)
    gen_buf: Array          # (N, G) int32 generated tokens per slot
    busy_steps: Array       # () int64-ish f32: sum of active slots/decode step
    decode_steps: Array     # () f32: total decode steps run


def init_engine_state(runner, num_slots: int, prompt_pad: int,
                      max_new: int, cache_len: int | None = None
                      ) -> EngineState:
    n, p, g = num_slots, prompt_pad, max_new
    if cache_len is None:
        cache_len = p + g
    state = EngineState(
        caches=runner.init_caches(n, cache_len),
        prompt=jnp.zeros((n, p), jnp.int32),
        plen=jnp.ones((n,), jnp.int32),
        gen_target=jnp.zeros((n,), jnp.int32),
        pos=jnp.zeros((n,), jnp.int32),
        last_tok=jnp.zeros((n,), jnp.int32),
        n_gen=jnp.zeros((n,), jnp.int32),
        active=jnp.zeros((n,), bool),
        req_id=jnp.full((n,), -1, jnp.int32),
        gen_buf=jnp.zeros((n, g), jnp.int32),
        busy_steps=jnp.zeros((), jnp.float32),
        decode_steps=jnp.zeros((), jnp.float32),
    )
    mesh = getattr(runner, "mesh", None)
    if mesh is not None:
        # match the step's OUTPUT placement from the start (caches are
        # stage-sharded by runner.init_caches, everything else comes out
        # of the stage pass replicated): a sharding flip between the
        # first and second call would compile the engine step twice
        from jax.sharding import NamedSharding, PartitionSpec

        rep = NamedSharding(mesh, PartitionSpec())
        state = state._replace(**{
            f: jax.device_put(getattr(state, f), rep)
            for f in EngineState._fields if f != "caches"})
    return state


def evict_slots(state: EngineState, mask) -> EngineState:
    """Free the masked slots WITHOUT touching their caches.

    The host calls this between engine ticks when the device carrying a
    stage dies: the in-flight requests are requeued and their slots
    handed back to the admitter. Caches are left stale on purpose - the
    finite-garbage invariant (module docstring) makes masked stale rows
    a bitwise no-op, exactly as after a normal completion, so eviction
    cannot perturb the tokens of requests it never touched. Plain slot
    bookkeeping on fixed shapes: the next engine step reuses the same
    compiled trace.
    """
    mask = jnp.asarray(mask, bool)
    return state._replace(
        active=state.active & ~mask,
        req_id=jnp.where(mask, jnp.int32(-1), state.req_id),
        n_gen=jnp.where(mask, jnp.int32(0), state.n_gen),
    )


def make_engine_step(runner, *, num_slots: int, arrival_slots: int,
                     prompt_pad: int, max_new: int, decode_chunk: int = 8,
                     temperature: float = 0.0, base_key=None,
                     skip_idle_prefill: bool = True):
    """Build the engine step. Returns ``step`` with a ``.trace_count``
    list ([0] on build; each RETRACE appends - the audit test pins
    ``len == 1`` across arrivals/completions/re-plans).

    ``step(params, state, arr_prompt (A, P), arr_plen (A,), arr_gen (A,),
    arr_req (A,), n_arr scalar)`` -> ``(state, report)`` where ``report``
    is the small host readback ``{active, req_id, n_gen, admitted}``.
    Jit with ``jax.jit(step, donate_argnums=(1,))`` so the caches update
    in place.

    ``skip_idle_prefill``: wrap the prefill sub-step in ``lax.cond`` so
    no-arrival ticks skip its FLOPs. Safe for the pipeline runner too:
    the predicate (``take.any()``) is computed from replicated state, so
    every stage shard takes the same branch and the prefill pass's
    collectives rendezvous uniformly. ``False`` runs the (masked)
    prefill unconditionally every tick.
    """
    if base_key is None:
        base_key = jax.random.PRNGKey(0)
    n, a, g = num_slots, arrival_slots, max_new
    trace_count: list = []

    def step(params, state: EngineState, arr_prompt, arr_plen, arr_gen,
             arr_req, n_arr):
        trace_count.append(1)

        # ---- admission: pack arrivals into free slots, in-trace --------
        free = ~state.active
        order = jnp.cumsum(free.astype(jnp.int32)) - 1     # rank among free
        take = free & (order < n_arr)                      # (N,)
        ai = jnp.clip(order, 0, a - 1)                     # arrival row/slot
        sel = lambda arr, old: jnp.where(take, arr[ai], old)
        prompt = jnp.where(take[:, None], arr_prompt[ai], state.prompt)
        plen = sel(arr_plen, state.plen)
        gen_target = sel(arr_gen, state.gen_target)
        req_id = sel(arr_req, state.req_id)
        n_gen = jnp.where(take, 0, state.n_gen)
        gen_buf = jnp.where(take[:, None], 0, state.gen_buf)
        active = state.active | take

        # ---- prefill sub-step (only the taken rows land) ---------------
        def do_prefill(operand):
            caches, prompt, last_tok, pos_c = operand
            logits_all, new_caches = runner.prefill(params, caches, prompt)
            caches = cache_where(take, new_caches, caches)
            last = jnp.take_along_axis(
                logits_all, (plen - 1)[:, None, None], axis=1)[:, 0]
            tok0 = _row_sample(last.astype(jnp.float32), base_key, req_id,
                               jnp.zeros((n,), jnp.int32), temperature)
            last_tok = jnp.where(take, tok0, last_tok)
            pos_c = jnp.where(take, plen, pos_c)
            return caches, prompt, last_tok, pos_c

        operand = (state.caches, prompt, state.last_tok, state.pos)
        if skip_idle_prefill:
            caches, _, last_tok, pos = jax.lax.cond(
                take.any(), do_prefill, lambda op: op, operand)
        else:
            caches, _, last_tok, pos = do_prefill(operand)
        gen_buf = jnp.where(take[:, None],
                            gen_buf.at[:, 0].set(last_tok), gen_buf)
        n_gen = jnp.where(take, 1, n_gen)
        # a gen_target==1 request completes at admission
        active = active & (n_gen < jnp.maximum(gen_target, 1))

        # ---- decode chunk: one scan, every slot at its own position ----
        def dstep(carry, _):
            caches, last_tok, pos, n_gen, active, gen_buf, busy = carry
            busy = busy + active.sum().astype(jnp.float32)
            logits, caches = runner.decode(params, last_tok[:, None],
                                           caches, pos)
            nxt = _row_sample(logits.astype(jnp.float32), base_key, req_id,
                              n_gen, temperature)
            last_tok = jnp.where(active, nxt, last_tok)
            written = jax.vmap(
                lambda row, t, i: jax.lax.dynamic_update_slice(
                    row, t[None], (i,))
            )(gen_buf, last_tok, jnp.clip(n_gen, 0, g - 1))
            gen_buf = jnp.where(active[:, None], written, gen_buf)
            pos = jnp.where(active, pos + 1, pos)
            n_gen = jnp.where(active, n_gen + 1, n_gen)
            active = active & (n_gen < gen_target)
            return (caches, last_tok, pos, n_gen, active, gen_buf, busy), None

        carry = (caches, last_tok, pos, n_gen, active, gen_buf,
                 state.busy_steps)
        (caches, last_tok, pos, n_gen, active, gen_buf, busy), _ = (
            jax.lax.scan(dstep, carry, None, length=decode_chunk))

        state = EngineState(
            caches=caches, prompt=prompt, plen=plen, gen_target=gen_target,
            pos=pos, last_tok=last_tok, n_gen=n_gen, active=active,
            req_id=req_id, gen_buf=gen_buf, busy_steps=busy,
            decode_steps=state.decode_steps + decode_chunk,
        )
        report = {"active": active, "req_id": req_id, "n_gen": n_gen,
                  "admitted": take}
        return state, report

    step.trace_count = trace_count
    return step
