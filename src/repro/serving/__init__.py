"""Continuous-batching split-inference serving.

The serving engine is the inference-side analogue of the fused train
chunk: ONE compiled step that admits newly arrived requests into free
microbatch slots, prefills them, and decodes a chunk of tokens for every
active slot - no stop-the-world rebatching, no per-token host dispatch.
Split plans (the paper's Eq. 10 output) run through the same engine via
the pipeline runner's per-stage KV rings, and the online re-planner
re-scores cut points as load shifts between ticks.

Layout:

* :mod:`repro.serving.engine` - jitted engine step + state.
* :mod:`repro.serving.runners` - single-device / pipeline model backends.
* :mod:`repro.serving.batching` - static batched generate (fused
  ``lax.scan`` decode) shared by the examples, the launcher, the
  benchmarks' static baseline, and the bit-identity reference.
* :mod:`repro.serving.service` - host-side queue, slot scheduler,
  wall-clock service loop, Poisson traces.
* :mod:`repro.serving.replanner` - online split re-scoring.
* :mod:`repro.serving.config` - engine/service knobs + JSON config.
"""
from repro.serving.batching import (decode_python_loop, generate_reference,
                                    generate_static, sample_token)
from repro.serving.config import ServeConfig
from repro.serving.engine import (EngineState, evict_slots,
                                  init_engine_state, make_engine_step)
from repro.serving.replanner import OnlineReplanner
from repro.serving.runners import PipelineRunner, SingleDeviceRunner
from repro.serving.service import (Request, RequestQueue, ServingService,
                                   SlotScheduler, poisson_trace)

__all__ = [
    "EngineState", "OnlineReplanner", "PipelineRunner", "Request",
    "RequestQueue", "ServeConfig", "ServingService", "SingleDeviceRunner",
    "SlotScheduler", "decode_python_loop", "evict_slots",
    "generate_reference", "generate_static", "init_engine_state",
    "make_engine_step", "poisson_trace", "sample_token",
]
