"""Host-side serving: request queue, slot scheduler, wall-clock loop.

The host does only bookkeeping - every token-level decision lives inside
the jitted engine step. Per tick the host (a) moves requests whose
arrival time has passed into the FIFO queue, (b) packs at most
``min(A, pending, free slots)`` of them into the fixed-shape arrival
buffers, (c) calls the engine step, and (d) drains completions from the
small report readback (pulling ``gen_buf`` rows only for slots that
finished). Idle ticks (nothing pending, nothing active) skip the step
call entirely.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.serving.config import ServeConfig


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (plen,) int32
    gen_target: int
    arrival_time: float = 0.0     # seconds from trace start

    @property
    def plen(self) -> int:
        return int(self.prompt.shape[0])


@dataclass
class Completion:
    rid: int
    tokens: np.ndarray
    arrival_time: float
    admit_time: float
    done_time: float

    @property
    def latency(self) -> float:
        return self.done_time - self.arrival_time


class RequestQueue:
    """FIFO of arrived-but-unadmitted requests."""

    def __init__(self, trace: List[Request]):
        self._future = sorted(trace, key=lambda r: r.arrival_time)
        self._ready: deque = deque()

    def advance(self, now: float) -> None:
        while self._future and self._future[0].arrival_time <= now:
            self._ready.append(self._future.pop(0))

    def pop(self, k: int) -> List[Request]:
        return [self._ready.popleft() for _ in range(min(k, len(self._ready)))]

    @property
    def pending(self) -> int:
        return len(self._ready)

    @property
    def exhausted(self) -> bool:
        return not self._future and not self._ready

    def next_arrival(self) -> Optional[float]:
        return self._future[0].arrival_time if self._future else None


class SlotScheduler:
    """Packs ready requests into the engine's fixed-shape arrival buffers."""

    def __init__(self, arrival_slots: int, prompt_pad: int):
        self.a = arrival_slots
        self.p = prompt_pad

    def pack(self, queue: RequestQueue, free_slots: int):
        """-> (admitted requests, prompt (A,P), plen, gen, rid, n_arr)."""
        reqs = queue.pop(min(self.a, free_slots))
        ap = np.zeros((self.a, self.p), np.int32)
        al = np.ones((self.a,), np.int32)
        ag = np.ones((self.a,), np.int32)
        ar = np.full((self.a,), -1, np.int32)
        for i, r in enumerate(reqs):
            if r.plen > self.p:
                raise ValueError(
                    f"request {r.rid} prompt length {r.plen} exceeds "
                    f"prompt_pad {self.p}")
            ap[i, :r.plen] = r.prompt
            al[i] = r.plen
            ag[i] = r.gen_target
            ar[i] = r.rid
        return reqs, ap, al, ag, ar, len(reqs)


class ServingService:
    """The continuous-batching service loop over one engine."""

    def __init__(self, cfg: ServeConfig, params=None, mesh=None):
        import jax
        import jax.numpy as jnp

        from repro.models import model as M
        from repro.serving.engine import (init_engine_state,
                                          make_engine_step)
        from repro.serving.runners import PipelineRunner, SingleDeviceRunner

        self.cfg = cfg
        self.model_cfg = cfg.model_config()
        dtype = jnp.dtype(cfg.compute_dtype)
        if cfg.boundaries is None:
            self.runner = SingleDeviceRunner(self.model_cfg,
                                             compute_dtype=dtype)
        else:
            from repro.core.pipeline import PipelineConfig
            from repro.launch.mesh import make_stage_mesh

            if mesh is None:
                mesh = make_stage_mesh(len(cfg.boundaries))
            pipe = PipelineConfig(compute_dtype=cfg.compute_dtype,
                                  wire_dtype=cfg.wire_dtype)
            self.runner = PipelineRunner(self.model_cfg, mesh,
                                         cfg.boundaries, pipe=pipe)
        if params is None:
            params = M.init_params(jax.random.PRNGKey(cfg.seed),
                                   self.model_cfg)
        self.params = params
        self.base_key = jax.random.PRNGKey(cfg.seed)
        self.step = make_engine_step(
            self.runner, num_slots=cfg.num_slots,
            arrival_slots=cfg.arrival_slots, prompt_pad=cfg.prompt_pad,
            max_new=cfg.max_new, decode_chunk=cfg.decode_chunk,
            temperature=cfg.temperature, base_key=self.base_key,
            # safe for BOTH runners: the cond predicate (take.any()) is
            # computed from replicated state, so every stage shard takes
            # the same branch and the prefill pass's collectives
            # rendezvous uniformly (pinned bitwise by the pipeline
            # serving test)
            skip_idle_prefill=True)
        self._jstep = jax.jit(self.step, donate_argnums=(1,))
        self.state = init_engine_state(
            self.runner, cfg.num_slots, cfg.prompt_pad, cfg.max_new)
        self.replanner = None  # attach via attach_replanner()

    def attach_replanner(self, replanner) -> None:
        self.replanner = replanner

    def run(self, trace: List[Request], *, realtime: bool = False,
            max_ticks: int = 100_000) -> Dict:
        """Serve ``trace`` to completion; returns results + metrics.

        ``realtime=False`` (benchmark mode) treats arrival times as a
        virtual clock that only moves forward when the engine would
        otherwise idle - arrivals still gate admission ORDER, but the
        engine never sleeps, so throughput comparisons are
        compute-bound. ``realtime=True`` sleeps until the next arrival.
        """
        import jax
        import jax.numpy as jnp

        queue = RequestQueue(list(trace))
        sched = SlotScheduler(self.cfg.arrival_slots, self.cfg.prompt_pad)
        admit_t: Dict[int, float] = {}
        arrive_t = {r.rid: r.arrival_time for r in trace}
        completions: List[Completion] = []
        seen_done = set()
        t0 = time.perf_counter()
        free = self.cfg.num_slots
        active_rids: set = set()
        replans = []
        tick = 0
        while tick < max_ticks:
            now = time.perf_counter() - t0
            queue.advance(now)
            if queue.pending == 0 and not active_rids:
                if queue.exhausted:
                    break
                # idle: jump the virtual clock to the next arrival
                nxt = queue.next_arrival()
                if realtime:
                    time.sleep(max(nxt - now, 0.0))
                else:
                    t0 -= max(nxt - now, 0.0)
                queue.advance(time.perf_counter() - t0)
            reqs, ap, al, ag, ar, n_arr = sched.pack(queue, free)
            now = time.perf_counter() - t0
            for r in reqs:
                admit_t[r.rid] = now
            self.state, report = self._jstep(
                self.params, self.state, jnp.asarray(ap), jnp.asarray(al),
                jnp.asarray(ag), jnp.asarray(ar), jnp.int32(n_arr))
            act = np.asarray(report["active"])
            rids = np.asarray(report["req_id"])
            ngen = np.asarray(report["n_gen"])
            now = time.perf_counter() - t0
            active_rids = {int(r) for r, a in zip(rids, act) if a and r >= 0}
            done_slots = [s for s in range(len(rids))
                          if rids[s] >= 0 and not act[s]
                          and int(rids[s]) not in seen_done]
            if done_slots:
                buf = np.asarray(self.state.gen_buf)  # pull only on completions
                for s in done_slots:
                    rid = int(rids[s])
                    seen_done.add(rid)
                    completions.append(Completion(
                        rid=rid, tokens=buf[s, :ngen[s]].copy(),
                        arrival_time=arrive_t[rid],
                        admit_time=admit_t[rid], done_time=now))
            free = int((~act).sum())
            if (self.replanner is not None and self.cfg.replan_every
                    and tick % self.cfg.replan_every == 0):
                occupancy = float(act.sum()) / max(len(act), 1)
                replans.append(self.replanner.replan(load=occupancy))
            tick += 1
        wall = time.perf_counter() - t0
        return self._metrics(completions, wall, tick, replans)

    def _metrics(self, completions: List[Completion], wall: float,
                 ticks: int, replans) -> Dict:
        lats = sorted(c.latency for c in completions)
        total_tokens = int(sum(len(c.tokens) for c in completions))
        busy = float(self.state.busy_steps)
        steps = float(self.state.decode_steps)
        pct = (lambda q: lats[min(int(q * len(lats)), len(lats) - 1)]
               if lats else float("nan"))
        return {
            "completions": {c.rid: c.tokens for c in completions},
            "latencies": {c.rid: c.latency for c in completions},
            "num_requests": len(completions),
            "wall_seconds": wall,
            "ticks": ticks,
            "requests_per_sec": len(completions) / wall if wall else 0.0,
            "tokens_per_sec": total_tokens / wall if wall else 0.0,
            "p50_latency_s": pct(0.50),
            "p99_latency_s": pct(0.99),
            # structural accounting (wall-clock independent, as in
            # core.transport): fraction of slot-steps doing useful decode
            "slot_occupancy": busy / (steps * self.cfg.num_slots)
            if steps else 0.0,
            "replans": replans,
        }


def poisson_trace(*, n_requests: int, rate_per_sec: float, vocab_size: int,
                  plen_range=(4, 32), gen_range=(4, 24), seed: int = 0
                  ) -> List[Request]:
    """Mixed-length Poisson arrival trace (exponential inter-arrivals)."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for rid in range(n_requests):
        t += float(rng.exponential(1.0 / rate_per_sec))
        pl = int(rng.integers(plen_range[0], plen_range[1] + 1))
        gt = int(rng.integers(gen_range[0], gen_range[1] + 1))
        out.append(Request(
            rid=rid,
            prompt=rng.integers(0, vocab_size, size=pl).astype(np.int32),
            gen_target=gt, arrival_time=t))
    return out
