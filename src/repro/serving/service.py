"""Host-side serving: request queue, slot scheduler, wall-clock loop.

The host does only bookkeeping - every token-level decision lives inside
the jitted engine step. Per tick the host (a) moves requests whose
arrival time has passed into the FIFO queue, (b) packs at most
``min(A, pending, free slots)`` of them into the fixed-shape arrival
buffers, (c) calls the engine step, and (d) drains completions from the
small report readback (pulling ``gen_buf`` rows only for slots that
finished). Idle ticks (nothing pending, nothing active) skip the step
call entirely.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.serving.config import ServeConfig


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (plen,) int32
    gen_target: int
    arrival_time: float = 0.0     # seconds from trace start
    # absolute trace-time completion deadline; inf = none. A request
    # still QUEUED past its deadline is dropped (reported under
    # ``expired``) instead of admitted - under faults an evicted request
    # re-enters the queue and can expire there too.
    deadline: float = float("inf")

    @property
    def plen(self) -> int:
        return int(self.prompt.shape[0])


@dataclass
class Completion:
    rid: int
    tokens: np.ndarray
    arrival_time: float
    admit_time: float
    done_time: float

    @property
    def latency(self) -> float:
        return self.done_time - self.arrival_time


class RequestQueue:
    """FIFO of arrived-but-unadmitted requests."""

    def __init__(self, trace: List[Request]):
        self._future = sorted(trace, key=lambda r: r.arrival_time)
        self._ready: deque = deque()

    def advance(self, now: float) -> None:
        while self._future and self._future[0].arrival_time <= now:
            self._ready.append(self._future.pop(0))

    def pop(self, k: int) -> List[Request]:
        k = max(min(int(k), len(self._ready)), 0)  # k <= 0 pops nothing
        return [self._ready.popleft() for _ in range(k)]

    def peek(self, k: int) -> List[Request]:
        """First ``k`` ready requests WITHOUT removing them (the
        scheduler validates before it pops, so a rejection never loses
        queued requests)."""
        k = max(min(int(k), len(self._ready)), 0)
        return [self._ready[i] for i in range(k)]

    def requeue_front(self, reqs: List[Request]) -> None:
        """Put evicted in-flight requests back at the HEAD of the queue
        (in the given order) so recovery re-admits them before newer
        arrivals."""
        self._ready.extendleft(reversed(reqs))

    def drop_expired(self, now: float) -> List[Request]:
        """Remove (and return) ready requests past their deadline."""
        expired = [r for r in self._ready if r.deadline <= now]
        if expired:
            dead = {id(r) for r in expired}
            self._ready = deque(r for r in self._ready if id(r) not in dead)
        return expired

    @property
    def pending(self) -> int:
        return len(self._ready)

    @property
    def exhausted(self) -> bool:
        return not self._future and not self._ready

    def next_arrival(self) -> Optional[float]:
        return self._future[0].arrival_time if self._future else None


class SlotScheduler:
    """Packs ready requests into the engine's fixed-shape arrival buffers."""

    def __init__(self, arrival_slots: int, prompt_pad: int):
        self.a = arrival_slots
        self.p = prompt_pad

    def pack(self, queue: RequestQueue, free_slots: int):
        """-> (admitted requests, prompt (A,P), plen, gen, rid, n_arr).

        Rejection is TOTAL: candidates are validated by peek before any
        is popped, so an oversized prompt raises with the queue intact
        (nothing admitted, nothing lost).
        """
        reqs = queue.peek(min(self.a, free_slots))
        for r in reqs:
            if r.plen > self.p:
                raise ValueError(
                    f"request {r.rid} prompt length {r.plen} exceeds "
                    f"prompt_pad {self.p}")
        reqs = queue.pop(len(reqs))
        ap = np.zeros((self.a, self.p), np.int32)
        al = np.ones((self.a,), np.int32)
        ag = np.ones((self.a,), np.int32)
        ar = np.full((self.a,), -1, np.int32)
        for i, r in enumerate(reqs):
            ap[i, :r.plen] = r.prompt
            al[i] = r.plen
            ag[i] = r.gen_target
            ar[i] = r.rid
        return reqs, ap, al, ag, ar, len(reqs)


class ServingService:
    """The continuous-batching service loop over one engine."""

    def __init__(self, cfg: ServeConfig, params=None, mesh=None):
        import jax
        import jax.numpy as jnp

        from repro.models import model as M
        from repro.serving.engine import (init_engine_state,
                                          make_engine_step)
        from repro.serving.runners import PipelineRunner, SingleDeviceRunner

        self.cfg = cfg
        self.model_cfg = cfg.model_config()
        dtype = jnp.dtype(cfg.compute_dtype)
        if cfg.boundaries is None:
            self.runner = SingleDeviceRunner(self.model_cfg,
                                             compute_dtype=dtype)
        else:
            from repro.core.pipeline import PipelineConfig
            from repro.launch.mesh import make_stage_mesh

            if mesh is None:
                mesh = make_stage_mesh(len(cfg.boundaries))
            pipe = PipelineConfig(compute_dtype=cfg.compute_dtype,
                                  wire_dtype=cfg.wire_dtype)
            self.runner = PipelineRunner(self.model_cfg, mesh,
                                         cfg.boundaries, pipe=pipe)
        if params is None:
            params = M.init_params(jax.random.PRNGKey(cfg.seed),
                                   self.model_cfg)
        self.params = params
        self.base_key = jax.random.PRNGKey(cfg.seed)
        self.step = make_engine_step(
            self.runner, num_slots=cfg.num_slots,
            arrival_slots=cfg.arrival_slots, prompt_pad=cfg.prompt_pad,
            max_new=cfg.max_new, decode_chunk=cfg.decode_chunk,
            temperature=cfg.temperature, base_key=self.base_key,
            # safe for BOTH runners: the cond predicate (take.any()) is
            # computed from replicated state, so every stage shard takes
            # the same branch and the prefill pass's collectives
            # rendezvous uniformly (pinned bitwise by the pipeline
            # serving test)
            skip_idle_prefill=True)
        self._jstep = jax.jit(self.step, donate_argnums=(1,))
        self.state = init_engine_state(
            self.runner, cfg.num_slots, cfg.prompt_pad, cfg.max_new)
        self.replanner = None  # attach via attach_replanner()
        # devices the serving pipeline occupies, as FaultSchedule rows:
        # one per stage for split serving, device 0 standalone
        self.stage_devices = (tuple(range(len(cfg.boundaries)))
                              if cfg.boundaries else (0,))

    def attach_replanner(self, replanner) -> None:
        self.replanner = replanner

    def run(self, trace: List[Request], *, realtime: bool = False,
            max_ticks: int = 100_000, faults=None) -> Dict:
        """Serve ``trace`` to completion; returns results + metrics.

        ``realtime=False`` (benchmark mode) treats arrival times as a
        virtual clock that only moves forward when the engine would
        otherwise idle - arrivals still gate admission ORDER, but the
        engine never sleeps, so throughput comparisons are
        compute-bound. ``realtime=True`` sleeps until the next arrival.

        ``faults`` is an optional :class:`repro.core.faults.FaultSchedule`
        covering the service's ``stage_devices``. A tick whose fault-clock
        time (``cfg.fault_tick_s > 0``: deterministic ``tick *
        fault_tick_s``; else the virtual arrival clock) lands inside an
        assigned device's outage window is a FAILED tick: the engine is
        not dispatched, the service retries with bounded exponential
        backoff (``cfg.max_retries`` / ``cfg.retry_backoff_s``), and if
        the device is still down it evicts every in-flight slot
        (``engine.evict_slots``), requeues those requests at the queue
        head, re-plans around the dead devices
        (``replan(exclude_devices=...)``), and jumps the clock to the
        outage's end. Requests the outage never touched complete with
        bitwise-identical tokens to a fault-free run (rid-keyed sampling;
        pinned by ``tests/test_chaos.py``), and injection adds zero
        engine retraces.
        """
        import jax
        import jax.numpy as jnp

        from repro.core import faults as F
        from repro.serving.engine import evict_slots

        trace = list(trace)
        if self.cfg.deadline_s > 0:
            import dataclasses

            trace = [dataclasses.replace(
                r, deadline=min(r.deadline,
                                r.arrival_time + self.cfg.deadline_s))
                for r in trace]
        queue = RequestQueue(trace)
        sched = SlotScheduler(self.cfg.arrival_slots, self.cfg.prompt_pad)
        clock = F.FaultClock(self.cfg.fault_tick_s)
        if faults is not None:
            # host-side numpy mirrors of faults.device_up /
            # faults.next_recovery: the SAME half-open window arithmetic
            # (pinned against the jnp versions by tests/test_chaos.py)
            # without paying a per-tick XLA dispatch + first-call compile
            # inside the timed service loop
            f_start = np.asarray(faults.outage_start, np.float32)
            f_end = np.asarray(faults.outage_end, np.float32)
            f_stage = np.asarray(self.stage_devices, np.int64)

            def _f_up(t):
                t = np.float32(t)
                return ~(((t >= f_start) & (t < f_end)).any(axis=-1))

            def _f_recovery(t):
                t = np.float32(t)
                cov = (t >= f_start[f_stage]) & (t < f_end[f_stage])
                if not cov.any():
                    return float(t)
                return float(max(t, np.where(cov, f_end[f_stage],
                                             -np.inf).max()))
        admit_t: Dict[int, float] = {}
        arrive_t = {r.rid: r.arrival_time for r in trace}
        completions: List[Completion] = []
        seen_done = set()
        inflight: Dict[int, Request] = {}
        expired: List[Request] = []
        t0 = time.perf_counter()
        free = self.cfg.num_slots
        active_rids: set = set()
        replans = []
        fault_events = retries = evictions = recovery_ticks = 0
        tick = 0
        while tick < max_ticks:
            now = time.perf_counter() - t0
            queue.advance(now)
            expired.extend(queue.drop_expired(now))
            if queue.pending == 0 and not active_rids:
                if queue.exhausted:
                    break
                # idle: jump the virtual clock to the next arrival
                nxt = queue.next_arrival()
                if realtime:
                    time.sleep(max(nxt - now, 0.0))
                else:
                    t0 -= max(nxt - now, 0.0)
                queue.advance(time.perf_counter() - t0)
                expired.extend(queue.drop_expired(time.perf_counter() - t0))
                if queue.pending == 0 and not active_rids:
                    # early wake / all arrivals expired: nothing to do,
                    # skip the engine dispatch instead of burning a
                    # no-op step (the realtime busy-loop fix)
                    tick += 1
                    continue
            if faults is not None:
                now = time.perf_counter() - t0
                t_f = clock.time_of(tick, now)
                up = _f_up(t_f)
                down = [d for d in self.stage_devices if not up[d]]
                if down:
                    fault_events += 1
                    # bounded exponential backoff before giving up
                    t_probe, backoff = t_f, self.cfg.retry_backoff_s
                    recovered = False
                    for _ in range(max(self.cfg.max_retries, 0)):
                        retries += 1
                        t_probe += backoff
                        backoff *= 2.0
                        probe_up = _f_up(t_probe)
                        if all(probe_up[d] for d in self.stage_devices):
                            recovered = True
                            break
                    if not recovered:
                        # give up on this outage: free every in-flight
                        # slot (the pipeline spans all stage devices),
                        # requeue its requests at the head, and route
                        # re-planning around the dead devices
                        victims = sorted(
                            (inflight[r] for r in active_rids if r in inflight),
                            key=lambda r: (r.arrival_time, r.rid))
                        if victims:
                            evictions += len(victims)
                            queue.requeue_front(victims)
                            self.state = evict_slots(
                                self.state, np.asarray(self.state.active))
                            active_rids = set()
                            free = self.cfg.num_slots
                        if self.replanner is not None:
                            occupancy = 0.0
                            replans.append(self.replanner.replan(
                                load=occupancy, exclude_devices=down))
                        t_probe = _f_recovery(t_probe)
                    # stall to the recovery point: charge it to the
                    # clock and advance the fault clock past it
                    stall = max(t_probe - t_f, 0.0)
                    if realtime:
                        time.sleep(stall)
                    else:
                        t0 -= stall
                    skipped = clock.ticks_until(t_f, t_probe)
                    recovery_ticks += skipped
                    tick += skipped
                    continue
            reqs, ap, al, ag, ar, n_arr = sched.pack(queue, free)
            now = time.perf_counter() - t0
            for r in reqs:
                admit_t[r.rid] = now
                inflight[r.rid] = r
            self.state, report = self._jstep(
                self.params, self.state, jnp.asarray(ap), jnp.asarray(al),
                jnp.asarray(ag), jnp.asarray(ar), jnp.int32(n_arr))
            act = np.asarray(report["active"])
            rids = np.asarray(report["req_id"])
            ngen = np.asarray(report["n_gen"])
            now = time.perf_counter() - t0
            active_rids = {int(r) for r, a in zip(rids, act) if a and r >= 0}
            done_slots = [s for s in range(len(rids))
                          if rids[s] >= 0 and not act[s]
                          and int(rids[s]) not in seen_done]
            if done_slots:
                buf = np.asarray(self.state.gen_buf)  # pull only on completions
                for s in done_slots:
                    rid = int(rids[s])
                    seen_done.add(rid)
                    inflight.pop(rid, None)
                    completions.append(Completion(
                        rid=rid, tokens=buf[s, :ngen[s]].copy(),
                        arrival_time=arrive_t[rid],
                        admit_time=admit_t[rid], done_time=now))
            free = int((~act).sum())
            if (self.replanner is not None and self.cfg.replan_every
                    and tick % self.cfg.replan_every == 0):
                occupancy = float(act.sum()) / max(len(act), 1)
                replans.append(self.replanner.replan(load=occupancy))
            tick += 1
        wall = time.perf_counter() - t0
        return self._metrics(completions, wall, tick, replans,
                             expired=expired, fault_events=fault_events,
                             retries=retries, evictions=evictions,
                             recovery_ticks=recovery_ticks)

    def _metrics(self, completions: List[Completion], wall: float,
                 ticks: int, replans, *, expired=(), fault_events: int = 0,
                 retries: int = 0, evictions: int = 0,
                 recovery_ticks: int = 0) -> Dict:
        lats = sorted(c.latency for c in completions)
        total_tokens = int(sum(len(c.tokens) for c in completions))
        busy = float(self.state.busy_steps)
        steps = float(self.state.decode_steps)
        # empty-trace runs report 0.0, not NaN (NaN poisons JSON gates)
        pct = (lambda q: lats[min(int(q * len(lats)), len(lats) - 1)]
               if lats else 0.0)
        return {
            "completions": {c.rid: c.tokens for c in completions},
            "latencies": {c.rid: c.latency for c in completions},
            "num_requests": len(completions),
            "wall_seconds": wall,
            "ticks": ticks,
            "requests_per_sec": len(completions) / wall if wall else 0.0,
            "tokens_per_sec": total_tokens / wall if wall else 0.0,
            "p50_latency_s": pct(0.50),
            "p99_latency_s": pct(0.99),
            # structural accounting (wall-clock independent, as in
            # core.transport): fraction of slot-steps doing useful decode
            "slot_occupancy": busy / (steps * self.cfg.num_slots)
            if steps else 0.0,
            "replans": replans,
            # failure accounting (all zero on fault-free runs)
            "expired": sorted(r.rid for r in expired),
            "fault_events": fault_events,
            "retries": retries,
            "evictions": evictions,
            "recovery_ticks": recovery_ticks,
        }


def poisson_trace(*, n_requests: int, rate_per_sec: float, vocab_size: int,
                  plen_range=(4, 32), gen_range=(4, 24), seed: int = 0
                  ) -> List[Request]:
    """Mixed-length Poisson arrival trace (exponential inter-arrivals)."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for rid in range(n_requests):
        t += float(rng.exponential(1.0 / rate_per_sec))
        pl = int(rng.integers(plen_range[0], plen_range[1] + 1))
        gt = int(rng.integers(gen_range[0], gen_range[1] + 1))
        out.append(Request(
            rid=rid,
            prompt=rng.integers(0, vocab_size, size=pl).astype(np.int32),
            gen_target=gt, arrival_time=t))
    return out
