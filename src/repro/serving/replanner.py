"""Online split re-planning: re-score cut points as load shifts.

Between engine ticks the service's simulated link/budget conditions
drift (load raises effective delay budgets' pressure; batteries drain
energy budgets). The re-planner wraps the env's split oracle
(``MHSLEnv.make_split_oracle`` -> batched ``score_plans`` over the full
boundary enumeration) and re-scores EVERY candidate plan under the
shifted :class:`repro.core.scenario.ScenarioParams` - zero recompiles,
because ``ScenarioParams`` is a runtime pytree (the same property the
scenario-sweep training tests pin).

Re-plans are DECISIONS, not live migrations: the engine keeps serving on
its current plan (moving per-stage KV rings between devices mid-request
is out of scope), and the recorded decisions drive plan switches at
request boundaries / restarts.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np


class OnlineReplanner:
    """Re-scores the split-plan enumeration under shifted conditions.

    ``load`` in [0, 1] (e.g. the engine's slot occupancy) scales the
    per-hop bandwidth down by ``bandwidth_sensitivity * load`` (a busier
    box serves each hop a thinner share) and the energy budget down by
    ``energy_drain`` per replan call (batteries only drain).
    """

    def __init__(self, env, *, scenario=None,
                 bandwidth_sensitivity: float = 0.5,
                 energy_drain: float = 0.0, seed: int = 0):
        self.env = env
        self.oracle = env.make_split_oracle()
        self.base = env._params(scenario)
        self.bandwidth_sensitivity = float(bandwidth_sensitivity)
        self.energy_drain = float(energy_drain)
        self._drained = 0.0
        # a fixed candidate geometry: device ring + uniform powers (the
        # serving box is not moving devices around between ticks)
        import jax

        key = jax.random.PRNGKey(seed)
        state = env.reset(key, self.base)
        self.dev_pos = state.dev_pos
        # first S-1 stages on trainer devices, last on the server (index U)
        self.devices = jnp.asarray(tuple(range(env.S - 1)) + (env.U,),
                                   jnp.int32)
        self.p_tx = jnp.full((env.S - 1,), self.base.power_levels[0])
        self.decoy_power = jnp.zeros((env.S - 1, env.U + 1))

    def shifted_scenario(self, load: float):
        """The scenario the next replan scores under (pure; no state)."""
        bw_scale = max(1.0 - self.bandwidth_sensitivity * float(load), 1e-3)
        return self.base._replace(
            hop_bandwidth_hz=self.base.hop_bandwidth_hz * bw_scale,
            gamma_e=self.base.gamma_e * max(1.0 - self._drained, 1e-3),
        )

    def replan(self, *, load: float, scenario=None) -> Dict:
        """Score all plans under the shifted scenario; pick the feasible
        min-delay plan. Returns a plain-host decision record."""
        sp = scenario if scenario is not None else self.shifted_scenario(load)
        self._drained += self.energy_drain
        out = self.oracle(self.dev_pos, self.devices, self.p_tx,
                          self.decoy_power, sp)
        delay = np.asarray(out["delay"])
        feas = np.asarray(out["feasible"])
        bounds = np.asarray(out["boundaries"])
        masked = np.where(feas, delay, np.inf)
        best = int(np.argmin(masked))
        return {
            "boundaries": tuple(int(b) for b in bounds[best]),
            "delay": float(delay[best]),
            "energy": float(np.asarray(out["energy"])[best]),
            "feasible": bool(feas[best]),
            "any_feasible": bool(feas.any()),
            "load": float(load),
            "num_plans": int(bounds.shape[0]),
        }

    @property
    def trace_count(self):
        """Compiled-trace audit handle (shared with the underlying
        ``make_plan_scorer`` jit cache)."""
        return self.oracle.trace_count
