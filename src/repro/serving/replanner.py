"""Online split re-planning: re-score cut points as load shifts.

Between engine ticks the service's simulated link/budget conditions
drift (load raises effective delay budgets' pressure; batteries drain
energy budgets). The re-planner wraps the env's split oracle
(``MHSLEnv.make_split_oracle`` -> batched ``score_plans`` over the full
boundary enumeration) and re-scores EVERY candidate plan under the
shifted :class:`repro.core.scenario.ScenarioParams` - zero recompiles,
because ``ScenarioParams`` is a runtime pytree (the same property the
scenario-sweep training tests pin).

Failure-aware degradation: ``replan(..., exclude_devices=...)`` marks
every plan whose assignment touches an excluded (dead) device
infeasible via the oracle's ``device_mask`` runtime arg, and scores
alternate device assignments (``candidate_assignments="rotations"``) so
the service can route AROUND the failed device instead of merely
rejecting its plans. Assignment candidates all share the oracle's
shapes, so fault recovery still costs one compiled trace.

Re-plans are DECISIONS, not live migrations: the engine keeps serving on
its current plan (moving per-stage KV rings between devices mid-request
is out of scope), and the recorded decisions drive plan switches at
request boundaries / restarts.
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


class OnlineReplanner:
    """Re-scores the split-plan enumeration under shifted conditions.

    ``load`` in [0, 1] (e.g. the engine's slot occupancy) scales the
    per-hop bandwidth down by ``bandwidth_sensitivity * load`` (a busier
    box serves each hop a thinner share) and the energy budget down by
    ``energy_drain`` per replan call (batteries only drain).

    ``candidate_assignments`` controls the stage -> device assignments
    scored per replan: ``None`` keeps the single canonical ring (first
    S-1 stages on trainers 0..S-2, last on the server) - the static
    serving default; ``"rotations"`` scores all U rotations of the
    trainer ring (server stage fixed), giving the replanner somewhere to
    go when a trainer dies; an explicit sequence of device tuples is
    used as-is.
    """

    def __init__(self, env, *, scenario=None,
                 bandwidth_sensitivity: float = 0.5,
                 energy_drain: float = 0.0, seed: int = 0,
                 candidate_assignments=None):
        self.env = env
        self.oracle = env.make_split_oracle()
        self.base = env._params(scenario)
        self.bandwidth_sensitivity = float(bandwidth_sensitivity)
        self.energy_drain = float(energy_drain)
        self._drained = 0.0
        # a fixed candidate geometry: device ring + uniform powers (the
        # serving box is not moving devices around between ticks)
        import jax

        key = jax.random.PRNGKey(seed)
        state = env.reset(key, self.base)
        self.dev_pos = state.dev_pos
        # first S-1 stages on trainer devices, last on the server (index U)
        canonical = tuple(range(env.S - 1)) + (env.U,)
        if candidate_assignments is None:
            assignments = [canonical]
        elif candidate_assignments == "rotations":
            assignments = [
                tuple((j + i) % env.U for i in range(env.S - 1)) + (env.U,)
                for j in range(env.U)
            ]
        else:
            assignments = [tuple(int(d) for d in a)
                           for a in candidate_assignments]
            if not assignments:
                raise ValueError("candidate_assignments is empty")
            for a in assignments:
                if len(a) != env.S:
                    raise ValueError(
                        f"assignment {a} has {len(a)} stages, env has {env.S}")
        self.assignments: Tuple[Tuple[int, ...], ...] = tuple(assignments)
        self.devices = jnp.asarray(self.assignments[0], jnp.int32)
        self.p_tx = jnp.full((env.S - 1,), self.base.power_levels[0])
        self.decoy_power = jnp.zeros((env.S - 1, env.U + 1))

    def shifted_scenario(self, load: float):
        """The scenario the next replan scores under (pure; no state)."""
        bw_scale = max(1.0 - self.bandwidth_sensitivity * float(load), 1e-3)
        return self.base._replace(
            hop_bandwidth_hz=self.base.hop_bandwidth_hz * bw_scale,
            gamma_e=self.base.gamma_e * max(1.0 - self._drained, 1e-3),
        )

    def _device_mask(self, exclude_devices: Iterable[int]):
        """(U+1,) up-mask with the excluded rows down (None when empty)."""
        excl = sorted({int(d) for d in exclude_devices})
        if not excl:
            return None
        mask = np.ones((self.env.U + 1,), bool)
        for d in excl:
            if not 0 <= d <= self.env.U:
                raise ValueError(
                    f"excluded device {d} not in [0, {self.env.U}]")
            mask[d] = False
        return jnp.asarray(mask)

    def replan(self, *, load: float, scenario=None,
               exclude_devices: Sequence[int] = ()) -> Dict:
        """Score all plans x candidate assignments under the shifted
        scenario; pick the feasible min-delay plan. Assignments whose
        trainer stages touch an excluded device are skipped outright
        (their every plan is infeasible by construction); the oracle's
        ``device_mask`` enforces the same exclusion in-band so the result
        equals fresh scoring over the masked plan set. Returns a
        plain-host decision record."""
        sp = scenario if scenario is not None else self.shifted_scenario(load)
        self._drained += self.energy_drain
        mask = self._device_mask(exclude_devices)
        excl = frozenset(int(d) for d in exclude_devices)
        best: Optional[Dict] = None
        any_feasible = False
        num_plans = 0
        for assign in self.assignments:
            if excl and excl.intersection(assign):
                continue
            devices = jnp.asarray(assign, jnp.int32)
            out = self.oracle(self.dev_pos, devices, self.p_tx,
                              self.decoy_power, sp, device_mask=mask)
            delay = np.asarray(out["delay"])
            feas = np.asarray(out["feasible"])
            bounds = np.asarray(out["boundaries"])
            num_plans += int(bounds.shape[0])
            any_feasible = any_feasible or bool(feas.any())
            masked = np.where(feas, delay, np.inf)
            i = int(np.argmin(masked))
            cand = {
                "boundaries": tuple(int(b) for b in bounds[i]),
                "devices": assign,
                "delay": float(delay[i]),
                "energy": float(np.asarray(out["energy"])[i]),
                "feasible": bool(feas[i]),
                "key": float(masked[i]),
            }
            if best is None or cand["key"] < best["key"]:
                best = cand
        if best is None:  # every assignment intersected the exclusion set
            best = {
                "boundaries": tuple(int(b)
                                    for b in np.asarray(
                                        self.oracle(
                                            self.dev_pos, self.devices,
                                            self.p_tx, self.decoy_power, sp,
                                            device_mask=mask,
                                        )["boundaries"])[0]),
                "devices": self.assignments[0],
                "delay": float("inf"),
                "energy": float("inf"),
                "feasible": False,
                "key": float("inf"),
            }
            num_plans = 0
        best.pop("key", None)
        best.update({
            "any_feasible": any_feasible,
            "load": float(load),
            "num_plans": num_plans,
            "excluded": tuple(sorted(excl)),
        })
        return best

    @property
    def trace_count(self):
        """Compiled-trace audit handle (shared with the underlying
        ``make_plan_scorer`` jit cache)."""
        return self.oracle.trace_count
