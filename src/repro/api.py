"""Single-import facade over the repro stack.

``repro.api`` re-exports the stable entry points of every subsystem so
drivers (examples, benchmarks, notebooks) depend on ONE module instead
of deep submodule paths:

* **RL planning** - :func:`train_sac` (single env),
  :func:`train_population` (vectorized scenario batch),
  :func:`score_plans` / :func:`make_split_oracle` (exhaustive scoring).
* **Execution** - :func:`pipeline_step_fn` (1F1B training executor),
  :class:`ServingService` (continuous-batching inference).
* **Leakage** - :func:`evaluate_leakage` with :class:`AnalyticLeakage`
  (the paper's closed-form Theorem 1 / Eq. 30 model) or
  :class:`EmpiricalLeakage` (the trained FSHA-style attacker's measured
  per-boundary values, :func:`train_empirical_model`).
* **Model stack** - configs, params, train step, data, optimizers,
  checkpointing, used by the quickstart and the pipeline drivers.
* **Fault tolerance** - :class:`FaultSchedule` /
  :func:`sample_fault_schedule` (seeded replayable outages),
  :func:`degrade_scenario` (fold hop degradation into scenario
  physics), consumed by ``ServingService.run(faults=...)`` and the
  kill-and-resume chaos harness (``repro.launch.chaos``).
"""
from __future__ import annotations

from repro.attack import (AttackConfig, capture_weight,
                          train_attacker_population, train_empirical_model)
from repro.checkpoint import load_pytree, save_pytree
from repro.configs import get_config
from repro.core.agents.action_space import flat_dim, onehot
from repro.core.agents.loops import train_sac
from repro.core.agents.sac import SACConfig, select_action
from repro.core.channel import NetworkConfig
from repro.core.env import MHSLEnv
from repro.core.faults import (FaultClock, FaultSchedule, degrade_scenario,
                               fault_free, make_schedule, reference_schedule,
                               sample_fault_schedule)
from repro.core.leakage import (AnalyticLeakage, EmpiricalLeakage,
                                LeakageModel, evaluate_leakage,
                                plan_hop_geometry)
from repro.core.pipeline import (PipelineConfig, make_stage_mesh,
                                 pipeline_step_fn)
from repro.core.profiles import transformer_profile
from repro.core.scenario import (ScenarioParams, evaluate_population,
                                 train_population)
from repro.core.splitting import make_plan_scorer, score_plans
from repro.data import synthetic_stream
from repro.models import init_params, make_train_step
from repro.optim import adamw, linear_warmup_cosine
from repro.serving import ServeConfig, ServingService


def make_split_oracle(env: MHSLEnv):
    """Batched exhaustive split-plan scorer for ``env`` (the serving
    re-planner's oracle): ``oracle(p_tx, decoy, positions) -> scores``
    over every (boundaries x devices) candidate. Facade wrapper over
    :meth:`repro.core.env.MHSLEnv.make_split_oracle`."""
    return env.make_split_oracle()


__all__ = [
    "AnalyticLeakage",
    "AttackConfig",
    "EmpiricalLeakage",
    "FaultClock",
    "FaultSchedule",
    "LeakageModel",
    "MHSLEnv",
    "NetworkConfig",
    "PipelineConfig",
    "SACConfig",
    "ScenarioParams",
    "ServeConfig",
    "ServingService",
    "adamw",
    "capture_weight",
    "degrade_scenario",
    "evaluate_leakage",
    "evaluate_population",
    "fault_free",
    "flat_dim",
    "get_config",
    "init_params",
    "linear_warmup_cosine",
    "load_pytree",
    "make_plan_scorer",
    "make_schedule",
    "make_split_oracle",
    "make_stage_mesh",
    "make_train_step",
    "onehot",
    "pipeline_step_fn",
    "plan_hop_geometry",
    "reference_schedule",
    "sample_fault_schedule",
    "save_pytree",
    "score_plans",
    "select_action",
    "synthetic_stream",
    "train_attacker_population",
    "train_empirical_model",
    "train_population",
    "train_sac",
    "transformer_profile",
]
