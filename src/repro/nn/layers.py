"""Tiny pure-JAX NN library for the RL networks (no flax on this box).

Params are nested dicts of jnp arrays; every layer is an (init, apply)
pair. Used by the ICM-CA SAC agent, PPO, and DQN.
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp


def init_dense(key, d_in: int, d_out: int, scale: float | None = None):
    scale = scale if scale is not None else math.sqrt(2.0 / d_in)
    return {
        "w": jax.random.normal(key, (d_in, d_out)) * scale,
        "b": jnp.zeros((d_out,)),
    }


def dense_apply(p, x):
    return x @ p["w"] + p["b"]


def init_layernorm(d: int):
    return {"g": jnp.ones((d,)), "b": jnp.zeros((d,))}


def layernorm_apply(p, x, eps: float = 1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * p["g"] + p["b"]


def init_mlp(key, dims: Sequence[int]):
    ks = jax.random.split(key, len(dims) - 1)
    return {"layers": [init_dense(k, a, b) for k, a, b in zip(ks, dims[:-1], dims[1:])]}


def mlp_apply(p, x, act=jax.nn.relu, final_act=None):
    n = len(p["layers"])
    for i, lp in enumerate(p["layers"]):
        x = dense_apply(lp, x)
        if i < n - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x


def init_residual_mlp(key, d_in: int, d_hidden: int, n_blocks: int, d_out: int):
    """MLP with residual blocks (paper's ICM feature extractor)."""
    ks = jax.random.split(key, n_blocks * 2 + 2)
    blocks = []
    for i in range(n_blocks):
        blocks.append(
            {
                "fc1": init_dense(ks[2 * i], d_hidden, d_hidden),
                "fc2": init_dense(ks[2 * i + 1], d_hidden, d_hidden),
                "ln": init_layernorm(d_hidden),
            }
        )
    return {
        "inp": init_dense(ks[-2], d_in, d_hidden),
        "blocks": blocks,
        "out": init_dense(ks[-1], d_hidden, d_out),
    }


def residual_mlp_apply(p, x, final_act=None):
    h = jax.nn.relu(dense_apply(p["inp"], x))
    for b in p["blocks"]:
        r = jax.nn.relu(dense_apply(b["fc1"], layernorm_apply(b["ln"], h)))
        h = h + dense_apply(b["fc2"], r)
    out = dense_apply(p["out"], h)
    return final_act(out) if final_act is not None else out


def init_gru(key, d_in: int, d_hidden: int):
    k1, k2, k3 = jax.random.split(key, 3)
    s = math.sqrt(1.0 / d_hidden)
    return {
        "wi": jax.random.normal(k1, (d_in, 3 * d_hidden)) * s,
        "wh": jax.random.normal(k2, (d_hidden, 3 * d_hidden)) * s,
        "b": jnp.zeros((3 * d_hidden,)),
    }


def gru_apply(p, h, x):
    """Standard GRU cell. h: (..., H), x: (..., D) -> new h."""
    gi = x @ p["wi"] + p["b"]
    gh = h @ p["wh"]
    i_r, i_z, i_n = jnp.split(gi, 3, axis=-1)
    h_r, h_z, h_n = jnp.split(gh, 3, axis=-1)
    r = jax.nn.sigmoid(i_r + h_r)
    z = jax.nn.sigmoid(i_z + h_z)
    n = jnp.tanh(i_n + r * h_n)
    return (1 - z) * n + z * h
