from repro.nn.layers import (
    dense_apply,
    gru_apply,
    init_dense,
    init_gru,
    init_layernorm,
    init_mlp,
    init_residual_mlp,
    layernorm_apply,
    mlp_apply,
    residual_mlp_apply,
)

__all__ = [
    "init_dense",
    "dense_apply",
    "init_mlp",
    "mlp_apply",
    "init_layernorm",
    "layernorm_apply",
    "init_gru",
    "gru_apply",
    "init_residual_mlp",
    "residual_mlp_apply",
]
