from repro.distribution.sharding import (
    batch_sharding,
    cache_shardings,
    named,
    param_shardings,
    population_axes,
    population_sharding,
    replicated_sharding,
    spec_for_param,
)

__all__ = [
    "batch_sharding",
    "cache_shardings",
    "named",
    "param_shardings",
    "population_axes",
    "population_sharding",
    "replicated_sharding",
    "spec_for_param",
]
