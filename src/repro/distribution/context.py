"""Activation-sharding context: explicit with_sharding_constraint hints.

GSPMD propagation loses the batch sharding through nested scans (flash-style
attention, SSD chunk scans), silently replicating activations 16x. Model
code calls ``constrain(x, {dim: role})`` at key points; outside a context
(CPU tests) it is a no-op.

Roles: 'batch' -> the ('pod','data') axes, 'model' -> tensor-parallel axis,
'expert' -> alias of 'model' (experts live on the TP axis).
"""
from __future__ import annotations

import math
from contextlib import contextmanager
from typing import Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = {"mesh": None, "batch": None, "model": "model", "kv_seq": False,
          "moe_a2a": False}


@contextmanager
def activation_sharding(mesh: Mesh, batch_axes, model_axis: str = "model",
                        kv_seq_shard: bool = False, moe_a2a: bool = False):
    old = dict(_STATE)
    _STATE.update(mesh=mesh, batch=batch_axes, model=model_axis,
                  kv_seq=kv_seq_shard, moe_a2a=moe_a2a)
    try:
        yield
    finally:
        _STATE.clear()
        _STATE.update(old)


def _axes_size(mesh: Mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if axes is None:
        return 1
    if isinstance(axes, str):
        return sizes.get(axes, 1)
    return math.prod(sizes.get(a, 1) for a in axes)


def constrain(x, roles: Dict[int, str]):
    """Apply a sharding constraint; no-op outside an activation context."""
    mesh = _STATE["mesh"]
    if mesh is None or not hasattr(x, "ndim"):
        return x
    spec = [None] * x.ndim
    for d, role in roles.items():
        if d >= x.ndim:
            continue
        ax = _STATE["batch"] if role == "batch" else _STATE["model"]
        if ax is None:
            continue
        if role != "batch" and (
            not isinstance(ax, str) or ax not in mesh.axis_names
        ):
            continue
        if x.shape[d] % _axes_size(mesh, ax) == 0 and x.shape[d] > 0:
            spec[d] = ax
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def active() -> bool:
    return _STATE["mesh"] is not None


def kv_seq_shard_enabled() -> bool:
    return bool(_STATE.get("kv_seq"))


def moe_a2a_enabled() -> bool:
    return bool(_STATE.get("moe_a2a"))


def model_axis_divides(n: int) -> bool:
    """True when the tensor-parallel axis evenly divides `n` (False when no
    activation-sharding context is installed)."""
    mesh = _STATE["mesh"]
    if mesh is None:
        return False
    return n % _axes_size(mesh, _STATE["model"]) == 0
