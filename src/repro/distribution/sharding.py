"""Sharding rules: logical roles -> PartitionSpecs on the production mesh.

Strategy (MaxText-style FSDP + tensor parallelism):
  * weights: one dim sharded over 'data' (FSDP / ZeRO-3) and one over
    'model' (tensor parallel), chosen per logical role, only when divisible;
  * activations/batch: leading batch dim over ('pod', 'data');
  * KV caches: heads over 'model' when divisible, else cache length over
    'model' (GQA with few KV heads cannot head-shard across 16-way TP).

The 'pod' axis (multi-pod mesh) carries pure data parallelism: weights are
replicated across pods (DCN is too slow for cross-pod FSDP) and gradients
all-reduce over ('pod', 'data').
"""
from __future__ import annotations

import re
from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _maybe(axis: Optional[str], dim: int, mesh: Mesh):
    """Use `axis` for a dim only if the dim is divisible by the axis size."""
    if axis is None:
        return None
    if axis not in mesh.axis_names:
        return None
    if dim % mesh_axis_size(mesh, axis) != 0:
        return None
    return axis


def named(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


def _data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_axes(mesh: Mesh, batch: int):
    """Largest prefix of ('pod','data') whose product divides batch."""
    axes = []
    prod = 1
    for a in _data_axes(mesh):
        prod *= mesh_axis_size(mesh, a)
        if batch % prod == 0:
            axes.append(a)
        else:
            break
    return tuple(axes) if axes else None


def batch_sharding(mesh: Mesh, batch_spec, *, extra_dims: int = 1) -> NamedSharding:
    """Sharding for (B, ...) arrays: B over ('pod','data') when divisible."""
    b = batch_spec if isinstance(batch_spec, int) else batch_spec.shape[0]
    return named(mesh, batch_axes(mesh, b), *([None] * extra_dims))


# ---------------------------------------------------------------------------
# population / env-axis sharding (RL engine)
# ---------------------------------------------------------------------------

ENV_AXIS = "env"


def population_axes(mesh: Mesh, num: int):
    """Mesh axes for a population axis of size ``num``.

    A dedicated ``'env'`` axis (``launch.mesh.make_population_mesh``) wins;
    otherwise the population rides the pure-data-parallel prefix of a
    production mesh (``('pod', 'data')``), largest divisible prefix. Returns
    ``None`` (replicate) when nothing divides ``num``.
    """
    if ENV_AXIS in mesh.axis_names:
        return _maybe(ENV_AXIS, num, mesh)
    return batch_axes(mesh, num)


def population_sharding(mesh: Mesh, num: int, ndim: int) -> NamedSharding:
    """Sharding for a ``(num, ...)`` population-axis array of rank ``ndim``:
    leading axis over the population mesh axes, everything else replicated.
    Indivisible populations fall back to full replication."""
    return named(mesh, population_axes(mesh, num), *([None] * (ndim - 1)))


def replicated_sharding(mesh: Mesh) -> NamedSharding:
    """Fully-replicated placement (agent params shared by every shard)."""
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# stage x env sharding (split executor on a 2-D mesh)
# ---------------------------------------------------------------------------

STAGE_AXIS = "stage"


def stage_sharding(mesh: Mesh, ndim: int, stage_axis: str = STAGE_AXIS) -> NamedSharding:
    """Sharding for ``(S, ...)`` stage-stacked arrays (restacked block
    params, per-stage lengths) on a mesh with a ``stage`` axis: leading dim
    over the stage axis, replicated along every other axis (in particular
    along ``env`` on a 2-D stage x env mesh)."""
    ax = stage_axis if stage_axis in mesh.axis_names else None
    return named(mesh, ax, *([None] * (ndim - 1)))


def microbatch_sharding(mesh: Mesh, ndim: int, env_axis: str = ENV_AXIS) -> NamedSharding:
    """Sharding for ``(M, mb, ...)`` microbatched data on a 2-D
    stage x env mesh: microbatch ROWS over the env axis (data parallelism
    composed with the pipeline), the schedule dim and everything trailing
    replicated. On a stage-only mesh this degrades to full replication."""
    ax = env_axis if env_axis in mesh.axis_names else None
    return named(mesh, None, ax, *([None] * (ndim - 2)))


# ---------------------------------------------------------------------------
# parameter sharding by key path
# ---------------------------------------------------------------------------


def spec_for_param(path: str, shape: Tuple[int, ...], cfg: ModelConfig, mesh: Mesh) -> P:
    """Map a parameter (by key path + shape) to a PartitionSpec.

    Stacked layer-group params have a leading `repeats` dim (never sharded).
    """
    dims = list(shape)
    stacked = "slots/" in path
    off = 1 if stacked and len(dims) >= 2 else 0  # leading repeats dim

    def spec(*entries):
        full = [None] * len(dims)
        for i, ax in enumerate(entries):
            full[off + i] = _maybe(ax, dims[off + i], mesh)
        return P(*full)

    leaf = path.split("/")[-1]
    if leaf in ("embed",):  # (V, D)
        return spec("model", "data")
    if leaf == "lm_head":  # (D, V)
        return spec("data", "model")
    if leaf in ("wq", "wk", "wv"):  # (D, H*hd)
        return spec("data", "model")
    if leaf == "wo":  # (H*hd, D)
        return spec("model", "data")
    if leaf in ("bq", "bk", "bv"):
        return spec("model")
    if leaf in ("w_gate", "w_up"):
        if len(dims) - off == 3:  # MoE (E, D, F)
            return spec("model", "data", None)
        return spec("data", "model")  # (D, F)
    if leaf == "w_down":
        if len(dims) - off == 3:  # MoE (E, F, D)
            return spec("model", None, "data")
        return spec("model", "data")  # (F, D)
    if leaf == "router":  # (D, E)
        return spec("data", None)
    if leaf == "in_proj":  # (D, Din)
        return spec("data", "model")
    if leaf == "out_proj":  # (di, D)
        return spec("model", "data")
    if leaf == "proj":  # frontend (d_in, D)
        return spec("data", "model")
    # norms, biases, conv, scalars: replicated
    return P(*([None] * len(dims)))


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_shardings(params_shape, cfg: ModelConfig, mesh: Mesh, *, mode: str = "train"):
    """Tree of NamedShardings matching a params (or opt-state) shape tree.

    mode='train': FSDP over 'data' + tensor parallel over 'model'.
    mode='serve': weights RESIDENT - the 'data' axis is dropped from weight
    specs (no per-layer FSDP all-gather at decode; weights cost 16x more
    HBM per chip but decode stops being gather-bound).
    """

    def one(path, leaf):
        sp = spec_for_param(_path_str(path), tuple(leaf.shape), cfg, mesh)
        if mode == "serve":
            sp = P(*[None if ax == "data" else ax for ax in (tuple(sp) + (None,) * 0)])
        return NamedSharding(mesh, sp)

    return jax.tree_util.tree_map_with_path(one, params_shape)


# ---------------------------------------------------------------------------
# cache sharding
# ---------------------------------------------------------------------------


def cache_shardings(caches_shape, cfg: ModelConfig, mesh: Mesh, batch: int):
    """KV caches: (repeats, B, len, KH, hd) / SSM: (repeats, B, H, P, N)."""
    baxes = batch_axes(mesh, batch)

    def one(path, leaf):
        p = _path_str(path)
        dims = leaf.shape
        leafname = p.split("/")[-1]
        if leafname in ("k", "v"):  # (repeats, B, L, KH, hd)
            kh_ax = _maybe("model", dims[3], mesh)
            len_ax = _maybe("model", dims[2], mesh) if kh_ax is None else None
            return named(mesh, None, baxes, len_ax, kh_ax, None)
        if leafname == "ssm":  # (repeats, B, H, P, N)
            h_ax = _maybe("model", dims[2], mesh)
            return named(mesh, None, baxes, h_ax, None, None)
        if leafname == "conv":  # (repeats, B, K-1, C)
            c_ax = _maybe("model", dims[3], mesh)
            return named(mesh, None, baxes, None, c_ax)
        return named(mesh, *([None] * len(dims)))

    return jax.tree_util.tree_map_with_path(one, caches_shape)
