"""Mesh-aware placement for the vectorized RL population engine.

PR 1 made rollouts a vmapped ``lax.scan`` population and PR 2 added the
scenario axis on top - but the whole population still lived on ONE device,
so sweep breadth (number of scenarios x envs the paper's Figs. 3-8 need)
was capped by a single accelerator. This module scales that population
axis across a device mesh with data placement only:

* the ``num_envs`` / scenario axis of env states, PRNG key batches, and
  replay buffers is sharded over the mesh's population axes
  (``NamedSharding``; see ``sharding.population_axes``);
* agent parameters and optimizer state stay replicated (``train_sac``) or
  ride the scenario axis (``train_population``, one agent per scenario);
* the compiled functions themselves are UNCHANGED - jit propagates the
  committed input shardings through the vmapped scans (GSPMD), so the
  1-device-mesh path runs the exact same executable as the plain vmap
  path and is bit-identical to it (pinned by
  ``tests/test_population_mesh.py``);
* metrics leave the device through ``jax.device_get``, which all-gathers
  the population shards into one host array.

Per-env computation is embarrassingly parallel along the population axis,
so sharding it adds no collectives to the rollout itself; cross-env
reductions (replay sampling, fused update batches) are handled by GSPMD
where they occur.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh

from repro.distribution.sharding import (
    population_sharding,
    replicated_sharding,
)


def mesh_size(mesh: Mesh) -> int:
    return int(mesh.devices.size)


def population_shardings(tree: Any, mesh: Mesh, num: int) -> Any:
    """NamedSharding tree for a population-axis pytree.

    Leaves with a leading axis of size ``num`` get that axis sharded over
    the mesh's population axes; every other leaf (scalars, ring pointers,
    shared keys) is replicated. The same rule serves env-state chunks
    (``num = num_envs``), replay buffers (``num = capacity``), and stacked
    per-scenario train state (``num = num_scenarios``).
    """

    def one(x):
        shape = jax.numpy.shape(x)
        if len(shape) >= 1 and shape[0] == num:
            return population_sharding(mesh, num, len(shape))
        return replicated_sharding(mesh)

    return jax.tree.map(one, tree)


def shard_population(tree: Any, mesh: Optional[Mesh], num: int) -> Any:
    """``device_put`` a population pytree with its leading axis sharded.

    ``mesh=None`` is the no-mesh fast path (identity) so trainers can
    thread an optional mesh without branching at every call site.
    """
    if mesh is None:
        return tree
    return jax.tree.map(
        jax.device_put, tree, population_shardings(tree, mesh, num)
    )


def replicate(tree: Any, mesh: Optional[Mesh]) -> Any:
    """``device_put`` a pytree fully replicated over the mesh (agent
    params / optimizer state shared by every population shard)."""
    if mesh is None:
        return tree
    sh = replicated_sharding(mesh)
    return jax.tree.map(lambda x: jax.device_put(x, sh), tree)
