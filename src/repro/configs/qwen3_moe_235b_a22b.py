"""Qwen3-MoE 235B-A22B [hf:Qwen/Qwen3-30B-A3B family, scaled card].

94L d_model=4096 64H (GQA kv=4) per-expert d_ff=1536 vocab=151936,
MoE 128 experts top-8.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    arch_type="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=0,  # all blocks are MoE
    vocab_size=151936,
    activation="swiglu",
    rope_theta=1e6,
    moe=MoEConfig(num_experts=128, top_k=8, expert_d_ff=1536),
    source="hf:Qwen/Qwen3-30B-A3B",
)
