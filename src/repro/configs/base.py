"""Config system: dataclasses for model / mesh / run configuration.

Every assigned architecture has a module in this package exporting a
``CONFIG: ModelConfig`` with the exact published dimensions (source cited in
its docstring) plus a ``reduced()`` variant used by CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Optional, Sequence, Tuple

ArchType = str  # 'dense' | 'moe' | 'ssm' | 'hybrid' | 'vlm' | 'audio'


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0          # per-expert FFN hidden size
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-2
    # every `moe_every`-th block is MoE (1 = every block); used by hybrids
    moe_every: int = 1
    # token routing: "dropless" (sort-based grouping, every routed token
    # computed - layers.moe_apply_dropless) or "capacity" (the classic
    # ceil(T*k*cf/E) buffer with token dropping - layers.moe_apply).
    # capacity_factor only matters under "capacity".
    dispatch: str = "dropless"

    @property
    def enabled(self) -> bool:
        return self.num_experts > 0


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 64               # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: ArchType
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads
    activation: str = "swiglu"        # swiglu | relu2 | gelu
    qkv_bias: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # attention variants
    attention_window: Optional[int] = None   # sliding window (tokens); None = full
    # MoE / SSM / hybrid structure
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    # layout string per block for hybrids: 'A'=attention, 'M'=mamba.
    # None -> homogeneous ('A'*L for attention archs, 'M'*L for ssm archs).
    block_pattern: Optional[str] = None
    # modality frontend stub: 'none' | 'vision' | 'audio'
    frontend: str = "none"
    frontend_tokens: int = 0          # prefix embedding tokens provided by stub
    source: str = ""                  # citation

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ---- derived structure -------------------------------------------------
    @property
    def pattern(self) -> str:
        if self.block_pattern is not None:
            assert len(self.block_pattern) == self.num_layers
            return self.block_pattern
        return ("M" if self.arch_type == "ssm" else "A") * self.num_layers

    def is_moe_block(self, i: int) -> bool:
        return self.moe.enabled and (i % max(self.moe.moe_every, 1) == 0)

    @property
    def num_attn_layers(self) -> int:
        return self.pattern.count("A")

    @property
    def num_ssm_layers(self) -> int:
        return self.pattern.count("M")

    # ---- parameter counts --------------------------------------------------
    def attn_params(self) -> int:
        d, h, kh, hd = self.d_model, self.num_heads, self.num_kv_heads, self.head_dim
        p = d * h * hd + 2 * d * kh * hd + h * hd * d
        if self.qkv_bias:
            p += (h + 2 * kh) * hd
        return p

    def mlp_params(self, moe_block: bool) -> int:
        d = self.d_model
        if moe_block and self.moe.enabled:
            ff = self.moe.expert_d_ff
            per = (3 if self.activation == "swiglu" else 2) * d * ff
            return self.moe.num_experts * per + d * self.moe.num_experts  # + router
        ff = self.d_ff
        return (3 if self.activation == "swiglu" else 2) * d * ff

    def ssm_params(self) -> int:
        d = self.d_model
        di = self.ssm.d_inner(d)
        nh = self.ssm.num_heads(d)
        # in_proj (z,x,B,C,dt) + conv + A,D + norm + out_proj (Mamba-2 layout)
        in_proj = d * (2 * di + 2 * self.ssm.d_state + nh)
        conv = self.ssm.d_conv * (di + 2 * self.ssm.d_state)
        return in_proj + conv + 2 * nh + di + di * d

    def block_params(self, i: int) -> int:
        kind = self.pattern[i]
        p = 2 * self.d_model  # two RMSNorms
        if kind == "A":
            p += self.attn_params() + self.mlp_params(self.is_moe_block(i))
        else:
            p += self.ssm_params() + (
                self.mlp_params(self.is_moe_block(i)) if self.arch_type == "hybrid" else 0
            )
        return p

    def active_block_params(self, i: int) -> int:
        """Params touched per token (MoE counts only top-k experts + router)."""
        p = self.block_params(i)
        if self.is_moe_block(i) and (self.pattern[i] == "A" or self.arch_type == "hybrid"):
            ff = self.moe.expert_d_ff
            per = (3 if self.activation == "swiglu" else 2) * self.d_model * ff
            p -= (self.moe.num_experts - self.moe.top_k) * per
        return p

    def embed_params(self) -> int:
        p = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            p += self.vocab_size * self.d_model
        return p + self.d_model  # final norm

    def param_count(self) -> int:
        return self.embed_params() + sum(self.block_params(i) for i in range(self.num_layers))

    def active_param_count(self) -> int:
        return self.embed_params() + sum(
            self.active_block_params(i) for i in range(self.num_layers)
        )

    # ---- reductions ----------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests (2 layers, d<=512)."""
        d = min(self.d_model, 256)
        nh = min(self.num_heads, 4) or 0
        nkv = min(self.num_kv_heads, max(1, nh // 2)) if self.num_kv_heads else 0
        moe = self.moe
        if moe.enabled:
            moe = replace(moe, num_experts=4, top_k=min(moe.top_k, 2), expert_d_ff=128)
        ssm = replace(self.ssm, d_state=16, head_dim=32)
        pattern = None
        if self.block_pattern is not None:
            pattern = (self.block_pattern[: self.num_layers])
            # keep one attention and one mamba block
            pattern = "AM"
        return replace(
            self,
            name=self.name + "-smoke",
            num_layers=2,
            d_model=d,
            num_heads=nh,
            num_kv_heads=nkv,
            head_dim=64 if nh else 0,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=512,
            moe=moe,
            ssm=ssm,
            block_pattern=pattern,
            attention_window=None if self.attention_window is None else 64,
            frontend_tokens=8 if self.frontend != "none" else 0,
        )

    def with_window(self, window: int) -> "ModelConfig":
        return replace(self, name=self.name + f"-sw{window}", attention_window=window)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)


def get_shape(name: str) -> ShapeConfig:
    for s in INPUT_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown input shape {name!r}; have {[s.name for s in INPUT_SHAPES]}")


ARCH_IDS: Tuple[str, ...] = (
    "qwen3-moe-235b-a22b",
    "nemotron-4-340b",
    "qwen2.5-3b",
    "jamba-v0.1-52b",
    "minitron-4b",
    "pixtral-12b",
    "musicgen-large",
    "mamba2-370m",
    "stablelm-1.6b",
    "qwen3-moe-30b-a3b",
)

_MODULES = {
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "nemotron-4-340b": "nemotron_4_340b",
    "qwen2.5-3b": "qwen2_5_3b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "minitron-4b": "minitron_4b",
    "pixtral-12b": "pixtral_12b",
    "musicgen-large": "musicgen_large",
    "mamba2-370m": "mamba2_370m",
    "stablelm-1.6b": "stablelm_1_6b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {list(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
