"""Mamba2-370m (SSD, state-space duality) [arXiv:2405.21060].

48L d_model=1024, attention-free, ssm_state=128, vocab=50280.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    arch_type="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    activation="swiglu",
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=64),
    source="arXiv:2405.21060",
)
