"""Pixtral-12B decoder backbone [hf:mistralai/Pixtral-12B-2409].

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072. The Pixtral-ViT
vision encoder + projector is a STUB per the assignment: ``input_specs``
provides precomputed patch embeddings (frontend='vision').
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    arch_type="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    activation="swiglu",
    rope_theta=1e9,
    frontend="vision",
    frontend_tokens=256,  # one 16x16-patch image tile worth of embeddings
    source="hf:mistralai/Pixtral-12B-2409",
)
