"""Qwen2.5-3B [hf:Qwen/Qwen2.5-3B family card].

36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936, QKV bias.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    arch_type="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    head_dim=128,
    d_ff=11008,
    vocab_size=151936,
    activation="swiglu",
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
    source="hf:Qwen/Qwen2.5-0.5B",
)
