"""StableLM-2 1.6B [hf:stabilityai/stablelm-2-1_6b].

24L d_model=2048 32H (MHA kv=32) d_ff=5632 vocab=100352.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    arch_type="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab_size=100352,
    activation="swiglu",
    rope_theta=1e4,
    source="hf:stabilityai/stablelm-2-1_6b",
)
