"""Qwen3-MoE 30B-A3B [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048 32H (GQA kv=4) per-expert d_ff=768 vocab=151936,
MoE 128 experts top-8.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    arch_type="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=0,
    vocab_size=151936,
    activation="swiglu",
    rope_theta=1e6,
    moe=MoEConfig(num_experts=128, top_k=8, expert_d_ff=768),
    source="hf:Qwen/Qwen3-30B-A3B",
)
