"""Jamba v0.1 52B [arXiv:2403.19887].

32L d_model=4096, attention:mamba 1:7 interleave (attention at index 4 of
every 8-block period), 32H (GQA kv=8) d_ff=14336, MoE 16 experts top-2 on
every other block, vocab=65536.
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

# period-8 pattern, attention in slot 4 (as in the Jamba paper), x4 periods
_PATTERN = ("MMMMAMMM" * 4)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    activation="swiglu",
    block_pattern=_PATTERN,
    moe=MoEConfig(num_experts=16, top_k=2, expert_d_ff=14336, moe_every=2),
    ssm=SSMConfig(d_state=16, head_dim=64, expand=2),
    source="arXiv:2403.19887",
)
