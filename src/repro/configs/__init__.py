from repro.configs.base import (
    ARCH_IDS,
    INPUT_SHAPES,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    ShapeConfig,
    all_configs,
    get_config,
    get_shape,
)

__all__ = [
    "ARCH_IDS",
    "INPUT_SHAPES",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "ShapeConfig",
    "all_configs",
    "get_config",
    "get_shape",
]
