"""Nemotron-4 340B [arXiv:2402.16819 / 2406.11704].

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000, squared-ReLU MLP.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    arch_type="dense",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    activation="relu2",
    rope_theta=1e4,
    source="arXiv:2402.16819",
)
