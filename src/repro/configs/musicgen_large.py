"""MusicGen-large decoder over EnCodec tokens [arXiv:2306.05284].

48L d_model=2048 32H (MHA kv=32) d_ff=8192 vocab=2048 (EnCodec codebook).
The mel/EnCodec conv frontend is a STUB: ``input_specs`` provides frame
embeddings (frontend='audio'). GELU MLP, full attention.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    arch_type="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    activation="gelu",
    rope_theta=1e4,
    frontend="audio",
    frontend_tokens=64,  # conditioning frames from the (stub) codec encoder
    source="arXiv:2306.05284",
)
