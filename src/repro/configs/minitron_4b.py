"""Minitron-4B (pruned Nemotron-4) [arXiv:2407.14679].

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000, squared-ReLU.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    arch_type="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
    activation="relu2",
    rope_theta=1e4,
    source="arXiv:2407.14679",
)
