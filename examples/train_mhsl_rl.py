"""End-to-end MHSL driver (the paper's full loop):

1. train the ICM-CA SAC controller on the wireless MHSL environment for a
   chosen architecture's layer profile;
2. roll out the learned policy -> a split plan (boundaries + devices);
3. EXECUTE that plan as real pipeline-parallel training of the (reduced)
   model over multiple JAX devices, multi-hop activations via ppermute.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python examples/train_mhsl_rl.py --arch qwen2.5-3b
"""
import argparse
import os

if "--xla-devices" in os.sys.argv or True:
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp
import numpy as np
from dataclasses import replace

from repro.api import (MHSLEnv, NetworkConfig, PipelineConfig, SACConfig,
                       adamw, flat_dim, get_config, init_params,
                       make_stage_mesh, onehot, pipeline_step_fn,
                       select_action, train_sac, transformer_profile)
from repro.optim.optimizers import apply_updates


def rollout_plan(env, params, cfg, seed=7):
    key = jax.random.PRNGKey(seed)
    st = env.reset(jax.random.PRNGKey(0))
    pair_dim = env.obs_dim + flat_dim(env.action_dims)
    hist = jnp.zeros((cfg.hist_len, pair_dim))
    hmask = jnp.zeros((cfg.hist_len,))
    leaked = 0.0
    for t in range(env.episode_len):
        key, ka, ks = jax.random.split(key, 3)
        obs = env.observe(st)
        masks = env.action_masks(st)
        a = select_action(params, ka, obs, hist, hmask, masks, env.action_dims, cfg)
        pair = jnp.concatenate([obs, onehot(a, env.action_dims)])
        hist = jnp.roll(hist, -1, axis=0).at[-1].set(pair)
        hmask = jnp.roll(hmask, -1).at[-1].set(1.0)
        st, r, done, info = env.step(st, a, ks)
        leaked += float(info["leak"])
    return (
        tuple(int(b) for b in np.asarray(st.boundaries)),
        tuple(int(d) for d in np.asarray(st.stage_dev)),
        leaked,
        float(st.t_r),
        float(st.e_r),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--episodes", type=int, default=60)
    ap.add_argument("--pipeline-steps", type=int, default=20)
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--num-envs", type=int, default=4,
                    help="vmapped env population per rollout chunk")
    ap.add_argument("--shard-envs", action="store_true",
                    help="shard the num-envs axis over a population mesh "
                         "spanning every host device")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="save/resume RL training state under this directory")
    ap.add_argument("--checkpoint-every", type=int, default=20,
                    help="episodes between checkpoints (with --checkpoint-dir)")
    ap.add_argument("--fresh", action="store_true",
                    help="ignore an existing checkpoint and train from scratch")
    args = ap.parse_args()

    model_cfg_full = get_config(args.arch)
    # 1) RL controller on the FULL architecture's layer profile
    prof = transformer_profile(model_cfg_full, batch=1, seq=128)
    env = MHSLEnv(profile=prof, net=NetworkConfig(max_split=args.stages))
    sac_cfg = SACConfig()
    mesh = None
    if args.shard_envs:
        from repro.launch.mesh import make_population_mesh

        mesh = make_population_mesh()
        print(f"      population mesh: {len(jax.devices())} devices, "
              f"num_envs axis sharded")
    print(f"[1/3] training ICM-CA SAC on {args.arch} profile "
          f"({prof.num_layers} layers, {args.episodes} episodes, "
          f"{args.num_envs} vmapped envs)...")
    res = train_sac(env, sac_cfg, episodes=args.episodes, warmup_episodes=10,
                    num_envs=args.num_envs, mesh=mesh,
                    checkpoint_dir=args.checkpoint_dir,
                    checkpoint_every=args.checkpoint_every,
                    resume=not args.fresh)
    print(f"      reward: first10={np.mean(res.episode_reward[:10]):.2f} "
          f"last10={np.mean(res.episode_reward[-10:]):.2f}")

    boundaries_full, devices, leaked, t_r, e_r = rollout_plan(env, res.params, sac_cfg)
    print(f"[2/3] learned plan on {prof.num_layers} layers: boundaries={boundaries_full} "
          f"devices={devices} leaked={leaked:.3f} T_R={t_r:.2f}s E_R={e_r:.1f}J")

    # 3) execute the plan (rescaled to the reduced model depth) as a real
    # pipeline across `stages` JAX devices
    n_dev = len(jax.devices())
    stages = min(args.stages, n_dev)
    depth = 8
    cfg = replace(get_config(args.arch).reduced(), num_layers=depth)
    # rescale the learned stage-length fractions to the reduced depth
    lens_full = np.diff(np.concatenate([[0], np.asarray(boundaries_full)]))
    lens = np.maximum(1, np.round(lens_full / lens_full.sum() * depth).astype(int))
    lens = lens[:stages]
    while lens.sum() > depth:
        lens[np.argmax(lens)] -= 1
    while lens.sum() < depth:
        lens[np.argmin(lens)] += 1
    boundaries = tuple(int(b) for b in np.cumsum(lens))
    print(f"[3/3] executing plan {boundaries} as a {stages}-stage pipeline "
          f"on {n_dev} devices")

    params = init_params(jax.random.PRNGKey(0), cfg)
    mesh = make_stage_mesh(stages)
    # the 1F1B executor: interleaved schedule, masked uneven stages
    step_fn = pipeline_step_fn(cfg, mesh, boundaries=boundaries,
                               n_microbatches=2, pipe=PipelineConfig())
    opt = adamw(3e-4, max_grad_norm=1.0)
    opt_state = opt.init(params)

    @jax.jit
    def train_step(params, opt_state, tokens, labels):
        loss, grads = step_fn(params, tokens, labels)
        ups, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, ups), opt_state, loss

    rng = np.random.default_rng(0)
    for step in range(args.pipeline_steps):
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 64)), jnp.int32)
        labs = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 64)), jnp.int32)
        params, opt_state, loss = train_step(params, opt_state, toks, labs)
        if step % 5 == 0 or step == args.pipeline_steps - 1:
            print(f"      pipeline step {step:3d} loss {float(loss):.4f}")
    print("done: RL-planned multi-hop split training executed as a real pipeline.")


if __name__ == "__main__":
    main()
