"""Quickstart: train a ~100M-param LM for a few hundred steps on CPU.

    PYTHONPATH=src python examples/quickstart.py [--arch stablelm-1.6b]
                                                 [--steps 300] [--d-model 512]

Uses the public API only: config -> reduced-but-real model -> synthetic
data pipeline -> AdamW train loop -> checkpoint save/restore.
"""
import argparse
import time
from dataclasses import replace

import jax
import jax.numpy as jnp

from repro.api import (adamw, get_config, init_params, linear_warmup_cosine,
                       load_pytree, make_train_step, save_pytree,
                       synthetic_stream)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/quickstart_ckpt.npz")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    cfg = replace(cfg, num_layers=args.layers, d_model=args.d_model,
                  num_heads=max(cfg.num_heads, 4) or 4,
                  num_kv_heads=max(cfg.num_kv_heads, 2) or 2,
                  head_dim=64, vocab_size=2048, name=f"{args.arch}-quickstart")
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name}  params={n_params/1e6:.1f}M  layers={cfg.num_layers}")

    opt = adamw(linear_warmup_cosine(3e-4, warmup=20, total_steps=args.steps),
                weight_decay=0.01, max_grad_norm=1.0)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt, remat=False))

    stream = synthetic_stream(cfg, args.batch, args.seq)
    t0 = time.time()
    for step in range(args.steps):
        batch = next(stream)
        params, opt_state, m = step_fn(params, opt_state, batch)
        if step % 25 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(m['loss']):.4f}  "
                  f"({(time.time()-t0):.1f}s)")
    save_pytree(params, args.ckpt)
    restored = load_pytree(args.ckpt, jax.eval_shape(lambda: params))
    assert all(
        bool(jnp.allclose(jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32)))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored))
    )
    print(f"checkpoint round-trip ok -> {args.ckpt}")


if __name__ == "__main__":
    main()
