"""Batched serving example: ONE fused generate dispatch.

    PYTHONPATH=src python examples/serve.py --arch qwen2_5_3b \
        --batch 4 --prompt-len 64 --gen 32

Thin wrapper over :func:`repro.serving.batching.generate_static` - the
shared static-generate core (padded batched prefill + a single jitted
``lax.scan`` over the decode steps, so the whole generation is one
device dispatch instead of the v0 per-token host loop). The continuous
service with request arrivals lives in ``repro.launch.serve``.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import init_params
from repro.serving import SingleDeviceRunner, generate_static
from repro.serving.config import ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_5_3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = ServeConfig(arch=args.arch.replace("-", "_").replace(".", "_"))
    model_cfg = cfg.model_config()
    params = init_params(jax.random.PRNGKey(0), model_cfg)
    runner = SingleDeviceRunner(model_cfg)

    rng = np.random.default_rng(0)
    prompts = rng.integers(
        0, model_cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)
    plens = np.full((args.batch,), args.prompt_len, np.int32)
    gens = np.full((args.batch,), args.gen, np.int32)

    t0 = time.time()
    toks, n_gen = generate_static(
        runner, params, prompts, plens, gens, max_new=args.gen,
        temperature=args.temperature)
    jax.block_until_ready(toks)
    dt = time.time() - t0
    total = int(np.asarray(n_gen).sum())
    print(f"generate: {args.batch}x{args.prompt_len}+{args.gen} in "
          f"{dt*1e3:.1f} ms ({total/max(dt,1e-9):.0f} tok/s incl. compile, "
          "one dispatch)")
    gen = np.asarray(toks)
    print("sample generations (token ids):")
    for b in range(min(args.batch, 2)):
        print(f"  [{b}] {gen[b][:16].tolist()} ...")


if __name__ == "__main__":
    main()
