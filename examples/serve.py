"""Batched serving example: prefill + decode with KV caches.

    PYTHONPATH=src python examples/serve.py --arch qwen3-moe-30b-a3b \
        --batch 4 --prompt-len 64 --gen 32

Runs the reduced variant of the chosen architecture on CPU: prefill the
prompt batch, then greedy-decode new tokens one at a time through the
cached serve path (ring-buffer cache if the arch has a sliding window).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import (
    init_caches,
    init_params,
    make_decode_step,
    make_prefill_step,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-30b-a3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    cache_len = args.prompt_len + args.gen
    caches = init_caches(cfg, args.batch, cache_len)

    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )
    t0 = time.time()
    logits, caches = prefill(params, prompts, caches)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    print(f"prefill: {args.batch}x{args.prompt_len} tokens in {t_prefill*1e3:.1f} ms")

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    outs = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.asarray(args.prompt_len + i, jnp.int32)
        logits, caches = decode(params, tok, caches, pos)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        outs.append(tok)
    jax.block_until_ready(outs[-1])
    t_dec = time.time() - t0
    gen = np.concatenate([np.asarray(o) for o in outs], axis=1)
    print(f"decode: {args.gen-1} steps x batch {args.batch} in {t_dec*1e3:.1f} ms "
          f"({(args.gen-1)*args.batch/max(t_dec,1e-9):.0f} tok/s on CPU)")
    print("sample generations (token ids):")
    for b in range(min(args.batch, 2)):
        print(f"  [{b}] {gen[b][:16].tolist()} ...")


if __name__ == "__main__":
    main()
