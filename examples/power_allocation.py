"""Optimal transmit powers (Theorem 1 + Corollaries 1-2) walkthrough.

    PYTHONPATH=src python examples/power_allocation.py

Shows how expected leakage E[I] (Eq. 30) moves with trainer/decoy power,
and that the closed-form powers hit the constrained optimum.
"""
import jax.numpy as jnp
import numpy as np

from repro.core.channel import NetworkConfig, data_rate, tx_time
from repro.core.leakage import (
    capture_probability,
    expected_leakage,
    optimal_powers_single_decoy,
    optimal_powers_single_eave,
)


def main():
    net = NetworkConfig()
    d_tx_rx = jnp.asarray(150.0)  # trainer -> receiver
    d_tx_d = jnp.asarray(180.0)  # decoy interference at the receiver
    dist_e = jnp.asarray([250.0])  # trainer -> eavesdropper
    dd_e = jnp.asarray([[90.0]])  # decoy -> eavesdropper (close!)
    q = jnp.asarray([net.monitor_prob])
    bits = jnp.asarray(2e6)
    b_t, b_e = jnp.asarray(1.5), jnp.asarray(3.0)

    print("E[leak] vs trainer power (decoy fixed 0.5 W):")
    for ps in [0.05, 0.2, 0.5, 1.0, 1.5]:
        leak = float(expected_leakage(jnp.asarray(ps), dist_e, jnp.asarray([0.5]),
                                      dd_e, q, jnp.asarray(1.0)))
        rate = float(data_rate(jnp.asarray(ps), d_tx_rx, jnp.asarray([0.5]),
                               jnp.asarray([d_tx_d]), net))
        print(f"  p_s={ps:4.2f} W  E[I]={leak:.4f}  hop_time={float(tx_time(bits, rate)):6.2f} s")

    print("\nE[leak] vs decoy power (trainer fixed 0.5 W):")
    for pd in [0.0, 0.1, 0.5, 1.0, 2.0]:
        leak = float(expected_leakage(jnp.asarray(0.5), dist_e, jnp.asarray([pd]),
                                      dd_e, q, jnp.asarray(1.0)))
        print(f"  p_d={pd:4.2f} W  E[I]={leak:.4f}")

    p_s, p_d = optimal_powers_single_decoy(bits, d_tx_rx, d_tx_d, b_t, b_e, net)
    leak = float(expected_leakage(p_s, dist_e, jnp.asarray([p_d]), dd_e, q, jnp.asarray(1.0)))
    rate = data_rate(p_s, d_tx_rx, jnp.asarray([p_d]), jnp.asarray([d_tx_d]), net)
    print(f"\nCorollary 1 (|D|=1): p_s*={float(p_s):.3f} W  p_d*={float(p_d):.3f} W")
    print(f"  E[I]={leak:.4f}, hop_time={float(tx_time(bits, rate)):.3f} s (= B_T), "
          f"energy={(float(p_s)+float(p_d))*float(b_t):.3f} J (= B_E)")

    dd_many = jnp.asarray([100.0, 250.0, 400.0])
    p_s2, p_d2 = optimal_powers_single_eave(bits, d_tx_rx, dd_many, b_t, b_e, net)
    print(f"\nCorollary 2 (|E|=1, 3 decoys): p_s*={float(p_s2):.3f} W")
    for i, pd in enumerate(np.asarray(p_d2)):
        print(f"  decoy {i}: d_e={float(dd_many[i]):.0f} m  p_d*={pd:.3f} W "
              f"(received at eave: {pd/float(dd_many[i])**2:.2e})")
    print("  -> received decoy powers are water-levelled at the eavesdropper.")


if __name__ == "__main__":
    main()
