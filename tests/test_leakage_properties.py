"""Property-based tests (hypothesis) for the paper's analytical results:
Theorem 1 monotonicity, capture probability bounds, Corollary 1/2 optimality
vs grid search, and Monte-Carlo agreement with the closed form."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip, unit tests still run
    from _hypothesis_compat import given, settings, st

from repro.core.channel import NetworkConfig, data_rate, tx_time
from repro.core.leakage import (
    capture_probability,
    expected_leakage,
    optimal_powers_single_decoy,
    optimal_powers_single_eave,
    sample_leakage,
)

NET = NetworkConfig()

pos = st.floats(min_value=10.0, max_value=800.0)
pw = st.floats(min_value=0.01, max_value=2.0)


@given(p_tx=pw, d1=pos, d2=pos, pd=pw, dd=pos)
@settings(max_examples=50, deadline=None)
def test_capture_probability_in_unit_interval(p_tx, d1, d2, pd, dd):
    cap = capture_probability(
        jnp.asarray(p_tx),
        jnp.asarray([d1, d2]),
        jnp.asarray([pd, 0.0]),
        jnp.asarray([[dd, dd], [dd, dd]]),
    )
    c = np.asarray(cap)
    assert np.all(c >= 0) and np.all(c <= 1)


@given(p_lo=pw, p_hi=pw, d=pos, pd=pw, dd=pos)
@settings(max_examples=50, deadline=None)
def test_leakage_monotone_in_trainer_power(p_lo, p_hi, d, pd, dd):
    """Theorem 1: E[I] increases with p_s (more capture probability)."""
    lo, hi = sorted([p_lo, p_hi])
    args = (
        jnp.asarray([d]),
        jnp.asarray([pd]),
        jnp.asarray([[dd]]),
        jnp.asarray([0.8]),
        jnp.asarray(1.0),
    )
    l_lo = float(expected_leakage(jnp.asarray(lo), *args))
    l_hi = float(expected_leakage(jnp.asarray(hi), *args))
    assert l_hi >= l_lo - 1e-9


@given(p=pw, d=pos, pd_lo=pw, pd_hi=pw, dd=pos)
@settings(max_examples=50, deadline=None)
def test_leakage_monotone_decreasing_in_decoy_power(p, d, pd_lo, pd_hi, dd):
    """Theorem 1: E[I] decreases as decoy power grows."""
    lo, hi = sorted([pd_lo, pd_hi])
    def leak(pd):
        return float(
            expected_leakage(
                jnp.asarray(p),
                jnp.asarray([d]),
                jnp.asarray([pd]),
                jnp.asarray([[dd]]),
                jnp.asarray([0.8]),
                jnp.asarray(1.0),
            )
        )
    assert leak(hi) <= leak(lo) + 1e-9


def test_zero_power_edge_cases():
    """p_s = 0 -> no leakage; huge decoy power -> leakage -> 0 (paper §IV)."""
    dist_e = jnp.asarray([100.0])
    dd = jnp.asarray([[120.0]])
    q = jnp.asarray([0.8])
    l0 = float(expected_leakage(jnp.asarray(0.0), dist_e, jnp.asarray([0.5]), dd, q, jnp.asarray(1.0)))
    assert l0 == pytest.approx(0.0, abs=1e-6)
    lbig = float(
        expected_leakage(jnp.asarray(0.5), dist_e, jnp.asarray([1e9]), dd, q, jnp.asarray(1.0))
    )
    assert lbig < 1e-5


def test_monte_carlo_matches_theorem1():
    """Sampled capture frequency ~= closed-form capture probability."""
    p_tx = jnp.asarray(0.5)
    dist_e = jnp.asarray([150.0])
    decoy_p = jnp.asarray([0.3, 0.0])
    dd = jnp.asarray([[200.0], [999.0]])
    q = jnp.asarray([1.0])
    delta = jnp.asarray(1.0)
    want = float(capture_probability(p_tx, dist_e, decoy_p, dd)[0])
    keys = jax.random.split(jax.random.PRNGKey(0), 3000)
    draws = jax.vmap(
        lambda k: sample_leakage(k, p_tx, dist_e, decoy_p, dd, q, delta)
    )(keys)
    got = float(jnp.mean(draws))
    assert abs(got - want) < 0.04, (got, want)


def _cor1_setting():
    bits = jnp.asarray(2e6)
    d_tx_rx = jnp.asarray(150.0)
    d_tx_d = jnp.asarray(200.0)
    b_t = jnp.asarray(1.5)
    b_e = jnp.asarray(3.0)
    return bits, d_tx_rx, d_tx_d, b_t, b_e


def test_corollary1_satisfies_constraints():
    bits, d_tx_rx, d_tx_d, b_t, b_e = _cor1_setting()
    p_s, p_d = optimal_powers_single_decoy(bits, d_tx_rx, d_tx_d, b_t, b_e, NET)
    assert float(p_s) > 0 and float(p_d) > 0
    # energy tight: (p_s + p_d) * B_T == B_E
    assert float((p_s + p_d) * b_t) == pytest.approx(float(b_e), rel=1e-5)
    # rate constraint met: transmission of `bits` finishes within B_T
    rate = data_rate(p_s, d_tx_rx, jnp.asarray([p_d]), jnp.asarray([d_tx_d]), NET)
    assert float(tx_time(bits, rate)) <= float(b_t) * (1 + 1e-4)


def test_corollary1_beats_grid_search():
    """No feasible (p_s, p_d) grid point leaks less than the closed form."""
    bits, d_tx_rx, d_tx_d, b_t, b_e = _cor1_setting()
    p_s, p_d = optimal_powers_single_decoy(bits, d_tx_rx, d_tx_d, b_t, b_e, NET)
    dist_e = jnp.asarray([220.0])
    dd_e = jnp.asarray([[90.0]])
    q = jnp.asarray([0.8])

    def leak(ps, pd):
        return float(
            expected_leakage(jnp.asarray(ps), dist_e, jnp.asarray([pd]), dd_e, q,
                             jnp.asarray(1.0))
        )

    best = leak(float(p_s), float(p_d))
    grid = np.linspace(0.01, float(b_e / b_t), 40)
    for ps in grid:
        for pd in grid:
            if (ps + pd) * float(b_t) > float(b_e) + 1e-9:
                continue
            rate = data_rate(
                jnp.asarray(ps), d_tx_rx, jnp.asarray([pd]), jnp.asarray([d_tx_d]), NET
            )
            if float(tx_time(bits, rate)) > float(b_t):
                continue
            assert leak(ps, pd) >= best - 5e-3, (ps, pd)


def test_corollary2_structure():
    """|E|=1: p_s depends only on the rate constraint; decoys water-level."""
    bits = jnp.asarray(2e6)
    d_tx_rx = jnp.asarray(150.0)
    b_t, b_e = jnp.asarray(1.5), jnp.asarray(3.0)
    dd_e = jnp.asarray([100.0, 300.0])
    p_s, p_d = optimal_powers_single_eave(bits, d_tx_rx, dd_e, b_t, b_e, NET)
    # rate exactly satisfied ignoring decoy interference
    rate = data_rate(p_s, d_tx_rx, jnp.zeros(1), jnp.ones(1), NET)
    assert float(tx_time(bits, rate)) == pytest.approx(float(b_t), rel=1e-4)
    # energy tight
    assert float(p_s + p_d.sum()) == pytest.approx(float(b_e / b_t), rel=1e-5)
    # equalized received decoy power at the eavesdropper: p_d * d^-2 equal
    recv = np.asarray(p_d) / np.asarray(dd_e) ** 2
    assert recv[0] == pytest.approx(recv[1], rel=1e-4)
