"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret=True executes kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import flash_attention, ssd_scan
from repro.kernels.ref import flash_attention_ref, ssd_scan_ref

FLASH_SHAPES = [
    # (B, S, H, KH, hd, window, q_blk, kv_blk)
    (1, 128, 2, 2, 32, None, 64, 64),
    (2, 256, 4, 2, 64, None, 128, 128),
    (1, 200, 4, 1, 32, None, 64, 64),  # ragged seq, MQA
    (2, 256, 8, 2, 64, 64, 64, 64),  # sliding window
    (1, 512, 2, 2, 16, 128, 128, 64),  # window, uneven blocks
]


@pytest.mark.parametrize("shape", FLASH_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(shape, dtype):
    b, s, h, kh, hd, win, qb, kb = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, kh, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, kh, hd), dtype)
    out = flash_attention(q, k, v, window=win, q_blk=qb, kv_blk=kb, interpret=True)
    ref = flash_attention_ref(q, k, v, window=win)
    atol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=atol
    )


def test_flash_attention_q_offset():
    """Chunked decode-style usage: query block at an offset into the kv."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    skv, sq, off = 128, 32, 96
    q = jax.random.normal(ks[0], (1, sq, 2, 32))
    k = jax.random.normal(ks[1], (1, skv, 2, 32))
    v = jax.random.normal(ks[2], (1, skv, 2, 32))
    out = flash_attention(q, k, v, q_offset=off, q_blk=32, kv_blk=32, interpret=True)
    ref = flash_attention_ref(q, k, v, q_offset=off)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


SSD_SHAPES = [
    # (B, S, H, P, N, chunk)
    (1, 64, 2, 16, 8, 16),
    (2, 128, 4, 32, 16, 32),
    (1, 96, 2, 64, 128, 64),
    (1, 80, 1, 8, 4, 32),  # ragged
]


@pytest.mark.parametrize("shape", SSD_SHAPES)
def test_ssd_scan_sweep(shape):
    b, s, h, p, n, chunk = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, n))
    cm = jax.random.normal(ks[4], (b, s, n))
    y, hL = ssd_scan(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    yr, hr = ssd_scan_ref(x, dt, a, bm, cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=5e-4)
    np.testing.assert_allclose(np.asarray(hL), np.asarray(hr), atol=5e-4)


def test_model_attention_pallas_path():
    """attention_apply(impl='pallas') agrees with the default path."""
    from repro.configs import get_config
    from repro.models import layers as L

    cfg = get_config("stablelm-1.6b").reduced()
    params = L.init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model))
    pos = jnp.arange(64)
    ref, _ = L.attention_apply(params, x, cfg, positions=pos, impl="dense")
    out, _ = L.attention_apply(params, x, cfg, positions=pos, impl="pallas")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-2)


def test_model_ssd_pallas_path():
    from repro.configs import get_config
    from repro.models import ssm as S

    cfg = get_config("mamba2-370m").reduced()
    params = S.init_mamba(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model))
    ref, (h0, _) = S.mamba_apply(params, x, cfg, use_pallas=False)
    out, (h1, _) = S.mamba_apply(params, x, cfg, use_pallas=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-3)
    np.testing.assert_allclose(np.asarray(h0), np.asarray(h1), atol=1e-3)
