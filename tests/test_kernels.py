"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret=True executes kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import ca_attention, flash_attention, ssd_scan
from repro.kernels.ref import flash_attention_ref, ssd_scan_ref

FLASH_SHAPES = [
    # (B, S, H, KH, hd, window, q_blk, kv_blk)
    (1, 128, 2, 2, 32, None, 64, 64),
    (2, 256, 4, 2, 64, None, 128, 128),
    (1, 200, 4, 1, 32, None, 64, 64),  # ragged seq, MQA
    (2, 256, 8, 2, 64, 64, 64, 64),  # sliding window
    (1, 512, 2, 2, 16, 128, 128, 64),  # window, uneven blocks
]


@pytest.mark.parametrize("shape", FLASH_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(shape, dtype):
    b, s, h, kh, hd, win, qb, kb = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, kh, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, kh, hd), dtype)
    out = flash_attention(q, k, v, window=win, q_blk=qb, kv_blk=kb, interpret=True)
    ref = flash_attention_ref(q, k, v, window=win)
    atol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=atol
    )


def test_flash_attention_q_offset():
    """Chunked decode-style usage: query block at an offset into the kv."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    skv, sq, off = 128, 32, 96
    q = jax.random.normal(ks[0], (1, sq, 2, 32))
    k = jax.random.normal(ks[1], (1, skv, 2, 32))
    v = jax.random.normal(ks[2], (1, skv, 2, 32))
    out = flash_attention(q, k, v, q_offset=off, q_blk=32, kv_blk=32, interpret=True)
    ref = flash_attention_ref(q, k, v, q_offset=off)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


SSD_SHAPES = [
    # (B, S, H, P, N, chunk)
    (1, 64, 2, 16, 8, 16),
    (2, 128, 4, 32, 16, 32),
    (1, 96, 2, 64, 128, 64),
    (1, 80, 1, 8, 4, 32),  # ragged
]


@pytest.mark.parametrize("shape", SSD_SHAPES)
def test_ssd_scan_sweep(shape):
    b, s, h, p, n, chunk = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, n))
    cm = jax.random.normal(ks[4], (b, s, n))
    y, hL = ssd_scan(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    yr, hr = ssd_scan_ref(x, dt, a, bm, cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=5e-4)
    np.testing.assert_allclose(np.asarray(hL), np.asarray(hr), atol=5e-4)


CA_SHAPES = [
    # (batch, obs_dim, pair_dim, I, attn_dim, blk)
    (1, 10, 14, 4, 8, 128),
    (7, 25, 51, 4, 64, 4),  # ragged batch, tiny blocks
    (128, 25, 51, 4, 64, 128),
    (130, 16, 32, 8, 32, 64),  # ragged vs block size, longer history
]


@pytest.mark.parametrize("shape", CA_SHAPES)
def test_ca_attention_matches_reference(shape):
    """The fused Pallas CA kernel reproduces agents.attention's
    cross_attention (current-state row) on CPU interpret mode, including
    all-masked rows and partial histories."""
    from repro.core.agents.attention import cross_attention, init_cross_attention

    b, obs_dim, pair_dim, i, c, blk = shape
    params = init_cross_attention(jax.random.PRNGKey(0), obs_dim, pair_dim, c)
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    obs = jax.random.normal(ks[0], (b, obs_dim))
    hist = jax.random.normal(ks[1], (b, i, pair_dim))
    mask = (jax.random.uniform(ks[2], (b, i)) > 0.4).astype(jnp.float32)
    mask = mask.at[0].set(0.0)  # row with no history -> zero summary

    ref = jax.vmap(lambda o, h, m: cross_attention(params, o, h, m))(
        obs, hist, mask)
    out = ca_attention(params, obs, hist, mask, blk=blk, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out[0, obs_dim:]), 0.0, atol=1e-7)


def test_ca_attention_grads_match_reference():
    """The kernel's custom VJP (slim-reference backward) reproduces the
    full reference's gradients - including wq_h's exact zero."""
    from repro.core.agents.attention import cross_attention, init_cross_attention

    b, obs_dim, pair_dim, i, c = 16, 12, 20, 4, 16
    params = init_cross_attention(jax.random.PRNGKey(0), obs_dim, pair_dim, c)
    obs = jax.random.normal(jax.random.PRNGKey(1), (b, obs_dim))
    hist = jax.random.normal(jax.random.PRNGKey(2), (b, i, pair_dim))
    mask = (jax.random.uniform(jax.random.PRNGKey(3), (b, i)) > 0.3
            ).astype(jnp.float32)
    tgt = jax.random.normal(jax.random.PRNGKey(4), (b, obs_dim + c))

    def loss_kernel(p):
        return jnp.sum((ca_attention(p, obs, hist, mask) - tgt) ** 2)

    def loss_ref(p):
        out = jax.vmap(lambda o, h, m: cross_attention(p, o, h, m))(
            obs, hist, mask)
        return jnp.sum((out - tgt) ** 2)

    gk = jax.grad(loss_kernel)(params)
    gr = jax.grad(loss_ref)(params)
    for name in ("wq_s", "wk", "wv", "wq_h"):
        np.testing.assert_allclose(np.asarray(gk[name]), np.asarray(gr[name]),
                                   atol=2e-4, rtol=2e-4, err_msg=name)
    np.testing.assert_array_equal(np.asarray(gk["wq_h"]), 0.0)


def test_ca_attention_low_precision_mask_safe():
    """The kernel's finfo-based masking survives fp16/bf16 scores (a -1e9
    literal overflows fp16 to -inf and NaNs fully-masked rows)."""
    from repro.core.agents.attention import init_cross_attention

    b, obs_dim, pair_dim, i, c = 9, 12, 20, 4, 16
    params = init_cross_attention(jax.random.PRNGKey(0), obs_dim, pair_dim, c)
    obs = jax.random.normal(jax.random.PRNGKey(1), (b, obs_dim))
    hist = jax.random.normal(jax.random.PRNGKey(2), (b, i, pair_dim))
    mask = jnp.zeros((b, i)).at[1:, :2].set(1.0)
    ref = np.asarray(ca_attention(params, obs, hist, mask))
    for dtype in (jnp.bfloat16, jnp.float16):
        cast = jax.tree.map(lambda x: x.astype(dtype), params)
        out = ca_attention(cast, obs.astype(dtype), hist.astype(dtype),
                           mask.astype(dtype))
        out = np.asarray(out, np.float32)
        assert np.isfinite(out).all(), dtype
        np.testing.assert_allclose(out, ref, atol=0.15)


def test_model_attention_pallas_path():
    """attention_apply(impl='pallas') agrees with the default path."""
    from repro.configs import get_config
    from repro.models import layers as L

    cfg = get_config("stablelm-1.6b").reduced()
    params = L.init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model))
    pos = jnp.arange(64)
    ref, _ = L.attention_apply(params, x, cfg, positions=pos, impl="dense")
    out, _ = L.attention_apply(params, x, cfg, positions=pos, impl="pallas")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-2)


def test_model_ssd_pallas_path():
    from repro.configs import get_config
    from repro.models import ssm as S

    cfg = get_config("mamba2-370m").reduced()
    params = S.init_mamba(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model))
    ref, (h0, _) = S.mamba_apply(params, x, cfg, use_pallas=False)
    out, (h1, _) = S.mamba_apply(params, x, cfg, use_pallas=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-3)
    np.testing.assert_allclose(np.asarray(h0), np.asarray(h1), atol=1e-3)


STAGE_SHAPES = [
    # (B, S, D, F, blk)
    (1, 32, 64, 128, 128),
    (2, 48, 64, 160, 32),   # ragged rows vs block size
    (3, 37, 128, 96, 64),   # ragged, F < D
]


@pytest.mark.parametrize("shape", STAGE_SHAPES)
@pytest.mark.parametrize("activation", ["swiglu", "gelu", "relu2"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stage_mlp_block_forward(shape, activation, dtype):
    """Fused residual stage kernel vs the models.layers reference."""
    from repro.kernels.stage_block import stage_mlp_block
    from repro.models.layers import init_mlp, mlp_block

    b, s, d, f, blk = shape
    params = init_mlp(jax.random.PRNGKey(0), d, f, activation)
    norm_w = jnp.ones((d,)) + 0.1 * jax.random.normal(jax.random.PRNGKey(1), (d,))
    x = jax.random.normal(jax.random.PRNGKey(2), (b, s, d), dtype)
    out = stage_mlp_block(norm_w, params, x, activation=activation, blk=blk,
                          interpret=True)
    ref = mlp_block(norm_w, params, x, activation)
    assert out.dtype == x.dtype and out.shape == x.shape
    # bf16 tolerance covers the kernel's EXTRA precision: it accumulates
    # matmuls in fp32 where the reference rounds between einsums
    atol = 2e-6 if dtype == jnp.float32 else 8e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


@pytest.mark.parametrize("activation", ["swiglu", "gelu"])
def test_stage_mlp_block_grads_match_reference(activation):
    """With a FIXED cotangent, the kernel's custom VJP runs the reference
    VJP (same function, same residuals), so params/norm/input grads agree
    to compilation-level reassociation noise (~1e-7 rel; the two VJPs are
    compiled into different programs, so bitwise equality is not
    guaranteed)."""
    from repro.kernels.stage_block import stage_mlp_block
    from repro.models.layers import init_mlp, mlp_block

    d, f, b, s = 64, 96, 2, 19
    params = init_mlp(jax.random.PRNGKey(0), d, f, activation)
    norm_w = jnp.ones((d,)) * 1.05
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d))
    g = jax.random.normal(jax.random.PRNGKey(2), (b, s, d))
    _, vjp_k = jax.vjp(
        lambda nw, p, xx: stage_mlp_block(nw, p, xx, activation=activation,
                                          blk=16, interpret=True),
        norm_w, params, x)
    _, vjp_r = jax.vjp(
        lambda nw, p, xx: mlp_block(nw, p, xx, activation), norm_w, params, x)
    for a, b_ in zip(jax.tree.leaves(vjp_k(g)), jax.tree.leaves(vjp_r(g))):
        a, b_ = np.asarray(a, np.float64), np.asarray(b_, np.float64)
        np.testing.assert_allclose(a, b_, rtol=1e-6,
                                   atol=1e-6 * max(np.abs(b_).max(), 1e-8))
