"""shard_map expert-parallel MoE (all_to_all dispatch) vs the GSPMD path."""


def test_moe_a2a_matches_gspmd(subproc):
    out = subproc(
        """
import jax, jax.numpy as jnp
from dataclasses import replace
from repro.configs import get_config
from repro.models import layers as L
from repro.models.moe_a2a import moe_apply_a2a, a2a_applicable
from repro.launch.mesh import make_host_mesh
from repro.distribution.context import activation_sharding

cfg = get_config('qwen3-moe-30b-a3b').reduced()
cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=4.0))  # no drops
params = L.init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
y_ref, _ = L.moe_apply(params, x, cfg)
mesh = make_host_mesh(2, 2)
with activation_sharding(mesh, ('data',), moe_a2a=True):
    assert a2a_applicable(cfg)
    y, aux = jax.jit(lambda p, x: moe_apply_a2a(p, x, cfg))(params, x)
    err = float(jnp.abs(y - y_ref).max())
    assert err < 1e-4, err
    g = jax.grad(lambda p: moe_apply_a2a(p, x, cfg)[0].astype(jnp.float32).sum())(params)
    assert all(bool(jnp.isfinite(v).all()) for v in jax.tree.leaves(g))
print('MOE_A2A_OK', err)
""",
        n_devices=4,
    )
    assert "MOE_A2A_OK" in out


def test_moe_a2a_end_to_end_train_step(subproc):
    """A full sharded train step routed through the a2a MoE path."""
    out = subproc(
        """
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import init_params, make_train_step
from repro.distribution.sharding import param_shardings, batch_axes
from repro.distribution.context import activation_sharding
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
from repro.data import synthetic_batch

cfg = get_config('qwen3-moe-30b-a3b').reduced()
mesh = make_host_mesh(2, 2)
params = init_params(jax.random.PRNGKey(0), cfg)
psh = param_shardings(jax.eval_shape(lambda: params), cfg, mesh)
params = jax.tree.map(lambda a, s: jax.device_put(a, s), params, psh)
opt = adamw(1e-3)
ostate = opt.init(params)
osh = param_shardings(jax.eval_shape(lambda: ostate), cfg, mesh)
batch = synthetic_batch(cfg, 4, 32)
bsh = {k: NamedSharding(mesh, P('data', *([None]*(v.ndim-1)))) for k, v in batch.items()}
batch = jax.tree.map(lambda a, s: jax.device_put(a, s), batch, bsh)
step = jax.jit(make_train_step(cfg, opt), in_shardings=(psh, osh, bsh),
               out_shardings=(psh, osh, None))
with activation_sharding(mesh, ('data',), moe_a2a=True):
    p2, o2, m = step(params, ostate, batch)
assert bool(jnp.isfinite(m['loss'])), m
print('MOE_A2A_TRAIN_OK', float(m['loss']))
""",
        n_devices=4,
    )
    assert "MOE_A2A_TRAIN_OK" in out
