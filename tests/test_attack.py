"""The FSHA-style attacker: fused chunk, population vmap, capture gating.

Mirrors the rollout-engine test contract: the vmapped population is
bit-identical to the single-attacker loop at population size 1, the
whole (boundary x scenario) population compiles exactly ONCE, training
actually reduces the reconstruction loss, and zero capture probability
makes the captured client pool's CONTENTS irrelevant bit-for-bit.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.attack import (
    AttackConfig,
    attack_scores,
    flatten_rows,
    init_attack_state,
    init_attacker,
    init_attacker_population,
    make_attack_chunk,
    make_population_attack_chunk,
    smashed_activations,
    tiny_attack_model_cfg,
)

CFG = AttackConfig(d_data=6, d_smash=6, feat_dim=8, hidden=8, batch=16)
POOL = 48
STEPS = 12


def _pools(key, n=None):
    ks = jax.random.split(key, 4)
    shape = (POOL,) if n is None else (n, POOL)
    mk = lambda k, d: jax.random.normal(k, shape + (d,))
    return {
        "z_cli": mk(ks[0], CFG.d_smash),
        "x_cli": mk(ks[1], CFG.d_data),
        "z_aux": mk(ks[2], CFG.d_smash),
        "x_aux": mk(ks[3], CFG.d_data),
    }


def _leaves_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_population_of_one_matches_single_chunk_bitwise():
    key = jax.random.PRNGKey(0)
    k_init, k_pool, k_run = jax.random.split(key, 3)
    params = init_attacker(k_init, CFG)
    opt_state = init_attack_state(params, CFG)
    pools = _pools(k_pool)
    p_eff = jnp.asarray(0.7)

    single = make_attack_chunk(CFG, STEPS)
    p1, s1, m1 = single(params, opt_state, pools, p_eff, k_run)

    pop = make_population_attack_chunk(CFG, STEPS)
    stack = lambda t: jax.tree.map(lambda a: a[None], t)
    p2, s2, m2 = pop(stack(params), stack(opt_state), stack(pools),
                     p_eff[None], k_run[None])
    assert _leaves_equal(p1, jax.tree.map(lambda a: a[0], p2))
    assert _leaves_equal(s1, jax.tree.map(lambda a: a[0], s2))
    assert _leaves_equal(m1, jax.tree.map(lambda a: a[0], m2))


def test_population_compiles_once_across_boundaries_and_scenarios():
    """One trace serves every (boundary x scenario) batch of the same
    shape - new pools, new capture weights, new keys, zero recompiles."""
    n = 6  # e.g. 3 boundaries x 2 scenarios
    pop = make_population_attack_chunk(CFG, STEPS)
    params, opt_state = init_attacker_population(jax.random.PRNGKey(1), CFG, n)
    for i in range(3):  # three different boundary/scenario batches
        pools = _pools(jax.random.PRNGKey(10 + i), n)
        p_eff = jax.random.uniform(jax.random.PRNGKey(20 + i), (n,))
        keys = jax.random.split(jax.random.PRNGKey(30 + i), n)
        params, opt_state, _ = pop(params, opt_state, pools, p_eff, keys)
    assert pop.trace_count == [1]


def test_training_reduces_reconstruction_loss():
    key = jax.random.PRNGKey(2)
    k_init, k_pool, k_run = jax.random.split(key, 3)
    params = init_attacker(k_init, CFG)
    opt_state = init_attack_state(params, CFG)
    # learnable task: x is a fixed linear readout of z
    pools = _pools(k_pool)
    w = jax.random.normal(jax.random.PRNGKey(3), (CFG.d_smash, CFG.d_data))
    pools["x_cli"] = pools["z_cli"] @ w
    pools["x_aux"] = pools["z_aux"] @ w
    chunk = make_attack_chunk(CFG, 150)
    p, _, m = chunk(params, opt_state, pools, jnp.asarray(1.0), k_run)
    mse = np.asarray(m["recon_mse"])
    assert mse[-10:].mean() < 0.5 * mse[:10].mean()
    sc, _ = attack_scores(p, pools["z_cli"], pools["x_cli"])
    assert float(sc) > 0.3


def test_zero_capture_ignores_client_pool_contents():
    """p_eff=0: the captured pool's values must not influence training -
    the client-data loss terms are gated to exactly zero."""
    key = jax.random.PRNGKey(4)
    k_init, k_pool, k_run = jax.random.split(key, 3)
    params = init_attacker(k_init, CFG)
    opt_state = init_attack_state(params, CFG)
    chunk = make_attack_chunk(CFG, STEPS)
    pools_a = _pools(k_pool)
    pools_b = dict(pools_a)
    pools_b["z_cli"] = pools_a["z_cli"] * -3.0 + 1.0
    pools_b["x_cli"] = pools_a["x_cli"] * 5.0 - 2.0
    pa, _, _ = chunk(params, opt_state, pools_a, jnp.asarray(0.0), k_run)
    pb, _, _ = chunk(params, opt_state, pools_b, jnp.asarray(0.0), k_run)
    assert _leaves_equal(pa["atk"], pb["atk"])
    # and with capture ON the same perturbation must matter
    pc, _, _ = chunk(params, opt_state, pools_a, jnp.asarray(1.0), k_run)
    pd, _, _ = chunk(params, opt_state, pools_b, jnp.asarray(1.0), k_run)
    assert not _leaves_equal(pc["atk"], pd["atk"])


def test_smashed_activations_match_manual_block_loop():
    from repro.models import init_params
    from repro.models import model as M

    cfg = tiny_attack_model_cfg(depth=3, d_model=32)
    params = init_params(jax.random.PRNGKey(5), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(6), (2, 8), 0,
                                cfg.vocab_size)
    cuts = [1, 3]
    x0, z = smashed_activations(params, cfg, tokens, cuts)
    assert np.array_equal(np.asarray(x0), np.asarray(params["embed"][tokens]))
    sig = M.signature(cfg)
    x = x0
    outs = []
    for layer in range(cfg.num_layers):
        blk = jax.tree.map(lambda a: a[layer], params["slots"][0])
        x, _, _ = M.block_apply(blk, x, cfg, sig[0],
                                positions=jnp.arange(tokens.shape[-1]))
        outs.append(x)
    for k, cut in enumerate(cuts):
        # scan vs python loop: same math, different fusion -> tiny ulp noise
        assert np.allclose(np.asarray(z[k]), np.asarray(outs[cut - 1]),
                           atol=1e-5)
    flat = flatten_rows(z)
    assert flat.shape == (len(cuts), 2 * 8, cfg.d_model)
