"""Property-based pins on :mod:`repro.core.profiles` derived tables.

The plan scorer prices a stage as two gathers into cumulative tables
(``fwd_cum[hi] - fwd_cum[lo]``) instead of summing the per-layer slice.
For that rewrite to be EXACTLY the seed semantics the cumulative-gather
difference must be bit-equal to the direct segment sum - which holds
whenever the per-layer values (and all their partial sums) are exactly
representable in float64. The property tests draw integer-valued
profiles (each value < 2^40, L <= 12, so every partial sum < 2^53) and
pin bit-equality over the FULL boundary enumeration, including the
PR-9 architecture-aware ``state_cum``/``kind`` columns and the
legacy-``None`` normalization in :func:`profile_digest`.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip, unit tests still run
    from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.profiles import (
    KIND_ATTN_MOE, KIND_SSM, KIND_SSM_MOE, LayerProfile, block_kind,
    profile_digest, profile_table, transformer_profile,
)
from repro.core.splitting import stack_boundaries

# one drawn row per layer: (act, fwd, bwd, state, kind). Integer-valued
# so float64 cumsum arithmetic is exact (see module docstring).
_ROW = st.tuples(
    st.integers(min_value=1, max_value=2**40),  # act_bytes (>0: leak_norm)
    st.integers(min_value=0, max_value=2**40),  # fwd_flops
    st.integers(min_value=0, max_value=2**40),  # bwd_flops
    st.integers(min_value=0, max_value=2**40),  # state_bytes
    st.integers(min_value=0, max_value=3),      # KIND_* code
)
_ROWS = st.lists(_ROW, min_size=4, max_size=12)


def _profile_from_rows(rows, with_state=True):
    act, fwd, bwd, state, kind = (np.asarray(c, np.float64)
                                  for c in zip(*rows))
    return LayerProfile(
        name="property-draw",
        param_bytes=act.copy(),
        act_bytes=act,
        grad_bytes=act.copy(),
        fwd_flops=fwd,
        bwd_flops=bwd,
        leak_value=act.copy(),
        state_bytes=state if with_state else None,
        kind=kind.astype(np.int8) if with_state else None,
    )


@given(rows=_ROWS, s=st.integers(min_value=2, max_value=4))
@settings(max_examples=40, deadline=None)
def test_cumulative_tables_bit_equal_segment_sums(rows, s):
    """Every (lo, hi) stage segment of every S-way cut of a random
    integer-valued profile: cumulative-gather difference == direct
    per-segment sum, BITWISE, for fwd/bwd/state columns alike."""
    prof = _profile_from_rows(rows)
    tab = profile_table(prof)
    L = prof.num_layers
    s = min(s, L)

    assert tab.fwd_cum[0] == 0.0 and tab.bwd_cum[0] == 0.0
    assert tab.state_cum[0] == 0.0
    # bits columns are exact *8 of the drawn integers
    assert np.array_equal(tab.act_bits, prof.act_bytes * 8.0)
    assert np.array_equal(tab.state_bits, prof.state_bytes * 8.0)
    assert np.array_equal(tab.kind, prof.kind)

    for bounds in stack_boundaries(L, s):
        edges = [0, *(int(b) for b in bounds)]
        for lo, hi in zip(edges[:-1], edges[1:]):
            assert (tab.fwd_cum[hi] - tab.fwd_cum[lo]
                    == prof.fwd_flops[lo:hi].sum())
            assert (tab.bwd_cum[hi] - tab.bwd_cum[lo]
                    == prof.bwd_flops[lo:hi].sum())
            assert (tab.state_cum[hi] - tab.state_cum[lo]
                    == prof.state_bytes[lo:hi].sum() * 8.0)


@given(rows=_ROWS)
@settings(max_examples=20, deadline=None)
def test_legacy_none_state_matches_explicit_zeros(rows):
    """A profile built without the PR-9 columns (state_bytes=kind=None)
    must digest - and therefore cache - identically to one carrying
    explicit zeros, and differently once any state is nonzero."""
    legacy = _profile_from_rows(rows, with_state=False)
    zeroed = LayerProfile(
        name=legacy.name, param_bytes=legacy.param_bytes,
        act_bytes=legacy.act_bytes, grad_bytes=legacy.grad_bytes,
        fwd_flops=legacy.fwd_flops, bwd_flops=legacy.bwd_flops,
        leak_value=legacy.leak_value,
        state_bytes=np.zeros(legacy.num_layers),
        kind=np.zeros(legacy.num_layers, np.int8),
    )
    assert profile_digest(legacy) == profile_digest(zeroed)
    assert profile_table(legacy) is profile_table(zeroed)
    assert np.array_equal(profile_table(legacy).state_cum,
                          np.zeros(legacy.num_layers + 1))

    stated = _profile_from_rows(rows)
    if stated.state_bytes.any() or stated.kind.any():
        assert profile_digest(stated) != profile_digest(legacy)


@pytest.mark.parametrize("arch", ["qwen3-moe-30b-a3b", "jamba-v0.1-52b",
                                  "mamba2-370m"])
def test_transformer_profile_kind_column_matches_config(arch):
    """The profile's kind codes must agree with ``block_kind`` over the
    config pattern, and every heterogeneous zoo config must carry
    strictly positive resident state on every block."""
    cfg = get_config(arch)
    prof = transformer_profile(cfg, batch=1, seq=512)
    tab = profile_table(prof)
    expect = np.asarray([block_kind(cfg, i) for i in range(cfg.num_layers)],
                        np.int8)
    assert np.array_equal(tab.kind, expect)
    assert np.all(tab.state_bits > 0)
    if arch == "jamba-v0.1-52b":
        assert {KIND_SSM, KIND_SSM_MOE} <= set(int(k) for k in tab.kind)
    if arch == "qwen3-moe-30b-a3b":
        assert set(int(k) for k in tab.kind) == {KIND_ATTN_MOE}
