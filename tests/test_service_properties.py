"""Property tests for the host-side slot scheduler and request queue.

The scheduler's contract (``SlotScheduler.pack``):

* never admits more than ``min(arrival_slots, free_slots, pending)``;
* never drops or duplicates a request - admitted + still-queued is
  exactly the original queue, in order;
* prompt-pad rejection is TOTAL: an oversized prompt raises before ANY
  request is popped, so a rejected pack leaves the queue intact.

Property tests run under hypothesis when installed; a seeded
exhaustive-ish sweep alongside exercises the same invariants on boxes
without it (same shim idiom as the other ``*_properties`` modules).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip, unit tests still run
    from _hypothesis_compat import given, settings, st

from repro.serving import Request, RequestQueue, SlotScheduler


def _mk_queue(plens, now=1.0):
    reqs = [Request(rid=i, prompt=np.full(pl, i + 1, np.int32),
                    gen_target=2, arrival_time=0.0)
            for i, pl in enumerate(plens)]
    q = RequestQueue(reqs)
    q.advance(now)
    return q, reqs


def _check_pack(plens, arrival_slots, prompt_pad, free_slots):
    q, reqs = _mk_queue(plens)
    sched = SlotScheduler(arrival_slots, prompt_pad)
    oversized = [r for r in reqs[: max(min(arrival_slots, free_slots), 0)]
                 if r.plen > prompt_pad]
    if oversized:
        with pytest.raises(ValueError):
            sched.pack(q, free_slots)
        # rejection is total: nothing popped, order preserved
        assert q.pending == len(reqs)
        assert [r.rid for r in q.peek(len(reqs))] == [r.rid for r in reqs]
        return
    admitted, ap, al, ag, ar, n_arr = sched.pack(q, free_slots)
    # bound: never exceeds free slots, arrival slots, or pending
    assert n_arr == len(admitted)
    assert n_arr <= max(free_slots, 0)
    assert n_arr <= arrival_slots
    assert n_arr <= len(reqs)
    assert n_arr == min(arrival_slots, max(free_slots, 0), len(reqs))
    # conservation: admitted + still queued == original, in order
    left = [r.rid for r in q.peek(q.pending)]
    assert [r.rid for r in admitted] + left == [r.rid for r in reqs]
    # the packed buffers describe exactly the admitted requests
    for i, r in enumerate(admitted):
        assert al[i] == r.plen and ag[i] == r.gen_target and ar[i] == r.rid
        assert np.array_equal(ap[i, : r.plen], r.prompt)
        assert not ap[i, r.plen:].any()
    for i in range(len(admitted), arrival_slots):
        assert ar[i] == -1


@given(
    plens=st.lists(st.integers(min_value=1, max_value=12), min_size=0,
                   max_size=10),
    arrival_slots=st.integers(min_value=1, max_value=6),
    prompt_pad=st.integers(min_value=1, max_value=10),
    free_slots=st.integers(min_value=0, max_value=8),
)
@settings(max_examples=200, deadline=None)
def test_pack_properties(plens, arrival_slots, prompt_pad, free_slots):
    _check_pack(plens, arrival_slots, prompt_pad, free_slots)


def test_pack_properties_seeded_sweep():
    """The same invariants without hypothesis: a seeded randomized sweep
    plus the known corner cases (k=0, empty queue, all-oversized)."""
    rng = np.random.default_rng(0)
    for _ in range(200):
        plens = rng.integers(1, 13, size=rng.integers(0, 11)).tolist()
        _check_pack(plens, int(rng.integers(1, 7)), int(rng.integers(1, 11)),
                    int(rng.integers(0, 9)))
    _check_pack([], 4, 8, 4)            # empty queue
    _check_pack([3, 3], 4, 8, 0)        # no free slots -> admits nothing
    _check_pack([9, 9], 2, 8, 2)        # every candidate oversized
    _check_pack([3, 9, 3], 3, 8, 3)     # oversized in the middle


def test_pop_and_peek_clamp():
    q, reqs = _mk_queue([2, 2, 2])
    assert q.pop(0) == [] and q.pop(-1) == []
    assert q.pending == 3
    assert [r.rid for r in q.peek(99)] == [0, 1, 2]
    assert [r.rid for r in q.pop(99)] == [0, 1, 2]
    assert q.pop(5) == [] and q.peek(1) == []


def test_requeue_front_preserves_order():
    q, reqs = _mk_queue([2, 2, 2, 2])
    taken = q.pop(2)
    q.requeue_front(taken)
    assert [r.rid for r in q.peek(4)] == [0, 1, 2, 3]
    # evicted requests jump ahead of later arrivals
    q.pop(1)
    q.requeue_front([reqs[3]])
    assert [r.rid for r in q.peek(3)] == [3, 1, 2]


def test_drop_expired_only_past_deadline():
    reqs = [Request(rid=i, prompt=np.ones(2, np.int32), gen_target=1,
                    arrival_time=0.0, deadline=dl)
            for i, dl in enumerate([0.5, float("inf"), 2.0])]
    q = RequestQueue(reqs)
    q.advance(1.0)
    dropped = q.drop_expired(1.0)
    assert [r.rid for r in dropped] == [0]
    assert [r.rid for r in q.peek(3)] == [1, 2]
    assert q.drop_expired(1.5) == []
    assert [r.rid for r in q.drop_expired(2.0)] == [2]
