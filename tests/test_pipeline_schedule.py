"""Split-executor schedule tests: the 1F1B executor must be loss- and
gradient-compatible with the fill-drain reference (rtol <= 2e-5 at f32),
including uneven masked splits, the Pallas stage-kernel knob, and the
stage-grad re-layout."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.pipeline import (
    PipelineConfig,
    make_stage_mesh,
    pipeline_step_fn,
    restack_for_stages,
    stage_lengths,
    unstack_stage_grads,
)
from repro.models import init_params

RTOL = 2e-5


def _assert_grads_close(g_ref, g_new, rtol=RTOL):
    assert jax.tree.structure(g_ref) == jax.tree.structure(g_new)
    for (path, a), (_, b) in zip(
        jax.tree_util.tree_flatten_with_path(g_ref)[0],
        jax.tree_util.tree_flatten_with_path(g_new)[0],
    ):
        a = np.asarray(a, np.float64)
        b = np.asarray(b, np.float64)
        # rtol on the leaf scale: element-wise rtol is meaningless for the
        # near-zero entries of scatter-sparse grads (embed rows of unseen
        # tokens carry exact zeros on both sides, but neighbours sit at
        # rounding level)
        np.testing.assert_allclose(
            b, a, rtol=rtol, atol=rtol * max(np.abs(a).max(), 1e-8),
            err_msg=jax.tree_util.keystr(path),
        )


def _data(cfg, rows, seq, seed=0):
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (rows, seq)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (rows, seq)), jnp.int32)
    return tokens, labels


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "qwen2.5-3b"])
def test_1f1b_matches_fill_drain_single_stage(arch):
    """S=1 exercises the full manual-VJP machinery (stash, loss seeding,
    embed scatter, tied/untied heads) without needing a multi-device mesh."""
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    mesh = make_stage_mesh(1)
    tokens, labels = _data(cfg, rows=4, seq=32)
    fd = pipeline_step_fn(cfg, mesh, (2,), 4,
                          pipe=PipelineConfig(schedule="fill_drain",
                                              compute_dtype="float32"))
    f1 = pipeline_step_fn(cfg, mesh, (2,), 4,
                          pipe=PipelineConfig(schedule="1f1b",
                                              compute_dtype="float32"))
    l0, g0 = jax.jit(fd)(params, tokens, labels)
    l1, g1 = jax.jit(f1)(params, tokens, labels)
    np.testing.assert_allclose(float(l1), float(l0), rtol=RTOL)
    _assert_grads_close(g0, g1)


def test_1f1b_matches_fill_drain_multistage(subproc):
    """Uneven 3-stage split on a real stage mesh: masked active-length
    compute + ppermute hops + per-stage grad re-layout, against jax.grad
    of the fill-drain reference."""
    out = subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from dataclasses import replace
from repro.configs import get_config
from repro.models import init_params
from repro.core.pipeline import PipelineConfig, make_stage_mesh, pipeline_step_fn

cfg = replace(get_config('qwen2.5-3b').reduced(), num_layers=4)
params = init_params(jax.random.PRNGKey(0), cfg)
mesh = make_stage_mesh(3)
rng = np.random.default_rng(0)
tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (6, 16)), jnp.int32)
labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (6, 16)), jnp.int32)
bounds = (1, 3, 4)  # uneven: stage lengths 1/2/1, max_len 2
fd = pipeline_step_fn(cfg, mesh, bounds, 3,
                      pipe=PipelineConfig(schedule="fill_drain", compute_dtype="float32"))
f1 = pipeline_step_fn(cfg, mesh, bounds, 3,
                      pipe=PipelineConfig(schedule="1f1b", compute_dtype="float32"))
l0, g0 = jax.jit(fd)(params, tokens, labels)
l1, g1 = jax.jit(f1)(params, tokens, labels)
assert abs(float(l0) - float(l1)) <= 2e-5 * abs(float(l0)), (float(l0), float(l1))
for (path, a), (_, b) in zip(jax.tree_util.tree_flatten_with_path(g0)[0],
                             jax.tree_util.tree_flatten_with_path(g1)[0]):
    a = np.asarray(a, np.float64); b = np.asarray(b, np.float64)
    np.testing.assert_allclose(b, a, rtol=2e-5,
                               atol=2e-5 * max(np.abs(a).max(), 1e-8),
                               err_msg=jax.tree_util.keystr(path))
print('F1B_PARITY_OK', float(l0))
""",
        n_devices=3,
    )
    assert "F1B_PARITY_OK" in out


def test_1f1b_pallas_stage_impl_matches_reference():
    """PipelineConfig.stage_impl='pallas' (fused residual-MLP kernel,
    interpret mode on CPU) is loss/grad-compatible with the reference
    stage implementation through the whole 1F1B executor."""
    cfg = get_config("stablelm-1.6b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    mesh = make_stage_mesh(1)
    tokens, labels = _data(cfg, rows=4, seq=16)
    ref = pipeline_step_fn(cfg, mesh, (2,), 2,
                           pipe=PipelineConfig(compute_dtype="float32"))
    pal = pipeline_step_fn(cfg, mesh, (2,), 2,
                           pipe=PipelineConfig(compute_dtype="float32",
                                               stage_impl="pallas"))
    l0, g0 = jax.jit(ref)(params, tokens, labels)
    l1, g1 = jax.jit(pal)(params, tokens, labels)
    np.testing.assert_allclose(float(l1), float(l0), rtol=RTOL)
    _assert_grads_close(g0, g1)


def test_boundary_validation():
    """Malformed split plans are refused with a clear ValueError before
    they reach shard_map (satellite: stage_lengths/restack_for_stages)."""
    for bad in [(), (2, 2, 4), (3, 2), (0, 2), (-1, 4)]:
        with pytest.raises(ValueError):
            stage_lengths(bad)
    tree = {"w": jnp.zeros((4, 3))}
    with pytest.raises(ValueError):
        restack_for_stages(tree, (1, 3))  # last boundary != num_layers
    with pytest.raises(ValueError):
        restack_for_stages(tree, (2, 2, 4))
    out = restack_for_stages(tree, (1, 4))  # valid: lens 1/3
    assert out["w"].shape == (2, 3, 3)


def test_transport_sync_overlap_bit_identical_multistage(subproc):
    """The double-buffered handoff consumes every buffer on the same tick
    as the synchronous one, so loss AND grads must match bit-for-bit; a
    bf16 wire under f32 compute stays close at bf16 tolerance."""
    out = subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from dataclasses import replace
from repro.configs import get_config
from repro.models import init_params
from repro.core.pipeline import PipelineConfig, make_stage_mesh, pipeline_step_fn

cfg = replace(get_config('qwen2.5-3b').reduced(), num_layers=4)
params = init_params(jax.random.PRNGKey(0), cfg)
mesh = make_stage_mesh(3)
rng = np.random.default_rng(0)
tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (6, 16)), jnp.int32)
labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (6, 16)), jnp.int32)
bounds = (1, 3, 4)
steps = {}
for tr in ('sync', 'overlap'):
    fn = pipeline_step_fn(cfg, mesh, bounds, 3,
                          pipe=PipelineConfig(transport=tr, compute_dtype='float32'))
    steps[tr] = jax.jit(fn)(params, tokens, labels)
l_s, g_s = steps['sync']; l_o, g_o = steps['overlap']
assert float(l_s) == float(l_o), (float(l_s), float(l_o))
for (path, a), (_, b) in zip(jax.tree_util.tree_flatten_with_path(g_s)[0],
                             jax.tree_util.tree_flatten_with_path(g_o)[0]):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                  err_msg=jax.tree_util.keystr(path))
# bf16 wire under f32 compute: quantizes each hop, bounded drift
wire = pipeline_step_fn(cfg, mesh, bounds, 3,
                        pipe=PipelineConfig(compute_dtype='float32',
                                            wire_dtype='bfloat16'))
l_w, g_w = jax.jit(wire)(params, tokens, labels)
assert abs(float(l_w) - float(l_s)) <= 3e-2 * abs(float(l_s)), (float(l_w), float(l_s))
print('TRANSPORT_PARITY_OK', float(l_s))
""",
        n_devices=3,
    )
    assert "TRANSPORT_PARITY_OK" in out


def test_1f1b_matches_fill_drain_8stage_uneven(subproc):
    """S=8 uneven split on a real 8-device stage mesh (satellite c):
    overlapped 1F1B vs jax.grad of fill-drain, loss + grads."""
    out = subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from dataclasses import replace
from repro.configs import get_config
from repro.models import init_params
from repro.core.pipeline import PipelineConfig, make_stage_mesh, pipeline_step_fn

cfg = replace(get_config('qwen2.5-3b').reduced(), num_layers=9)
params = init_params(jax.random.PRNGKey(0), cfg)
mesh = make_stage_mesh(8)
rng = np.random.default_rng(0)
m = 8
tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (m, 8)), jnp.int32)
labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (m, 8)), jnp.int32)
bounds = (2, 3, 4, 5, 6, 7, 8, 9)  # stage lens 2/1/1/1/1/1/1/1
fd = pipeline_step_fn(cfg, mesh, bounds, m,
                      pipe=PipelineConfig(schedule="fill_drain", compute_dtype="float32"))
f1 = pipeline_step_fn(cfg, mesh, bounds, m,
                      pipe=PipelineConfig(schedule="1f1b", transport="overlap",
                                          compute_dtype="float32"))
l0, g0 = jax.jit(fd)(params, tokens, labels)
l1, g1 = jax.jit(f1)(params, tokens, labels)
assert abs(float(l0) - float(l1)) <= 2e-5 * abs(float(l0)), (float(l0), float(l1))
for (path, a), (_, b) in zip(jax.tree_util.tree_flatten_with_path(g0)[0],
                             jax.tree_util.tree_flatten_with_path(g1)[0]):
    a = np.asarray(a, np.float64); b = np.asarray(b, np.float64)
    np.testing.assert_allclose(b, a, rtol=2e-5,
                               atol=2e-5 * max(np.abs(a).max(), 1e-8),
                               err_msg=jax.tree_util.keystr(path))
print('F1B_8STAGE_OK', float(l0))
""",
        n_devices=8,
        timeout=600,
    )
    assert "F1B_8STAGE_OK" in out


@pytest.mark.parametrize("arch", ["jamba-v0.1-52b", "qwen3-moe-30b-a3b"])
def test_1f1b_mixed_blocks_matches_forward_single_stage(arch):
    """Mixed block types per stage (PR 9): the union-param + lax.switch
    executor on a hybrid SSM/MoE (period-2) and pure-MoE stack must
    match jax.value_and_grad of the plain forward pass - exact-zero
    union rows for foreign fields must contribute exact-zero grads."""
    from repro.models import model as M

    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    mesh = make_stage_mesh(1)
    tokens, labels = _data(cfg, rows=2, seq=16)

    def ref_loss(p):
        logits, _, _ = M.forward(p, tokens, cfg, compute_dtype=jnp.float32,
                                 remat=False)
        return M.softmax_xent(logits, labels)

    l0, g0 = jax.jit(jax.value_and_grad(ref_loss))(params)
    f1 = pipeline_step_fn(cfg, mesh, (cfg.num_layers,), 2,
                          pipe=PipelineConfig(compute_dtype="float32"))
    l1, g1 = jax.jit(f1)(params, tokens, labels)
    np.testing.assert_allclose(float(l1), float(l0), rtol=RTOL)
    _assert_grads_close(g0, g1)


def test_1f1b_mixed_blocks_multistage(subproc):
    """Hybrid period-2 stack split unevenly across a real 2-stage mesh:
    the static per-slot block-kind schedule rides the shard_map scan
    (codes restacked like the union params) and must reproduce the plain
    forward loss/grads."""
    out = subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from dataclasses import replace
from repro.configs import get_config
from repro.models import model as M
from repro.models.model import init_params
from repro.core.pipeline import PipelineConfig, make_stage_mesh, pipeline_step_fn

base = get_config('jamba-v0.1-52b').reduced()
cfg = replace(base, num_layers=4, block_pattern='AMAM')
params = init_params(jax.random.PRNGKey(0), cfg)
mesh = make_stage_mesh(2)
rng = np.random.default_rng(0)
tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)
labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)

def ref_loss(p):
    logits, _, _ = M.forward(p, tokens, cfg, compute_dtype=jnp.float32,
                             remat=False)
    return M.softmax_xent(logits, labels)

l0, g0 = jax.jit(jax.value_and_grad(ref_loss))(params)
f1 = pipeline_step_fn(cfg, mesh, (1, 4), 2,  # uneven: stage lens 1/3
                      pipe=PipelineConfig(compute_dtype='float32'))
l1, g1 = jax.jit(f1)(params, tokens, labels)
assert abs(float(l0) - float(l1)) <= 2e-5 * abs(float(l0)), (float(l0), float(l1))
for (path, a), (_, b) in zip(jax.tree_util.tree_flatten_with_path(g0)[0],
                             jax.tree_util.tree_flatten_with_path(g1)[0]):
    a = np.asarray(a, np.float64); b = np.asarray(b, np.float64)
    np.testing.assert_allclose(b, a, rtol=2e-5,
                               atol=2e-5 * max(np.abs(a).max(), 1e-8),
                               err_msg=jax.tree_util.keystr(path))
print('MIXED_MULTISTAGE_OK', float(l0))
""",
        n_devices=2,
    )
    assert "MIXED_MULTISTAGE_OK" in out


def test_fill_drain_rejects_mixed_period():
    """The fill-drain reference stays period-1 only; mixed stacks must
    raise the redirect to the 1F1B schedule, not silently mis-stack."""
    from repro.core.pipeline import pipeline_loss_fn

    cfg = get_config("jamba-v0.1-52b").reduced()
    mesh = make_stage_mesh(1)
    with pytest.raises(AssertionError, match="1f1b"):
        pipeline_loss_fn(cfg, mesh, (cfg.num_layers,), 2)


def test_restack_unstack_roundtrip():
    """unstack_stage_grads inverts restack_for_stages for any split."""
    leaf = jnp.arange(5 * 3 * 2, dtype=jnp.float32).reshape(5, 3, 2)
    tree = {"w": leaf, "b": jnp.arange(5.0)}
    for bounds in [(5,), (2, 5), (1, 2, 5), (3, 4, 5)]:
        stacked = restack_for_stages(tree, bounds)
        s, max_len = len(bounds), max(stage_lengths(bounds))
        assert stacked["w"].shape == (s, max_len, 3, 2)
        back = unstack_stage_grads(stacked, bounds)
        for k in tree:
            np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(tree[k]))
