"""Scenario-parameter pytree tests: batch-of-1 vmap parity with the
unbatched path, padded-eavesdropper equivalence with a smaller env, and
the no-recompile guarantee across a parameter sweep."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.agents import rollout as R
from repro.core.agents import sac as SAC
from repro.core.channel import NetworkConfig
from repro.core.env import MHSLEnv
from repro.core.profiles import resnet101_profile
from repro.core import scenario as SC

QS = [0.3, 0.45, 0.6, 0.75, 0.9]


@pytest.fixture(scope="module")
def env():
    return MHSLEnv(profile=resnet101_profile(batch=1))


@pytest.fixture(scope="module")
def sac_setup(env):
    cfg = SAC.SACConfig(hidden=16, feat_dim=4, attn_dim=8)
    params = SAC.init_agent(jax.random.PRNGKey(0), env.obs_dim,
                            env.action_dims, cfg)
    return cfg, params, R.sac_policy(env.action_dims, cfg)


def test_scenario_matches_network_config(env):
    sp = env.scenario()
    assert sp.monitor_prob.shape == (env.E,)
    assert float(sp.monitor_prob[0]) == pytest.approx(env.net.monitor_prob)
    assert sp.power_levels.shape == (env.num_power_levels,)
    np.testing.assert_allclose(np.asarray(sp.power_levels),
                               env.net.power_levels)
    assert float(sp.noise_w) == pytest.approx(env.net.noise_w)
    assert float(sp.know_eave_locations) == 1.0
    blind = MHSLEnv(profile=env.profile, know_eave_locations=False)
    assert float(blind.scenario().know_eave_locations) == 0.0


def test_scenario_grid_and_stack(env):
    base = env.scenario()
    grid = SC.scenario_grid(base, monitor_prob=QS, gamma_e=[50.0, 75.0])
    assert len(grid) == len(QS) * 2
    # row-major kwargs order: monitor_prob outer, gamma_e inner
    assert float(grid[0].monitor_prob[0]) == pytest.approx(QS[0])
    assert float(grid[1].gamma_e) == 75.0
    stacked = SC.stack_scenarios(grid)
    assert SC.num_scenarios(stacked) == len(grid)
    assert stacked.monitor_prob.shape == (len(grid), env.E)
    with pytest.raises(ValueError):
        SC.stack_scenarios([])
    with pytest.raises(ValueError):
        SC.with_active_eaves(base, env.E + 1)


def test_default_scenario_step_bit_identical(env):
    """The explicit-scenario step reproduces the implicit-default step
    bit-for-bit (the refactor moved constants, not math)."""
    st = env.reset(jax.random.PRNGKey(0))
    a = {"u": jnp.asarray(0), "size": jnp.asarray(1),
         "decoys": jnp.zeros(env.U, jnp.int32),
         "p_tx": jnp.asarray(2), "p_d": jnp.asarray(1)}
    ks = jax.random.PRNGKey(3)
    st_a, r_a, d_a, _ = env.step(st, a, ks)
    st_b, r_b, d_b, _ = env.step(st, a, ks, env.scenario())
    assert float(r_a) == float(r_b)
    jax.tree.map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x),
                                                   np.asarray(y)),
        st_a, st_b,
    )


def test_population_batch_of_one_bit_identical(env, sac_setup):
    """A vmapped scenario batch of 1 reproduces the unbatched rollout
    engine bit-for-bit under the same PRNG keys."""
    cfg, params, policy = sac_setup
    n = 3
    rkeys = jax.random.split(jax.random.PRNGKey(2), n)
    akeys = jax.random.split(jax.random.PRNGKey(3), n)

    st0 = R.make_batched_reset(env)(rkeys)
    _, ref = R.make_batched_rollout(env, policy, cfg.hist_len)(
        params, st0, akeys)

    pop = SC.make_population_rollout(env, policy, cfg.hist_len)
    _, traj = pop(params, rkeys, akeys, SC.stack_scenarios([env.scenario()]))

    for field in ("obs", "reward", "leak", "action"):
        jax.tree.map(
            lambda a, b: np.testing.assert_array_equal(
                np.asarray(a)[0], np.asarray(b)),
            traj[field], ref[field],
        )


def test_padded_eavesdroppers_match_smaller_env():
    """An E=2 scenario padded to E_max=4 via ``eave_mask`` is
    bit-identical to a true E=2 env: identical leak and reward at every
    step under the same actions and keys (per-eavesdropper PRNG folding
    makes padding invisible to the active eavesdroppers)."""
    prof = resnet101_profile(batch=1)
    env4 = MHSLEnv(profile=prof, net=replace(NetworkConfig(), num_eaves=4))
    env2 = MHSLEnv(profile=prof, net=replace(NetworkConfig(), num_eaves=2))
    sp4 = SC.with_active_eaves(env4.scenario(), 2)

    st4 = env4.reset(jax.random.PRNGKey(0), sp4)
    st2 = env2.reset(jax.random.PRNGKey(0))
    # same geometry: E=2 env sees exactly the two active eavesdroppers
    st2 = st2._replace(dev_pos=st4.dev_pos, eav_pos=st4.eav_pos[:2])

    key = jax.random.PRNGKey(5)
    for _ in range(env4.episode_len):
        key, ka, ks = jax.random.split(key, 3)
        m = env4.action_masks(st4)
        a = {"u": jax.random.categorical(ka, jnp.where(m["u"], 0.0, -1e9)),
             "size": jnp.asarray(1), "decoys": m["decoys"].astype(jnp.int32),
             "p_tx": jnp.asarray(2), "p_d": jnp.asarray(3)}
        st4, r4, _, i4 = env4.step(st4, a, ks, sp4)
        st2, r2, _, i2 = env2.step(st2, a, ks)
        assert float(i4["leak"]) == float(i2["leak"])
        assert float(r4) == float(r2)
    assert float(st4.leaked) == float(st2.leaked)
    # padded eavesdroppers are invisible in the observation
    obs4 = env4.observe(st4, sp4)
    lm_start = 3 + 2 * (env4.U + 1)
    np.testing.assert_array_equal(
        np.asarray(obs4[lm_start + 2:lm_start + 4]), 0.0)


def test_monitor_prob_sweep_compiles_once(env, sac_setup):
    """The tentpole guarantee: a 5-point ``monitor_prob`` grid re-uses one
    compiled evaluation step - sequentially (same jit cache entry for
    every point) and stacked (one vmapped call)."""
    cfg, params, policy = sac_setup
    n = 2
    rkeys = jax.random.split(jax.random.PRNGKey(4), n)
    akeys = jax.random.split(jax.random.PRNGKey(5), n)

    # sequential sweep through one batched rollout: values change, the
    # compiled step does not
    rollout = R.make_batched_rollout(env, policy, cfg.hist_len)
    st0 = R.make_batched_reset(env)(rkeys)
    leaks = []
    for q in QS:
        sp = SC.replace_param(env.scenario(), "monitor_prob", q)
        _, traj = rollout(params, st0, akeys, sp)
        leaks.append(float(traj["leak"].sum()))
    assert rollout.trace_count[0] == 1
    assert SC.jit_cache_size(rollout) == 1
    assert len(set(leaks)) > 1  # the sweep actually changed the physics

    # stacked sweep through the population evaluator: one compile total
    ev = SC.make_population_evaluator(env, policy, cfg.hist_len)
    stacked = SC.stack_scenarios(
        SC.scenario_grid(env.scenario(), monitor_prob=QS))
    out = ev(params, rkeys, akeys, stacked)
    assert out["leak"].shape == (len(QS),)
    assert ev.trace_count[0] == 1
    assert SC.jit_cache_size(ev) == 1
    # more monitoring can never reduce expected leakage; check the
    # endpoints of the sampled sweep agree directionally
    assert float(out["leak"][-1]) >= float(out["leak"][0])


def test_evaluate_population_matches_evaluate_sac(env, sac_setup):
    """Batch-of-1 population evaluation reproduces ``evaluate_sac`` (same
    key derivation, same metrics)."""
    from repro.core.agents.loops import evaluate_sac

    cfg, params, policy = sac_setup
    ref = evaluate_sac(env, params, cfg, episodes=4, seed=77)
    got = SC.evaluate_population(
        env, policy, params, SC.stack_scenarios([env.scenario()]),
        episodes=4, seed=77, hist_len=cfg.hist_len)
    assert float(got["leak"][0]) == pytest.approx(ref["leak"], rel=1e-5)
    assert float(got["reward"][0]) == pytest.approx(ref["reward"], rel=1e-5)


def test_train_population_lockstep(env):
    """Two scenarios train in lockstep: full curves for each, finite
    metrics, per-scenario params stacked on the leading axis, and the
    physics axis actually differentiates the runs (blinded obs)."""
    cfg = SAC.SACConfig(hidden=16, feat_dim=4, attn_dim=8, batch=8,
                        buffer_size=300)
    scens = SC.stack_scenarios(
        SC.scenario_grid(env.scenario(), know_eave_locations=[1.0, 0.0]))
    pop = SC.train_population(env, cfg, scens, episodes=5,
                              warmup_episodes=2, num_envs=2)
    assert len(pop.results) == 2
    for res in pop.results:
        assert len(res.episode_reward) == 5
        assert all(np.isfinite(r) for r in res.episode_reward)
        assert res.states_explored == sorted(res.states_explored)
    assert jax.tree.leaves(pop.params)[0].shape[0] == 2
    with pytest.raises(ValueError, match="num_envs"):
        SC.train_population(env, cfg, scens, episodes=2, num_envs=0)


def test_optimal_powers_clamped_nonnegative():
    """Regression (Corollaries 1-2): a tight energy budget used to push
    the closed-form decoy power negative; both solutions must clamp to
    physical (non-negative) powers while keeping the energy identity."""
    from repro.core.leakage import (
        optimal_powers_single_decoy, optimal_powers_single_eave,
    )

    net = NetworkConfig()
    bits = jnp.asarray(2e7)  # heavy hop
    d_tx_rx = jnp.asarray(700.0)  # far receiver -> huge required SNR
    b_t, b_e = jnp.asarray(1.0), jnp.asarray(0.5)  # tight energy budget

    p_s, p_d = optimal_powers_single_decoy(
        bits, d_tx_rx, jnp.asarray(50.0), b_t, b_e, net)
    assert float(p_d) == 0.0  # clamped, not negative
    assert float(p_s) >= 0.0
    # energy identity still tight: p_s + p_d == B_E / B_T
    assert float(p_s + p_d) == pytest.approx(float(b_e / b_t), rel=1e-6)

    p_s2, p_d2 = optimal_powers_single_eave(
        bits, d_tx_rx, jnp.asarray([100.0, 300.0]), b_t, b_e, net)
    assert float(p_s2) >= 0.0
    assert np.all(np.asarray(p_d2) >= 0.0)
    assert float(p_s2 + p_d2.sum()) <= float(b_e / b_t) * (1 + 1e-6)

    # the untight regime is unchanged: interior solution, both positive
    p_s3, p_d3 = optimal_powers_single_decoy(
        jnp.asarray(2e6), jnp.asarray(150.0), jnp.asarray(200.0),
        jnp.asarray(1.5), jnp.asarray(3.0), net)
    assert float(p_s3) > 0 and float(p_d3) > 0
