"""Sharding rules + multi-device subprocess tests (pipeline, pjit train)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.distribution.sharding import spec_for_param
from jax.sharding import PartitionSpec as P


class FakeMesh:
    axis_names = ("data", "model")
    class devices:  # noqa: D106
        shape = (16, 16)


def test_spec_for_param_rules():
    cfg = get_config("qwen2.5-3b")
    mesh = FakeMesh()
    assert spec_for_param("embed", (cfg.vocab_size, cfg.d_model), cfg, mesh) == P("model", "data")
    assert spec_for_param("slots/0/attn/wq", (36, 2048, 2048), cfg, mesh) == P(None, "data", "model")
    assert spec_for_param("slots/0/attn/wo", (36, 2048, 2048), cfg, mesh) == P(None, "model", "data")
    assert spec_for_param("slots/0/norm1", (36, 2048), cfg, mesh) == P(None, None)
    # indivisible dims are not sharded
    assert spec_for_param("slots/0/attn/wq", (36, 100, 2048), cfg, mesh) == P(None, None, "model")
    # MoE experts on the model axis
    moe = get_config("qwen3-moe-30b-a3b")
    assert spec_for_param("slots/0/moe/w_up", (48, 128, 2048, 768), moe, mesh) == P(
        None, "model", "data", None
    )


def test_batch_axes_divisibility():
    from repro.distribution.sharding import batch_axes

    class M3:
        axis_names = ("pod", "data", "model")
        class devices:  # noqa: D106
            shape = (2, 16, 16)

    assert batch_axes(M3(), 256) == ("pod", "data")
    assert batch_axes(M3(), 2) == ("pod",)
    assert batch_axes(M3(), 1) is None
    assert batch_axes(FakeMesh(), 128) == ("data",)


def test_pipeline_matches_reference(subproc):
    out = subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import init_params, loss_fn
from repro.core.pipeline import pipeline_loss_fn, make_stage_mesh

cfg = get_config('stablelm-1.6b').reduced()
params = init_params(jax.random.PRNGKey(0), cfg)
mesh = make_stage_mesh(2)
rng = np.random.default_rng(0)
tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32)
labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32)
pl = pipeline_loss_fn(cfg, mesh, boundaries=(1, 2), n_microbatches=2)
loss_pipe = float(jax.jit(pl)(params, tokens, labels))
ref = float(loss_fn(params, {'tokens': tokens, 'labels': labels}, cfg, remat=False)[0])
assert abs(loss_pipe - ref) < 5e-3, (loss_pipe, ref)
g = jax.grad(lambda p: pl(p, tokens, labels))(params)
assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))
print('PIPELINE_OK', loss_pipe, ref)
""",
        n_devices=2,
    )
    assert "PIPELINE_OK" in out


def test_uneven_pipeline_split(subproc):
    """RL-style uneven split (3 stages of a 4-layer model: 2/1/1)."""
    out = subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from dataclasses import replace
from repro.configs import get_config
from repro.models import init_params, loss_fn
from repro.core.pipeline import pipeline_loss_fn, make_stage_mesh

cfg = replace(get_config('qwen2.5-3b').reduced(), num_layers=4)
params = init_params(jax.random.PRNGKey(0), cfg)
mesh = make_stage_mesh(3)
rng = np.random.default_rng(0)
tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (6, 16)), jnp.int32)
labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (6, 16)), jnp.int32)
pl = pipeline_loss_fn(cfg, mesh, boundaries=(2, 3, 4), n_microbatches=3)
loss_pipe = float(jax.jit(pl)(params, tokens, labels))
ref = float(loss_fn(params, {'tokens': tokens, 'labels': labels}, cfg, remat=False)[0])
assert abs(loss_pipe - ref) < 5e-3, (loss_pipe, ref)
print('UNEVEN_OK')
""",
        n_devices=3,
    )
    assert "UNEVEN_OK" in out


def test_sharded_train_step_runs(subproc):
    """pjit train step on a 2x2 host mesh with real (reduced) params."""
    out = subproc(
        """
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import init_params, make_train_step
from repro.distribution.sharding import param_shardings, batch_axes
from repro.distribution.context import activation_sharding
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
from repro.data import synthetic_batch

cfg = get_config('qwen3-moe-30b-a3b').reduced()
mesh = make_host_mesh(2, 2)
params = init_params(jax.random.PRNGKey(0), cfg)
psh = param_shardings(jax.eval_shape(lambda: params), cfg, mesh)
params = jax.tree.map(lambda a, s: jax.device_put(a, s), params, psh)
opt = adamw(1e-3)
ostate = opt.init(params)
osh = param_shardings(jax.eval_shape(lambda: ostate), cfg, mesh)
batch = synthetic_batch(cfg, 4, 32)
bsh = {k: NamedSharding(mesh, P('data', *([None]*(v.ndim-1)))) for k, v in batch.items()}
batch = jax.tree.map(lambda a, s: jax.device_put(a, s), batch, bsh)
step = jax.jit(make_train_step(cfg, opt), in_shardings=(psh, osh, bsh),
               out_shardings=(psh, osh, None))
with activation_sharding(mesh, ('data',)):
    p2, o2, m = step(params, ostate, batch)
assert bool(jnp.isfinite(m['loss'])), m
print('SHARDED_TRAIN_OK', float(m['loss']))
""",
        n_devices=4,
    )
    assert "SHARDED_TRAIN_OK" in out
