"""Optional-hypothesis shim for the property-based test modules.

``pytest.importorskip("hypothesis")`` at module scope would skip the whole
file, losing the plain unit tests that live alongside the property tests.
Instead the three property-test modules do::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, st

When hypothesis is missing, ``given`` marks just the property tests as
skipped while every other test in the module still collects and runs.
Install the real thing with ``pip install -r requirements-test.txt``.
"""
from __future__ import annotations

import pytest


class _Strategy:
    """Stand-in for any hypothesis strategy expression (built at module
    import time, e.g. ``st.floats(min_value=...)``); never actually drawn."""

    def __call__(self, *args, **kwargs):
        return self

    def __getattr__(self, name):
        return self


st = _Strategy()


def settings(*args, **kwargs):
    """No-op ``@settings`` decorator."""

    def deco(fn):
        return fn

    return deco


def given(*args, **kwargs):
    """Replace the property test with a skip marker."""

    def deco(fn):
        return pytest.mark.skip(
            reason="hypothesis not installed (see requirements-test.txt)"
        )(fn)

    return deco
