"""record_baseline write-once semantics (benchmarks/common.py).

The throughput baseline is an append-only ledger: a benchmark may
backfill NEW metric keys but must never silently clobber a recorded
number - refreshing requires the explicit --force / force=True or the
BENCH_THROUGHPUT_REFRESH=1 escape hatch.
"""
import json
import sys

import pytest

sys.path.insert(0, ".")  # benchmarks/ is a top-level package dir
from benchmarks import common  # noqa: E402


@pytest.fixture()
def baseline(tmp_path, monkeypatch):
    path = tmp_path / "BENCH_throughput.json"
    monkeypatch.setattr(common, "BASELINE_PATH", str(path))
    monkeypatch.delenv("BENCH_THROUGHPUT_REFRESH", raising=False)
    return path


def _read(path):
    with open(path) as f:
        return json.load(f)


def test_first_write_and_refusal(baseline, capsys):
    written = common.record_baseline({"a": 1.0, "b": {"x": 2}})
    assert sorted(written) == ["a", "b"]
    assert _read(baseline) == {"a": 1.0, "b": {"x": 2}}

    # second write: existing keys refused, file untouched, new key merged
    written = common.record_baseline({"a": 99.0, "c": 3.0})
    assert written == ["c"]
    assert _read(baseline)["a"] == 1.0
    assert _read(baseline)["c"] == 3.0
    err = capsys.readouterr().err
    assert "refusing to overwrite" in err and "'a'" in err


def test_force_overwrites_only_callers_keys(baseline):
    common.record_baseline({"a": 1.0, "other": 7.0})
    written = common.record_baseline({"a": 42.0}, force=True)
    assert written == ["a"]
    data = _read(baseline)
    assert data["a"] == 42.0
    assert data["other"] == 7.0  # untouched entries preserved


def test_refresh_env_var(baseline, monkeypatch):
    common.record_baseline({"a": 1.0})
    monkeypatch.setenv("BENCH_THROUGHPUT_REFRESH", "1")
    assert common.record_baseline({"a": 5.0}) == ["a"]
    assert _read(baseline)["a"] == 5.0


def test_noop_returns_empty(baseline):
    common.record_baseline({"a": 1.0})
    assert common.record_baseline({"a": 2.0}) == []
    assert _read(baseline) == {"a": 1.0}


# ---------------------------------------------------------------------------
# run.py --only selection semantics


def test_only_selection_exact_or_prefix():
    """``--only`` matches exact names or explicit ``name_`` prefixes -
    ``fig1`` must NOT silently swallow ``fig10_leakage_attack``, and an
    entry matching nothing is an error, not an empty run."""
    from benchmarks.run import ALL, select

    assert select(ALL, "fig10") == ["fig10_leakage_attack"]
    assert select(ALL, "pipeline") == ["pipeline"]
    assert select(ALL, "moe_dispatch,zoo_plan_scoring") == [
        "moe_dispatch", "zoo_plan_scoring"]
    # list-order output regardless of spec order; duplicates collapse
    assert select(ALL, "serving,pipeline,serving") == ["pipeline", "serving"]
    with pytest.raises(SystemExit):
        select(ALL, "fig1")  # prefix of fig10_... but not an explicit one
    with pytest.raises(SystemExit):
        select(ALL, "nope")
    assert "moe_dispatch" in ALL and "zoo_plan_scoring" in ALL
