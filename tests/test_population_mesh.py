"""Mesh-sharded population training + checkpoint/resume (pinned parity).

Three contracts from the sharding layer:

* a 1-device population mesh is BIT-IDENTICAL to the plain vmap path
  (``train_sac`` and ``train_population``) - the mesh only places data;
* a multi-device mesh sharding the scenario axis keeps per-scenario math
  on one device, so even the 4-way-sharded population matches the vmap
  path exactly (subprocess with forced host devices);
* stopping at a checkpoint and resuming replays the exact episode-reward
  trajectory of an uninterrupted run (``train_sac`` and
  ``train_population``, including a sharded resume).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.train_state import (
    latest_checkpoint_step,
    load_train_checkpoint,
    save_train_checkpoint,
)
from repro.core.agents.loops import train_sac
from repro.core.agents.sac import SACConfig
from repro.core.env import MHSLEnv
from repro.core.profiles import resnet101_profile
from repro.core.scenario import (
    scenario_grid,
    stack_scenarios,
    train_population,
)
from repro.launch.mesh import make_population_mesh


@pytest.fixture(scope="module")
def env():
    return MHSLEnv(profile=resnet101_profile(batch=1))


def _trees_equal(a, b) -> bool:
    return all(
        bool(jnp.array_equal(x, y))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def test_train_sac_one_device_mesh_bit_identical(env):
    cfg = SACConfig()
    kw = dict(episodes=10, warmup_episodes=4, seed=5, num_envs=2)
    ref = train_sac(env, cfg, **kw)
    mesh = train_sac(env, cfg, mesh=make_population_mesh(1), **kw)
    assert mesh.episode_reward == ref.episode_reward
    assert mesh.episode_leak == ref.episode_leak
    assert mesh.states_explored == ref.states_explored
    assert _trees_equal(mesh.params, ref.params)


def test_train_population_one_device_mesh_bit_identical(env):
    cfg = SACConfig()
    scens = stack_scenarios(
        scenario_grid(env.scenario(), monitor_prob=[0.3, 0.8])
    )
    kw = dict(episodes=8, warmup_episodes=3, seed=5, num_envs=2)
    ref = train_population(env, cfg, scens, **kw)
    mesh = train_population(env, cfg, scens,
                            mesh=make_population_mesh(1), **kw)
    for s in range(2):
        assert mesh.results[s].episode_reward == ref.results[s].episode_reward
        assert mesh.results[s].episode_leak == ref.results[s].episode_leak
    assert _trees_equal(mesh.params, ref.params)


def test_sharded_population_multi_device_parity(subproc):
    """4-way scenario sharding matches the single-device vmap path exactly:
    each scenario's computation stays whole on its shard."""
    out = subproc(
        """
import jax
from repro.core.agents.sac import SACConfig
from repro.core.env import MHSLEnv
from repro.core.profiles import resnet101_profile
from repro.core.scenario import scenario_grid, stack_scenarios, train_population
from repro.launch.mesh import make_population_mesh

env = MHSLEnv(profile=resnet101_profile(batch=1))
cfg = SACConfig()
scens = stack_scenarios(scenario_grid(env.scenario(),
                                      monitor_prob=[0.3, 0.5, 0.7, 0.9]))
kw = dict(episodes=8, warmup_episodes=3, seed=5, num_envs=2)
ref = train_population(env, cfg, scens, **kw)
mesh = make_population_mesh(4)
shd = train_population(env, cfg, scens, mesh=mesh, **kw)
leaf = jax.tree.leaves(shd.params)[0]
assert "env" in leaf.sharding.mesh.axis_names, leaf.sharding
for s in range(4):
    assert shd.results[s].episode_reward == ref.results[s].episode_reward, s
    assert shd.results[s].episode_leak == ref.results[s].episode_leak, s
print('SHARDED_POPULATION_OK')
""",
        n_devices=4,
    )
    assert "SHARDED_POPULATION_OK" in out


def test_pipeline_on_stage_env_mesh_parity(subproc):
    """2-D (stage x env) mesh: the split executor runs pipelined stage
    compute with the microbatch rows sharded over the env axis, matching
    the 1-D stage mesh at f32 tolerance (pmean-of-means reassociation)."""
    out = subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from dataclasses import replace
from jax.sharding import PartitionSpec as P
from repro.configs import get_config
from repro.models import init_params
from repro.core.pipeline import PipelineConfig, make_stage_mesh, pipeline_step_fn
from repro.launch.mesh import make_stage_env_mesh
from repro.distribution.sharding import (
    microbatch_sharding, population_axes, stage_sharding,
)

cfg = replace(get_config('qwen2.5-3b').reduced(), num_layers=4)
params = init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
m = 2
tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (m * 4, 16)), jnp.int32)
labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (m * 4, 16)), jnp.int32)
bounds = (1, 4)
pipe = PipelineConfig(compute_dtype='float32')
l0, g0 = jax.jit(pipeline_step_fn(cfg, make_stage_mesh(2), bounds, m,
                                  pipe=pipe))(params, tokens, labels)

mesh2 = make_stage_env_mesh(2, 2)
assert mesh2.devices.shape == (2, 2)
assert mesh2.axis_names == ('stage', 'env')
assert population_axes(mesh2, 2) == 'env'  # train_population picks env by name
assert microbatch_sharding(mesh2, 3).spec == P(None, 'env', None)
assert stage_sharding(mesh2, 2).spec == P('stage', None)
l1, g1 = jax.jit(pipeline_step_fn(cfg, mesh2, bounds, m, pipe=pipe,
                                  env_axis='env'))(params, tokens, labels)
assert abs(float(l0) - float(l1)) <= 1e-6 * abs(float(l0)), (float(l0), float(l1))
for (path, a), (_, b) in zip(jax.tree_util.tree_flatten_with_path(g0)[0],
                             jax.tree_util.tree_flatten_with_path(g1)[0]):
    a = np.asarray(a, np.float64); b = np.asarray(b, np.float64)
    np.testing.assert_allclose(b, a, rtol=1e-5,
                               atol=1e-5 * max(np.abs(a).max(), 1e-8),
                               err_msg=jax.tree_util.keystr(path))
print('STAGE_ENV_PIPELINE_OK', float(l0))
""",
        n_devices=4,
    )
    assert "STAGE_ENV_PIPELINE_OK" in out


def test_train_population_on_stage_env_mesh(subproc):
    """train_population drives the 2-D stage x env mesh unchanged: the
    scenario axis shards over 'env' (picked by name), stage rows stay
    replicated, and the results match the vmap path exactly."""
    out = subproc(
        """
import jax
from repro.core.agents.sac import SACConfig
from repro.core.env import MHSLEnv
from repro.core.profiles import resnet101_profile
from repro.core.scenario import scenario_grid, stack_scenarios, train_population
from repro.launch.mesh import make_stage_env_mesh

env = MHSLEnv(profile=resnet101_profile(batch=1))
cfg = SACConfig()
scens = stack_scenarios(scenario_grid(env.scenario(), monitor_prob=[0.3, 0.8]))
kw = dict(episodes=6, warmup_episodes=3, seed=5, num_envs=2)
ref = train_population(env, cfg, scens, **kw)
mesh = make_stage_env_mesh(2, 2)
shd = train_population(env, cfg, scens, mesh=mesh, **kw)
leaf = jax.tree.leaves(shd.params)[0]
assert "env" in leaf.sharding.mesh.axis_names, leaf.sharding
for s in range(2):
    assert shd.results[s].episode_reward == ref.results[s].episode_reward, s
    assert shd.results[s].episode_leak == ref.results[s].episode_leak, s
print('STAGE_ENV_POPULATION_OK')
""",
        n_devices=4,
        timeout=600,
    )
    assert "STAGE_ENV_POPULATION_OK" in out


def test_train_sac_checkpoint_resume_bit_identical(env, tmp_path):
    """Save mid-training, resume, and the episode-reward trajectory is
    bit-identical to an uninterrupted run (the paper's long population
    studies can stop/restart without perturbing the curves)."""
    cfg = SACConfig()
    kw = dict(warmup_episodes=4, seed=5, num_envs=2)
    ref = train_sac(env, cfg, episodes=12, **kw)

    ck = os.fspath(tmp_path / "sac")
    part = train_sac(env, cfg, episodes=6, checkpoint_dir=ck,
                     checkpoint_every=2, **kw)
    assert part.episode_reward == ref.episode_reward[:6]
    assert latest_checkpoint_step(ck) == 6

    res = train_sac(env, cfg, episodes=12, checkpoint_dir=ck,
                    checkpoint_every=4, **kw)
    assert res.episode_reward == ref.episode_reward
    assert res.episode_leak == ref.episode_leak
    assert res.episode_violation == ref.episode_violation
    assert res.states_explored == ref.states_explored
    assert _trees_equal(res.params, ref.params)
    # the finished run saved its final state too
    assert latest_checkpoint_step(ck) == 12


def test_train_population_checkpoint_resume_bit_identical(env, tmp_path):
    cfg = SACConfig()
    scens = stack_scenarios(
        scenario_grid(env.scenario(), know_eave_locations=[1.0, 0.0])
    )
    kw = dict(warmup_episodes=3, seed=5, num_envs=2)
    ref = train_population(env, cfg, scens, episodes=8, **kw)

    ck = os.fspath(tmp_path / "pop")
    train_population(env, cfg, scens, episodes=4, checkpoint_dir=ck,
                     checkpoint_every=2, **kw)
    res = train_population(env, cfg, scens, episodes=8, checkpoint_dir=ck,
                           checkpoint_every=2, **kw)
    for s in range(2):
        assert res.results[s].episode_reward == ref.results[s].episode_reward
        assert res.results[s].states_explored == ref.results[s].states_explored
    assert _trees_equal(res.params, ref.params)


def test_resume_rejects_mismatched_run(env, tmp_path):
    """A checkpoint written under different loop knobs (seed here) must be
    a hard error, not a silent resume of someone else's trajectory."""
    cfg = SACConfig()
    ck = os.fspath(tmp_path / "sac")
    train_sac(env, cfg, episodes=4, warmup_episodes=2, seed=5, num_envs=2,
              checkpoint_dir=ck, checkpoint_every=2)
    with pytest.raises(ValueError, match="cannot resume"):
        train_sac(env, cfg, episodes=8, warmup_episodes=2, seed=6,
                  num_envs=2, checkpoint_dir=ck)
    with pytest.raises(ValueError, match="past the requested"):
        train_sac(env, cfg, episodes=2, warmup_episodes=2, seed=5,
                  num_envs=2, checkpoint_dir=ck)


def test_orphan_checkpoint_ignored(tmp_path):
    """An npz without its json (crash between the two writes) is not
    offered for resume."""
    state = {"a": jnp.zeros((2,))}
    d = os.fspath(tmp_path / "ck")
    save_train_checkpoint(d, 2, state, {"ep": 2})
    # simulate a crash mid-write of step 4: npz lands, json does not
    os.replace(os.path.join(d, "step_00000002.npz"),
               os.path.join(d, "step_00000004.npz"))
    os.remove(os.path.join(d, "LATEST"))
    assert latest_checkpoint_step(d) is None
    save_train_checkpoint(d, 6, state, {"ep": 6})
    assert latest_checkpoint_step(d) == 6


def test_checkpoint_store_roundtrip(tmp_path):
    """Unit-level: save/load with LATEST bookkeeping and sharding-aware
    restore onto a 1-device mesh placement."""
    from repro.distribution import population as PD

    state = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(3, 2),
        "b": {"k": jax.random.PRNGKey(7)},
    }
    host = {"ep": 4, "curve": [1.0, 2.0], "seen": [3, 9]}
    d = os.fspath(tmp_path / "ck")
    assert latest_checkpoint_step(d) is None
    save_train_checkpoint(d, 2, state, host)
    save_train_checkpoint(d, 4, state, host)
    assert latest_checkpoint_step(d) == 4

    mesh = make_population_mesh(1)
    like = PD.shard_population(state, mesh, 3)
    step, dev, h = load_train_checkpoint(d, like)
    assert step == 4 and h["ep"] == 4 and h["seen"] == [3, 9]
    assert _trees_equal(dev, state)
    assert np.asarray(dev["b"]["k"]).dtype == np.uint32
