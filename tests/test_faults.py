"""Fault schedule semantics + the Eq. 10 consistency of faulted costs.

The contracts pinned here:

* schedules are REPLAYABLE: same key -> bit-identical schedule; queries
  are pure and respect half-open windows;
* ``degrade_scenario`` with a ``fault_free`` schedule is a bit-exact
  no-op, and fault injection never retraces the plan scorer (the
  schedule is a runtime pytree, same contract as ``ScenarioParams``);
* the faulted transport model at M=1 sync equals ``plan_cost`` under
  the DEGRADED scenario to 1e-12 - the executor's delay accounting
  under partial outage and the Eq. 10 oracle are the same number;
* the split oracle's ``device_mask`` marks exactly the plans touching a
  down device infeasible, and the replanner's ``exclude_devices`` path
  equals fresh scoring over the surviving-device plan set through ONE
  compiled trace.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import faults as F
from repro.core.channel import NetworkConfig
from repro.core.env import MHSLEnv
from repro.core.profiles import resnet101_profile
from repro.core.scenario import scenario_from_net
from repro.core.splitting import (SplitPlan, make_plan_scorer, plan_cost,
                                  plan_devices_up)
from repro.core.transport import (faulted_transport_model,
                                  plan_transport_model, simulate_1f1b,
                                  simulate_1f1b_faulted)


@pytest.fixture(scope="module")
def env():
    return MHSLEnv(profile=resnet101_profile(batch=1))


def _setup(s, *, num_devices=8):
    net = NetworkConfig(num_devices=num_devices, max_split=max(s, 4),
                        hop_bandwidth=tuple(1e6 / (k + 1)
                                            for k in range(max(s, 4) - 1)),
                        hop_latency=1e-3)
    prof = resnet101_profile(batch=1)
    rng = np.random.default_rng(0)
    pos = rng.uniform(0, net.area_m, (net.num_devices + 1, 2))
    devices = tuple(range(s - 1)) + (net.num_devices,)
    bounds = tuple(int(b) for b in np.linspace(4, prof.num_layers, s))
    plan = SplitPlan(bounds, devices)
    p_tx = np.full(s - 1, 0.5)
    decoy = np.zeros((s - 1, net.num_devices + 1))
    decoy[:, -1] = 0.1
    return prof, plan, pos, p_tx, decoy, net


# ---------------------------------------------------------------------------
# schedule construction + replay


def test_sampled_schedule_is_replayable():
    kw = dict(num_devices=5, num_hops=3, horizon_s=2.0, num_windows=2,
              outage_prob=0.5, outage_len_s=(0.1, 0.4),
              bandwidth_scale=(0.5, 0.9), slowdown=(1.0, 2.0))
    a = F.sample_fault_schedule(jax.random.PRNGKey(7), **kw)
    b = F.sample_fault_schedule(jax.random.PRNGKey(7), **kw)
    c = F.sample_fault_schedule(jax.random.PRNGKey(8), **kw)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert jnp.array_equal(x, y)
    assert any(not jnp.array_equal(x, y)
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(c)))
    assert a.num_devices == 5 and a.num_hops == 3 and a.num_windows == 2


def test_make_schedule_validation():
    with pytest.raises(ValueError, match="not in"):
        F.make_schedule(2, 1, outages=[(5, 0.0, 1.0)])
    with pytest.raises(ValueError, match="empty"):
        F.make_schedule(2, 1, outages=[(0, 1.0, 1.0)])
    with pytest.raises(ValueError, match="num_windows"):
        F.make_schedule(2, 1, outages=[(0, 0.0, 1.0), (0, 2.0, 3.0)],
                        num_windows=1)


def test_device_up_half_open_windows_and_recovery():
    s = F.make_schedule(3, 2, outages=[(0, 1.0, 2.0), (0, 3.0, 4.0),
                                       (1, 1.5, 2.5)])
    up = lambda t: np.asarray(F.device_up(s, t))
    assert up(0.99).tolist() == [True, True, True]
    assert up(1.0).tolist() == [False, True, True]    # start is inclusive
    assert up(1.75).tolist() == [False, False, True]
    assert up(2.0).tolist() == [True, False, True]    # end is exclusive
    assert up(3.5).tolist() == [False, True, True]    # second window
    # recovery: max over covering windows' ends, identity when all up
    assert float(F.next_recovery(s, 1.75, np.array([0, 1]))) == 2.5
    assert float(F.next_recovery(s, 0.5, np.array([0, 1]))) == 0.5
    assert float(F.outage_stall(s, 1.0, np.array([0]))) == pytest.approx(1.0)
    assert float(F.outage_stall(s, 0.0, np.array([2]))) == 0.0


def test_fault_clock_mapping():
    tickc = F.FaultClock(tick_seconds=0.02)
    assert tickc.time_of(5, now=99.0) == pytest.approx(0.1)
    assert tickc.ticks_until(0.08, 0.18) == 5
    assert tickc.ticks_until(0.08, 0.08) == 1   # always progress
    wallc = F.FaultClock()
    assert wallc.time_of(5, now=99.0) == 99.0
    assert wallc.ticks_until(0.0, 10.0) == 1


# ---------------------------------------------------------------------------
# scenario degradation


def test_degrade_fault_free_is_bit_exact_noop(env):
    sp = env._params(None)
    sched = F.fault_free(env.U + 1, env.S - 1)
    sp2 = F.degrade_scenario(sp, sched)
    for a, b in zip(jax.tree.leaves(sp), jax.tree.leaves(sp2)):
        assert jnp.array_equal(a, b)


def test_degrade_scenario_hop_count_mismatch(env):
    sp = env._params(None)
    with pytest.raises(ValueError, match="hops"):
        F.degrade_scenario(sp, F.fault_free(env.U + 1, env.S))


def test_degrade_scenario_scales_links(env):
    sp = env._params(None)
    h = env.S - 1
    sched = F.make_schedule(env.U + 1, h,
                            hop_bandwidth_scale=[0.5] * h,
                            hop_latency_add_s=[1e-3] * h)
    sp2 = F.degrade_scenario(sp, sched)
    np.testing.assert_allclose(np.asarray(sp2.hop_bandwidth_hz),
                               np.asarray(sp.hop_bandwidth_hz) * 0.5)
    np.testing.assert_allclose(np.asarray(sp2.hop_latency_s),
                               np.asarray(sp.hop_latency_s) + 1e-3,
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# Eq. 10 consistency of the faulted executor accounting


@pytest.mark.parametrize("s", [2, 4])
def test_faulted_m1_sync_matches_plan_cost_under_degraded_scenario(s):
    """The faulted transport model's M=1 synchronous wall time equals the
    Eq. 10 delay computed from the DEGRADED scenario - the executor and
    the plan oracle price a partial outage identically."""
    prof, plan, pos, p_tx, decoy, net = _setup(s)
    sp = scenario_from_net(net)
    sched = F.make_schedule(
        net.num_devices + 1, max(s, 4) - 1,
        hop_bandwidth_scale=[0.7] * (max(s, 4) - 1),
        hop_latency_add_s=[2e-3] * (max(s, 4) - 1))
    t_ref, _ = plan_cost(prof, plan, pos, p_tx, decoy,
                         F.degrade_scenario(sp, sched))
    model = faulted_transport_model(prof, plan, pos, p_tx, decoy, sp, sched)
    sim = simulate_1f1b(model, 1, transport="sync")
    np.testing.assert_allclose(sim["total_s"], float(t_ref), rtol=1e-12)


def test_faulted_model_fault_free_is_exact(env):
    prof, plan, pos, p_tx, decoy, net = _setup(4)
    sp = scenario_from_net(net)
    sched = F.fault_free(net.num_devices + 1, 3)
    base = plan_transport_model(prof, plan, pos, p_tx, decoy, sp)
    faulted = faulted_transport_model(prof, plan, pos, p_tx, decoy, sp, sched)
    for f in ("t_comp_fwd", "t_comp_bwd", "t_tx_fwd", "t_tx_bwd",
              "hop_latency"):
        np.testing.assert_array_equal(getattr(base, f), getattr(faulted, f))
    # the faulted simulator under fault_free reproduces the base one
    a = simulate_1f1b(base, 4)
    b = simulate_1f1b_faulted(base, 4, sched, plan.devices)
    assert b["total_s"] == a["total_s"] and b["stall_s"] == 0.0


def test_straggler_scales_assigned_stage_compute():
    prof, plan, pos, p_tx, decoy, net = _setup(4)
    sp = scenario_from_net(net)
    slow = [1.0] * (net.num_devices + 1)
    slow[plan.devices[1]] = 3.0   # stage 1's device straggles
    sched = F.make_schedule(net.num_devices + 1, 3, compute_slowdown=slow)
    base = plan_transport_model(prof, plan, pos, p_tx, decoy, sp)
    faulted = faulted_transport_model(prof, plan, pos, p_tx, decoy, sp, sched)
    np.testing.assert_allclose(faulted.t_comp_fwd[1], base.t_comp_fwd[1] * 3.0)
    np.testing.assert_array_equal(faulted.t_comp_fwd[[0, 2, 3]],
                                  base.t_comp_fwd[[0, 2, 3]])
    np.testing.assert_array_equal(faulted.t_tx_fwd, base.t_tx_fwd)


def test_outage_stalls_add_exactly():
    """An outage covering a mid-schedule tick stalls it to the window's
    end; total = fault-free total + stall."""
    prof, plan, pos, p_tx, decoy, net = _setup(3)
    sp = scenario_from_net(net)
    model = plan_transport_model(prof, plan, pos, p_tx, decoy, sp)
    base = simulate_1f1b(model, 2, transport="sync")
    per = np.asarray(base["per_tick_s"])
    # window opening exactly at tick 1's start, on stage 0's device
    t1 = float(per[0])
    sched = F.make_schedule(net.num_devices + 1, 2,
                            outages=[(plan.devices[0], t1, t1 + 0.5)])
    sim = simulate_1f1b_faulted(model, 2, sched, plan.devices,
                                transport="sync")
    np.testing.assert_allclose(sim["per_tick_stall_s"][1], 0.5, rtol=1e-9)
    np.testing.assert_allclose(sim["stall_s"], 0.5, rtol=1e-9)
    np.testing.assert_allclose(sim["total_s"], base["total_s"] + 0.5,
                               rtol=1e-9)


# ---------------------------------------------------------------------------
# zero-retrace fault injection + the oracle's device mask


def test_fault_injection_adds_zero_retraces(env):
    """Scoring under N different fault schedules (including stragglers
    and link degradation) reuses ONE compiled scorer trace."""
    oracle = env.make_split_oracle()
    key = jax.random.PRNGKey(0)
    state = env.reset(key, None)
    devices = jnp.asarray(tuple(range(env.S - 1)) + (env.U,), jnp.int32)
    p_tx = jnp.full((env.S - 1,), env._params(None).power_levels[0])
    decoy = jnp.zeros((env.S - 1, env.U + 1))
    sp = env._params(None)
    outs = []
    for i in range(4):
        sched = F.sample_fault_schedule(
            jax.random.PRNGKey(i), env.U + 1, env.S - 1, horizon_s=1.0,
            bandwidth_scale=(0.4, 1.0), slowdown=(1.0, 2.0))
        mask = F.device_up(sched, 0.0)
        outs.append(oracle(state.dev_pos, devices, p_tx, decoy,
                           F.degrade_scenario(sp, sched), device_mask=mask))
    assert oracle.trace_count[0] == 1
    # degradation is a real input: at least one sweep point moved delay
    d0 = np.asarray(outs[0]["delay"])
    assert any(not np.array_equal(np.asarray(o["delay"]), d0)
               for o in outs[1:])


def test_plan_devices_up_and_oracle_mask(env):
    mask = np.ones(env.U + 1, bool)
    mask[1] = False
    up = plan_devices_up(np.asarray([[0, 1, 6], [0, 2, 6], [2, 3, 4]]),
                         mask)
    assert np.asarray(up).tolist() == [False, True, True]
    # oracle: masking a device used by the canonical assignment kills
    # every plan; masking an unused device changes nothing
    oracle = env.make_split_oracle()
    state = env.reset(jax.random.PRNGKey(0), None)
    devices = jnp.asarray(tuple(range(env.S - 1)) + (env.U,), jnp.int32)
    p_tx = jnp.full((env.S - 1,), env._params(None).power_levels[0])
    decoy = jnp.zeros((env.S - 1, env.U + 1))
    base = oracle(state.dev_pos, devices, p_tx, decoy)
    unused = np.ones(env.U + 1, bool)
    unused[env.S] = False   # not in the canonical assignment
    same = oracle(state.dev_pos, devices, p_tx, decoy, device_mask=unused)
    assert jnp.array_equal(base["feasible"], same["feasible"])
    dead = np.ones(env.U + 1, bool)
    dead[0] = False
    out = oracle(state.dev_pos, devices, p_tx, decoy, device_mask=dead)
    assert not bool(np.asarray(out["feasible"]).any())
    assert oracle.trace_count[0] == 1


def test_masked_replan_equals_fresh_scoring_one_trace(env):
    """The acceptance-criterion proof: a replan excluding a dead device
    equals an independent fresh scoring pass over the surviving-device
    plan set (every rotation assignment not touching the dead device),
    through ONE compiled trace."""
    from repro.serving import OnlineReplanner

    rp = OnlineReplanner(env, candidate_assignments="rotations")
    dead = 0
    dec = rp.replan(load=0.3, exclude_devices=[dead])
    assert rp.trace_count[0] == 1
    assert dead not in dec["devices"]
    assert dec["excluded"] == (dead,)

    # fresh scoring over the masked plan set, independent oracle
    fresh = env.make_split_oracle()
    sp = rp.shifted_scenario(0.3)
    mask = np.ones(env.U + 1, bool)
    mask[dead] = False
    best_key, best = np.inf, None
    surviving = [a for a in rp.assignments if dead not in a]
    assert surviving and len(surviving) < len(rp.assignments)
    n_plans = 0
    for assign in surviving:
        out = fresh(rp.dev_pos, jnp.asarray(assign, jnp.int32), rp.p_tx,
                    rp.decoy_power, sp, device_mask=jnp.asarray(mask))
        delay = np.asarray(out["delay"])
        feas = np.asarray(out["feasible"])
        n_plans += len(delay)
        masked = np.where(feas, delay, np.inf)
        i = int(np.argmin(masked))
        if masked[i] < best_key or best is None:
            best_key = masked[i]
            best = (tuple(int(b)
                          for b in np.asarray(out["boundaries"])[i]),
                    assign, float(delay[i]))
    assert dec["num_plans"] == n_plans
    assert dec["boundaries"] == best[0]
    assert dec["devices"] == best[1]
    assert dec["delay"] == best[2]


def test_replan_default_assignment_unchanged(env):
    """Back-compat: the default replanner (no candidate assignments, no
    exclusion) produces the same decision record as before plus the new
    bookkeeping fields."""
    from repro.serving import OnlineReplanner

    rp = OnlineReplanner(env)
    dec = rp.replan(load=0.2)
    assert dec["devices"] == tuple(range(env.S - 1)) + (env.U,)
    assert dec["excluded"] == ()
    fresh = env.make_split_oracle()
    out = fresh(rp.dev_pos, rp.devices, rp.p_tx, rp.decoy_power,
                rp.shifted_scenario(0.2))
    delay = np.asarray(out["delay"])
    feas = np.asarray(out["feasible"])
    i = int(np.argmin(np.where(feas, delay, np.inf)))
    assert dec["boundaries"] == tuple(
        int(b) for b in np.asarray(out["boundaries"])[i])
    assert dec["num_plans"] == len(delay)
