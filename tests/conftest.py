import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
sys.path.insert(0, SRC)


def pytest_configure(config):
    # Known-failure sets live IN-REPO as markers (not as hand-curated
    # --deselect lists in the CI workflow), so the marked set shrinks in the
    # same commit that fixes a subsystem. The historical ``seed_broken``
    # marker (seed-era shard_map/jax-version breakage) emptied out and its
    # plumbing is gone; the CI gate runs the plain suite.
    config.addinivalue_line(
        "markers",
        "jamba_decode: tracks jamba greedy-decode vs teacher-forced-forward "
        "agreement. RETIRED as an xfail: dropless MoE dispatch (the "
        "default) computes every routed token, so a token's output no "
        "longer depends on its dispatch-group size and decode matches the "
        "forward - the test must now PASS (see test_models_smoke.py)",
    )


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 420) -> str:
    """Run a python snippet in a subprocess with a forced host device count.

    Tests in THIS process keep the default single device (per the dry-run
    contract); multi-device behaviour is exercised in clean subprocesses.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
        cwd=REPO,
    )
    assert out.returncode == 0, f"subprocess failed:\nSTDOUT:{out.stdout}\nSTDERR:{out.stderr[-3000:]}"
    return out.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_with_devices
