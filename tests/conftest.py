import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
sys.path.insert(0, SRC)


def pytest_configure(config):
    # The known-failure set lives IN-REPO as a marker (not as a hand-curated
    # --deselect list in the CI workflow): the CI gate runs
    # ``-m "not seed_broken"`` and the marked set shrinks as subsystems get
    # fixed. A full local ``pytest`` run still executes the marked tests.
    config.addinivalue_line(
        "markers",
        "seed_broken: failing since the repo seed (shard_map/jax-version "
        "breakage in subsystems untouched since then); excluded from the CI "
        "gate - remove the mark when the subsystem is fixed. The set is "
        "currently EMPTY: the last member (jamba decode) was diagnosed as "
        "structural MoE capacity-dropping and split into the jamba_decode "
        "xfail",
    )
    config.addinivalue_line(
        "markers",
        "jamba_decode: jamba greedy decode drifts from the teacher-forced "
        "forward because capacity-bounded MoE token-dropping depends on the "
        "dispatch-group token count (see test_models_smoke.py); xfail'd, "
        "with the dropless companion test pinning the SSM/attention cache "
        "handoff itself as exact",
    )


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 420) -> str:
    """Run a python snippet in a subprocess with a forced host device count.

    Tests in THIS process keep the default single device (per the dry-run
    contract); multi-device behaviour is exercised in clean subprocesses.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
        cwd=REPO,
    )
    assert out.returncode == 0, f"subprocess failed:\nSTDOUT:{out.stdout}\nSTDERR:{out.stderr[-3000:]}"
    return out.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_with_devices
