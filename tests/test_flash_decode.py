"""Distributed flash-decoding (shard_map over length-sharded KV caches).

This is the SPerf pair-3 optimization (176x collective reduction on
qwen3-moe-30b decode_32k); exactness vs the dense oracle is load-bearing.
"""


def test_flash_decode_exact(subproc):
    out = subproc(
        """
import jax, jax.numpy as jnp
from repro.launch.mesh import make_host_mesh
from repro.distribution.context import activation_sharding
from repro.models.flash_decode import flash_decode
from repro.kernels.ref import flash_attention_ref

mesh = make_host_mesh(2, 2)
B, L, H, KH, hd = 4, 32, 4, 2, 16
ks = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(ks[0], (B,1,H,hd))
ck = jax.random.normal(ks[1], (B,L,KH,hd))
cv = jax.random.normal(ks[2], (B,L,KH,hd))
for idx in (0, 7, 19, 31):
    with activation_sharding(mesh, ('data',)):
        out = jax.jit(lambda q,k,v,i: flash_decode(q,k,v,i))(q, ck, cv, jnp.array(idx))
    ref = flash_attention_ref(q, ck[:, :idx+1], cv[:, :idx+1], causal=True, q_offset=idx)
    err = float(jnp.abs(out - ref).max())
    assert err < 1e-5, (idx, err)
    with activation_sharding(mesh, ('data',)):
        outw = jax.jit(lambda q,k,v,i: flash_decode(q,k,v,i,window=8))(q, ck, cv, jnp.array(idx))
    refw = flash_attention_ref(q, ck[:, :idx+1], cv[:, :idx+1], causal=True, window=8, q_offset=idx)
    assert float(jnp.abs(outw - refw).max()) < 1e-5, idx
print('FLASH_DECODE_OK')
""",
        n_devices=4,
    )
    assert "FLASH_DECODE_OK" in out


def test_decode_step_uses_flash_decode_under_context(subproc):
    """End-to-end: a sharded decode step with kh not divisible by TP routes
    through flash_decode and matches the unsharded decode step."""
    out = subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from dataclasses import replace
from repro.configs import get_config
from repro.models import init_params, init_caches, make_decode_step
from repro.launch.mesh import make_host_mesh
from repro.distribution.context import activation_sharding
from repro.distribution.sharding import cache_shardings, param_shardings

# reduced arch with kh=1 so the 2-way TP axis cannot head-shard the cache
cfg = replace(get_config('qwen2.5-3b').reduced(), num_kv_heads=1)
params = init_params(jax.random.PRNGKey(0), cfg)
caches = init_caches(cfg, batch=2, cache_len=8, dtype=jnp.float32)
dec = make_decode_step(cfg, compute_dtype=jnp.float32)
tok = jnp.ones((2,1), jnp.int32)
idx = jnp.array(3, jnp.int32)
ref_logits, _ = jax.jit(dec)(params, tok, caches, idx)

mesh = make_host_mesh(2, 2)
psh = param_shardings(jax.eval_shape(lambda: params), cfg, mesh, mode='serve')
csh = cache_shardings(jax.eval_shape(lambda: caches), cfg, mesh, 2)
params_s = jax.tree.map(lambda a, s: jax.device_put(a, s), params, psh)
caches_s = jax.tree.map(lambda a, s: jax.device_put(a, s), caches, csh)
with activation_sharding(mesh, ('data',)):
    logits, _ = jax.jit(dec)(params_s, tok, caches_s, idx)
err = float(jnp.abs(logits - ref_logits).max())
assert err < 1e-3, err
print('SHARDED_DECODE_OK', err)
""",
        n_devices=4,
    )
    assert "SHARDED_DECODE_OK" in out


def test_dryrun_builder_on_host_mesh(subproc):
    """The dry-run lowering machinery itself (shardings, specs, steps)
    compiles on a small host mesh with a reduced config."""
    out = subproc(
        """
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, get_shape
from repro.configs.base import ShapeConfig
from repro.data.pipeline import input_specs
from repro.distribution.context import activation_sharding
from repro.distribution.sharding import batch_axes, param_shardings
from repro.launch.mesh import make_host_mesh
from repro.models import init_params, make_train_step
from repro.optim import adamw

cfg = get_config('qwen3-moe-30b-a3b').reduced()
shape = ShapeConfig('tiny_train', 64, 8, 'train')
mesh = make_host_mesh(2, 2)
params_shape = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
psh = param_shardings(params_shape, cfg, mesh)
opt = adamw(1e-4)
opt_shape = jax.eval_shape(opt.init, params_shape)
osh = param_shardings(opt_shape, cfg, mesh)
specs = input_specs(cfg, shape)
bsh = {k: NamedSharding(mesh, P(batch_axes(mesh, shape.global_batch),
                                *([None]*(len(v.shape)-1)))) for k, v in specs.items()}
step = make_train_step(cfg, opt)
jitted = jax.jit(step, in_shardings=(psh, osh, bsh), out_shardings=(psh, osh, None))
with activation_sharding(mesh, batch_axes(mesh, shape.global_batch)):
    lowered = jitted.lower(params_shape, opt_shape, specs)
compiled = lowered.compile()
assert compiled.memory_analysis().temp_size_in_bytes >= 0
ca = compiled.cost_analysis() or {}
ca = ca[0] if isinstance(ca, list) else ca  # older jax: list of per-computation dicts
assert ca.get('flops', 0) > 0
print('DRYRUN_BUILD_OK')
""",
        n_devices=4,
    )
    assert "DRYRUN_BUILD_OK" in out
