"""RL agent unit tests: masking, ICM, cross-attention, update steps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.agents import action_space as A
from repro.core.agents import icm as ICM
from repro.core.agents import sac as SAC
from repro.core.agents.attention import cross_attention, init_cross_attention
from repro.core.env import MHSLEnv
from repro.core.profiles import resnet101_profile


@pytest.fixture(scope="module")
def env():
    return MHSLEnv(profile=resnet101_profile(batch=1))


def test_masked_sampling_never_picks_invalid(env):
    dims = env.action_dims
    key = jax.random.PRNGKey(0)
    logits = {
        "u": jnp.zeros((dims["u"],)),
        "size": jnp.zeros((dims["size"],)),
        "decoys": jnp.zeros((dims["decoys"], 2)),
        "p_tx": jnp.zeros((dims["p_tx"],)),
        "p_d": jnp.zeros((dims["p_d"],)),
    }
    masks = {
        "u": jnp.array([True, False, True, False, False, False]),
        "size": jnp.array([True, False, False, False]),
        "decoys": jnp.array([False, True, False, True, False, False]),
        "p_tx": jnp.ones(dims["p_tx"], bool),
        "p_d": jnp.ones(dims["p_d"], bool),
    }
    ml = A.masked_logits(logits, masks)
    for i in range(200):
        key, k = jax.random.split(key)
        a = A.sample(k, ml)
        assert int(a["u"]) in (0, 2)
        assert int(a["size"]) == 0
        d = np.asarray(a["decoys"])
        assert d[0] == 0 and d[2] == 0 and d[4] == 0 and d[5] == 0


def test_log_prob_and_entropy_shapes(env):
    dims = env.action_dims
    bs = 7
    logits = {
        "u": jnp.zeros((bs, dims["u"])),
        "size": jnp.zeros((bs, dims["size"])),
        "decoys": jnp.zeros((bs, dims["decoys"], 2)),
        "p_tx": jnp.zeros((bs, dims["p_tx"])),
        "p_d": jnp.zeros((bs, dims["p_d"])),
    }
    action = {
        "u": jnp.zeros((bs,), jnp.int32),
        "size": jnp.zeros((bs,), jnp.int32),
        "decoys": jnp.zeros((bs, dims["decoys"]), jnp.int32),
        "p_tx": jnp.zeros((bs,), jnp.int32),
        "p_d": jnp.zeros((bs,), jnp.int32),
    }
    lp = A.log_prob(logits, action)
    ent = A.entropy(logits)
    assert lp.shape == (bs,) and ent.shape == (bs,)
    # uniform logits: lp = -sum(log |head|)
    want = -(np.log(dims["u"]) + np.log(dims["size"]) + dims["decoys"] * np.log(2)
             + np.log(dims["p_tx"]) + np.log(dims["p_d"]))
    np.testing.assert_allclose(np.asarray(lp), want, rtol=1e-5)


def test_icm_features_bounded(env):
    dims = env.action_dims
    params = ICM.init_icm(jax.random.PRNGKey(0), env.obs_dim, dims)
    obs = jax.random.normal(jax.random.PRNGKey(1), (5, env.obs_dim)) * 3
    phi = ICM.features(params, obs)
    assert float(phi.min()) >= 0.0 and float(phi.max()) <= 1.0  # Lemma 1 premise


def test_icm_losses_finite_and_reward_nonneg(env):
    dims = env.action_dims
    params = ICM.init_icm(jax.random.PRNGKey(0), env.obs_dim, dims)
    bs = 6
    obs = jax.random.normal(jax.random.PRNGKey(1), (bs, env.obs_dim))
    obs2 = jax.random.normal(jax.random.PRNGKey(2), (bs, env.obs_dim))
    action = {
        "u": jnp.zeros((bs,), jnp.int32),
        "size": jnp.ones((bs,), jnp.int32),
        "decoys": jnp.zeros((bs, dims["decoys"]), jnp.int32),
        "p_tx": jnp.zeros((bs,), jnp.int32),
        "p_d": jnp.zeros((bs,), jnp.int32),
    }
    avec = A.onehot(action, dims)
    l_i, l_f, r_c = ICM.icm_losses(params, obs, obs2, action, avec, dims)
    assert np.isfinite(float(l_i)) and np.isfinite(float(l_f))
    assert float(r_c.min()) >= 0.0


def test_cross_attention_masked_history():
    obs_dim, pair_dim, I = 10, 14, 4
    p = init_cross_attention(jax.random.PRNGKey(0), obs_dim, pair_dim, attn_dim=8)
    obs = jax.random.normal(jax.random.PRNGKey(1), (obs_dim,))
    hist = jax.random.normal(jax.random.PRNGKey(2), (I, pair_dim))
    m_none = jnp.zeros((I,))
    out0 = cross_attention(p, obs, hist, m_none)
    # empty history -> attended part is zeros, obs passes through
    np.testing.assert_allclose(np.asarray(out0[:obs_dim]), np.asarray(obs), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out0[obs_dim:]), 0.0, atol=1e-6)
    m_one = jnp.array([0.0, 0.0, 0.0, 1.0])
    out1 = cross_attention(p, obs, hist, m_one)
    # with one valid pair, attended output == its value projection
    want = hist[3] @ p["wv"]
    np.testing.assert_allclose(np.asarray(out1[obs_dim:]), np.asarray(want), rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float16])
def test_cross_attention_low_precision_dtypes(dtype):
    """Masking must use a dtype-aware sentinel: the old -1e9 literal
    overflows fp16 to -inf, which NaNs the softmax as soon as a row is
    fully masked. Pins finite outputs + agreement with the fp32 path."""
    obs_dim, pair_dim, I = 10, 14, 4
    p32 = init_cross_attention(jax.random.PRNGKey(0), obs_dim, pair_dim,
                               attn_dim=8)
    obs = jax.random.normal(jax.random.PRNGKey(1), (obs_dim,))
    hist = jax.random.normal(jax.random.PRNGKey(2), (I, pair_dim))
    ref_partial = np.asarray(
        cross_attention(p32, obs, hist, jnp.array([0.0, 1.0, 0.0, 1.0])))

    p = jax.tree.map(lambda x: x.astype(dtype), p32)
    obs_l, hist_l = obs.astype(dtype), hist.astype(dtype)
    for mask in (jnp.zeros((I,)), jnp.ones((I,)),
                 jnp.array([0.0, 1.0, 0.0, 1.0])):
        out = np.asarray(cross_attention(p, obs_l, hist_l,
                                         mask.astype(dtype)), np.float32)
        assert np.isfinite(out).all(), (dtype, np.asarray(mask))
    np.testing.assert_allclose(
        np.asarray(cross_attention(p, obs_l, hist_l,
                                   jnp.array([0.0, 1.0, 0.0, 1.0],
                                             dtype)), np.float32),
        ref_partial, atol=0.15)


def test_sac_update_runs_and_reduces_critic_loss(env):
    dims = env.action_dims
    cfg = SAC.SACConfig(hidden=32, feat_dim=8, attn_dim=8, batch=16)
    params = SAC.init_agent(jax.random.PRNGKey(0), env.obs_dim, dims, cfg)
    update, init_opt = SAC.make_update(dims, cfg)
    opt_state = init_opt(params)
    bs = 16
    key = jax.random.PRNGKey(1)
    pair_dim = env.obs_dim + A.flat_dim(dims)
    batch = {
        "obs": jax.random.normal(key, (bs, env.obs_dim)),
        "obs_next": jax.random.normal(key, (bs, env.obs_dim)),
        "hist": jnp.zeros((bs, cfg.hist_len, pair_dim)),
        "hist_mask": jnp.zeros((bs, cfg.hist_len)),
        "action": {
            "u": jnp.zeros((bs,), jnp.int32),
            "size": jnp.zeros((bs,), jnp.int32),
            "decoys": jnp.zeros((bs, dims["decoys"]), jnp.int32),
            "p_tx": jnp.zeros((bs,), jnp.int32),
            "p_d": jnp.zeros((bs,), jnp.int32),
        },
        "masks": {
            "u": jnp.ones((bs, dims["u"]), bool),
            "size": jnp.ones((bs, dims["size"]), bool),
            "decoys": jnp.ones((bs, dims["decoys"]), bool),
            "p_tx": jnp.ones((bs, dims["p_tx"]), bool),
            "p_d": jnp.ones((bs, dims["p_d"]), bool),
        },
        "reward": jnp.full((bs,), -1.0),
        "done": jnp.zeros((bs,)),
    }
    losses = []
    for i in range(30):
        params, opt_state, m = update(params, opt_state, batch)
        losses.append(float(m["critic_loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0]


def test_sac_ablation_variants_init(env):
    dims = env.action_dims
    for use_icm, use_ca in [(True, True), (False, True), (True, False), (False, False)]:
        cfg = SAC.SACConfig(use_icm=use_icm, use_ca=use_ca, hidden=16, feat_dim=4)
        p = SAC.init_agent(jax.random.PRNGKey(0), env.obs_dim, dims, cfg)
        assert ("icm" in p) == use_icm
        assert ("ca" in p["actor"]) == use_ca
        update, init_opt = SAC.make_update(dims, cfg)
        init_opt(p)  # must not raise
