"""MHSL environment invariants (unit + hypothesis property tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip, unit tests still run
    from _hypothesis_compat import given, settings, st

from repro.core.channel import NetworkConfig
from repro.core.env import MHSLEnv, NBINS, OMEGA_1, OMEGA_2
from repro.core.profiles import resnet101_profile, transformer_profile
from repro.configs import get_config


@pytest.fixture(scope="module")
def env():
    return MHSLEnv(profile=resnet101_profile(batch=1))


def _rand_action(env, key, masks):
    ks = jax.random.split(key, 5)
    return {
        "u": jax.random.categorical(ks[0], jnp.where(masks["u"], 0.0, -1e9)),
        "size": jax.random.categorical(ks[1], jnp.where(masks["size"], 0.0, -1e9)),
        "decoys": (jax.random.uniform(ks[2], masks["decoys"].shape) < 0.5).astype(jnp.int32)
        * masks["decoys"],
        "p_tx": jax.random.randint(ks[3], (), 0, env.num_power_levels),
        "p_d": jax.random.randint(ks[4], (), 0, env.num_power_levels),
    }


@given(seed=st.integers(0, 500))
@settings(max_examples=15, deadline=None)
def test_episode_invariants(seed):
    env = MHSLEnv(profile=resnet101_profile(batch=1))
    key = jax.random.PRNGKey(seed)
    st_ = env.reset(key)
    lmax = env.L
    prev_t = float(st_.t_r)
    prev_e = float(st_.e_r)
    for i in range(env.episode_len):
        key, ka, ks = jax.random.split(key, 3)
        masks = env.action_masks(st_)
        a = _rand_action(env, ka, masks)
        st_, r, done, info = env.step(st_, a, ks)
        # budgets never increase
        assert float(st_.t_r) <= prev_t + 1e-6
        assert float(st_.e_r) <= prev_e + 1e-6
        prev_t, prev_e = float(st_.t_r), float(st_.e_r)
        # reward bounded per Lemma 1 discussion
        assert float(r) <= 0.0
        assert float(r) >= -(env.E * env.leak_scale + OMEGA_1 + OMEGA_2)
    assert bool(done)
    # split-plan conservation: boundaries strictly increasing to L
    b = np.asarray(st_.boundaries)
    assert b[-1] == lmax
    assert np.all(np.diff(b) >= 1)
    # exactly S-1 devices + server assigned
    sd = np.asarray(st_.stage_dev)
    assert sd[-1] == env.U  # server holds the last stage
    assert len(set(sd.tolist())) == env.S  # all distinct


def test_masks_prevent_double_assignment(env):
    key = jax.random.PRNGKey(0)
    st_ = env.reset(key)
    chosen = []
    for i in range(env.S - 1):
        key, ka, ks = jax.random.split(key, 3)
        masks = env.action_masks(st_)
        m = np.asarray(masks["u"])
        for c in chosen:
            assert not m[c], "already-assigned device must be masked"
        a = _rand_action(env, ka, masks)
        chosen.append(int(a["u"]))
        st_, *_ = env.step(st_, a, ks)


def test_decoys_exclude_tx_rx(env):
    """The EFFECTIVE decoy set (env.step enforcement, Eq. 14b) never
    contains the transmitter or receiver, even if the agent asked for
    them; the mask already excludes the transmitter ahead of time."""
    key = jax.random.PRNGKey(1)
    st_ = env.reset(key)
    for i in range(env.episode_len):
        key, ka, ks = jax.random.split(key, 3)
        masks = env.action_masks(st_)
        a = _rand_action(env, ka, masks)
        a["decoys"] = jnp.ones_like(a["decoys"])  # adversarial: ask for all
        st2, r, done, info = env.step(st_, a, ks)
        tx, rx = int(info["tx"]), int(info["rx"])
        dp = np.asarray(info["decoy_p"])
        if int(st_.n) >= 2:
            if tx < env.U:
                assert dp[tx] == 0.0
            if rx < env.U:
                assert dp[rx] == 0.0
            m = np.asarray(masks["decoys"])
            if tx < env.U:
                assert not m[tx]
        st_ = st2


def test_no_decoys_increases_leak_risk(env):
    """With all decoys off, expected leakage over many episodes is larger
    than with full decoys at max power (paper's core premise)."""
    def run(decoys_on, seed):
        key = jax.random.PRNGKey(seed)
        st_ = env.reset(jax.random.PRNGKey(7))  # fixed geometry
        tot = 0.0
        for i in range(env.episode_len):
            key, ka, ks = jax.random.split(key, 3)
            masks = env.action_masks(st_)
            a = _rand_action(env, ka, masks)
            a["decoys"] = masks["decoys"].astype(jnp.int32) * (1 if decoys_on else 0)
            a["p_d"] = jnp.array(env.num_power_levels - 1)
            a["p_tx"] = jnp.array(1)
            st_, r, done, info = env.step(st_, a, ks)
            tot += float(info["leak"])
        return tot

    leak_off = np.mean([run(False, s) for s in range(8)])
    leak_on = np.mean([run(True, s) for s in range(8)])
    assert leak_on <= leak_off + 1e-6


def test_transformer_profile_env_runs():
    cfg = get_config("qwen2.5-3b")
    prof = transformer_profile(cfg, batch=1, seq=128)
    env = MHSLEnv(profile=prof)
    key = jax.random.PRNGKey(0)
    st_ = env.reset(key)
    for i in range(env.episode_len):
        key, ka, ks = jax.random.split(key, 3)
        a = _rand_action(env, ka, env.action_masks(st_))
        st_, r, done, info = env.step(st_, a, ks)
        assert np.isfinite(float(r))
    assert int(np.asarray(st_.boundaries)[-1]) == cfg.num_layers


def test_compute_time_attribution_fwd_vs_bwd(env):
    """Regression for the once-dead branch in ``env.step``'s stage-compute
    charge (Eq. 20): a forward hop charges the RECEIVING stage's forward
    FLOPs, a backward hop charges the TRANSMITTING stage's backward FLOPs -
    both resolve to stage ``hop+1``, but the FLOP tables must differ. The
    energy model (Eq. 11) must charge the same direction-dependent FLOPs."""
    from repro.core.channel import (
        compute_energy, compute_time_bwd, compute_time_fwd,
    )

    prof = env.profile
    fwd_cum = np.concatenate([[0.0], np.cumsum(prof.fwd_flops)])
    bwd_cum = np.concatenate([[0.0], np.cumsum(prof.bwd_flops)])
    S = env.S
    key = jax.random.PRNGKey(3)
    st_ = env.reset(jax.random.PRNGKey(0))
    checked_fwd = checked_bwd = 0
    for i in range(env.episode_len):
        key, ka, ks = jax.random.split(key, 3)
        masks = env.action_masks(st_)
        a = _rand_action(env, ka, masks)
        st2, r, done, info = env.step(st_, a, ks)
        n = int(st_.n)
        if n >= 2:
            fwd = n <= S
            hop = (n - 2) if fwd else (2 * S - n - 1)
            stage = hop + 1  # fwd: receiver; bwd: transmitter
            b = np.asarray(st2.boundaries)
            lo, hi = b[stage - 1], b[stage]
            flops_fwd = fwd_cum[hi] - fwd_cum[lo]
            flops_bwd = bwd_cum[hi] - bwd_cum[lo]
            expect = float(
                compute_time_fwd(jnp.asarray(flops_fwd), env.net) if fwd
                else compute_time_bwd(jnp.asarray(flops_bwd), env.net)
            )
            t_comp = float(st_.t_r) - float(st2.t_r) - float(info["t_hop"])
            np.testing.assert_allclose(t_comp, expect, rtol=1e-4, atol=1e-5)
            # energy: e_hop = (p_tx + sum decoy_p) * t_hop + e_comp(flops)
            flops = flops_fwd if fwd else flops_bwd
            p_tx = env.net.power_levels[int(a["p_tx"])]
            expect_e = (
                (p_tx + float(np.asarray(info["decoy_p"]).sum()))
                * float(info["t_hop"])
                + float(compute_energy(jnp.asarray(flops), env.net))
            )
            np.testing.assert_allclose(
                float(st_.e_r) - float(st2.e_r), expect_e, rtol=1e-4, atol=1e-5
            )
            if fwd:
                checked_fwd += 1
            else:
                # the regression: bwd attribution must use the bwd table
                assert flops_bwd != flops_fwd
                checked_bwd += 1
        st_ = st2
    assert checked_fwd == S - 1 and checked_bwd == S - 1


def test_observe_shape_and_location_blinding():
    prof = resnet101_profile(batch=1)
    env_known = MHSLEnv(profile=prof, know_eave_locations=True)
    env_blind = MHSLEnv(profile=prof, know_eave_locations=False)
    st_ = env_known.reset(jax.random.PRNGKey(0))
    o1 = env_known.observe(st_)
    o2 = env_blind.observe(st_)
    assert o1.shape == (env_known.obs_dim,)
    # blinded obs zeroes the eavesdropper distances, all else equal
    diff = np.flatnonzero(np.asarray(o1) != np.asarray(o2))
    assert len(diff) <= env_known.E
