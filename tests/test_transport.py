"""Link-model + structural transport-accounting tests.

Pins the agreement contract between the split executor's tick accounting
(``repro.core.transport``) and the Eq. 10/11 plan oracle
(``splitting.plan_cost``): same per-stage compute terms, same per-hop
transmission terms at each hop's link bandwidth/latency, and at M=1 the
synchronous 1F1B schedule IS the oracle's serial delay. Also covers the
per-hop link model itself (heterogeneous bandwidths, fixed latencies,
validation) through both scoring paths.
"""
import numpy as np
import pytest

from repro.core.channel import NetworkConfig
from repro.core.profiles import resnet101_profile
from repro.core.splitting import SplitPlan, make_plan_scorer, plan_cost
from repro.core.transport import (
    TransportModel,
    plan_transport_model,
    simulate_1f1b,
    tick_costs,
)


def _setup(s, *, hop_bandwidth=(), hop_latency=0.0, seed=0, num_devices=8,
           max_split=None):
    net = NetworkConfig(num_devices=num_devices,
                        max_split=max_split or max(s, 4),
                        hop_bandwidth=hop_bandwidth, hop_latency=hop_latency)
    prof = resnet101_profile(batch=1)
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0, net.area_m, (net.num_devices + 1, 2))
    devices = tuple(range(s - 1)) + (net.num_devices,)
    bounds = tuple(int(b) for b in np.linspace(4, prof.num_layers, s))
    plan = SplitPlan(bounds, devices)
    p_tx = np.full(s - 1, 0.5)
    decoy = np.zeros((s - 1, net.num_devices + 1))
    decoy[:, -1] = 0.1
    return prof, plan, pos, p_tx, decoy, net


@pytest.mark.parametrize("s", [2, 4])
def test_sync_m1_matches_plan_cost(s):
    """At one microbatch there is nothing to overlap: the executor's
    synchronous tick accounting must equal the Eq. 10 delay exactly."""
    prof, plan, pos, p_tx, decoy, net = _setup(
        s, hop_bandwidth=tuple(1e6 / (k + 1) for k in range(max(s, 4) - 1)),
        hop_latency=1e-3)
    t_ref, _ = plan_cost(prof, plan, pos, p_tx, decoy, net)
    model = plan_transport_model(prof, plan, pos, p_tx, decoy, net)
    sim = simulate_1f1b(model, 1, transport="sync")
    np.testing.assert_allclose(sim["total_s"], t_ref, rtol=1e-12)


def test_overlap_never_slower_and_bubble():
    prof, plan, pos, p_tx, decoy, net = _setup(4, hop_latency=2e-3)
    model = plan_transport_model(prof, plan, pos, p_tx, decoy, net)
    for m in (1, 2, 4, 8):
        sync = simulate_1f1b(model, m, transport="sync")
        ovl = simulate_1f1b(model, m, transport="overlap")
        # per tick: max(compute, in-flight) <= compute + transport
        assert ovl["total_s"] <= sync["total_s"] + 1e-12, m
        s = model.num_stages
        expect = 2 * (s - 1) / (m + 2 * (s - 1))
        np.testing.assert_allclose(ovl["bubble_fraction"], expect, rtol=1e-12)
    with pytest.raises(ValueError):
        simulate_1f1b(model, 2, transport="eager")


def test_heterogeneous_hop_tick_accounting():
    """Hand-built model, S=3, M=2: each tick's transport is the max over
    the hops ACTIVE that tick (paired ppermutes fire links concurrently),
    with per-microbatch tx costs and undivided per-hop latency."""
    model = TransportModel(
        t_comp_fwd=np.array([2.0, 4.0, 6.0]),
        t_comp_bwd=np.array([4.0, 8.0, 12.0]),
        t_tx_fwd=np.array([10.0, 2.0]),   # hop 0 is the slow link
        t_tx_bwd=np.array([6.0, 2.0]),
        hop_latency=np.array([0.5, 0.25]),
    )
    m = 2
    compute, transport = tick_costs(model, m)
    assert len(compute) == m + 2 * (3 - 1)
    # tick 0: only stage 0 forwards mb0; only hop 0 carries it
    np.testing.assert_allclose(compute[0], 2.0 / m)
    np.testing.assert_allclose(transport[0], 10.0 / m + 0.5)
    # tick 1: stage 0 fwd mb1 + stage 1 fwd mb0; both forward hops active,
    # the slow hop 0 dominates
    np.testing.assert_allclose(compute[1], max(2.0, 4.0) / m)
    np.testing.assert_allclose(transport[1], max(10.0 / m + 0.5,
                                                 2.0 / m + 0.25))
    # tick 2: stage 2 fwd+bwd mb0 back-to-back; hop 1 fwd mb1 vs hop 1
    # (stage 2 -> 1) cotangent of mb0
    np.testing.assert_allclose(compute[2], (6.0 + 12.0) / m)
    np.testing.assert_allclose(transport[2], max(2.0 / m + 0.25,
                                                 2.0 / m + 0.25))
    # last tick: only stage 0 backwards the last microbatch; no hops left
    np.testing.assert_allclose(compute[-1], 4.0 / m)
    np.testing.assert_allclose(transport[-1], 0.0)
    # totals: every slot/hop appears exactly once per microbatch
    sim = simulate_1f1b(model, m, transport="sync")
    np.testing.assert_allclose(
        sim["total_s"], compute.sum() + transport.sum(), rtol=1e-12)


def test_slower_hop_bandwidth_raises_hop_time():
    """Halving one hop's bandwidth strictly raises that hop's time in the
    plan breakdown (rate falls with B even though the noise floor N0*B
    falls too) and leaves other hops untouched."""
    from repro.core.splitting import plan_cost_parts

    prof, plan, pos, p_tx, decoy, net0 = _setup(4)
    base = plan_cost_parts(prof, plan, pos, p_tx, decoy, net0)
    net1 = NetworkConfig(num_devices=net0.num_devices, max_split=net0.max_split,
                         hop_bandwidth=(5e5, 1e6, 1e6))
    slow = plan_cost_parts(prof, plan, pos, p_tx, decoy, net1)
    assert slow["t_hop_fwd"][0] > base["t_hop_fwd"][0]
    np.testing.assert_allclose(slow["t_hop_fwd"][1:], base["t_hop_fwd"][1:],
                               rtol=1e-12)


def test_default_link_model_is_bit_identical():
    """An explicit per-hop bandwidth equal to the base bandwidth and zero
    latency reproduces the uniform-link plan cost EXACTLY (the noise-floor
    scale factor is exactly 1.0)."""
    prof, plan, pos, p_tx, decoy, net0 = _setup(4)
    net1 = NetworkConfig(num_devices=net0.num_devices, max_split=net0.max_split,
                         hop_bandwidth=(1e6, 1e6, 1e6), hop_latency=0.0)
    t0, e0 = plan_cost(prof, plan, pos, p_tx, decoy, net0)
    t1, e1 = plan_cost(prof, plan, pos, p_tx, decoy, net1)
    assert t0 == t1 and e0 == e1


def test_scorer_matches_plan_cost_heterogeneous():
    """The jitted vmap scorer and the host plan_cost loop agree under a
    heterogeneous link ladder (per-hop bandwidths + latency)."""
    s = 4
    prof, plan, pos, p_tx, decoy, net = _setup(
        s, hop_bandwidth=(1e6, 4e5, 7e5), hop_latency=3e-3)
    t_ref, e_ref = plan_cost(prof, plan, pos, p_tx, decoy, net)
    scorer = make_plan_scorer(prof)
    t, e = scorer(np.asarray([plan.boundaries]), np.asarray(plan.devices),
                  pos, p_tx, decoy, net)
    np.testing.assert_allclose(float(t[0]), t_ref, rtol=2e-6)
    np.testing.assert_allclose(float(e[0]), e_ref, rtol=2e-6)


def test_link_model_validation():
    with pytest.raises(ValueError):
        _ = NetworkConfig(hop_bandwidth=(1e6,), max_split=4).hop_bandwidth_hz
    # a plan with more hops than the link model is refused by the scorer
    prof = resnet101_profile(batch=1)
    net = NetworkConfig(max_split=2)
    scorer = make_plan_scorer(prof)
    bounds = np.asarray([[4, 8, prof.num_layers]])
    with pytest.raises(ValueError):
        scorer(bounds, np.asarray([0, 1, net.num_devices]),
               np.zeros((net.num_devices + 1, 2)), np.full(2, 0.5),
               np.zeros((2, net.num_devices + 1)), net)
