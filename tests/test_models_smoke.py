"""Per-architecture smoke tests (deliverable f): reduced variant, one
forward + one train step + one decode step on CPU, asserting shapes and
finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.data import synthetic_batch
from repro.models import (
    forward,
    init_caches,
    init_params,
    make_decode_step,
    make_train_step,
)
from repro.optim import adamw


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = synthetic_batch(cfg, batch=2, seq=32, seed=0)
    logits, _, aux = forward(
        params, batch["tokens"], cfg, frontend_feats=batch.get("frontend"), remat=False
    )
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))

    opt = adamw(1e-3, max_grad_norm=1.0)
    step = jax.jit(make_train_step(cfg, opt))
    ostate = opt.init(params)
    p2, ostate, metrics = step(params, ostate, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    # params changed
    changed = jax.tree.map(lambda a, b: bool((a != b).any()), params, p2)
    assert any(jax.tree.leaves(changed))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_with_cache(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    caches = init_caches(cfg, batch=2, cache_len=64)
    dec = jax.jit(make_decode_step(cfg))
    toks = jnp.ones((2, 1), jnp.int32)
    logits, caches2 = dec(params, toks, caches, jnp.array(3, jnp.int32))
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    # cache structure preserved
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


def _decode_vs_forward_err(cfg) -> float:
    """Max |greedy-decode logits - teacher-forced forward logits|."""
    params = init_params(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0, cfg.vocab_size)
    full_logits, _, _ = forward(params, toks, cfg, remat=False, compute_dtype=jnp.float32)

    caches = init_caches(cfg, batch=1, cache_len=16, dtype=jnp.float32)
    dec = make_decode_step(cfg, compute_dtype=jnp.float32)
    outs = []
    for t in range(8):
        logits, caches = jax.jit(dec)(
            params, toks[:, t : t + 1], caches, jnp.array(t, jnp.int32)
        )
        outs.append(logits)
    dec_logits = jnp.stack(outs, axis=1)  # (1, 8, V)
    return float(jnp.abs(dec_logits - full_logits).max())


@pytest.mark.parametrize("arch", [
    "qwen2.5-3b",
    "mamba2-370m",
    # NOT a cache-handoff bug (the dropless test below pins the handoff):
    # capacity-bounded MoE dropping depends on the dispatch-group token
    # count, so teacher-forced forward (8 tokens/group, capacity 5) drops
    # tokens that single-token decode (capacity >= top_k) never drops.
    # Structural - decode-consistent capacity would need a router-occupancy
    # cache plus a capacity fixed against an unknown final length. Tracked
    # as the jamba_decode xfail.
    pytest.param("jamba-v0.1-52b", marks=[
        pytest.mark.jamba_decode,
        pytest.mark.xfail(
            reason="MoE capacity token-dropping is dispatch-group-size "
            "dependent; teacher-forced and decode disagree by design",
            strict=False,
        ),
    ]),
])
def test_decode_matches_forward(arch):
    """Greedy decode logits must match teacher-forced forward logits."""
    err = _decode_vs_forward_err(get_config(arch).reduced())
    assert err < 2e-2, err


def test_jamba_decode_matches_forward_dropless():
    """The hybrid SSM/attention cache handoff IS exact: with MoE capacity
    dropping neutralized (capacity_factor >> 1 admits every token in both
    group sizes), jamba decode matches the teacher-forced forward. This
    pins the jamba_decode xfail's diagnosis to capacity-dropping context
    dependence rather than state handoff."""
    from dataclasses import replace

    cfg = get_config("jamba-v0.1-52b").reduced()
    cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=64.0))
    err = _decode_vs_forward_err(cfg)
    assert err < 2e-2, err


def test_sliding_window_decode():
    cfg = get_config("stablelm-1.6b").reduced().with_window(8)
    params = init_params(jax.random.PRNGKey(0), cfg)
    caches = init_caches(cfg, batch=1, cache_len=8)  # ring buffer = window
    dec = jax.jit(make_decode_step(cfg))
    toks = jnp.ones((1, 1), jnp.int32)
    for t in range(20):  # wraps the ring buffer twice
        logits, caches = dec(params, toks, caches, jnp.array(t, jnp.int32))
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
