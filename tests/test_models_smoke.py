"""Per-architecture smoke tests (deliverable f): reduced variant, one
forward + one train step + one decode step on CPU, asserting shapes and
finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.data import synthetic_batch
from repro.models import (
    forward,
    init_caches,
    init_params,
    make_decode_step,
    make_train_step,
)
from repro.optim import adamw


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = synthetic_batch(cfg, batch=2, seq=32, seed=0)
    logits, _, aux = forward(
        params, batch["tokens"], cfg, frontend_feats=batch.get("frontend"), remat=False
    )
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))

    opt = adamw(1e-3, max_grad_norm=1.0)
    step = jax.jit(make_train_step(cfg, opt))
    ostate = opt.init(params)
    p2, ostate, metrics = step(params, ostate, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    # params changed
    changed = jax.tree.map(lambda a, b: bool((a != b).any()), params, p2)
    assert any(jax.tree.leaves(changed))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_with_cache(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    caches = init_caches(cfg, batch=2, cache_len=64)
    dec = jax.jit(make_decode_step(cfg))
    toks = jnp.ones((2, 1), jnp.int32)
    logits, caches2 = dec(params, toks, caches, jnp.array(3, jnp.int32))
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    # cache structure preserved
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


def _decode_vs_forward_err(cfg) -> float:
    """Max |greedy-decode logits - teacher-forced forward logits|."""
    params = init_params(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0, cfg.vocab_size)
    full_logits, _, _ = forward(params, toks, cfg, remat=False, compute_dtype=jnp.float32)

    caches = init_caches(cfg, batch=1, cache_len=16, dtype=jnp.float32)
    dec = make_decode_step(cfg, compute_dtype=jnp.float32)
    outs = []
    for t in range(8):
        logits, caches = jax.jit(dec)(
            params, toks[:, t : t + 1], caches, jnp.array(t, jnp.int32)
        )
        outs.append(logits)
    dec_logits = jnp.stack(outs, axis=1)  # (1, 8, V)
    return float(jnp.abs(dec_logits - full_logits).max())


@pytest.mark.parametrize("arch", [
    "qwen2.5-3b",
    "mamba2-370m",
    # xfail RETIRED: under dropless MoE dispatch (the default) every routed
    # token is computed, so a token's output is independent of its
    # dispatch-group size and teacher-forced forward (8-token groups)
    # agrees with single-token decode. The old capacity path (ceil(T*k*cf/E)
    # buffer) dropped tokens group-size-dependently - that structural
    # disagreement is what the xfail tracked.
    pytest.param("jamba-v0.1-52b", marks=[pytest.mark.jamba_decode]),
])
def test_decode_matches_forward(arch):
    """Greedy decode logits must match teacher-forced forward logits."""
    err = _decode_vs_forward_err(get_config(arch).reduced())
    assert err < 2e-2, err


def test_jamba_decode_matches_forward_capacity_neutralized():
    """The hybrid SSM/attention cache handoff is exact even on the legacy
    CAPACITY dispatch path, once its dropping is neutralized
    (capacity_factor >> 1 admits every token at both group sizes). This
    keeps the retired jamba_decode xfail's diagnosis pinned: the old
    decode drift came from capacity-dropping context dependence, not the
    state handoff."""
    from dataclasses import replace

    cfg = get_config("jamba-v0.1-52b").reduced()
    cfg = replace(cfg, moe=replace(cfg.moe, dispatch="capacity",
                                   capacity_factor=64.0))
    err = _decode_vs_forward_err(cfg)
    assert err < 2e-2, err


def test_sliding_window_decode():
    cfg = get_config("stablelm-1.6b").reduced().with_window(8)
    params = init_params(jax.random.PRNGKey(0), cfg)
    caches = init_caches(cfg, batch=1, cache_len=8)  # ring buffer = window
    dec = jax.jit(make_decode_step(cfg))
    toks = jnp.ones((1, 1), jnp.int32)
    for t in range(20):  # wraps the ring buffer twice
        logits, caches = dec(params, toks, caches, jnp.array(t, jnp.int32))
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
