"""The unified LeakageModel API: analytic bit-exactness + empirical wiring.

The redesign moved the paper's closed-form leakage into
``AnalyticLeakage`` methods and kept the module-level free functions as
thin wrappers - these tests pin that refactor bit-exactly (wrapper vs
method vs an inline re-derivation of the original formulas), check the
env threads a custom model through reward/step, and exercise the
``EmpiricalLeakage`` depth interpolation.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import channel_gain
from repro.core.env import MHSLEnv
from repro.core.leakage import (
    AnalyticLeakage,
    EmpiricalLeakage,
    LeakageModel,
    capture_probability,
    evaluate_leakage,
    expected_leakage,
    plan_hop_geometry,
    sample_leakage,
)
from repro.core.profiles import profile_table, resnet101_profile


def _geometry(seed=0, e=3, u=4):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 4)
    p_tx = jax.random.uniform(ks[0], (), minval=0.05, maxval=1.5)
    d_e = jax.random.uniform(ks[1], (e,), minval=20.0, maxval=600.0)
    dp = jax.random.uniform(ks[2], (u,), minval=0.0, maxval=1.0)
    dde = jax.random.uniform(ks[3], (u, e), minval=20.0, maxval=600.0)
    return p_tx, d_e, dp, dde


def test_free_functions_are_bitwise_wrappers():
    model = AnalyticLeakage()
    p_tx, d_e, dp, dde = _geometry()
    q = jnp.asarray([0.8, 0.3, 0.0])
    delta = jnp.asarray(0.7)
    key = jax.random.PRNGKey(7)
    assert np.array_equal(
        np.asarray(capture_probability(p_tx, d_e, dp, dde)),
        np.asarray(model.capture_probability(p_tx, d_e, dp, dde)))
    assert np.array_equal(
        np.asarray(expected_leakage(p_tx, d_e, dp, dde, q, delta)),
        np.asarray(model.expected_leakage(p_tx, d_e, dp, dde, q, delta)))
    assert np.array_equal(
        np.asarray(sample_leakage(key, p_tx, d_e, dp, dde, q, delta)),
        np.asarray(model.sample_leakage(key, p_tx, d_e, dp, dde, q, delta)))


def test_capture_probability_matches_inline_theorem1():
    """Regression pin: the method body IS the pre-refactor formula."""
    p_tx, d_e, dp, dde = _geometry(seed=3)
    s_tx = p_tx * channel_gain(d_e, 1.0)
    s_d = dp[:, None] * channel_gain(dde, 1.0)
    frac = s_tx[None, :] / jnp.maximum(s_d + s_tx[None, :], 1e-30)
    frac = jnp.where(dp[:, None] > 0, frac, 1.0)
    expect = jnp.prod(frac, axis=0)
    got = capture_probability(p_tx, d_e, dp, dde)
    assert np.array_equal(np.asarray(expect), np.asarray(got))
    q = jnp.asarray([0.5, 0.9, 0.1])
    expect_leak = jnp.sum(expect * q) * 0.42
    got_leak = expected_leakage(p_tx, d_e, dp, dde, q, jnp.asarray(0.42))
    assert np.allclose(np.asarray(expect_leak), np.asarray(got_leak),
                       rtol=0, atol=0)


def test_env_default_model_is_explicit_analytic():
    """leakage_model=None and leakage_model=AnalyticLeakage() are the
    same env bit-for-bit (rewards, leak info, state)."""
    prof = resnet101_profile(batch=1)
    env0 = MHSLEnv(profile=prof)
    env1 = MHSLEnv(profile=prof, leakage_model=AnalyticLeakage())
    key = jax.random.PRNGKey(11)
    s0, s1 = env0.reset(key), env1.reset(key)
    k = jax.random.PRNGKey(5)
    for _ in range(4):
        k, ka, ks = jax.random.split(k, 3)
        masks = env0.action_masks(s0)
        ks_a = jax.random.split(ka, 5)
        a = {
            "u": jax.random.categorical(ks_a[0], jnp.where(masks["u"], 0.0, -1e9)),
            "size": jax.random.categorical(ks_a[1], jnp.where(masks["size"], 0.0, -1e9)),
            "decoys": (jax.random.uniform(ks_a[2], masks["decoys"].shape) < 0.5
                       ).astype(jnp.int32) * masks["decoys"],
            "p_tx": jax.random.randint(ks_a[3], (), 0, env0.num_power_levels),
            "p_d": jax.random.randint(ks_a[4], (), 0, env0.num_power_levels),
        }
        s0, r0, d0, i0 = env0.step(s0, a, ks)
        s1, r1, d1, i1 = env1.step(s1, a, ks)
        assert np.array_equal(np.asarray(r0), np.asarray(r1))
        assert np.array_equal(np.asarray(i0["leak"]), np.asarray(i1["leak"]))


def test_evaluate_expected_matches_per_hop_loop():
    prof = resnet101_profile(batch=1)
    model = AnalyticLeakage.for_profile(prof)
    assert isinstance(model, LeakageModel)
    ell = len(profile_table(prof).leak_norm)
    dev_pos = jnp.asarray([[100.0, 100.0], [250.0, 120.0], [400.0, 300.0]])
    eav_pos = jnp.asarray([[200.0, 200.0], [380.0, 90.0]])
    boundaries = jnp.asarray([ell // 3, 2 * ell // 3, ell])
    devices = jnp.asarray([0, 1, 2])
    decoy_p = jnp.asarray([0.0, 0.2, 0.1])
    plan = plan_hop_geometry(boundaries, devices, dev_pos, eav_pos,
                             p_tx=0.5, decoy_p=decoy_p)
    env = MHSLEnv(profile=prof)
    sc = env.scenario()
    got = np.asarray(evaluate_leakage(model, sc, plan))
    assert got.shape == (2,)
    q_e = sc.monitor_prob * sc.eave_mask
    table = np.asarray(profile_table(prof).leak_norm)
    for h in range(2):
        delta = table[int(plan.boundary_layer[h])] * float(sc.leak_scale)
        expect = expected_leakage(plan.p_tx[h], plan.dist_tx_e[h],
                                  plan.decoy_p[h], plan.decoy_dist_e[h],
                                  q_e, delta, sc.rayleigh_o)
        assert np.allclose(got[h], float(expect), rtol=1e-6)
    # sampled path: per-hop fold_in keys over the same geometry
    key = jax.random.PRNGKey(3)
    samp = np.asarray(evaluate_leakage(model, sc, plan, key=key))
    for h in range(2):
        delta = table[int(plan.boundary_layer[h])] * float(sc.leak_scale)
        expect = sample_leakage(jax.random.fold_in(key, h), plan.p_tx[h],
                                plan.dist_tx_e[h], plan.decoy_p[h],
                                plan.decoy_dist_e[h], q_e, delta,
                                sc.rayleigh_o)
        assert np.array_equal(samp[h], np.asarray(expect))


def test_empirical_interpolation_and_env_threading():
    emp = EmpiricalLeakage.from_scores([1, 2, 4], [0.6, 0.3, 0.1], 4)
    assert isinstance(emp, LeakageModel)
    # measured depths hit their own scores exactly
    tab = np.asarray(emp.value_table)
    assert np.allclose(tab[[0, 1, 3]], [0.6, 0.3, 0.1])
    # interpolated onto a deeper profile: bounded by the measured range,
    # monotone for monotone scores
    vals = emp.layer_values(np.zeros(16))
    assert vals.shape == (16,)
    assert vals.min() >= 0.1 - 1e-6 and vals.max() <= 0.6 + 1e-6
    assert np.all(np.diff(vals) <= 1e-6)
    # env threads the table through its reward constants
    prof = resnet101_profile(batch=1)
    env = MHSLEnv(profile=prof, leakage_model=emp)
    ell = len(profile_table(prof).leak_norm)
    assert np.allclose(np.asarray(env._consts()[2]), emp.layer_values(
        profile_table(prof).leak_norm), atol=1e-7)
    assert dataclasses.fields(env)  # still a dataclass after the new field
    assert ell >= 16  # deeper than the measured depth: interpolation ran
