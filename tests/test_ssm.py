"""Mamba-2 SSD tests: chunked == naive recurrence == decode steps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ssm as S


def _inputs(key, b, s, h, p, n):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)) - 0.5)
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, n))
    cm = jax.random.normal(ks[4], (b, s, n))
    return x, dt, a, bm, cm


def naive_ssd(x, dt, a, bm, cm):
    """Token-by-token linear recurrence (ground truth)."""
    b, s, h, p = x.shape
    n = bm.shape[-1]
    hstate = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        dec = jnp.exp(dt[:, t, :] * a[None, :])  # (B, H)
        upd = jnp.einsum("bhp,bn->bhpn", x[:, t] * dt[:, t, :, None], bm[:, t])
        hstate = hstate * dec[:, :, None, None] + upd
        ys.append(jnp.einsum("bhpn,bn->bhp", hstate, cm[:, t]))
    return jnp.stack(ys, axis=1), hstate


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_chunked_matches_naive(chunk):
    x, dt, a, bm, cm = _inputs(jax.random.PRNGKey(0), 2, 32, 2, 8, 4)
    y_ref, h_ref = naive_ssd(x, dt, a, bm, cm)
    y, h = S.ssd_chunked(x, dt, a, bm, cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=1e-4)


def test_decode_continues_chunked():
    """Prefill via chunked then decode steps == one long chunked pass."""
    x, dt, a, bm, cm = _inputs(jax.random.PRNGKey(1), 1, 24, 2, 8, 4)
    y_full, h_full = S.ssd_chunked(x, dt, a, bm, cm, chunk=8)
    y_pre, h = S.ssd_chunked(x[:, :16], dt[:, :16], a, bm[:, :16], cm[:, :16], chunk=8)
    ys = [y_pre]
    for t in range(16, 24):
        y_t, h = S.ssd_decode_step(
            x[:, t : t + 1], dt[:, t : t + 1], a, bm[:, t : t + 1], cm[:, t : t + 1], h
        )
        ys.append(y_t)
    y_cat = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_cat), np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_full), atol=1e-4)


def test_causal_conv_matches_padded():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (2, 10, 6))
    w = jax.random.normal(jax.random.PRNGKey(3), (4, 6)) * 0.3
    b = jnp.zeros((6,))
    y_full, state = S.causal_conv1d(x, w, b)
    # streaming: conv state carries the tail
    y1, st = S.causal_conv1d(x[:, :6], w, b)
    y2, st2 = S.causal_conv1d(x[:, 6:], w, b, state=st)
    y_cat = jnp.concatenate([y1, y2], axis=1)
    np.testing.assert_allclose(np.asarray(y_cat), np.asarray(y_full), atol=1e-5)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(state), atol=1e-6)


def test_segsum_lower_triangular():
    x = jnp.arange(1.0, 5.0)[None]
    out = S.segsum(x)[0]
    assert out.shape == (4, 4)
    assert float(out[2, 0]) == pytest.approx(2 + 3)  # sum over k in (0, 2]
    assert float(out[3, 3]) == 0.0
    assert np.isneginf(np.asarray(out)[0, 1])


def test_mamba_block_shapes():
    cfg = get_config("mamba2-370m").reduced()
    params = S.init_mamba(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, (h, conv) = S.mamba_apply(params, x, cfg)
    assert y.shape == x.shape
    sc = cfg.ssm
    assert h.shape == (2, sc.num_heads(cfg.d_model), sc.head_dim, sc.d_state)
    assert conv.shape[1] == sc.d_conv - 1
