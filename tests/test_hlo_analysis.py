"""HLO collective parser + roofline unit tests."""
import numpy as np

from repro.launch.hlo_analysis import (
    CollectiveStats,
    parse_collectives,
    roofline_from,
    split_computations,
    loop_multipliers,
)

HLO = """HloModule test, num_partitions=4

%body.1 (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %ag = f32[8,8]{1,0} all-gather(%x), channel_id=1, replica_groups=[2,2]<=[4], dimensions={0}
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %ag)
}

%cond.1 (p: (s32[], f32[8,8])) -> pred[] {
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %ar = f32[4,4]{1,0} all-reduce(%a), replica_groups=[1,4]<=[4], to_apply=%add
  %w = (s32[], f32[8,8]) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""


def test_split_computations():
    comps = split_computations(HLO)
    assert set(comps) == {"body.1", "cond.1", "main"}


def test_flat_parse():
    st = parse_collectives(HLO)
    # all-gather result 8*8*4 = 256 B, group size 2 -> wire 128
    assert st.result_bytes["all-gather"] == 256
    assert st.wire_bytes["all-gather"] == 128.0
    # all-reduce result 4*4*4 = 64 B, group 4 -> 2*(3/4)*64 = 96
    assert st.wire_bytes["all-reduce"] == 96.0


def test_loop_aware_parse():
    st = parse_collectives(HLO, loop_aware=True)
    assert st.counts["all-gather"] == 5  # trip count from backend_config
    assert st.wire_bytes["all-gather"] == 5 * 128.0
    assert st.counts["all-reduce"] == 1


def test_loop_multipliers_trip_fallback():
    hlo = HLO.replace(', backend_config={"known_trip_count":{"n":"5"}}', "")
    st = parse_collectives(hlo, loop_aware=True)
    assert st.counts["all-gather"] == 5  # constant(5) in the condition


def test_roofline_dominant():
    coll = CollectiveStats(
        result_bytes={"all-reduce": 10}, wire_bytes={"all-reduce": 1e9}, counts={}
    )
    r = roofline_from({"flops": 1e12, "bytes accessed": 1e9}, coll, 5e11)
    assert r.dominant == "collective"
    assert abs(r.compute_s - 1e12 / 197e12) < 1e-9
    assert r.useful_ratio == 0.5


def test_pipeline_collective_counts_synthetic():
    """Per-tick normalization: loop-aware issue counts divided by the
    schedule's tick count."""
    from repro.launch.hlo_analysis import pipeline_collective_counts

    per_tick = pipeline_collective_counts(HLO, n_ticks=5)
    assert per_tick["all-gather"] == 1.0  # 5 loop issues over 5 ticks
    assert per_tick["all-reduce"] == 1 / 5  # entry-level, outside the loop


def test_overlap_issues_no_more_collectives_than_sync(subproc):
    """Regression gate for the double-buffered transport (satellite d):
    compiling the overlapped 1F1B executor must not issue more
    collectives per tick (ppermute hops, psum reductions) than the
    synchronous handoff - overlap only MOVES the hop to the top of the
    tick."""
    out = subproc(
        """
import json
import jax, jax.numpy as jnp, numpy as np
from dataclasses import replace
from repro.configs import get_config
from repro.models import init_params
from repro.core.pipeline import PipelineConfig, make_stage_mesh, pipeline_step_fn
from repro.launch.hlo_analysis import pipeline_collective_counts

cfg = replace(get_config('qwen2.5-3b').reduced(), num_layers=4)
params = init_params(jax.random.PRNGKey(0), cfg)
mesh = make_stage_mesh(3)
rng = np.random.default_rng(0)
m = 3
tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (m * 2, 16)), jnp.int32)
labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (m * 2, 16)), jnp.int32)
bounds = (1, 3, 4)
ticks = m + 2 * (len(bounds) - 1)
counts = {}
for tr in ('sync', 'overlap'):
    fn = pipeline_step_fn(cfg, mesh, bounds, m,
                          pipe=PipelineConfig(transport=tr, compute_dtype='float32'))
    hlo = jax.jit(fn).lower(params, tokens, labels).compile().as_text()
    counts[tr] = pipeline_collective_counts(hlo, ticks)
assert any('permute' in k for k in counts['sync']), counts['sync']
assert set(counts['overlap']) <= set(counts['sync']), counts
for kind, sync_n in counts['sync'].items():
    assert counts['overlap'].get(kind, 0.0) <= sync_n + 1e-9, (kind, counts)
print('HLO_COUNTS_OK', json.dumps(counts))
""",
        n_devices=3,
    )
    assert "HLO_COUNTS_OK" in out
