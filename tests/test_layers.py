"""Attention/MLP/MoE layer unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ModelConfig, MoEConfig
from repro.models import layers as L


def _qkv(key, b, s, h, kh, hd, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, kh, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, kh, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("window", [None, 16])
@pytest.mark.parametrize("kh", [4, 2, 1])
def test_chunked_matches_dense(window, kh):
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, 96, 4, kh, 32)
    ref = L.dense_attention(q, k, v, q_offset=0, window=window)
    out = L.chunked_attention(q, k, v, window=window, q_chunk=32, kv_chunk=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_chunked_ragged_length():
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 67, 2, 2, 16)
    ref = L.dense_attention(q, k, v, q_offset=0)
    out = L.chunked_attention(q, k, v, q_chunk=32, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_rope_relative_property():
    """RoPE: attention score depends only on relative distance."""
    hd = 32
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    q = jax.random.normal(k1, (1, 1, 1, hd))
    k = jax.random.normal(k2, (1, 1, 1, hd))

    def score(qpos, kpos):
        cq, sq = L.rope_angles(jnp.array([qpos]), hd, 1e4)
        ck, sk = L.rope_angles(jnp.array([kpos]), hd, 1e4)
        qr = L.apply_rope(q, cq, sq)
        kr = L.apply_rope(k, ck, sk)
        return float(jnp.sum(qr * kr))

    assert abs(score(5, 3) - score(105, 103)) < 1e-4
    assert abs(score(7, 0) - score(17, 10)) < 1e-4


def test_rms_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8)) * 10
    w = jnp.ones((8,))
    y = L.rms_norm(x, w)
    rms = jnp.sqrt(jnp.mean(y * y, axis=-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, atol=1e-3)


def _moe_cfg(e=4, k=2, d=16, f=32):
    return ModelConfig(
        name="t", arch_type="moe", num_layers=2, d_model=d, num_heads=2,
        num_kv_heads=2, d_ff=0, vocab_size=64,
        moe=MoEConfig(num_experts=e, top_k=k, expert_d_ff=f, capacity_factor=4.0),
    )


def test_moe_matches_dense_computation():
    """With ample capacity, scatter-dispatch MoE == explicit per-expert loop."""
    cfg = _moe_cfg()
    params = L.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y, aux = L.moe_apply(params, x, cfg)

    # naive reference
    logits = jnp.einsum("bsd,de->bse", x, params["router"])
    probs = jax.nn.softmax(logits, -1)
    gv, ei = jax.lax.top_k(probs, cfg.moe.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    y_ref = jnp.zeros_like(x)
    for e in range(cfg.moe.num_experts):
        g = jax.nn.silu(x @ params["w_gate"][e]) * (x @ params["w_up"][e])
        fe = g @ params["w_down"][e]
        w = jnp.where(ei == e, gv, 0.0).sum(-1)
        y_ref = y_ref + fe * w[..., None]
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
    assert float(aux) >= 0


def test_moe_capacity_drops_tokens():
    """With capacity factor << 1, output magnitude shrinks (tokens dropped)."""
    cfg = _moe_cfg()
    from dataclasses import replace

    tight = replace(cfg, moe=replace(cfg.moe, capacity_factor=0.05))
    params = L.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y_full, _ = L.moe_apply(params, x, cfg)
    y_tight, _ = L.moe_apply(params, x, tight)
    assert float(jnp.abs(y_tight).sum()) < float(jnp.abs(y_full).sum())


def test_qkv_bias_used():
    cfg = get_config("qwen2.5-3b").reduced()
    p = L.init_attention(jax.random.PRNGKey(0), cfg)
    assert "bq" in p and "bk" in p and "bv" in p
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    out0, _ = L.attention_apply(p, x, cfg, positions=jnp.arange(8))
    p2 = dict(p)
    p2["bq"] = p["bq"] + 1.0
    out1, _ = L.attention_apply(p2, x, cfg, positions=jnp.arange(8))
    assert float(jnp.abs(out0 - out1).max()) > 1e-6


def test_activations():
    x = jnp.array([-2.0, 0.0, 3.0])
    np.testing.assert_allclose(np.asarray(L.activation_fn("relu2")(x)), [0.0, 0.0, 9.0])
