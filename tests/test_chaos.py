"""Chaos invariants: SIGKILL-resume training, fault-injected serving,
torn checkpoint writes.

The three acceptance criteria of the fault-injection subsystem:

* a training run SIGKILLed mid-chunk and resumed from its checkpoint
  directory finishes with metric trajectories BIT-IDENTICAL to an
  uninterrupted run (``launch.chaos`` harness, exercised in-process via
  its own subprocess machinery);
* serving under a fault schedule completes every request, adds zero
  engine retraces, and requests the outage never touched (and even the
  evicted ones, thanks to rid-keyed sampling) produce tokens bitwise
  identical to the fault-free run;
* a SIGKILL landing mid-``save_pytree`` can never leave a torn archive
  where a resumable checkpoint is expected (atomic temp + rename).
"""
import json
import os

import numpy as np
import pytest

import jax

from repro.checkpoint.store import load_pytree, save_pytree
from repro.checkpoint.train_state import (latest_checkpoint_step,
                                          save_train_checkpoint)


# ---------------------------------------------------------------------------
# SIGKILL mid-chunk + resume == uninterrupted, bit-identical


def test_sigkill_resume_metrics_bit_identical(tmp_path):
    """The full kill-and-resume dance through the ``launch.chaos``
    harness: launch a checkpointed training subprocess, SIGKILL it after
    its first resumable checkpoint, relaunch into the same directory,
    and compare against an uninterrupted reference run element-for-
    element (float equality, no tolerance)."""
    from repro.launch import chaos

    rc = chaos.main([
        "--dir", str(tmp_path), "--seed", "5", "--episodes", "8",
        "--warmup", "4", "--num-envs", "2", "--checkpoint-every", "2",
        "--kill-after", "2", "--timeout", "420",
    ])
    assert rc == 0


# ---------------------------------------------------------------------------
# fault-injected serving: untouched requests bitwise, zero retraces


def _serve_pair():
    from repro.core import faults as F
    from repro.serving import ServeConfig, ServingService, poisson_trace

    cfg = ServeConfig(num_slots=3, arrival_slots=2, prompt_pad=8, max_new=8,
                      decode_chunk=2, fault_tick_s=0.02, max_retries=2,
                      retry_backoff_s=0.005)
    svc_free = ServingService(cfg)
    trace = poisson_trace(n_requests=7, rate_per_sec=50.0,
                          vocab_size=svc_free.model_cfg.vocab_size,
                          plen_range=(2, 8), gen_range=(2, 8), seed=3)
    free = svc_free.run(list(trace))
    svc_faulted = ServingService(cfg)
    sched = F.reference_schedule(1, 1, tick_seconds=cfg.fault_tick_s)
    faulted = svc_faulted.run(list(trace), faults=sched)
    return trace, free, faulted, svc_free, svc_faulted


def test_serving_fault_injection_invariants():
    trace, free, faulted, svc_free, svc_faulted = _serve_pair()
    # every request completes despite the outage
    assert faulted["num_requests"] == len(trace) == free["num_requests"]
    # the outage actually fired and was recovered from
    assert faulted["fault_events"] >= 1
    assert faulted["recovery_ticks"] >= 1
    assert faulted["retries"] >= 1
    # zero retraces: injection, eviction, and recovery all ran through
    # the single compiled engine trace
    assert svc_faulted.step.trace_count == [1]
    assert svc_free.step.trace_count == [1]
    # fault-free runs report zeroed failure accounting
    assert free["fault_events"] == 0 and free["evictions"] == 0
    assert free["recovery_ticks"] == 0 and free["expired"] == []
    # completions bitwise identical to the fault-free run - for EVERY
    # request: untouched ones by slot-content independence, evicted ones
    # because per-(rid, token) sampling keys make the regenerated stream
    # identical to the lost one
    for r in trace:
        assert np.array_equal(free["completions"][r.rid],
                              faulted["completions"][r.rid]), r.rid


def test_serving_deadline_expiry():
    """A request whose deadline passes while it waits in the queue is
    dropped and reported, not admitted."""
    from repro.serving import Request, ServeConfig, ServingService

    cfg = ServeConfig(num_slots=2, arrival_slots=2, prompt_pad=8, max_new=4,
                      decode_chunk=2)
    svc = ServingService(cfg)
    v = svc.model_cfg.vocab_size
    rng = np.random.default_rng(0)
    mk = lambda rid, t, dl: Request(
        rid=rid, prompt=rng.integers(0, v, 4).astype(np.int32),
        gen_target=3, arrival_time=t, deadline=dl)
    # rid 1's deadline is BEFORE its arrival: it must expire untouched
    trace = [mk(0, 0.0, float("inf")), mk(1, 0.05, 0.01)]
    res = svc.run(trace)
    assert res["expired"] == [1]
    assert sorted(res["completions"]) == [0]


def test_serving_empty_trace_and_zero_pop():
    from repro.serving import RequestQueue, ServeConfig, ServingService

    q = RequestQueue([])
    assert q.pop(0) == [] and q.pop(-3) == [] and q.peek(5) == []
    assert q.exhausted
    svc = ServingService(ServeConfig(num_slots=2, arrival_slots=1,
                                     prompt_pad=8, max_new=4,
                                     decode_chunk=2))
    res = svc.run([])
    assert res["num_requests"] == 0 and res["ticks"] == 0
    # percentiles are 0.0, not NaN (JSON gates choke on NaN)
    assert res["p50_latency_s"] == 0.0 and res["p99_latency_s"] == 0.0


# ---------------------------------------------------------------------------
# torn-write regression: atomic save_pytree


@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnraisableExceptionWarning")
def test_save_pytree_is_atomic_under_interrupt(tmp_path):
    """Simulate a SIGKILL mid-save: interrupt the write at every byte
    boundary the implementation flushes through, and the destination
    must either hold the OLD complete archive or not exist - never a
    torn half-archive."""
    tree = {"a": np.arange(100, dtype=np.float32),
            "b": np.ones((32, 32), np.float32)}
    path = os.path.join(tmp_path, "ck.npz")
    save_pytree(tree, path)
    good = open(path, "rb").read()

    # a crash BEFORE the rename leaves the old archive intact: emulate by
    # failing the temp write partway
    import repro.checkpoint.store as store

    class Boom(RuntimeError):
        pass

    real_open = open
    calls = {"n": 0}

    class TornFile:
        """Write-limited file wrapper: the Nth flush dies mid-archive."""

        def __init__(self, f):
            self._f = f

        def write(self, data):
            calls["n"] += 1
            if calls["n"] > 1:
                raise Boom()
            return self._f.write(data)

        def __getattr__(self, name):
            return getattr(self._f, name)

        def __enter__(self):
            self._f.__enter__()
            return self

        def __exit__(self, *a):
            return self._f.__exit__(*a)

    def exploding_open(p, mode="r", *a, **kw):
        f = real_open(p, mode, *a, **kw)
        if str(p).endswith(".tmp") and "w" in mode:
            return TornFile(f)
        return f

    tree2 = {"a": np.zeros(100, dtype=np.float32),
             "b": np.zeros((32, 32), np.float32)}
    store.open = exploding_open  # shadows the builtin inside the module
    try:
        with pytest.raises(Boom):
            save_pytree(tree2, path)
    finally:
        del store.open
    # old archive untouched, temp file cleaned up
    assert open(path, "rb").read() == good
    assert not os.path.exists(path + ".tmp")
    restored = load_pytree(path, tree)
    assert np.array_equal(np.asarray(restored["a"]), tree["a"])


def test_garbage_latest_and_orphan_json_fall_back(tmp_path):
    """A crash between the (atomic) npz write and the json write leaves
    an orphan half; a torn LATEST write leaves garbage. Neither may be
    offered for resume - the scan falls back to the newest COMPLETE
    step instead of crashing or resuming a half-checkpoint."""
    d = str(tmp_path)
    state = {"w": np.arange(8, dtype=np.float32)}
    save_train_checkpoint(d, 2, state, {"ep": 2, "meta": {}})
    save_train_checkpoint(d, 4, state, {"ep": 4, "meta": {}})
    # orphan step 6: json without its npz (the npz write never landed,
    # atomicity guarantees no partial file), plus a garbage LATEST
    with open(os.path.join(d, "step_00000006.json"), "w") as f:
        json.dump({"step": 6, "ep": 6, "meta": {}}, f)
    with open(os.path.join(d, "LATEST"), "w") as f:
        f.write("not-a-step")
    assert latest_checkpoint_step(d) == 4


def test_save_train_checkpoint_npz_is_atomic(tmp_path):
    """The train-state writer inherits store atomicity: after any
    completed save, the npz under the step path is a loadable archive
    (np.load validates the zip directory)."""
    d = str(tmp_path)
    state = {"w": np.arange(8, dtype=np.float32),
             "k": jax.random.PRNGKey(0)}
    save_train_checkpoint(d, 1, state, {"ep": 1, "meta": {}})
    p = os.path.join(d, "step_00000001.npz")
    with np.load(p, allow_pickle=False) as z:
        assert "__manifest__" in z
    assert latest_checkpoint_step(d) == 1
