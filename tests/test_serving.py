"""Serving engine: bit-identity, slot reuse, trace audits, re-planning.

The contract under test (measured on the CPU backend, leaned on by the
engine design):

* per-ROW float results at a FIXED batch shape are bitwise invariant to
  the other rows' contents and to which row a request occupies;
* therefore the single-request reference (``generate_reference``: the
  request alone in a batch padded to the engine's slot count) must match
  the engine's output for that request BITWISE, no matter when it
  arrived, which slot it landed in, or what stale garbage the slot's KV
  ring held;
* the engine step stays ONE compiled trace across arrivals, completions,
  idle ticks, and re-plans (all shapes static).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.model import init_params
from repro.serving import (
    ServeConfig, ServingService, SlotScheduler, RequestQueue, Request,
    SingleDeviceRunner, generate_reference, generate_static,
    decode_python_loop, poisson_trace,
)
from repro.serving.engine import init_engine_state, make_engine_step
from repro.serving.runners import check_servable


def _model(num_layers=2):
    cfg = ServeConfig(num_layers=num_layers).model_config()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _trace(cfg, n=6, seed=3, rate=50.0, plen=(2, 8), gen=(2, 8)):
    return poisson_trace(n_requests=n, rate_per_sec=rate,
                         vocab_size=cfg.vocab_size, plen_range=plen,
                         gen_range=gen, seed=seed)


# ---------------------------------------------------------------------------
# vector cache_index: the per-slot decode primitive


def test_vector_cache_index_bitwise_matches_scalar():
    """Decoding B rows at a COMMON position through the vector-(B,)
    cache_index path must be bitwise the scalar-index path (the vector
    path only generalizes the mask/position arithmetic)."""
    cfg, params = _model()
    runner = SingleDeviceRunner(cfg)
    b, p = 3, 6
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, p)), jnp.int32)
    caches = runner.init_caches(b, p + 4)
    _, caches = runner.prefill(params, caches, prompts)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, 1)), jnp.int32)

    lg_s, c_s, _ = M.forward(params, tok, cfg, caches=caches,
                             cache_index=jnp.asarray(p, jnp.int32),
                             compute_dtype=jnp.float32)
    lg_v, c_v, _ = M.forward(params, tok, cfg, caches=caches,
                             cache_index=jnp.full((b,), p, jnp.int32),
                             compute_dtype=jnp.float32)
    assert jnp.array_equal(lg_s, lg_v)
    for a, bb in zip(jax.tree.leaves(c_s), jax.tree.leaves(c_v)):
        assert jnp.array_equal(a, bb)


def test_check_servable_moe_and_ssm_gates():
    """PR 9 servability matrix: SSM/hybrid stay rejected (padded prefill
    pollutes recurrent state), MoE serves under dropless dispatch ONLY
    (capacity buffers let padding rows steal expert capacity)."""
    from dataclasses import replace
    from repro.configs import get_config

    with pytest.raises(ValueError, match="SSM/hybrid"):
        check_servable(get_config("jamba-v0.1-52b").reduced())
    with pytest.raises(ValueError, match="SSM/hybrid"):
        check_servable(get_config("mamba2-370m").reduced())
    moe = get_config("qwen3-moe-30b-a3b").reduced()
    check_servable(moe)  # dropless (the default): servable
    with pytest.raises(ValueError, match="dropless"):
        check_servable(replace(moe, moe=replace(moe.moe, dispatch="capacity")))


# ---------------------------------------------------------------------------
# fused decode scan vs the v0 per-token loop


def test_fused_generate_matches_python_loop():
    cfg, params = _model()
    runner = SingleDeviceRunner(cfg)
    rng = np.random.default_rng(1)
    b, p, g = 4, 6, 8
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, p)), jnp.int32)
    plens = jnp.asarray([6, 3, 5, 2], jnp.int32)
    prompts = prompts * (jnp.arange(p)[None, :] < plens[:, None])
    gens = jnp.asarray([8, 2, 5, 1], jnp.int32)

    fused, n_f = generate_static(runner, params, prompts, plens, gens,
                                 max_new=g)
    loop, n_l = decode_python_loop(runner, params, prompts, plens, gens,
                                   max_new=g)
    assert jnp.array_equal(n_f, n_l)
    assert jnp.array_equal(fused, loop)


# ---------------------------------------------------------------------------
# engine vs single-request reference, bitwise


def _check_engine_vs_reference(temperature):
    cfg = ServeConfig(num_slots=3, arrival_slots=2, prompt_pad=8, max_new=8,
                      decode_chunk=2, temperature=temperature)
    svc = ServingService(cfg)
    # 7 requests through 3 slots: arrivals land mid-flight of earlier
    # requests and every slot is reused at least once
    trace = _trace(svc.model_cfg, n=7)
    res = svc.run(trace)
    assert res["num_requests"] == len(trace)
    for r in trace:
        ref = generate_reference(
            svc.runner, svc.params, r.prompt, gen_target=r.gen_target,
            max_new=cfg.max_new, prompt_pad=cfg.prompt_pad,
            slots=cfg.num_slots, temperature=temperature,
            base_key=svc.base_key, req_id=r.rid)
        got = res["completions"][r.rid]
        assert np.array_equal(got, np.asarray(ref)), (
            f"request {r.rid}: engine {got} != reference {np.asarray(ref)}")
    # the whole service ran on one compiled engine trace
    assert len(svc.step.trace_count) == 1


def test_engine_bitwise_matches_reference_greedy():
    _check_engine_vs_reference(0.0)


def test_engine_bitwise_matches_reference_sampled():
    """Temperature sampling: per-(request, token) keys are slot- and
    tick-independent, so the engine consumes the same stream as the
    reference."""
    _check_engine_vs_reference(0.7)


def test_slot_reuse_survives_poisoned_stale_cache():
    """Freed slots are NOT zeroed; correctness rests on stale FINITE
    values being masked into exact-zero attention weights. Poison every
    KV ring with large finite garbage between requests and the next
    request must still match the reference bitwise."""
    cfg, params = _model()
    runner = SingleDeviceRunner(cfg)
    n, p, g = 2, 6, 6
    key = jax.random.PRNGKey(0)
    step = make_engine_step(runner, num_slots=n, arrival_slots=1,
                            prompt_pad=p, max_new=g, decode_chunk=3,
                            base_key=key)
    jstep = jax.jit(step)
    state = init_engine_state(runner, n, p, g)
    rng = np.random.default_rng(5)

    def admit_and_drain(state, rid, prompt, gen):
        ap = np.zeros((1, p), np.int32)
        ap[0, :len(prompt)] = prompt
        args = (jnp.asarray(ap), jnp.asarray([len(prompt)], jnp.int32),
                jnp.asarray([gen], jnp.int32), jnp.asarray([rid], jnp.int32))
        state, rep = jstep(params, state, *args, jnp.int32(1))
        while bool(np.asarray(rep["active"]).any()):
            state, rep = jstep(params, state, *(jnp.zeros_like(a) for a in args),
                               jnp.int32(0))
        slot = int(np.asarray(rep["req_id"]).tolist().index(rid))
        ngen = int(np.asarray(rep["n_gen"])[slot])
        return state, np.asarray(state.gen_buf)[slot, :ngen]

    pr_a = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
    state, _ = admit_and_drain(state, 0, pr_a, 4)

    # poison EVERY slot's KV ring with large finite garbage
    state = state._replace(caches=jax.tree.map(
        lambda c: jnp.full_like(c, 1e4), state.caches))

    pr_b = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
    state, got = admit_and_drain(state, 1, pr_b, 5)
    ref = generate_reference(runner, params, pr_b, gen_target=5, max_new=g,
                             prompt_pad=p, slots=n, base_key=key, req_id=1)
    assert np.array_equal(got, np.asarray(ref))
    assert len(step.trace_count) == 1


# ---------------------------------------------------------------------------
# host-side scheduler / queue / trace units


def test_scheduler_packs_bounded_by_free_slots():
    q = RequestQueue([Request(rid=i, prompt=np.arange(3, dtype=np.int32),
                              gen_target=2, arrival_time=0.0)
                      for i in range(5)])
    q.advance(1.0)
    sched = SlotScheduler(arrival_slots=4, prompt_pad=8)
    reqs, ap, al, ag, ar, n_arr = sched.pack(q, free_slots=2)
    assert [r.rid for r in reqs] == [0, 1] and n_arr == 2
    assert ap.shape == (4, 8) and list(ar) == [0, 1, -1, -1]
    reqs, *_, n_arr = sched.pack(q, free_slots=99)  # capped by arrival_slots
    assert [r.rid for r in reqs] == [2, 3, 4] and n_arr == 3
    assert q.exhausted


def test_scheduler_rejects_overlong_prompt():
    q = RequestQueue([Request(rid=0, prompt=np.zeros(9, np.int32),
                              gen_target=1)])
    q.advance(0.0)
    with pytest.raises(ValueError, match="exceeds prompt_pad"):
        SlotScheduler(arrival_slots=1, prompt_pad=8).pack(q, 1)


def test_poisson_trace_shapes_and_config_roundtrip(tmp_path):
    tr = poisson_trace(n_requests=10, rate_per_sec=5.0, vocab_size=64,
                       plen_range=(2, 6), gen_range=(1, 4), seed=0)
    times = [r.arrival_time for r in tr]
    assert times == sorted(times) and times[0] > 0
    assert all(2 <= r.plen <= 6 and 1 <= r.gen_target <= 4 for r in tr)

    path = tmp_path / "serve.json"
    path.write_text('{"num_slots": 16, "boundaries": [1, 2]}')
    cfg = ServeConfig.load(str(path), {"decode_chunk": 2})
    assert (cfg.num_slots, cfg.decode_chunk, cfg.boundaries) == (16, 2, (1, 2))
    with pytest.raises(KeyError):
        ServeConfig.load(None, {"num_slotz": 4})


# ---------------------------------------------------------------------------
# online re-planner


def test_replanner_matches_fresh_scoring_zero_recompile():
    from repro.core.env import MHSLEnv
    from repro.core.profiles import resnet101_profile
    from repro.serving import OnlineReplanner

    env = MHSLEnv(profile=resnet101_profile(batch=1))
    rp = OnlineReplanner(env, bandwidth_sensitivity=0.5, energy_drain=0.0)
    decisions = [rp.replan(load=l) for l in (0.0, 0.4, 0.9)]
    # shifting load shifted the scenario, all through ONE compiled trace
    assert rp.trace_count[0] == 1
    assert all(d["num_plans"] == decisions[0]["num_plans"] for d in decisions)

    # decision must equal a FRESH scoring pass under the same shifted
    # scenario (independent oracle instance)
    fresh = env.make_split_oracle()
    for load, dec in zip((0.0, 0.4, 0.9), decisions):
        out = fresh(rp.dev_pos, rp.devices, rp.p_tx, rp.decoy_power,
                    rp.shifted_scenario(load))
        delay = np.asarray(out["delay"])
        feas = np.asarray(out["feasible"])
        best = int(np.argmin(np.where(feas, delay, np.inf)))
        assert dec["boundaries"] == tuple(
            int(b) for b in np.asarray(out["boundaries"])[best])
        assert dec["delay"] == pytest.approx(delay[best], rel=0, abs=0)

    # heavier load can only tighten the delay-optimal plan's delay
    assert decisions[2]["delay"] >= decisions[0]["delay"]


def test_service_replan_cadence():
    cfg = ServeConfig(num_slots=2, arrival_slots=2, prompt_pad=8, max_new=4,
                      decode_chunk=4, replan_every=1)
    svc = ServingService(cfg)
    from repro.core.env import MHSLEnv
    from repro.core.profiles import resnet101_profile
    from repro.serving import OnlineReplanner

    svc.attach_replanner(OnlineReplanner(
        MHSLEnv(profile=resnet101_profile(batch=1))))
    res = svc.run(_trace(svc.model_cfg, n=3, gen=(1, 4)))
    assert res["num_requests"] == 3
    assert len(res["replans"]) == res["ticks"]
    assert all(len(r["boundaries"]) > 0 for r in res["replans"])
    assert svc.replanner.trace_count[0] == 1


# ---------------------------------------------------------------------------
# pipeline serving (clean subprocess: forced stage devices)


def test_pipeline_engine_matches_single_device(subproc):
    """The split engine (per-stage KV rings, activations on the wire)
    serves bitwise the same tokens as the single-device engine at
    f32 compute / f32 wire."""
    out = subproc(
        """
import numpy as np
from repro.serving import ServeConfig, ServingService, poisson_trace

kw = dict(num_slots=3, arrival_slots=2, prompt_pad=8, max_new=8,
          decode_chunk=2)
single = ServingService(ServeConfig(**kw))
piped = ServingService(ServeConfig(boundaries=(1, 2), **kw))
trace = poisson_trace(n_requests=5, rate_per_sec=50.0,
                      vocab_size=single.model_cfg.vocab_size,
                      plen_range=(2, 8), gen_range=(2, 8), seed=3)
a = single.run(list(trace))
b = piped.run(list(trace))
assert set(a["completions"]) == set(b["completions"])
for rid in a["completions"]:
    assert np.array_equal(a["completions"][rid], b["completions"][rid]), rid
assert len(piped.step.trace_count) == 1
print("PIPE_SERVE_OK", len(a["completions"]))
""",
        n_devices=2)
    assert "PIPE_SERVE_OK 5" in out


def test_pipeline_serve_moe_prefill_bitwise(subproc):
    """MoE stages through the serving token ring (PR 9): dropless
    dispatch makes every row per-row independent, so the padded pipeline
    prefill must be BITWISE the plain forward pass - for the pure-MoE
    period-1 config AND a mixed MoE/dense period-2 stack - and a decode
    tick must produce finite logits. Capacity dispatch is refused."""
    out = subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from dataclasses import replace
from repro.configs import get_config
from repro.core.pipeline import (
    PipelineConfig, make_stage_mesh, pipeline_serve_fns, stage_kv_caches)
from repro.models import model as M
from repro.models.model import init_params

mesh = make_stage_mesh(2)
base = get_config('qwen3-moe-30b-a3b').reduced()
cases = {
    'period1': replace(base, num_layers=2),
    'period2_mixed': replace(base, num_layers=4, d_ff=96,
                             moe=replace(base.moe, moe_every=2)),
}
for name, cfg in cases.items():
    bounds = (1, cfg.num_layers)
    params = init_params(jax.random.PRNGKey(0), cfg)
    prefill, decode = pipeline_serve_fns(cfg, mesh, bounds)
    b, p = 2, 8
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, p)), jnp.int32)
    caches = stage_kv_caches(cfg, bounds, b, p + 4)
    lg, caches = jax.jit(prefill)(params, caches, prompts)
    ref, _, _ = M.forward(params, prompts, cfg, compute_dtype=jnp.float32)
    assert jnp.array_equal(lg, ref), name
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, 1)), jnp.int32)
    dlg, caches = jax.jit(decode)(params, tok, caches,
                                  jnp.full((b,), p, jnp.int32))
    assert bool(jnp.all(jnp.isfinite(dlg))), name
try:
    pipeline_serve_fns(
        replace(cases['period1'],
                moe=replace(base.moe, dispatch='capacity')), mesh, (1, 2))
    raise SystemExit('capacity MoE dispatch not refused')
except ValueError as e:
    assert 'dropless' in str(e)
print('MOE_SERVE_OK', len(cases))
""",
        n_devices=2)
    assert "MOE_SERVE_OK 2" in out
