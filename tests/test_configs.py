"""Config system: published sizes, reductions, shape registry."""
import pytest

from repro.configs import ARCH_IDS, INPUT_SHAPES, all_configs, get_config, get_shape

# published total / active parameter counts (billions) with tolerance
PUBLISHED = {
    "qwen3-moe-235b-a22b": (235.0, 22.0),
    "nemotron-4-340b": (340.0, 340.0),
    "qwen2.5-3b": (3.1, 3.1),
    "jamba-v0.1-52b": (52.0, 12.0),
    "minitron-4b": (4.2, 4.2),
    "pixtral-12b": (12.3, 12.3),
    "musicgen-large": (2.4, 2.4),  # decoder backbone only (frontend stubbed)
    "mamba2-370m": (0.37, 0.37),
    "stablelm-1.6b": (1.6, 1.6),
    "qwen3-moe-30b-a3b": (30.5, 3.3),
}


def test_all_archs_registered():
    assert len(ARCH_IDS) == 10
    cfgs = all_configs()
    assert set(cfgs) == set(ARCH_IDS)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_counts_match_published(arch):
    cfg = get_config(arch)
    total, active = PUBLISHED[arch]
    got_total = cfg.param_count() / 1e9
    got_active = cfg.active_param_count() / 1e9
    assert abs(got_total - total) / total < 0.08, (arch, got_total, total)
    assert abs(got_active - active) / active < 0.12, (arch, got_active, active)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_variants_are_smoke_sized(arch):
    rc = get_config(arch).reduced()
    assert rc.num_layers == 2
    assert rc.d_model <= 512
    if rc.moe.enabled:
        assert rc.moe.num_experts <= 4
    assert rc.vocab_size <= 1024


def test_shapes_registry():
    assert [s.name for s in INPUT_SHAPES] == [
        "train_4k", "prefill_32k", "decode_32k", "long_500k",
    ]
    s = get_shape("train_4k")
    assert s.seq_len == 4096 and s.global_batch == 256 and s.kind == "train"
    s = get_shape("long_500k")
    assert s.seq_len == 524288 and s.global_batch == 1 and s.kind == "decode"
    with pytest.raises(KeyError):
        get_shape("nope")


def test_pattern_structure():
    jamba = get_config("jamba-v0.1-52b")
    assert jamba.pattern.count("A") == 4  # 1:7 attention:mamba over 32 layers
    assert jamba.pattern.count("M") == 28
    mamba = get_config("mamba2-370m")
    assert set(mamba.pattern) == {"M"}
    assert get_config("qwen2.5-3b").qkv_bias
    assert get_config("nemotron-4-340b").activation == "relu2"
