"""Split-plan helpers, optimizer, checkpoint, data pipeline tests."""
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip, unit tests still run
    from _hypothesis_compat import given, settings, st

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.core.profiles import resnet101_profile, transformer_profile
from repro.core.splitting import (
    SplitPlan,
    boundary_bits,
    enumerate_boundaries,
    even_boundaries,
    make_plan_scorer,
    plan_cost,
    score_plans,
    stack_boundaries,
    stage_sums,
)
from repro.core.channel import NetworkConfig
from repro.data import input_specs, synthetic_batch
from repro.optim import adamw, clip_by_global_norm, linear_warmup_cosine, sgd_momentum
from repro.optim.optimizers import apply_updates, global_norm


@given(L=st.integers(4, 12), s=st.integers(2, 4))
@settings(max_examples=20, deadline=None)
def test_enumerate_boundaries_count(L, s):
    plans = list(enumerate_boundaries(L, s))
    assert len(plans) == math.comb(L - 1, s - 1)
    for p in plans:
        assert p[-1] == L
        assert all(b2 > b1 for b1, b2 in zip(p, p[1:]))


@given(s=st.integers(2, 6))
@settings(max_examples=10, deadline=None)
def test_stage_sums_conservation(s):
    prof = resnet101_profile(batch=1)
    b = even_boundaries(prof.num_layers, s)
    for field in ("param_bytes", "fwd_flops", "bwd_flops"):
        total = stage_sums(prof, b, field).sum()
        assert total == pytest.approx(getattr(prof, field).sum(), rel=1e-9)


def test_plan_cost_monotone_in_bits():
    prof = resnet101_profile(batch=1)
    net = NetworkConfig()
    rng = np.random.default_rng(0)
    pos = rng.uniform(0, 800, (net.num_devices + 1, 2))
    plan = SplitPlan(boundaries=even_boundaries(prof.num_layers, 4), devices=(0, 1, 2, 6))
    p_tx = np.full(3, 0.5)
    decoy = np.zeros((3, net.num_devices + 1))
    t1, e1 = plan_cost(prof, plan, pos, p_tx, decoy, net)
    # doubling all activation bytes doubles hop times
    import dataclasses

    prof2 = dataclasses.replace(
        prof, act_bytes=prof.act_bytes * 2, grad_bytes=prof.grad_bytes * 2
    )
    t2, e2 = plan_cost(prof2, plan, pos, p_tx, decoy, net)
    assert t2 > t1 and e2 > e1


def _score_setup(s, seed=0):
    net = NetworkConfig()
    u = net.num_devices
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0, net.area_m, (u + 1, 2))
    devices = tuple(range(s - 1)) + (u,)
    p_tx = np.linspace(0.2, 1.0, s - 1)
    decoy = np.zeros((s - 1, u + 1))
    decoy[:, s] = 0.2
    return net, pos, devices, p_tx, decoy


@pytest.mark.parametrize("L,s", [(6, 2), (8, 3), (7, 4)])
def test_score_plans_matches_plan_cost_full_enumeration(L, s):
    """The vectorized scorer reproduces the python plan_cost loop over the
    ENTIRE enumeration (both sides share the hoisted cumulative tables, so
    the stage sums are identical; remaining diffs are f32 vs host-float64
    summation order at ~1e-7 relative)."""
    prof = resnet101_profile(batch=1)
    net, pos, devices, p_tx, decoy = _score_setup(s)
    bounds = stack_boundaries(L, s)
    ref = np.asarray([
        plan_cost(prof, SplitPlan(tuple(int(x) for x in b), devices), pos,
                  p_tx, decoy, net)
        for b in bounds
    ])
    t, e = score_plans(prof, bounds, np.asarray(devices), pos, p_tx, decoy, net)
    np.testing.assert_allclose(np.asarray(t), ref[:, 0], rtol=2e-6)
    np.testing.assert_allclose(np.asarray(e), ref[:, 1], rtol=2e-6)


@pytest.mark.parametrize("arch", ["qwen3-moe-30b-a3b", "jamba-v0.1-52b"])
def test_state_priced_score_plans_matches_plan_cost(arch):
    """Architecture-aware pricing parity: with a nonzero
    ``state_cycles_per_bit`` the vectorized scorer must still reproduce
    the python ``plan_cost`` loop over a heterogeneous profile (KV vs SSM
    state vs resident MoE expert banks all priced through
    ``ProfileTable.state_cum``), and the pricing must actually BITE -
    plan delays strictly above the unpriced ones."""
    from dataclasses import replace

    s = 3
    prof = transformer_profile(get_config(arch), batch=1, seq=512)
    assert float(np.asarray(prof.state_bytes).sum()) > 0
    net0, pos, devices, p_tx, decoy = _score_setup(s)
    net = replace(net0, state_cycles_per_bit=0.01)
    bounds = stack_boundaries(prof.num_layers, s)[::7].copy()
    ref = np.asarray([
        plan_cost(prof, SplitPlan(tuple(int(x) for x in b), devices), pos,
                  p_tx, decoy, net)
        for b in bounds
    ])
    t, e = score_plans(prof, bounds, np.asarray(devices), pos, p_tx, decoy, net)
    np.testing.assert_allclose(np.asarray(t), ref[:, 0], rtol=2e-6)
    np.testing.assert_allclose(np.asarray(e), ref[:, 1], rtol=2e-6)
    t0, e0 = score_plans(prof, bounds, np.asarray(devices), pos, p_tx, decoy,
                         net0)
    assert np.all(np.asarray(t) > np.asarray(t0))
    assert np.all(np.asarray(e) > np.asarray(e0))


def test_plan_scorer_single_trace_across_sweeps():
    """Boundary-sweep recompile audit: re-scoring different boundary
    batches, positions, powers, AND ScenarioParams values reuses ONE
    compiled trace (the ISSUE's acceptance pin: trace_count == 1)."""
    from repro.core.scenario import scenario_from_net

    prof = resnet101_profile(batch=1)
    net, pos, devices, p_tx, decoy = _score_setup(4)
    scorer = make_plan_scorer(prof)
    bounds = stack_boundaries(10, 4)
    scorer(bounds, np.asarray(devices), pos, p_tx, decoy, net)
    # boundary sweep: same shape, different cut points
    scorer(bounds[::-1].copy(), np.asarray(devices), pos, p_tx, decoy, net)
    # geometry + power sweep
    scorer(bounds, np.asarray(devices), pos * 0.5, p_tx * 2.0, decoy, net)
    # scenario sweep (bandwidth + budget changes as pytree leaves)
    sp = scenario_from_net(net)._replace(
        bandwidth_hz=jnp.asarray(2e6, jnp.float32),
        gamma_t=jnp.asarray(4.0, jnp.float32),
    )
    scorer(bounds, np.asarray(devices), pos, p_tx, decoy, sp)
    assert scorer.trace_count[0] == 1


def test_env_split_oracle_consistent_with_plan_cost():
    """The env's device-side split oracle scores the full enumeration and
    its budget mask agrees with per-plan plan_cost against the budgets."""
    from repro.core.env import MHSLEnv

    prof = resnet101_profile(batch=1)
    env = MHSLEnv(profile=prof)
    net, pos, devices, p_tx, decoy = _score_setup(env.S)
    oracle = env.make_split_oracle()
    out = oracle(jnp.asarray(pos), np.asarray(devices), p_tx, decoy)
    n_plans = math.comb(prof.num_layers - 1, env.S - 1)
    assert out["boundaries"].shape == (n_plans, env.S)
    assert out["delay"].shape == (n_plans,)
    # spot-check a handful of plans against the host reference
    idx = np.linspace(0, n_plans - 1, 7).astype(int)
    for i in idx:
        b = tuple(int(x) for x in out["boundaries"][i])
        t_ref, e_ref = plan_cost(prof, SplitPlan(b, devices), pos, p_tx,
                                 decoy, net)
        np.testing.assert_allclose(float(out["delay"][i]), t_ref, rtol=2e-6)
        np.testing.assert_allclose(float(out["energy"][i]), e_ref, rtol=2e-6)
        assert bool(out["feasible"][i]) == (
            (t_ref <= net.gamma_t) and (e_ref <= net.gamma_e)
        )
    # scenario sweep through the oracle stays on the same trace
    sp = env.scenario()._replace(gamma_t=jnp.asarray(1e9, jnp.float32),
                                 gamma_e=jnp.asarray(1e9, jnp.float32))
    out2 = oracle(jnp.asarray(pos), np.asarray(devices), p_tx, decoy, sp)
    assert bool(out2["feasible"].all())
    assert oracle.trace_count[0] == 1


def test_adamw_optimizes_quadratic():
    opt = adamw(0.1)
    params = {"x": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
        ups, state = opt.update(grads, state, params)
        params = apply_updates(params, ups)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


def test_sgd_momentum_optimizes():
    opt = sgd_momentum(0.05)
    params = {"x": jnp.array([2.0])}
    state = opt.init(params)
    for _ in range(100):
        grads = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
        ups, state = opt.update(grads, state, params)
        params = apply_updates(params, ups)
    assert abs(float(params["x"][0])) < 1e-2


@given(scale=st.floats(0.1, 100.0))
@settings(max_examples=20, deadline=None)
def test_clip_by_global_norm(scale):
    tree = {"a": jnp.ones((3,)) * scale, "b": jnp.ones((2, 2)) * scale}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-5


def test_lr_schedule():
    lr = linear_warmup_cosine(1e-3, warmup=10, total_steps=110)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-5)
    assert float(lr(110)) < float(lr(50))


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import load_pytree, save_pytree

    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nest": {"b": jnp.ones((4,), jnp.bfloat16)},
        "t": (jnp.zeros((2,)), jnp.array(3, jnp.int32)),
    }
    path = os.path.join(tmp_path, "ckpt.npz")
    save_pytree(tree, path)
    loaded = load_pytree(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
    # shape mismatch is rejected
    bad = dict(tree)
    bad["a"] = jnp.zeros((3, 3))
    with pytest.raises(ValueError):
        load_pytree(path, bad)


def test_synthetic_batch_deterministic():
    cfg = get_config("qwen2.5-3b").reduced()
    b1 = synthetic_batch(cfg, 2, 16, seed=7)
    b2 = synthetic_batch(cfg, 2, 16, seed=7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    # labels are next-token shifted
    assert b1["tokens"].shape == b1["labels"].shape


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_cover_all_shapes(arch):
    cfg = get_config(arch)
    for shape in INPUT_SHAPES:
        specs = input_specs(cfg, shape)
        assert "tokens" in specs
        if shape.kind == "decode":
            assert specs["tokens"].shape == (shape.global_batch, 1)
        else:
            total = specs["tokens"].shape[1] + (
                cfg.frontend_tokens if cfg.frontend != "none" else 0
            )
            assert total == shape.seq_len
        if cfg.frontend != "none" and shape.kind != "decode":
            assert "frontend" in specs
