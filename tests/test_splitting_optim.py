"""Split-plan helpers, optimizer, checkpoint, data pipeline tests."""
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip, unit tests still run
    from _hypothesis_compat import given, settings, st

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.core.profiles import resnet101_profile, transformer_profile
from repro.core.splitting import (
    SplitPlan,
    boundary_bits,
    enumerate_boundaries,
    even_boundaries,
    plan_cost,
    stage_sums,
)
from repro.core.channel import NetworkConfig
from repro.data import input_specs, synthetic_batch
from repro.optim import adamw, clip_by_global_norm, linear_warmup_cosine, sgd_momentum
from repro.optim.optimizers import apply_updates, global_norm


@given(L=st.integers(4, 12), s=st.integers(2, 4))
@settings(max_examples=20, deadline=None)
def test_enumerate_boundaries_count(L, s):
    plans = list(enumerate_boundaries(L, s))
    assert len(plans) == math.comb(L - 1, s - 1)
    for p in plans:
        assert p[-1] == L
        assert all(b2 > b1 for b1, b2 in zip(p, p[1:]))


@given(s=st.integers(2, 6))
@settings(max_examples=10, deadline=None)
def test_stage_sums_conservation(s):
    prof = resnet101_profile(batch=1)
    b = even_boundaries(prof.num_layers, s)
    for field in ("param_bytes", "fwd_flops", "bwd_flops"):
        total = stage_sums(prof, b, field).sum()
        assert total == pytest.approx(getattr(prof, field).sum(), rel=1e-9)


def test_plan_cost_monotone_in_bits():
    prof = resnet101_profile(batch=1)
    net = NetworkConfig()
    rng = np.random.default_rng(0)
    pos = rng.uniform(0, 800, (net.num_devices + 1, 2))
    plan = SplitPlan(boundaries=even_boundaries(prof.num_layers, 4), devices=(0, 1, 2, 6))
    p_tx = np.full(3, 0.5)
    decoy = np.zeros((3, net.num_devices + 1))
    t1, e1 = plan_cost(prof, plan, pos, p_tx, decoy, net)
    # doubling all activation bytes doubles hop times
    import dataclasses

    prof2 = dataclasses.replace(
        prof, act_bytes=prof.act_bytes * 2, grad_bytes=prof.grad_bytes * 2
    )
    t2, e2 = plan_cost(prof2, plan, pos, p_tx, decoy, net)
    assert t2 > t1 and e2 > e1


def test_adamw_optimizes_quadratic():
    opt = adamw(0.1)
    params = {"x": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
        ups, state = opt.update(grads, state, params)
        params = apply_updates(params, ups)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


def test_sgd_momentum_optimizes():
    opt = sgd_momentum(0.05)
    params = {"x": jnp.array([2.0])}
    state = opt.init(params)
    for _ in range(100):
        grads = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
        ups, state = opt.update(grads, state, params)
        params = apply_updates(params, ups)
    assert abs(float(params["x"][0])) < 1e-2


@given(scale=st.floats(0.1, 100.0))
@settings(max_examples=20, deadline=None)
def test_clip_by_global_norm(scale):
    tree = {"a": jnp.ones((3,)) * scale, "b": jnp.ones((2, 2)) * scale}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-5


def test_lr_schedule():
    lr = linear_warmup_cosine(1e-3, warmup=10, total_steps=110)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-5)
    assert float(lr(110)) < float(lr(50))


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import load_pytree, save_pytree

    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nest": {"b": jnp.ones((4,), jnp.bfloat16)},
        "t": (jnp.zeros((2,)), jnp.array(3, jnp.int32)),
    }
    path = os.path.join(tmp_path, "ckpt.npz")
    save_pytree(tree, path)
    loaded = load_pytree(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
    # shape mismatch is rejected
    bad = dict(tree)
    bad["a"] = jnp.zeros((3, 3))
    with pytest.raises(ValueError):
        load_pytree(path, bad)


def test_synthetic_batch_deterministic():
    cfg = get_config("qwen2.5-3b").reduced()
    b1 = synthetic_batch(cfg, 2, 16, seed=7)
    b2 = synthetic_batch(cfg, 2, 16, seed=7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    # labels are next-token shifted
    assert b1["tokens"].shape == b1["labels"].shape


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_cover_all_shapes(arch):
    cfg = get_config(arch)
    for shape in INPUT_SHAPES:
        specs = input_specs(cfg, shape)
        assert "tokens" in specs
        if shape.kind == "decode":
            assert specs["tokens"].shape == (shape.global_batch, 1)
        else:
            total = specs["tokens"].shape[1] + (
                cfg.frontend_tokens if cfg.frontend != "none" else 0
            )
            assert total == shape.seq_len
        if cfg.frontend != "none" and shape.kind != "decode":
            assert "frontend" in specs
