"""Update hot-path tests: joint single-backward SAC update parity, fused
train-chunk semantics, scan-metric means, vectorized distinct-state keys,
and the recompile audit for the fused chunk.

Joint-update tolerance contract (documented): with ``joint_update=True``
all three heads' gradients are computed by ONE backward at the SAME
parameter point, so they must match the sequential path's per-loss
gradients to float-reassociation tolerance (rtol 2e-5). After applying
one optimizer step, critic and ICM parameters agree to the same
tolerance; ACTOR parameters differ by the advantage-freshness semantics
(the sequential path re-evaluates the stop-gradiented advantage against
the critic it just moved by one ``eta_c`` Adam step), bounded here by
5e-4 absolute - a few actor-lr quanta.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.agents import action_space as A
from repro.core.agents import icm as ICM
from repro.core.agents import rollout as R
from repro.core.agents import sac as SAC
from repro.core.agents.loops import _pack_obs_keys_np, _sac_example, _SAC_FIELDS
from repro.core.env import MHSLEnv
from repro.core.profiles import resnet101_profile
from repro.core.scenario import replace_param


@pytest.fixture(scope="module")
def env():
    return MHSLEnv(profile=resnet101_profile(batch=1))


def _real_batch(env, cfg, n_episodes=4, batch_key=9):
    """A replay batch drawn from real uniform-policy transitions."""
    params = SAC.init_agent(jax.random.PRNGKey(0), env.obs_dim,
                            env.action_dims, cfg)
    buf = R.buffer_init(512, _sac_example(env, cfg))
    rollout = R.make_batched_rollout(env, R.uniform_policy(env.action_dims),
                                     cfg.hist_len)
    st0 = R.make_batched_reset(env)(
        jax.random.split(jax.random.PRNGKey(5), n_episodes))
    _, traj = rollout(params, st0,
                      jax.random.split(jax.random.PRNGKey(6), n_episodes))
    buf = R.buffer_add(buf, R.flatten_transitions(traj, _SAC_FIELDS))
    return params, buf, R.buffer_sample(buf, jax.random.PRNGKey(batch_key),
                                        cfg.batch)


def _tree_allclose(a, b, rtol=2e-5, atol=1e-6):
    jax.tree.map(
        lambda x, y: np.testing.assert_allclose(
            np.asarray(x), np.asarray(y), rtol=rtol, atol=atol
        ),
        a, b,
    )


@pytest.mark.parametrize("use_icm,use_ca", [(True, True), (False, True),
                                            (True, False), (False, False)])
def test_joint_grads_match_per_loss_grads(env, use_icm, use_ca):
    """The single backward over joint_loss reproduces each head's gradient
    as computed by an independent backward of its own loss at the SAME
    parameter point - i.e. the stop_gradient routing leaks nothing."""
    dims = env.action_dims
    cfg = SAC.SACConfig(hidden=32, feat_dim=8, attn_dim=8, batch=16,
                        use_icm=use_icm, use_ca=use_ca)
    params, _, batch = _real_batch(env, cfg)

    (_, metrics), gj = jax.value_and_grad(SAC.joint_loss, has_aux=True)(
        params, batch, dims, cfg
    )
    if use_icm:
        r_total, _, _, _ = SAC.intrinsic_reward(params["icm"], batch, dims,
                                                cfg)
    else:
        r_total = batch["reward"]

    def loss_critic(critic_params):
        p = dict(params)
        p["critic"] = critic_params
        v = SAC.critic_v(p, batch["obs"])
        v_next = jax.lax.stop_gradient(SAC.critic_v(p, batch["obs_next"]))
        target = r_total + cfg.gamma * (1.0 - batch["done"]) * v_next
        return jnp.mean((target - v) ** 2)

    def loss_actor(actor_params):
        p = dict(params)
        p["actor"] = actor_params
        logits = SAC.actor_logits(p, batch["obs"], batch["hist"],
                                  batch["hist_mask"], batch["masks"], dims,
                                  cfg)
        lp = A.log_prob(logits, batch["action"])
        ent = A.entropy(logits)
        v = SAC.critic_v(p, batch["obs"])
        v_next = SAC.critic_v(p, batch["obs_next"])
        y = jax.lax.stop_gradient(
            r_total + cfg.gamma * (1.0 - batch["done"]) * v_next - v
        )
        return -jnp.mean(lp * y + cfg.alpha * ent)

    lc, gc = jax.value_and_grad(loss_critic)(params["critic"])
    la, ga = jax.value_and_grad(loss_actor)(params["actor"])
    _tree_allclose(gj["critic"], gc)
    _tree_allclose(gj["actor"], ga)
    np.testing.assert_allclose(float(metrics["critic_loss"]), float(lc),
                               rtol=1e-6)
    np.testing.assert_allclose(float(metrics["actor_loss"]), float(la),
                               rtol=1e-6)

    if use_icm:
        def loss_icm(icm_params):
            avec = A.onehot(batch["action"], dims)
            l_i, l_f, _ = ICM.icm_losses(icm_params, batch["obs"],
                                         batch["obs_next"], batch["action"],
                                         avec, dims)
            return l_f + cfg.v_inv * l_i

        _, gi = jax.value_and_grad(loss_icm)(params["icm"])
        _tree_allclose(gj["icm"], gi)
    else:
        assert "icm" not in gj


def test_joint_update_step_matches_sequential(env):
    """One update step: critic/ICM land on the same parameters as the
    sequential path (same grads, same optimizer); the actor agrees to the
    documented advantage-freshness tolerance; shared-metric values match
    except actor_loss (evaluated pre- vs post-critic-step)."""
    dims = env.action_dims
    cfg_j = SAC.SACConfig(hidden=32, feat_dim=8, attn_dim=8, batch=16)
    cfg_s = SAC.SACConfig(hidden=32, feat_dim=8, attn_dim=8, batch=16,
                          joint_update=False)
    params, _, batch = _real_batch(env, cfg_j)

    upd_j, init_j = SAC.make_update(dims, cfg_j)
    upd_s, init_s = SAC.make_update(dims, cfg_s)
    pj, oj, mj = upd_j(params, init_j(params), batch)
    ps, os_, ms = upd_s(params, init_s(params), batch)

    _tree_allclose(pj["critic"], ps["critic"])
    _tree_allclose(pj["icm"], ps["icm"])
    _tree_allclose(jax.tree.map(lambda x: x, oj["critic"]), os_["critic"])
    for k in ("critic_loss", "r_c", "icm_inv_loss", "icm_fwd_loss"):
        np.testing.assert_allclose(float(mj[k]), float(ms[k]), rtol=1e-5,
                                   atol=1e-7)
    # actor: bounded by the one-eta_c-step advantage staleness
    diffs = [np.abs(np.asarray(a) - np.asarray(b)).max()
             for a, b in zip(jax.tree.leaves(pj["actor"]),
                             jax.tree.leaves(ps["actor"]))]
    assert max(diffs) < 5e-4, diffs
    # actor_loss is evaluated against pre- vs post-step critic values, so
    # it agrees only to the relative scale of the advantage staleness
    np.testing.assert_allclose(float(mj["actor_loss"]),
                               float(ms["actor_loss"]), rtol=2e-2)


def test_fused_scan_metrics_are_means():
    """make_fused_update / make_scan_updates report per-metric MEANS over
    the scan, not the final step's sample."""
    def update_fn(params, opt_state, batch):
        step = params + 1.0
        return step, opt_state, {"step": step}

    buf = R.buffer_init(8, {"x": jnp.zeros(())})
    buf = R.buffer_add(buf, {"x": jnp.arange(8.0)})
    p0 = jnp.zeros(())
    _, _, m = R.make_fused_update(update_fn, 2, 5)(p0, (), buf,
                                                   jax.random.PRNGKey(0))
    np.testing.assert_allclose(float(m["step"]), np.mean([1, 2, 3, 4, 5]))

    def scan_update(params, opt_state, batch):
        step = params + 1.0
        return step, opt_state, {"step": step}

    _, _, m = R.make_scan_updates(scan_update, 4)(p0, (), {"x": jnp.zeros(2)})
    np.testing.assert_allclose(float(m["step"]), np.mean([1, 2, 3, 4]))


def test_packed_obs_keys_match_legacy_hash_counts(env):
    """The vectorized packing gives exactly the legacy _obs_hash's
    distinct-state counts on real trajectories, and the device lanes
    reassemble to the host keys bit-for-bit."""

    def legacy_obs_hash(obs, bins=4.0):  # the pre-refactor row hash
        o = np.asarray(obs)
        discrete = o[3:]
        head = np.round(o[:3] * bins)
        return hash(tuple(np.round(discrete * bins).astype(np.int64).tolist())
                    + tuple(head.astype(np.int64).tolist()))

    cfg = SAC.SACConfig(hidden=16, feat_dim=4, attn_dim=8)
    params = SAC.init_agent(jax.random.PRNGKey(0), env.obs_dim,
                            env.action_dims, cfg)
    rollout = R.make_batched_rollout(env, R.uniform_policy(env.action_dims),
                                     cfg.hist_len)
    st0 = R.make_batched_reset(env)(jax.random.split(jax.random.PRNGKey(1), 6))
    _, traj = rollout(params, st0, jax.random.split(jax.random.PRNGKey(2), 6))
    obs = np.asarray(traj["obs"])  # (6, T, D)

    legacy_seen, new_seen = set(), set()
    keys = _pack_obs_keys_np(obs)
    legacy_counts, new_counts = [], []
    for i in range(obs.shape[0]):
        for row in obs[i]:
            legacy_seen.add(legacy_obs_hash(row))
        new_seen.update(int(k) for k in np.unique(keys[i]))
        legacy_counts.append(len(legacy_seen))
        new_counts.append(len(new_seen))
    assert new_counts == legacy_counts

    lanes = np.asarray(R.pack_obs_keys(traj["obs"]))
    combined = ((lanes[..., 0].astype(np.uint64) << np.uint64(32))
                | lanes[..., 1].astype(np.uint64))
    np.testing.assert_array_equal(combined, keys)


def test_train_chunk_matches_unfused_pieces(env):
    """One fused chunk call reproduces the unfused engine ops it replaced:
    same rollout sums, same buffer contents, same packed keys, and the
    cond-gated update scan matches make_fused_update on the same key."""
    cfg = SAC.SACConfig(hidden=16, feat_dim=4, attn_dim=8, batch=8,
                        buffer_size=128)
    dims = env.action_dims
    params = SAC.init_agent(jax.random.PRNGKey(0), env.obs_dim, dims, cfg)
    update, init_opt = SAC.make_update(dims, cfg)
    opt_state = init_opt(params)
    n_updates = 3
    num_envs = 4

    chunk = R.make_train_chunk(
        env, R.uniform_policy(dims), R.sac_policy(dims, cfg), update,
        hist_len=cfg.hist_len, fields=_SAC_FIELDS, batch_size=cfg.batch,
        n_updates=n_updates,
    )
    rkeys = jax.random.split(jax.random.PRNGKey(1), num_envs)
    akeys = jax.random.split(jax.random.PRNGKey(2), num_envs)
    ukey = jax.random.PRNGKey(3)

    buf0 = R.buffer_init(cfg.buffer_size, _sac_example(env, cfg))
    p1, o1, buf1, m1 = chunk(params, opt_state, buf0,
                             rkeys, akeys, ukey, jnp.asarray(False))
    # warmup chunk: no update ran, params/opt untouched, update metrics zero
    assert not bool(m1["did_update"])
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), p1, params)
    assert all(float(v) == 0.0 for v in jax.tree.leaves(m1["update"]))

    # reference: the previously-separate dispatches with the same keys
    st0 = R.make_batched_reset(env)(rkeys)
    rollout = R.make_batched_rollout(env, R.uniform_policy(dims),
                                     cfg.hist_len)
    _, traj = rollout(params, st0, akeys)
    np.testing.assert_allclose(np.asarray(m1["reward"]),
                               np.asarray(traj["reward"].sum(1)), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(m1["leak"]),
                               np.asarray(traj["leak"].sum(1)), rtol=1e-6)
    np.testing.assert_array_equal(
        np.asarray(m1["obs_keys"]),
        np.asarray(R.pack_obs_keys(traj["obs"])))
    buf_ref = R.buffer_add(buf0, R.flatten_transitions(traj, _SAC_FIELDS))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), buf1.data, buf_ref.data)

    # training chunk: buffer now holds >= batch rows, so the update runs
    # and matches make_fused_update applied to the post-add buffer
    p2, o2, buf2, m2 = chunk(params, opt_state, buf1,
                             rkeys, akeys, ukey, jnp.asarray(True))
    assert bool(m2["did_update"])
    st0 = R.make_batched_reset(env)(rkeys)
    actor_roll = R.make_batched_rollout(env, R.sac_policy(dims, cfg),
                                        cfg.hist_len)
    _, traj2 = actor_roll(params, st0, akeys)
    buf_ref2 = R.buffer_add(buf_ref, R.flatten_transitions(traj2, _SAC_FIELDS))
    fused = R.make_fused_update(update, cfg.batch, n_updates)
    p_ref, o_ref, m_ref = fused(params, opt_state, buf_ref2, ukey)
    _tree_allclose(p2, p_ref)
    _tree_allclose(m2["update"], m_ref, atol=1e-5)


def test_train_chunk_compiles_once(env):
    """Recompile audit: warmup -> train transition, repeated chunks, and a
    multi-point scenario sweep all reuse ONE compiled fused chunk."""
    cfg = SAC.SACConfig(hidden=16, feat_dim=4, attn_dim=8, batch=8,
                        buffer_size=128)
    dims = env.action_dims
    params = SAC.init_agent(jax.random.PRNGKey(0), env.obs_dim, dims, cfg)
    update, init_opt = SAC.make_update(dims, cfg)
    opt_state = init_opt(params)
    chunk = R.make_train_chunk(
        env, R.uniform_policy(dims), R.sac_policy(dims, cfg), update,
        hist_len=cfg.hist_len, fields=_SAC_FIELDS, batch_size=cfg.batch,
        n_updates=2,
    )
    buf = R.buffer_init(cfg.buffer_size, _sac_example(env, cfg))
    rkeys = jax.random.split(jax.random.PRNGKey(1), 2)
    base = env.scenario()
    sweep = [None, None,  # warmup chunks
             replace_param(base, "monitor_prob", 0.3),
             replace_param(base, "monitor_prob", 0.9),
             replace_param(base, "gamma_e", 40.0)]
    for i, sp in enumerate(sweep):
        akeys = jax.random.split(jax.random.PRNGKey(10 + i), 2)
        params, opt_state, buf, _ = chunk(
            params, opt_state, buf, rkeys, akeys,
            jax.random.PRNGKey(20 + i), jnp.asarray(i >= 2), sp,
        )
    assert chunk.trace_count[0] == 1
