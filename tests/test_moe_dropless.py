"""Dropless MoE dispatch parity (tentpole PR 9).

``layers.moe_apply_dropless`` (stable-sort grouping + block-padded
grouped matmul) must be BITWISE-equal to the dense per-expert reference
``layers.moe_apply_dense`` - same routing (shared ``_moe_route``), same
per-row arithmetic, merely regrouped. Bitwise parity is what retired the
``jamba_decode`` xfail: the capacity path drops different (token, choice)
pairs at different group sizes, so decode-time groups disagreed with
prefill; the dropless path computes every routed pair, so outputs are
independent of grouping - pinned here directly by the block-size and
decode-slice invariance tests.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import layers as L
from repro.models.model import init_block, signature

ARCHS = ["qwen3-moe-30b-a3b", "jamba-v0.1-52b"]


def _setup(arch, seed=0, b=2, s=16):
    cfg = get_config(arch).reduced()
    slot = next(sig for sig in signature(cfg) if sig[1])  # a MoE slot
    params = init_block(jax.random.PRNGKey(seed), cfg, slot)["moe"]
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)), jnp.float32)
    return cfg, params, x


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("block_size", [8, 32])
def test_dropless_reference_bitwise_vs_dense(arch, block_size):
    cfg, params, x = _setup(arch)
    y_dense, aux_dense = L.moe_apply_dense(params, x, cfg)
    y_ref, aux_ref = L.moe_apply_dropless(
        params, x, cfg, impl="reference", block_size=block_size)
    assert jnp.array_equal(y_dense, y_ref), (
        f"dropless(block_size={block_size}) != dense per-expert reference")
    np.testing.assert_allclose(np.asarray(aux_ref), np.asarray(aux_dense),
                               rtol=1e-6)


@pytest.mark.parametrize("arch", ARCHS)
def test_dropless_pallas_matches_dense(arch):
    cfg, params, x = _setup(arch)
    y_dense, _ = L.moe_apply_dense(params, x, cfg)
    y_pal, _ = L.moe_apply_dropless(params, x, cfg, impl="pallas",
                                    block_size=32)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_dense),
                               rtol=2e-6, atol=2e-6)


@pytest.mark.parametrize("arch", ARCHS)
def test_dropless_decode_slice_bitwise(arch):
    """Group-size independence - the property the capacity path lacks:
    dispatching a single decode position alone must produce BITWISE the
    same rows as dispatching it inside the full prefill batch."""
    cfg, params, x = _setup(arch)
    y_full, _ = L.moe_apply_dropless(params, x, cfg, impl="reference",
                                     block_size=32)
    y_last, _ = L.moe_apply_dropless(params, x[:, -1:], cfg,
                                     impl="reference", block_size=32)
    assert jnp.array_equal(y_full[:, -1:], y_last)


def test_dropless_grads_match_dense():
    cfg, params, x = _setup("qwen3-moe-30b-a3b")

    def loss(impl):
        def f(p, xx):
            if impl == "dense":
                y, _ = L.moe_apply_dense(p, xx, cfg)
            else:
                y, _ = L.moe_apply_dropless(p, xx, cfg, impl="reference",
                                            block_size=32)
            return jnp.mean(y * y)
        return jax.value_and_grad(f, argnums=(0, 1))

    v_dense, g_dense = jax.jit(loss("dense"))(params, x)
    v_drop, g_drop = jax.jit(loss("dropless"))(params, x)
    np.testing.assert_allclose(float(v_drop), float(v_dense), rtol=2e-6)
    flat_dense = jax.tree.leaves(g_dense)
    flat_drop = jax.tree.leaves(g_drop)
    assert len(flat_dense) == len(flat_drop)
    for a, b in zip(flat_dense, flat_drop):
        assert bool(jnp.all(jnp.isfinite(b)))
        scale = float(jnp.max(jnp.abs(a))) or 1.0
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=2e-5, atol=2e-5 * scale)
