"""End-to-end behaviour tests: the RL agent produces a valid split plan for
a real architecture, and that plan executes as an actual pipelined training
step whose loss matches single-device execution."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.agents.loops import train_sac
from repro.core.agents.sac import SACConfig
from repro.core.env import MHSLEnv
from repro.core.profiles import resnet101_profile, transformer_profile


def _rollout_plan(env, params, cfg, seed=123):
    """Greedy rollout of a trained policy -> (boundaries, devices)."""
    from repro.core.agents import action_space as A
    from repro.core.agents import sac as SAC

    key = jax.random.PRNGKey(seed)
    st = env.reset(jax.random.PRNGKey(0))
    pair_dim = env.obs_dim + A.flat_dim(env.action_dims)
    hist = jnp.zeros((cfg.hist_len, pair_dim))
    hmask = jnp.zeros((cfg.hist_len,))
    for t in range(env.episode_len):
        key, ka, ks = jax.random.split(key, 3)
        obs = env.observe(st)
        masks = env.action_masks(st)
        a = SAC.select_action(params, ka, obs, hist, hmask, masks, env.action_dims, cfg)
        pair = jnp.concatenate([obs, A.onehot(a, env.action_dims)])
        hist = jnp.roll(hist, -1, axis=0).at[-1].set(pair)
        hmask = jnp.roll(hmask, -1).at[-1].set(1.0)
        st, r, done, info = env.step(st, a, ks)
    return tuple(int(b) for b in np.asarray(st.boundaries)), tuple(
        int(d) for d in np.asarray(st.stage_dev)
    )


def test_rl_agent_emits_valid_plan_for_transformer():
    cfg_model = get_config("qwen2.5-3b")
    prof = transformer_profile(cfg_model, batch=1, seq=128)
    env = MHSLEnv(profile=prof)
    cfg = SACConfig(hidden=32, feat_dim=8, attn_dim=8, batch=32, buffer_size=2000)
    res = train_sac(env, cfg, episodes=12, warmup_episodes=4)
    boundaries, devices = _rollout_plan(env, res.params, cfg)
    assert boundaries[-1] == cfg_model.num_layers
    assert all(b2 > b1 for b1, b2 in zip(boundaries, boundaries[1:]))
    assert devices[-1] == env.U  # server holds the head
    assert len(set(devices)) == env.S


def test_training_improves_over_random():
    """After training, ICM-CA SAC beats the random-policy return on the
    fixed geometry (coarse check - full curves live in benchmarks)."""
    env = MHSLEnv(profile=resnet101_profile(batch=1))
    cfg = SACConfig(hidden=64, feat_dim=16, attn_dim=16, batch=64, buffer_size=5000)
    res = train_sac(env, cfg, episodes=60, warmup_episodes=8)
    first = np.mean(res.episode_reward[:8])  # random warmup episodes
    last = np.mean(res.episode_reward[-10:])
    assert last > first, (first, last)


def test_rl_plan_executes_as_pipeline(subproc):
    """The full loop: env plan -> pipeline execution on multiple devices."""
    out = subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from dataclasses import replace
from repro.configs import get_config
from repro.models import init_params, loss_fn
from repro.core.pipeline import pipeline_loss_fn, make_stage_mesh
from repro.core.env import MHSLEnv
from repro.core.profiles import transformer_profile
from repro.core.channel import NetworkConfig

# a 6-layer reduced model split into S=3 stages by an env rollout
cfg = replace(get_config('stablelm-1.6b').reduced(), num_layers=6)
prof = transformer_profile(cfg, batch=1, seq=64)
net = NetworkConfig(max_split=3)
env = MHSLEnv(profile=prof, net=net)
key = jax.random.PRNGKey(0)
st = env.reset(key)
for t in range(env.episode_len):
    key, ka, ks = jax.random.split(key, 3)
    masks = env.action_masks(st)
    a = {'u': jnp.argmax(masks['u']), 'size': jnp.argmax(masks['size']),
         'decoys': jnp.zeros(env.U, jnp.int32), 'p_tx': jnp.array(2), 'p_d': jnp.array(0)}
    st, *_ = env.step(st, a, ks)
boundaries = tuple(int(b) for b in np.asarray(st.boundaries))
assert boundaries[-1] == 6

params = init_params(jax.random.PRNGKey(0), cfg)
mesh = make_stage_mesh(3)
rng = np.random.default_rng(0)
tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32)
labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32)
pl = pipeline_loss_fn(cfg, mesh, boundaries=boundaries, n_microbatches=2)
loss_pipe = float(jax.jit(pl)(params, tokens, labels))
ref = float(loss_fn(params, {'tokens': tokens, 'labels': labels}, cfg, remat=False)[0])
assert abs(loss_pipe - ref) < 5e-3, (loss_pipe, ref, boundaries)
print('E2E_OK', boundaries)
""",
        n_devices=3,
        timeout=420,
    )
    assert "E2E_OK" in out
