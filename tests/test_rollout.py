"""Device-resident rollout engine tests: replay-buffer parity with the
legacy numpy buffer, scanned-rollout equivalence with the per-step loop,
and fused-update equivalence with sequential gradient steps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.agents import action_space as A
from repro.core.agents import rollout as R
from repro.core.agents import sac as SAC
from repro.core.agents.buffer import ReplayBuffer
from repro.core.env import MHSLEnv
from repro.core.profiles import resnet101_profile


@pytest.fixture(scope="module")
def env():
    return MHSLEnv(profile=resnet101_profile(batch=1))


def _mixed_item(i: int):
    """One transition with nested dicts and mixed dtypes."""
    return dict(
        obs=np.full((5,), i, np.float32),
        action={
            "u": np.int32(i),
            "decoys": np.full((3,), i, np.int32),
        },
        masks={"u": np.array([i % 2 == 0, True], bool)},
        reward=np.float32(-i),
        done=np.float32(i % 2),
    )


def _stack_items(lo: int, hi: int):
    return jax.tree.map(
        lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]),
        *[_mixed_item(i) for i in range(lo, hi)],
    )


def _check_store_parity(np_buf, dev_buf):
    assert int(dev_buf.size) == np_buf.size
    assert int(dev_buf.ptr) == np_buf.ptr

    def check(np_leaf, dev_leaf):
        dev_np = np.asarray(dev_leaf)
        assert dev_np.dtype == np_leaf.dtype
        np.testing.assert_array_equal(dev_np, np_leaf)

    jax.tree.map(check, np_buf.store, dev_buf.data)


def test_device_buffer_matches_numpy_wraparound():
    """Ring semantics, dtype round-trip, and stored contents match the
    legacy host-numpy ReplayBuffer exactly, including capacity wraparound."""
    capacity, total = 8, 11
    np_buf = ReplayBuffer(capacity, _mixed_item(0))
    dev_buf = R.buffer_init(capacity, jax.tree.map(jnp.asarray, _mixed_item(0)))

    for i in range(total):
        np_buf.add(_mixed_item(i))
    # device buffer writes in batches (4 + 4 + 3) over the same items
    for lo, hi in ((0, 4), (4, 8), (8, 11)):
        dev_buf = R.buffer_add(dev_buf, _stack_items(lo, hi))

    assert int(dev_buf.size) == np_buf.size == capacity
    assert int(dev_buf.ptr) == np_buf.ptr == total % capacity
    _check_store_parity(np_buf, dev_buf)


def test_device_buffer_batch_larger_than_capacity():
    """One batched write bigger than the whole ring keeps exactly the last
    ``capacity`` rows, like adding the items one-by-one to the host buffer."""
    capacity, total = 4, 11
    np_buf = ReplayBuffer(capacity, _mixed_item(0))
    for i in range(total):
        np_buf.add(_mixed_item(i))
    dev_buf = R.buffer_init(capacity, jax.tree.map(jnp.asarray, _mixed_item(0)))
    dev_buf = R.buffer_add(dev_buf, _stack_items(0, total))
    _check_store_parity(np_buf, dev_buf)

    # sampling round-trips dtypes and only returns stored rows
    sample = R.buffer_sample(dev_buf, jax.random.PRNGKey(0), 16)
    assert np.asarray(sample["action"]["u"]).dtype == np.int32
    assert np.asarray(sample["masks"]["u"]).dtype == np.bool_
    assert sample["obs"].shape == (16, 5)
    assert set(np.asarray(sample["obs"])[:, 0]) <= set(range(3, 11))


def test_scanned_rollout_bit_identical_to_python_loop(env):
    """The lax.scan rollout with fixed PRNG keys reproduces the legacy
    per-step Python loop bit-for-bit: same EnvState trajectory, same
    rewards. This pins that the >=5x throughput win changes no semantics."""
    cfg = SAC.SACConfig(hidden=32, feat_dim=8, attn_dim=8)
    adims = env.action_dims
    params = SAC.init_agent(jax.random.PRNGKey(0), env.obs_dim, adims, cfg)
    policy = R.sac_policy(adims, cfg)

    st0 = env.reset(jax.random.PRNGKey(42))
    key = jax.random.PRNGKey(7)

    legacy = R.make_legacy_episode(env, policy, cfg.hist_len)
    ref_states, ref_rewards = legacy(params, st0, key)

    scan = jax.jit(
        R.make_episode_rollout(env, policy, cfg.hist_len, record_state=True)
    )
    st_final, traj = scan(params, st0, key)

    ref_stack = jax.tree.map(lambda *xs: np.stack([np.asarray(x) for x in xs]),
                             *ref_states)
    for name, ref_leaf, scan_leaf in zip(
        ref_stack._fields, ref_stack, traj["env_state"]
    ):
        np.testing.assert_array_equal(
            np.asarray(scan_leaf), np.asarray(ref_leaf),
            err_msg=f"EnvState field {name!r} diverged",
        )
    np.testing.assert_array_equal(
        np.asarray(traj["reward"]),
        np.asarray([np.float32(r) for r in ref_rewards]),
    )
    # final carry state == last recorded state
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)[-1]),
        st_final, traj["env_state"],
    )


def test_vmapped_rollout_rows_match_single_env(env):
    """Each row of the vmapped population equals an independent single-env
    rollout with the same keys."""
    cfg = SAC.SACConfig(hidden=16, feat_dim=4, attn_dim=8)
    adims = env.action_dims
    params = SAC.init_agent(jax.random.PRNGKey(1), env.obs_dim, adims, cfg)
    policy = R.sac_policy(adims, cfg)
    n = 3

    rkeys = jax.random.split(jax.random.PRNGKey(2), n)
    akeys = jax.random.split(jax.random.PRNGKey(3), n)
    st0 = R.make_batched_reset(env)(rkeys)
    _, traj = R.make_batched_rollout(env, policy, cfg.hist_len)(
        params, st0, akeys
    )

    one = jax.jit(R.make_episode_rollout(env, policy, cfg.hist_len))
    for i in range(n):
        _, ti = one(params, env.reset(rkeys[i]), akeys[i])
        np.testing.assert_allclose(
            np.asarray(traj["reward"][i]), np.asarray(ti["reward"]),
            rtol=1e-6, atol=1e-6,
        )
        np.testing.assert_allclose(
            np.asarray(traj["obs"][i]), np.asarray(ti["obs"]),
            rtol=1e-6, atol=1e-6,
        )


def test_trainers_vectorized_num_envs(env):
    """The num_envs>1 chunked paths across all three trainers: odd episode
    counts truncate the final chunk's metrics to exactly `episodes`, curves
    stay finite, the distinct-state counter is cumulative, and num_envs<1
    is rejected instead of looping forever."""
    from repro.core.agents.dqn import DQNConfig, train_dqn
    from repro.core.agents.loops import train_sac
    from repro.core.agents.ppo import PPOConfig, train_ppo

    sac_cfg = SAC.SACConfig(hidden=16, feat_dim=4, attn_dim=8, batch=8,
                            buffer_size=300)
    res = train_sac(env, sac_cfg, episodes=5, warmup_episodes=2, num_envs=2)
    assert len(res.episode_reward) == 5  # 3 chunks of 2, last truncated
    assert all(np.isfinite(r) for r in res.episode_reward)
    assert res.states_explored == sorted(res.states_explored)

    res = train_dqn(env, DQNConfig(hidden=16, batch=8, buffer_size=300),
                    episodes=5, num_envs=2)
    assert len(res.episode_reward) == 5
    assert all(np.isfinite(r) for r in res.episode_reward)

    res = train_ppo(env, PPOConfig(hidden=16, episodes_per_batch=2),
                    episodes=4, num_envs=2)
    assert len(res.episode_reward) == 4
    assert all(np.isfinite(r) for r in res.episode_reward)

    for fn, cfg in ((train_sac, sac_cfg), (train_dqn, DQNConfig()),
                    (train_ppo, PPOConfig())):
        with pytest.raises(ValueError, match="num_envs"):
            fn(env, cfg, episodes=2, num_envs=0)


def test_fused_update_matches_sequential_updates(env):
    """make_fused_update's scanned gradient steps produce the same params
    as calling the jitted update step-by-step on the same minibatches."""
    cfg = SAC.SACConfig(hidden=16, feat_dim=4, attn_dim=8, batch=8,
                        buffer_size=64, updates_per_step=1)
    adims = env.action_dims
    params = SAC.init_agent(jax.random.PRNGKey(0), env.obs_dim, adims, cfg)
    update, init_opt = SAC.make_update(adims, cfg)
    opt_state = init_opt(params)

    # fill a small buffer from a real uniform-policy rollout
    from repro.core.agents.loops import _SAC_FIELDS, _sac_example

    buf = R.buffer_init(cfg.buffer_size, _sac_example(env, cfg))
    rollout = R.make_batched_rollout(env, R.uniform_policy(adims), cfg.hist_len)
    st0 = R.make_batched_reset(env)(jax.random.split(jax.random.PRNGKey(5), 4))
    _, traj = rollout(params, st0, jax.random.split(jax.random.PRNGKey(6), 4))
    buf = R.buffer_add(buf, R.flatten_transitions(traj, _SAC_FIELDS))

    n_updates = 5
    key = jax.random.PRNGKey(9)
    fused = R.make_fused_update(update, cfg.batch, n_updates)
    p_fused, _, _ = fused(params, opt_state, buf, key)

    # replay the exact same pre-sampled indices sequentially
    idx = jax.random.randint(
        key, (n_updates, cfg.batch), 0, jnp.maximum(buf.size, 1)
    )
    p_seq, o_seq = params, opt_state
    for row in idx:
        p_seq, o_seq, _ = update(p_seq, o_seq, R.buffer_gather(buf, row))

    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-5, atol=1e-6
        ),
        p_fused, p_seq,
    )
